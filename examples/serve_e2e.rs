//! End-to-end serving driver (the EXPERIMENTS.md validation run): load
//! the real tiny Qwen3-style model (AOT-compiled HLO artifacts), serve a
//! wave of batched requests through the megakernel with continuous
//! batching + paged KV, and report latency/throughput — all layers
//! composing: Pallas kernels (L1) → JAX model artifacts (L2) → rust
//! coordinator + PJRT runtime (L3).
//!
//! The decode inner loop is zero-copy **and allocation-free** end to
//! end: task inputs are slices borrowed from the session tensor arena,
//! task results land directly in their destination tensors through the
//! pool's write-into boundary (`execute_into` — no output `Vec` per
//! task), every batch-size specialization aliases one shared max-batch
//! KV arena (batch transitions move no cache rows) and one shared
//! weight arena (weights synthesized exactly once, whatever the number
//! of specializations), batch slots are stable (retirements never
//! remap a survivor), and the store + pool counters prove it — this
//! driver asserts all of those invariants.
//!
//! ```bash
//! make artifacts && cargo run --release --example serve_e2e
//! ```

use mpk::exec::real::{self, RealSession};
use mpk::exec::TileExecutor;
use mpk::megakernel::MegaConfig;
use mpk::serving::{Request, ServeEngine};

fn main() {
    let mega = MegaConfig { workers: 6, schedulers: 2, ..Default::default() };

    // --- correctness gate: megakernel logits vs fused reference HLO ---
    println!("== validation: tiled megakernel vs fused reference (batch 2, 3 steps) ==");
    let s = RealSession::create(2, 2, 42)
        .expect("needs `make artifacts` and a real PJRT backend (offline builds ship the xla stub)");
    // resident persistent kernel re-armed per step — the validation
    // session outlives each run, same as serving.
    let mut kernel = s.persistent_kernel(mega.workers, mega.schedulers);
    let exec = TileExecutor::new(&s.compiled.graph, &s.store, &s.pool, 2);
    let mut ids = vec![3i32, 11];
    for step in 0..3 {
        real::set_ids(&s.compiled.graph, &s.store, &ids);
        let want = real::run_reference(&s.manifest, &s.pool, &s.compiled.graph, &s.store, 2, &ids, step)
            .expect("reference");
        real::run_iteration(&mut kernel, &exec, step).expect("megakernel");
        let got = real::get_logits(&s.compiled.graph, &s.store);
        let max_err = got.iter().zip(&want).map(|(a, b)| (a - b).abs()).fold(0.0f32, f32::max);
        println!("  step {step}: max |logit diff| = {max_err:.2e}");
        assert!(max_err < 1e-3, "validation failed");
        let vocab = s.manifest.model.vocab;
        ids = (0..2).map(|r| real::argmax(&got[r * vocab..(r + 1) * vocab]) as i32).collect();
    }
    drop(kernel);
    drop(s);

    // --- the serving run ---
    println!("\n== serving: 12 requests, max batch 8, continuous batching ==");
    let mut engine = ServeEngine::create(8, 3, 42, mega).expect("engine");
    for i in 0..12u64 {
        // uniform lengths: the wave admits together and retires
        // together, so the whole run is steady-state — the shared
        // max-batch KV arena must move zero rows even as the batch size
        // ramps 8 → 4 across waves. (Staggered per-row cache lengths
        // are covered by the engine's continuous-batching tests.)
        let prompt: Vec<i32> = (0..3).map(|t| 1 + (i as i32 * 7 + t) % 500).collect();
        engine.submit(Request::new(i, prompt, 8)).expect("request within max_seq");
    }
    let (outputs, stats) = engine.serve().expect("serve");

    println!("requests completed : {}", outputs.len());
    println!("tokens generated   : {}", stats.tokens_generated);
    println!("decode iterations  : {}", stats.iterations);
    println!("total wall time    : {:?}", stats.total);
    println!("p50 iter latency   : {:?}", stats.p50_latency());
    println!("p99 iter latency   : {:?}", stats.p99_latency());
    println!("throughput         : {:.1} tok/s", stats.throughput_tok_s());
    let max_b = stats.batch_sizes.iter().max().unwrap();
    println!("peak batch         : {max_b} (graphs specialized per power-of-two batch)");
    println!(
        "KV rows migrated   : {} (stable slots + shared max-batch arena: structurally zero)",
        stats.kv_rows_migrated
    );
    assert_eq!(stats.kv_rows_migrated, 0, "serving must not move KV rows");
    let (allocs, bytes) = engine.store_counters();
    println!("store copies       : {allocs} allocs / {bytes} bytes (zero-copy borrowed-view hot path)");
    assert_eq!((allocs, bytes), (0, 0), "decode hot path copied tensor data");
    println!(
        "pool output allocs : {} (execute_into boundary: results land in the arena)",
        engine.output_allocs()
    );
    assert_eq!(engine.output_allocs(), 0, "decode hot path received an allocated output buffer");
    println!(
        "weight arena       : {} f32 elements shared by every specialization, {} init run(s)",
        engine.weight_arena_len(),
        engine.weight_init_runs()
    );
    assert_eq!(engine.weight_init_runs(), 1, "weights must be synthesized exactly once");
    let mut sample: Vec<_> = outputs.iter().collect();
    sample.sort();
    for (id, toks) in sample.iter().take(3) {
        println!("  req {id}: {toks:?}");
    }
    println!("\nall layers composed: Pallas kernels -> HLO artifacts -> PJRT pool -> megakernel");
}
