//! End-to-end serving driver (the EXPERIMENTS.md validation run): load
//! the real tiny Qwen3-style model (AOT-compiled HLO artifacts), serve a
//! wave of batched requests through the megakernel with continuous
//! batching + paged KV, and report latency/throughput — all layers
//! composing: Pallas kernels (L1) → JAX model artifacts (L2) → rust
//! coordinator + PJRT runtime (L3).
//!
//! The decode inner loop is zero-copy **and allocation-free** end to
//! end: task inputs are slices borrowed from the session tensor arena,
//! task results land directly in their destination tensors through the
//! pool's write-into boundary (`execute_into` — no output `Vec` per
//! task), every batch-size specialization aliases one shared max-batch
//! KV arena (batch transitions move no cache rows) and one shared
//! weight arena (weights synthesized exactly once, whatever the number
//! of specializations), batch slots are stable (retirements never
//! remap a survivor), and the store + pool counters prove it — this
//! driver asserts all of those invariants.
//!
//! ```bash
//! make artifacts && cargo run --release --example serve_e2e
//! ```

use mpk::exec::real::{self, RealSession};
use mpk::exec::TileExecutor;
use mpk::megakernel::MegaConfig;
use mpk::serving::{FinishReason, Request, ServeEngine, TokenEvent};

fn main() {
    let mega = MegaConfig { workers: 6, schedulers: 2, ..Default::default() };

    // --- correctness gate: megakernel logits vs fused reference HLO ---
    println!("== validation: tiled megakernel vs fused reference (batch 2, 3 steps) ==");
    let s = RealSession::create(2, 2, 42)
        .expect("needs `make artifacts` and a real PJRT backend (offline builds ship the xla stub)");
    // resident persistent kernel re-armed per step — the validation
    // session outlives each run, same as serving.
    let mut kernel = s.persistent_kernel(mega.workers, mega.schedulers);
    let exec = TileExecutor::new(&s.compiled.graph, &s.store, &s.pool, 2);
    let mut ids = vec![3i32, 11];
    for step in 0..3 {
        real::set_ids(&s.compiled.graph, &s.store, &ids);
        let want = real::run_reference(&s.manifest, &s.pool, &s.compiled.graph, &s.store, 2, &ids, step)
            .expect("reference");
        real::run_iteration(&mut kernel, &exec, step).expect("megakernel");
        let got = real::get_logits(&s.compiled.graph, &s.store);
        let max_err = got.iter().zip(&want).map(|(a, b)| (a - b).abs()).fold(0.0f32, f32::max);
        println!("  step {step}: max |logit diff| = {max_err:.2e}");
        assert!(max_err < 1e-3, "validation failed");
        let vocab = s.manifest.model.vocab;
        ids = (0..2).map(|r| real::argmax(&got[r * vocab..(r + 1) * vocab]) as i32).collect();
    }
    drop(kernel);
    drop(s);

    // --- the serving run (batch mode: serve() is a thin loop over
    //     step(), so this also exercises the step-driven core) ---
    println!("\n== serving: 12 requests, max batch 8, continuous batching ==");
    let mut engine = ServeEngine::builder()
        .max_batch(8)
        .pool_threads(3)
        .seed(42)
        .mega(mega)
        .build()
        .expect("engine");
    for i in 0..12u64 {
        // uniform lengths: the wave admits together and retires
        // together, so the whole run is steady-state — the shared
        // max-batch KV arena must move zero rows even as the batch size
        // ramps 8 → 4 across waves. (Staggered per-row cache lengths
        // are covered by the engine's continuous-batching tests.)
        let prompt: Vec<i32> = (0..3).map(|t| 1 + (i as i32 * 7 + t) % 500).collect();
        engine.submit(Request::new(i, prompt, 8)).expect("request within max_seq");
    }
    let (outputs, stats) = engine.serve().expect("serve");

    println!("requests completed : {}", outputs.len());
    println!("tokens generated   : {}", stats.tokens_generated);
    println!("decode iterations  : {}", stats.iterations);
    println!("total wall time    : {:?}", stats.total);
    println!("p50 iter latency   : {:?}", stats.p50_latency());
    println!("p99 iter latency   : {:?}", stats.p99_latency());
    println!("throughput         : {:.1} tok/s", stats.throughput_tok_s());
    let max_b = stats.batch_sizes.iter().max().unwrap();
    println!("peak batch         : {max_b} (graphs specialized per power-of-two batch)");
    println!(
        "KV rows migrated   : {} (stable slots + shared max-batch arena: structurally zero)",
        stats.kv_rows_migrated
    );
    assert_eq!(stats.kv_rows_migrated, 0, "serving must not move KV rows");
    let (allocs, bytes) = engine.store_counters();
    println!("store copies       : {allocs} allocs / {bytes} bytes (zero-copy borrowed-view hot path)");
    assert_eq!((allocs, bytes), (0, 0), "decode hot path copied tensor data");
    println!(
        "pool output allocs : {} (execute_into boundary: results land in the arena)",
        engine.output_allocs()
    );
    assert_eq!(engine.output_allocs(), 0, "decode hot path received an allocated output buffer");
    println!(
        "weight arena       : {} f32 elements shared by every specialization, {} init run(s)",
        engine.weight_arena_len(),
        engine.weight_init_runs()
    );
    assert_eq!(engine.weight_init_runs(), 1, "weights must be synthesized exactly once");
    let mut sample: Vec<_> = outputs.iter().collect();
    sample.sort();
    for (id, toks) in sample.iter().take(3) {
        println!("  req {id}: {toks:?}");
    }

    // --- the streaming run: step(), mid-flight admission, cancel ---
    println!("\n== streaming: step-driven, online admission + cancellation ==");
    let mut s = ServeEngine::builder()
        .max_batch(4)
        .pool_threads(3)
        .seed(42)
        .mega(mega)
        .build()
        .expect("engine");
    s.submit(Request::new(100, vec![3, 11], 8)).expect("submit");
    s.submit(Request::new(101, vec![42], 8)).expect("submit");
    let mut streamed: Vec<TokenEvent> = Vec::new();
    let mut steps = 0;
    while s.has_work() {
        let outcome = s.step().expect("step");
        streamed.extend(outcome.events);
        steps += 1;
        if steps == 2 {
            // a request joins while the kernel is resident and serving.
            s.submit(Request::new(102, vec![7, 9, 4], 8)).expect("mid-flight submit");
        }
        if steps == 4 {
            // and one leaves: slot + KV blocks free immediately.
            s.cancel(101).expect("cancel");
        }
    }
    let stats = s.take_stats();
    let stream_of = |id: u64| -> Vec<i32> {
        streamed.iter().filter(|ev| ev.request == id).filter_map(|ev| ev.token).collect()
    };
    println!("req 100 streamed    : {:?}", stream_of(100));
    println!("req 101 (cancelled) : {:?} then {:?}", stream_of(101), FinishReason::Cancelled);
    println!("req 102 (mid-flight): {:?}", stream_of(102));
    println!(
        "busy {:?} of {:?} wall | {:.1} tok/s (busy-time) | ttft p50 {:?} | completion p99 {:?}",
        stats.busy,
        stats.total,
        stats.throughput_tok_s(),
        stats.ttft_p50(),
        stats.completion_p99()
    );
    assert_eq!(stream_of(100).len(), 8, "request 100 must stream its full budget");
    assert!(stream_of(101).len() < 8, "cancelled request must stop early");
    assert_eq!(stream_of(102).len(), 8, "mid-flight request must stream its full budget");
    assert!(
        streamed.contains(&TokenEvent { request: 101, token: None, finish: Some(FinishReason::Cancelled) }),
        "cancellation must emit a terminal event"
    );
    // the streamed path keeps every zero-copy invariant of batch mode.
    assert_eq!(s.store_counters(), (0, 0), "streaming copied tensor data");
    assert_eq!(s.output_allocs(), 0, "streaming allocated output buffers");
    assert_eq!(stats.kv_rows_migrated, 0, "streaming moved KV rows");
    // long-lived streaming loops drain retired requests periodically.
    let retired = s.take_finished();
    assert_eq!(retired.len(), 3, "all three requests retired on this engine");

    println!("\nall layers composed: Pallas kernels -> HLO artifacts -> PJRT pool -> megakernel");
}
