//! Compiler explorer: watch one model walk through every MPK compiler
//! stage (Figure 5), with per-stage statistics and a dump of the first
//! few tasks/events of the final linearized tGraph.
//!
//! ```bash
//! cargo run --release --example compiler_explorer [model] [batch]
//! ```

use mpk::models::{build_decode_graph, GraphOptions, ModelConfig};
use mpk::tgraph::{
    analyze_deps, compile, compiler::task_label, decompose, CompileOptions, DecomposeConfig,
};

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let model = args.get(1).map(|s| s.as_str()).unwrap_or("Qwen3-1.7B");
    let batch: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(2);
    let cfg = ModelConfig::by_name(model).unwrap_or_else(|| {
        eprintln!("unknown model {model}; try Qwen3-0.6B / Llama-3.2-1B / Qwen3-1.7B / Qwen3-8B / Qwen3-30B-A3B / Tiny-Qwen3");
        std::process::exit(1);
    });

    println!("(a) computation graph — {} at batch {batch}", cfg.name);
    let g = build_decode_graph(&cfg, &GraphOptions { batch, kv_len: 256, ..Default::default() });
    println!("    {} operators, {} tensors, {:.2} GB params\n", g.ops.len(), g.tensors.len(), g.param_bytes() as f64 / 1e9);

    let dc = DecomposeConfig { target_tasks: 64, min_tile_cols: 8 };
    println!("(b) operator decomposition (target 64 tasks/op)");
    let d = decompose(&g, &dc);
    let total: usize = d.iter().map(|t| t.tiles.len()).sum();
    println!("    {} tasks ({:.1}/op)", total, total as f64 / g.ops.len() as f64);
    for ot in d.iter().take(4) {
        println!("    {:<16} partition {:?} -> {} tiles", g.ops[ot.op].name, ot.partition, ot.tiles.len());
    }

    println!("\n(c) dependency analysis");
    let raw = analyze_deps(&g, &d);
    println!("    {} producer/consumer task pairs -> {} pair events", raw.dep_pairs, raw.events.len());

    println!("\n(d-f) fusion -> normalization -> linearization");
    let c = compile(&g, &CompileOptions { decompose: dc, ..Default::default() });
    let s = c.stats();
    println!("    events: {} (fusion reduction {:.0}x)", s.events, s.fusion_reduction);
    println!("    dummy tasks from normalization: {} ({:.2}%)", s.dummy_tasks, s.norm_overhead * 100.0);
    println!(
        "    successor encoding: {} B naive -> {} B linearized ({:.1}x)",
        s.lin_naive_bytes, s.lin_bytes, s.lin_reduction
    );

    println!("\nfinal tGraph head (launch order):");
    for &tid in c.linear.order.iter().take(10) {
        let t = &c.tgraph.tasks[tid];
        println!(
            "    #{tid:<6} {:<40} dep ev {:?} trig ev {:?} [{:?}]",
            task_label(&c.graph, t),
            t.dependent_events,
            t.trigger_events,
            t.launch
        );
    }
    let (jit, aot) = mpk::tgraph::compiler::launch_histogram(&c.tgraph);
    println!("\nhybrid launch split: {jit} JIT tasks, {aot} AOT tasks (§5.2)");
}
