//! MoE workload-balancer walkthrough (§6.4): route a batch through
//! Qwen3-30B-A3B's 128 experts under increasing routing skew and watch
//! the static strategy collapse while MPK's hybrid stays flat.
//!
//! ```bash
//! cargo run --release --example moe_balancer
//! ```

use mpk::models::ModelConfig;
use mpk::moe::{dynamic_us, hybrid_us, route, sglang_us, static_partition_us, Skew};
use mpk::sim::GpuSpec;
use mpk::util::Table;

fn main() {
    let cfg = ModelConfig::qwen3_30b_a3b();
    let moe = cfg.moe.unwrap();
    let gpu = GpuSpec::b200();
    println!(
        "Qwen3-30B-A3B MoE block on {}: {} experts, top-{}, expert FFN {}\n",
        gpu.name, moe.num_experts, moe.top_k, moe.expert_ffn
    );

    let mut t = Table::new(&["skew", "max/mean load", "Static µs", "Hybrid µs", "Dynamic µs", "SGLang µs"]);
    for (label, skew) in [
        ("uniform", Skew::Uniform),
        ("zipf 0.8", Skew::Zipf(0.8)),
        ("zipf 1.2", Skew::Zipf(1.2)),
        ("zipf 1.6", Skew::Zipf(1.6)),
    ] {
        let r = route(16, moe.num_experts, moe.top_k, skew, 123);
        let mean = r.total_assignments() as f64 / r.activated().max(1) as f64;
        let st = static_partition_us(&moe, cfg.d_model, &r, &gpu, 16).us;
        let hy = hybrid_us(&moe, cfg.d_model, &r, &gpu).us;
        let dy = dynamic_us(&moe, cfg.d_model, &r, &gpu).us;
        let sg = sglang_us(&moe, cfg.d_model, &r, &gpu).us;
        t.row(vec![
            label.into(),
            format!("{:.1}", r.max_load() as f64 / mean),
            format!("{st:.1}"),
            format!("{hy:.1}"),
            format!("{dy:.1}"),
            format!("{sg:.1}"),
        ]);
    }
    println!("{}", t.render());
    println!("takeaways (the Figure 10 story):");
    println!(" * static SM groups oversubscribe hot experts as skew grows;");
    println!(" * hybrid = static task structure + runtime meta-tensor refinement stays near even;");
    println!(" * fully dynamic pays per-tile synchronization;");
    println!(" * SGLang-class pays the standalone gather (~11% at batch 1) + launches,");
    println!("   which MPK folds into the GEMM's data-loading phase (fused gather-GEMM).");
}
