//! Quickstart: compile a model into an SM-level tGraph and execute it on
//! the threaded in-kernel runtime — the 60-second tour of MPK.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use mpk::megakernel::{MegaConfig, MegaKernel};
use mpk::models::{build_decode_graph, GraphOptions, ModelConfig};
use mpk::sim::{simulate_baseline, simulate_megakernel, BaselineSystem, GpuSpec, SimOptions};
use mpk::tgraph::{compile, CompileOptions, DecomposeConfig, TaskDesc};

fn main() {
    // 1. a tensor program: one decode iteration of Qwen3-1.7B, batch 4.
    let cfg = ModelConfig::qwen3_1_7b();
    let graph = build_decode_graph(&cfg, &GraphOptions { batch: 4, kv_len: 256, ..Default::default() });
    println!("computation graph: {} ops, {} tensors", graph.ops.len(), graph.tensors.len());

    // 2. the MPK compiler: decompose → dependencies → fusion →
    //    normalization → linearization (§4).
    let gpu = GpuSpec::b200();
    let compiled = compile(
        &graph,
        &CompileOptions {
            decompose: DecomposeConfig { target_tasks: gpu.workers, min_tile_cols: 8 },
            ..Default::default()
        },
    );
    let s = compiled.stats();
    println!(
        "tGraph: {} tasks ({:.1}/op), {} events (fusion {:.0}x, linearization {:.1}x smaller)",
        s.tasks, s.tasks_per_op, s.events, s.fusion_reduction, s.lin_reduction
    );

    // 3. execute on the threaded in-kernel runtime (workers + schedulers,
    //    hybrid JIT/AOT launch — §5). Tasks are no-ops here; see
    //    serve_e2e for real numerics through PJRT, driven through the
    //    streaming serving API (ServeEngine::builder() + step()).
    let kernel = MegaKernel::new(&compiled, MegaConfig { workers: 8, schedulers: 2, ..Default::default() });
    let report = kernel.run(&|_: &TaskDesc| {}).expect("mega-kernel run");
    println!(
        "threaded run: {} tasks in {:?} ({} JIT dispatches, {} AOT hits)",
        report.metrics.tasks_executed, report.elapsed, report.metrics.jit_dispatches, report.metrics.aot_hits
    );

    // 4. what would this cost on a B200? (roofline DES, §6)
    let mpk_us = simulate_megakernel(&compiled, &gpu, &SimOptions::default()).makespan_us;
    let sg_us = simulate_baseline(&compiled, &gpu, &BaselineSystem::sglang(), None);
    println!(
        "simulated on {}: MPK {:.0} µs/iter vs SGLang-class {:.0} µs/iter ({:.2}x)",
        gpu.name,
        mpk_us,
        sg_us,
        sg_us / mpk_us
    );
}
