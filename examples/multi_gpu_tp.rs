//! Multi-GPU tensor parallelism walkthrough (§6.5): shard Qwen3-1.7B
//! across 1–8 simulated H100s, show the in-kernel ring all-reduce
//! schedule, and compare fine-grained vs coarse compute–communication
//! overlap.
//!
//! ```bash
//! cargo run --release --example multi_gpu_tp
//! ```

use mpk::models::ModelConfig;
use mpk::multigpu::{collective, tp};
use mpk::sim::{BaselineSystem, GpuSpec, LinkSpec};
use mpk::tgraph::DepGranularity;
use mpk::util::Table;

fn main() {
    let gpu = GpuSpec::h100();
    let link = LinkSpec::nvlink_h100();
    let cfg = ModelConfig::qwen3_1_7b();

    println!("== ring all-reduce lowering (d_model row, batch 8, bf16) ==");
    let bytes = (8 * cfg.d_model * 2) as u64;
    for w in [2usize, 4, 8] {
        let steps = collective::ring_schedule(bytes, w);
        println!(
            "  world {w}: {} steps, {} B/device on the wire, in-kernel {:.1} µs vs NCCL-class {:.1} µs",
            steps.len(),
            collective::ring_bytes_per_device(bytes, w),
            collective::inkernel_allreduce_us(bytes, w, &link),
            collective::nccl_allreduce_us(bytes, w, &link),
        );
    }

    println!("\n== Qwen3-1.7B iteration latency by world size (batch 8) ==");
    let mut t = Table::new(&["GPUs", "MPK fine µs", "MPK coarse µs", "overlap", "SGLang µs", "speedup"]);
    for w in [1usize, 2, 4, 8] {
        let fine = tp::plan(&cfg, 8, 512, w, &gpu, DepGranularity::Fine);
        let coarse = tp::plan(&cfg, 8, 512, w, &gpu, DepGranularity::CoarseCollectives);
        let f = tp::mpk_iteration_us(&fine, &gpu, &link, true);
        let c = tp::mpk_iteration_us(&coarse, &gpu, &link, true);
        let sg = tp::baseline_iteration_us(&fine, &gpu, &link, &BaselineSystem::sglang());
        t.row(vec![
            w.to_string(),
            format!("{f:.0}"),
            format!("{c:.0}"),
            format!("{:.3}x", c / f),
            format!("{sg:.0}"),
            format!("{:.2}x", sg / f),
        ]);
    }
    println!("{}", t.render());
    println!("communication tasks live in the same tGraph as compute and are");
    println!("dispatched by the same event-driven scheduler — overlap emerges");
    println!("from the task schedule, not from stream management (§6.5/§8).");
}
