//! Paged-KV subsystem integration suite.
//!
//! * **Allocator churn property** — seeded random admit / append /
//!   promote / release sequences against a deliberately small pool,
//!   with the pool's own `check_invariants` (refcount conservation,
//!   free-list consistency, prefix-index pinning) asserted after every
//!   single operation, including the exhaustion and eviction paths.
//! * **Prefix identity** — two requests admitted with the same prompt
//!   share the *same physical blocks* (table-prefix equality), and the
//!   first write into a shared block copies it (COW) instead of
//!   corrupting the neighbor.
//! * **Chunked-prefill cadence** — a 40-token prompt arriving
//!   mid-decode is prefilled through extra epochs without ever costing
//!   a live request its one-token-per-step decode cadence, and the
//!   live request's tokens are bit-identical to a run with chunking
//!   off.

use mpk::megakernel::MegaConfig;
use mpk::runtime::BackendKind;
use mpk::serving::{Append, KvArena, PagedKvPool, Request, ServeEngine};
use mpk::util::XorShift64;

/// A tiny pool (2 layers × 4 slots × 32 rows, 8-token blocks → 16
/// blocks) so churn actually exercises exhaustion and prefix eviction.
fn small_pool() -> PagedKvPool {
    let arena = KvArena::new(2, 4, 32, 8);
    PagedKvPool::over(&arena, 8)
}

#[test]
fn pool_churn_preserves_invariants_across_seeds() {
    for seed in [1u64, 42, 0xBEEF, 31337, 2024] {
        let mut rng = XorShift64::new(seed);
        let mut pool = small_pool();
        let total = pool.total_blocks();
        // (id, prompt, cache_len) for every admitted request. Prompts
        // draw from a 4-token alphabet so identical prefixes recur and
        // the sharing/COW paths fire under churn, not just in the
        // targeted tests below.
        let mut live: Vec<(u64, Vec<i32>, usize)> = Vec::new();
        let mut next_id = 1u64;
        let mut exhausted = 0usize;
        for op in 0..400 {
            match rng.below(4) {
                0 | 1 => {
                    let len = 1 + rng.below(24);
                    let prompt: Vec<i32> =
                        (0..len).map(|_| 1 + rng.below(4) as i32).collect();
                    if let Some(adm) = pool.admit(next_id, &prompt) {
                        // prefill must always have at least one
                        // position left to run, even on a full-prompt
                        // prefix hit.
                        assert!(
                            adm.resume < prompt.len(),
                            "seed {seed} op {op}: resume {} >= prompt {}",
                            adm.resume,
                            prompt.len()
                        );
                        live.push((next_id, prompt, adm.resume));
                        next_id += 1;
                    } else {
                        exhausted += 1;
                    }
                }
                2 => {
                    if !live.is_empty() {
                        let i = rng.below(live.len());
                        let (id, pos) = (live[i].0, live[i].2);
                        match pool.ensure_append(id, pos) {
                            Append::Exhausted => {
                                // what the engine does: shed, free.
                                pool.release(id);
                                live.swap_remove(i);
                                exhausted += 1;
                            }
                            _ => {
                                live[i].2 += 1;
                                let cl = live[i].2;
                                if cl % pool.block_tokens() == 0 && cl <= live[i].1.len() {
                                    let prompt = live[i].1.clone();
                                    pool.promote(id, &prompt, cl);
                                }
                            }
                        }
                    }
                }
                _ => {
                    if !live.is_empty() {
                        let i = rng.below(live.len());
                        pool.release(live[i].0);
                        live.swap_remove(i);
                    }
                }
            }
            pool.check_invariants()
                .unwrap_or_else(|e| panic!("seed {seed} op {op}: {e}"));
            assert!(pool.free_blocks() <= total, "seed {seed} op {op}: free list grew");
        }
        // drain: every table disappears; whatever stays allocated is
        // exactly what the prefix index pins, and it still balances.
        for (id, _, _) in &live {
            pool.release(*id);
        }
        pool.check_invariants().unwrap_or_else(|e| panic!("seed {seed} drain: {e}"));
        for (id, _, _) in &live {
            assert!(pool.table(*id).is_none(), "seed {seed}: table survived release");
        }
        // a 16-block pool under 24-token prompts must have hit an
        // exhaustion arm (refused admit or exhausted append) at least
        // once, or the test proved too little.
        assert!(exhausted > 0, "seed {seed}: churn never exercised pool exhaustion");
    }
}

#[test]
fn shared_prefixes_alias_identical_physical_blocks_until_cow() {
    let mut pool = small_pool();
    let prompt: Vec<i32> = (0..16).map(|i| (i % 5) as i32 + 1).collect();
    pool.admit(1, &prompt).expect("room for the first request");
    pool.promote(1, &prompt, prompt.len());
    let t1: Vec<usize> = pool.table(1).expect("table 1").to_vec();

    let adm = pool.admit(2, &prompt).expect("room for the second request");
    assert_eq!(adm.shared_blocks, 2, "both full prompt blocks must map from the index");
    assert_eq!(adm.resume, 15, "resume clamps to P-1 so prefill still runs");
    let t2: Vec<usize> = pool.table(2).expect("table 2").to_vec();
    assert_eq!(t1[..2], t2[..2], "shared prefix must alias the same physical blocks");

    // the clamped position 15 lands in shared block 1: the first write
    // must copy it, leaving request 1's view untouched.
    match pool.ensure_append(2, 15) {
        Append::Cowed => {}
        other => panic!("write into a shared block must COW, got {other:?}"),
    }
    let t2 = pool.table(2).expect("table 2").to_vec();
    assert_eq!(t1[0], t2[0], "untouched prefix block stays shared");
    assert_ne!(t1[1], t2[1], "COW must hand request 2 a private copy");
    assert_eq!(pool.cowed_total(), 1);
    pool.check_invariants().expect("invariants after COW");

    pool.release(1);
    pool.release(2);
    pool.check_invariants().expect("invariants after release");
}

#[test]
fn chunked_prefill_never_costs_a_live_request_its_decode_cadence() {
    let run = |chunk: usize| -> (Vec<i32>, usize) {
        let mut e = ServeEngine::builder()
            .max_batch(2)
            .pool_threads(2)
            .seed(42)
            .mega(MegaConfig { workers: 4, schedulers: 1, ..Default::default() })
            .backend(BackendKind::Cpu)
            .paged_kv(true)
            .prefill_chunk(chunk)
            .build()
            .expect("cpu paged engine");
        e.submit(Request::new(0, vec![5, 9], 40)).expect("submit decoder");
        let mut per_step: Vec<usize> = Vec::new();
        let mut trace: Vec<i32> = Vec::new();
        // three solo steps: req 0 reaches steady decode.
        for _ in 0..3 {
            let out = e.step().expect("solo step");
            let toks: Vec<i32> = out
                .events
                .iter()
                .filter(|ev| ev.request == 0)
                .filter_map(|ev| ev.token)
                .collect();
            if !trace.is_empty() || !toks.is_empty() {
                per_step.push(toks.len());
            }
            trace.extend(toks);
        }
        // the long prompt arrives mid-decode. From here, every step in
        // which req 0 is still live must carry exactly one req-0 token
        // — chunked prefill may only spend *extra* epochs, never the
        // batch's decode step.
        let long: Vec<i32> = (0..40).map(|i| 2 + (i % 7) as i32).collect();
        e.submit(Request::new(1, long, 4)).expect("submit long prompt");
        let mut guard = 0;
        while e.has_work() {
            guard += 1;
            assert!(guard < 400, "step livelock");
            let out = e.step().expect("step");
            let toks: Vec<i32> = out
                .events
                .iter()
                .filter(|ev| ev.request == 0)
                .filter_map(|ev| ev.token)
                .collect();
            if !trace.is_empty() || !toks.is_empty() {
                per_step.push(toks.len());
            }
            trace.extend(toks);
        }
        let last_live = per_step.iter().rposition(|&n| n > 0).unwrap();
        assert!(
            per_step[..=last_live].iter().all(|&n| n == 1),
            "chunk {chunk}: decode cadence broke: {per_step:?}"
        );
        let stats = e.take_stats();
        if chunk > 0 {
            assert!(stats.prefill_chunks > 0, "chunking on but no extra epochs ran");
        } else {
            assert_eq!(stats.prefill_chunks, 0, "chunking off but extra epochs ran");
        }
        (trace, last_live + 1)
    };
    let (plain, plain_steps) = run(0);
    let (chunked, chunked_steps) = run(3);
    assert_eq!(plain.len(), 40, "req 0 must decode its full budget");
    assert_eq!(
        plain, chunked,
        "chunked prefill changed a live request's decoded tokens"
    );
    assert_eq!(
        plain_steps, chunked_steps,
        "chunked prefill changed a live request's step count"
    );
}
