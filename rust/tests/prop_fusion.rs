//! Soundness properties of the event-reduction passes on *random
//! bipartite task/event DAGs* (not just model-shaped graphs): fusion and
//! fork-merging may add synchronization, but must never lose a
//! producer→consumer pair or introduce a cycle.

use mpk::ops::{LaunchMode, Region};
use mpk::proputil::forall;
use mpk::tgraph::fusion::{encoded_pairs, fuse_events, merge_task_forks};
use mpk::tgraph::{EventDesc, TaskDesc, TaskKind};
use mpk::util::XorShift64;
use std::collections::HashSet;

/// Random layered DAG: tasks in layers, pair events only forward.
fn random_dag(rng: &mut XorShift64) -> (Vec<TaskDesc>, Vec<EventDesc>) {
    let layers = rng.range(2, 5);
    let per_layer = rng.range(1, 6);
    let mut tasks: Vec<TaskDesc> = Vec::new();
    let mut layer_ids: Vec<Vec<usize>> = Vec::new();
    for _ in 0..layers {
        let mut ids = Vec::new();
        for _ in 0..per_layer {
            let id = tasks.len();
            ids.push(id);
            tasks.push(TaskDesc {
                id,
                kind: TaskKind::Dummy,
                out_region: Region::new(vec![]),
                launch: LaunchMode::Aot,
                dependent_events: Vec::new(),
                trigger_events: Vec::new(),
                device: 0,
            });
        }
        layer_ids.push(ids);
    }
    let mut events = Vec::new();
    for l in 1..layers {
        for &c in &layer_ids[l] {
            // each task depends on 1..=3 random tasks of earlier layers.
            for _ in 0..rng.range(1, 3) {
                let pl = rng.below(l);
                let p = layer_ids[pl][rng.below(layer_ids[pl].len())];
                let id = events.len();
                events.push(EventDesc { id, in_tasks: vec![p], out_tasks: vec![c] });
                tasks[p].trigger_events.push(id);
                tasks[c].dependent_events.push(id);
            }
        }
    }
    (tasks, events)
}

fn is_acyclic(tasks: &[TaskDesc], events: &[EventDesc]) -> bool {
    // Kahn over tasks through events.
    let n = tasks.len();
    let mut indeg = vec![0usize; n];
    for t in tasks {
        indeg[t.id] = t.dependent_events.iter().map(|&e| events[e].in_tasks.len()).sum();
    }
    let mut q: Vec<usize> = (0..n).filter(|&i| indeg[i] == 0).collect();
    let mut seen = 0;
    while let Some(t) = q.pop() {
        seen += 1;
        for &e in &tasks[t].trigger_events {
            for &s in &events[e].out_tasks {
                indeg[s] -= 1;
                if indeg[s] == 0 {
                    q.push(s);
                }
            }
        }
    }
    seen == n
}

#[test]
fn prop_fusion_preserves_pairs_and_acyclicity() {
    forall("fusion soundness", 0xF051, 80, random_dag, |(tasks, events)| {
        let before: HashSet<(usize, usize)> = encoded_pairs(events);
        let mut tasks = tasks.clone();
        let fused = fuse_events(&mut tasks, events.clone());
        let after = encoded_pairs(&fused);
        if !after.is_superset(&before) {
            return Err("fusion lost a dependency pair".into());
        }
        if !is_acyclic(&tasks, &fused) {
            return Err("fusion introduced a cycle".into());
        }
        Ok(())
    });
}

#[test]
fn prop_fork_merge_preserves_pairs_and_acyclicity() {
    forall("fork-merge soundness", 0xF0C2, 80, random_dag, |(tasks, events)| {
        let before: HashSet<(usize, usize)> = encoded_pairs(events);
        let mut tasks = tasks.clone();
        let fused = fuse_events(&mut tasks, events.clone());
        let merged = merge_task_forks(&mut tasks, fused);
        let after = encoded_pairs(&merged);
        if !after.is_superset(&before) {
            return Err("fork-merge lost a dependency pair".into());
        }
        if !is_acyclic(&tasks, &merged) {
            return Err("fork-merge introduced a cycle".into());
        }
        Ok(())
    });
}

#[test]
fn prop_normalization_bounds_degrees_on_random_dags() {
    forall("normalization degrees", 0x0123, 80, random_dag, |(tasks, events)| {
        let mut tasks = tasks.clone();
        let mut events = fuse_events(&mut tasks, events.clone());
        let before: HashSet<(usize, usize)> = encoded_pairs(&events);
        mpk::tgraph::normalize::normalize(&mut tasks, &mut events);
        for t in &tasks {
            if t.dependent_events.len() > 1 || t.trigger_events.len() > 1 {
                return Err(format!("task {} degree bound violated", t.id));
            }
        }
        if !is_acyclic(&tasks, &events) {
            return Err("normalization introduced a cycle".into());
        }
        // pairs preserved transitively: check reachability for a sample.
        let mut rng = XorShift64::new(1);
        let sample: Vec<&(usize, usize)> = {
            let v: Vec<&(usize, usize)> = before.iter().collect();
            (0..v.len().min(20)).map(|_| v[rng.below(v.len())]).collect()
        };
        for &&(p, c) in &sample {
            if !reaches(&tasks, &events, p, c) {
                return Err(format!("normalization lost {p} -> {c}"));
            }
        }
        Ok(())
    });
}

fn reaches(tasks: &[TaskDesc], events: &[EventDesc], from: usize, to: usize) -> bool {
    let mut seen = vec![false; tasks.len()];
    let mut stack = vec![from];
    while let Some(t) = stack.pop() {
        if t == to {
            return true;
        }
        if seen[t] {
            continue;
        }
        seen[t] = true;
        for &e in &tasks[t].trigger_events {
            for &s in &events[e].out_tasks {
                stack.push(s);
            }
        }
    }
    false
}
