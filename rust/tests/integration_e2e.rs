//! Cross-layer integration: compile → threaded megakernel → simulator
//! agreement, and the real-numerics path on the native CPU backend.

use mpk::megakernel::{MegaConfig, MegaKernel};
use mpk::models::{build_decode_graph, GraphOptions, ModelConfig};
use mpk::sim::{simulate_megakernel, GpuSpec, SimOptions};
use mpk::tgraph::{compile, CompileOptions, DecomposeConfig, TaskDesc};

/// The threaded runtime and the DES replay the same policy over the same
/// tGraph: both must execute the full task set.
#[test]
fn threaded_runtime_and_simulator_agree_on_task_count() {
    let cfg = ModelConfig::tiny();
    let g = build_decode_graph(&cfg, &GraphOptions { batch: 4, kv_len: 16, ..Default::default() });
    let c = compile(
        &g,
        &CompileOptions { decompose: DecomposeConfig { target_tasks: 8, min_tile_cols: 8 }, ..Default::default() },
    );
    let mk = MegaKernel::new(&c, MegaConfig { workers: 4, schedulers: 1, ..Default::default() });
    let r = mk.run(&|_: &TaskDesc| {}).unwrap();
    let gpu = GpuSpec::a100();
    let s = simulate_megakernel(&c, &gpu, &SimOptions::default());
    assert_eq!(r.metrics.tasks_executed as usize, c.tgraph.tasks.len());
    assert_eq!(s.tasks, c.tgraph.real_task_count());
}

/// All five paper models compile and simulate on all three GPUs without
/// violating the basic ordering invariants (smoke over the full matrix).
#[test]
fn full_model_gpu_matrix_compiles_and_simulates() {
    for cfg in ModelConfig::paper_models() {
        // trim depth to keep the matrix fast; structure is per-layer.
        let mut small = cfg.clone();
        small.layers = 2;
        let g = build_decode_graph(&small, &GraphOptions { batch: 2, kv_len: 64, ..Default::default() });
        for gpu in GpuSpec::all() {
            let c = compile(
                &g,
                &CompileOptions {
                    decompose: DecomposeConfig { target_tasks: gpu.workers, min_tile_cols: 8 },
                    ..Default::default()
                },
            );
            c.tgraph.check_consistent().unwrap();
            let r = simulate_megakernel(&c, &gpu, &SimOptions::default());
            assert!(r.makespan_us > 0.0, "{} on {}", cfg.name, gpu.name);
            assert!(r.utilization > 0.0 && r.utilization <= 1.0);
        }
    }
}

/// Real-numerics path on the native CPU backend (the default — no
/// artifacts dir, no PJRT library): serving a request through the
/// engine matches serving it through a second, freshly constructed
/// engine (determinism across engine instances).
#[test]
fn serving_is_deterministic_across_engines() {
    use mpk::serving::{Request, ServeEngine};
    let mega = MegaConfig { workers: 4, schedulers: 1, ..Default::default() };
    let run = || {
        let mut e = ServeEngine::builder()
            .max_batch(2)
            .pool_threads(2)
            .seed(77)
            .mega(mega)
            .build()
            .unwrap();
        e.submit(Request::new(0, vec![9, 17], 4)).unwrap();
        e.submit(Request::new(1, vec![250], 4)).unwrap();
        e.serve().unwrap().0
    };
    let a = run();
    let b = run();
    assert_eq!(a[&0], b[&0]);
    assert_eq!(a[&1], b[&1]);
}
