//! Property suite over the serving substrate: random request mixes must
//! conserve KV blocks, never exceed batch capacity, and complete every
//! request with exactly the asked-for token count. (Scheduler-level —
//! no artifacts needed; the real-numerics serving path is covered by
//! `serving::engine` tests and `examples/serve_e2e`.)

use mpk::proputil::forall;
use mpk::serving::{Batcher, KvAllocator, Request};
use mpk::util::XorShift64;

struct Workload {
    max_batch: usize,
    blocks: usize,
    requests: Vec<(usize, usize)>, // (prompt_len, gen_len)
}

fn random_workload(rng: &mut XorShift64) -> Workload {
    Workload {
        max_batch: rng.range(1, 6),
        blocks: rng.range(4, 64),
        requests: (0..rng.range(1, 20))
            .map(|_| (rng.range(1, 8), rng.range(1, 8)))
            .collect(),
    }
}

/// Drive the batcher with a fake model (each iteration generates one
/// token for every active request).
fn drive(w: &Workload) -> Result<(), String> {
    let kv = KvAllocator::new(w.blocks, 8);
    let mut b = Batcher::new(w.max_batch, 64, kv);
    for (i, &(p, g)) in w.requests.iter().enumerate() {
        b.submit(Request::new(i as u64, vec![1; p], g));
    }
    let total_blocks = w.blocks;
    let mut guard = 0;
    while b.has_work() {
        guard += 1;
        if guard > 10_000 {
            return Err("batcher livelock".into());
        }
        b.step_admission();
        if b.active.is_empty() {
            if b.pending() > 0 {
                // a single waiting request must always fit eventually:
                // worst-case demand ≤ pool size?
                let (p, g) = w.requests[0];
                if (p + g).div_ceil(8) > total_blocks {
                    return Ok(()); // permanently oversized workload: fine to stall
                }
                return Err("stall with free capacity".into());
            }
            break;
        }
        if b.active.len() > w.max_batch {
            return Err(format!("batch overflow: {}", b.active.len()));
        }
        // slots compact and unique.
        let mut slots: Vec<_> = b.active.iter().map(|r| r.slot.unwrap()).collect();
        slots.sort_unstable();
        if slots != (0..b.active.len()).collect::<Vec<_>>() {
            return Err(format!("non-compact slots {slots:?}"));
        }
        // fake decode step.
        for r in b.active.iter_mut() {
            r.cache_len += 1;
            if r.in_prefill() {
                r.prompt_pos += 1;
                if !r.in_prefill() {
                    r.generated.push(0);
                }
            } else {
                r.generated.push(0);
            }
        }
    }
    // every request finished with the right token count.
    if b.finished.len() != w.requests.len() {
        return Err(format!("{} of {} finished", b.finished.len(), w.requests.len()));
    }
    for r in &b.finished {
        let want = w.requests[r.id as usize].1;
        if r.generated.len() != want {
            return Err(format!("req {} generated {} of {want}", r.id, r.generated.len()));
        }
    }
    // all KV blocks returned.
    if b.kv.free_blocks() != total_blocks {
        return Err(format!("leaked blocks: {} of {total_blocks} free", b.kv.free_blocks()));
    }
    Ok(())
}

#[test]
fn prop_continuous_batching_conserves_blocks_and_completes() {
    forall("serving invariants", 0x5E11, 60, random_workload, |w| {
        // skip impossible workloads (a single request larger than pool).
        if w.requests.iter().any(|&(p, g)| (p + g).div_ceil(8) > w.blocks) {
            return Ok(());
        }
        drive(w)
    });
}

#[test]
fn prop_kv_allocator_never_oversubscribes() {
    forall(
        "kv allocator",
        0xA110C,
        100,
        |rng: &mut XorShift64| {
            let blocks = rng.range(1, 32);
            let ops: Vec<(u64, usize, bool)> =
                (0..rng.range(1, 60)).map(|_| (rng.below(8) as u64, rng.range(0, 40), rng.below(4) == 0)).collect();
            (blocks, ops)
        },
        |(blocks, ops)| {
            let mut a = KvAllocator::new(*blocks, 4);
            let mut outstanding = 0usize;
            let mut held: std::collections::HashMap<u64, usize> = Default::default();
            for &(req, tokens, release) in ops {
                if release {
                    let freed = a.release(req);
                    outstanding -= freed;
                    held.remove(&req);
                } else if a.ensure(req, tokens) {
                    let new_held = a.held_by(req);
                    let old = held.insert(req, new_held).unwrap_or(0);
                    outstanding += new_held - old;
                }
                if outstanding + a.free_blocks() != *blocks {
                    return Err("block conservation violated".into());
                }
            }
            Ok(())
        },
    );
}
