//! Property suite over the serving substrate: random request mixes must
//! conserve KV blocks, never exceed batch capacity, keep every active
//! request's slot **stable** from admission to retirement
//! (lowest-free-slot batching), survive arbitrary interleavings of
//! admission and **cancellation** without losing or duplicating a
//! token, and complete every request with exactly the asked-for token
//! count. (Mostly scheduler-level — no artifacts needed; the
//! real-numerics step/submit/cancel/EOS churn runs on the native CPU
//! backend everywhere, and `examples/serve_e2e` drives it too.)

use mpk::proputil::forall;
use mpk::serving::{Batcher, EngineError, FinishReason, KvAllocator, Request};
use mpk::util::XorShift64;
use std::collections::HashMap;

struct Workload {
    max_batch: usize,
    blocks: usize,
    requests: Vec<(usize, usize)>, // (prompt_len, gen_len)
}

fn random_workload(rng: &mut XorShift64) -> Workload {
    Workload {
        max_batch: rng.range(1, 6),
        blocks: rng.range(4, 64),
        requests: (0..rng.range(1, 20))
            .map(|_| (rng.range(1, 8), rng.range(1, 8)))
            .collect(),
    }
}

/// Check the slot invariants for the current active set against the
/// stability ledger: slots are unique, in bounds, and — for requests
/// seen active before — unchanged since admission.
fn check_slots(b: &Batcher, ledger: &mut HashMap<u64, usize>) -> Result<(), String> {
    let mut seen = vec![false; b.max_batch];
    for r in &b.active {
        let slot = r.slot.ok_or_else(|| format!("active req {} without slot", r.id))?;
        if slot >= b.max_batch {
            return Err(format!("req {} slot {slot} out of bounds", r.id));
        }
        if seen[slot] {
            return Err(format!("slot {slot} occupied twice"));
        }
        seen[slot] = true;
        match ledger.get(&r.id) {
            None => {
                ledger.insert(r.id, slot);
            }
            Some(&home) if home == slot => {}
            Some(&home) => {
                return Err(format!("req {} moved slot {home} -> {slot}", r.id));
            }
        }
    }
    Ok(())
}

/// Drive the batcher with a fake model (each iteration generates one
/// token for every active request).
fn drive(w: &Workload) -> Result<(), String> {
    let kv = KvAllocator::new(w.blocks, 8);
    let mut b = Batcher::new(w.max_batch, 64, kv);
    for (i, &(p, g)) in w.requests.iter().enumerate() {
        b.submit(Request::new(i as u64, vec![1; p], g))?;
    }
    let total_blocks = w.blocks;
    let mut slot_ledger: HashMap<u64, usize> = HashMap::new();
    let mut guard = 0;
    while b.has_work() {
        guard += 1;
        if guard > 10_000 {
            return Err("batcher livelock".into());
        }
        let retired = b.step_admission();
        for id in retired {
            slot_ledger.remove(&id);
        }
        if b.active.is_empty() {
            if b.pending() > 0 {
                // a single waiting request must always fit eventually:
                // worst-case demand ≤ pool size?
                let (p, g) = w.requests[0];
                if (p + g).div_ceil(8) > total_blocks {
                    return Ok(()); // permanently oversized workload: fine to stall
                }
                return Err("stall with free capacity".into());
            }
            break;
        }
        if b.active.len() > w.max_batch {
            return Err(format!("batch overflow: {}", b.active.len()));
        }
        // slots unique, in bounds, and stable across the request's life.
        check_slots(&b, &mut slot_ledger)?;
        // the specialized graph must cover every occupied slot.
        let bound = b.active.iter().map(|r| r.slot.unwrap() + 1).max().unwrap();
        if b.graph_batch() < bound {
            return Err(format!("graph_batch {} < slot bound {bound}", b.graph_batch()));
        }
        // fake decode step.
        for r in b.active.iter_mut() {
            r.cache_len += 1;
            if r.in_prefill() {
                r.prompt_pos += 1;
                if !r.in_prefill() {
                    r.generated.push(0);
                }
            } else {
                r.generated.push(0);
            }
        }
    }
    // every request finished with the right token count.
    if b.finished.len() != w.requests.len() {
        return Err(format!("{} of {} finished", b.finished.len(), w.requests.len()));
    }
    for r in &b.finished {
        let want = w.requests[r.id as usize].1;
        if r.generated.len() != want {
            return Err(format!("req {} generated {} of {want}", r.id, r.generated.len()));
        }
    }
    // all KV blocks returned.
    if b.kv.free_blocks() != total_blocks {
        return Err(format!("leaked blocks: {} of {total_blocks} free", b.kv.free_blocks()));
    }
    Ok(())
}

#[test]
fn prop_continuous_batching_conserves_blocks_and_completes() {
    forall("serving invariants", 0x5E11, 60, random_workload, |w| {
        // skip impossible workloads (a single request larger than pool).
        if w.requests.iter().any(|&(p, g)| (p + g).div_ceil(8) > w.blocks) {
            return Ok(());
        }
        drive(w)
    });
}

/// Arbitrary retire/admit sequences — not just run-to-completion decode:
/// each step force-finishes a random subset of the active set and
/// trickles in new submissions, which is exactly the churn that used to
/// trigger prefix compaction. No surviving request's slot may ever
/// change, and freed slots must be re-issued lowest-first.
#[test]
fn prop_slots_stable_under_arbitrary_retire_admit() {
    forall(
        "slot stability",
        0x5107_AB1E,
        80,
        |rng: &mut XorShift64| {
            let max_batch = rng.range(1, 9);
            let steps: Vec<(u64, bool)> =
                (0..rng.range(5, 60)).map(|_| (rng.next_u64(), rng.below(3) == 0)).collect();
            (max_batch, steps)
        },
        |(max_batch, steps)| {
            let mut b = Batcher::new(*max_batch, 64, KvAllocator::new(1024, 8));
            let mut ledger: HashMap<u64, usize> = HashMap::new();
            let mut next_id = 0u64;
            for &(roll, submit_burst) in steps {
                // retire a random subset of the active set.
                let n = b.active.len();
                for i in 0..n {
                    if (roll >> i) & 1 == 1 {
                        let r = &mut b.active[i];
                        while r.generated.len() < r.max_new_tokens {
                            r.generated.push(0);
                        }
                    }
                }
                if submit_burst {
                    for _ in 0..=(roll % 3) {
                        b.submit(Request::new(next_id, vec![1, 2], 4)).unwrap();
                        next_id += 1;
                    }
                }
                let retired = b.step_admission();
                for id in &retired {
                    if ledger.remove(id).is_none() {
                        return Err(format!("retired req {id} was never active"));
                    }
                }
                let before: HashMap<u64, usize> = ledger.clone();
                check_slots(&b, &mut ledger)?;
                // lowest-free-slot: every *newly* admitted request must
                // sit below every free slot at or under the bound.
                let occupied: Vec<usize> = b.active.iter().map(|r| r.slot.unwrap()).collect();
                for r in &b.active {
                    if before.contains_key(&r.id) {
                        continue; // pre-existing: stability already checked
                    }
                    let slot = r.slot.unwrap();
                    for lower in 0..slot {
                        if !occupied.contains(&lower) {
                            return Err(format!(
                                "req {} admitted at {slot} while slot {lower} was free",
                                r.id
                            ));
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

/// Arbitrary interleavings of submit bursts, mid-flight cancellation,
/// natural retirements, and scheduling steps — the full churn the step
/// API exposes, minus the kernel. Invariants: slots stay stable and unique,
/// no token is lost or duplicated (each request's `generated` length
/// equals the decode steps it was emitted), every submitted id lands in
/// `finished` exactly once with the right finish state, cancelled ids
/// can never be resubmitted, and every KV block comes home.
#[test]
fn prop_churn_submit_cancel_conserves_slots_tokens_blocks() {
    forall(
        "churn with cancellation",
        0xCA9CE1,
        80,
        |rng: &mut XorShift64| {
            let max_batch = rng.range(1, 7);
            let blocks = rng.range(8, 64);
            let steps: Vec<(u64, bool, bool)> = (0..rng.range(5, 60))
                .map(|_| (rng.next_u64(), rng.below(3) == 0, rng.below(4) == 0))
                .collect();
            (max_batch, blocks, steps)
        },
        |(max_batch, blocks, steps)| {
            let mut b = Batcher::new(*max_batch, 64, KvAllocator::new(*blocks, 8));
            let mut ledger: HashMap<u64, usize> = HashMap::new();
            // id → (max_new, emitted so far, cancelled?)
            let mut tracked: HashMap<u64, (usize, usize, bool)> = HashMap::new();
            let mut next_id = 0u64;
            let drive_one = |b: &mut Batcher,
                             ledger: &mut HashMap<u64, usize>,
                             tracked: &mut HashMap<u64, (usize, usize, bool)>|
             -> Result<(), String> {
                for id in b.step_admission() {
                    if ledger.remove(&id).is_none() {
                        return Err(format!("retired req {id} was never active"));
                    }
                }
                check_slots(b, ledger)?;
                for r in b.active.iter_mut() {
                    r.cache_len += 1;
                    let emitted = if r.in_prefill() {
                        r.prompt_pos += 1;
                        if !r.in_prefill() {
                            r.generated.push(0);
                            true
                        } else {
                            false
                        }
                    } else {
                        r.generated.push(0);
                        true
                    };
                    if emitted {
                        tracked.get_mut(&r.id).expect("active is tracked").1 += 1;
                    }
                }
                Ok(())
            };
            for &(roll, submit_burst, try_cancel) in steps {
                if submit_burst {
                    for _ in 0..=(roll % 3) as usize {
                        let id = next_id;
                        next_id += 1;
                        let prompt = 1 + (roll as usize % 3);
                        let gen = 1 + ((roll >> 8) as usize % 4);
                        tracked.insert(id, (gen, 0, false));
                        b.submit(Request::new(id, vec![1; prompt], gen))?;
                    }
                }
                if try_cancel {
                    // an active, not-yet-terminal target — cancel must
                    // succeed on those (waiting-queue cancellation is
                    // covered at the batcher unit level).
                    let live: Vec<u64> =
                        b.active.iter().filter(|r| !r.finished()).map(|r| r.id).collect();
                    if !live.is_empty() {
                        let victim = live[(roll % live.len() as u64) as usize];
                        b.cancel(victim).map_err(|e| format!("cancel of live {victim}: {e}"))?;
                        ledger.remove(&victim);
                        tracked.get_mut(&victim).expect("live is tracked").2 = true;
                        // a cancelled id stays burned: resubmission must
                        // be a typed duplicate rejection.
                        match b.submit(Request::new(victim, vec![1], 1)) {
                            Err(EngineError::DuplicateId { id }) if id == victim => {}
                            other => return Err(format!("resubmit after cancel: {other:?}")),
                        }
                    }
                }
                drive_one(&mut b, &mut ledger, &mut tracked)?;
            }
            // drain to completion.
            let mut guard = 0;
            while b.has_work() {
                guard += 1;
                if guard > 10_000 {
                    return Err("drain livelock".into());
                }
                drive_one(&mut b, &mut ledger, &mut tracked)?;
            }
            // every submitted id finished exactly once, with consistent
            // token accounting and finish state.
            if b.finished.len() != tracked.len() {
                return Err(format!("{} of {} finished", b.finished.len(), tracked.len()));
            }
            let mut seen = std::collections::HashSet::new();
            for r in &b.finished {
                if !seen.insert(r.id) {
                    return Err(format!("req {} finished twice", r.id));
                }
                let &(want, emitted, cancelled) = tracked
                    .get(&r.id)
                    .ok_or_else(|| format!("req {} finished but never tracked", r.id))?;
                if r.generated.len() != emitted {
                    return Err(format!(
                        "req {}: {} tokens recorded, {emitted} emitted (lost/duplicated)",
                        r.id,
                        r.generated.len()
                    ));
                }
                if cancelled {
                    if r.finish != Some(FinishReason::Cancelled) {
                        return Err(format!("req {} cancelled but finish = {:?}", r.id, r.finish));
                    }
                    if r.generated.len() > want {
                        return Err(format!("req {} overshot its budget after cancel", r.id));
                    }
                } else if r.generated.len() != want {
                    return Err(format!("req {}: {} of {want} tokens", r.id, r.generated.len()));
                }
            }
            if !ledger.is_empty() {
                return Err(format!("{} requests never retired", ledger.len()));
            }
            if b.kv.free_blocks() != *blocks {
                return Err(format!("leaked blocks: {} of {blocks} free", b.kv.free_blocks()));
            }
            Ok(())
        },
    );
}

/// The real-numerics churn the step API promises: ≥ 100 `step()` calls
/// with mid-flight submission, cancellation, and EOS stops, holding
/// `allocs == bytes_copied == output_allocs == kv_rows_migrated == 0`
/// throughout (compaction off), with no token lost or duplicated —
/// every request's event stream equals its recorded output. Runs on
/// the native CPU backend — no artifacts dir, no PJRT library.
#[test]
fn engine_step_churn_100_steps_is_zero_copy_with_cancel_and_eos() {
    use mpk::megakernel::MegaConfig;
    use mpk::serving::{ServeEngine, TokenEvent};
    use std::collections::HashSet;

    let mega = MegaConfig { workers: 4, schedulers: 1, ..Default::default() };

    // discover an EOS token: requests are row-independent, so whatever
    // prompt [7] decodes third under this seed, it decodes third in any
    // batch composition — a budget-4 request with that EOS stops at 3.
    let mut probe = ServeEngine::builder().max_batch(1).pool_threads(2).seed(42).mega(mega).build().unwrap();
    probe.submit(Request::new(999_999, vec![7], 4)).unwrap();
    let (pout, _) = probe.serve().unwrap();
    let eos = pout[&999_999][2];
    drop(probe);

    let mut e = ServeEngine::builder()
        .max_batch(4)
        .pool_threads(2)
        .seed(42)
        .mega(mega)
        .eos_token(eos)
        .build()
        .unwrap();
    let mut rng = XorShift64::new(0xC0FFEE);
    let mut expected: HashMap<u64, usize> = HashMap::new(); // id → budget
    let mut cancelled: HashSet<u64> = HashSet::new();
    let mut events: Vec<TokenEvent> = Vec::new();
    let mut next_id = 0u64;
    let submit = |e: &mut ServeEngine, expected: &mut HashMap<u64, usize>, id: u64| {
        // every 5th request is EOS-prone: prompt [7], budget 4, stops
        // at 3 via the discovered token. If the discovered token also
        // appears earlier/later in other streams, those stop early too
        // — the stream-vs-output check below stays exact either way.
        let (prompt, budget) =
            if id % 5 == 0 { (vec![7], 4) } else { (vec![1 + (id as i32 % 9), 3], 1 + (id as usize % 4)) };
        expected.insert(id, budget);
        e.submit(Request::new(id, prompt, budget)).unwrap();
    };
    // one long-lived request we cancel deterministically mid-decode.
    let victim = 500_000u64;
    expected.insert(victim, 12);
    e.submit(Request::new(victim, vec![5, 5], 12)).unwrap();

    let mut steps = 0usize;
    while steps < 110 || e.has_work() {
        if steps < 100 && rng.below(2) == 0 {
            for _ in 0..=rng.below(2) {
                let id = next_id;
                next_id += 1;
                submit(&mut e, &mut expected, id);
            }
        }
        if steps == 5 {
            // mid-decode cancellation: slot + KV blocks free now.
            e.cancel(victim).unwrap();
            cancelled.insert(victim);
        } else if steps > 5 && rng.below(6) == 0 {
            // plus random cancels of live non-EOS requests.
            let live: Vec<u64> = e
                .batcher
                .active
                .iter()
                .filter(|r| !r.finished() && r.id % 5 != 0 && r.id != victim)
                .map(|r| r.id)
                .collect();
            if !live.is_empty() {
                let id = live[rng.below(live.len())];
                e.cancel(id).unwrap();
                cancelled.insert(id);
            }
        }
        events.extend(e.step().unwrap().events);
        steps += 1;
        assert!(steps < 5000, "churn livelock");
    }
    assert!(steps >= 100, "churn too short: {steps} steps");

    // no token lost or duplicated: each request's event stream equals
    // its recorded output, with exactly one terminal event.
    assert_eq!(e.batcher.finished.len(), expected.len());
    for r in &e.batcher.finished {
        let stream: Vec<i32> =
            events.iter().filter(|ev| ev.request == r.id).filter_map(|ev| ev.token).collect();
        assert_eq!(stream, r.generated, "req {} stream != output", r.id);
        let terminals =
            events.iter().filter(|ev| ev.request == r.id && ev.finish.is_some()).count();
        assert_eq!(terminals, 1, "req {} terminal events", r.id);
        match r.finish {
            Some(FinishReason::Cancelled) => {
                assert!(cancelled.contains(&r.id), "req {} cancelled by nobody", r.id)
            }
            Some(FinishReason::Eos) => {
                assert_eq!(*r.generated.last().unwrap(), eos, "req {} EOS mismatch", r.id)
            }
            Some(FinishReason::MaxTokens) => {
                assert_eq!(r.generated.len(), expected[&r.id], "req {} budget", r.id)
            }
            None => panic!("req {} retired without a finish reason", r.id),
        }
    }
    // all three finish reasons actually occurred in this churn.
    let reasons: HashSet<_> = e.batcher.finished.iter().filter_map(|r| r.finish).collect();
    assert!(reasons.contains(&FinishReason::MaxTokens), "no natural finish exercised");
    assert!(reasons.contains(&FinishReason::Eos), "no EOS stop exercised");
    assert!(reasons.contains(&FinishReason::Cancelled), "no cancellation exercised");
    // the acceptance invariant: a hundred churned steps, zero copies,
    // zero output allocations, zero migrated rows (compaction off).
    assert_eq!(e.store_counters(), (0, 0), "churn copied tensor data");
    assert_eq!(e.output_allocs(), 0, "churn allocated output buffers");
    assert_eq!(e.stats().kv_rows_migrated, 0, "churn moved KV rows");
}

#[test]
fn prop_kv_allocator_never_oversubscribes() {
    forall(
        "kv allocator",
        0xA110C,
        100,
        |rng: &mut XorShift64| {
            let blocks = rng.range(1, 32);
            let ops: Vec<(u64, usize, bool)> =
                (0..rng.range(1, 60)).map(|_| (rng.below(8) as u64, rng.range(0, 40), rng.below(4) == 0)).collect();
            (blocks, ops)
        },
        |(blocks, ops)| {
            let mut a = KvAllocator::new(*blocks, 4);
            let mut outstanding = 0usize;
            let mut held: std::collections::HashMap<u64, usize> = Default::default();
            for &(req, tokens, release) in ops {
                if release {
                    let freed = a.release(req);
                    outstanding -= freed;
                    held.remove(&req);
                } else if a.ensure(req, tokens) {
                    let new_held = a.held_by(req);
                    let old = held.insert(req, new_held).unwrap_or(0);
                    outstanding += new_held - old;
                }
                if outstanding + a.free_blocks() != *blocks {
                    return Err("block conservation violated".into());
                }
            }
            Ok(())
        },
    );
}
