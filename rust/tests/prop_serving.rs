//! Property suite over the serving substrate: random request mixes must
//! conserve KV blocks, never exceed batch capacity, keep every active
//! request's slot **stable** from admission to retirement
//! (lowest-free-slot batching), and complete every request with exactly
//! the asked-for token count. (Scheduler-level — no artifacts needed;
//! the real-numerics serving path is covered by `serving::engine` tests
//! and `examples/serve_e2e`.)

use mpk::proputil::forall;
use mpk::serving::{Batcher, KvAllocator, Request};
use mpk::util::XorShift64;
use std::collections::HashMap;

struct Workload {
    max_batch: usize,
    blocks: usize,
    requests: Vec<(usize, usize)>, // (prompt_len, gen_len)
}

fn random_workload(rng: &mut XorShift64) -> Workload {
    Workload {
        max_batch: rng.range(1, 6),
        blocks: rng.range(4, 64),
        requests: (0..rng.range(1, 20))
            .map(|_| (rng.range(1, 8), rng.range(1, 8)))
            .collect(),
    }
}

/// Check the slot invariants for the current active set against the
/// stability ledger: slots are unique, in bounds, and — for requests
/// seen active before — unchanged since admission.
fn check_slots(b: &Batcher, ledger: &mut HashMap<u64, usize>) -> Result<(), String> {
    let mut seen = vec![false; b.max_batch];
    for r in &b.active {
        let slot = r.slot.ok_or_else(|| format!("active req {} without slot", r.id))?;
        if slot >= b.max_batch {
            return Err(format!("req {} slot {slot} out of bounds", r.id));
        }
        if seen[slot] {
            return Err(format!("slot {slot} occupied twice"));
        }
        seen[slot] = true;
        match ledger.get(&r.id) {
            None => {
                ledger.insert(r.id, slot);
            }
            Some(&home) if home == slot => {}
            Some(&home) => {
                return Err(format!("req {} moved slot {home} -> {slot}", r.id));
            }
        }
    }
    Ok(())
}

/// Drive the batcher with a fake model (each iteration generates one
/// token for every active request).
fn drive(w: &Workload) -> Result<(), String> {
    let kv = KvAllocator::new(w.blocks, 8);
    let mut b = Batcher::new(w.max_batch, 64, kv);
    for (i, &(p, g)) in w.requests.iter().enumerate() {
        b.submit(Request::new(i as u64, vec![1; p], g))?;
    }
    let total_blocks = w.blocks;
    let mut slot_ledger: HashMap<u64, usize> = HashMap::new();
    let mut guard = 0;
    while b.has_work() {
        guard += 1;
        if guard > 10_000 {
            return Err("batcher livelock".into());
        }
        let retired = b.step_admission();
        for id in retired {
            slot_ledger.remove(&id);
        }
        if b.active.is_empty() {
            if b.pending() > 0 {
                // a single waiting request must always fit eventually:
                // worst-case demand ≤ pool size?
                let (p, g) = w.requests[0];
                if (p + g).div_ceil(8) > total_blocks {
                    return Ok(()); // permanently oversized workload: fine to stall
                }
                return Err("stall with free capacity".into());
            }
            break;
        }
        if b.active.len() > w.max_batch {
            return Err(format!("batch overflow: {}", b.active.len()));
        }
        // slots unique, in bounds, and stable across the request's life.
        check_slots(&b, &mut slot_ledger)?;
        // the specialized graph must cover every occupied slot.
        let bound = b.active.iter().map(|r| r.slot.unwrap() + 1).max().unwrap();
        if b.graph_batch() < bound {
            return Err(format!("graph_batch {} < slot bound {bound}", b.graph_batch()));
        }
        // fake decode step.
        for r in b.active.iter_mut() {
            r.cache_len += 1;
            if r.in_prefill() {
                r.prompt_pos += 1;
                if !r.in_prefill() {
                    r.generated.push(0);
                }
            } else {
                r.generated.push(0);
            }
        }
    }
    // every request finished with the right token count.
    if b.finished.len() != w.requests.len() {
        return Err(format!("{} of {} finished", b.finished.len(), w.requests.len()));
    }
    for r in &b.finished {
        let want = w.requests[r.id as usize].1;
        if r.generated.len() != want {
            return Err(format!("req {} generated {} of {want}", r.id, r.generated.len()));
        }
    }
    // all KV blocks returned.
    if b.kv.free_blocks() != total_blocks {
        return Err(format!("leaked blocks: {} of {total_blocks} free", b.kv.free_blocks()));
    }
    Ok(())
}

#[test]
fn prop_continuous_batching_conserves_blocks_and_completes() {
    forall("serving invariants", 0x5E11, 60, random_workload, |w| {
        // skip impossible workloads (a single request larger than pool).
        if w.requests.iter().any(|&(p, g)| (p + g).div_ceil(8) > w.blocks) {
            return Ok(());
        }
        drive(w)
    });
}

/// Arbitrary retire/admit sequences — not just run-to-completion decode:
/// each step force-finishes a random subset of the active set and
/// trickles in new submissions, which is exactly the churn that used to
/// trigger prefix compaction. No surviving request's slot may ever
/// change, and freed slots must be re-issued lowest-first.
#[test]
fn prop_slots_stable_under_arbitrary_retire_admit() {
    forall(
        "slot stability",
        0x5107_AB1E,
        80,
        |rng: &mut XorShift64| {
            let max_batch = rng.range(1, 9);
            let steps: Vec<(u64, bool)> =
                (0..rng.range(5, 60)).map(|_| (rng.next_u64(), rng.below(3) == 0)).collect();
            (max_batch, steps)
        },
        |(max_batch, steps)| {
            let mut b = Batcher::new(*max_batch, 64, KvAllocator::new(1024, 8));
            let mut ledger: HashMap<u64, usize> = HashMap::new();
            let mut next_id = 0u64;
            for &(roll, submit_burst) in steps {
                // retire a random subset of the active set.
                let n = b.active.len();
                for i in 0..n {
                    if (roll >> i) & 1 == 1 {
                        let r = &mut b.active[i];
                        while r.generated.len() < r.max_new_tokens {
                            r.generated.push(0);
                        }
                    }
                }
                if submit_burst {
                    for _ in 0..=(roll % 3) {
                        b.submit(Request::new(next_id, vec![1, 2], 4)).unwrap();
                        next_id += 1;
                    }
                }
                let retired = b.step_admission();
                for id in &retired {
                    if ledger.remove(id).is_none() {
                        return Err(format!("retired req {id} was never active"));
                    }
                }
                let before: HashMap<u64, usize> = ledger.clone();
                check_slots(&b, &mut ledger)?;
                // lowest-free-slot: every *newly* admitted request must
                // sit below every free slot at or under the bound.
                let occupied: Vec<usize> = b.active.iter().map(|r| r.slot.unwrap()).collect();
                for r in &b.active {
                    if before.contains_key(&r.id) {
                        continue; // pre-existing: stability already checked
                    }
                    let slot = r.slot.unwrap();
                    for lower in 0..slot {
                        if !occupied.contains(&lower) {
                            return Err(format!(
                                "req {} admitted at {slot} while slot {lower} was free",
                                r.id
                            ));
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_kv_allocator_never_oversubscribes() {
    forall(
        "kv allocator",
        0xA110C,
        100,
        |rng: &mut XorShift64| {
            let blocks = rng.range(1, 32);
            let ops: Vec<(u64, usize, bool)> =
                (0..rng.range(1, 60)).map(|_| (rng.below(8) as u64, rng.range(0, 40), rng.below(4) == 0)).collect();
            (blocks, ops)
        },
        |(blocks, ops)| {
            let mut a = KvAllocator::new(*blocks, 4);
            let mut outstanding = 0usize;
            let mut held: std::collections::HashMap<u64, usize> = Default::default();
            for &(req, tokens, release) in ops {
                if release {
                    let freed = a.release(req);
                    outstanding -= freed;
                    held.remove(&req);
                } else if a.ensure(req, tokens) {
                    let new_held = a.held_by(req);
                    let old = held.insert(req, new_held).unwrap_or(0);
                    outstanding += new_held - old;
                }
                if outstanding + a.free_blocks() != *blocks {
                    return Err("block conservation violated".into());
                }
            }
            Ok(())
        },
    );
}
