//! Edge cases and failure injection across the stack: degenerate
//! graphs, minimal runtime configurations, timeout behavior, and
//! simulator monotonicity properties.

use mpk::megakernel::{MegaConfig, MegaKernel};
use mpk::models::{build_decode_graph, GraphOptions, ModelConfig};
use mpk::ops::{CompGraph, DType, OpKind};
use mpk::sim::{simulate_megakernel, GpuSpec, SimOptions};
use mpk::tgraph::{compile, CompileOptions, DecomposeConfig, TaskDesc};
use std::time::Duration;

fn compile_default(g: &CompGraph) -> mpk::tgraph::CompiledGraph {
    compile(g, &CompileOptions { decompose: DecomposeConfig { target_tasks: 4, min_tile_cols: 4 }, ..Default::default() })
}

#[test]
fn single_op_graph_compiles_and_runs() {
    let mut g = CompGraph::new();
    let x = g.input("x", vec![2, 16], DType::F32);
    let w = g.param("w", vec![16, 8], DType::F32);
    g.op("mm", OpKind::MatMul, &[x, w], vec![2, 8], DType::F32);
    let c = compile_default(&g);
    c.tgraph.check_consistent().unwrap();
    let mk = MegaKernel::new(&c, MegaConfig::default());
    let r = mk.run(&|_: &TaskDesc| {}).unwrap();
    assert_eq!(r.metrics.tasks_executed as usize, c.tgraph.tasks.len());
}

#[test]
fn chain_of_elementwise_ops() {
    // long sequential chain: degenerate parallelism, must not deadlock.
    let mut g = CompGraph::new();
    let mut x = g.input("x", vec![1, 8], DType::F32);
    let y = g.input("y", vec![1, 8], DType::F32);
    for i in 0..50 {
        x = g.op(&format!("add{i}"), OpKind::Add, &[x, y], vec![1, 8], DType::F32);
    }
    let c = compile_default(&g);
    let mk = MegaKernel::new(&c, MegaConfig { workers: 2, schedulers: 1, ..Default::default() });
    let r = mk.run(&|_: &TaskDesc| {}).unwrap();
    assert_eq!(r.metrics.tasks_executed as usize, c.tgraph.tasks.len());
}

#[test]
fn wide_fanout_graph() {
    // one producer feeding 30 consumers: stress fusion + normalization.
    let mut g = CompGraph::new();
    let x = g.input("x", vec![2, 32], DType::F32);
    let w0 = g.param("w0", vec![32, 32], DType::F32);
    let h = g.op("h", OpKind::MatMul, &[x, w0], vec![2, 32], DType::F32);
    for i in 0..30 {
        let w = g.param(&format!("w{}", i + 1), vec![32, 16], DType::F32);
        g.op(&format!("mm{i}"), OpKind::MatMul, &[h, w], vec![2, 16], DType::F32);
    }
    let c = compile_default(&g);
    c.tgraph.check_consistent().unwrap();
    assert!(c.tgraph.is_normalized());
    mpk::tgraph::linearize::verify(&c.linear, &c.tgraph.tasks, &c.tgraph.events).unwrap();
}

#[test]
fn timeout_surfaces_as_error_not_hang() {
    // an executor that blocks forever on one task must trip the
    // watchdog, not hang the test suite.
    let cfg = ModelConfig::tiny();
    let g = build_decode_graph(&cfg, &GraphOptions { batch: 1, kv_len: 8, ..Default::default() });
    let c = compile_default(&g);
    let mk = MegaKernel::new(
        &c,
        MegaConfig { workers: 2, schedulers: 1, timeout: Duration::from_millis(300) },
    );
    let res = mk.run(&|t: &TaskDesc| {
        if t.id == c.linear.order[0] {
            std::thread::sleep(Duration::from_secs(10));
        }
    });
    assert!(res.is_err(), "watchdog should have fired");
    assert!(res.unwrap_err().0.contains("timed out"));
}

#[test]
fn sim_makespan_monotone_in_batch() {
    let gpu = GpuSpec::h100();
    let cfg = ModelConfig::qwen3_0_6b();
    let mut last = 0.0;
    for b in [1usize, 4, 16] {
        let g = build_decode_graph(&cfg, &GraphOptions { batch: b, kv_len: 128, ..Default::default() });
        let c = compile(
            &g,
            &CompileOptions {
                decompose: DecomposeConfig { target_tasks: gpu.workers, min_tile_cols: 8 },
                ..Default::default()
            },
        );
        let m = simulate_megakernel(&c, &gpu, &SimOptions { jitter: 0.0, ..Default::default() }).makespan_us;
        assert!(m > last, "batch {b}: {m} <= {last}");
        last = m;
    }
}

#[test]
fn sim_makespan_monotone_in_kv_len() {
    let gpu = GpuSpec::a100();
    let cfg = ModelConfig::qwen3_1_7b();
    let mut last = 0.0;
    for kv in [64usize, 512, 4096] {
        let g = build_decode_graph(&cfg, &GraphOptions { batch: 4, kv_len: kv, ..Default::default() });
        let c = compile(
            &g,
            &CompileOptions {
                decompose: DecomposeConfig { target_tasks: gpu.workers, min_tile_cols: 8 },
                ..Default::default()
            },
        );
        let m = simulate_megakernel(&c, &gpu, &SimOptions { jitter: 0.0, ..Default::default() }).makespan_us;
        assert!(m >= last, "kv {kv}: {m} < {last}");
        last = m;
    }
}

#[test]
fn faster_gpu_is_faster() {
    let cfg = ModelConfig::qwen3_1_7b();
    let g = build_decode_graph(&cfg, &GraphOptions { batch: 1, kv_len: 256, ..Default::default() });
    let mut times = Vec::new();
    for gpu in [GpuSpec::a100(), GpuSpec::h100(), GpuSpec::b200()] {
        let c = compile(
            &g,
            &CompileOptions {
                decompose: DecomposeConfig { target_tasks: gpu.workers, min_tile_cols: 8 },
                ..Default::default()
            },
        );
        times.push(simulate_megakernel(&c, &gpu, &SimOptions { jitter: 0.0, ..Default::default() }).makespan_us);
    }
    assert!(times[0] > times[1] && times[1] > times[2], "{times:?}");
}

#[test]
fn global_queue_policy_slower_but_correct() {
    use mpk::sim::engine::SchedPolicy;
    let gpu = GpuSpec::b200();
    let cfg = ModelConfig::tiny();
    let g = build_decode_graph(&cfg, &GraphOptions { batch: 4, kv_len: 16, ..Default::default() });
    let c = compile(
        &g,
        &CompileOptions { decompose: DecomposeConfig { target_tasks: 16, min_tile_cols: 8 }, ..Default::default() },
    );
    let dec = simulate_megakernel(&c, &gpu, &SimOptions { jitter: 0.0, ..Default::default() });
    let glob = simulate_megakernel(
        &c,
        &gpu,
        &SimOptions { jitter: 0.0, policy: SchedPolicy::GlobalQueue, ..Default::default() },
    );
    assert_eq!(dec.tasks, glob.tasks);
    assert!(glob.makespan_us > dec.makespan_us, "global {} <= dec {}", glob.makespan_us, dec.makespan_us);
}

#[test]
fn zero_generation_requests_complete_immediately() {
    use mpk::serving::{Batcher, KvAllocator, Request};
    let mut b = Batcher::new(2, 64, KvAllocator::new(16, 8));
    // max_new_tokens = 1: shortest legal request.
    b.submit(Request::new(0, vec![1], 1)).unwrap();
    b.step_admission();
    assert_eq!(b.active.len(), 1);
    b.active[0].generated.push(5);
    let retired = b.step_admission();
    assert_eq!(retired, vec![0]);
    assert!(!b.has_work());
}
