//! Acceptance suite for the static race/deadlock verifier
//! (`tgraph/verify.rs`) on the *built-in* decode graphs:
//!
//! * every unmutated compile — all `DepGranularity` options × `fuse` ×
//!   `merge_forks` — verifies clean (all four analyses);
//! * the seeded mutation harness catches ≥ 95% of single-edge
//!   deletions/redirections (the acceptance bar: an analyzer that
//!   passes everything is worthless);
//! * verification is observation-only: compiling with the gate on and
//!   off yields the same simulated makespan (paper-figure stats are
//!   untouched by the new stage).

use mpk::models::{build_decode_graph, GraphOptions, ModelConfig};
use mpk::sim::{simulate_megakernel, GpuSpec, SimOptions};
use mpk::tgraph::{
    compile, compile_verified, mutation_sweep, CompileOptions, DecomposeConfig, DepGranularity,
};

/// The decode graphs the suite runs against: the tiny end-to-end model
/// plus the smallest paper model (the full five-model sweep runs in CI
/// via `mpk verify`).
fn builtin_graphs() -> Vec<(ModelConfig, GraphOptions)> {
    vec![
        (ModelConfig::tiny(), GraphOptions { batch: 2, kv_len: 64, ..Default::default() }),
        (ModelConfig::qwen3_0_6b(), GraphOptions { batch: 1, kv_len: 64, ..Default::default() }),
    ]
}

fn all_option_combos() -> Vec<CompileOptions> {
    let grans =
        [DepGranularity::Fine, DepGranularity::CoarseCollectives, DepGranularity::CoarseAll];
    let mut v = Vec::new();
    for &granularity in &grans {
        for &fuse in &[false, true] {
            for &merge_forks in &[false, true] {
                v.push(CompileOptions {
                    decompose: DecomposeConfig { target_tasks: 32, min_tile_cols: 8 },
                    granularity,
                    fuse,
                    merge_forks,
                    verify: true,
                });
            }
        }
    }
    v
}

#[test]
fn builtin_graphs_verify_clean_under_all_options() {
    for (cfg, gopt) in builtin_graphs() {
        let g = build_decode_graph(&cfg, &gopt);
        for opt in all_option_combos() {
            let (_, report) = compile_verified(&g, &opt);
            assert!(
                report.is_clean(),
                "{} with {:?}/fuse={}/merge={} failed verification:\n{}",
                cfg.name,
                opt.granularity,
                opt.fuse,
                opt.merge_forks,
                report.render(8)
            );
            assert!(report.region_pairs > 0, "{}: verifier checked no pairs", cfg.name);
            assert!(report.hb_edges > 0, "{}: verifier saw no hb edges", cfg.name);
        }
    }
}

#[test]
fn mutation_catch_rate_meets_acceptance_bar() {
    // ≥ 95% of seeded single-edge mutations on the built-in decode
    // graphs must trip the race or liveness analysis. Aggregated over
    // the default options and both coarse ablations so the bar covers
    // every happens-before construction path.
    let mut total = 0usize;
    let mut caught = 0usize;
    let mut survivors = Vec::new();
    for (cfg, gopt) in builtin_graphs() {
        let g = build_decode_graph(&cfg, &gopt);
        for &granularity in
            &[DepGranularity::Fine, DepGranularity::CoarseCollectives, DepGranularity::CoarseAll]
        {
            let opt = CompileOptions {
                decompose: DecomposeConfig { target_tasks: 32, min_tile_cols: 8 },
                granularity,
                ..Default::default()
            };
            let (c, report) = compile_verified(&g, &opt);
            assert!(report.is_clean(), "{}: baseline not clean", cfg.name);
            let sweep = mutation_sweep(&c, 40, 0xD15EA5E);
            total += sweep.total;
            caught += sweep.caught;
            survivors.extend(sweep.survivors.into_iter().map(|m| (cfg.name, granularity, m)));
        }
    }
    assert!(total >= 100, "sweep too small to be meaningful: {total}");
    let rate = caught as f64 / total as f64;
    assert!(
        rate >= 0.95,
        "mutation catch rate {:.1}% < 95% ({caught}/{total}); survivors: {survivors:?}",
        rate * 100.0
    );
}

#[test]
fn verification_does_not_perturb_compiled_output() {
    // The verifier is a read-only gate: same tGraph, same linear order,
    // same simulated makespan with the gate on or off.
    let graphs = builtin_graphs();
    let (cfg, gopt) = &graphs[0];
    let g = build_decode_graph(cfg, gopt);
    let base = CompileOptions {
        decompose: DecomposeConfig { target_tasks: 32, min_tile_cols: 8 },
        ..Default::default()
    };
    let on = compile(&g, &CompileOptions { verify: true, ..base.clone() });
    let off = compile(&g, &CompileOptions { verify: false, ..base });
    assert_eq!(on.tgraph.tasks.len(), off.tgraph.tasks.len());
    assert_eq!(on.tgraph.events.len(), off.tgraph.events.len());
    assert_eq!(on.linear.order, off.linear.order);
    let gpu = GpuSpec::by_name("A100").unwrap();
    let m_on = simulate_megakernel(&on, &gpu, &SimOptions::default()).makespan_us;
    let m_off = simulate_megakernel(&off, &gpu, &SimOptions::default()).makespan_us;
    assert_eq!(m_on.to_bits(), m_off.to_bits(), "verification changed the simulated makespan");
    // and the gate's coverage stats landed in the Table-2 row.
    assert!(on.stats().verify_pairs > 0);
    assert!(on.stats().verify_us > 0 || on.stats().verify_pairs > 0);
    assert_eq!(off.stats().verify_pairs, 0);
}
