//! Chaos and robustness suite for the TCP transport
//! ([`mpk::serving::ServeTransport`]), run over loopback sockets
//! against the backend-free `MockEngine`.
//!
//! Three layers, mirroring `server_overload.rs` one level down the
//! stack:
//!
//! 1. Deterministic unit tests of each wire policy in isolation:
//!    end-to-end streaming round trip, oversized-frame refusal,
//!    slowloris mid-frame stall cutoff, the per-connection in-flight
//!    cap, both slow-reader policies, and the forced-drain deadline.
//! 2. A seeded property test (`mpk::proputil::forall`): clients that
//!    disconnect mid-stream at random points must always leave the
//!    server reconciled — every submission the transport accepted gets
//!    exactly one terminal event, and every KV block returns to the
//!    pool.
//! 3. A chaos acceptance run: 32 concurrent connections with seeded
//!    wire faults armed in *both* directions (truncated, corrupted,
//!    delayed frames; dropped connections). Whatever the wire does,
//!    the server-side ledger must balance: no lost or duplicated
//!    terminal events, no leaked slots or KV blocks, a drain that
//!    completes within its bounded deadline, and no hangs. A larger
//!    `#[ignore]`d soak (64 connections) rides along for CI.

use mpk::proputil::forall;
use mpk::serving::mock::MockEngine;
use mpk::serving::{
    EngineError, FinishReason, Priority, Request, ServeServer, ServeStats, ServeTransport,
    ServerConfig, ServerFrame, SlowReaderPolicy, StepEngine, StepOutcome, SubmitOptions,
    TransportClient, TransportConfig, WireFaultPlan,
};
use mpk::util::XorShift64;
use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// KV pool gauges exported from inside the serving thread. The engine
/// moves into the server on spawn, so post-drain conservation checks
/// cannot probe it directly — the wrapper below mirrors the pool state
/// into these shared atomics after every mutating engine call.
#[derive(Clone, Default)]
struct KvGauges {
    total: Arc<AtomicUsize>,
    free: Arc<AtomicUsize>,
}

impl KvGauges {
    fn leaked(&self) -> bool {
        self.free.load(Ordering::SeqCst) != self.total.load(Ordering::SeqCst)
    }
}

/// A [`MockEngine`] that (a) mirrors its KV pool occupancy into
/// [`KvGauges`] and (b) optionally sleeps per step, so requests stay
/// in flight long enough for disconnects and drain deadlines to catch
/// them mid-stream.
struct GaugedEngine {
    inner: MockEngine,
    delay: Duration,
    gauges: KvGauges,
}

impl GaugedEngine {
    fn new(inner: MockEngine, delay: Duration) -> (GaugedEngine, KvGauges) {
        let gauges = KvGauges::default();
        let e = GaugedEngine { inner, delay, gauges: gauges.clone() };
        e.sync();
        (e, gauges)
    }

    fn sync(&self) {
        self.gauges.total.store(self.inner.kv_total_blocks(), Ordering::SeqCst);
        self.gauges.free.store(self.inner.kv_free_blocks(), Ordering::SeqCst);
    }
}

impl StepEngine for GaugedEngine {
    fn submit(&mut self, r: Request) -> Result<(), EngineError> {
        let res = self.inner.submit(r);
        self.sync();
        res
    }
    fn validate(&self, r: &Request) -> Result<(), EngineError> {
        self.inner.validate(r)
    }
    fn terminate(&mut self, id: u64, reason: FinishReason) -> Result<(), EngineError> {
        let res = self.inner.terminate(id, reason);
        self.sync();
        res
    }
    fn step(&mut self) -> Result<StepOutcome, EngineError> {
        if !self.delay.is_zero() {
            std::thread::sleep(self.delay);
        }
        let res = self.inner.step();
        self.sync();
        res
    }
    fn has_work(&self) -> bool {
        self.inner.has_work()
    }
    fn capacity(&self) -> usize {
        self.inner.capacity()
    }
    fn in_flight(&self) -> usize {
        self.inner.in_flight()
    }
    fn take_finished(&mut self) -> Vec<Request> {
        let r = self.inner.take_finished();
        self.sync();
        r
    }
    fn take_stats(&mut self) -> ServeStats {
        let r = self.inner.take_stats();
        self.sync();
        r
    }
}

/// Bind a transport over a gauged mock on an ephemeral loopback port.
fn bind(
    capacity: usize,
    step_delay: Duration,
    queue_depth: usize,
    cfg: TransportConfig,
) -> (ServeTransport, KvGauges) {
    let (engine, gauges) = GaugedEngine::new(MockEngine::new(capacity), step_delay);
    let server = ServeServer::spawn_with(
        engine,
        ServerConfig { queue_depth, idle_poll: Duration::from_micros(200) },
    );
    let transport = ServeTransport::bind("127.0.0.1:0", server, cfg).expect("bind loopback");
    (transport, gauges)
}

// ---------------------------------------------------------------------
// deterministic unit tests
// ---------------------------------------------------------------------

#[test]
fn loopback_round_trip_streams_tokens_and_status() {
    let (transport, gauges) = bind(4, Duration::ZERO, 64, TransportConfig::default());
    let mut client = TransportClient::connect(transport.local_addr()).expect("connect");

    let (tokens, finish) = client.run(1, vec![3, 7], 8, SubmitOptions::default()).expect("run");
    assert_eq!(finish, FinishReason::MaxTokens);
    assert_eq!(tokens.len(), 8, "full budget over the wire: {tokens:?}");

    client.request_status().expect("status request");
    loop {
        match client.next_frame().expect("status frame") {
            Some(ServerFrame::Status { capacity, finished, .. }) => {
                assert_eq!(capacity, 4);
                assert_eq!(finished, 1);
                break;
            }
            Some(other) => panic!("expected Status, got {other:?}"),
            None => panic!("connection closed before the status frame"),
        }
    }

    let report = transport.drain(Duration::from_secs(5));
    assert!(report.server.fatal.is_none());
    assert_eq!(report.server.finished, 1);
    assert_eq!(report.forced, 0, "nothing was live at drain");
    assert_eq!(report.transport.requests_submitted, 1);
    assert!(report.transport.frames_sent >= 10, "accepted + 8 tokens + status");
    assert!(!gauges.leaked(), "KV blocks leaked");
}

#[test]
fn oversized_length_prefix_is_refused_before_the_body() {
    let (transport, _gauges) = bind(1, Duration::ZERO, 64, TransportConfig::default());
    let mut raw = TcpStream::connect(transport.local_addr()).expect("connect");
    raw.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
    // a prefix claiming a 4 GiB body: the cap check must fire on the
    // prefix alone — no buffer of that size is ever allocated.
    raw.write_all(&u32::MAX.to_le_bytes()).expect("write prefix");
    let t0 = Instant::now();
    while transport.metrics().protocol_errors == 0 {
        assert!(t0.elapsed() < Duration::from_secs(5), "oversized frame was not rejected");
        std::thread::sleep(Duration::from_millis(5));
    }
    // the connection is torn down: reads drain to EOF (a best-effort
    // Close{Protocol} frame may or may not precede it).
    let mut buf = Vec::new();
    let _ = raw.read_to_end(&mut buf);
    let report = transport.drain(Duration::from_secs(2));
    assert_eq!(report.transport.protocol_errors, 1);
    assert_eq!(report.server.finished, 0, "nothing was ever submitted");
}

#[test]
fn slowloris_mid_frame_stall_is_cut_off() {
    let cfg = TransportConfig { read_timeout: Duration::from_millis(150), ..Default::default() };
    let (transport, _gauges) = bind(1, Duration::ZERO, 64, cfg);
    let mut raw = TcpStream::connect(transport.local_addr()).expect("connect");
    raw.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    // announce a 20-byte body, send one byte, then go silent: the
    // stall budget (150ms) must cut the connection off — not the 10s a
    // naive blocking read would wait, and not forever.
    raw.write_all(&20u32.to_le_bytes()).expect("write prefix");
    raw.write_all(&[mpk::serving::wire::WIRE_VERSION]).expect("write one body byte");
    let t0 = Instant::now();
    let mut buf = Vec::new();
    let _ = raw.read_to_end(&mut buf); // returns once the server hangs up
    assert!(t0.elapsed() < Duration::from_secs(5), "stalled peer was not cut off");
    let report = transport.drain(Duration::from_secs(2));
    assert_eq!(report.transport.protocol_errors, 1);
}

#[test]
fn in_flight_cap_sheds_typed_and_drain_deadline_forces_the_rest() {
    // 2ms steps x 300-token budgets keep ids 1 and 2 live for over a
    // second — far past the 200ms drain deadline below.
    let cfg = TransportConfig { max_in_flight: 2, ..Default::default() };
    let (transport, gauges) = bind(4, Duration::from_millis(2), 64, cfg);
    let mut client = TransportClient::connect(transport.local_addr()).expect("connect");
    client.submit(1, vec![1], 300, SubmitOptions::default()).unwrap();
    client.submit(2, vec![1], 300, SubmitOptions::default()).unwrap();
    client.submit(3, vec![1], 300, SubmitOptions::default()).unwrap();
    // ids 1 and 2 fill the per-connection window; 3 must be answered
    // with the typed Shed frame carrying the cap, without ever
    // reaching the server.
    let t0 = Instant::now();
    let shed = loop {
        assert!(t0.elapsed() < Duration::from_secs(10), "no shed frame arrived");
        match client.next_frame().expect("frame") {
            Some(ServerFrame::Shed { id, queue_depth }) => break (id, queue_depth),
            Some(_) => {}
            None => panic!("connection closed before the shed frame"),
        }
    };
    assert_eq!(shed, (3, 2), "the third submit sheds against the cap of 2");

    let deadline = Duration::from_millis(200);
    let report = transport.drain(deadline);
    assert_eq!(report.forced, 2, "both live requests outlived the drain deadline");
    assert_eq!(report.transport.drain_forced, 2);
    assert!(report.elapsed < Duration::from_secs(5), "drain must stay bounded");
    assert!(report.server.fatal.is_none());
    // the forced cancels still produced terminal events: the ledger
    // balances even on the force path.
    assert_eq!(report.server.finished, report.transport.requests_submitted as usize);
    assert_eq!(report.transport.requests_submitted, 2);
    assert!(report.transport.requests_rejected >= 1, "the shed submit was counted");
    assert!(!gauges.leaked(), "forced drain leaked KV blocks");
}

#[test]
fn slow_reader_shed_policy_closes_the_connection_and_frees_the_request() {
    // The writer is made artificially slow (every frame delayed 2ms)
    // while the engine decodes at full speed, so the 4-deep outbound
    // queue deterministically overflows while the client reads nothing.
    let cfg = TransportConfig {
        outbound_depth: 4,
        slow_reader: SlowReaderPolicy::Shed,
        faults: WireFaultPlan {
            delay_rate: 1.0,
            delay: Duration::from_millis(2),
            ..Default::default()
        },
        ..Default::default()
    };
    let (transport, gauges) = bind(2, Duration::ZERO, 64, cfg);
    let mut client = TransportClient::connect(transport.local_addr()).expect("connect");
    client.submit(1, vec![1], 200, SubmitOptions::default()).unwrap();
    // never read: the Shed policy must close the connection rather
    // than buffer without bound or stall the pump forever.
    let t0 = Instant::now();
    while transport.metrics().slow_consumer_closes == 0 {
        assert!(t0.elapsed() < Duration::from_secs(10), "slow consumer was never shed");
        std::thread::sleep(Duration::from_millis(5));
    }
    let report = transport.drain(Duration::from_secs(5));
    assert!(report.server.fatal.is_none());
    assert!(report.transport.slow_consumer_closes >= 1);
    // the shed connection's request was cancelled (or had already
    // finished): exactly one terminal either way.
    assert_eq!(report.server.finished, report.transport.requests_submitted as usize);
    assert!(!gauges.leaked(), "shed slow consumer leaked KV blocks");
    drop(client);
}

#[test]
fn slow_reader_block_policy_delivers_every_token_to_a_stalled_reader() {
    // Same slow writer and tiny queue as the Shed test, but the Block
    // policy: the pump waits for queue slots, so a reader that stalls
    // 200ms still receives the complete stream, nothing dropped.
    let cfg = TransportConfig {
        outbound_depth: 4,
        slow_reader: SlowReaderPolicy::Block,
        faults: WireFaultPlan {
            delay_rate: 1.0,
            delay: Duration::from_millis(1),
            ..Default::default()
        },
        ..Default::default()
    };
    let (transport, gauges) = bind(1, Duration::ZERO, 64, cfg);
    let mut client = TransportClient::connect(transport.local_addr()).expect("connect");
    client.submit(1, vec![1], 64, SubmitOptions::default()).unwrap();
    std::thread::sleep(Duration::from_millis(200)); // stall the reader
    let mut tokens = 0usize;
    let finish = loop {
        match client.next_frame().expect("frame") {
            Some(ServerFrame::Token { .. }) => tokens += 1,
            Some(ServerFrame::Finish { token, reason, .. }) => {
                if token.is_some() {
                    tokens += 1;
                }
                break reason;
            }
            Some(_) => {}
            None => panic!("connection closed before the terminal frame"),
        }
    };
    assert_eq!(finish, FinishReason::MaxTokens);
    assert_eq!(tokens, 64, "Block policy must deliver the full budget despite the stall");
    let report = transport.drain(Duration::from_secs(5));
    assert_eq!(report.transport.slow_consumer_closes, 0);
    assert_eq!(report.transport.frames_dropped, 0, "nothing may be dropped under Block");
    assert_eq!(report.server.finished, 1);
    assert!(!gauges.leaked());
}

// ---------------------------------------------------------------------
// property test: disconnect mid-stream always reconciles
// ---------------------------------------------------------------------

#[derive(Clone, Copy, Debug)]
struct DropClient {
    prompt: usize,
    budget: usize,
    /// Frames to read before dropping the connection — varies the
    /// point in the stream where the disconnect lands.
    read_frames: usize,
}

#[derive(Debug)]
struct DropScript {
    capacity: usize,
    delay_us: usize,
    clients: Vec<DropClient>,
}

fn random_drop_script(rng: &mut XorShift64) -> DropScript {
    DropScript {
        capacity: rng.range(1, 4),
        delay_us: rng.range(200, 1000),
        clients: (0..rng.range(2, 6))
            .map(|_| DropClient {
                prompt: rng.range(1, 4),
                budget: rng.range(100, 400),
                read_frames: rng.range(1, 8),
            })
            .collect(),
    }
}

/// Whatever point in its stream a connection dies at, the server must
/// cancel that connection's live requests (terminal event, slots and
/// KV freed) and the books must balance: terminals delivered ==
/// submissions the transport accepted, and every KV block back in the
/// pool after drain.
fn drive_disconnect(s: &DropScript) -> Result<(), String> {
    let (engine, gauges) =
        GaugedEngine::new(MockEngine::new(s.capacity), Duration::from_micros(s.delay_us as u64));
    let server = ServeServer::spawn_with(
        engine,
        ServerConfig { queue_depth: 8, idle_poll: Duration::from_micros(200) },
    );
    let transport = ServeTransport::bind("127.0.0.1:0", server, TransportConfig::default())?;
    let addr = transport.local_addr();
    let handles: Vec<_> = s
        .clients
        .iter()
        .copied()
        .enumerate()
        .map(|(i, c)| {
            std::thread::spawn(move || -> Result<(), String> {
                let mut client = TransportClient::connect(addr)?;
                client.submit(
                    i as u64 + 1,
                    vec![1; c.prompt],
                    c.budget as u32,
                    SubmitOptions::default(),
                )?;
                for _ in 0..c.read_frames {
                    if client.next_frame()?.is_none() {
                        break;
                    }
                }
                client.abort(); // disconnect mid-stream, no goodbye
                Ok(())
            })
        })
        .collect();
    for h in handles {
        h.join().map_err(|_| "client thread panicked".to_string())??;
    }
    let deadline = Duration::from_secs(10);
    let report = transport.drain(deadline);
    if let Some(err) = &report.server.fatal {
        return Err(format!("serving thread died: {err}"));
    }
    if report.server.finished != report.transport.requests_submitted as usize {
        return Err(format!(
            "{} terminals for {} accepted submissions (lost or duplicated)",
            report.server.finished, report.transport.requests_submitted
        ));
    }
    if gauges.leaked() {
        return Err(format!(
            "KV leak: {} of {} blocks free after drain",
            gauges.free.load(Ordering::SeqCst),
            gauges.total.load(Ordering::SeqCst)
        ));
    }
    if report.elapsed > deadline + Duration::from_secs(2) {
        return Err(format!("drain overran its deadline: {:?}", report.elapsed));
    }
    Ok(())
}

#[test]
fn prop_disconnect_mid_stream_cancels_and_conserves_kv() {
    forall("transport-disconnects", 0xd15c, 8, random_drop_script, drive_disconnect);
}

// ---------------------------------------------------------------------
// chaos acceptance: concurrent connections under seeded wire faults
// ---------------------------------------------------------------------

/// `conns` concurrent connections, `per_conn` sequential requests
/// each, seeded wire faults armed on both the server's outbound path
/// and every client's outbound path. Clients tolerate any typed
/// outcome; the server-side ledger must reconcile exactly.
fn run_chaos(conns: usize, per_conn: usize, seed: u64) {
    let (engine, gauges) = GaugedEngine::new(MockEngine::new(8), Duration::from_micros(300));
    let server = ServeServer::spawn_with(
        engine,
        ServerConfig { queue_depth: 32, idle_poll: Duration::from_micros(200) },
    );
    let cfg = TransportConfig {
        max_in_flight: 4,
        faults: WireFaultPlan {
            seed,
            truncate_rate: 0.01,
            corrupt_rate: 0.02,
            delay_rate: 0.05,
            delay: Duration::from_micros(500),
            drop_rate: 0.01,
        },
        ..Default::default()
    };
    let transport = ServeTransport::bind("127.0.0.1:0", server, cfg).expect("bind loopback");
    let addr = transport.local_addr();
    let handles: Vec<_> = (0..conns)
        .map(|t| {
            std::thread::spawn(move || {
                let mut rng =
                    XorShift64::new(seed ^ (t as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15));
                let Ok(client) = TransportClient::connect(addr) else { return };
                let mut client = client.with_faults(WireFaultPlan {
                    seed: rng.next_u64(),
                    truncate_rate: 0.01,
                    corrupt_rate: 0.02,
                    drop_rate: 0.01,
                    ..Default::default()
                });
                if client.set_read_timeout(Duration::from_secs(2)).is_err() {
                    return;
                }
                for i in 0..per_conn {
                    let id = (t * per_conn + i) as u64 + 1;
                    let prompt = vec![1; rng.range(1, 4)];
                    let budget = rng.range(1, 40) as u32;
                    let opts = SubmitOptions {
                        priority: if rng.below(2) == 0 {
                            Priority::Interactive
                        } else {
                            Priority::Batch
                        },
                        deadline: (rng.below(8) == 0)
                            .then(|| Duration::from_millis(rng.below(20) as u64)),
                    };
                    // under chaos every outcome is legitimate — tokens,
                    // a typed shed/error, a corrupted frame, a dead
                    // socket. The connection is abandoned on the first
                    // failure; the server must reconcile regardless.
                    if client.run(id, prompt, budget, opts).is_err() {
                        break;
                    }
                }
                if rng.below(4) == 0 {
                    client.abort(); // some clients leave without a goodbye
                }
            })
        })
        .collect();
    for h in handles {
        let _ = h.join();
    }
    let deadline = Duration::from_secs(10);
    let report = transport.drain(deadline);
    assert!(report.server.fatal.is_none(), "serving thread died: {:?}", report.server.fatal);
    // zero lost, zero duplicated terminal events: every submission the
    // transport accepted produced exactly one terminal server-side.
    assert_eq!(
        report.server.finished, report.transport.requests_submitted as usize,
        "terminal events must match accepted submissions exactly"
    );
    assert!(!gauges.leaked(), "KV blocks leaked under wire chaos");
    assert!(
        report.elapsed <= deadline + Duration::from_secs(5),
        "drain overran its bounded deadline: {:?}",
        report.elapsed
    );
}

#[test]
fn chaos_32_connections_with_wire_faults_reconciles() {
    run_chaos(32, 4, 0xc4a05);
}

/// The CI soak (see `.github/workflows/tier1.yml`): heavier than the
/// default suite, run with `cargo test --release -- --ignored soak`.
#[test]
#[ignore = "long soak; run explicitly (CI runs it with --ignored)"]
fn soak_64_connections_with_wire_faults() {
    run_chaos(64, 6, 0x50a4);
}
