//! Property suite over the MPK compiler: random model shapes, batch
//! sizes and decomposition targets must always yield consistent,
//! normalized, linearizable tGraphs that preserve every producer/
//! consumer dependency (seeded mini-proptest — see `mpk::proputil`).

use mpk::models::{build_decode_graph, GraphOptions, ModelConfig, MoeConfig};
use mpk::proputil::forall;
use mpk::tgraph::{
    compile, compile_verified, mutation_sweep, CompileOptions, CompiledGraph, DecomposeConfig,
    DepGranularity,
};
use mpk::util::XorShift64;

fn random_config(rng: &mut XorShift64) -> (ModelConfig, GraphOptions) {
    let head_dim = [32, 64, 128][rng.below(3)];
    let kv_heads = [1, 2, 4][rng.below(3)];
    let heads = kv_heads * [1, 2, 4][rng.below(3)];
    let cfg = ModelConfig {
        name: "random",
        layers: rng.range(1, 4),
        d_model: [128, 256, 512][rng.below(3)],
        heads,
        kv_heads,
        head_dim,
        ffn: [256, 512, 1024][rng.below(3)],
        vocab: [512, 2048][rng.below(2)],
        moe: if rng.below(4) == 0 {
            Some(MoeConfig { num_experts: [8, 16][rng.below(2)], top_k: 2, expert_ffn: 128 })
        } else {
            None
        },
    };
    // tp_world must divide both head counts.
    let tp = if rng.below(4) == 0 && heads % 2 == 0 && kv_heads % 2 == 0 { 2 } else { 1 };
    let opt = GraphOptions {
        batch: [1, 2, 3, 5, 8][rng.below(5)],
        kv_len: rng.range(4, 128),
        tp_world: tp,
        unfused_qkv: rng.below(3) == 0,
        fused_kv_append: rng.below(2) == 0,
        lm_head: rng.below(4) != 0,
        ..Default::default()
    };
    (cfg, opt)
}

fn compile_random(rng: &mut XorShift64) -> CompiledGraph {
    let (cfg, opt) = random_config(rng);
    let g = build_decode_graph(&cfg, &opt);
    let copt = CompileOptions {
        decompose: DecomposeConfig { target_tasks: rng.range(2, 48), min_tile_cols: 8 },
        granularity: match rng.below(5) {
            0 => DepGranularity::CoarseAll,
            1 => DepGranularity::CoarseCollectives,
            _ => DepGranularity::Fine,
        },
        fuse: rng.below(8) != 0,
        merge_forks: rng.below(4) != 0,
        // the static race/deadlock verifier gates every random compile:
        // compile() panics with the full report on any violation.
        verify: true,
    };
    compile(&g, &copt)
}

#[test]
fn prop_compiled_graphs_are_consistent_and_normalized() {
    forall("compiler consistency", 0xC0FFEE, 40, compile_random, |c| {
        c.tgraph.check_consistent()?;
        if !c.tgraph.is_normalized() {
            return Err("graph not normalized".into());
        }
        Ok(())
    });
}

#[test]
fn prop_linearization_contiguity_and_permutation() {
    forall("linearization", 0xBEEF, 40, compile_random, |c| {
        mpk::tgraph::linearize::verify(&c.linear, &c.tgraph.tasks, &c.tgraph.events)
    });
}

#[test]
fn prop_every_real_task_covered_exactly_once() {
    // decomposition tiles partition each op's output exactly.
    forall("tile coverage", 0xDECADE, 40, compile_random, |c| {
        for ot in &c.decomposition {
            let op = &c.graph.ops[ot.op];
            let out_numel = c.graph.tensor(op.output).numel();
            let sum: usize = ot.tiles.iter().map(|t| t.numel()).sum();
            if sum != out_numel {
                return Err(format!("op {}: tiles cover {sum} of {out_numel}", op.name));
            }
            for i in 0..ot.tiles.len() {
                for j in i + 1..ot.tiles.len() {
                    if ot.tiles[i].overlaps(&ot.tiles[j]) {
                        return Err(format!("op {}: tiles {i},{j} overlap", op.name));
                    }
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_dependencies_preserved_through_pipeline() {
    // every overlapping producer/consumer tile pair found by a fresh
    // dependency analysis must be ordered in the final tGraph: the
    // producer's position in the linear order precedes the consumer's,
    // and there is an event path enforcing it (checked transitively via
    // a reachability walk over the final events).
    forall("dependency preservation", 0xFEED, 25, compile_random, |c| {
        let tg = &c.tgraph;
        // recompute raw pairs.
        let raw = mpk::tgraph::analyze_deps(&c.graph, &c.decomposition);
        // reachability: task -> tasks unlocked after it (BFS via events).
        // check a sample of pairs to bound cost.
        let mut rng = XorShift64::new(7);
        let pairs: Vec<(usize, usize)> = raw
            .events
            .iter()
            .map(|e| (e.in_tasks[0], e.out_tasks[0]))
            .collect();
        let sample: Vec<(usize, usize)> = (0..pairs.len().min(50))
            .map(|_| pairs[rng.below(pairs.len())])
            .collect();
        for (p, q) in sample {
            if !reaches(tg, p, q) {
                return Err(format!("dependency {p} -> {q} lost"));
            }
        }
        Ok(())
    });
}

fn reaches(tg: &mpk::tgraph::TGraph, from: usize, to: usize) -> bool {
    let mut seen = vec![false; tg.tasks.len()];
    let mut stack = vec![from];
    while let Some(t) = stack.pop() {
        if t == to {
            return true;
        }
        if seen[t] {
            continue;
        }
        seen[t] = true;
        for &e in &tg.tasks[t].trigger_events {
            for &succ in &tg.events[e].out_tasks {
                stack.push(succ);
            }
        }
    }
    false
}

#[test]
fn prop_verifier_clean_and_mutations_caught() {
    // Two-sided soundness check for the static verifier over random
    // graphs × all CompileOptions combinations: (a) every unmutated
    // compile must verify clean under compile_verified (all four
    // analyses), and (b) a seeded single-edge mutation sweep must be
    // caught by the race or liveness analysis. Random graphs can
    // contain the occasional semantically-equivalent mutant (a dropped
    // edge whose orderings all survive via alternate paths), so one
    // survivor per sweep is tolerated here; the built-in decode graphs
    // are held to the ≥95% acceptance bar in tests/verify_mutation.rs.
    forall(
        "verifier soundness",
        0xFACADE,
        12,
        |rng| {
            let (cfg, opt) = random_config(rng);
            let g = build_decode_graph(&cfg, &opt);
            let copt = CompileOptions {
                decompose: DecomposeConfig { target_tasks: rng.range(2, 48), min_tile_cols: 8 },
                granularity: match rng.below(3) {
                    0 => DepGranularity::CoarseAll,
                    1 => DepGranularity::CoarseCollectives,
                    _ => DepGranularity::Fine,
                },
                fuse: rng.below(2) == 0,
                merge_forks: rng.below(2) == 0,
                verify: true,
            };
            let (c, report) = compile_verified(&g, &copt);
            let sweep_seed = rng.below(1 << 30) as u64;
            (c, report, sweep_seed)
        },
        |(c, report, sweep_seed)| {
            if !report.is_clean() {
                return Err(format!("verifier flagged a clean compile:\n{}", report.render(8)));
            }
            let sweep = mutation_sweep(c, 8, *sweep_seed);
            if sweep.total == 0 {
                return Err("mutation harness produced no mutants".into());
            }
            if sweep.caught + 1 < sweep.total {
                return Err(format!(
                    "mutation sweep: only {}/{} caught; survivors: {:?}",
                    sweep.caught, sweep.total, sweep.survivors
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_stats_are_sane() {
    forall("stats sanity", 0xACE, 40, compile_random, |c| {
        let s = c.stats();
        if s.tasks == 0 || s.events == 0 {
            return Err("empty tGraph".into());
        }
        if s.fusion_reduction < 1.0 - 1e-9 {
            return Err(format!("fusion made things worse: {}", s.fusion_reduction));
        }
        // range encoding costs 8 B/event vs 4 B/successor-entry: with
        // fusion disabled (1:1 events) the worst case is exactly 2x.
        if s.lin_bytes > s.lin_naive_bytes * 2 + 16 {
            return Err("linearization footprint above worst case".into());
        }
        Ok(())
    });
}
