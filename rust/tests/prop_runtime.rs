//! Property suite over the threaded in-kernel runtime: for random
//! compiled graphs and random worker/scheduler splits, every run must
//! execute each task exactly once, respect the dependency order, and
//! terminate — for both the scoped (spawn-per-run) and persistent
//! (spawn-once, re-armed-per-epoch) kernels. The persistent kernel is
//! additionally stress-tested for thread stability across ≥100
//! consecutive epochs.

use mpk::megakernel::{MegaConfig, MegaKernel, PersistentMegaKernel};
use mpk::models::{build_decode_graph, GraphOptions, ModelConfig};
use mpk::proputil::forall;
use mpk::tgraph::{compile, CompileOptions, CompiledGraph, DecomposeConfig, TaskDesc};
use mpk::util::XorShift64;
use std::sync::{Arc, Mutex};

struct Case {
    compiled: Arc<CompiledGraph>,
    workers: usize,
    schedulers: usize,
}

fn random_case(rng: &mut XorShift64) -> Case {
    let cfg = ModelConfig {
        name: "rand-rt",
        layers: rng.range(1, 3),
        d_model: [128, 256][rng.below(2)],
        heads: 4,
        kv_heads: 2,
        head_dim: 32,
        ffn: [128, 256][rng.below(2)],
        vocab: 256,
        moe: None,
    };
    let opt = GraphOptions {
        batch: rng.range(1, 6),
        kv_len: rng.range(4, 32),
        unfused_qkv: rng.below(2) == 0,
        fused_kv_append: rng.below(2) == 0,
        ..Default::default()
    };
    let g = build_decode_graph(&cfg, &opt);
    let compiled = compile(
        &g,
        &CompileOptions {
            decompose: DecomposeConfig { target_tasks: rng.range(2, 16), min_tile_cols: 8 },
            merge_forks: rng.below(2) == 0,
            ..Default::default()
        },
    );
    Case { compiled: Arc::new(compiled), workers: rng.range(1, 6), schedulers: rng.range(1, 3) }
}

/// Exactly-once over non-dummy tasks.
fn check_exactly_once(c: &CompiledGraph, order: &[usize]) -> Result<(), String> {
    let mut seen = vec![0u32; c.tgraph.tasks.len()];
    for &t in order {
        seen[t] += 1;
    }
    for (tid, &n) in seen.iter().enumerate() {
        let dummy = c.tgraph.tasks[tid].kind.is_dummy();
        let want = if dummy { 0 } else { 1 };
        if n != want {
            return Err(format!("task {tid} ran {n} times (dummy={dummy})"));
        }
    }
    Ok(())
}

/// Completion order must respect event dependencies.
fn check_topological(c: &CompiledGraph, order: &[usize]) -> Result<(), String> {
    let mut pos = vec![usize::MAX; c.tgraph.tasks.len()];
    for (i, &t) in order.iter().enumerate() {
        pos[t] = i;
    }
    let tg = &c.tgraph;
    for t in &tg.tasks {
        if t.kind.is_dummy() {
            continue;
        }
        for &e in &t.dependent_events {
            for &p in &tg.events[e].in_tasks {
                if tg.tasks[p].kind.is_dummy() {
                    continue; // dummies not recorded by the executor
                }
                if pos[p] == usize::MAX || pos[p] > pos[t.id] {
                    return Err(format!("task {} ran before producer {p}", t.id));
                }
            }
        }
    }
    Ok(())
}

/// Count live OS threads of this process whose name starts with
/// `prefix` (Linux /proc; `None` when unavailable). Persistent-kernel
/// threads are named `<prefix>-worker-N` / `<prefix>-sched-N`, so this
/// counts exactly one kernel's residents even while other tests spawn
/// threads concurrently.
fn named_thread_count(prefix: &str) -> Option<usize> {
    let dir = std::fs::read_dir("/proc/self/task").ok()?;
    let mut n = 0;
    for entry in dir.flatten() {
        let comm = std::fs::read_to_string(entry.path().join("comm")).unwrap_or_default();
        if comm.trim_end().starts_with(prefix) {
            n += 1;
        }
    }
    Some(n)
}

#[test]
fn prop_every_task_runs_exactly_once() {
    forall("exactly-once execution", 0x51DE, 12, random_case, |case| {
        let mk = MegaKernel::new(
            &case.compiled,
            MegaConfig { workers: case.workers, schedulers: case.schedulers, ..Default::default() },
        );
        let seen = Mutex::new(Vec::new());
        let report = mk
            .run(&|t: &TaskDesc| {
                seen.lock().unwrap().push(t.id);
            })
            .map_err(|e| e.to_string())?;
        check_exactly_once(&case.compiled, &seen.lock().unwrap())?;
        if report.metrics.tasks_executed as usize != case.compiled.tgraph.tasks.len() {
            return Err("runtime lost tasks".into());
        }
        Ok(())
    });
}

#[test]
fn prop_execution_respects_dependencies() {
    forall("dependency order", 0xAB1E, 10, random_case, |case| {
        let mk = MegaKernel::new(
            &case.compiled,
            MegaConfig { workers: case.workers, schedulers: case.schedulers, ..Default::default() },
        );
        let order = Mutex::new(Vec::new());
        mk.run(&|t: &TaskDesc| order.lock().unwrap().push(t.id)).map_err(|e| e.to_string())?;
        check_topological(&case.compiled, &order.lock().unwrap())
    });
}

#[test]
fn prop_repeat_runs_are_stable() {
    forall("re-run stability", 0xD0, 6, random_case, |case| {
        let mk = MegaKernel::new(
            &case.compiled,
            MegaConfig { workers: case.workers, schedulers: case.schedulers, ..Default::default() },
        );
        for _ in 0..3 {
            let r = mk.run(&|_: &TaskDesc| {}).map_err(|e| e.to_string())?;
            if r.metrics.tasks_executed as usize != case.compiled.tgraph.tasks.len() {
                return Err("re-run dropped tasks".into());
            }
        }
        Ok(())
    });
}

#[test]
fn prop_persistent_epochs_match_scoped_semantics() {
    // the persistent kernel must give the same exactly-once +
    // topological-order guarantees on every re-armed epoch.
    forall("persistent epochs", 0x9E125, 8, random_case, |case| {
        let mut mk = PersistentMegaKernel::new(
            case.compiled.clone(),
            MegaConfig { workers: case.workers, schedulers: case.schedulers, ..Default::default() },
        );
        for epoch in 1..=3u64 {
            let order = Mutex::new(Vec::new());
            let r = mk
                .run(&|t: &TaskDesc| order.lock().unwrap().push(t.id))
                .map_err(|e| e.to_string())?;
            if r.epoch != epoch {
                return Err(format!("epoch counter {} != {epoch}", r.epoch));
            }
            if r.metrics.tasks_executed as usize != case.compiled.tgraph.tasks.len() {
                return Err(format!("epoch {epoch} lost tasks"));
            }
            let order = order.lock().unwrap();
            check_exactly_once(&case.compiled, &order)
                .map_err(|e| format!("epoch {epoch}: {e}"))?;
            check_topological(&case.compiled, &order)
                .map_err(|e| format!("epoch {epoch}: {e}"))?;
        }
        Ok(())
    });
}

#[test]
fn persistent_stress_100_epochs_no_thread_leak() {
    let cfg = ModelConfig::tiny();
    let g = build_decode_graph(&cfg, &GraphOptions { batch: 4, kv_len: 16, ..Default::default() });
    let compiled = Arc::new(compile(
        &g,
        &CompileOptions {
            decompose: DecomposeConfig { target_tasks: 12, min_tile_cols: 8 },
            ..Default::default()
        },
    ));
    let mut mk = PersistentMegaKernel::new(
        compiled.clone(),
        MegaConfig { workers: 4, schedulers: 2, ..Default::default() },
    );
    let complement = mk.thread_count();
    assert_eq!(complement, 6, "4 workers + 2 schedulers");
    // "mpkN-" — the trailing dash keeps mpk1 from matching mpk12.
    let prefix = format!("{}-", mk.thread_name_prefix());
    // first epoch brings every resident thread fully up.
    mk.run(&|_: &TaskDesc| {}).unwrap();
    let threads_before = named_thread_count(&prefix);
    assert!(
        threads_before.is_none() || threads_before == Some(complement),
        "expected {complement} resident threads, found {threads_before:?}"
    );
    let expected_tasks = compiled.tgraph.tasks.len();
    for epoch in 2..=101u64 {
        let order = Mutex::new(Vec::new());
        let r = mk.run(&|t: &TaskDesc| order.lock().unwrap().push(t.id)).unwrap();
        assert_eq!(r.epoch, epoch);
        assert_eq!(
            r.metrics.tasks_executed as usize, expected_tasks,
            "epoch {epoch}: task count drifted"
        );
        let order = order.lock().unwrap();
        check_exactly_once(&compiled, &order).unwrap_or_else(|e| panic!("epoch {epoch}: {e}"));
        check_topological(&compiled, &order).unwrap_or_else(|e| panic!("epoch {epoch}: {e}"));
    }
    assert_eq!(mk.epochs(), 101);
    // 100 more epochs must not have spawned or leaked a single thread.
    assert_eq!(
        named_thread_count(&prefix),
        threads_before,
        "persistent kernel leaked threads across 100 epochs"
    );
    // teardown joins the full complement.
    drop(mk);
    if threads_before.is_some() {
        assert_eq!(
            named_thread_count(&prefix),
            Some(0),
            "drop did not join all resident threads"
        );
    }
}
