//! Property suite over the threaded in-kernel runtime: for random
//! compiled graphs and random worker/scheduler splits, every run must
//! execute each task exactly once, respect the dependency order, and
//! terminate.

use mpk::megakernel::{MegaConfig, MegaKernel};
use mpk::models::{build_decode_graph, GraphOptions, ModelConfig};
use mpk::proputil::forall;
use mpk::tgraph::{compile, CompileOptions, CompiledGraph, DecomposeConfig, TaskDesc};
use mpk::util::XorShift64;
use std::sync::Mutex;

struct Case {
    compiled: CompiledGraph,
    workers: usize,
    schedulers: usize,
}

fn random_case(rng: &mut XorShift64) -> Case {
    let cfg = ModelConfig {
        name: "rand-rt",
        layers: rng.range(1, 3),
        d_model: [128, 256][rng.below(2)],
        heads: 4,
        kv_heads: 2,
        head_dim: 32,
        ffn: [128, 256][rng.below(2)],
        vocab: 256,
        moe: None,
    };
    let opt = GraphOptions {
        batch: rng.range(1, 6),
        kv_len: rng.range(4, 32),
        unfused_qkv: rng.below(2) == 0,
        fused_kv_append: rng.below(2) == 0,
        ..Default::default()
    };
    let g = build_decode_graph(&cfg, &opt);
    let compiled = compile(
        &g,
        &CompileOptions {
            decompose: DecomposeConfig { target_tasks: rng.range(2, 16), min_tile_cols: 8 },
            merge_forks: rng.below(2) == 0,
            ..Default::default()
        },
    );
    Case { compiled, workers: rng.range(1, 6), schedulers: rng.range(1, 3) }
}

#[test]
fn prop_every_task_runs_exactly_once() {
    forall("exactly-once execution", 0x51DE, 12, random_case, |case| {
        let mk = MegaKernel::new(
            &case.compiled,
            MegaConfig { workers: case.workers, schedulers: case.schedulers, ..Default::default() },
        );
        let seen = Mutex::new(vec![0u32; case.compiled.tgraph.tasks.len()]);
        let report = mk
            .run(&|t: &TaskDesc| {
                seen.lock().unwrap()[t.id] += 1;
            })
            .map_err(|e| e.to_string())?;
        let seen = seen.lock().unwrap();
        for (tid, &n) in seen.iter().enumerate() {
            let dummy = case.compiled.tgraph.tasks[tid].kind.is_dummy();
            let want = if dummy { 0 } else { 1 };
            if n != want {
                return Err(format!("task {tid} ran {n} times (dummy={dummy})"));
            }
        }
        if report.metrics.tasks_executed as usize != case.compiled.tgraph.tasks.len() {
            return Err("runtime lost tasks".into());
        }
        Ok(())
    });
}

#[test]
fn prop_execution_respects_dependencies() {
    forall("dependency order", 0xAB1E, 10, random_case, |case| {
        let mk = MegaKernel::new(
            &case.compiled,
            MegaConfig { workers: case.workers, schedulers: case.schedulers, ..Default::default() },
        );
        let order = Mutex::new(Vec::new());
        mk.run(&|t: &TaskDesc| order.lock().unwrap().push(t.id)).map_err(|e| e.to_string())?;
        let order = order.lock().unwrap();
        let mut pos = vec![usize::MAX; case.compiled.tgraph.tasks.len()];
        for (i, &t) in order.iter().enumerate() {
            pos[t] = i;
        }
        let tg = &case.compiled.tgraph;
        for t in &tg.tasks {
            if t.kind.is_dummy() {
                continue;
            }
            for &e in &t.dependent_events {
                for &p in &tg.events[e].in_tasks {
                    if tg.tasks[p].kind.is_dummy() {
                        continue; // dummies not recorded by the executor
                    }
                    if pos[p] == usize::MAX || pos[p] > pos[t.id] {
                        return Err(format!("task {} ran before producer {p}", t.id));
                    }
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_repeat_runs_are_stable() {
    forall("re-run stability", 0xD0, 6, random_case, |case| {
        let mk = MegaKernel::new(
            &case.compiled,
            MegaConfig { workers: case.workers, schedulers: case.schedulers, ..Default::default() },
        );
        for _ in 0..3 {
            let r = mk.run(&|_: &TaskDesc| {}).map_err(|e| e.to_string())?;
            if r.metrics.tasks_executed as usize != case.compiled.tgraph.tasks.len() {
                return Err("re-run dropped tasks".into());
            }
        }
        Ok(())
    });
}
