//! Overload and fault suite for the serving front-end
//! ([`mpk::serving::ServeServer`]), run entirely against the
//! backend-free `MockEngine` — the same batcher, slot, KV, and
//! fault-recovery machinery as the real engine, minus the kernel.
//!
//! Three layers:
//!
//! 1. Deterministic unit tests of each overload policy in isolation:
//!    streaming + id reuse, queued and admitted deadline expiry,
//!    priority-ordered admission, displacement shedding vs typed
//!    `Overloaded` refusal, poison quarantine, and the
//!    fatal-unattributable-failure path.
//! 2. Seeded property tests (`mpk::proputil::forall`) over random
//!    interleavings of submit / cancel / deadline-style termination /
//!    faulted steps, asserting exactly-one-terminal per accepted
//!    request, unique **stable** slots, and KV block conservation; plus
//!    a server-level variant where shedding and real deadlines join the
//!    mix and the report counters must reconcile exactly.
//! 3. A threaded saturation stress: 1024 concurrent clients (32 threads
//!    × 32 requests, jittered arrivals, mixed priorities and deadlines)
//!    against a slow, fault-injected engine at several times slot
//!    capacity. Every submission must resolve — a terminal event or a
//!    typed rejection — with no lost or duplicated terminals and no
//!    engine rebuild (`ServerReport::fatal` stays `None`).

use mpk::proputil::forall;
use mpk::serving::mock::MockEngine;
use mpk::serving::{
    EngineError, FaultPlan, FinishReason, Priority, Request, ServeServer, ServeStats,
    ServerConfig, StepEngine, StepOutcome, SubmitOptions,
};
use mpk::util::XorShift64;
use std::collections::HashMap;
use std::time::Duration;

/// A [`MockEngine`] whose steps take wall-clock time, so tests can hold
/// requests in flight long enough to exercise queue backpressure,
/// displacement shedding, and admitted-request deadline expiry — the
/// mock alone decodes too fast for any of those windows to open.
struct SlowEngine {
    inner: MockEngine,
    delay: Duration,
}

impl SlowEngine {
    fn new(inner: MockEngine, delay: Duration) -> SlowEngine {
        SlowEngine { inner, delay }
    }
}

impl StepEngine for SlowEngine {
    fn submit(&mut self, r: Request) -> Result<(), EngineError> {
        self.inner.submit(r)
    }
    fn validate(&self, r: &Request) -> Result<(), EngineError> {
        self.inner.validate(r)
    }
    fn terminate(&mut self, id: u64, reason: FinishReason) -> Result<(), EngineError> {
        self.inner.terminate(id, reason)
    }
    fn step(&mut self) -> Result<StepOutcome, EngineError> {
        std::thread::sleep(self.delay);
        self.inner.step()
    }
    fn has_work(&self) -> bool {
        self.inner.has_work()
    }
    fn capacity(&self) -> usize {
        self.inner.capacity()
    }
    fn in_flight(&self) -> usize {
        self.inner.in_flight()
    }
    fn take_finished(&mut self) -> Vec<Request> {
        self.inner.take_finished()
    }
    fn take_stats(&mut self) -> ServeStats {
        self.inner.take_stats()
    }
}

// ---------------------------------------------------------------------
// deterministic unit tests
// ---------------------------------------------------------------------

#[test]
fn server_streams_and_releases_ids_for_reuse() {
    let server = ServeServer::spawn_with(MockEngine::new(2), ServerConfig::default());
    let client = server.client();
    let (tokens, finish) = client.submit(Request::new(7, vec![1], 3)).unwrap().collect_output();
    assert_eq!(tokens.len(), 3);
    assert_eq!(finish, Some(FinishReason::MaxTokens));
    // the terminal event released the id: a fresh request may reuse it.
    let (tokens, finish) = client.submit(Request::new(7, vec![1, 2], 2)).unwrap().collect_output();
    assert_eq!(tokens.len(), 2);
    assert_eq!(finish, Some(FinishReason::MaxTokens));
    let report = server.shutdown();
    assert_eq!(report.finished, 2);
    assert!(report.fatal.is_none());
    assert_eq!(report.stats.tokens_generated, 5);
}

#[test]
fn token_stream_is_fused_after_its_terminal_event() {
    // Regression: `TokenStream::recv` used to block on the channel even
    // after the terminal event, so polling a finished stream after
    // `shutdown()` raced the serving thread's sender drop — sometimes
    // a quick disconnect, sometimes a hang until the thread exited.
    // The stream now fuses on its own state: post-terminal reads are
    // deterministic `ServerClosed`, and iteration yields `None`.
    let server = ServeServer::spawn_with(MockEngine::new(1), ServerConfig::default());
    let client = server.client();
    let mut stream = client.submit(Request::new(1, vec![2], 2)).unwrap();
    let mut terminals = 0;
    while let Ok(ev) = stream.recv() {
        if ev.finish.is_some() {
            terminals += 1;
        }
    }
    assert_eq!(terminals, 1);
    let report = server.shutdown();
    assert_eq!(report.finished, 1);
    // the server is gone and the terminal event was consumed: every
    // further read must fail the same way, immediately.
    for _ in 0..3 {
        assert!(matches!(stream.recv(), Err(EngineError::ServerClosed)));
    }
    assert!(stream.next().is_none(), "fused iteration after the terminal event");
}

#[test]
fn zero_deadline_expires_in_the_queue_before_admission() {
    let server = ServeServer::spawn_with(MockEngine::new(1), ServerConfig::default());
    let client = server.client();
    let stream = client
        .submit_with(
            Request::new(1, vec![4], 8),
            SubmitOptions { deadline: Some(Duration::ZERO), ..Default::default() },
        )
        .unwrap();
    // deadline checks run before admission each tick, so an
    // already-expired deadline deterministically beats the engine.
    let (tokens, finish) = stream.collect_output();
    assert!(tokens.is_empty(), "expired before admission, yet decoded {tokens:?}");
    assert_eq!(finish, Some(FinishReason::DeadlineExceeded));
    let report = server.shutdown();
    assert_eq!(report.deadline_expired, 1);
    assert_eq!(report.finished, 1);
    assert!(report.fatal.is_none());
}

#[test]
fn admitted_request_is_terminated_at_its_deadline() {
    // 5ms steps x 400-token budget = ~2s without a deadline; the 40ms
    // deadline must cut in long before, keeping partial output.
    let server = ServeServer::spawn_with(
        SlowEngine::new(MockEngine::new(1), Duration::from_millis(5)),
        ServerConfig::default(),
    );
    let client = server.client();
    let stream = client
        .submit_with(
            Request::new(1, vec![2], 400),
            SubmitOptions { deadline: Some(Duration::from_millis(40)), ..Default::default() },
        )
        .unwrap();
    let (tokens, finish) = stream.collect_output();
    assert_eq!(finish, Some(FinishReason::DeadlineExceeded));
    assert!(tokens.len() < 400, "deadline did not cut the budget short");
    let report = server.shutdown();
    assert_eq!(report.deadline_expired, 1);
    assert!(report.fatal.is_none());
}

#[test]
fn full_queue_sheds_lower_priority_or_refuses_typed() {
    let server = ServeServer::spawn_with(
        SlowEngine::new(MockEngine::new(1), Duration::from_millis(10)),
        ServerConfig { queue_depth: 1, idle_poll: Duration::from_millis(1) },
    );
    let client = server.client();
    // A occupies the only slot for ~2s of steps (cancelled below).
    let mut a = client.submit(Request::new(1, vec![3], 200)).unwrap();
    assert!(a.recv().expect("first token").token.is_some());
    // B fills the depth-1 wait queue.
    let b = client
        .submit_with(
            Request::new(2, vec![3], 2),
            SubmitOptions { priority: Priority::Batch, ..Default::default() },
        )
        .unwrap();
    // C finds the queue full with nothing strictly below Batch to
    // displace: a typed, synchronous refusal — not an engine error.
    let err = client
        .submit_with(
            Request::new(3, vec![3], 2),
            SubmitOptions { priority: Priority::Batch, ..Default::default() },
        )
        .unwrap_err();
    assert!(matches!(err, EngineError::Overloaded { id: 3, queue_depth: 1 }), "got: {err}");
    // D outranks the queued Batch request and displaces it.
    let d = client.submit_with(Request::new(4, vec![3], 2), SubmitOptions::default()).unwrap();
    let (b_tokens, b_finish) = b.collect_output();
    assert!(b_tokens.is_empty());
    assert_eq!(b_finish, Some(FinishReason::Shed));
    let status = client.status().unwrap();
    assert_eq!(status.capacity, 1);
    assert_eq!(status.in_flight, 1, "A still holds the slot");
    assert_eq!(status.queued, 1, "D waits behind A");
    assert_eq!(status.shed, 1);
    assert_eq!(status.rejected, 1);
    // free the slot; D runs to completion.
    client.cancel(1).unwrap();
    let (_, a_finish) = a.collect_output();
    assert_eq!(a_finish, Some(FinishReason::Cancelled));
    let (d_tokens, d_finish) = d.collect_output();
    assert_eq!(d_tokens.len(), 2);
    assert_eq!(d_finish, Some(FinishReason::MaxTokens));
    let report = server.shutdown();
    assert_eq!(report.shed, 1);
    assert_eq!(report.rejected, 1);
    assert_eq!(report.finished, 3, "A cancelled + B shed + D completed");
    assert!(report.fatal.is_none());
}

#[test]
fn interactive_is_admitted_before_earlier_batch_submissions() {
    let server = ServeServer::spawn_with(
        SlowEngine::new(MockEngine::new(1), Duration::from_millis(3)),
        ServerConfig::default(),
    );
    let client = server.client();
    // blocker holds the single slot while B and C queue up.
    let mut a = client.submit(Request::new(1, vec![5], 60)).unwrap();
    assert!(a.recv().expect("first token").token.is_some());
    let b = client
        .submit_with(
            Request::new(2, vec![5], 2),
            SubmitOptions { priority: Priority::Batch, ..Default::default() },
        )
        .unwrap();
    let c = client.submit_with(Request::new(3, vec![5], 2), SubmitOptions::default()).unwrap();
    client.cancel(1).unwrap();
    let (c_tokens, c_finish) = c.collect_output();
    let (b_tokens, b_finish) = b.collect_output();
    assert_eq!(c_finish, Some(FinishReason::MaxTokens));
    assert_eq!(b_finish, Some(FinishReason::MaxTokens));
    // mock token values are global step numbers: with one slot, the
    // later-submitted Interactive request decoding strictly first means
    // all its tokens numerically precede the Batch request's.
    assert!(
        c_tokens.iter().max() < b_tokens.iter().min(),
        "interactive {c_tokens:?} must fully precede batch {b_tokens:?}"
    );
    let report = server.shutdown();
    assert_eq!(report.finished, 3);
    assert!(report.fatal.is_none());
}

#[test]
fn poisoned_request_is_quarantined_while_survivors_complete() {
    let engine =
        MockEngine::new(2).with_faults(FaultPlan { poison: Some(1), ..Default::default() }, 1);
    let server = ServeServer::spawn_with(engine, ServerConfig::default());
    let client = server.client();
    let poisoned = client.submit(Request::new(1, vec![3, 4], 4)).unwrap();
    let survivor = client.submit(Request::new(2, vec![5], 2)).unwrap();
    let (p_tokens, p_finish) = poisoned.collect_output();
    assert!(p_tokens.is_empty(), "poison fires before any decode: {p_tokens:?}");
    assert_eq!(p_finish, Some(FinishReason::Failed));
    let (s_tokens, s_finish) = survivor.collect_output();
    assert_eq!(s_tokens.len(), 2, "the survivor must decode its full budget");
    assert_eq!(s_finish, Some(FinishReason::MaxTokens));
    let report = server.shutdown();
    assert_eq!(report.quarantined, 1);
    assert_eq!(report.stats.requests_quarantined, 1);
    assert!(report.stats.faulted_epochs >= 2, "retry budget 1 needs two failures to quarantine");
    assert!(report.fatal.is_none());
}

#[test]
fn unattributable_persistent_failure_fails_streams_and_reports_fatal() {
    // every epoch fails with no per-request attribution: retries exhaust
    // and the serving thread dies loudly — streams get a terminal
    // `Failed`, clients get `ServerClosed`, the report carries the error.
    let engine =
        MockEngine::new(2).with_faults(FaultPlan { kernel_rate: 1.0, ..Default::default() }, 2);
    let server = ServeServer::spawn_with(engine, ServerConfig::default());
    let client = server.client();
    let stream = client.submit(Request::new(1, vec![2], 4)).unwrap();
    let (tokens, finish) = stream.collect_output();
    assert!(tokens.is_empty());
    assert_eq!(finish, Some(FinishReason::Failed), "no client may hang on a dead server");
    let report = server.shutdown();
    assert!(matches!(report.fatal, Some(EngineError::Kernel(_))), "got: {:?}", report.fatal);
    assert_eq!(report.quarantined, 1, "the fatal broadcast fails the live stream");
    assert!(matches!(
        client.submit(Request::new(9, vec![1], 1)),
        Err(EngineError::ServerClosed)
    ));
}

// ---------------------------------------------------------------------
// property tests: random interleavings
// ---------------------------------------------------------------------

#[derive(Debug, Clone, Copy)]
enum Op {
    Submit { prompt: usize, gen: usize },
    /// Models the server's scheduled terminations (cancel / deadline)
    /// landing between steps.
    Terminate { victim: usize, deadline: bool },
    Step,
}

#[derive(Debug)]
struct FaultedScript {
    capacity: usize,
    plan: FaultPlan,
    ops: Vec<Op>,
}

fn random_script(rng: &mut XorShift64) -> FaultedScript {
    let plan = FaultPlan {
        seed: rng.next_u64(),
        // modest rates + a 12-retry budget keep an unattributable
        // failure streak (which would legitimately error the step)
        // astronomically unlikely, so the property can demand Ok.
        kernel_rate: rng.f64() * 0.1,
        task_rate: rng.f64() * 0.1,
        poison: (rng.below(4) == 0).then(|| rng.below(8) as u64),
    };
    FaultedScript {
        capacity: rng.range(1, 4),
        plan,
        ops: (0..rng.range(8, 48))
            .map(|_| match rng.below(10) {
                0..=4 => Op::Submit { prompt: rng.range(1, 5), gen: rng.range(1, 5) },
                5 | 6 => Op::Terminate { victim: rng.below(64), deadline: rng.below(2) == 0 },
                _ => Op::Step,
            })
            .collect(),
    }
}

/// Slots must be unique, in bounds, and — per request — unchanged from
/// admission to retirement (ids are never reused within a case).
fn check_slots(e: &MockEngine, ledger: &mut HashMap<u64, usize>) -> Result<(), String> {
    let mut seen = vec![false; e.capacity()];
    for (id, slot) in e.active_slots() {
        if slot >= e.capacity() {
            return Err(format!("req {id} slot {slot} out of bounds"));
        }
        if seen[slot] {
            return Err(format!("slot {slot} occupied twice"));
        }
        seen[slot] = true;
        match ledger.get(&id) {
            None => {
                ledger.insert(id, slot);
            }
            Some(&home) if home == slot => {}
            Some(&home) => return Err(format!("req {id} moved slot {home} -> {slot}")),
        }
    }
    Ok(())
}

fn drive_faulted(s: &FaultedScript) -> Result<(), String> {
    let mut e = MockEngine::new(s.capacity).with_faults(s.plan, 12);
    let total = e.kv_total_blocks();
    let mut events = Vec::new();
    let mut accepted: Vec<u64> = Vec::new();
    let mut next_id = 0u64;
    let mut ledger = HashMap::new();
    for op in &s.ops {
        match *op {
            Op::Submit { prompt, gen } => {
                let id = next_id;
                next_id += 1;
                if e.submit(Request::new(id, vec![1; prompt], gen)).is_ok() {
                    accepted.push(id);
                }
            }
            Op::Terminate { victim, deadline } => {
                if next_id > 0 {
                    let id = victim as u64 % next_id;
                    let reason = if deadline {
                        FinishReason::DeadlineExceeded
                    } else {
                        FinishReason::Cancelled
                    };
                    // unknown / already-finished targets are fine — the
                    // server ignores those races the same way.
                    let _ = e.terminate(id, reason);
                }
            }
            Op::Step => {
                let out = e.step().map_err(|err| format!("step gave up: {err}"))?;
                events.extend(out.events);
                check_slots(&e, &mut ledger)?;
            }
        }
    }
    let mut guard = 0;
    while e.has_work() {
        guard += 1;
        if guard > 10_000 {
            return Err("drain livelock".into());
        }
        let out = e.step().map_err(|err| format!("step gave up: {err}"))?;
        events.extend(out.events);
        check_slots(&e, &mut ledger)?;
        e.take_finished();
    }
    if e.kv_free_blocks() != total {
        return Err(format!("KV leak: {} of {} blocks free after drain", e.kv_free_blocks(), total));
    }
    for &id in &accepted {
        let terminals = events.iter().filter(|ev| ev.request == id && ev.finish.is_some()).count();
        if terminals != 1 {
            return Err(format!("req {id} got {terminals} terminal events, want exactly 1"));
        }
    }
    for ev in &events {
        if !accepted.contains(&ev.request) {
            return Err(format!("event for never-accepted req {}", ev.request));
        }
    }
    Ok(())
}

#[test]
fn prop_faulted_interleavings_conserve_slots_kv_and_terminals() {
    forall("faulted-interleavings", 0xfa57, 64, random_script, drive_faulted);
}

#[derive(Debug)]
struct ClientPlan {
    batch: bool,
    deadline_ms: Option<usize>,
    prompt: usize,
    gen: usize,
    cancel: bool,
}

#[derive(Debug)]
struct ServerScript {
    capacity: usize,
    queue_depth: usize,
    delay_us: usize,
    plan: FaultPlan,
    clients: Vec<ClientPlan>,
}

fn random_server_script(rng: &mut XorShift64) -> ServerScript {
    ServerScript {
        capacity: rng.range(1, 3),
        queue_depth: rng.range(1, 4),
        delay_us: rng.range(100, 1200),
        plan: FaultPlan {
            seed: rng.next_u64(),
            kernel_rate: rng.f64() * 0.05,
            task_rate: rng.f64() * 0.05,
            poison: None,
        },
        clients: (0..rng.range(4, 20))
            .map(|_| ClientPlan {
                batch: rng.below(2) == 0,
                deadline_ms: (rng.below(4) == 0).then(|| rng.below(4)),
                prompt: rng.range(1, 3),
                gen: rng.range(1, 5),
                cancel: rng.below(8) == 0,
            })
            .collect(),
    }
}

/// Whatever mix of completion, cancellation, deadline expiry, shedding,
/// and fault quarantine each request hits, the books must balance:
/// every submission resolves into exactly one terminal event or one
/// typed rejection, and the server's counters agree with the client's.
fn drive_server(s: &ServerScript) -> Result<(), String> {
    let engine = SlowEngine::new(
        MockEngine::new(s.capacity).with_faults(s.plan, 16),
        Duration::from_micros(s.delay_us as u64),
    );
    let server = ServeServer::spawn_with(
        engine,
        ServerConfig { queue_depth: s.queue_depth, idle_poll: Duration::from_micros(200) },
    );
    let client = server.client();
    let mut streams = Vec::new();
    let mut rejected = 0usize;
    for (i, c) in s.clients.iter().enumerate() {
        let opts = SubmitOptions {
            priority: if c.batch { Priority::Batch } else { Priority::Interactive },
            deadline: c.deadline_ms.map(|ms| Duration::from_millis(ms as u64)),
        };
        match client.submit_with(Request::new(i as u64, vec![1; c.prompt], c.gen), opts) {
            Ok(stream) => streams.push(stream),
            Err(EngineError::Overloaded { .. }) => rejected += 1,
            Err(err) => return Err(format!("unexpected refusal: {err}")),
        }
        if c.cancel {
            // may target a queued, active, finished, shed, or rejected
            // request depending on timing; all must be handled.
            let _ = client.cancel(i as u64);
        }
    }
    let accepted = streams.len();
    for stream in streams {
        let terminals = stream.filter(|ev| ev.finish.is_some()).count();
        if terminals != 1 {
            return Err(format!("a stream saw {terminals} terminal events, want exactly 1"));
        }
    }
    let report = server.shutdown();
    if let Some(err) = report.fatal {
        return Err(format!("serving thread died: {err}"));
    }
    if accepted + rejected != s.clients.len() {
        return Err(format!(
            "{} accepted + {rejected} rejected != {} submissions",
            accepted,
            s.clients.len()
        ));
    }
    // `finished` counts every terminal delivery, streamed or not — a
    // duplicate terminal would inflate it past the accepted count.
    if report.finished != accepted {
        return Err(format!("{} terminals delivered for {accepted} accepted", report.finished));
    }
    if report.rejected != rejected {
        return Err(format!("server counted {} rejections, client saw {rejected}", report.rejected));
    }
    Ok(())
}

#[test]
fn prop_server_interleavings_reconcile_every_submission() {
    forall("server-interleavings", 0x5e4e, 10, random_server_script, drive_server);
}

// ---------------------------------------------------------------------
// saturation stress: 1024 concurrent clients
// ---------------------------------------------------------------------

#[test]
fn saturation_1024_clients_with_faults_loses_nothing() {
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;

    const THREADS: usize = 32;
    const PER_THREAD: usize = 32;

    // 8 slots + a 16-deep queue against 32 concurrent submitters keeps
    // the system several times oversubscribed, so shedding, priority
    // displacement, and deadline expiry all fire; kernel faults are
    // armed with a retry budget deep enough (16) that an unattributable
    // give-up streak is out of reach (0.05^17).
    let engine = SlowEngine::new(
        MockEngine::new(8).with_faults(
            FaultPlan { seed: 0xbeef, kernel_rate: 0.05, ..Default::default() },
            16,
        ),
        Duration::from_micros(200),
    );
    let server = ServeServer::spawn_with(
        engine,
        ServerConfig { queue_depth: 16, idle_poll: Duration::from_micros(200) },
    );
    let terminals = Arc::new(AtomicUsize::new(0));
    let rejected = Arc::new(AtomicUsize::new(0));
    let handles: Vec<_> = (0..THREADS)
        .map(|t| {
            let client = server.client();
            let terminals = Arc::clone(&terminals);
            let rejected = Arc::clone(&rejected);
            std::thread::spawn(move || {
                let mut rng = XorShift64::new(0xc0ffee ^ t as u64);
                for i in 0..PER_THREAD {
                    // arrival jitter so submissions interleave rather
                    // than phase-lock behind the command channel.
                    std::thread::sleep(Duration::from_micros(rng.below(1500) as u64));
                    let id = (t * PER_THREAD + i) as u64;
                    let opts = SubmitOptions {
                        priority: if rng.below(2) == 0 {
                            Priority::Interactive
                        } else {
                            Priority::Batch
                        },
                        deadline: (rng.below(4) == 0)
                            .then(|| Duration::from_millis(rng.below(8) as u64)),
                    };
                    let prompt = rng.range(1, 3);
                    let gen = rng.range(1, 8);
                    match client.submit_with(Request::new(id, vec![1; prompt], gen), opts) {
                        Ok(stream) => {
                            let (_tokens, finish) = stream.collect_output();
                            assert!(finish.is_some(), "req {id} lost its terminal event");
                            terminals.fetch_add(1, Ordering::Relaxed);
                        }
                        Err(EngineError::Overloaded { .. }) => {
                            rejected.fetch_add(1, Ordering::Relaxed);
                        }
                        Err(err) => panic!("req {id}: unexpected refusal: {err}"),
                    }
                }
            })
        })
        .collect();
    for h in handles {
        h.join().expect("client thread panicked");
    }
    let terminals = terminals.load(Ordering::Relaxed);
    let rejected = rejected.load(Ordering::Relaxed);
    assert_eq!(terminals + rejected, THREADS * PER_THREAD, "every submission must resolve");
    let report = server.shutdown();
    assert!(report.fatal.is_none(), "engine was rebuilt / thread died: {:?}", report.fatal);
    // exactly one terminal delivery per accepted request: a lost one
    // would hang its client above, a duplicate would inflate `finished`.
    assert_eq!(report.finished, terminals);
    assert_eq!(report.rejected, rejected);
    assert!(
        report.shed + report.deadline_expired + report.quarantined <= report.finished,
        "terminal-reason counters must partition the terminals"
    );
    assert!(report.stats.faulted_epochs > 0, "faults were armed at 5% per epoch over 100s of epochs");
}
