//! Property suite over the tensor arena (`exec::store`): tile
//! writes/reads must agree with a plain reference model, borrowed views
//! must equal owned reads without touching the copy counters, the
//! aliasing contract must hold under concurrent disjoint readers +
//! writers, and shared-slab aliasing across stores must behave like the
//! serving engine's max-batch KV arena.

use mpk::exec::store::{SharedSlab, StoreCounters, TensorStore};
use mpk::ops::{CompGraph, DType, Region};
use mpk::proputil::forall;
use mpk::util::XorShift64;

/// A random tensor shape (rank 1..=3, small dims) plus a random
/// non-empty region inside it.
struct Case {
    shape: Vec<usize>,
    region: Region,
}

fn random_case(rng: &mut XorShift64) -> Case {
    let rank = rng.range(1, 3);
    let shape: Vec<usize> = (0..rank).map(|_| rng.range(1, 6)).collect();
    let region = Region::new(
        shape
            .iter()
            .map(|&d| {
                let s = rng.below(d);
                let e = rng.range(s + 1, d);
                (s, e)
            })
            .collect(),
    );
    Case { shape, region }
}

fn store_for(shape: &[usize]) -> (TensorStore, usize) {
    let mut g = CompGraph::new();
    let t = g.input("x", shape.to_vec(), DType::F32);
    (TensorStore::new(&g), t)
}

/// Reference model: plain row-major Vec with nested index arithmetic.
fn ref_write(buf: &mut [f32], shape: &[usize], r: &Region, data: &[f32]) {
    let mut di = 0;
    let mut idx: Vec<usize> = r.dims.iter().map(|&(s, _)| s).collect();
    loop {
        let mut off = 0;
        let mut stride = 1;
        for d in (0..shape.len()).rev() {
            off += idx[d] * stride;
            stride *= shape[d];
        }
        buf[off] = data[di];
        di += 1;
        // odometer over the region
        let mut d = shape.len();
        loop {
            if d == 0 {
                return;
            }
            d -= 1;
            idx[d] += 1;
            if idx[d] < r.dims[d].1 {
                break;
            }
            idx[d] = r.dims[d].0;
            if d == 0 {
                return;
            }
        }
    }
}

#[test]
fn prop_tile_roundtrip_matches_reference_model() {
    forall("tile write/read vs reference", 0x57031, 200, random_case, |c| {
        let (store, t) = store_for(&c.shape);
        let numel: usize = c.shape.iter().product();
        let base: Vec<f32> = (0..numel).map(|i| i as f32).collect();
        store.set(t, &base);
        let tile: Vec<f32> = (0..c.region.numel()).map(|i| 1000.0 + i as f32).collect();
        store.write_tile(t, &c.region, &tile);

        let mut want = base.clone();
        ref_write(&mut want, &c.shape, &c.region, &tile);
        if store.get(t) != want {
            return Err(format!("tile write mismatch for shape {:?} region {}", c.shape, c.region));
        }
        if store.read_tile(t, &c.region) != tile {
            return Err("readback of written tile differs".into());
        }
        Ok(())
    });
}

#[test]
fn prop_views_equal_owned_reads_and_count_nothing() {
    forall("views vs owned reads", 0xB0880, 200, random_case, |c| {
        let (store, t) = store_for(&c.shape);
        let numel: usize = c.shape.iter().product();
        let data: Vec<f32> = (0..numel).map(|i| (i * 3) as f32).collect();
        store.set(t, &data);
        store.reset_counters();

        // whole-tensor view == data, no counter movement.
        if store.view(t) != &data[..] {
            return Err("view != set data".into());
        }
        // borrowed tile gather == owned read_tile.
        let mut scratch = Vec::new();
        store.tile(t, &c.region).gather_into(&mut scratch);
        if store.counters() != StoreCounters::default() {
            return Err("borrowed path moved the counters".into());
        }
        let owned = store.read_tile(t, &c.region);
        if scratch != owned {
            return Err(format!("gather != read_tile for region {}", c.region));
        }
        // contiguous regions must also agree via as_slice.
        let tv = store.tile(t, &c.region);
        if let Some(s) = tv.as_slice() {
            if s != &owned[..] {
                return Err("as_slice != read_tile on contiguous region".into());
            }
        }
        drop(tv);
        let after = store.counters();
        if after.allocs != 1 || after.bytes_copied != (c.region.numel() * 4) as u64 {
            return Err(format!("owned read counted wrong: {after:?}"));
        }
        Ok(())
    });
}

#[test]
fn prop_mut_views_agree_with_write_tile() {
    forall("mutable views vs write_tile", 0xD00D5, 200, random_case, |c| {
        let (via_tile, t1) = store_for(&c.shape);
        let (via_view, t2) = store_for(&c.shape);
        let numel: usize = c.shape.iter().product();
        let base: Vec<f32> = (0..numel).map(|i| i as f32).collect();
        via_tile.set(t1, &base);
        via_view.set(t2, &base);
        let tile: Vec<f32> = (0..c.region.numel()).map(|i| 5000.0 + i as f32).collect();
        via_tile.write_tile(t1, &c.region, &tile);

        // write the same tile through the mutable-view surface: the
        // contiguous fast path when the region allows it, the strided
        // scatter otherwise — and neither moves the copy counters.
        via_view.reset_counters();
        {
            let mut mv = via_view.tile_mut(t2, &c.region);
            match mv.as_slice_mut() {
                Some(s) => s.copy_from_slice(&tile),
                None => mv.scatter_from(&tile),
            }
        }
        if via_view.counters() != StoreCounters::default() {
            return Err("mutable view moved the counters".into());
        }
        if via_view.get(t2) != via_tile.get(t1) {
            return Err(format!("mutable-view write differs for region {}", c.region));
        }
        // a contiguous region must also round-trip via view_region_mut.
        {
            let mut probe = via_view.tile_mut(t2, &c.region);
            if probe.as_slice_mut().is_some() {
                drop(probe);
                let zeros = vec![0.0; c.region.numel()];
                via_view.view_region_mut(t2, &c.region).copy_from_slice(&zeros);
                if via_view.read_tile(t2, &c.region) != zeros {
                    return Err("view_region_mut write did not land".into());
                }
            }
        }
        Ok(())
    });
}

#[test]
fn concurrent_disjoint_mut_views_do_not_corrupt() {
    // The write half of the aliasing contract: one thread per row band
    // holds a mutable view of its own band (as a pool output
    // destination would) and writes through it repeatedly, while reader
    // threads view disjoint read-only bands. No locks anywhere — only
    // region disjointness, exactly like concurrently executing tasks
    // whose output tiles the compiler made disjoint.
    let rows = 8usize;
    let cols = 64usize;
    let mut g = CompGraph::new();
    let t = g.input("x", vec![rows * 2, cols], DType::F32);
    let store = TensorStore::new(&g);
    for r in rows..rows * 2 {
        let band = vec![r as f32; cols];
        store.write_tile(t, &Region::new(vec![(r, r + 1), (0, cols)]), &band);
    }
    std::thread::scope(|sc| {
        for w in 0..rows {
            let store = &store;
            sc.spawn(move || {
                let reg = Region::new(vec![(w, w + 1), (0, cols)]);
                for round in 0..200u32 {
                    let val = (w * 1000 + round as usize) as f32;
                    let dst = store.view_region_mut(t, &reg);
                    dst.iter_mut().for_each(|x| *x = val);
                }
            });
        }
        for rdr in 0..4 {
            let store = &store;
            sc.spawn(move || {
                for i in 0..200usize {
                    let r = rows + (rdr + i) % rows;
                    let v = store.view_region(t, &Region::new(vec![(r, r + 1), (0, cols)]));
                    assert!(v.iter().all(|&x| x == r as f32), "read-only band corrupted");
                }
            });
        }
    });
    for w in 0..rows {
        let band = store.read_tile(t, &Region::new(vec![(w, w + 1), (0, cols)]));
        assert_eq!(band, vec![(w * 1000 + 199) as f32; cols], "writer band {w} lost its last write");
    }
}

#[cfg(debug_assertions)]
#[test]
#[should_panic(expected = "aliasing violation")]
fn overlapping_mut_views_panic_in_debug() {
    // writer/writer overlap: two in-flight mutable views of
    // intersecting regions are exactly the bug the event graph is
    // supposed to make impossible — the debug tracker must catch it.
    let mut g = CompGraph::new();
    let t = g.input("x", vec![4, 8], DType::F32);
    let store = TensorStore::new(&g);
    let held = store.tile_mut(t, &Region::new(vec![(0, 3), (0, 8)]));
    let _clash = store.tile_mut(t, &Region::new(vec![(2, 4), (0, 8)]));
    drop(held);
}

#[cfg(debug_assertions)]
#[test]
#[should_panic(expected = "aliasing violation")]
fn reader_overlapping_mut_view_panics_in_debug() {
    // writer/reader overlap: reading a region while a mutable view
    // (e.g. a pool output destination mid-flight) covers it.
    let mut g = CompGraph::new();
    let t = g.input("x", vec![4, 8], DType::F32);
    let store = TensorStore::new(&g);
    let held = store.tile_mut(t, &Region::new(vec![(1, 3), (0, 8)]));
    let _ = store.view_region(t, &Region::new(vec![(2, 3), (0, 8)]));
    drop(held);
}

#[test]
fn concurrent_disjoint_writers_and_readers_stress() {
    // The arena aliasing contract under load: writer threads own
    // disjoint row bands of one tensor; reader threads repeatedly take
    // borrowed views of *other* rows that are never written. Interleave
    // for many rounds, then verify every band.
    let rows = 8usize;
    let cols = 64usize;
    let mut g = CompGraph::new();
    let t = g.input("x", vec![rows * 2, cols], DType::F32);
    let store = TensorStore::new(&g);
    // rows [rows, 2*rows) are pre-set and read-only throughout.
    for r in rows..rows * 2 {
        let band = vec![r as f32; cols];
        store.write_tile(t, &Region::new(vec![(r, r + 1), (0, cols)]), &band);
    }
    std::thread::scope(|sc| {
        for w in 0..rows {
            let store = &store;
            sc.spawn(move || {
                let mut band = vec![0.0f32; cols];
                for round in 0..200u32 {
                    let val = (w * 1000 + round as usize) as f32;
                    band.iter_mut().for_each(|x| *x = val);
                    store.write_tile(t, &Region::new(vec![(w, w + 1), (0, cols)]), &band);
                }
            });
        }
        for rdr in 0..4 {
            let store = &store;
            sc.spawn(move || {
                let mut scratch = Vec::new();
                for i in 0..200usize {
                    let r = rows + (rdr + i) % rows;
                    let reg = Region::new(vec![(r, r + 1), (0, cols)]);
                    store.tile(t, &reg).gather_into(&mut scratch);
                    assert_eq!(scratch, vec![r as f32; cols], "read-only band corrupted");
                    let v = store.view_region(t, &reg);
                    assert!(v.iter().all(|&x| x == r as f32));
                }
            });
        }
    });
    for w in 0..rows {
        let band = store.read_tile(t, &Region::new(vec![(w, w + 1), (0, cols)]));
        assert_eq!(band, vec![(w * 1000 + 199) as f32; cols], "writer band {w} lost its last write");
    }
}

#[test]
fn shared_arena_stress_across_aliased_stores() {
    // Two stores aliasing one slab, as batch-size-specialized serving
    // sessions do: writes through the small store must be visible
    // through the big one, concurrently with reads of disjoint slots.
    let slots = 4usize;
    let s_max = 8usize;
    let kv = 4usize;
    let slab = SharedSlab::new(slots * s_max * kv);
    let mut g_small = CompGraph::new();
    let ts = g_small.input("kc", vec![2, s_max, kv], DType::F32);
    let small = TensorStore::new_with_aliases(&g_small, vec![(ts, slab.clone(), 0)]);
    let mut g_big = CompGraph::new();
    let tb = g_big.input("kc", vec![slots, s_max, kv], DType::F32);
    let big = TensorStore::new_with_aliases(&g_big, vec![(tb, slab.clone(), 0)]);

    // slot 3 (visible only to the big store) is the read-only band.
    let nines = vec![9.0; s_max * kv];
    big.write_tile(tb, &Region::new(vec![(3, 4), (0, s_max), (0, kv)]), &nines);
    std::thread::scope(|sc| {
        let small = &small;
        let big = &big;
        sc.spawn(move || {
            let mut rowbuf = vec![0.0f32; kv];
            for round in 0..200u32 {
                let row = (round as usize) % s_max;
                rowbuf.iter_mut().for_each(|x| *x = round as f32);
                small.write_tile(ts, &Region::new(vec![(1, 2), (row, row + 1), (0, kv)]), &rowbuf);
            }
        });
        sc.spawn(move || {
            for _ in 0..200 {
                let v = big.view_region(tb, &Region::new(vec![(3, 4), (0, s_max), (0, kv)]));
                assert!(v.iter().all(|&x| x == 9.0), "disjoint slot corrupted");
            }
        });
    });
    // last write through `small` is visible through `big`.
    let last_row = 199 % s_max;
    let got = big.read_tile(tb, &Region::new(vec![(1, 2), (last_row, last_row + 1), (0, kv)]));
    assert_eq!(got, vec![199.0; kv]);
}

/// The weight-arena flavour of cross-store aliasing: a random subset of
/// batch-size specializations aliases one [`mpk::exec::WeightArena`];
/// every session's view of every param must agree element-for-element
/// (and pointer-for-pointer) with the per-store `init_weights` result,
/// under arbitrary seeds — the property that makes one shared init
/// sound.
#[test]
fn prop_weight_arena_agrees_with_per_store_init() {
    use mpk::exec::{init_weights, WeightArena};
    use mpk::models::{build_decode_graph, GraphOptions, ModelConfig};
    forall(
        "weight arena",
        0x3EED5,
        8,
        |rng: &mut XorShift64| {
            let seed = rng.next_u64();
            // a subset of specializations (kept small: every case
            // synthesizes full model weights per store in debug builds).
            let sizes: Vec<usize> =
                [1usize, 2, 8].into_iter().filter(|_| rng.below(2) == 0).collect();
            (seed, if sizes.is_empty() { vec![1] } else { sizes })
        },
        |(seed, sizes)| {
            let mk = |b: usize| {
                build_decode_graph(
                    &ModelConfig::tiny(),
                    &GraphOptions { batch: b, kv_len: 7, ..Default::default() },
                )
            };
            let build = mk(*sizes.iter().max().unwrap());
            let arena = WeightArena::build(&build);
            arena.init(&build, *seed);
            let mut first_ptr: Option<*const f32> = None;
            for &b in sizes {
                let g = mk(b);
                let aliased = TensorStore::new_with_aliases(&g, arena.aliases_for(&g));
                let owned = TensorStore::new(&g);
                init_weights(&g, &owned, *seed);
                for t in g.tensors.iter().filter(|t| t.is_param) {
                    if aliased.view(t.id) != owned.view(t.id) {
                        return Err(format!("param {} disagrees at batch {b}", t.name));
                    }
                }
                let embed = g.tensor_by_name("embed.weight").unwrap().id;
                let p = aliased.view(embed).as_ptr();
                if *first_ptr.get_or_insert(p) != p {
                    return Err(format!("batch {b} got a private weight copy"));
                }
            }
            if arena.init_runs() != 1 {
                return Err(format!("init ran {} times", arena.init_runs()));
            }
            Ok(())
        },
    );
}
