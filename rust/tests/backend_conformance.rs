//! Backend conformance: every backend in
//! `mpk::runtime::backend::registry()` is held to the same contract.
//!
//! * **Golden vectors** — each artifact op gets seeded inputs and an
//!   inline, independently written reference (two-pass softmax for
//!   attention, plain k-outer accumulation for matmul), so a backend
//!   whose kernels drift is caught without trusting any other backend.
//! * **Decode agreement** — binder-driven megakernel decode and the
//!   fused `ref_decode` artifact must produce the same logits for 100+
//!   steps of argmax-fed decoding.
//! * **Partial-write protection** — a failed `execute_into` must leave
//!   every destination untouched, no matter which validation tripped.
//! * **Zero-copy property** — CPU-backend serving holds the steady-state
//!   counters (`allocs == bytes_copied == output_allocs ==
//!   kv_rows_migrated == 0`) across seeded request mixes.
//! * **Paged-KV agreement** — the block-granular paged KV pool decodes
//!   bit-identically to the contiguous slot layout across 100+ CPU
//!   steps, including waves whose block-multiple shared prompts decode
//!   over physically shared prefix blocks, with the same zero-copy
//!   counters held at zero.
//!
//! Backends that report themselves unavailable at session construction
//! (the PJRT backend in an offline stub build) are skipped **loudly**,
//! per backend, so CI output shows exactly what was exercised.

use mpk::exec::binder::TileExecutor;
use mpk::exec::real::{self, RealSession};
use mpk::megakernel::MegaConfig;
use mpk::runtime::backend::{registry, BackendSession, ExecBackend, In};
use mpk::runtime::{ArgType, ArtifactSpec, BackendKind, Manifest, OutView};
use mpk::serving::{Request, ServeEngine};
use mpk::util::XorShift64;
use std::sync::Arc;

const TOL: f32 = 1e-4;

/// Deterministic in-range inputs shaped by the artifact signature:
/// f32 in (-1, 1), i32 small (valid token ids and cache lengths for
/// the builtin tiny model). Returns owned buffers plus an index map so
/// callers can rebuild borrowed `In` views per call.
#[allow(clippy::type_complexity)]
fn seeded_inputs(
    spec: &ArtifactSpec,
    rng: &mut XorShift64,
) -> (Vec<Vec<f32>>, Vec<Vec<i32>>, Vec<(bool, usize)>) {
    let mut f_bufs: Vec<Vec<f32>> = Vec::new();
    let mut i_bufs: Vec<Vec<i32>> = Vec::new();
    let mut kinds: Vec<(bool, usize)> = Vec::new();
    for a in &spec.inputs {
        match a.ty {
            ArgType::F32 => {
                f_bufs.push((0..a.numel()).map(|_| rng.unit_f32() - 0.5).collect());
                kinds.push((true, f_bufs.len() - 1));
            }
            ArgType::I32 => {
                i_bufs.push((0..a.numel()).map(|_| rng.below(8) as i32 + 1).collect());
                kinds.push((false, i_bufs.len() - 1));
            }
        }
    }
    (f_bufs, i_bufs, kinds)
}

fn views<'a>(
    f_bufs: &'a [Vec<f32>],
    i_bufs: &'a [Vec<i32>],
    kinds: &[(bool, usize)],
) -> Vec<In<'a>> {
    kinds
        .iter()
        .map(|&(f, i)| if f { In::F32(&f_bufs[i]) } else { In::I32(&i_bufs[i]) })
        .collect()
}

/// A session on `be`, or a **loud** skip when the backend reports
/// itself unavailable (the stub PJRT build).
fn session_or_skip(
    be: &Arc<dyn ExecBackend>,
    manifest: &Arc<Manifest>,
) -> Option<Box<dyn BackendSession>> {
    match be.session(manifest.clone()) {
        Ok(s) => Some(s),
        Err(e) => {
            eprintln!("SKIPPING backend {:?} ({}): unavailable: {e}", be.kind(), be.name());
            None
        }
    }
}

fn assert_close(backend: &str, op: &str, got: &[f32], want: &[f32]) {
    assert_eq!(got.len(), want.len(), "{backend}/{op}: output length");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        assert!(
            (g - w).abs() <= TOL * (1.0 + w.abs()),
            "{backend}/{op}: element {i}: got {g}, want {w}"
        );
    }
}

/// Inline references — written independently of any backend's kernels
/// (two-pass softmax, unblocked matmul) so they cross-check real math,
/// not shared code.
mod reference {
    pub fn add(a: &[f32], b: &[f32]) -> Vec<f32> {
        a.iter().zip(b).map(|(x, y)| x + y).collect()
    }

    pub fn embed(ids: &[i32], table: &[f32], vocab: usize, d: usize) -> Vec<f32> {
        let mut out = Vec::with_capacity(ids.len() * d);
        for &id in ids {
            let row = (id.max(0) as usize).min(vocab - 1);
            out.extend_from_slice(&table[row * d..][..d]);
        }
        out
    }

    pub fn rmsnorm(x: &[f32], w: &[f32], rows: usize, d: usize) -> Vec<f32> {
        let mut out = vec![0.0; rows * d];
        for r in 0..rows {
            let xr = &x[r * d..][..d];
            let ss: f32 = xr.iter().map(|v| v * v).sum();
            let inv = 1.0 / (ss / d as f32 + 1e-6).sqrt();
            for (o, (&xv, &wv)) in out[r * d..][..d].iter_mut().zip(xr.iter().zip(w)) {
                *o = xv * inv * wv;
            }
        }
        out
    }

    /// Plain unblocked row-major matmul: `x [rows, k] · w [k, n]`.
    pub fn matmul(x: &[f32], w: &[f32], rows: usize, k: usize, n: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; rows * n];
        for r in 0..rows {
            for kk in 0..k {
                let xv = x[r * k + kk];
                for j in 0..n {
                    out[r * n + j] += xv * w[kk * n + j];
                }
            }
        }
        out
    }

    pub fn swiglu(x: &[f32], rows: usize, f: usize) -> Vec<f32> {
        let mut out = vec![0.0; rows * f];
        for r in 0..rows {
            let row = &x[r * 2 * f..][..2 * f];
            let (gate, up) = row.split_at(f);
            for (o, (&g, &u)) in out[r * f..][..f].iter_mut().zip(gate.iter().zip(up)) {
                *o = (g / (1.0 + (-g).exp())) * u;
            }
        }
        out
    }

    /// GQA decode attention over the first `valid` cache rows via
    /// **two-pass** softmax (max, then normalize) — deliberately a
    /// different algorithm from any backend's online softmax.
    #[allow(clippy::too_many_arguments)]
    pub fn attention(
        q: &[f32],
        kc: &[f32],
        vc: &[f32],
        valid: usize,
        heads: usize,
        kv_heads: usize,
        head_dim: usize,
    ) -> Vec<f32> {
        let kv_dim = kv_heads * head_dim;
        let group = (heads / kv_heads).max(1);
        let scale = 1.0 / (head_dim as f32).sqrt();
        let mut out = vec![0.0f32; heads * head_dim];
        for h in 0..heads {
            let qh = &q[h * head_dim..][..head_dim];
            let kvh = h / group;
            let scores: Vec<f32> = (0..valid)
                .map(|s| {
                    let krow = &kc[s * kv_dim + kvh * head_dim..][..head_dim];
                    qh.iter().zip(krow).map(|(a, b)| a * b).sum::<f32>() * scale
                })
                .collect();
            if scores.is_empty() {
                continue;
            }
            let m = scores.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let exps: Vec<f32> = scores.iter().map(|s| (s - m).exp()).collect();
            let l: f32 = exps.iter().sum();
            for (s, &p) in exps.iter().enumerate() {
                let vrow = &vc[s * kv_dim + kvh * head_dim..][..head_dim];
                for (o, &v) in out[h * head_dim..][..head_dim].iter_mut().zip(vrow) {
                    *o += p * v / l;
                }
            }
        }
        out
    }
}

/// Per-op golden vectors against every registered backend: seeded
/// inputs, inline reference outputs, tolerance `TOL`.
#[test]
fn golden_vectors_hold_for_every_available_backend() {
    let manifest = Arc::new(Manifest::builtin());
    let m = manifest.model;
    let (d, vocab, ffn) = (m.d_model, m.vocab, m.ffn);
    let mut exercised = 0usize;
    for be in registry() {
        let Some(mut sess) = session_or_skip(be, &manifest) else { continue };
        let name = be.name();
        let mut rng = XorShift64::new(0xB0A7 + be.kind() as u64);

        for b in [1usize, 4] {
            // add_b{b}
            let (idx, spec) = manifest.find(&format!("add_b{b}")).unwrap();
            let (f, i, k) = seeded_inputs(spec, &mut rng);
            let got = sess.execute(idx, &views(&f, &i, &k)).unwrap();
            assert_close(name, &spec.name, &got[0], &reference::add(&f[0], &f[1]));

            // embed_b{b}
            let (idx, spec) = manifest.find(&format!("embed_b{b}")).unwrap();
            let (f, i, k) = seeded_inputs(spec, &mut rng);
            let got = sess.execute(idx, &views(&f, &i, &k)).unwrap();
            assert_close(name, &spec.name, &got[0], &reference::embed(&i[0], &f[0], vocab, d));

            // rmsnorm_b{b}
            let (idx, spec) = manifest.find(&format!("rmsnorm_b{b}")).unwrap();
            let (f, i, k) = seeded_inputs(spec, &mut rng);
            let got = sess.execute(idx, &views(&f, &i, &k)).unwrap();
            assert_close(name, &spec.name, &got[0], &reference::rmsnorm(&f[0], &f[1], b, d));

            // swiglu_b{b}
            let (idx, spec) = manifest.find(&format!("swiglu_b{b}")).unwrap();
            let (f, i, k) = seeded_inputs(spec, &mut rng);
            let got = sess.execute(idx, &views(&f, &i, &k)).unwrap();
            assert_close(name, &spec.name, &got[0], &reference::swiglu(&f[0], b, ffn));

            // matmul_b{b}_k*_n* (both k widths)
            for kk in [d, 2 * d] {
                let (idx, spec) = manifest.find(&format!("matmul_b{b}_k{kk}_n128")).unwrap();
                let n = spec.inputs[1].shape[1];
                let (f, i, kd) = seeded_inputs(spec, &mut rng);
                let got = sess.execute(idx, &views(&f, &i, &kd)).unwrap();
                assert_close(name, &spec.name, &got[0], &reference::matmul(&f[0], &f[1], b, kk, n));
            }
        }

        // attn_q1: two-pass-softmax reference vs the backend's kernel.
        let (idx, spec) = manifest.find("attn_q1").unwrap();
        let (f, mut i, k) = seeded_inputs(spec, &mut rng);
        i[0][0] = 5; // attend over the first 5 cache rows
        let got = sess.execute(idx, &views(&f, &i, &k)).unwrap();
        let want = reference::attention(&f[0], &f[1], &f[2], 5, m.heads, m.kv_heads, m.head_dim);
        assert_close(name, &spec.name, &got[0], &want);

        exercised += 1;
    }
    // the CPU backend is always constructible: at least one backend
    // must have actually been exercised or this test proves nothing.
    assert!(exercised >= 1, "no backend was available for conformance");
}

/// Binder-driven megakernel decode agrees with the fused `ref_decode`
/// artifact for 120 argmax-fed steps (two independent sessions, 60
/// steps each) on the CPU backend.
#[test]
fn cpu_decode_agrees_with_reference_for_100_plus_steps() {
    let batch = 2usize;
    let mut total = 0usize;
    for seed in [42u64, 7] {
        let s = RealSession::create_with(batch, 2, seed, BackendKind::Cpu).unwrap();
        let mut kernel = s.persistent_kernel(4, 1);
        let exec = TileExecutor::new(&s.compiled.graph, &s.store, &s.pool, batch);
        let vocab = s.manifest.model.vocab;
        let mut ids: Vec<i32> = (0..batch as i32).map(|r| 7 + 11 * r).collect();
        for step in 0..60 {
            real::set_ids(&s.compiled.graph, &s.store, &ids).unwrap();
            // the reference reads caches as stored (it appends this
            // step's K/V itself), so it must run before the binder's
            // KvAppend mutates the arena.
            let want =
                real::run_reference(&s.manifest, &s.pool, &s.compiled.graph, &s.store, batch, &ids, step)
                    .unwrap();
            real::run_iteration(&mut kernel, &exec, step).unwrap();
            let got = real::get_logits(&s.compiled.graph, &s.store).unwrap();
            assert_close("cpu", &format!("decode step {step} (seed {seed})"), &got, &want);
            ids = (0..batch)
                .map(|r| real::argmax(&got[r * vocab..][..vocab]) as i32)
                .collect();
            total += 1;
        }
    }
    assert!(total >= 100, "only {total} agreement steps ran");
}

/// A failed `execute_into` leaves every destination untouched — checked
/// per backend, for each validation arm (destination count, destination
/// numel, input arity).
#[test]
fn execute_into_failures_never_touch_destinations() {
    let manifest = Arc::new(Manifest::builtin());
    for be in registry() {
        let Some(mut sess) = session_or_skip(be, &manifest) else { continue };
        let name = be.name();
        let (idx, spec) = manifest.find("add_b1").unwrap();
        let mut rng = XorShift64::new(99);
        let (f, i, k) = seeded_inputs(spec, &mut rng);
        let numel = spec.inputs[0].numel();
        let sentinel = -7.0f32;

        // wrong destination count (zero destinations).
        let mut buf = vec![sentinel; numel];
        let err = sess.execute_into(idx, &views(&f, &i, &k), &mut []).unwrap_err();
        assert!(!format!("{err}").is_empty());
        assert!(buf.iter().all(|&v| v == sentinel), "{name}: count-failure wrote");

        // wrong destination numel.
        let mut short = vec![sentinel; numel - 1];
        {
            let mut outs = [OutView::from_slice(&mut short)];
            sess.execute_into(idx, &views(&f, &i, &k), &mut outs).unwrap_err();
        }
        assert!(short.iter().all(|&v| v == sentinel), "{name}: numel-failure wrote");

        // wrong input arity.
        {
            let mut outs = [OutView::from_slice(&mut buf)];
            sess.execute_into(idx, &views(&f, &i, &k)[..1], &mut outs).unwrap_err();
        }
        assert!(buf.iter().all(|&v| v == sentinel), "{name}: arity-failure wrote");

        // and the same inputs/destination succeed once valid.
        {
            let mut outs = [OutView::from_slice(&mut buf)];
            sess.execute_into(idx, &views(&f, &i, &k), &mut outs).unwrap();
        }
        assert_close(name, "add_b1 (post-failure)", &buf, &reference::add(&f[0], &f[1]));
    }
}

/// Seeded property: CPU-backend serving keeps the steady-state
/// zero-copy contract — no store allocations, no bytes copied through
/// the store boundary, no pool output allocations, no KV row moves —
/// across varied request mixes.
/// Acceptance: paged decode is bit-identical to the contiguous layout
/// across 100+ CPU decode steps. Two waves each carry a pair of
/// requests on the same block-multiple (16-token) system prompt: wave
/// 1 publishes its prefix blocks, wave 2's pair admits *through* the
/// prefix index and decodes over physically shared cache rows — and
/// every generated token still matches the contiguous run exactly,
/// while the paged engine holds the zero-copy counters at zero.
#[test]
fn cpu_paged_decode_is_bit_identical_to_contiguous_for_100_plus_steps() {
    use std::collections::HashMap;
    let run = |paged: bool| -> (HashMap<u64, Vec<i32>>, usize, u64) {
        let mut e = ServeEngine::builder()
            .max_batch(4)
            .pool_threads(2)
            .seed(42)
            .mega(MegaConfig { workers: 4, schedulers: 1, ..Default::default() })
            .backend(BackendKind::Cpu)
            .paged_kv(paged)
            .build()
            .unwrap();
        let sys: Vec<i32> = (0..16).map(|i| 1 + (i * 7 % 90) as i32).collect();
        let mut outputs: HashMap<u64, Vec<i32>> = HashMap::new();
        let mut steps = 0usize;
        let mut shared_peak = 0u64;
        let mut migrated = 0usize;
        for wave in 0..2u64 {
            for k in 0..3u64 {
                let prompt = if k < 2 {
                    sys.clone()
                } else {
                    vec![3 + wave as i32, 11, 4 + k as i32]
                };
                e.submit(Request::new(wave * 10 + k, prompt, 50)).unwrap();
            }
            let (out, stats) = e.serve().unwrap();
            assert_eq!(out.len(), 3, "paged={paged} wave {wave}");
            steps += stats.iterations;
            shared_peak = shared_peak.max(stats.kv_blocks_shared);
            migrated += stats.kv_rows_migrated;
            outputs.extend(out);
        }
        assert_eq!(e.store_counters(), (0, 0), "paged={paged}: store alloc/copy in decode");
        assert_eq!(e.output_allocs(), 0, "paged={paged}: pool allocated output buffers");
        assert_eq!(migrated, 0, "paged={paged}: KV rows moved");
        (outputs, steps, shared_peak)
    };
    let (plain, plain_steps, plain_shared) = run(false);
    let (paged, paged_steps, paged_shared) = run(true);
    assert_eq!(plain, paged, "paged decode diverged from the contiguous layout");
    assert!(
        plain_steps >= 100 && paged_steps >= 100,
        "agreement held for only {plain_steps} contiguous / {paged_steps} paged steps"
    );
    assert_eq!(plain_shared, 0, "contiguous run reported shared KV blocks");
    assert!(paged_shared > 0, "wave 2 never decoded over a shared prefix block");
}

#[test]
fn cpu_serving_decode_preserves_zero_copy_counters() {
    for seed in [1u64, 0xC0FFEE, 31337] {
        let mut rng = XorShift64::new(seed);
        let mut e = ServeEngine::builder()
            .max_batch(4)
            .pool_threads(2)
            .seed(42)
            .mega(MegaConfig { workers: 4, schedulers: 1, ..Default::default() })
            .backend(BackendKind::Cpu)
            .build()
            .unwrap();
        assert_eq!(e.pool().backend_kind(), BackendKind::Cpu);
        let n = 3 + rng.below(4) as u64;
        for id in 0..n {
            let prompt: Vec<i32> = (0..1 + rng.below(3)).map(|_| 1 + rng.below(500) as i32).collect();
            e.submit(Request::new(id, prompt, 2 + rng.below(4))).unwrap();
        }
        let (out, stats) = e.serve().unwrap();
        assert_eq!(out.len(), n as usize, "seed {seed}");
        assert_eq!(e.store_counters(), (0, 0), "seed {seed}: store alloc/copy in decode");
        assert_eq!(e.output_allocs(), 0, "seed {seed}: pool allocated output buffers");
        assert_eq!(stats.kv_rows_migrated, 0, "seed {seed}: KV rows moved");
    }
}
