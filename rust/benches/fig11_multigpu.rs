//! Figure 11: multi-GPU tensor parallelism — Qwen3-1.7B on 1/2/4/8
//! H100s, MPK vs PyTorch / vLLM / SGLang (normalized to MPK).

use mpk::models::ModelConfig;
use mpk::multigpu::tp::{baseline_iteration_us, mpk_iteration_us, plan};
use mpk::sim::{BaselineSystem, GpuSpec, LinkSpec};
use mpk::tgraph::DepGranularity;
use mpk::util::Table;

fn main() {
    println!("== Figure 11: Qwen3-1.7B tensor parallelism on H100 (batch 1) ==\n");
    let gpu = GpuSpec::h100();
    let link = LinkSpec::nvlink_h100();
    let cfg = ModelConfig::qwen3_1_7b();
    let mut t = Table::new(&["GPUs", "MPK ms/tok", "PyTorch", "vLLM", "SGLang", "speedup", "scaling"]);
    let mut base_mpk = 0.0;
    for w in [1usize, 2, 4, 8] {
        let p = plan(&cfg, 1, 512, w, &gpu, DepGranularity::Fine);
        let mpk = mpk_iteration_us(&p, &gpu, &link, true);
        if w == 1 {
            base_mpk = mpk;
        }
        let rel = |sys: &BaselineSystem| baseline_iteration_us(&p, &gpu, &link, sys) / mpk;
        let pt = rel(&BaselineSystem::pytorch());
        let vl = rel(&BaselineSystem::vllm());
        let sg = rel(&BaselineSystem::sglang());
        t.row(vec![
            w.to_string(),
            format!("{:.3}", mpk / 1000.0),
            format!("{pt:.2}x"),
            format!("{vl:.2}x"),
            format!("{sg:.2}x"),
            format!("{:.2}x", vl.min(sg)),
            format!("{:.2}x", base_mpk / mpk),
        ]);
    }
    println!("{}", t.render());
    println!("paper shape: up to 10x vs PyTorch; 1.1-1.4x vs vLLM/SGLang at 8 GPUs;");
    println!("sub-linear scaling as per-rank weights shrink and collectives grow.");
}
