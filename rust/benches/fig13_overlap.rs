//! Figure 13: compute–communication overlap ablation — Qwen3-1.7B on
//! 4×H100, fine-grained events vs coarse per-collective events
//! (Figure 5c semantics). Per-iteration latency in µs, lower is better.

use mpk::models::ModelConfig;
use mpk::multigpu::tp::{mpk_iteration_us, plan};
use mpk::sim::{GpuSpec, LinkSpec};
use mpk::tgraph::DepGranularity;
use mpk::util::Table;

fn main() {
    println!("== Figure 13: compute-communication overlap, Qwen3-1.7B on 4xH100 ==\n");
    let gpu = GpuSpec::h100();
    let link = LinkSpec::nvlink_h100();
    let cfg = ModelConfig::qwen3_1_7b();
    let mut t = Table::new(&["batch", "overlap ON (fine)", "overlap OFF (coarse)", "benefit"]);
    for b in [1usize, 4, 8, 16] {
        let fine = plan(&cfg, b, 512, 4, &gpu, DepGranularity::Fine);
        let coarse = plan(&cfg, b, 512, 4, &gpu, DepGranularity::CoarseCollectives);
        let f = mpk_iteration_us(&fine, &gpu, &link, true);
        let c = mpk_iteration_us(&coarse, &gpu, &link, true);
        t.row(vec![b.to_string(), format!("{f:.0}"), format!("{c:.0}"), format!("{:.3}x", c / f)]);
    }
    println!("{}", t.render());
    println!("paper shape: fine-grained dependencies reduce per-iteration");
    println!("latency ~1.1x; our roofline DES reproduces the direction with a");
    println!("smaller magnitude (completion-time spread is the only staggering");
    println!("source we model — see EXPERIMENTS.md).");
}
