//! §6.6 kernel-launch reduction: kernels per token under the
//! kernel-per-operator model (eager vs CUDA graphs) vs MPK's single
//! launch, and the in-kernel scheduler's share of runtime — measured on
//! the *real threaded megakernel* over the tiny model, and modeled for
//! Qwen3-8B on B200.

use mpk::megakernel::{MegaConfig, MegaKernel};
use mpk::models::{build_decode_graph, GraphOptions, ModelConfig};
use mpk::sim::{kernel_launches, GpuSpec};
use mpk::tgraph::{compile, CompileOptions, DecomposeConfig, TaskDesc};
use mpk::util::Table;

fn main() {
    println!("== §6.6: kernel-launch reduction ==\n");
    let gpu = GpuSpec::b200();
    let cfg = ModelConfig::qwen3_8b();
    let g = build_decode_graph(&cfg, &GraphOptions { batch: 1, kv_len: 512, ..Default::default() });
    let c = compile(
        &g,
        &CompileOptions {
            decompose: DecomposeConfig { target_tasks: gpu.workers, min_tile_cols: 8 },
            ..Default::default()
        },
    );
    let n = kernel_launches(&c);
    let mut t = Table::new(&["mode", "launches/token", "overhead/token"]);
    t.row(vec!["eager".into(), n.to_string(), format!("{:.2} ms", n as f64 * gpu.launch_us_eager / 1000.0)]);
    t.row(vec!["CUDA graphs".into(), n.to_string(), format!("{:.2} ms", n as f64 * gpu.launch_us_graph / 1000.0)]);
    t.row(vec!["MPK mega-kernel".into(), "1".into(), "0.00 ms".into()]);
    println!("{}", t.render());
    println!("paper: 293 launches -> 1.1 ms eager / 0.2 ms graphs; ours: {n} ops.\n");

    // real threaded runtime: scheduler overhead share (paper: 0.28%).
    println!("== in-kernel scheduler overhead (real threaded runtime, tiny model) ==");
    let tiny = ModelConfig::tiny();
    let g = build_decode_graph(&tiny, &GraphOptions { batch: 4, kv_len: 16, ..Default::default() });
    let c = compile(
        &g,
        &CompileOptions { decompose: DecomposeConfig { target_tasks: 16, min_tile_cols: 8 }, ..Default::default() },
    );
    let mk = MegaKernel::new(&c, MegaConfig { workers: 4, schedulers: 1, ..Default::default() });
    // simulate ~5 µs of work per task so overhead fractions are honest.
    let busy = |_: &TaskDesc| {
        let t0 = std::time::Instant::now();
        while t0.elapsed().as_micros() < 5 {
            std::hint::spin_loop();
        }
    };
    let mut fracs = Vec::new();
    for _ in 0..5 {
        let r = mk.run(&busy).expect("run");
        fracs.push(r.metrics.sched_overhead() * 100.0);
    }
    fracs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    println!("scheduler share of accounted runtime: {:.2}% (median of 5 runs)", fracs[2]);
    println!("paper: 0.28% on B200.");
}
