//! §6.6 kernel-launch reduction: kernels per token under the
//! kernel-per-operator model (eager vs CUDA graphs) vs MPK's single
//! launch, the in-kernel scheduler's share of runtime, and — the real
//! measurement this repo optimizes — per-iteration overhead of the
//! spawn-per-run scoped kernel vs the persistent kernel (threads
//! spawned once, re-armed per epoch). Emits `BENCH_launch_overhead.json`
//! (path overridable via `MPK_BENCH_JSON`) so the perf trajectory is
//! tracked across PRs.

use mpk::megakernel::{MegaConfig, MegaKernel, PersistentMegaKernel};
use mpk::models::{build_decode_graph, GraphOptions, ModelConfig};
use mpk::sim::{kernel_launches, GpuSpec};
use mpk::tgraph::{compile, CompileOptions, DecomposeConfig, TaskDesc};
use mpk::util::{bench_median_ns, Table};
use std::sync::Arc;

fn main() {
    println!("== §6.6: kernel-launch reduction ==\n");
    let gpu = GpuSpec::b200();
    let cfg = ModelConfig::qwen3_8b();
    let g = build_decode_graph(&cfg, &GraphOptions { batch: 1, kv_len: 512, ..Default::default() });
    let c = compile(
        &g,
        &CompileOptions {
            decompose: DecomposeConfig { target_tasks: gpu.workers, min_tile_cols: 8 },
            ..Default::default()
        },
    );
    let n = kernel_launches(&c);
    let mut t = Table::new(&["mode", "launches/token", "overhead/token"]);
    t.row(vec!["eager".into(), n.to_string(), format!("{:.2} ms", n as f64 * gpu.launch_us_eager / 1000.0)]);
    t.row(vec!["CUDA graphs".into(), n.to_string(), format!("{:.2} ms", n as f64 * gpu.launch_us_graph / 1000.0)]);
    t.row(vec!["MPK mega-kernel".into(), "1".into(), "0.00 ms".into()]);
    println!("{}", t.render());
    println!("paper: 293 launches -> 1.1 ms eager / 0.2 ms graphs; ours: {n} ops.\n");

    // real threaded runtime: per-iteration launch overhead, spawn/join
    // per run (scoped) vs persistent parked threads re-armed per epoch.
    println!("== per-iteration overhead: spawn-per-run vs persistent (tiny model, no-op tasks) ==");
    let tiny = ModelConfig::tiny();
    let gt = build_decode_graph(&tiny, &GraphOptions { batch: 4, kv_len: 16, ..Default::default() });
    let ct = Arc::new(compile(
        &gt,
        &CompileOptions { decompose: DecomposeConfig { target_tasks: 16, min_tile_cols: 8 }, ..Default::default() },
    ));
    let kcfg = MegaConfig { workers: 4, schedulers: 1, ..Default::default() };
    let noop = |_: &TaskDesc| {};
    let ntasks = ct.tgraph.tasks.len();

    let scoped = MegaKernel::new(&ct, kcfg);
    let scoped_ns = bench_median_ns(3, 30, || {
        scoped.run(&noop).expect("scoped run");
    });
    let mut persistent = PersistentMegaKernel::new(ct.clone(), kcfg);
    let persistent_ns = bench_median_ns(3, 30, || {
        persistent.run(&noop).expect("persistent run");
    });
    let speedup = scoped_ns as f64 / persistent_ns.max(1) as f64;

    let mut t = Table::new(&["runtime", "median/iter", "ns/task", "threads spawned/iter"]);
    t.row(vec![
        "scoped (spawn per run)".into(),
        format!("{:.2} µs", scoped_ns as f64 / 1e3),
        format!("{:.0}", scoped_ns as f64 / ntasks as f64),
        format!("{}", kcfg.workers + kcfg.schedulers),
    ]);
    t.row(vec![
        "persistent (parked)".into(),
        format!("{:.2} µs", persistent_ns as f64 / 1e3),
        format!("{:.0}", persistent_ns as f64 / ntasks as f64),
        "0".into(),
    ]);
    println!("{}", t.render());
    println!("persistent speedup: {speedup:.2}x over spawn-per-iteration ({ntasks} tasks/iter)\n");

    // scheduler overhead share on the persistent runtime (paper: 0.28%).
    println!("== in-kernel scheduler overhead (persistent runtime, ~5 µs tasks) ==");
    let busy = |_: &TaskDesc| {
        let t0 = std::time::Instant::now();
        while t0.elapsed().as_micros() < 5 {
            std::hint::spin_loop();
        }
    };
    let mut fracs = Vec::new();
    for _ in 0..5 {
        let r = persistent.run(&busy).expect("run");
        fracs.push(r.metrics.sched_overhead() * 100.0);
    }
    fracs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    println!("scheduler share of accounted runtime: {:.2}% (median of 5 runs)", fracs[2]);
    println!("paper: 0.28% on B200.");

    // perf-trajectory record for CI (scripts/tier1.sh).
    let json_path = std::env::var("MPK_BENCH_JSON")
        .unwrap_or_else(|_| "BENCH_launch_overhead.json".to_string());
    let json = format!(
        "{{\n  \"bench\": \"launch_overhead\",\n  \"tasks_per_iteration\": {ntasks},\n  \
         \"scoped_spawn_per_iter_ns\": {scoped_ns},\n  \"persistent_ns\": {persistent_ns},\n  \
         \"persistent_speedup\": {speedup:.4},\n  \"sched_overhead_pct_median\": {:.4}\n}}\n",
        fracs[2]
    );
    match std::fs::write(&json_path, json) {
        Ok(()) => println!("\nwrote {json_path}"),
        Err(e) => eprintln!("\ncould not write {json_path}: {e}"),
    }
}
