//! Hot-path microbenchmarks for the perf pass (§Perf in
//! EXPERIMENTS.md): queue ops, event notification, compiler stages, DES
//! throughput, and tile marshalling into the PJRT pool. Custom harness
//! (criterion unavailable offline): warmup + median-of-N on the
//! monotonic clock.

use mpk::megakernel::{EventTable, MpmcQueue};
use mpk::models::{build_decode_graph, GraphOptions, ModelConfig};
use mpk::sim::{simulate_megakernel, GpuSpec, SimOptions};
use mpk::tgraph::{analyze_deps, compile, decompose, CompileOptions, DecomposeConfig};
use mpk::util::{bench_median_ns, Table};

fn main() {
    println!("== hot-path microbenchmarks (median ns unless noted) ==\n");
    let mut t = Table::new(&["benchmark", "median", "note"]);

    // queue push+pop round trip
    let q: MpmcQueue<usize> = MpmcQueue::new(1024);
    let ns = bench_median_ns(1000, 20000, || {
        q.push(1).unwrap();
        std::hint::black_box(q.pop());
    });
    t.row(vec!["MpmcQueue push+pop".into(), format!("{ns} ns"), "per task dispatch".into()]);

    // event notify
    let ev = EventTable::new(&[u32::MAX as usize]);
    let ns = bench_median_ns(1000, 20000, || {
        std::hint::black_box(ev.notify(0));
    });
    t.row(vec!["EventTable notify".into(), format!("{ns} ns"), "atomicAdd analogue".into()]);

    // compiler stages on Qwen3-1.7B
    let cfg = ModelConfig::qwen3_1_7b();
    let gpu = GpuSpec::b200();
    let g = build_decode_graph(&cfg, &GraphOptions { batch: 1, kv_len: 512, ..Default::default() });
    let dc = DecomposeConfig { target_tasks: gpu.workers, min_tile_cols: 8 };

    let ns = bench_median_ns(1, 5, || {
        std::hint::black_box(decompose(&g, &dc));
    });
    t.row(vec!["decompose (1.7B)".into(), format!("{:.2} ms", ns as f64 / 1e6), "per graph".into()]);

    let d = decompose(&g, &dc);
    let ns = bench_median_ns(1, 5, || {
        std::hint::black_box(analyze_deps(&g, &d));
    });
    t.row(vec!["dependency analysis (1.7B)".into(), format!("{:.2} ms", ns as f64 / 1e6), "pairwise overlap".into()]);

    let ns = bench_median_ns(1, 5, || {
        std::hint::black_box(compile(&g, &CompileOptions { decompose: dc, ..Default::default() }));
    });
    t.row(vec!["full compile (1.7B)".into(), format!("{:.2} ms", ns as f64 / 1e6), "all stages".into()]);

    // DES throughput
    let c = compile(&g, &CompileOptions { decompose: dc, ..Default::default() });
    let ns = bench_median_ns(1, 5, || {
        std::hint::black_box(simulate_megakernel(&c, &gpu, &SimOptions::default()));
    });
    let tasks = c.tgraph.tasks.len();
    t.row(vec![
        "DES replay (1.7B)".into(),
        format!("{:.2} ms", ns as f64 / 1e6),
        format!("{:.0} ktasks/s", tasks as f64 / (ns as f64 / 1e9) / 1000.0),
    ]);

    // threaded megakernel dispatch-only throughput (no-op tasks):
    // scoped (spawn/join per run) vs persistent (parked threads).
    let tiny = ModelConfig::tiny();
    let gt = build_decode_graph(&tiny, &GraphOptions { batch: 4, kv_len: 16, ..Default::default() });
    let ct = std::sync::Arc::new(compile(&gt, &CompileOptions { decompose: DecomposeConfig { target_tasks: 16, min_tile_cols: 8 }, ..Default::default() }));
    let kcfg = mpk::megakernel::MegaConfig { workers: 4, schedulers: 1, ..Default::default() };
    let nt = ct.tgraph.tasks.len();
    let mk = mpk::megakernel::MegaKernel::new(&ct, kcfg);
    let ns = bench_median_ns(2, 10, || {
        mk.run(&|_: &mpk::tgraph::TaskDesc| {}).unwrap();
    });
    t.row(vec![
        "scoped megakernel (no-op tasks)".into(),
        format!("{:.2} ms", ns as f64 / 1e6),
        format!("{} tasks, {:.0} ns/task", nt, ns as f64 / nt as f64),
    ]);
    let mut pk = mpk::megakernel::PersistentMegaKernel::new(ct.clone(), kcfg);
    let ns = bench_median_ns(2, 10, || {
        pk.run(&|_: &mpk::tgraph::TaskDesc| {}).unwrap();
    });
    t.row(vec![
        "persistent megakernel (no-op tasks)".into(),
        format!("{:.2} ms", ns as f64 / 1e6),
        format!("{} tasks, {:.0} ns/task", nt, ns as f64 / nt as f64),
    ]);

    println!("{}", t.render());
}
