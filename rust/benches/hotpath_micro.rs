//! Hot-path microbenchmarks for the perf pass (§Perf in
//! EXPERIMENTS.md): queue ops, event notification, compiler stages, DES
//! throughput, tile marshalling across the exec-pool boundary, the
//! native CPU backend's kernels, and the serving front-end under
//! saturation. Custom harness (criterion unavailable offline): warmup +
//! median-of-N on the monotonic clock.

use mpk::exec::real::{init_weights, WeightArena};
use mpk::exec::store::TensorStore;
use mpk::megakernel::{EventTable, MpmcQueue};
use mpk::models::{build_decode_graph, GraphOptions, ModelConfig};
use mpk::ops::{CompGraph, DType, Region};
use mpk::runtime::{ArgType, BackendKind, ExecPool, Manifest, OutView, Value};
use mpk::serving::mock::MockEngine;
use mpk::serving::{
    Batcher, EngineError, FinishReason, KvAllocator, Priority, Request, ServeEngine, ServeServer,
    ServeStats, ServeTransport, ServerConfig, StepEngine, StepOutcome, SubmitOptions,
    TransportClient, TransportConfig,
};
use mpk::sim::{simulate_megakernel, GpuSpec, SimOptions};
use mpk::tgraph::{
    analyze_deps, compile, decompose, verify_compiled, CompileOptions, DecomposeConfig,
};
use mpk::util::{bench_median_ns, Table};
use std::sync::Mutex;

/// The store hot path: the same strided weight-tile read through three
/// generations of the storage layer — the pre-arena locked-clone
/// (`Mutex<Vec<f32>>` + fresh `Vec` per read, reconstructed here), the
/// arena's owned `read_tile` (no lock, still allocates), and the
/// borrowed `TileView` gather into a reused per-worker scratch (no
/// lock, no allocation — asserted via the store counters, not timing).
/// Returns `(clone_ns, read_tile_ns, view_ns, view_allocs)`.
fn bench_store_hotpath(t: &mut Table) -> (u64, u64, u64, u64) {
    let rows = 256usize;
    let cols = 512usize;
    let tile = Region::new(vec![(0, rows), (128, 256)]); // strided matmul-style tile
    let data: Vec<f32> = (0..rows * cols).map(|i| (i % 97) as f32).collect();

    // legacy: one mutex per tensor, lock + gather into a fresh Vec.
    let legacy = Mutex::new(data.clone());
    let clone_ns = bench_median_ns(200, 2000, || {
        let buf = legacy.lock().unwrap();
        let mut out = Vec::with_capacity(tile.numel());
        for r in tile.dims[0].0..tile.dims[0].1 {
            let (c0, c1) = tile.dims[1];
            out.extend_from_slice(&buf[r * cols + c0..r * cols + c1]);
        }
        std::hint::black_box(&out);
    });

    let mut g = CompGraph::new();
    let w = g.input("w", vec![rows, cols], DType::F32);
    let store = TensorStore::new(&g);
    store.set(w, &data);

    let read_ns = bench_median_ns(200, 2000, || {
        std::hint::black_box(store.read_tile(w, &tile));
    });

    store.reset_counters();
    let mut scratch: Vec<f32> = Vec::new();
    let view_ns = bench_median_ns(200, 2000, || {
        store.tile(w, &tile).gather_into(&mut scratch);
        std::hint::black_box(&scratch);
    });
    let view_allocs = store.counters().allocs;
    assert_eq!(view_allocs, 0, "borrowed-view path must not allocate in the store");

    t.row(vec![
        "store_hotpath: locked clone (legacy)".into(),
        format!("{clone_ns} ns"),
        "mutex + fresh Vec per tile read".into(),
    ]);
    t.row(vec![
        "store_hotpath: arena read_tile".into(),
        format!("{read_ns} ns"),
        "no lock, owned Vec per read".into(),
    ]);
    t.row(vec![
        "store_hotpath: arena borrowed view".into(),
        format!("{view_ns} ns"),
        "zero lock, zero alloc (counter-asserted)".into(),
    ]);
    (clone_ns, read_ns, view_ns, view_allocs)
}

/// Weight initialization across the batch-size specializations of the
/// tiny model, two generations: per-session (every specialization packs
/// and synthesizes a private copy — the pre-arena serving engine) vs
/// one shared [`WeightArena`] that every session store aliases (one
/// layout, one synthesis, one allocation). Returns `(per_session_ns,
/// shared_ns, duplicated_bytes, shared_bytes)`.
fn bench_weight_arena(t: &mut Table) -> (u64, u64, u64, u64) {
    let mk = |b: usize| {
        build_decode_graph(
            &ModelConfig::tiny(),
            // f32, like the real-numerics serving path: param_bytes then
            // agrees with the 4-byte arena elements below.
            &GraphOptions { batch: b, kv_len: 15, dtype: DType::F32, ..Default::default() },
        )
    };
    let graphs: Vec<CompGraph> = [1usize, 2, 4, 8].iter().map(|&b| mk(b)).collect();

    // legacy: every batch-size session synthesizes its own copy. Store
    // construction happens outside the timed closure on both sides, so
    // the ratio compares synthesis work only (4 inits vs 1), not
    // store-allocation overhead.
    let legacy_stores: Vec<TensorStore> = graphs.iter().map(TensorStore::new).collect();
    let per_session_ns = bench_median_ns(1, 5, || {
        for (g, store) in graphs.iter().zip(&legacy_stores) {
            init_weights(g, store, 42);
            std::hint::black_box(store);
        }
    });

    // shared arena: one synthesis for all sessions (layout pre-built,
    // mirroring the pre-built stores above).
    let shared_arena = WeightArena::build(&graphs[3]);
    let shared_ns = bench_median_ns(1, 5, || {
        shared_arena.init(&graphs[3], 42);
        std::hint::black_box(&shared_arena);
    });

    // and the aliasing really shares memory: sessions' param views are
    // pointer-identical, so serving weight memory is `shared_bytes`
    // instead of `dup_bytes` (× the number of specializations).
    let arena = WeightArena::build(&graphs[3]);
    arena.init(&graphs[3], 42);
    let stores: Vec<TensorStore> =
        graphs.iter().map(|g| TensorStore::new_with_aliases(g, arena.aliases_for(g))).collect();
    let embed: Vec<*const f32> = graphs
        .iter()
        .zip(&stores)
        .map(|(g, s)| s.view(g.tensor_by_name("embed.weight").unwrap().id).as_ptr())
        .collect();
    assert!(embed.windows(2).all(|w| w[0] == w[1]), "weight arena failed to alias");
    assert_eq!(arena.init_runs(), 1);

    let dup_bytes: u64 = graphs.iter().map(|g| g.param_bytes()).sum();
    let shared_bytes = (arena.len() * 4) as u64;

    t.row(vec![
        "weight_arena: per-session init (legacy)".into(),
        format!("{per_session_ns} ns"),
        format!("{} sessions × private weight copies", graphs.len()),
    ]);
    t.row(vec![
        "weight_arena: shared-arena init".into(),
        format!("{shared_ns} ns"),
        "one synthesis, all sessions alias (ptr-asserted)".into(),
    ]);
    (per_session_ns, shared_ns, dup_bytes, shared_bytes)
}

/// The pool output boundary across its two generations: alloc-per-call
/// (`execute` replies with a fresh `Vec` the caller then copies into
/// the arena) vs write-into (`execute_into`: the executor scatters the
/// result straight into the caller's arena destination). Times the real
/// pool on `add_b1` on the default backend — the native CPU backend
/// runs everywhere, so the real path is the normal case now; the
/// synthetic store-primitive fallback (flagged `"mode": "synthetic"`)
/// survives only for builds where even that fails. Returns
/// `(alloc_per_call_ns, write_into_ns, mode/backend, into_path_output_allocs)`.
fn bench_exec_into(t: &mut Table) -> (u64, u64, &'static str, u64) {
    if let Ok(m) = Manifest::resolve(&Manifest::default_dir(), BackendKind::from_env()) {
        if let Ok(pool) = ExecPool::new(m, 1) {
            let backend = pool.backend_name();
            if let Some((idx, _)) = pool.manifest().find("add_b1") {
                let a = vec![1.5f32; 256];
                let b = vec![2.5f32; 256];
                let alloc_ns = bench_median_ns(20, 200, || {
                    let out = pool
                        .execute(idx, vec![Value::Borrowed(&a), Value::Borrowed(&b)])
                        .unwrap();
                    std::hint::black_box(&out);
                });
                let before = pool.output_allocs();
                let mut dst = vec![0.0f32; 256];
                let into_ns = bench_median_ns(20, 200, || {
                    pool.execute_into(
                        idx,
                        vec![Value::Borrowed(&a), Value::Borrowed(&b)],
                        &mut [OutView::from_slice(&mut dst)],
                    )
                    .unwrap();
                    std::hint::black_box(&dst);
                });
                let into_allocs = (pool.output_allocs() - before) as u64;
                assert_eq!(into_allocs, 0, "write-into boundary allocated output buffers");
                t.row(vec![
                    "exec_into: alloc-per-call (legacy execute)".into(),
                    format!("{alloc_ns} ns"),
                    "pool replies with a fresh Vec per output".into(),
                ]);
                t.row(vec![
                    "exec_into: write-into (execute_into)".into(),
                    format!("{into_ns} ns"),
                    "result lands in the caller's arena region".into(),
                ]);
                return (alloc_ns, into_ns, backend, into_allocs);
            }
        }
    }

    // offline: no artifacts/backend — time the boundary shapes on the
    // store. Destination is a strided matmul-style tile; "alloc" is
    // the legacy reply Vec + caller write_tile, "into" scatters the
    // same data through a held mutable view (what the executor thread
    // does on the caller's behalf).
    let rows = 8usize;
    let cols = 512usize;
    let tile = Region::new(vec![(0, rows), (128, 256)]);
    let src: Vec<f32> = (0..tile.numel()).map(|i| (i % 89) as f32).collect();
    let mut g = CompGraph::new();
    let w = g.input("out", vec![rows, cols], DType::F32);
    let store = TensorStore::new(&g);

    let alloc_ns = bench_median_ns(200, 2000, || {
        let out = src.to_vec(); // the reply allocation
        store.write_tile(w, &tile, &out); // the caller's copy-in
        std::hint::black_box(&out);
    });
    let into_ns = bench_median_ns(200, 2000, || {
        store.tile_mut(w, &tile).scatter_from(&src);
        std::hint::black_box(&store);
    });
    t.row(vec![
        "exec_into: alloc-per-call (synthetic)".into(),
        format!("{alloc_ns} ns"),
        "reply Vec + caller write_tile".into(),
    ]);
    t.row(vec![
        "exec_into: write-into (synthetic)".into(),
        format!("{into_ns} ns"),
        "direct scatter through a mutable arena view".into(),
    ]);
    (alloc_ns, into_ns, "synthetic", 0)
}

/// The step-API overhead: what one `ServeEngine::step()` call costs
/// beyond the kernel iteration it wraps (retire/admit, staging by slot,
/// harvest, event construction). Drives a real engine on the default
/// backend (the native CPU backend runs everywhere) and compares median
/// per-`step()` wall time to the median kernel iteration latency inside
/// it — the difference is the API's bookkeeping, which replaced the old
/// inlined `serve()` loop body. The scheduler-substrate fallback (no
/// kernel — `kernel_ns` reported as 0, flagged `"mode": "synthetic"`)
/// survives only for builds where even the CPU engine fails. Returns
/// `(step_ns, kernel_ns, mode/backend)`.
fn bench_step_overhead(t: &mut Table) -> (u64, u64, &'static str) {
    let median = |mut v: Vec<u64>| -> u64 {
        if v.is_empty() {
            return 0;
        }
        let mid = v.len() / 2;
        let (_, m, _) = v.select_nth_unstable(mid);
        *m
    };
    let engine = ServeEngine::builder()
        .max_batch(4)
        .pool_threads(2)
        .seed(42)
        .mega(mpk::megakernel::MegaConfig { workers: 4, schedulers: 1, ..Default::default() })
        .build();
    if let Ok(mut e) = engine {
        // warm-up wave (lazy artifact compiles, scratch growth).
        for i in 0..4u64 {
            e.submit(Request::new(i, vec![(i as i32) + 1, 3], 4)).unwrap();
        }
        while e.has_work() {
            e.step().unwrap();
        }
        let _ = e.take_stats();
        // measured wave: steady batch-4 decode, one step at a time.
        for i in 10..14u64 {
            e.submit(Request::new(i, vec![(i as i32) + 1, 5], 8)).unwrap();
        }
        let mut per_step = Vec::new();
        while e.has_work() {
            let t0 = std::time::Instant::now();
            e.step().unwrap();
            per_step.push(t0.elapsed().as_nanos() as u64);
        }
        let stats = e.take_stats();
        let step_ns = median(per_step);
        let kernel_ns = median(stats.iter_latencies.iter().map(|d| d.as_nanos() as u64).collect());
        t.row(vec![
            "step_overhead: step() call".into(),
            format!("{step_ns} ns"),
            "retire/admit + stage + kernel + harvest".into(),
        ]);
        t.row(vec![
            "step_overhead: kernel iteration".into(),
            format!("{kernel_ns} ns"),
            "resident megakernel re-arm inside step()".into(),
        ]);
        return (step_ns, kernel_ns, e.pool().backend_name());
    }

    // offline: the scheduler-side loop body alone — retire scan, graph
    // pick, slot staging into reused scratch — on a churning batcher.
    let mut b = Batcher::new(8, 62, KvAllocator::new(1024, 8));
    for i in 0..4u64 {
        b.submit(Request::new(i, vec![1, 2], 60)).unwrap();
    }
    b.step_admission();
    let mut ids = vec![0i32; 8];
    let mut lens = vec![0usize; 8];
    let ns = bench_median_ns(500, 5000, || {
        b.step_admission();
        let gb = b.graph_batch();
        ids[..gb].fill(0);
        lens[..gb].fill(0);
        for r in &b.active {
            let slot = r.slot.unwrap();
            ids[slot] = r.next_input();
            lens[slot] = r.cache_len;
        }
        std::hint::black_box((&ids, &lens));
    });
    t.row(vec![
        "step_overhead: scheduler body (synthetic)".into(),
        format!("{ns} ns"),
        "retire/admit + graph pick + slot staging, no kernel".into(),
    ]);
    (ns, 0, "synthetic")
}

/// The native CPU backend's kernels, per artifact op plus the fused
/// end-to-end decode step. Per-op timings drive a [`BackendSession`]
/// directly (no channel hops — the kernel alone); the end-to-end row
/// sends `ref_decode_b4` — a whole batch-4 decode iteration: embedding,
/// 4 transformer layers with GQA attention and KV append, final norm,
/// lm head — through a real [`ExecPool`], so it prices the full
/// protocol + numerics path serving takes per token. Inputs are seeded
/// deterministic fills shaped by the builtin manifest's signatures.
/// Returns `(per_op_rows, e2e_step_ns)`.
fn bench_cpu_backend(t: &mut Table) -> (Vec<(&'static str, u64)>, u64) {
    use mpk::runtime::backend::{backend, In};
    use std::sync::Arc;

    // deterministic in-range fills from the artifact signature: f32
    // small and varied, i32 all 1 (valid token id and cache length).
    let fill = |spec: &mpk::runtime::ArtifactSpec| -> (Vec<Vec<f32>>, Vec<Vec<i32>>, Vec<(bool, usize)>) {
        let mut f_bufs: Vec<Vec<f32>> = Vec::new();
        let mut i_bufs: Vec<Vec<i32>> = Vec::new();
        let mut kinds: Vec<(bool, usize)> = Vec::new();
        for (ai, a) in spec.inputs.iter().enumerate() {
            match a.ty {
                ArgType::F32 => {
                    f_bufs.push(
                        (0..a.numel()).map(|i| ((i * 31 + ai * 7) % 97) as f32 * 0.013 - 0.5).collect(),
                    );
                    kinds.push((true, f_bufs.len() - 1));
                }
                ArgType::I32 => {
                    i_bufs.push(vec![1; a.numel()]);
                    kinds.push((false, i_bufs.len() - 1));
                }
            }
        }
        (f_bufs, i_bufs, kinds)
    };

    let manifest = Arc::new(Manifest::builtin());
    let be = backend(BackendKind::Cpu);
    let mut sess = be.session(manifest.clone()).expect("cpu backend session");
    let ops = [
        "embed_b1",
        "rmsnorm_b1",
        "matmul_b1_k256_n128",
        "matmul_b1_k512_n128",
        "attn_q1",
        "add_b1",
        "swiglu_b1",
        "ref_decode_b1",
    ];
    let mut rows: Vec<(&'static str, u64)> = Vec::new();
    for name in ops {
        let (idx, spec) = manifest.find(name).expect("builtin artifact");
        let (f_bufs, i_bufs, kinds) = fill(spec);
        let inputs: Vec<In<'_>> = kinds
            .iter()
            .map(|&(f, i)| if f { In::F32(&f_bufs[i]) } else { In::I32(&i_bufs[i]) })
            .collect();
        // one allocating call sizes the destinations; the timed loop
        // then reuses them through the write-into path.
        let mut out_bufs: Vec<Vec<f32>> =
            sess.execute(idx, &inputs).expect("cpu execute").iter().map(|v| vec![0.0; v.len()]).collect();
        let ns = bench_median_ns(10, 100, || {
            let mut outs: Vec<OutView<'_>> =
                out_bufs.iter_mut().map(|b| OutView::from_slice(b)).collect();
            sess.execute_into(idx, &inputs, &mut outs).unwrap();
        });
        t.row(vec![
            format!("cpu_backend: {name}"),
            format!("{:.2} us", ns as f64 / 1e3),
            "native kernel, direct session".into(),
        ]);
        rows.push((name, ns));
    }

    // end to end: the fused batch-4 decode step through the pool.
    let pool = ExecPool::with_backend(Manifest::builtin(), 1, BackendKind::Cpu).expect("cpu pool");
    let (idx, spec) = pool.manifest().find("ref_decode_b4").expect("builtin artifact");
    let (f_bufs, i_bufs, kinds) = fill(spec);
    let mut out_bufs: Vec<Vec<f32>> =
        sess.execute(idx, &{
            kinds
                .iter()
                .map(|&(f, i)| if f { In::F32(&f_bufs[i]) } else { In::I32(&i_bufs[i]) })
                .collect::<Vec<In<'_>>>()
        })
        .expect("cpu execute")
        .iter()
        .map(|v| vec![0.0; v.len()])
        .collect();
    let e2e_ns = bench_median_ns(5, 50, || {
        let inputs: Vec<Value<'_>> = kinds
            .iter()
            .map(|&(f, i)| {
                if f {
                    Value::Borrowed(&f_bufs[i])
                } else {
                    Value::BorrowedI32(&i_bufs[i])
                }
            })
            .collect();
        let mut outs: Vec<OutView<'_>> =
            out_bufs.iter_mut().map(|b| OutView::from_slice(b)).collect();
        pool.execute_into(idx, inputs, &mut outs).unwrap();
    });
    assert_eq!(pool.output_allocs(), 0, "cpu decode step allocated output buffers");
    t.row(vec![
        "cpu_backend: decode step e2e (b4)".into(),
        format!("{:.2} us", e2e_ns as f64 / 1e3),
        "ref_decode_b4 through the pool protocol".into(),
    ]);
    (rows, e2e_ns)
}

/// A [`MockEngine`] with wall-clock step time, so the server front-end
/// actually saturates: the instant mock drains any burst before the
/// wait queue can fill, which would make the overload path unmeasurable.
struct SlowStep {
    inner: MockEngine,
    delay: std::time::Duration,
}

impl StepEngine for SlowStep {
    fn submit(&mut self, r: Request) -> Result<(), EngineError> {
        self.inner.submit(r)
    }
    fn validate(&self, r: &Request) -> Result<(), EngineError> {
        self.inner.validate(r)
    }
    fn terminate(&mut self, id: u64, reason: FinishReason) -> Result<(), EngineError> {
        self.inner.terminate(id, reason)
    }
    fn step(&mut self) -> Result<StepOutcome, EngineError> {
        std::thread::sleep(self.delay);
        self.inner.step()
    }
    fn has_work(&self) -> bool {
        self.inner.has_work()
    }
    fn capacity(&self) -> usize {
        self.inner.capacity()
    }
    fn in_flight(&self) -> usize {
        self.inner.in_flight()
    }
    fn take_finished(&mut self) -> Vec<Request> {
        self.inner.take_finished()
    }
    fn take_stats(&mut self) -> ServeStats {
        self.inner.take_stats()
    }
}

/// Serving front-end under saturation: a burst of 2× system capacity
/// (slots + wait queue) against a deliberately slow engine, measuring
/// what overload control costs the *client* — the latency of the
/// admission decision RPC (accept / displace / refuse, all synchronous)
/// — and how the excess load resolves (displacement `Shed` terminals
/// plus typed `Overloaded` refusals). Backend-free by construction, so
/// the numbers track the front-end, not the kernel. Returns
/// `(admission_p50_ns, admission_max_ns, accepted, shed, rejected)`.
fn bench_saturation(t: &mut Table) -> (u64, u64, u64, u64, u64) {
    use std::time::{Duration, Instant};
    let capacity = 8usize;
    let queue_depth = 8usize;
    let offered = 2 * (capacity + queue_depth);
    let server = ServeServer::spawn_with(
        SlowStep { inner: MockEngine::new(capacity), delay: Duration::from_micros(500) },
        ServerConfig { queue_depth, idle_poll: Duration::from_micros(200) },
    );
    let client = server.client();
    let mut lat = Vec::with_capacity(offered);
    let mut streams = Vec::new();
    let mut rejected = 0u64;
    for i in 0..offered {
        let opts = SubmitOptions {
            priority: if i % 2 == 0 { Priority::Interactive } else { Priority::Batch },
            deadline: None,
        };
        let t0 = Instant::now();
        let res = client.submit_with(Request::new(i as u64, vec![1, 2], 8), opts);
        lat.push(t0.elapsed().as_nanos() as u64);
        match res {
            Ok(s) => streams.push(s),
            Err(EngineError::Overloaded { .. }) => rejected += 1,
            Err(e) => panic!("saturation burst hit a non-overload refusal: {e}"),
        }
    }
    let accepted = streams.len() as u64;
    let mut shed = 0u64;
    for s in streams {
        let (_, finish) = s.collect_output();
        assert!(finish.is_some(), "accepted request lost its terminal event");
        if finish == Some(FinishReason::Shed) {
            shed += 1;
        }
    }
    let report = server.shutdown();
    assert_eq!(report.finished as u64, accepted, "terminal deliveries must match acceptances");
    lat.sort_unstable();
    let p50 = lat[lat.len() / 2];
    let max = *lat.last().unwrap();
    t.row(vec![
        "saturation: admission decision".into(),
        format!("{p50} ns"),
        format!("accept/displace/refuse RPC at 2x capacity (max {max} ns)"),
    ]);
    t.row(vec![
        "saturation: load resolution".into(),
        format!("{:.2} shed+reject rate", (shed + rejected) as f64 / offered as f64),
        format!("{accepted} accepted / {shed} shed / {rejected} refused of {offered}"),
    ]);
    (p50, max, accepted, shed, rejected)
}

/// The TCP transport boundary: what the wire layer adds on top of the
/// in-process server RPC. A loopback [`ServeTransport`] over the
/// instant mock, measured two ways — the median full request round
/// trip (submit frame → Accepted → terminal Finish, crossing encode,
/// two socket hops, the reader/pump/writer threads, and decode) and
/// sustained streaming frame throughput on one long request. Returns
/// `(round_trip_ns, stream_frames, frames_per_s)`.
fn bench_transport(t: &mut Table) -> (u64, u64, u64) {
    use std::time::{Duration, Instant};
    let server = ServeServer::spawn_with(
        MockEngine::new(4),
        ServerConfig { queue_depth: 64, idle_poll: Duration::from_micros(200) },
    );
    let transport = ServeTransport::bind("127.0.0.1:0", server, TransportConfig::default())
        .expect("bind loopback");
    let mut client = TransportClient::connect(transport.local_addr()).expect("connect loopback");

    // round trip: one-token requests, each driven to its terminal
    // frame before the next begins (admission + stream + teardown of a
    // whole request, not just a socket ping).
    let mut next_id = 0u64;
    let round_trip_ns = bench_median_ns(20, 200, || {
        next_id += 1;
        let (_, finish) = client
            .run(next_id, vec![1], 1, SubmitOptions::default())
            .expect("loopback round trip");
        assert_eq!(finish, FinishReason::MaxTokens);
    });

    // streaming throughput: one long request, frames counted from the
    // submit write to the terminal frame.
    next_id += 1;
    let budget = 500u64;
    let t0 = Instant::now();
    let (tokens, finish) = client
        .run(next_id, vec![1], budget as u32, SubmitOptions::default())
        .expect("loopback stream");
    let elapsed = t0.elapsed();
    assert_eq!(finish, FinishReason::MaxTokens);
    assert_eq!(tokens.len() as u64, budget, "stream must deliver the full budget");
    let stream_frames = budget + 1; // Accepted + budget-1 Token + Finish
    let frames_per_s = (stream_frames as f64 / elapsed.as_secs_f64().max(1e-9)) as u64;

    let report = transport.drain(Duration::from_secs(5));
    assert!(report.server.fatal.is_none(), "transport bench left the server dead");
    assert_eq!(
        report.server.finished,
        report.transport.requests_submitted as usize,
        "transport bench left unreconciled requests"
    );

    t.row(vec![
        "transport: request round trip".into(),
        format!("{round_trip_ns} ns"),
        "submit frame -> Accepted -> Finish over loopback TCP".into(),
    ]);
    t.row(vec![
        "transport: streaming throughput".into(),
        format!("{frames_per_s} frames/s"),
        format!("{stream_frames} frames on one stream"),
    ]);
    (round_trip_ns, stream_frames, frames_per_s)
}

/// The paged-KV hot paths (two levels). Pool level: what admission
/// costs cold (every block freshly allocated and hashed) vs through the
/// prefix index (every whole prompt block already resident — refcount
/// bumps and table writes only). Engine level: what block-table
/// indirection adds to a steady decode step — the same wave through
/// the same CPU-backend engine, paged vs slot-contiguous, with the
/// token streams asserted identical so the comparison is honest.
/// Returns `(cold_admit_ns, prefix_admit_ns, paged_step_ns,
/// legacy_step_ns, shared_blocks_per_hit)`.
fn bench_paged_kv(t: &mut Table) -> (u64, u64, u64, u64, u64) {
    use mpk::serving::{KvArena, PagedKvPool};

    // pool level: 2 layers x 32 slots x 64 rows of 32 elements,
    // 8-token blocks -> 256 blocks; a 32-token prompt spans 4.
    let arena = KvArena::new(2, 32, 64, 32);
    let prompt: Vec<i32> = (0..32).map(|i| (i % 50) + 1).collect();

    let mut pool = PagedKvPool::over(&arena, 8);
    let mut id = 0u64;
    let cold_ns = bench_median_ns(200, 2000, || {
        id += 1;
        let adm = pool.admit(id, &prompt).expect("pool has room");
        assert_eq!(adm.shared_blocks, 0, "cold admission found a prefix");
        pool.release(id);
    });

    // publish the prompt's blocks once, then every admission maps them.
    let mut pool = PagedKvPool::over(&arena, 8);
    pool.admit(1, &prompt).expect("pool has room");
    pool.promote(1, &prompt, prompt.len());
    let mut id = 1u64;
    let mut shared_per_hit = 0u64;
    let hit_ns = bench_median_ns(200, 2000, || {
        id += 1;
        let adm = pool.admit(id, &prompt).expect("pool has room");
        assert!(adm.shared_blocks > 0, "prefix index missed a published prompt");
        shared_per_hit = adm.shared_blocks as u64;
        pool.release(id);
    });
    pool.check_invariants().expect("pool invariants after admission churn");

    // engine level: identical wave, paged vs contiguous, CPU backend.
    let run = |paged: bool| -> (u64, Vec<(u64, Option<i32>)>) {
        let mut e = ServeEngine::builder()
            .max_batch(4)
            .pool_threads(2)
            .seed(42)
            .mega(mpk::megakernel::MegaConfig { workers: 4, schedulers: 1, ..Default::default() })
            .backend(BackendKind::Cpu)
            .paged_kv(paged)
            .build()
            .expect("cpu engine (no artifacts needed)");
        // warm-up wave (lazy compiles, scratch growth).
        for i in 0..4u64 {
            e.submit(Request::new(i, vec![(i as i32) + 1, 3], 4)).unwrap();
        }
        while e.has_work() {
            e.step().unwrap();
        }
        let _ = e.take_stats();
        // measured wave: steady batch-4 decode.
        for i in 10..14u64 {
            e.submit(Request::new(i, vec![(i as i32) + 1, 5], 8)).unwrap();
        }
        let mut per_step = Vec::new();
        let mut events = Vec::new();
        while e.has_work() {
            let t0 = std::time::Instant::now();
            let out = e.step().unwrap();
            per_step.push(t0.elapsed().as_nanos() as u64);
            events.extend(out.events.into_iter().map(|ev| (ev.request, ev.token)));
        }
        per_step.sort_unstable();
        (per_step[per_step.len() / 2], events)
    };
    let (legacy_step_ns, legacy_events) = run(false);
    let (paged_step_ns, paged_events) = run(true);
    assert_eq!(paged_events, legacy_events, "paged decode diverged from contiguous decode");

    t.row(vec![
        "paged_kv: cold admission".into(),
        format!("{cold_ns} ns"),
        "4 fresh blocks allocated + hashed per admit".into(),
    ]);
    t.row(vec![
        "paged_kv: prefix-hit admission".into(),
        format!("{hit_ns} ns"),
        format!("{shared_per_hit} blocks mapped from the prefix index"),
    ]);
    t.row(vec![
        "paged_kv: decode step (contiguous)".into(),
        format!("{legacy_step_ns} ns"),
        "slot-contiguous KV, CPU backend".into(),
    ]);
    t.row(vec![
        "paged_kv: decode step (paged)".into(),
        format!("{paged_step_ns} ns"),
        "block-table indirection, token streams asserted equal".into(),
    ]);
    (cold_ns, hit_ns, paged_step_ns, legacy_step_ns, shared_per_hit)
}

fn main() {
    println!("== hot-path microbenchmarks (median ns unless noted) ==\n");
    let mut t = Table::new(&["benchmark", "median", "note"]);

    let (clone_ns, read_ns, view_ns, view_allocs) = bench_store_hotpath(&mut t);
    let (per_session_ns, shared_ns, dup_bytes, shared_bytes) = bench_weight_arena(&mut t);
    let (exec_alloc_ns, exec_into_ns, exec_mode, exec_into_allocs) = bench_exec_into(&mut t);
    let (step_ns, kernel_ns, step_mode) = bench_step_overhead(&mut t);
    let (cpu_rows, cpu_e2e_ns) = bench_cpu_backend(&mut t);
    let (sat_p50, sat_max, sat_accepted, sat_shed, sat_rejected) = bench_saturation(&mut t);
    let (wire_rt_ns, wire_frames, wire_fps) = bench_transport(&mut t);
    let (paged_cold_ns, paged_hit_ns, paged_step_ns, paged_legacy_ns, paged_shared) =
        bench_paged_kv(&mut t);

    // queue push+pop round trip
    let q: MpmcQueue<usize> = MpmcQueue::new(1024);
    let ns = bench_median_ns(1000, 20000, || {
        q.push(1).unwrap();
        std::hint::black_box(q.pop());
    });
    t.row(vec!["MpmcQueue push+pop".into(), format!("{ns} ns"), "per task dispatch".into()]);

    // event notify
    let ev = EventTable::new(&[u32::MAX as usize]);
    let ns = bench_median_ns(1000, 20000, || {
        std::hint::black_box(ev.notify(0));
    });
    t.row(vec!["EventTable notify".into(), format!("{ns} ns"), "atomicAdd analogue".into()]);

    // compiler stages on Qwen3-1.7B
    let cfg = ModelConfig::qwen3_1_7b();
    let gpu = GpuSpec::b200();
    let g = build_decode_graph(&cfg, &GraphOptions { batch: 1, kv_len: 512, ..Default::default() });
    let dc = DecomposeConfig { target_tasks: gpu.workers, min_tile_cols: 8 };

    let ns = bench_median_ns(1, 5, || {
        std::hint::black_box(decompose(&g, &dc));
    });
    t.row(vec!["decompose (1.7B)".into(), format!("{:.2} ms", ns as f64 / 1e6), "per graph".into()]);

    let d = decompose(&g, &dc);
    let ns = bench_median_ns(1, 5, || {
        std::hint::black_box(analyze_deps(&g, &d));
    });
    t.row(vec!["dependency analysis (1.7B)".into(), format!("{:.2} ms", ns as f64 / 1e6), "pairwise overlap".into()]);

    let ns = bench_median_ns(1, 5, || {
        std::hint::black_box(compile(&g, &CompileOptions { decompose: dc, ..Default::default() }));
    });
    t.row(vec!["full compile (1.7B)".into(), format!("{:.2} ms", ns as f64 / 1e6), "all stages".into()]);

    // static verifier cost vs task count (BENCH_verify.json): the
    // compile gate re-derives every footprint and closes the
    // happens-before relation with reachability bitsets, so its wall
    // time must stay visible as a function of graph scale.
    let mut verify_rows: Vec<(usize, usize, usize, u64)> = Vec::new();
    for target in [gpu.workers / 2, gpu.workers, gpu.workers * 2] {
        let dcv = DecomposeConfig { target_tasks: target, min_tile_cols: 8 };
        let cv = compile(&g, &CompileOptions { decompose: dcv, verify: false, ..Default::default() });
        let rep = verify_compiled(&cv);
        assert!(rep.is_clean(), "verifier flagged the 1.7B graph:\n{}", rep.render(8));
        let vtasks = cv.tgraph.tasks.len();
        let ns = bench_median_ns(1, 3, || {
            std::hint::black_box(verify_compiled(&cv));
        });
        t.row(vec![
            format!("static verify (1.7B, {vtasks} tasks)"),
            format!("{:.2} ms", ns as f64 / 1e6),
            format!("{} region pairs, {} hb edges", rep.region_pairs, rep.hb_edges),
        ]);
        verify_rows.push((vtasks, rep.region_pairs, rep.hb_edges, ns));
    }

    // DES throughput
    let c = compile(&g, &CompileOptions { decompose: dc, ..Default::default() });
    let ns = bench_median_ns(1, 5, || {
        std::hint::black_box(simulate_megakernel(&c, &gpu, &SimOptions::default()));
    });
    let tasks = c.tgraph.tasks.len();
    t.row(vec![
        "DES replay (1.7B)".into(),
        format!("{:.2} ms", ns as f64 / 1e6),
        format!("{:.0} ktasks/s", tasks as f64 / (ns as f64 / 1e9) / 1000.0),
    ]);

    // threaded megakernel dispatch-only throughput (no-op tasks):
    // scoped (spawn/join per run) vs persistent (parked threads).
    let tiny = ModelConfig::tiny();
    let gt = build_decode_graph(&tiny, &GraphOptions { batch: 4, kv_len: 16, ..Default::default() });
    let ct = std::sync::Arc::new(compile(&gt, &CompileOptions { decompose: DecomposeConfig { target_tasks: 16, min_tile_cols: 8 }, ..Default::default() }));
    let kcfg = mpk::megakernel::MegaConfig { workers: 4, schedulers: 1, ..Default::default() };
    let nt = ct.tgraph.tasks.len();
    let mk = mpk::megakernel::MegaKernel::new(&ct, kcfg);
    let ns = bench_median_ns(2, 10, || {
        mk.run(&|_: &mpk::tgraph::TaskDesc| {}).unwrap();
    });
    t.row(vec![
        "scoped megakernel (no-op tasks)".into(),
        format!("{:.2} ms", ns as f64 / 1e6),
        format!("{} tasks, {:.0} ns/task", nt, ns as f64 / nt as f64),
    ]);
    let mut pk = mpk::megakernel::PersistentMegaKernel::new(ct.clone(), kcfg);
    let ns = bench_median_ns(2, 10, || {
        pk.run(&|_: &mpk::tgraph::TaskDesc| {}).unwrap();
    });
    t.row(vec![
        "persistent megakernel (no-op tasks)".into(),
        format!("{:.2} ms", ns as f64 / 1e6),
        format!("{} tasks, {:.0} ns/task", nt, ns as f64 / nt as f64),
    ]);

    println!("{}", t.render());

    // perf-trajectory record for CI (scripts/tier1.sh): the storage-
    // layer read path across its three generations.
    let json_path = std::env::var("MPK_BENCH_STORE_JSON")
        .unwrap_or_else(|_| "BENCH_store_hotpath.json".to_string());
    let json = format!(
        "{{\n  \"bench\": \"store_hotpath\",\n  \"locked_clone_ns\": {clone_ns},\n  \
         \"arena_read_tile_ns\": {read_ns},\n  \"arena_borrowed_view_ns\": {view_ns},\n  \
         \"borrowed_view_store_allocs\": {view_allocs},\n  \
         \"view_speedup_vs_locked_clone\": {:.4}\n}}\n",
        clone_ns as f64 / view_ns.max(1) as f64
    );
    match std::fs::write(&json_path, json) {
        Ok(()) => println!("\nwrote {json_path}"),
        Err(e) => eprintln!("\ncould not write {json_path}: {e}"),
    }

    // weight-arena record: serving weight memory and init cost across
    // batch-size specializations, duplicated vs shared.
    let weight_json_path = std::env::var("MPK_BENCH_WEIGHT_JSON")
        .unwrap_or_else(|_| "BENCH_weight_arena.json".to_string());
    let weight_json = format!(
        "{{\n  \"bench\": \"weight_arena\",\n  \"sessions\": 4,\n  \
         \"per_session_init_ns\": {per_session_ns},\n  \"shared_arena_init_ns\": {shared_ns},\n  \
         \"duplicated_weight_bytes\": {dup_bytes},\n  \"shared_weight_bytes\": {shared_bytes},\n  \
         \"memory_reduction\": {:.4},\n  \"init_speedup\": {:.4}\n}}\n",
        dup_bytes as f64 / shared_bytes.max(1) as f64,
        per_session_ns as f64 / shared_ns.max(1) as f64
    );
    match std::fs::write(&weight_json_path, weight_json) {
        Ok(()) => println!("wrote {weight_json_path}"),
        Err(e) => eprintln!("could not write {weight_json_path}: {e}"),
    }

    // pool-output-boundary record: alloc-per-call vs write-into. `mode`
    // doubles as the backend identity ("cpu"/"pjrt") when the real pool
    // ran; "synthetic" marks the offline store-primitive fallback.
    let exec_json_path = std::env::var("MPK_BENCH_EXEC_INTO_JSON")
        .unwrap_or_else(|_| "BENCH_exec_into.json".to_string());
    let exec_json = format!(
        "{{\n  \"bench\": \"exec_into\",\n  \"mode\": \"{exec_mode}\",\n  \
         \"backend\": \"{exec_mode}\",\n  \
         \"alloc_per_call_ns\": {exec_alloc_ns},\n  \"write_into_ns\": {exec_into_ns},\n  \
         \"into_path_output_allocs\": {exec_into_allocs},\n  \
         \"write_into_speedup\": {:.4}\n}}\n",
        exec_alloc_ns as f64 / exec_into_ns.max(1) as f64
    );
    match std::fs::write(&exec_json_path, exec_json) {
        Ok(()) => println!("wrote {exec_json_path}"),
        Err(e) => eprintln!("could not write {exec_json_path}: {e}"),
    }

    // step-API record: per-`step()` cost vs the kernel iteration inside
    // it (the difference is the serving API's bookkeeping, which
    // replaced the inlined serve() loop body). `mode` says whether a
    // real engine ran or the offline scheduler-only boundary.
    let step_json_path = std::env::var("MPK_BENCH_STEP_JSON")
        .unwrap_or_else(|_| "BENCH_step_overhead.json".to_string());
    let step_json = format!(
        "{{\n  \"bench\": \"step_overhead\",\n  \"mode\": \"{step_mode}\",\n  \
         \"backend\": \"{step_mode}\",\n  \
         \"step_ns\": {step_ns},\n  \"kernel_iter_ns\": {kernel_ns},\n  \
         \"api_overhead_ns\": {}\n}}\n",
        step_ns.saturating_sub(kernel_ns)
    );
    match std::fs::write(&step_json_path, step_json) {
        Ok(()) => println!("wrote {step_json_path}"),
        Err(e) => eprintln!("could not write {step_json_path}: {e}"),
    }

    // native-CPU-backend record: per-op kernel latency plus the fused
    // batch-4 decode step through the full pool protocol.
    let cpu_json_path = std::env::var("MPK_BENCH_CPU_JSON")
        .unwrap_or_else(|_| "BENCH_cpu_backend.json".to_string());
    let op_rows: Vec<String> = cpu_rows
        .iter()
        .map(|(op, ns)| format!("    {{ \"op\": \"{op}\", \"ns\": {ns} }}"))
        .collect();
    let cpu_json = format!(
        "{{\n  \"bench\": \"cpu_backend\",\n  \"backend\": \"cpu\",\n  \"ops\": [\n{}\n  ],\n  \
         \"e2e_decode_step_b4_ns\": {cpu_e2e_ns}\n}}\n",
        op_rows.join(",\n")
    );
    match std::fs::write(&cpu_json_path, cpu_json) {
        Ok(()) => println!("wrote {cpu_json_path}"),
        Err(e) => eprintln!("could not write {cpu_json_path}: {e}"),
    }

    // saturation record: admission-decision latency and shed rate when
    // the serving front-end is offered 2x system capacity (slots +
    // bounded wait queue). Backend-free: tracks the overload-control
    // front-end across PRs, not the kernel.
    let sat_json_path = std::env::var("MPK_BENCH_SATURATION_JSON")
        .unwrap_or_else(|_| "BENCH_saturation.json".to_string());
    let sat_offered = sat_accepted + sat_rejected;
    let sat_json = format!(
        "{{\n  \"bench\": \"saturation\",\n  \"offered\": {sat_offered},\n  \
         \"capacity\": 8,\n  \"queue_depth\": 8,\n  \
         \"admission_p50_ns\": {sat_p50},\n  \"admission_max_ns\": {sat_max},\n  \
         \"accepted\": {sat_accepted},\n  \"shed\": {sat_shed},\n  \
         \"rejected\": {sat_rejected},\n  \"shed_rate\": {:.4}\n}}\n",
        (sat_shed + sat_rejected) as f64 / sat_offered.max(1) as f64
    );
    match std::fs::write(&sat_json_path, sat_json) {
        Ok(()) => println!("wrote {sat_json_path}"),
        Err(e) => eprintln!("could not write {sat_json_path}: {e}"),
    }

    // transport record: what the wire layer costs over the in-process
    // RPC — loopback round-trip latency and streaming frame throughput.
    // Backend-free (mock engine): tracks the transport across PRs.
    let wire_json_path = std::env::var("MPK_BENCH_TRANSPORT_JSON")
        .unwrap_or_else(|_| "BENCH_transport.json".to_string());
    let wire_json = format!(
        "{{\n  \"bench\": \"transport\",\n  \"round_trip_p50_ns\": {wire_rt_ns},\n  \
         \"stream_frames\": {wire_frames},\n  \"stream_frames_per_s\": {wire_fps}\n}}\n"
    );
    match std::fs::write(&wire_json_path, wire_json) {
        Ok(()) => println!("wrote {wire_json_path}"),
        Err(e) => eprintln!("could not write {wire_json_path}: {e}"),
    }

    // paged-KV record: admission cost cold vs through the prefix index,
    // and the decode-step price of block-table indirection vs the
    // slot-contiguous layout (token streams asserted identical).
    let paged_json_path = std::env::var("MPK_BENCH_PAGED_JSON")
        .unwrap_or_else(|_| "BENCH_paged_kv.json".to_string());
    let paged_json = format!(
        "{{\n  \"bench\": \"paged_kv\",\n  \"backend\": \"cpu\",\n  \
         \"cold_admit_ns\": {paged_cold_ns},\n  \"prefix_admit_ns\": {paged_hit_ns},\n  \
         \"shared_blocks_per_hit\": {paged_shared},\n  \
         \"decode_step_contiguous_ns\": {paged_legacy_ns},\n  \
         \"decode_step_paged_ns\": {paged_step_ns},\n  \
         \"prefix_admit_speedup\": {:.4},\n  \"indirection_overhead\": {:.4}\n}}\n",
        paged_cold_ns as f64 / paged_hit_ns.max(1) as f64,
        paged_step_ns as f64 / paged_legacy_ns.max(1) as f64
    );
    match std::fs::write(&paged_json_path, paged_json) {
        Ok(()) => println!("wrote {paged_json_path}"),
        Err(e) => eprintln!("could not write {paged_json_path}: {e}"),
    }

    // verifier-cost record: static race/deadlock verification wall time
    // vs task count on the 1.7B decode graph, so the compile gate's
    // price stays visible across PRs.
    let verify_json_path = std::env::var("MPK_BENCH_VERIFY_JSON")
        .unwrap_or_else(|_| "BENCH_verify.json".to_string());
    let scale_rows: Vec<String> = verify_rows
        .iter()
        .map(|(tasks, pairs, hb, ns)| {
            format!(
                "    {{ \"tasks\": {tasks}, \"region_pairs\": {pairs}, \
                 \"hb_edges\": {hb}, \"verify_ns\": {ns} }}"
            )
        })
        .collect();
    let verify_json = format!(
        "{{\n  \"bench\": \"verify\",\n  \"model\": \"Qwen3-1.7B\",\n  \"scales\": [\n{}\n  ]\n}}\n",
        scale_rows.join(",\n")
    );
    match std::fs::write(&verify_json_path, verify_json) {
        Ok(()) => println!("wrote {verify_json_path}"),
        Err(e) => eprintln!("could not write {verify_json_path}: {e}"),
    }
}
