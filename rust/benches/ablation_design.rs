//! Design-choice ablations called out in DESIGN.md:
//!
//! 1. scheduling policy — decentralized (paper default) vs globally
//!    coordinated (§6.1 names this as the alternative the runtime could
//!    support); 2. event fusion on/off (what Table 2's "Fusion" column
//!    buys at runtime); 3. task-granularity sweep (tasks ∝ SMs is the
//!    paper's default — what happens at 0.5× / 2× / 4×?).

use mpk::models::{build_decode_graph, GraphOptions, ModelConfig};
use mpk::sim::engine::SchedPolicy;
use mpk::sim::{simulate_megakernel, GpuSpec, SimOptions};
use mpk::tgraph::{compile, CompileOptions, DecomposeConfig};
use mpk::util::Table;

fn main() {
    let gpu = GpuSpec::b200();
    let cfg = ModelConfig::qwen3_1_7b();
    let g = build_decode_graph(&cfg, &GraphOptions { batch: 4, kv_len: 512, ..Default::default() });
    let mk = |target: usize, fuse: bool| {
        compile(
            &g,
            &CompileOptions {
                decompose: DecomposeConfig { target_tasks: target, min_tile_cols: 8 },
                fuse,
                ..Default::default()
            },
        )
    };

    println!("== ablation 1: scheduling policy (Qwen3-1.7B, batch 4, B200) ==\n");
    let c = mk(gpu.workers, true);
    let mut t = Table::new(&["policy", "makespan µs", "vs decentralized"]);
    let dec = simulate_megakernel(&c, &gpu, &SimOptions::default()).makespan_us;
    let glob = simulate_megakernel(
        &c,
        &gpu,
        &SimOptions { policy: SchedPolicy::GlobalQueue, ..Default::default() },
    )
    .makespan_us;
    t.row(vec!["decentralized (paper)".into(), format!("{dec:.0}"), "1.00x".into()]);
    t.row(vec!["global queue".into(), format!("{glob:.0}"), format!("{:.2}x", glob / dec)]);
    println!("{}", t.render());
    println!("the paper's decentralized choice avoids the serialized grant path;");
    println!("with ~{} tasks a single coordinator becomes the bottleneck.\n", c.tgraph.tasks.len());

    println!("== ablation 2: event fusion on/off ==\n");
    let mut t = Table::new(&["fusion", "events", "makespan µs"]);
    for (label, fuse) in [("on (paper)", true), ("off", false)] {
        let c = mk(gpu.workers, fuse);
        let r = simulate_megakernel(&c, &gpu, &SimOptions::default());
        t.row(vec![label.into(), c.stats().events.to_string(), format!("{:.0}", r.makespan_us)]);
    }
    println!("{}", t.render());
    println!("fusion mainly shrinks synchronization state (Table 2); the");
    println!("schedule itself is dependency-equivalent, so makespans are close.\n");

    println!("== ablation 3: task-granularity sweep (tasks per op vs workers) ==\n");
    let mut t = Table::new(&["target tasks/op", "makespan µs", "utilization"]);
    for mult in [0.5f64, 1.0, 2.0, 4.0] {
        let target = ((gpu.workers as f64) * mult) as usize;
        let c = mk(target.max(1), true);
        let r = simulate_megakernel(&c, &gpu, &SimOptions::default());
        t.row(vec![
            format!("{:.1}x workers", mult),
            format!("{:.0}", r.makespan_us),
            format!("{:.2}", r.utilization),
        ]);
    }
    println!("{}", t.render());
    println!("tasks ∝ SMs (1x) balances decomposition overhead against load");
    println!("balance — the paper's default; 0.5x starves workers, 4x pays");
    println!("per-task dispatch without improving balance much.");
}
