//! Table 2: per-compiler-stage statistics on B200 — operators, tasks per
//! operator, final events, event-fusion reduction, linearization
//! footprint reduction; plus the §4.1 normalization-overhead claim
//! (< 1 %) and the unfused-QKV variant that exercises fork/join
//! normalization.

use mpk::models::{build_decode_graph, GraphOptions, ModelConfig};
use mpk::sim::GpuSpec;
use mpk::tgraph::{compile, compile_verified, CompileOptions, DecomposeConfig};
use mpk::util::Table;

fn main() {
    println!("== Table 2: compiler-stage statistics (B200, batch 1) ==\n");
    let gpu = GpuSpec::b200();
    let mut t = Table::new(&[
        "model", "Ops", "Tasks/op", "Events", "Fusion", "Lin.", "NormOvhd", "VPairs", "HbEdges",
        "Verify",
    ]);
    for cfg in [ModelConfig::qwen3_1_7b(), ModelConfig::qwen3_8b(), ModelConfig::qwen3_30b_a3b()] {
        let g = build_decode_graph(&cfg, &GraphOptions { batch: 1, kv_len: 512, ..Default::default() });
        // verification forced on (even in release) so the Table-2 row
        // includes the new stage's coverage and cost columns.
        let (c, report) = compile_verified(
            &g,
            &CompileOptions {
                decompose: DecomposeConfig { target_tasks: gpu.workers, min_tile_cols: 8 },
                ..Default::default()
            },
        );
        assert!(report.is_clean(), "{}: {}", cfg.name, report.render(8));
        let s = c.stats();
        t.row(vec![
            cfg.name.to_string(),
            s.ops.to_string(),
            format!("{:.1}", s.tasks_per_op),
            s.events.to_string(),
            format!("{:.0}x", s.fusion_reduction),
            format!("{:.1}x", s.lin_reduction),
            format!("{:.2}%", s.norm_overhead * 100.0),
            s.verify_pairs.to_string(),
            s.verify_hb_edges.to_string(),
            format!("{:.1} ms", s.verify_us as f64 / 1000.0),
        ]);
    }
    println!("{}", t.render());
    println!("paper (B200): Qwen3-1.7B 229 ops / 35.6 tasks-op / 1,870 ev / 37x / 4.4x");
    println!("              Qwen3-8B   293 ops / 47.3 tasks-op / 2,366 ev / 68x / 5.9x");
    println!("              Qwen3-30B  533 ops / 32.2 tasks-op / 1,142 ev / 118x / 15.0x");
    println!("              normalization overhead always < 1% (fused QKV → no forks)\n");

    // §6.7: normalization is exercised only when parallel branches exist.
    println!("== normalization fork/join check (unfused QKV variant) ==");
    let mut t2 = Table::new(&["variant", "dummy tasks", "overhead"]);
    for (label, unfused) in [("fused QKV (deep)", false), ("unfused QKV (wide)", true)] {
        let cfg = ModelConfig::qwen3_1_7b();
        let g = build_decode_graph(
            &cfg,
            &GraphOptions { batch: 1, kv_len: 512, unfused_qkv: unfused, ..Default::default() },
        );
        let c = compile(
            &g,
            &CompileOptions {
                decompose: DecomposeConfig { target_tasks: gpu.workers, min_tile_cols: 8 },
                ..Default::default()
            },
        );
        let s = c.stats();
        t2.row(vec![
            label.to_string(),
            s.dummy_tasks.to_string(),
            format!("{:.2}%", s.norm_overhead * 100.0),
        ]);
    }
    println!("{}", t2.render());
}
