//! Figure 10: MoE execution strategies — Qwen3-30B-A3B on B200,
//! batch 1–16. MPK-Hybrid vs MPK-Static vs fully-dynamic vs SGLang-MoE.
//! Values are one MoE block's runtime in µs (lower is better); the
//! speedup column is MPK-Hybrid over SGLang-MoE as in the paper.

use mpk::models::ModelConfig;
use mpk::moe::{dynamic_us, hybrid_us, route, sglang_us, static_partition_us, Skew};
use mpk::sim::GpuSpec;
use mpk::util::Table;

fn main() {
    println!("== Figure 10: MoE runtime per block (µs), Qwen3-30B-A3B on B200 ==\n");
    let cfg = ModelConfig::qwen3_30b_a3b();
    let moe = cfg.moe.unwrap();
    let gpu = GpuSpec::b200();
    for (label, skew) in [("skewed routing (Zipf 1.2)", Skew::Zipf(1.2)), ("uniform routing", Skew::Uniform)] {
        let mut t = Table::new(&["batch", "MPK-Hybrid", "MPK-Static", "Dynamic", "SGLang-MoE", "speedup"]);
        for b in [1usize, 2, 4, 8, 16] {
            let r = route(b, moe.num_experts, moe.top_k, skew, 7 + b as u64);
            let hy = hybrid_us(&moe, cfg.d_model, &r, &gpu).us;
            let st = static_partition_us(&moe, cfg.d_model, &r, &gpu, 16).us;
            let dy = dynamic_us(&moe, cfg.d_model, &r, &gpu).us;
            let sg = sglang_us(&moe, cfg.d_model, &r, &gpu).us;
            t.row(vec![
                b.to_string(),
                format!("{hy:.1}"),
                format!("{st:.1}"),
                format!("{dy:.1}"),
                format!("{sg:.1}"),
                format!("{:.2}x", sg / hy),
            ]);
        }
        println!("--- {label} ---");
        println!("{}", t.render());
    }
    println!("paper shape: Hybrid consistently beats Static across batch sizes;");
    println!("gather fusion removes the ~11% preprocessing SGLang pays at batch 1.");
}
