//! Figure 9: end-to-end serving comparison — 5 models × 3 GPUs ×
//! batch sizes, MPK vs PyTorch / vLLM / SGLang, normalized to MPK.
//! Also prints the §6.3 anchor: Qwen3-8B per-token latency on A100
//! against the 16 GB / 1.6 TB/s hardware lower bound.

use mpk::models::{build_decode_graph, GraphOptions, ModelConfig};
use mpk::sim::{simulate_baseline, simulate_megakernel, BaselineSystem, GpuSpec, SimOptions};
use mpk::tgraph::{compile, CompileOptions, DecomposeConfig};
use mpk::util::Table;

fn main() {
    println!("== Figure 9: end-to-end throughput (normalized to MPK; value = MPK/system) ==");
    println!("(each cell: relative throughput; >1 ⇒ MPK faster. speedup col = vs best of vLLM/SGLang)\n");
    let batches = [1usize, 4, 16];
    for gpu in GpuSpec::all() {
        let mut t = Table::new(&["model", "batch", "MPK ms/tok", "PyTorch", "vLLM", "SGLang", "speedup"]);
        for cfg in ModelConfig::paper_models() {
            for &b in &batches {
                let g = build_decode_graph(&cfg, &GraphOptions { batch: b, kv_len: 512, ..Default::default() });
                let c = compile(
                    &g,
                    &CompileOptions {
                        decompose: DecomposeConfig { target_tasks: gpu.workers, min_tile_cols: 8 },
                        ..Default::default()
                    },
                );
                let mpk = simulate_megakernel(&c, &gpu, &SimOptions::default()).makespan_us;
                let rel = |sys: &BaselineSystem| simulate_baseline(&c, &gpu, sys, None) / mpk;
                let pt = rel(&BaselineSystem::pytorch());
                let vl = rel(&BaselineSystem::vllm());
                let sg = rel(&BaselineSystem::sglang());
                let best = vl.min(sg);
                t.row(vec![
                    cfg.name.to_string(),
                    b.to_string(),
                    format!("{:.2}", mpk / 1000.0),
                    format!("{pt:.2}x"),
                    format!("{vl:.2}x"),
                    format!("{sg:.2}x"),
                    format!("{best:.2}x"),
                ]);
            }
        }
        println!("--- {} (workers {}, schedulers {}) ---", gpu.name, gpu.workers, gpu.schedulers);
        println!("{}", t.render());
    }

    // §6.3 anchor
    let gpu = GpuSpec::a100();
    let cfg = ModelConfig::qwen3_8b();
    let g = build_decode_graph(&cfg, &GraphOptions { batch: 1, kv_len: 512, ..Default::default() });
    let c = compile(
        &g,
        &CompileOptions { decompose: DecomposeConfig { target_tasks: gpu.workers, min_tile_cols: 8 }, ..Default::default() },
    );
    let mpk_ms = simulate_megakernel(&c, &gpu, &SimOptions::default()).makespan_us / 1000.0;
    let sg_ms = simulate_baseline(&c, &gpu, &BaselineSystem::sglang(), None) / 1000.0;
    let bound_ms = 16.0e9 / gpu.hbm_bytes_per_us / 1000.0;
    println!("== §6.3 anchor (Qwen3-8B on A100, batch 1) ==");
    println!("paper:    baseline 14.5 ms → MPK 12.5 ms, HW bound ≈ 10 ms");
    println!("measured: baseline {sg_ms:.1} ms → MPK {mpk_ms:.1} ms, HW bound ≈ {bound_ms:.1} ms");
}
