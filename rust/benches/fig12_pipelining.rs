//! Figure 12: cross-task pipelining ablation — the final linear layer
//! (lm_head) of Qwen3-8B on B200. MPK-Pipe vs MPK-No-Pipe vs a
//! cuBLAS-class monolithic kernel. Values in µs, lower is better.

use mpk::models::ModelConfig;
use mpk::ops::{CompGraph, DType, OpKind};
use mpk::sim::{op_kernel_us, simulate_megakernel, task_costs, GpuSpec, SimOptions};
use mpk::tgraph::{compile, CompileOptions, DecomposeConfig};
use mpk::util::Table;

fn main() {
    println!("== Figure 12: cross-task pipelining on the final linear layer ==");
    println!("(Qwen3-8B lm_head: [b,4096] x [4096,151936] on B200)\n");
    let gpu = GpuSpec::b200();
    let cfg = ModelConfig::qwen3_8b();
    let mut t = Table::new(&["batch", "MPK-Pipe", "MPK-No-Pipe", "cuBLAS-class", "Pipe speedup"]);
    for b in [1usize, 4, 8, 16] {
        // isolated graph: just the lm_head matmul.
        let mut g = CompGraph::new();
        let x = g.input("x", vec![b, cfg.d_model], DType::BF16);
        let w = g.param("lm_head", vec![cfg.d_model, cfg.vocab], DType::BF16);
        g.op("lm_head_mm", OpKind::MatMul, &[x, w], vec![b, cfg.vocab], DType::BF16);
        let c = compile(
            &g,
            &CompileOptions {
                // multiple task rounds per worker: cross-task pipelining
                // only exists when a worker runs tasks back-to-back.
                decompose: DecomposeConfig { target_tasks: 4 * gpu.workers, min_tile_cols: 8 },
                ..Default::default()
            },
        );
        // deterministic (jitter-free) for a clean ablation read.
        let pipe = simulate_megakernel(&c, &gpu, &SimOptions { jitter: 0.0, ..Default::default() });
        let nopipe = simulate_megakernel(
            &c,
            &gpu,
            &SimOptions { pipelining: false, jitter: 0.0, ..Default::default() },
        );
        let costs = task_costs(&c, &gpu, None);
        let cublas = op_kernel_us(&c, &costs, 0, &gpu, None) + gpu.launch_us_graph;
        t.row(vec![
            b.to_string(),
            format!("{:.1}", pipe.makespan_us),
            format!("{:.1}", nopipe.makespan_us),
            format!("{cublas:.1}"),
            format!("{:.2}x", nopipe.makespan_us / pipe.makespan_us),
        ]);
    }
    println!("{}", t.render());
    println!("paper shape: pipelining buys 1.2-1.3x and edges out cuBLAS.");
    println!("mechanism: back-to-back tasks keep the HBM pipe warm (bw_eff");
    println!("0.95 vs 0.75 cold; a monolithic kernel sustains ~0.88).");
}
