//! Multi-GPU tensor parallelism (§6.5): collective lowering to ring
//! schedules and per-rank TP execution plans.
pub mod collective;
pub mod tp;

pub use collective::{inkernel_allreduce_us, nccl_allreduce_us, ring_bytes_per_device, ring_schedule};
pub use tp::{baseline_iteration_us, mpk_iteration_us, plan, TpPlan};
