//! Collective lowering (§6.5): AllReduce → inter-GPU data-transfer tasks
//! + local reduction tasks, executable by the same event-driven runtime
//! as compute.
//!
//! The simulator's compiled graphs keep `AllReduce` as an op whose tasks
//! carry link cost (see `sim::cost`); this module provides the explicit
//! ring schedule those costs are derived from, plus a task-level
//! lowering used by tests and the multi-GPU example to show the
//! Transfer/Reduce structure.

use crate::sim::gpu::LinkSpec;

/// One step of a ring all-reduce for a tensor shard.
#[derive(Clone, Debug, PartialEq)]
pub struct RingStep {
    pub phase: RingPhase,
    pub step: usize,
    /// Bytes each device sends to its neighbor in this step.
    pub bytes_per_device: u64,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RingPhase {
    ReduceScatter,
    AllGather,
}

/// The classic 2(w−1)-step ring schedule over `total_bytes`.
pub fn ring_schedule(total_bytes: u64, world: usize) -> Vec<RingStep> {
    if world <= 1 {
        return Vec::new();
    }
    let chunk = total_bytes.div_ceil(world as u64);
    let mut steps = Vec::with_capacity(2 * (world - 1));
    for s in 0..world - 1 {
        steps.push(RingStep { phase: RingPhase::ReduceScatter, step: s, bytes_per_device: chunk });
    }
    for s in 0..world - 1 {
        steps.push(RingStep { phase: RingPhase::AllGather, step: s, bytes_per_device: chunk });
    }
    steps
}

/// Total bytes a device pushes through its link for the whole ring
/// all-reduce: 2(w−1)/w × N.
pub fn ring_bytes_per_device(total_bytes: u64, world: usize) -> u64 {
    if world <= 1 {
        return 0;
    }
    let w = world as u64;
    total_bytes * 2 * (w - 1) / w
}

/// Latency of an in-kernel ring all-reduce when transfers pipeline
/// across steps (NVSHMEM put + signal per step).
pub fn inkernel_allreduce_us(total_bytes: u64, world: usize, link: &LinkSpec) -> f64 {
    if world <= 1 {
        return 0.0;
    }
    let steps = 2.0 * (world - 1) as f64;
    let chunk = total_bytes as f64 / world as f64;
    steps * (chunk / link.bytes_per_us + link.latency_us)
}

/// Latency of a host-launched (NCCL-class) all-reduce: same wire time
/// plus the collective kernel launch.
pub fn nccl_allreduce_us(total_bytes: u64, world: usize, link: &LinkSpec) -> f64 {
    if world <= 1 {
        return 0.0;
    }
    inkernel_allreduce_us(total_bytes, world, link) + link.nccl_launch_us
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_has_2w_minus_2_steps() {
        for w in [2usize, 4, 8] {
            let s = ring_schedule(1 << 20, w);
            assert_eq!(s.len(), 2 * (w - 1));
            assert_eq!(s.iter().filter(|x| x.phase == RingPhase::ReduceScatter).count(), w - 1);
        }
    }

    #[test]
    fn world_one_is_free() {
        assert!(ring_schedule(1 << 20, 1).is_empty());
        assert_eq!(ring_bytes_per_device(1 << 20, 1), 0);
        let l = LinkSpec::nvlink_h100();
        assert_eq!(inkernel_allreduce_us(1 << 20, 1, &l), 0.0);
    }

    #[test]
    fn wire_bytes_formula() {
        // 2(w-1)/w of the tensor crosses each link.
        assert_eq!(ring_bytes_per_device(8000, 4), 12000);
        assert_eq!(ring_bytes_per_device(8000, 2), 8000);
    }

    #[test]
    fn inkernel_beats_nccl() {
        let l = LinkSpec::nvlink_h100();
        for bytes in [4096u64, 1 << 20] {
            assert!(
                inkernel_allreduce_us(bytes, 4, &l) < nccl_allreduce_us(bytes, 4, &l),
                "bytes {bytes}"
            );
        }
    }

    #[test]
    fn latency_dominates_small_messages() {
        let l = LinkSpec::nvlink_h100();
        let small = inkernel_allreduce_us(4096, 8, &l);
        // 14 steps × ~1.5 µs latency floor
        assert!(small > 14.0 * l.latency_us * 0.9, "{small}");
    }
}
