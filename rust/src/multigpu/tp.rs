//! Tensor-parallel execution planning (§6.5): Megatron-style sharding
//! with AllReduce after the attention output projection and after the
//! MLP down projection. Devices execute identical shards in lockstep, so
//! simulating one rank's tGraph — with its communication tasks costed on
//! the link model — captures the whole system's iteration latency.

use crate::models::{build_decode_graph, GraphOptions, ModelConfig};
use crate::sim::baseline::{simulate_baseline, BaselineSystem};
use crate::sim::engine::{simulate_megakernel, SimOptions};
use crate::sim::gpu::{GpuSpec, LinkSpec};
use crate::tgraph::{compile, CompileOptions, CompiledGraph, DecomposeConfig, DepGranularity};

/// A tensor-parallel execution plan for one rank.
pub struct TpPlan {
    pub world: usize,
    pub compiled: CompiledGraph,
}

/// Build and compile one rank's decode graph at `world`-way TP.
pub fn plan(
    cfg: &ModelConfig,
    batch: usize,
    kv_len: usize,
    world: usize,
    gpu: &GpuSpec,
    granularity: DepGranularity,
) -> TpPlan {
    let mut g = build_decode_graph(
        cfg,
        &GraphOptions { batch, kv_len, tp_world: world, ..Default::default() },
    );
    // Under TP, split the collective-adjacent ops by request row so a
    // row's AllReduce tiles can flow as soon as that row's producer
    // tiles finish (the Figure 3/5 fine-grained overlap structure).
    if world > 1 && batch > 1 {
        use crate::ops::OpKind;
        let rows = batch.min(8);
        let shapes: Vec<Vec<usize>> =
            g.ops.iter().map(|o| g.tensors[o.output].shape.clone()).collect();
        for (op, shape) in g.ops.iter_mut().zip(shapes) {
            let near_collective = matches!(op.kind, OpKind::AllReduce { .. })
                || op.name.ends_with("o_proj")
                || op.name.ends_with("down")
                || op.name.ends_with("attn_res")
                || op.name.ends_with("mlp_res");
            if near_collective && shape.len() == 2 {
                let cols = (gpu.workers / rows).max(1).min(shape[1] / 8);
                op.partition_hint = Some(vec![rows, cols.max(1)]);
            }
        }
    }
    let compiled = compile(
        &g,
        &CompileOptions {
            decompose: DecomposeConfig { target_tasks: gpu.workers, min_tile_cols: 8 },
            granularity,
            ..Default::default()
        },
    );
    TpPlan { world, compiled }
}

/// Per-iteration latency of MPK on this plan, µs.
pub fn mpk_iteration_us(p: &TpPlan, gpu: &GpuSpec, link: &LinkSpec, pipelining: bool) -> f64 {
    let link_opt = if p.world > 1 { Some(*link) } else { None };
    simulate_megakernel(&p.compiled, gpu, &SimOptions { pipelining, link: link_opt, ..Default::default() }).makespan_us
}

/// Per-iteration latency of a kernel-per-operator baseline, µs.
pub fn baseline_iteration_us(p: &TpPlan, gpu: &GpuSpec, link: &LinkSpec, sys: &BaselineSystem) -> f64 {
    let link_opt = if p.world > 1 { Some(link) } else { None };
    simulate_baseline(&p.compiled, gpu, sys, link_opt)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn h() -> (GpuSpec, LinkSpec) {
        (GpuSpec::h100(), LinkSpec::nvlink_h100())
    }

    #[test]
    fn tp_scales_iteration_latency_down() {
        // Figure 11 shape: more GPUs → faster iterations (weights shard),
        // with diminishing returns from communication.
        let (gpu, link) = h();
        let cfg = ModelConfig::qwen3_1_7b();
        let lat: Vec<f64> = [1usize, 2, 4, 8]
            .iter()
            .map(|&w| {
                let p = plan(&cfg, 1, 512, w, &gpu, DepGranularity::Fine);
                mpk_iteration_us(&p, &gpu, &link, true)
            })
            .collect();
        assert!(lat[1] < lat[0], "2-way not faster: {lat:?}");
        assert!(lat[2] < lat[1], "4-way not faster: {lat:?}");
        // scaling efficiency below ideal (communication).
        assert!(lat[3] > lat[0] / 8.0, "superlinear? {lat:?}");
    }

    #[test]
    fn mpk_beats_baselines_at_tp4() {
        let (gpu, link) = h();
        let cfg = ModelConfig::qwen3_1_7b();
        let p = plan(&cfg, 1, 512, 4, &gpu, DepGranularity::Fine);
        let mpk = mpk_iteration_us(&p, &gpu, &link, true);
        for sys in BaselineSystem::all() {
            let b = baseline_iteration_us(&p, &gpu, &link, &sys);
            assert!(b > mpk, "{}: {b:.0} vs MPK {mpk:.0}", sys.name);
        }
    }

    #[test]
    fn fine_grained_overlap_beats_coarse() {
        // Figure 13: compute–communication overlap ≈ 1.1× on 4×H100.
        let (gpu, link) = h();
        let cfg = ModelConfig::qwen3_1_7b();
        let fine = plan(&cfg, 8, 512, 4, &gpu, DepGranularity::Fine);
        let coarse = plan(&cfg, 8, 512, 4, &gpu, DepGranularity::CoarseCollectives);
        let f = mpk_iteration_us(&fine, &gpu, &link, true);
        let c = mpk_iteration_us(&coarse, &gpu, &link, true);
        let ratio = c / f;
        assert!((1.02..=1.6).contains(&ratio), "overlap ratio {ratio:.3} (fine {f:.0}, coarse {c:.0})");
    }

    #[test]
    fn speedup_vs_sglang_in_figure11_band() {
        // 1.1–1.4× vs optimized baselines on multi-GPU (§6.5).
        let (gpu, link) = h();
        let cfg = ModelConfig::qwen3_1_7b();
        for w in [2usize, 4, 8] {
            let p = plan(&cfg, 1, 512, w, &gpu, DepGranularity::Fine);
            let mpk = mpk_iteration_us(&p, &gpu, &link, true);
            let sg = baseline_iteration_us(&p, &gpu, &link, &BaselineSystem::sglang());
            let s = sg / mpk;
            assert!((1.02..=2.0).contains(&s), "TP{w}: speedup {s:.2}");
        }
    }
}
