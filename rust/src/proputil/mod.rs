//! Mini property-testing framework (proptest is unavailable offline).
//!
//! Seeded, deterministic, shrinking-free: `forall` runs a generator +
//! property over N cases and reports the failing seed so a case can be
//! replayed exactly. Used for the tGraph/runtime/serving invariant
//! suites in `rust/tests/prop_*.rs`.

use crate::util::XorShift64;

/// Run `prop` over `cases` generated inputs. Panics with the seed of the
/// first failing case.
pub fn forall<T, G, P>(name: &str, seed: u64, cases: usize, mut gen: G, mut prop: P)
where
    G: FnMut(&mut XorShift64) -> T,
    P: FnMut(&T) -> Result<(), String>,
{
    for case in 0..cases {
        let case_seed = seed.wrapping_add(case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut rng = XorShift64::new(case_seed);
        let input = gen(&mut rng);
        if let Err(e) = prop(&input) {
            panic!("property '{name}' failed at case {case} (seed {case_seed:#x}): {e}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_trivial_property() {
        forall("x<n", 1, 100, |r| r.below(10), |&x| {
            if x < 10 {
                Ok(())
            } else {
                Err(format!("{x} >= 10"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-fails' failed")]
    fn forall_reports_failures() {
        forall("always-fails", 2, 5, |r| r.below(3), |_| Err("nope".into()));
    }
}
