//! Model configurations for the paper's evaluated LLMs (§6.2, Figure 9)
//! plus a tiny Qwen3-style model used for the real-numerics CPU path.

/// Mixture-of-experts parameters (Qwen3-30B-A3B).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct MoeConfig {
    pub num_experts: usize,
    pub top_k: usize,
    /// Per-expert FFN intermediate size.
    pub expert_ffn: usize,
}

/// Architectural parameters of a decoder-only transformer.
#[derive(Clone, Debug)]
pub struct ModelConfig {
    pub name: &'static str,
    pub layers: usize,
    pub d_model: usize,
    pub heads: usize,
    pub kv_heads: usize,
    pub head_dim: usize,
    /// Dense FFN intermediate size (gate/up width). Ignored for MoE layers.
    pub ffn: usize,
    pub vocab: usize,
    pub moe: Option<MoeConfig>,
}

impl ModelConfig {
    /// Approximate parameter count (embedding + per-layer + head), used
    /// for the §6.3 bandwidth lower bound.
    pub fn param_count(&self) -> u64 {
        let d = self.d_model as u64;
        let q = (self.heads * self.head_dim) as u64;
        let kv = (self.kv_heads * self.head_dim) as u64;
        let attn = d * q + 2 * d * kv + q * d;
        let mlp = match self.moe {
            Some(m) => {
                let e = m.expert_ffn as u64;
                (self.layers as u64) * 0 + (m.num_experts as u64) * 3 * d * e + d * m.num_experts as u64
            }
            None => 3 * d * (self.ffn as u64),
        };
        let per_layer = attn + mlp + 2 * d; // + norms
        let emb = (self.vocab as u64) * d;
        emb * 2 + (self.layers as u64) * per_layer
    }

    /// Qwen3-0.6B.
    pub fn qwen3_0_6b() -> Self {
        ModelConfig {
            name: "Qwen3-0.6B",
            layers: 28,
            d_model: 1024,
            heads: 16,
            kv_heads: 8,
            head_dim: 128,
            ffn: 3072,
            vocab: 151_936,
            moe: None,
        }
    }

    /// Llama-3.2-1B-Instruct.
    pub fn llama32_1b() -> Self {
        ModelConfig {
            name: "Llama-3.2-1B",
            layers: 16,
            d_model: 2048,
            heads: 32,
            kv_heads: 8,
            head_dim: 64,
            ffn: 8192,
            vocab: 128_256,
            moe: None,
        }
    }

    /// Qwen3-1.7B.
    pub fn qwen3_1_7b() -> Self {
        ModelConfig {
            name: "Qwen3-1.7B",
            layers: 28,
            d_model: 2048,
            heads: 16,
            kv_heads: 8,
            head_dim: 128,
            ffn: 6144,
            vocab: 151_936,
            moe: None,
        }
    }

    /// Qwen3-8B.
    pub fn qwen3_8b() -> Self {
        ModelConfig {
            name: "Qwen3-8B",
            layers: 36,
            d_model: 4096,
            heads: 32,
            kv_heads: 8,
            head_dim: 128,
            ffn: 12_288,
            vocab: 151_936,
            moe: None,
        }
    }

    /// Qwen3-30B-A3B (MoE: 128 experts, top-8).
    pub fn qwen3_30b_a3b() -> Self {
        ModelConfig {
            name: "Qwen3-30B-A3B",
            layers: 48,
            d_model: 2048,
            heads: 32,
            kv_heads: 4,
            head_dim: 128,
            ffn: 6144,
            vocab: 151_936,
            moe: Some(MoeConfig { num_experts: 128, top_k: 8, expert_ffn: 768 }),
        }
    }

    /// Tiny Qwen3-style model for the real-numerics end-to-end path
    /// (small enough to AOT-compile and run on CPU PJRT in seconds).
    pub fn tiny() -> Self {
        ModelConfig {
            name: "Tiny-Qwen3",
            layers: 4,
            d_model: 256,
            heads: 4,
            kv_heads: 2,
            head_dim: 64,
            ffn: 512,
            vocab: 512,
            moe: None,
        }
    }

    /// The five paper models in Figure 9 order.
    pub fn paper_models() -> Vec<ModelConfig> {
        vec![
            Self::qwen3_0_6b(),
            Self::llama32_1b(),
            Self::qwen3_1_7b(),
            Self::qwen3_8b(),
            Self::qwen3_30b_a3b(),
        ]
    }

    /// Look up a config by (case-insensitive) name.
    pub fn by_name(name: &str) -> Option<ModelConfig> {
        let n = name.to_ascii_lowercase();
        Self::paper_models()
            .into_iter()
            .chain(std::iter::once(Self::tiny()))
            .find(|m| m.name.to_ascii_lowercase() == n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn param_counts_in_expected_band() {
        // sanity: within ~2x of the advertised sizes.
        let checks = [
            (ModelConfig::qwen3_0_6b(), 0.3e9, 1.4e9),
            (ModelConfig::llama32_1b(), 0.7e9, 2.5e9),
            (ModelConfig::qwen3_1_7b(), 1.0e9, 3.4e9),
            (ModelConfig::qwen3_8b(), 5.0e9, 12.0e9),
            (ModelConfig::qwen3_30b_a3b(), 18.0e9, 45.0e9),
        ];
        for (cfg, lo, hi) in checks {
            let p = cfg.param_count() as f64;
            assert!(p > lo && p < hi, "{}: {p:.2e} not in [{lo:.1e}, {hi:.1e}]", cfg.name);
        }
    }

    #[test]
    fn by_name_roundtrip() {
        for m in ModelConfig::paper_models() {
            assert_eq!(ModelConfig::by_name(m.name).unwrap().name, m.name);
        }
        assert!(ModelConfig::by_name("nope").is_none());
    }

    #[test]
    fn moe_config_present_only_for_a3b() {
        assert!(ModelConfig::qwen3_30b_a3b().moe.is_some());
        assert!(ModelConfig::qwen3_8b().moe.is_none());
    }
}
