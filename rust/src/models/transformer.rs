//! Decode-iteration computation-graph builders.
//!
//! [`build_decode_graph`] produces the kernel-level [`CompGraph`] for one
//! decoding iteration of a dense or MoE transformer at a given batch size
//! and KV length, optionally partitioned for tensor parallelism
//! (Megatron-style: heads and FFN columns sharded, AllReduce after the
//! attention output projection and after the MLP down projection, §6.5).
//!
//! Q/K/V projections are emitted as a single fused MatMul, mirroring the
//! paper's observation (§6.7) that compiled graphs are "deep, not wide".
//! An `unfused_qkv` option keeps them separate, which is what exercises
//! the normalization fork/join rewrites of Figure 6.

use crate::models::ModelConfig;
use crate::ops::{CompGraph, DType, OpKind};

/// Options controlling graph construction.
#[derive(Clone, Debug)]
pub struct GraphOptions {
    pub batch: usize,
    /// Current KV-cache length (tokens already decoded) per request.
    pub kv_len: usize,
    /// Tensor-parallel world size (1 = single GPU).
    pub tp_world: usize,
    /// Emit separate Q/K/V projections (exercises normalization).
    pub unfused_qkv: bool,
    /// Include the LM head (final vocab projection).
    pub lm_head: bool,
    /// Fuse the KV-cache append into the attention op (the paper's
    /// production graphs do this — §6.7's "no fork/join groups" relies
    /// on it). The real-numerics path keeps the explicit KvAppend op.
    pub fused_kv_append: bool,
    pub dtype: DType,
}

impl Default for GraphOptions {
    fn default() -> Self {
        GraphOptions { batch: 1, kv_len: 1024, tp_world: 1, unfused_qkv: false, lm_head: true, fused_kv_append: true, dtype: DType::BF16 }
    }
}

/// Build the decode-iteration graph for `cfg` under `opt`.
pub fn build_decode_graph(cfg: &ModelConfig, opt: &GraphOptions) -> CompGraph {
    assert!(opt.tp_world >= 1);
    assert_eq!(cfg.heads % opt.tp_world, 0, "heads must divide tp world");
    assert!(cfg.kv_heads % opt.tp_world == 0 || opt.tp_world <= cfg.kv_heads || opt.tp_world == 1);

    let mut g = CompGraph::new();
    let b = opt.batch;
    let d = cfg.d_model;
    let w = opt.tp_world;
    let heads = cfg.heads / w;
    let kv_heads = (cfg.kv_heads / w).max(1);
    let q_dim = heads * cfg.head_dim;
    let kv_dim = kv_heads * cfg.head_dim;
    let dt = opt.dtype;

    let ids = g.input("token_ids", vec![b], DType::I32);
    let emb_w = g.param("embed.weight", vec![cfg.vocab, d], dt);
    let mut x = g.op("embed", OpKind::Embedding, &[ids, emb_w], vec![b, d], dt);

    for l in 0..cfg.layers {
        let p = |s: &str| format!("l{l}.{s}");
        // ---- attention block ----
        let nw = g.param(&p("ln1.weight"), vec![d], dt);
        let normed = g.op(&p("ln1"), OpKind::RmsNorm, &[x, nw], vec![b, d], dt);

        let (q, k, v) = if opt.unfused_qkv {
            let wq = g.param(&p("wq"), vec![d, q_dim], dt);
            let wk = g.param(&p("wk"), vec![d, kv_dim], dt);
            let wv = g.param(&p("wv"), vec![d, kv_dim], dt);
            let q = g.op(&p("q_proj"), OpKind::MatMul, &[normed, wq], vec![b, q_dim], dt);
            let k = g.op(&p("k_proj"), OpKind::MatMul, &[normed, wk], vec![b, kv_dim], dt);
            let v = g.op(&p("v_proj"), OpKind::MatMul, &[normed, wv], vec![b, kv_dim], dt);
            (q, k, v)
        } else {
            let wqkv = g.param(&p("wqkv"), vec![d, q_dim + 2 * kv_dim], dt);
            let qkv = g.op(&p("qkv_proj"), OpKind::MatMul, &[normed, wqkv], vec![b, q_dim + 2 * kv_dim], dt);
            (qkv, qkv, qkv)
        };

        // Append this step's K/V into the paged cache (cache tensors are
        // graph inputs: state owned by the serving engine). In fused
        // mode the attention tasks perform the append themselves.
        let kcache = g.input(&p("kcache"), vec![b, opt.kv_len + 1, kv_dim], dt);
        let vcache = g.input(&p("vcache"), vec![b, opt.kv_len + 1, kv_dim], dt);
        let attn_kind = OpKind::Attention {
            heads,
            kv_heads,
            head_dim: cfg.head_dim,
            kv_len: opt.kv_len + 1,
        };
        let attn = if opt.fused_kv_append {
            g.op(&p("attn"), attn_kind, &[q, kcache, vcache], vec![b, q_dim], dt)
        } else {
            let kv_new =
                g.op(&p("kv_append"), OpKind::KvAppend, &[k, v, kcache, vcache], vec![b, 2 * kv_dim], dt);
            g.op(&p("attn"), attn_kind, &[q, kcache, vcache, kv_new], vec![b, q_dim], dt)
        };

        let wo = g.param(&p("wo"), vec![q_dim, d], dt);
        let mut attn_out = g.op(&p("o_proj"), OpKind::MatMul, &[attn, wo], vec![b, d], dt);
        if w > 1 {
            attn_out = g.op(&p("attn_ar"), OpKind::AllReduce { world: w }, &[attn_out], vec![b, d], dt);
        }
        let h = g.op(&p("attn_res"), OpKind::Add, &[x, attn_out], vec![b, d], dt);

        // ---- MLP / MoE block ----
        let nw2 = g.param(&p("ln2.weight"), vec![d], dt);
        let normed2 = g.op(&p("ln2"), OpKind::RmsNorm, &[h, nw2], vec![b, d], dt);

        let mut mlp_out = match &cfg.moe {
            None => {
                let f = cfg.ffn / w;
                let wgu = g.param(&p("w_gate_up"), vec![d, 2 * f], dt);
                let gu = g.op(&p("gate_up"), OpKind::MatMul, &[normed2, wgu], vec![b, 2 * f], dt);
                let act = g.op(&p("swiglu"), OpKind::SwiGLU, &[gu, gu], vec![b, f], dt);
                let wd = g.param(&p("w_down"), vec![f, d], dt);
                g.op(&p("down"), OpKind::MatMul, &[act, wd], vec![b, d], dt)
            }
            Some(moe) => {
                let wg = g.param(&p("router.weight"), vec![d, moe.num_experts], dt);
                let route = g.op(
                    &p("route"),
                    OpKind::MoeRoute { experts: moe.num_experts, topk: moe.top_k },
                    &[normed2, wg],
                    vec![b, moe.top_k],
                    dt,
                );
                // Expected tokens per expert under uniform routing; the
                // runtime balancer redistributes under skew (§6.4).
                let avg_tokens = ((b * moe.top_k) as f64 / moe.num_experts as f64).ceil() as usize;
                let e_per_rank = moe.num_experts / w;
                let mut outs = Vec::new();
                // One grouped ExpertGemm op per layer (rank-local experts
                // batched, like a grouped-GEMM kernel); the runtime
                // balancer splits its tasks by actual routing (§6.4).
                let group = e_per_rank.max(1);
                let ngroups = e_per_rank.div_ceil(group);
                for gidx in 0..ngroups {
                    let we = g.param(&p(&format!("expert{gidx}.w")), vec![d, 2 * moe.expert_ffn * group], dt);
                    let eo = g.op(
                        &p(&format!("expert{gidx}")),
                        OpKind::MoeExpertGemm { expert: gidx, avg_tokens: avg_tokens * group },
                        &[normed2, we, route],
                        vec![b, moe.expert_ffn],
                        dt,
                    );
                    outs.push(eo);
                }
                let mut combine_in = vec![route];
                combine_in.extend(outs);
                g.op(&p("combine"), OpKind::MoeCombine { topk: moe.top_k }, &combine_in, vec![b, d], dt)
            }
        };
        if w > 1 {
            mlp_out = g.op(&p("mlp_ar"), OpKind::AllReduce { world: w }, &[mlp_out], vec![b, d], dt);
        }
        x = g.op(&p("mlp_res"), OpKind::Add, &[h, mlp_out], vec![b, d], dt);
    }

    let fw = g.param("final_norm.weight", vec![d], dt);
    let xf = g.op("final_norm", OpKind::RmsNorm, &[x, fw], vec![b, d], dt);
    if opt.lm_head {
        let lw = g.param("lm_head.weight", vec![d, cfg.vocab], dt);
        g.op("lm_head", OpKind::MatMul, &[xf, lw], vec![b, cfg.vocab], dt);
    }
    debug_assert!(g.validate().is_ok());
    g
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dense_graph_builds_and_validates() {
        let cfg = ModelConfig::qwen3_1_7b();
        let g = build_decode_graph(&cfg, &GraphOptions { batch: 4, kv_len: 256, ..Default::default() });
        assert!(g.validate().is_ok());
        // embed + L×(ln1, qkv, attn, o_proj, attn_res, ln2, gate_up,
        // swiglu, down, mlp_res) + final_norm + lm_head (fused KV append)
        assert_eq!(g.ops.len(), 2 + cfg.layers * 10 + 1);
    }

    #[test]
    fn moe_graph_builds() {
        let cfg = ModelConfig::qwen3_30b_a3b();
        let g = build_decode_graph(&cfg, &GraphOptions { batch: 8, kv_len: 64, ..Default::default() });
        assert!(g.validate().is_ok());
        // Table 2 reports 533 ops for the MoE model — same order here.
        assert!(g.ops.len() > 400, "MoE graph too small: {}", g.ops.len());
    }

    #[test]
    fn tp_graph_has_allreduce() {
        let cfg = ModelConfig::qwen3_1_7b();
        let g = build_decode_graph(&cfg, &GraphOptions { batch: 1, kv_len: 128, tp_world: 4, ..Default::default() });
        let ars = g.ops.iter().filter(|o| matches!(o.kind, OpKind::AllReduce { .. })).count();
        assert_eq!(ars, 2 * cfg.layers);
        assert!(g.validate().is_ok());
    }

    #[test]
    fn unfused_qkv_creates_parallel_branches() {
        let cfg = ModelConfig::tiny();
        let g = build_decode_graph(&cfg, &GraphOptions { unfused_qkv: true, ..Default::default() });
        assert!(g.validate().is_ok());
        let ln1 = g.tensor_by_name("l0.ln1").unwrap().id;
        assert_eq!(g.consumers(ln1).len(), 3); // q, k, v projections
    }

    #[test]
    fn tp_shrinks_param_bytes_per_rank() {
        let cfg = ModelConfig::qwen3_1_7b();
        let g1 = build_decode_graph(&cfg, &GraphOptions { lm_head: false, ..Default::default() });
        let g4 = build_decode_graph(&cfg, &GraphOptions { tp_world: 4, lm_head: false, ..Default::default() });
        assert!(g4.param_bytes() < g1.param_bytes() / 2);
    }
}
