//! Model configurations and decode-iteration graph builders.
pub mod config;
pub mod transformer;

pub use config::{ModelConfig, MoeConfig};
pub use transformer::{build_decode_graph, GraphOptions};
