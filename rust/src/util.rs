//! Small utilities: deterministic RNG, JSON writer, table formatting,
//! timing helpers, and the boundary-error newtype macro.
//! (serde/criterion are unavailable offline — these are the minimal
//! in-repo replacements.)

/// Defines a typed boundary error. Two shapes:
///
/// * **Newtype** (`boundary_error!(Name)`): a `String`-newtype —
///   `Display` forwards the message, `std::error::Error` is
///   implemented, and `From<Self> for String` keeps legacy
///   `Result<_, String>` call sites compiling through `?`. One
///   definition per layer boundary (`runtime`'s manifest and pool,
///   `megakernel`'s kernel, `exec`'s task harvest).
/// * **Enum** (`boundary_error!(enum Name { Variant { field: Ty } =>
///   "fmt using {field}", ... })`): a field-carrying error enum for
///   boundaries where callers dispatch on *which* failure occurred
///   (the wire transport's `TransportError`). Each variant names its
///   fields and a format string that must reference every field; the
///   macro derives `Clone/Debug/PartialEq/Eq`, `Display`, `Error`,
///   and the same `From<Self> for String` legacy shim.
///
/// The serving layer adds its own `From<Self> for EngineError` shims
/// next to `EngineError` itself.
macro_rules! boundary_error {
    ($(#[$meta:meta])* $name:ident) => {
        $(#[$meta])*
        #[derive(Clone, Debug, PartialEq, Eq)]
        pub struct $name(pub String);

        impl std::fmt::Display for $name {
            fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
                write!(f, "{}", self.0)
            }
        }

        impl std::error::Error for $name {}

        impl From<$name> for String {
            fn from(e: $name) -> String {
                e.0
            }
        }
    };
    ($(#[$meta:meta])* enum $name:ident {
        $( $(#[$vmeta:meta])* $variant:ident { $($field:ident : $ftype:ty),* $(,)? } => $fmt:literal ),+ $(,)?
    }) => {
        $(#[$meta])*
        #[derive(Clone, Debug, PartialEq, Eq)]
        pub enum $name {
            $( $(#[$vmeta])* $variant { $($field: $ftype),* } ),+
        }

        impl std::fmt::Display for $name {
            fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
                match self {
                    $( $name::$variant { $($field),* } => {
                        write!(f, $fmt $(, $field = $field)*)
                    } ),+
                }
            }
        }

        impl std::error::Error for $name {}

        impl From<$name> for String {
            fn from(e: $name) -> String {
                e.to_string()
            }
        }
    };
}
pub(crate) use boundary_error;

//// xorshift64* — deterministic, seedable, fast. Used by the simulator,
/// workload generators and the property-test runner.
#[derive(Clone, Debug)]
pub struct XorShift64 {
    state: u64,
}

impl XorShift64 {
    pub fn new(seed: u64) -> Self {
        XorShift64 { state: seed.max(1) }
    }

    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545_F491_4F6C_DD1D)
    }

    /// Uniform in `[0, n)`.
    pub fn below(&mut self, n: usize) -> usize {
        (self.next_u64() % n.max(1) as u64) as usize
    }

    /// Uniform in `[lo, hi]` inclusive.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo + 1)
    }

    /// Uniform float in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Standard normal via Box–Muller.
    pub fn gauss(&mut self) -> f64 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Uniform f32 in [-1, 1) — used to synthesize model weights.
    pub fn unit_f32(&mut self) -> f32 {
        (self.f64() * 2.0 - 1.0) as f32
    }
}

/// Minimal JSON value + writer (enough for result files / manifests).
#[derive(Clone, Debug)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(kv) => {
                out.push('{');
                for (i, (k, v)) in kv.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Minimal JSON parser (for artifacts/manifest.json). Supports the
/// subset our tooling emits: objects, arrays, strings, numbers, bools.
pub mod json_parse {
    use super::Json;

    pub fn parse(s: &str) -> Result<Json, String> {
        let b = s.as_bytes();
        let mut i = 0;
        let v = value(b, &mut i)?;
        skip_ws(b, &mut i);
        if i != b.len() {
            return Err(format!("trailing data at byte {i}"));
        }
        Ok(v)
    }

    fn skip_ws(b: &[u8], i: &mut usize) {
        while *i < b.len() && (b[*i] as char).is_ascii_whitespace() {
            *i += 1;
        }
    }

    fn value(b: &[u8], i: &mut usize) -> Result<Json, String> {
        skip_ws(b, i);
        if *i >= b.len() {
            return Err("unexpected end".into());
        }
        match b[*i] {
            b'{' => obj(b, i),
            b'[' => arr(b, i),
            b'"' => Ok(Json::Str(string(b, i)?)),
            b't' => lit(b, i, "true", Json::Bool(true)),
            b'f' => lit(b, i, "false", Json::Bool(false)),
            b'n' => lit(b, i, "null", Json::Null),
            _ => num(b, i),
        }
    }

    fn lit(b: &[u8], i: &mut usize, word: &str, v: Json) -> Result<Json, String> {
        if b[*i..].starts_with(word.as_bytes()) {
            *i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at {i}", i = *i))
        }
    }

    fn num(b: &[u8], i: &mut usize) -> Result<Json, String> {
        let start = *i;
        while *i < b.len() && matches!(b[*i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E') {
            *i += 1;
        }
        std::str::from_utf8(&b[start..*i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at {start}"))
    }

    fn string(b: &[u8], i: &mut usize) -> Result<String, String> {
        *i += 1; // opening quote
        let mut s = String::new();
        while *i < b.len() {
            match b[*i] {
                b'"' => {
                    *i += 1;
                    return Ok(s);
                }
                b'\\' => {
                    *i += 1;
                    match b.get(*i) {
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'u') => {
                            let hex = std::str::from_utf8(&b[*i + 1..*i + 5]).map_err(|e| e.to_string())?;
                            let cp = u32::from_str_radix(hex, 16).map_err(|e| e.to_string())?;
                            s.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                            *i += 4;
                        }
                        _ => return Err("bad escape".into()),
                    }
                    *i += 1;
                }
                c => {
                    // UTF-8 passthrough
                    let ch_len = utf8_len(c);
                    s.push_str(std::str::from_utf8(&b[*i..*i + ch_len]).map_err(|e| e.to_string())?);
                    *i += ch_len;
                }
            }
        }
        Err("unterminated string".into())
    }

    fn utf8_len(c: u8) -> usize {
        if c < 0x80 {
            1
        } else if c >> 5 == 0b110 {
            2
        } else if c >> 4 == 0b1110 {
            3
        } else {
            4
        }
    }

    fn obj(b: &[u8], i: &mut usize) -> Result<Json, String> {
        *i += 1;
        let mut kv = Vec::new();
        skip_ws(b, i);
        if b.get(*i) == Some(&b'}') {
            *i += 1;
            return Ok(Json::Obj(kv));
        }
        loop {
            skip_ws(b, i);
            let k = string(b, i)?;
            skip_ws(b, i);
            if b.get(*i) != Some(&b':') {
                return Err(format!("expected ':' at {i}", i = *i));
            }
            *i += 1;
            let v = value(b, i)?;
            kv.push((k, v));
            skip_ws(b, i);
            match b.get(*i) {
                Some(b',') => *i += 1,
                Some(b'}') => {
                    *i += 1;
                    return Ok(Json::Obj(kv));
                }
                _ => return Err(format!("expected ',' or '}}' at {i}", i = *i)),
            }
        }
    }

    fn arr(b: &[u8], i: &mut usize) -> Result<Json, String> {
        *i += 1;
        let mut v = Vec::new();
        skip_ws(b, i);
        if b.get(*i) == Some(&b']') {
            *i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(value(b, i)?);
            skip_ws(b, i);
            match b.get(*i) {
                Some(b',') => *i += 1,
                Some(b']') => {
                    *i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(format!("expected ',' or ']' at {i}", i = *i)),
            }
        }
    }
}

impl Json {
    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        if let Json::Obj(kv) = self {
            kv.iter().find(|(k, _)| k == key).map(|(_, v)| v)
        } else {
            None
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        if let Json::Str(s) = self {
            Some(s)
        } else {
            None
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        if let Json::Num(n) = self {
            Some(*n)
        } else {
            None
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        if let Json::Arr(v) = self {
            Some(v)
        } else {
            None
        }
    }
}

/// Fixed-width ASCII table printer for bench reports.
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Self {
        Table { header: header.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len());
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut w = vec![0usize; ncol];
        for (i, h) in self.header.iter().enumerate() {
            w[i] = h.len();
        }
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                w[i] = w[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], w: &[usize]| -> String {
            let mut s = String::from("| ");
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!("{:<width$} | ", c, width = w[i]));
            }
            s.trim_end().to_string() + "\n"
        };
        out.push_str(&fmt_row(&self.header, &w));
        out.push_str(&format!("|{}\n", w.iter().map(|x| "-".repeat(x + 2) + "|").collect::<String>()));
        for r in &self.rows {
            out.push_str(&fmt_row(r, &w));
        }
        out
    }
}

/// Monotonic-clock micro-bench: warm up, then report the median of `n`
/// timed runs in nanoseconds. The in-repo criterion replacement.
pub fn bench_median_ns<F: FnMut()>(warmup: usize, n: usize, mut f: F) -> u64 {
    for _ in 0..warmup {
        f();
    }
    let mut samples: Vec<u64> = (0..n.max(1))
        .map(|_| {
            let t0 = std::time::Instant::now();
            f();
            t0.elapsed().as_nanos() as u64
        })
        .collect();
    samples.sort_unstable();
    samples[samples.len() / 2]
}

#[cfg(test)]
mod tests {
    use super::*;

    boundary_error!(
        /// Test-only enum-shaped boundary error.
        enum DemoError {
            /// Unit-ish variant (no fields).
            Closed {} => "demo closed",
            /// Field-carrying variant.
            TooBig { len: u32, cap: u32 } => "len {len} exceeds cap {cap}",
        }
    );

    #[test]
    fn boundary_error_enum_arm_displays_and_shims() {
        let e = DemoError::TooBig { len: 9, cap: 4 };
        assert_eq!(e.to_string(), "len 9 exceeds cap 4");
        assert_eq!(String::from(e.clone()), "len 9 exceeds cap 4");
        assert_eq!(e, DemoError::TooBig { len: 9, cap: 4 });
        assert_ne!(e, DemoError::Closed {});
        assert_eq!(DemoError::Closed {}.to_string(), "demo closed");
    }

    #[test]
    fn rng_is_deterministic() {
        let mut a = XorShift64::new(42);
        let mut b = XorShift64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn rng_below_in_range() {
        let mut r = XorShift64::new(7);
        for _ in 0..1000 {
            assert!(r.below(10) < 10);
            let x = r.range(3, 5);
            assert!((3..=5).contains(&x));
            let f = r.f64();
            assert!((0.0..1.0).contains(&f));
        }
    }

    #[test]
    fn json_roundtrip() {
        let j = Json::Obj(vec![
            ("name".into(), Json::Str("q\"uote".into())),
            ("n".into(), Json::Num(42.0)),
            ("arr".into(), Json::Arr(vec![Json::Num(1.5), Json::Bool(true), Json::Null])),
        ]);
        let s = j.to_string();
        let p = json_parse::parse(&s).unwrap();
        assert_eq!(p.get("name").unwrap().as_str().unwrap(), "q\"uote");
        assert_eq!(p.get("n").unwrap().as_usize().unwrap(), 42);
        assert_eq!(p.get("arr").unwrap().as_arr().unwrap().len(), 3);
    }

    #[test]
    fn json_parse_nested() {
        let s = r#"{"a": {"b": [1, 2, {"c": "d"}]}, "e": -1.5e2}"#;
        let p = json_parse::parse(s).unwrap();
        assert_eq!(p.get("e").unwrap().as_f64().unwrap(), -150.0);
        let arr = p.get("a").unwrap().get("b").unwrap().as_arr().unwrap();
        assert_eq!(arr[2].get("c").unwrap().as_str().unwrap(), "d");
    }

    #[test]
    fn table_renders() {
        let mut t = Table::new(&["model", "speedup"]);
        t.row(vec!["Qwen3-8B".into(), "1.7x".into()]);
        let s = t.render();
        assert!(s.contains("Qwen3-8B"));
        assert!(s.lines().count() == 3);
    }
}
