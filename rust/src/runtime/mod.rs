//! PJRT-backed artifact execution: manifest loading and the thread-
//! confined exec pool. Python builds the artifacts once (`make
//! artifacts`); this module runs them from the rust hot path. The
//! `xla` submodule is the offline stand-in for the PJRT binding so the
//! pool (and its protocol tests) compile in the stdlib-only build.
pub mod manifest;
pub mod pool;
pub mod xla;

pub use manifest::{ArgSpec, ArgType, ArtifactSpec, Manifest, ManifestError, TinyModelMeta};
pub use pool::{ExecPool, OutView, PoolError, Value};
