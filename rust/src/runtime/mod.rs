//! PJRT-backed artifact execution: manifest loading and the thread-
//! confined exec pool. Python builds the artifacts once (`make
//! artifacts`); this module runs them from the rust hot path.
pub mod manifest;
pub mod pool;

pub use manifest::{ArgSpec, ArgType, ArtifactSpec, Manifest, TinyModelMeta};
pub use pool::{ExecPool, Value};
