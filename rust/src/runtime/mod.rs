//! Artifact execution: manifest resolution and the thread-confined
//! exec pool, dispatching through pluggable [`ExecBackend`]s.
//!
//! The [`pool`] owns the execution-boundary *protocol* (lifetime-erased
//! request channels, validation, zero-copy output scatter); the
//! [`backend`] registry supplies the *numerics*. Two backends ship:
//! the native CPU backend ([`backend::cpu`]) — artifact-free, the
//! in-container default, what makes `mpk serve` and the real-numerics
//! tests run with no artifacts dir and no PJRT library — and the PJRT
//! backend ([`backend::pjrt`]), which compiles the HLO text artifacts
//! that `make artifacts` emits (the `xla` submodule is the offline
//! stand-in for the PJRT binding until a real build is vendored).
//! [`Manifest::resolve`] picks the artifact manifest from disk when
//! present and falls back to the compiled-in [`Manifest::builtin`]
//! signatures for artifact-free backends. See the [`backend`] module
//! docs for how to add a backend.
pub mod backend;
pub mod manifest;
pub mod pool;
pub mod xla;

pub use backend::{BackendKind, BackendSession, ExecBackend, In};
pub use manifest::{ArgSpec, ArgType, ArtifactSpec, Manifest, ManifestError, TinyModelMeta};
pub use pool::{ExecPool, OutView, PoolError, Value};
