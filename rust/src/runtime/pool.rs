//! Backend-neutral execution pool.
//!
//! The pool owns the *protocol* of the execution boundary and delegates
//! the numerics to an [`ExecBackend`] chosen at construction
//! ([`ExecPool::with_backend`]; [`ExecPool::new`] reads `MPK_BACKEND`,
//! defaulting to the native CPU backend). Backend sessions are
//! thread-confined — the PJRT client is `Rc`-based (`!Send`), and the
//! CPU backend keeps per-thread scratch — so the pool runs K *executor
//! threads, each owning its own [`BackendSession`]*; megakernel workers
//! submit host tensors over a channel and block on a per-request reply
//! channel. Artifacts prepare lazily on first use (for PJRT that means
//! compiling HLO text from disk; the CPU backend just parses the op out
//! of the artifact name).
//!
//! Inputs may be **borrowed** ([`Value::Borrowed`] /
//! [`Value::BorrowedI32`]): the zero-copy hot path hands the pool
//! slices that point straight into the `exec::store` tensor arena, so a
//! matmul/attention task marshals no input buffer at all. Borrowed
//! slices cross the thread boundary as raw pointer + length
//! ([`RawValue`]); this is sound because the submitter blocks on the
//! reply channel until the executor thread has finished with the inputs
//! and replied (or died) — the borrow outlives every read. See the
//! safety note on [`ExecPool::execute`].
//!
//! **Outputs** may land the same way: [`ExecPool::execute_into`] takes
//! a caller-owned destination per artifact output ([`OutView`], a
//! mutable arena region), and the executor thread scatters results
//! straight into them — no `Vec` is allocated at the boundary and the
//! caller copies nothing afterwards. Destinations cross the channel
//! lifetime-erased as raw pointer + run layout ([`RawOutView`]),
//! mirroring `RawValue::BorrowedF32`, and are sound via the same
//! blocking reply protocol: the caller's exclusive borrows of the
//! destination regions live across the whole call, so the executor is
//! the only writer while it runs. The executor re-materializes real
//! [`OutView`]s before dispatch, and backends write through their safe
//! run-wise accessors ([`OutView::span_mut`], [`OutView::copy_from`]) —
//! all pointer reconstruction stays in this audited module.
//! Destinations are validated (count here; numel and run geometry in
//! the backend) **before** the first element is written — a failed
//! `execute_into` never leaves a partial write. The pool counts every
//! output buffer it does allocate (the legacy [`ExecPool::execute`]
//! reply path) in [`ExecPool::output_allocs`]; the persistent-kernel
//! decode path asserts this stays at zero.
//!
//! Every fallible entry point returns the typed [`PoolError`]; legacy
//! `String` contexts (the binder's task bodies) convert through the
//! `From<PoolError> for String` shim, and no caller matches on error
//! strings.

use crate::runtime::backend::{self, BackendKind, BackendSession, ExecBackend, In};
use crate::runtime::manifest::{ArgType, Manifest};
use std::collections::VecDeque;
use std::marker::PhantomData;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};

crate::util::boundary_error! {
    /// Typed failure at the pool boundary: construction (backend
    /// unavailable, thread spawn failure, an executor dying during
    /// warm-up) and per-request execution (validation mismatches,
    /// backend errors, a dead executor thread). Legacy `String`
    /// contexts convert through the `From<PoolError> for String` shim;
    /// no caller matches on the message text.
    PoolError
}

/// A host tensor crossing the pool boundary. Borrowed variants carry a
/// slice borrowed from the caller (typically a tensor-arena view) for
/// the duration of the `execute` call.
#[derive(Clone, Debug)]
pub enum Value<'a> {
    F32(Vec<f32>),
    I32(Vec<i32>),
    Borrowed(&'a [f32]),
    BorrowedI32(&'a [i32]),
}

impl Value<'_> {
    pub fn len(&self) -> usize {
        match self {
            Value::F32(v) => v.len(),
            Value::I32(v) => v.len(),
            Value::Borrowed(s) => s.len(),
            Value::BorrowedI32(s) => s.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Which payload this value carries (for error messages).
    fn kind(&self) -> &'static str {
        match self {
            Value::F32(_) | Value::Borrowed(_) => "f32",
            Value::I32(_) | Value::BorrowedI32(_) => "i32",
        }
    }

    /// Borrow the f32 payload. A dtype mismatch is a typed
    /// [`PoolError`], **not** a panic: these accessors run inside task
    /// bodies on executor threads, where a panic would kill the thread
    /// and wedge the pool — callers record the error via
    /// `ExecCore::fail` instead, so it is harvested after the epoch and
    /// surfaces to the serving layer as `EngineError::Task`.
    pub fn as_f32(&self) -> Result<&[f32], PoolError> {
        match self {
            Value::F32(v) => Ok(v),
            Value::Borrowed(s) => Ok(s),
            other => Err(PoolError(format!("expected f32 value, got {}", other.kind()))),
        }
    }

    /// Borrow the i32 payload — the sibling typed accessor, fallible
    /// for the same reason as [`Value::as_f32`].
    pub fn as_i32(&self) -> Result<&[i32], PoolError> {
        match self {
            Value::I32(v) => Ok(v),
            Value::BorrowedI32(s) => Ok(s),
            other => Err(PoolError(format!("expected i32 value, got {}", other.kind()))),
        }
    }
}

/// A caller-owned output destination: a mutable f32 region (typically
/// an arena tile) an executor thread writes one artifact output into.
///
/// The region is a sequence of `runs` contiguous spans of `run`
/// elements whose starts are `stride` elements apart — `runs == 1` is
/// the plain contiguous case ([`OutView::from_slice`]), and the strided
/// form covers every regularly-tiled arena destination (e.g. a matmul
/// column tile: one run per output row, advancing by the row stride).
/// `exec::store::TileViewMut::out_view` builds these over arena tiles.
///
/// Backends write through the safe accessors ([`OutView::span_mut`],
/// [`OutView::run_mut`], [`OutView::copy_from`]) — the view holds
/// exclusive access to its runs for `'a` (the constructors' contract),
/// and `&mut self` makes each write uniquely referenced, so the
/// accessors are sound safe APIs over the raw parts kept here.
pub struct OutView<'a> {
    ptr: *mut f32,
    runs: usize,
    run: usize,
    stride: usize,
    _borrow: PhantomData<&'a mut [f32]>,
}

impl<'a> OutView<'a> {
    /// Contiguous destination over a caller-owned slice.
    pub fn from_slice(data: &'a mut [f32]) -> OutView<'a> {
        let run = data.len();
        OutView { ptr: data.as_mut_ptr(), runs: 1, run, stride: run, _borrow: PhantomData }
    }

    /// Strided destination from raw parts.
    ///
    /// SAFETY: for the lifetime `'a` the caller must hold exclusive
    /// write access to every run (`runs` spans of `run` elements,
    /// starting `stride` apart from `ptr`), all within one live
    /// allocation. `run <= stride` keeps the runs disjoint.
    pub(crate) unsafe fn from_raw_strided(
        ptr: *mut f32,
        runs: usize,
        run: usize,
        stride: usize,
    ) -> OutView<'a> {
        assert!(runs <= 1 || run <= stride, "overlapping output runs");
        OutView { ptr, runs, run, stride, _borrow: PhantomData }
    }

    /// Total elements this destination receives (the artifact output's
    /// numel must match exactly).
    pub fn len(&self) -> usize {
        self.runs * self.run
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Number of contiguous runs.
    pub fn runs(&self) -> usize {
        self.runs
    }

    /// Elements per contiguous run.
    pub fn run_len(&self) -> usize {
        self.run
    }

    /// Exclusive access to run `i`. Panics if `i` is out of range.
    pub fn run_mut(&mut self, i: usize) -> &mut [f32] {
        assert!(i < self.runs, "run index {i} out of range ({} runs)", self.runs);
        // SAFETY: the constructor contract grants this view exclusive
        // write access to run `i` for 'a; `&mut self` makes this the
        // only live slice into it; bounds checked just above.
        unsafe { std::slice::from_raw_parts_mut(self.ptr.add(i * self.stride), self.run) }
    }

    /// Exclusive access to `width` elements starting at run-major
    /// element offset `off` — how backends address "row `r` of a
    /// `rows × width` output" without knowing the run layout. Panics if
    /// the span is out of range or straddles a run boundary; callers
    /// validate geometry up front (see the CPU backend's `check_outs`)
    /// so the hot path never trips this.
    pub fn span_mut(&mut self, off: usize, width: usize) -> &mut [f32] {
        if width == 0 {
            return &mut [];
        }
        assert!(self.run > 0, "span into an empty destination");
        let (run_idx, in_run) = (off / self.run, off % self.run);
        assert!(
            in_run + width <= self.run && run_idx < self.runs,
            "span [{off}, +{width}) exceeds or straddles runs of {} elements",
            self.run
        );
        // SAFETY: as in `run_mut`; the span is inside run `run_idx`.
        unsafe { std::slice::from_raw_parts_mut(self.ptr.add(run_idx * self.stride + in_run), width) }
    }

    /// Scatter `src` (run-major) across the destination runs. Panics on
    /// length mismatch — callers validate numel before writing.
    pub fn copy_from(&mut self, src: &[f32]) {
        assert_eq!(src.len(), self.len(), "copy_from length mismatch");
        let run = self.run;
        for i in 0..self.runs {
            self.run_mut(i).copy_from_slice(&src[i * run..][..run]);
        }
    }
}

/// Lifetime-erased value stored in the request queue. Borrowed slices
/// become raw pointer + length so no reference type crosses the channel
/// (a reference must never dangle, even unused; a raw pointer may).
enum RawValue {
    F32(Vec<f32>),
    I32(Vec<i32>),
    BorrowedF32(*const f32, usize),
    BorrowedI32(*const i32, usize),
}

// SAFETY: the raw pointers are only dereferenced by the executor thread
// while the submitting thread is parked inside `execute` keeping the
// borrow alive (see `execute`'s safety note); `f32`/`i32` data is Send.
unsafe impl Send for RawValue {}

impl RawValue {
    fn len(&self) -> usize {
        match self {
            RawValue::F32(v) => v.len(),
            RawValue::I32(v) => v.len(),
            RawValue::BorrowedF32(_, n) | RawValue::BorrowedI32(_, n) => *n,
        }
    }
}

/// Lifetime-erased [`OutView`] in the request queue: the mutable
/// counterpart of `RawValue::BorrowedF32`. The executor thread turns it
/// back into an [`OutView`] (via [`OutView::from_raw_strided`]) before
/// handing it to the backend, so all writes go through the safe
/// accessors.
struct RawOutView {
    ptr: *mut f32,
    runs: usize,
    run: usize,
    stride: usize,
}

// SAFETY: dereferenced only by the executor thread while the submitter
// is parked in `execute_into` keeping its exclusive destination borrows
// alive (blocking reply protocol — see `execute`'s safety note).
unsafe impl Send for RawOutView {}

/// Where a request's outputs go.
enum RawOut {
    /// Legacy boundary: the reply carries freshly allocated `Vec`s
    /// (each one counted in `ExecPool::output_allocs`).
    Alloc,
    /// Write-into boundary: results are scattered into caller-owned
    /// destinations; the reply carries nothing.
    Into(Vec<RawOutView>),
}

struct Request {
    artifact: usize,
    inputs: Vec<RawValue>,
    out: RawOut,
    reply: mpsc::SyncSender<Result<Vec<Vec<f32>>, PoolError>>,
}

struct SharedQueue {
    q: Mutex<VecDeque<Request>>,
    cv: Condvar,
    closed: Mutex<bool>,
}

/// Thread pool of executor threads, each owning one thread-confined
/// [`BackendSession`] of the pool's selected backend.
pub struct ExecPool {
    queue: Arc<SharedQueue>,
    handles: Vec<std::thread::JoinHandle<()>>,
    /// Requests executed (per-pool counter, for perf accounting).
    pub executed: Arc<AtomicUsize>,
    /// Output buffers allocated at the boundary (legacy `execute` reply
    /// `Vec`s). `execute_into` never moves it.
    out_allocs: Arc<AtomicUsize>,
    manifest: Arc<Manifest>,
    backend: Arc<dyn ExecBackend>,
}

impl ExecPool {
    /// Build a pool with `threads` executor threads on the backend
    /// selected by `MPK_BACKEND` (native CPU unless set to `pjrt`).
    pub fn new(manifest: Manifest, threads: usize) -> Result<ExecPool, PoolError> {
        Self::with_backend(manifest, threads, BackendKind::from_env())
    }

    /// Build a pool on an explicit backend; each executor thread builds
    /// its own [`BackendSession`] and the call fails if any session
    /// cannot be constructed (e.g. PJRT selected in a stub build).
    pub fn with_backend(
        manifest: Manifest,
        threads: usize,
        kind: BackendKind,
    ) -> Result<ExecPool, PoolError> {
        let backend = backend::backend(kind);
        let manifest = Arc::new(manifest);
        let queue = Arc::new(SharedQueue {
            q: Mutex::new(VecDeque::new()),
            cv: Condvar::new(),
            closed: Mutex::new(false),
        });
        let executed = Arc::new(AtomicUsize::new(0));
        let out_allocs = Arc::new(AtomicUsize::new(0));
        let mut handles = Vec::new();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<(), PoolError>>();
        for t in 0..threads.max(1) {
            let queue = queue.clone();
            let manifest = manifest.clone();
            let executed = executed.clone();
            let out_allocs = out_allocs.clone();
            let ready = ready_tx.clone();
            let backend = backend.clone();
            handles.push(
                std::thread::Builder::new()
                    .name(format!("mpk-exec-{t}"))
                    .spawn(move || {
                        executor_thread(queue, manifest, backend, executed, out_allocs, ready)
                    })
                    .map_err(|e| PoolError(e.to_string()))?,
            );
        }
        drop(ready_tx);
        // session construction is checked before the pool is handed
        // out, so backend unavailability is a clean construction error.
        for _ in 0..threads.max(1) {
            ready_rx.recv().map_err(|e| PoolError(e.to_string()))??;
        }
        Ok(ExecPool { queue, handles, executed, out_allocs, manifest, backend })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Which backend this pool dispatches to.
    pub fn backend_kind(&self) -> BackendKind {
        self.backend.kind()
    }

    /// The backend's stable identity (tags `BENCH_*.json` records).
    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// Output buffers allocated at the pool boundary so far. The
    /// write-into path keeps this frozen; only the allocating `execute`
    /// reply moves it (one per output `Vec` handed to a caller).
    pub fn output_allocs(&self) -> usize {
        self.out_allocs.load(Ordering::Relaxed)
    }

    /// Erase lifetimes, enqueue, and block for the reply.
    ///
    /// SAFETY (borrowed inputs *and* output destinations): borrowed
    /// slices and `OutView`s are erased to raw pointers before entering
    /// the queue. This function does not return until `rx.recv()`
    /// resolves, which happens only after the executor thread has (a)
    /// finished `run_one` — every read of the inputs and every write to
    /// the destinations done — and sent the reply, or (b) died, dropping
    /// the reply sender after its last access. Either way the caller's
    /// borrows, which live across this entire call, outlive every
    /// dereference.
    fn submit(
        &self,
        artifact: usize,
        inputs: Vec<Value<'_>>,
        out: RawOut,
    ) -> Result<Vec<Vec<f32>>, PoolError> {
        let inputs: Vec<RawValue> = inputs
            .into_iter()
            .map(|v| match v {
                Value::F32(d) => RawValue::F32(d),
                Value::I32(d) => RawValue::I32(d),
                Value::Borrowed(s) => RawValue::BorrowedF32(s.as_ptr(), s.len()),
                Value::BorrowedI32(s) => RawValue::BorrowedI32(s.as_ptr(), s.len()),
            })
            .collect();
        let (tx, rx) = mpsc::sync_channel(1);
        {
            let mut q = self.queue.q.lock().unwrap();
            q.push_back(Request { artifact, inputs, out, reply: tx });
        }
        self.queue.cv.notify_one();
        rx.recv().map_err(|_| PoolError("executor thread died".into()))?
    }

    /// Execute artifact `artifact` (index into the manifest) with the
    /// given inputs; blocks until the result tuple (each element
    /// flattened to f32, freshly allocated) is ready. Compat wrapper
    /// over the same submission path as [`ExecPool::execute_into`] —
    /// output sizes are unknown until the artifact runs, so this is the
    /// boundary that allocates (counted in [`ExecPool::output_allocs`]).
    /// See [`ExecPool::submit`] for the borrowed-input safety argument.
    pub fn execute(
        &self,
        artifact: usize,
        inputs: Vec<Value<'_>>,
    ) -> Result<Vec<Vec<f32>>, PoolError> {
        self.submit(artifact, inputs, RawOut::Alloc)
    }

    /// Execute artifact `artifact`, writing each output into the
    /// corresponding caller-owned destination — the allocation-free
    /// boundary the persistent-kernel task bodies use. `outs` must
    /// carry exactly one [`OutView`] per artifact output, each sized to
    /// that output's numel; any mismatch returns `Err` **before a
    /// single element is written** (destination count is checked before
    /// execution, every destination length before the first scatter).
    /// Blocks until the executor thread has finished writing; the
    /// mutable destination borrows live across the call, which is what
    /// makes the erased pointers sound (see [`ExecPool::submit`]).
    pub fn execute_into(
        &self,
        artifact: usize,
        inputs: Vec<Value<'_>>,
        outs: &mut [OutView<'_>],
    ) -> Result<(), PoolError> {
        let raw = outs
            .iter()
            .map(|o| RawOutView { ptr: o.ptr, runs: o.runs, run: o.run, stride: o.stride })
            .collect();
        self.submit(artifact, inputs, RawOut::Into(raw)).map(|_| ())
    }

    /// Execute by artifact name (convenience for tests/examples).
    pub fn execute_by_name(
        &self,
        name: &str,
        inputs: Vec<Value<'_>>,
    ) -> Result<Vec<Vec<f32>>, PoolError> {
        let (idx, _) = self
            .manifest
            .find(name)
            .ok_or_else(|| PoolError(format!("unknown artifact {name}")))?;
        self.execute(idx, inputs)
    }

    /// [`ExecPool::execute_into`] by artifact name.
    pub fn execute_into_by_name(
        &self,
        name: &str,
        inputs: Vec<Value<'_>>,
        outs: &mut [OutView<'_>],
    ) -> Result<(), PoolError> {
        let (idx, _) = self
            .manifest
            .find(name)
            .ok_or_else(|| PoolError(format!("unknown artifact {name}")))?;
        self.execute_into(idx, inputs, outs)
    }
}

impl Drop for ExecPool {
    fn drop(&mut self) {
        *self.queue.closed.lock().unwrap() = true;
        self.queue.cv.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn executor_thread(
    queue: Arc<SharedQueue>,
    manifest: Arc<Manifest>,
    backend: Arc<dyn ExecBackend>,
    executed: Arc<AtomicUsize>,
    out_allocs: Arc<AtomicUsize>,
    ready: mpsc::Sender<Result<(), PoolError>>,
) {
    // Own session: nothing in it is Send (PJRT clients are Rc-based,
    // the CPU backend keeps per-thread scratch).
    let mut session = match backend.session(manifest.clone()) {
        Ok(s) => {
            let _ = ready.send(Ok(()));
            s
        }
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };

    loop {
        let req = {
            let mut q = queue.q.lock().unwrap();
            loop {
                if let Some(r) = q.pop_front() {
                    break r;
                }
                if *queue.closed.lock().unwrap() {
                    return;
                }
                q = queue.cv.wait(q).unwrap();
            }
        };
        let result = run_one(session.as_mut(), &manifest, &req, &out_allocs);
        executed.fetch_add(1, Ordering::Relaxed);
        let _ = req.reply.send(result);
    }
}

/// Validate one request against the manifest, re-materialize the erased
/// inputs/destinations, and dispatch to the backend session. Validation
/// order is part of the boundary contract: destination *count* (known
/// statically) is rejected before anything runs; input count, numel,
/// and dtype before the backend sees the request; the backend validates
/// every destination's numel and run geometry before its first write.
fn run_one(
    session: &mut dyn BackendSession,
    manifest: &Manifest,
    req: &Request,
    out_allocs: &AtomicUsize,
) -> Result<Vec<Vec<f32>>, PoolError> {
    let spec = &manifest.artifacts[req.artifact];
    // destination *count* is known statically — reject before running
    // so a miscounted call can never write anything at all.
    if let RawOut::Into(dsts) = &req.out {
        if dsts.len() != spec.outputs {
            return Err(PoolError(format!(
                "{}: expected {} output destinations, got {}",
                spec.name,
                spec.outputs,
                dsts.len()
            )));
        }
    }
    session.prepare(req.artifact)?;
    if req.inputs.len() != spec.inputs.len() {
        return Err(PoolError(format!(
            "{}: expected {} inputs, got {}",
            spec.name,
            spec.inputs.len(),
            req.inputs.len()
        )));
    }
    let mut ins: Vec<In<'_>> = Vec::with_capacity(req.inputs.len());
    for (v, s) in req.inputs.iter().zip(spec.inputs.iter()) {
        if v.len() != s.numel() {
            return Err(PoolError(format!(
                "{}: input numel mismatch {} vs {:?}",
                spec.name,
                v.len(),
                s.shape
            )));
        }
        let arg = match (v, s.ty) {
            (RawValue::F32(data), ArgType::F32) => In::F32(data.as_slice()),
            (RawValue::I32(data), ArgType::I32) => In::I32(data.as_slice()),
            (RawValue::BorrowedF32(p, n), ArgType::F32) => {
                // SAFETY: the submitter is blocked in `execute` keeping
                // the arena borrow alive until we reply (see `submit`).
                In::F32(unsafe { std::slice::from_raw_parts(*p, *n) })
            }
            (RawValue::BorrowedI32(p, n), ArgType::I32) => {
                // SAFETY: as above.
                In::I32(unsafe { std::slice::from_raw_parts(*p, *n) })
            }
            _ => return Err(PoolError(format!("{}: dtype mismatch", spec.name))),
        };
        ins.push(arg);
    }
    match &req.out {
        RawOut::Alloc => {
            let parts = session.execute(req.artifact, &ins)?;
            out_allocs.fetch_add(parts.len(), Ordering::Relaxed);
            Ok(parts)
        }
        RawOut::Into(dsts) => {
            let mut views: Vec<OutView<'_>> = dsts
                .iter()
                .map(|d| {
                    // SAFETY: the submitter is parked in `execute_into`
                    // keeping its exclusive destination borrows alive
                    // until we reply; the raw parts came from a real
                    // OutView, so the run layout contract holds.
                    unsafe { OutView::from_raw_strided(d.ptr, d.runs, d.run, d.stride) }
                })
                .collect();
            session.execute_into(req.artifact, &ins, &mut views)?;
            Ok(Vec::new())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::Manifest;

    /// CPU-backend pool over the compiled-in manifest: always available
    /// (no artifacts dir, no PJRT library).
    fn pool(threads: usize) -> ExecPool {
        ExecPool::with_backend(Manifest::builtin(), threads, BackendKind::Cpu).unwrap()
    }

    // -- protocol-level tests (these are the ones the miri gate runs
    //    over the channel-crossing unsafe and the OutView accessors). --

    #[test]
    fn typed_value_accessors_error_instead_of_panicking() {
        // a dtype mismatch inside a task body must surface as a typed
        // error the binder can record (ExecCore::fail → EngineError::
        // Task), never a panic that kills an executor thread.
        let f = Value::F32(vec![1.0, 2.0]);
        let i = Value::I32(vec![3, 4]);
        assert_eq!(f.as_f32().unwrap(), &[1.0, 2.0]);
        assert_eq!(i.as_i32().unwrap(), &[3, 4]);
        let err = i.as_f32().unwrap_err();
        assert!(err.0.contains("expected f32") && err.0.contains("i32"), "got: {}", err.0);
        let err = f.as_i32().unwrap_err();
        assert!(err.0.contains("expected i32") && err.0.contains("f32"), "got: {}", err.0);
        // borrowed variants behave like their owned twins.
        let buf = [9.0f32];
        assert_eq!(Value::Borrowed(&buf).as_f32().unwrap(), &[9.0]);
        let ids = [7i32];
        assert_eq!(Value::BorrowedI32(&ids).as_i32().unwrap(), &[7]);
        assert!(Value::Borrowed(&buf).as_i32().is_err());
    }

    #[test]
    fn out_view_scatter_writes_strided_runs_only() {
        // 4×6 row-major buffer; destination = rows 0..4, cols 2..5
        // (runs of 3, stride 6, starting at offset 2).
        let mut dst = vec![0.0f32; 24];
        {
            // SAFETY: `dst` outlives the view and nothing else touches
            // the runs while it lives.
            let mut v = unsafe { OutView::from_raw_strided(dst.as_mut_ptr().add(2), 4, 3, 6) };
            assert_eq!((v.runs(), v.run_len(), v.len()), (4, 3, 12));
            let src: Vec<f32> = (1..=12).map(|i| i as f32).collect();
            v.copy_from(&src);
        }
        for r in 0..4 {
            for c in 0..6 {
                let want = if (2..5).contains(&c) { (r * 3 + (c - 2) + 1) as f32 } else { 0.0 };
                assert_eq!(dst[r * 6 + c], want, "row {r} col {c}");
            }
        }
    }

    #[test]
    fn out_view_from_slice_is_one_contiguous_run() {
        let mut dst = vec![0.0f32; 8];
        let mut v = OutView::from_slice(&mut dst);
        assert_eq!((v.runs(), v.run_len(), v.len()), (1, 8, 8));
        v.copy_from(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0]);
        drop(v);
        assert_eq!(dst, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0]);
    }

    #[test]
    fn out_view_span_mut_addresses_rows_across_runs() {
        // runs of 4 with stride 6: row-major spans of width 2 must land
        // inside the right run, and a straddling span must panic (see
        // the should_panic sibling below).
        let mut dst = vec![0.0f32; 12];
        {
            // SAFETY: `dst` outlives the view; nothing else touches it.
            let mut v = unsafe { OutView::from_raw_strided(dst.as_mut_ptr(), 2, 4, 6) };
            v.span_mut(0, 2).copy_from_slice(&[1.0, 2.0]);
            v.span_mut(2, 2).copy_from_slice(&[3.0, 4.0]);
            v.span_mut(4, 4).copy_from_slice(&[5.0, 6.0, 7.0, 8.0]);
            assert!(v.span_mut(0, 0).is_empty());
            assert_eq!(v.run_mut(1), &[5.0, 6.0, 7.0, 8.0]);
        }
        assert_eq!(dst, vec![1.0, 2.0, 3.0, 4.0, 0.0, 0.0, 5.0, 6.0, 7.0, 8.0, 0.0, 0.0]);
    }

    #[test]
    #[should_panic(expected = "straddles")]
    fn out_view_span_straddling_a_run_boundary_panics() {
        let mut dst = vec![0.0f32; 12];
        // SAFETY: `dst` outlives the view; nothing else touches it.
        let mut v = unsafe { OutView::from_raw_strided(dst.as_mut_ptr(), 2, 4, 6) };
        let _ = v.span_mut(2, 4); // elements 2..6 cross the run edge at 4
    }

    #[test]
    fn out_view_crosses_threads_like_the_reply_protocol() {
        // the erased destination is re-materialized and written by
        // another thread while this one "blocks" (the scope join models
        // the reply recv) — the exact shape of the execute_into channel
        // crossing, including the from_raw_strided round trip.
        let mut dst = vec![0.0f32; 12];
        let raw = RawOutView { ptr: dst.as_mut_ptr(), runs: 3, run: 2, stride: 4 };
        std::thread::scope(|s| {
            s.spawn(move || {
                // SAFETY: the owning thread is parked in scope-join
                // until this write completes (blocking reply protocol).
                let mut v = unsafe { OutView::from_raw_strided(raw.ptr, raw.runs, raw.run, raw.stride) };
                v.copy_from(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
            });
        });
        assert_eq!(dst, vec![1.0, 2.0, 0.0, 0.0, 3.0, 4.0, 0.0, 0.0, 5.0, 6.0, 0.0, 0.0]);
    }

    #[test]
    #[should_panic(expected = "overlapping output runs")]
    fn overlapping_strided_runs_rejected() {
        let mut dst = vec![0.0f32; 8];
        // run 4 > stride 2 would self-overlap.
        let _ = unsafe { OutView::from_raw_strided(dst.as_mut_ptr(), 2, 4, 2) };
    }

    // -- execution tests: run un-gated on the CPU backend over the
    //    compiled-in manifest. --

    #[test]
    fn cpu_pool_reports_backend_identity() {
        let p = pool(1);
        assert_eq!(p.backend_kind(), BackendKind::Cpu);
        assert_eq!(p.backend_name(), "cpu");
    }

    #[test]
    fn pjrt_pool_is_a_clean_construction_error_in_stub_builds() {
        match ExecPool::with_backend(Manifest::builtin(), 1, BackendKind::Pjrt) {
            Err(e) => assert!(e.0.contains("stub"), "unexpected error: {e}"),
            Ok(p) => {
                // a vendored real PJRT binding makes this succeed.
                assert_eq!(p.backend_kind(), BackendKind::Pjrt);
            }
        }
    }

    #[test]
    fn matmul_artifact_computes() {
        let p = pool(1);
        // x = ones(1,256), w[i,j] = 2 if i==j else 0 for i,j < 128.
        let x = vec![1.0f32; 256];
        let mut w = vec![0.0f32; 256 * 128];
        for i in 0..128 {
            w[i * 128 + i] = 2.0;
        }
        let out = p
            .execute_by_name("matmul_b1_k256_n128", vec![Value::F32(x), Value::F32(w)])
            .unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].len(), 128);
        for &v in &out[0] {
            assert!((v - 2.0).abs() < 1e-5, "got {v}");
        }
    }

    #[test]
    fn borrowed_inputs_match_owned() {
        let p = pool(1);
        let a = vec![3.0f32; 256];
        let b = vec![4.0f32; 256];
        let owned = p
            .execute_by_name("add_b1", vec![Value::F32(a.clone()), Value::F32(b.clone())])
            .unwrap();
        let borrowed = p
            .execute_by_name("add_b1", vec![Value::Borrowed(&a), Value::Borrowed(&b)])
            .unwrap();
        assert_eq!(owned, borrowed);
    }

    #[test]
    fn execute_into_matches_execute_bitwise() {
        let p = pool(1);
        let a = vec![3.5f32; 256];
        let b = vec![0.25f32; 256];
        let owned = p
            .execute_by_name("add_b1", vec![Value::Borrowed(&a), Value::Borrowed(&b)])
            .unwrap();
        let before = p.output_allocs();
        let mut dst = vec![0.0f32; 256];
        p.execute_into_by_name(
            "add_b1",
            vec![Value::Borrowed(&a), Value::Borrowed(&b)],
            &mut [OutView::from_slice(&mut dst)],
        )
        .unwrap();
        // bit-identical results, and the write-into boundary allocated
        // no output buffer.
        assert_eq!(owned[0], dst);
        assert_eq!(p.output_allocs(), before, "execute_into moved the alloc counter");
    }

    #[test]
    fn execute_into_validates_before_writing() {
        let p = pool(1);
        let a = vec![1.0f32; 256];
        let b = vec![2.0f32; 256];
        // wrong destination count: rejected before execution.
        let mut d0 = vec![-7.0f32; 256];
        let mut d1 = vec![-7.0f32; 256];
        let err = p
            .execute_into_by_name(
                "add_b1",
                vec![Value::Borrowed(&a), Value::Borrowed(&b)],
                &mut [OutView::from_slice(&mut d0), OutView::from_slice(&mut d1)],
            )
            .unwrap_err();
        assert!(err.0.contains("output destinations"), "{err}");
        assert!(d0.iter().chain(&d1).all(|&v| v == -7.0), "partial write on count mismatch");
        // wrong destination length: rejected before the first element.
        let mut short = vec![-7.0f32; 8];
        let err = p
            .execute_into_by_name(
                "add_b1",
                vec![Value::Borrowed(&a), Value::Borrowed(&b)],
                &mut [OutView::from_slice(&mut short)],
            )
            .unwrap_err();
        assert!(err.0.contains("numel mismatch"), "{err}");
        assert!(short.iter().all(|&v| v == -7.0), "partial write on length mismatch");
    }

    #[test]
    fn concurrent_execution_from_many_threads() {
        let p = pool(2);
        let p = std::sync::Arc::new(p);
        std::thread::scope(|s| {
            for t in 0..8 {
                let p = p.clone();
                s.spawn(move || {
                    for i in 0..4 {
                        let scale = (t * 4 + i + 1) as f32;
                        let a = vec![scale; 256];
                        let b = vec![1.0f32; 256];
                        // exercise the borrowed path under concurrency:
                        // the submitting thread parks in `execute_into`
                        // while the executor reads the inputs and
                        // writes the destination.
                        let mut out = vec![0.0f32; 256];
                        p.execute_into_by_name(
                            "add_b1",
                            vec![Value::Borrowed(&a), Value::Borrowed(&b)],
                            &mut [OutView::from_slice(&mut out)],
                        )
                        .unwrap();
                        for &v in &out {
                            assert!((v - (scale + 1.0)).abs() < 1e-6);
                        }
                    }
                });
            }
        });
        assert_eq!(p.executed.load(Ordering::Relaxed), 32);
        assert_eq!(p.output_allocs(), 0, "write-into boundary allocated output buffers");
    }

    #[test]
    fn input_validation_errors() {
        let p = pool(1);
        let err = p.execute_by_name("add_b1", vec![Value::F32(vec![0.0; 3])]).unwrap_err();
        assert!(err.0.contains("expected 2 inputs"), "{err}");
        let err = p
            .execute_by_name("add_b1", vec![Value::F32(vec![0.0; 3]), Value::F32(vec![0.0; 256])])
            .unwrap_err();
        assert!(err.0.contains("numel mismatch"), "{err}");
        let err = p
            .execute_by_name("add_b1", vec![Value::I32(vec![0; 256]), Value::F32(vec![0.0; 256])])
            .unwrap_err();
        assert!(err.0.contains("dtype mismatch"), "{err}");
    }
}
