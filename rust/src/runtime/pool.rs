//! PJRT execution pool.
//!
//! The `xla` crate's `PjRtClient` is `Rc`-based (`!Send`), and
//! `execute()` clones that `Rc` per output buffer — so a client must
//! never be shared across threads. The pool therefore runs K *executor
//! threads, each owning its own client and its own compiled copy of
//! every artifact*; megakernel workers submit host tensors over a
//! channel and block on a per-request reply channel. Python is never
//! involved: artifacts are HLO text on disk, compiled once per executor
//! thread at pool construction.
//!
//! Inputs may be **borrowed** ([`Value::Borrowed`] /
//! [`Value::BorrowedI32`]): the zero-copy hot path hands the pool
//! slices that point straight into the `exec::store` tensor arena, so a
//! matmul/attention task marshals no input buffer at all. Borrowed
//! slices cross the thread boundary as raw pointer + length
//! ([`RawValue`]); this is sound because [`ExecPool::execute`] blocks
//! on the reply channel until the executor thread has finished building
//! input literals and replied (or died) — the borrow outlives every
//! read. See the safety note on `execute`.

use crate::runtime::manifest::{ArgType, Manifest};
use std::collections::VecDeque;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};

/// A host tensor crossing the pool boundary. Borrowed variants carry a
/// slice borrowed from the caller (typically a tensor-arena view) for
/// the duration of the `execute` call.
#[derive(Clone, Debug)]
pub enum Value<'a> {
    F32(Vec<f32>),
    I32(Vec<i32>),
    Borrowed(&'a [f32]),
    BorrowedI32(&'a [i32]),
}

impl Value<'_> {
    pub fn len(&self) -> usize {
        match self {
            Value::F32(v) => v.len(),
            Value::I32(v) => v.len(),
            Value::Borrowed(s) => s.len(),
            Value::BorrowedI32(s) => s.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_f32(&self) -> &[f32] {
        match self {
            Value::F32(v) => v,
            Value::Borrowed(s) => *s,
            _ => panic!("expected f32 value"),
        }
    }
}

/// Lifetime-erased value stored in the request queue. Borrowed slices
/// become raw pointer + length so no reference type crosses the channel
/// (a reference must never dangle, even unused; a raw pointer may).
enum RawValue {
    F32(Vec<f32>),
    I32(Vec<i32>),
    BorrowedF32(*const f32, usize),
    BorrowedI32(*const i32, usize),
}

// SAFETY: the raw pointers are only dereferenced by the executor thread
// while the submitting thread is parked inside `execute` keeping the
// borrow alive (see `execute`'s safety note); `f32`/`i32` data is Send.
unsafe impl Send for RawValue {}

impl RawValue {
    fn len(&self) -> usize {
        match self {
            RawValue::F32(v) => v.len(),
            RawValue::I32(v) => v.len(),
            RawValue::BorrowedF32(_, n) | RawValue::BorrowedI32(_, n) => *n,
        }
    }
}

struct Request {
    artifact: usize,
    inputs: Vec<RawValue>,
    reply: mpsc::SyncSender<Result<Vec<Vec<f32>>, String>>,
}

struct SharedQueue {
    q: Mutex<VecDeque<Request>>,
    cv: Condvar,
    closed: Mutex<bool>,
}

/// Thread pool of PJRT executor threads.
pub struct ExecPool {
    queue: Arc<SharedQueue>,
    handles: Vec<std::thread::JoinHandle<()>>,
    /// Requests executed (per-pool counter, for perf accounting).
    pub executed: Arc<AtomicUsize>,
    manifest: Arc<Manifest>,
}

impl ExecPool {
    /// Build a pool with `threads` executor threads; each compiles all
    /// artifacts in `manifest` on its own CPU client.
    pub fn new(manifest: Manifest, threads: usize) -> Result<ExecPool, String> {
        let manifest = Arc::new(manifest);
        let queue = Arc::new(SharedQueue {
            q: Mutex::new(VecDeque::new()),
            cv: Condvar::new(),
            closed: Mutex::new(false),
        });
        let executed = Arc::new(AtomicUsize::new(0));
        // compile-check on the main thread first for a clean error.
        let mut handles = Vec::new();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<(), String>>();
        for t in 0..threads.max(1) {
            let queue = queue.clone();
            let manifest = manifest.clone();
            let executed = executed.clone();
            let ready = ready_tx.clone();
            handles.push(
                std::thread::Builder::new()
                    .name(format!("pjrt-exec-{t}"))
                    .spawn(move || executor_thread(queue, manifest, executed, ready))
                    .map_err(|e| e.to_string())?,
            );
        }
        drop(ready_tx);
        for _ in 0..threads.max(1) {
            ready_rx.recv().map_err(|e| e.to_string())??;
        }
        Ok(ExecPool { queue, handles, executed, manifest })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Execute artifact `artifact` (index into the manifest) with the
    /// given inputs; blocks until the result tuple (each element
    /// flattened to f32) is ready.
    ///
    /// SAFETY (borrowed inputs): the borrowed slices are erased to raw
    /// pointers before entering the queue. This function does not
    /// return until `rx.recv()` resolves, which happens only after the
    /// executor thread has (a) finished `run_one` — every read of the
    /// inputs done — and sent the reply, or (b) died, dropping the
    /// reply sender after its last read. Either way the caller's
    /// borrow, which lives across this entire call, outlives every
    /// dereference.
    pub fn execute(&self, artifact: usize, inputs: Vec<Value<'_>>) -> Result<Vec<Vec<f32>>, String> {
        let inputs: Vec<RawValue> = inputs
            .into_iter()
            .map(|v| match v {
                Value::F32(d) => RawValue::F32(d),
                Value::I32(d) => RawValue::I32(d),
                Value::Borrowed(s) => RawValue::BorrowedF32(s.as_ptr(), s.len()),
                Value::BorrowedI32(s) => RawValue::BorrowedI32(s.as_ptr(), s.len()),
            })
            .collect();
        let (tx, rx) = mpsc::sync_channel(1);
        {
            let mut q = self.queue.q.lock().unwrap();
            q.push_back(Request { artifact, inputs, reply: tx });
        }
        self.queue.cv.notify_one();
        rx.recv().map_err(|_| "executor thread died".to_string())?
    }

    /// Execute by artifact name (convenience for tests/examples).
    pub fn execute_by_name(&self, name: &str, inputs: Vec<Value<'_>>) -> Result<Vec<Vec<f32>>, String> {
        let (idx, _) = self.manifest.find(name).ok_or_else(|| format!("unknown artifact {name}"))?;
        self.execute(idx, inputs)
    }
}

impl Drop for ExecPool {
    fn drop(&mut self) {
        *self.queue.closed.lock().unwrap() = true;
        self.queue.cv.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn executor_thread(
    queue: Arc<SharedQueue>,
    manifest: Arc<Manifest>,
    executed: Arc<AtomicUsize>,
    ready: mpsc::Sender<Result<(), String>>,
) {
    // Own client + own compiled executables: nothing here is Send.
    // Artifacts compile lazily on first use (compiling all ~30 up front
    // costs tens of seconds; a typical run touches a handful).
    let client = match xla::PjRtClient::cpu() {
        Ok(c) => {
            let _ = ready.send(Ok(()));
            c
        }
        Err(e) => {
            let _ = ready.send(Err(e.to_string()));
            return;
        }
    };
    let mut exes: Vec<Option<xla::PjRtLoadedExecutable>> =
        (0..manifest.artifacts.len()).map(|_| None).collect();

    loop {
        let req = {
            let mut q = queue.q.lock().unwrap();
            loop {
                if let Some(r) = q.pop_front() {
                    break r;
                }
                if *queue.closed.lock().unwrap() {
                    return;
                }
                q = queue.cv.wait(q).unwrap();
            }
        };
        let result = run_one(&client, &mut exes, &manifest, &req);
        executed.fetch_add(1, Ordering::Relaxed);
        let _ = req.reply.send(result);
    }
}

fn run_one(
    client: &xla::PjRtClient,
    exes: &mut [Option<xla::PjRtLoadedExecutable>],
    manifest: &Manifest,
    req: &Request,
) -> Result<Vec<Vec<f32>>, String> {
    let spec = &manifest.artifacts[req.artifact];
    if exes[req.artifact].is_none() {
        let proto = xla::HloModuleProto::from_text_file(
            spec.path.to_str().ok_or("non-utf8 path")?,
        )
        .map_err(|e| format!("{}: {e}", spec.name))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        exes[req.artifact] =
            Some(client.compile(&comp).map_err(|e| format!("compile {}: {e}", spec.name))?);
    }
    if req.inputs.len() != spec.inputs.len() {
        return Err(format!(
            "{}: expected {} inputs, got {}",
            spec.name,
            spec.inputs.len(),
            req.inputs.len()
        ));
    }
    let mut literals = Vec::with_capacity(req.inputs.len());
    for (v, s) in req.inputs.iter().zip(spec.inputs.iter()) {
        if v.len() != s.numel() {
            return Err(format!(
                "{}: input numel mismatch {} vs {:?}",
                spec.name,
                v.len(),
                s.shape
            ));
        }
        let dims: Vec<i64> = s.shape.iter().map(|&d| d as i64).collect();
        let lit = match (v, s.ty) {
            (RawValue::F32(data), ArgType::F32) => {
                xla::Literal::vec1(data).reshape(&dims).map_err(|e| e.to_string())?
            }
            (RawValue::I32(data), ArgType::I32) => {
                xla::Literal::vec1(data).reshape(&dims).map_err(|e| e.to_string())?
            }
            (RawValue::BorrowedF32(p, n), ArgType::F32) => {
                // SAFETY: the submitter is blocked in `execute` keeping
                // the arena borrow alive until we reply (see there).
                let data = unsafe { std::slice::from_raw_parts(*p, *n) };
                xla::Literal::vec1(data).reshape(&dims).map_err(|e| e.to_string())?
            }
            (RawValue::BorrowedI32(p, n), ArgType::I32) => {
                // SAFETY: as above.
                let data = unsafe { std::slice::from_raw_parts(*p, *n) };
                xla::Literal::vec1(data).reshape(&dims).map_err(|e| e.to_string())?
            }
            _ => return Err(format!("{}: dtype mismatch", spec.name)),
        };
        literals.push(lit);
    }
    let out = exes[req.artifact]
        .as_ref()
        .unwrap()
        .execute::<xla::Literal>(&literals)
        .map_err(|e| e.to_string())?;
    let tuple = out[0][0].to_literal_sync().map_err(|e| e.to_string())?;
    let parts = tuple.to_tuple().map_err(|e| e.to_string())?;
    if parts.len() != spec.outputs {
        return Err(format!("{}: expected {} outputs, got {}", spec.name, spec.outputs, parts.len()));
    }
    parts
        .into_iter()
        .map(|p| p.to_vec::<f32>().map_err(|e| e.to_string()))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::Manifest;

    fn pool(threads: usize) -> Option<ExecPool> {
        let m = Manifest::load(&Manifest::default_dir()).ok()?;
        Some(ExecPool::new(m, threads).expect("pool construction"))
    }

    #[test]
    fn matmul_artifact_computes() {
        let Some(p) = pool(1) else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        // x = ones(1,256), w = identity-ish: w[i,j] = 1 if i==j else 0
        let x = vec![1.0f32; 256];
        let mut w = vec![0.0f32; 256 * 128];
        for i in 0..128 {
            w[i * 128 + i] = 2.0; // rows 0..128 map to cols scaled by 2
        }
        let out = p
            .execute_by_name("matmul_b1_k256_n128", vec![Value::F32(x), Value::F32(w)])
            .unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].len(), 128);
        for &v in &out[0] {
            assert!((v - 2.0).abs() < 1e-5, "got {v}");
        }
    }

    #[test]
    fn borrowed_inputs_match_owned() {
        let Some(p) = pool(1) else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let a = vec![3.0f32; 256];
        let b = vec![4.0f32; 256];
        let owned = p
            .execute_by_name("add_b1", vec![Value::F32(a.clone()), Value::F32(b.clone())])
            .unwrap();
        let borrowed = p
            .execute_by_name("add_b1", vec![Value::Borrowed(&a), Value::Borrowed(&b)])
            .unwrap();
        assert_eq!(owned, borrowed);
    }

    #[test]
    fn concurrent_execution_from_many_threads() {
        let Some(p) = pool(2) else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let p = std::sync::Arc::new(p);
        std::thread::scope(|s| {
            for t in 0..8 {
                let p = p.clone();
                s.spawn(move || {
                    for i in 0..4 {
                        let scale = (t * 4 + i + 1) as f32;
                        let a = vec![scale; 256];
                        let b = vec![1.0f32; 256];
                        // exercise the borrowed path under concurrency:
                        // the submitting thread parks in `execute`
                        // while the executor reads the slices.
                        let out = p
                            .execute_by_name("add_b1", vec![Value::Borrowed(&a), Value::Borrowed(&b)])
                            .unwrap();
                        for &v in &out[0] {
                            assert!((v - (scale + 1.0)).abs() < 1e-6);
                        }
                    }
                });
            }
        });
        assert_eq!(p.executed.load(Ordering::Relaxed), 32);
    }

    #[test]
    fn input_validation_errors() {
        let Some(p) = pool(1) else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let err = p.execute_by_name("add_b1", vec![Value::F32(vec![0.0; 3])]).unwrap_err();
        assert!(err.contains("expected 2 inputs"), "{err}");
        let err = p
            .execute_by_name("add_b1", vec![Value::F32(vec![0.0; 3]), Value::F32(vec![0.0; 256])])
            .unwrap_err();
        assert!(err.contains("numel mismatch"), "{err}");
    }
}
