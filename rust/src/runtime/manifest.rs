//! Reader for `artifacts/manifest.json` (emitted by `python -m
//! compile.aot`). Describes every AOT-compiled HLO-text artifact: name,
//! file, input signature and output arity, plus the tiny-model metadata
//! the exec layer needs (S_MAX, tile width, batch sizes).

use crate::runtime::backend::BackendKind;
use crate::util::{json_parse, Json};
use std::path::{Path, PathBuf};

crate::util::boundary_error! {
    /// Typed failure from manifest loading / artifact resolution — the
    /// `runtime` boundary error for [`Manifest::load`] and the
    /// graph-shape checks in `exec::real`. Callers that still speak
    /// `String` (validation helpers, examples) convert through the
    /// `From<ManifestError> for String` shim; the serving layer
    /// converts it into its own typed error instead — a bad artifacts
    /// dir degrades into `EngineError`, never a panic.
    enum ManifestError {
        /// Reading/parsing `manifest.json` failed (missing dir, bad
        /// JSON, missing keys).
        Load { detail: String } => "{detail}",
        /// The manifest's tiny-model metadata disagrees with the model
        /// this binary compiles its decode graph for.
        ModelMismatch { manifest: String, builtin: String } =>
            "manifest model {manifest} does not match the compiled decode graph {builtin}",
        /// A named tensor is absent from the compiled graph.
        MissingTensor { name: String } => "missing tensor {name} in compiled graph",
        /// An op's width does not tile by the manifest's `tile_n`.
        NotTileable { op: String, n: usize, tile_n: usize } =>
            "op {op}: width {n} is not divisible by tile_n {tile_n}",
    }
}

/// Element type tag of an artifact input.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ArgType {
    F32,
    I32,
}

/// One input slot of an artifact.
#[derive(Clone, Debug)]
pub struct ArgSpec {
    pub shape: Vec<usize>,
    pub ty: ArgType,
}

impl ArgSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One AOT-compiled computation.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    pub path: PathBuf,
    pub inputs: Vec<ArgSpec>,
    /// Number of tuple outputs.
    pub outputs: usize,
}

/// Tiny-model metadata mirrored from python `TinyConfig`.
#[derive(Clone, Copy, Debug)]
pub struct TinyModelMeta {
    pub layers: usize,
    pub d_model: usize,
    pub heads: usize,
    pub kv_heads: usize,
    pub head_dim: usize,
    pub ffn: usize,
    pub vocab: usize,
}

impl TinyModelMeta {
    pub fn q_dim(&self) -> usize {
        self.heads * self.head_dim
    }

    pub fn kv_dim(&self) -> usize {
        self.kv_heads * self.head_dim
    }
}

/// The parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub model: TinyModelMeta,
    pub s_max: usize,
    pub tile_n: usize,
    pub batch_sizes: Vec<usize>,
    pub artifacts: Vec<ArtifactSpec>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest, ManifestError> {
        Self::load_impl(dir).map_err(|detail| ManifestError::Load { detail })
    }

    fn load_impl(dir: &Path) -> Result<Manifest, String> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .map_err(|e| format!("reading manifest.json in {dir:?}: {e} — run `make artifacts`"))?;
        let j = json_parse::parse(&text)?;
        let model = j.get("model").ok_or("missing model")?;
        let get = |o: &Json, k: &str| -> Result<usize, String> {
            o.get(k).and_then(Json::as_usize).ok_or_else(|| format!("missing {k}"))
        };
        let meta = TinyModelMeta {
            layers: get(model, "layers")?,
            d_model: get(model, "d_model")?,
            heads: get(model, "heads")?,
            kv_heads: get(model, "kv_heads")?,
            head_dim: get(model, "head_dim")?,
            ffn: get(model, "ffn")?,
            vocab: get(model, "vocab")?,
        };
        let batch_sizes = j
            .get("batch_sizes")
            .and_then(Json::as_arr)
            .ok_or("missing batch_sizes")?
            .iter()
            .filter_map(Json::as_usize)
            .collect();
        let mut artifacts = Vec::new();
        for a in j.get("artifacts").and_then(Json::as_arr).ok_or("missing artifacts")? {
            let name = a.get("name").and_then(Json::as_str).ok_or("artifact name")?.to_string();
            let file = a.get("file").and_then(Json::as_str).ok_or("artifact file")?;
            let mut inputs = Vec::new();
            for i in a.get("inputs").and_then(Json::as_arr).ok_or("artifact inputs")? {
                let shape = i
                    .get("shape")
                    .and_then(Json::as_arr)
                    .ok_or("input shape")?
                    .iter()
                    .filter_map(Json::as_usize)
                    .collect();
                let ty = match i.get("dtype").and_then(Json::as_str) {
                    Some("i32") => ArgType::I32,
                    _ => ArgType::F32,
                };
                inputs.push(ArgSpec { shape, ty });
            }
            let outputs = a.get("outputs").and_then(Json::as_usize).unwrap_or(1);
            artifacts.push(ArtifactSpec { name, path: dir.join(file), inputs, outputs });
        }
        Ok(Manifest { model: meta, s_max: get(&j, "s_max")?, tile_n: get(&j, "tile_n")?, batch_sizes, artifacts })
    }

    /// The compiled-in manifest: the same tiny model, `s_max`, tile
    /// width, batch sizes, and artifact signatures `python -m
    /// compile.aot` emits, with placeholder paths (no files exist).
    /// This is what makes artifact-free backends work in a bare
    /// container: the CPU backend executes straight from these
    /// signatures, so nothing ever opens the paths. Kept in lockstep
    /// with the AOT pipeline by the artifact-gated loader tests, which
    /// compare a loaded manifest against this one when artifacts are
    /// present.
    pub fn builtin() -> Manifest {
        let model = TinyModelMeta {
            layers: 4,
            d_model: 256,
            heads: 4,
            kv_heads: 2,
            head_dim: 64,
            ffn: 512,
            vocab: 512,
        };
        let (s_max, tile_n) = (64usize, 128usize);
        let batch_sizes = vec![1usize, 2, 4, 8];
        let (d, qd, kvd) = (model.d_model, model.q_dim(), model.kv_dim());
        let (ffn, vocab, l) = (model.ffn, model.vocab, model.layers);
        let f = |shape: &[usize]| ArgSpec { shape: shape.to_vec(), ty: ArgType::F32 };
        let int = |shape: &[usize]| ArgSpec { shape: shape.to_vec(), ty: ArgType::I32 };
        let mut artifacts = Vec::new();
        let mut push = |name: String, inputs: Vec<ArgSpec>, outputs: usize| {
            let path = PathBuf::from(format!("<builtin>/{name}.hlo"));
            artifacts.push(ArtifactSpec { name, path, inputs, outputs });
        };
        for &b in &batch_sizes {
            push(format!("embed_b{b}"), vec![int(&[b]), f(&[vocab, d])], 1);
            push(format!("rmsnorm_b{b}"), vec![f(&[b, d]), f(&[d])], 1);
            for k in [d, 2 * d] {
                push(format!("matmul_b{b}_k{k}_n{tile_n}"), vec![f(&[b, k]), f(&[k, tile_n])], 1);
            }
            push(format!("add_b{b}"), vec![f(&[b, d]), f(&[b, d])], 1);
            push(format!("swiglu_b{b}"), vec![f(&[b, 2 * ffn])], 1);
            // ids + 2L caches + cur_len + embed + 6L weights + final + head
            let mut ins = vec![int(&[b])];
            ins.extend((0..2 * l).map(|_| f(&[b, s_max, kvd])));
            ins.push(int(&[1]));
            ins.push(f(&[vocab, d]));
            for _ in 0..l {
                ins.push(f(&[d])); // ln1
                ins.push(f(&[d, qd + 2 * kvd])); // wqkv
                ins.push(f(&[qd, d])); // wo
                ins.push(f(&[d])); // ln2
                ins.push(f(&[d, 2 * ffn])); // w_gate_up
                ins.push(f(&[ffn, d])); // w_down
            }
            ins.push(f(&[d])); // final_norm
            ins.push(f(&[d, vocab])); // lm_head
            push(format!("ref_decode_b{b}"), ins, 1 + 2 * l);
        }
        push(
            "attn_q1".to_string(),
            vec![f(&[1, qd]), f(&[s_max, kvd]), f(&[s_max, kvd]), int(&[1])],
            1,
        );
        Manifest { model, s_max, tile_n, batch_sizes, artifacts }
    }

    /// Resolve the manifest for a backend: load from `dir` when the
    /// artifacts are present, else fall back to [`Manifest::builtin`]
    /// for artifact-free backends. Backends that open artifact files
    /// get the load error instead — a missing dir must fail loudly
    /// there, not hand out placeholder paths.
    pub fn resolve(dir: &Path, kind: BackendKind) -> Result<Manifest, ManifestError> {
        match Self::load(dir) {
            Ok(m) => Ok(m),
            Err(_) if kind.artifact_free() => Ok(Self::builtin()),
            Err(e) => Err(e),
        }
    }

    pub fn find(&self, name: &str) -> Option<(usize, &ArtifactSpec)> {
        self.artifacts.iter().enumerate().find(|(_, a)| a.name == name)
    }

    /// Default artifacts directory: `$MPK_ARTIFACTS`, else `./artifacts`,
    /// else the repo-root `artifacts/` anchored at the crate directory
    /// (compile-time `CARGO_MANIFEST_DIR`, *not* the CWD — a CWD-relative
    /// guess could silently pick up a foreign directory). The crate
    /// lives in `rust/` while the AOT pipeline writes artifacts at the
    /// repo root, so `cargo test` and examples run from the crate
    /// directory still find them.
    pub fn default_dir() -> PathBuf {
        if let Ok(p) = std::env::var("MPK_ARTIFACTS") {
            return PathBuf::from(p);
        }
        let local = PathBuf::from("artifacts");
        if !local.is_dir() {
            let repo_root = Path::new(env!("CARGO_MANIFEST_DIR")).join("../artifacts");
            if repo_root.is_dir() {
                return repo_root;
            }
        }
        local
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // -- builtin-manifest tests: run everywhere, no artifacts needed. --

    #[test]
    fn builtin_matches_tiny_config() {
        let m = Manifest::builtin();
        assert_eq!(m.model.layers, 4);
        assert_eq!(m.model.d_model, 256);
        assert_eq!(m.model.q_dim(), 256);
        assert_eq!(m.model.kv_dim(), 128);
        assert_eq!(m.batch_sizes, vec![1, 2, 4, 8]);
        assert_eq!((m.s_max, m.tile_n), (64, 128));
        for b in &m.batch_sizes {
            for name in [
                format!("matmul_b{b}_k256_n128"),
                format!("matmul_b{b}_k512_n128"),
                format!("rmsnorm_b{b}"),
                format!("swiglu_b{b}"),
                format!("add_b{b}"),
                format!("embed_b{b}"),
                format!("ref_decode_b{b}"),
            ] {
                assert!(m.find(&name).is_some(), "missing builtin artifact {name}");
            }
        }
        let (_, r) = m.find("ref_decode_b1").unwrap();
        assert_eq!(r.inputs.len(), 1 + 2 * 4 + 1 + 1 + 6 * 4 + 2);
        assert_eq!(r.outputs, 1 + 2 * 4);
        let (_, attn) = m.find("attn_q1").unwrap();
        assert_eq!(attn.inputs.len(), 4);
        assert_eq!(attn.inputs[3].ty, ArgType::I32);
    }

    #[test]
    fn resolve_falls_back_only_for_artifact_free_backends() {
        let missing = Path::new("/nonexistent-mpk-artifacts");
        let m = Manifest::resolve(missing, BackendKind::Cpu).unwrap();
        assert_eq!(m.model.layers, Manifest::builtin().model.layers);
        let err = Manifest::resolve(missing, BackendKind::Pjrt).unwrap_err();
        assert!(matches!(err, ManifestError::Load { .. }), "got: {err}");
    }

    #[test]
    fn manifest_error_variants_render_their_context() {
        let e = ManifestError::ModelMismatch { manifest: "L4".into(), builtin: "L2".into() };
        assert!(e.to_string().contains("L4") && e.to_string().contains("L2"));
        let e = ManifestError::MissingTensor { name: "wqkv_3".into() };
        assert!(e.to_string().contains("wqkv_3"));
        let e = ManifestError::NotTileable { op: "lm_head".into(), n: 500, tile_n: 128 };
        assert!(e.to_string().contains("500") && e.to_string().contains("128"));
    }

    // These tests require `make artifacts` to have run; they are the
    // integration contract between aot.py and the rust loader.
    fn manifest() -> Option<Manifest> {
        let dir = Manifest::default_dir();
        Manifest::load(&dir).ok()
    }

    #[test]
    fn manifest_loads_and_matches_tiny_config() {
        let Some(m) = manifest() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        assert_eq!(m.model.layers, 4);
        assert_eq!(m.model.d_model, 256);
        assert_eq!(m.model.q_dim(), 256);
        assert_eq!(m.model.kv_dim(), 128);
        assert_eq!(m.batch_sizes, vec![1, 2, 4, 8]);
        assert!(m.s_max >= 16);
        // the compiled-in manifest must stay in lockstep with aot.py.
        let b = Manifest::builtin();
        assert_eq!(format!("{:?}", m.model), format!("{:?}", b.model));
        for a in &b.artifacts {
            let (_, loaded) = m.find(&a.name).unwrap_or_else(|| panic!("{} not in aot manifest", a.name));
            assert_eq!(loaded.inputs.len(), a.inputs.len(), "{}", a.name);
            assert_eq!(loaded.outputs, a.outputs, "{}", a.name);
        }
    }

    #[test]
    fn expected_artifacts_present_with_files() {
        let Some(m) = manifest() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        for b in &m.batch_sizes {
            for name in [
                format!("matmul_b{b}_k256_n128"),
                format!("matmul_b{b}_k512_n128"),
                format!("rmsnorm_b{b}"),
                format!("swiglu_b{b}"),
                format!("add_b{b}"),
                format!("embed_b{b}"),
                format!("ref_decode_b{b}"),
            ] {
                let (_, a) = m.find(&name).unwrap_or_else(|| panic!("missing artifact {name}"));
                assert!(a.path.exists(), "file missing for {name}");
            }
        }
        let (_, attn) = m.find("attn_q1").expect("attn_q1");
        assert_eq!(attn.inputs.len(), 4);
        assert_eq!(attn.inputs[3].ty, ArgType::I32);
    }

    #[test]
    fn ref_decode_signature_arity() {
        // Signature-only check: the compiled-in manifest carries the
        // same arity contract, so this runs with or without artifacts.
        let m = manifest().unwrap_or_else(Manifest::builtin);
        let (_, r) = m.find("ref_decode_b1").unwrap();
        // ids + 2L caches + cur_len + embed + 6L weights + final + head
        assert_eq!(r.inputs.len(), 1 + 2 * 4 + 1 + 1 + 6 * 4 + 2);
        assert_eq!(r.outputs, 1 + 2 * 4);
    }
}
