//! Reader for `artifacts/manifest.json` (emitted by `python -m
//! compile.aot`). Describes every AOT-compiled HLO-text artifact: name,
//! file, input signature and output arity, plus the tiny-model metadata
//! the exec layer needs (S_MAX, tile width, batch sizes).

use crate::util::{json_parse, Json};
use std::path::{Path, PathBuf};

crate::util::boundary_error! {
    /// Typed failure from manifest loading / artifact discovery — the
    /// `runtime` boundary error for [`Manifest::load`]. Callers that
    /// still speak `String` (validation helpers, examples) convert
    /// through the `From<ManifestError> for String` shim; the serving
    /// layer converts it into its own typed error instead.
    ManifestError
}

/// Element type tag of an artifact input.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ArgType {
    F32,
    I32,
}

/// One input slot of an artifact.
#[derive(Clone, Debug)]
pub struct ArgSpec {
    pub shape: Vec<usize>,
    pub ty: ArgType,
}

impl ArgSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One AOT-compiled computation.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    pub path: PathBuf,
    pub inputs: Vec<ArgSpec>,
    /// Number of tuple outputs.
    pub outputs: usize,
}

/// Tiny-model metadata mirrored from python `TinyConfig`.
#[derive(Clone, Copy, Debug)]
pub struct TinyModelMeta {
    pub layers: usize,
    pub d_model: usize,
    pub heads: usize,
    pub kv_heads: usize,
    pub head_dim: usize,
    pub ffn: usize,
    pub vocab: usize,
}

impl TinyModelMeta {
    pub fn q_dim(&self) -> usize {
        self.heads * self.head_dim
    }

    pub fn kv_dim(&self) -> usize {
        self.kv_heads * self.head_dim
    }
}

/// The parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub model: TinyModelMeta,
    pub s_max: usize,
    pub tile_n: usize,
    pub batch_sizes: Vec<usize>,
    pub artifacts: Vec<ArtifactSpec>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest, ManifestError> {
        Self::load_impl(dir).map_err(ManifestError)
    }

    fn load_impl(dir: &Path) -> Result<Manifest, String> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .map_err(|e| format!("reading manifest.json in {dir:?}: {e} — run `make artifacts`"))?;
        let j = json_parse::parse(&text)?;
        let model = j.get("model").ok_or("missing model")?;
        let get = |o: &Json, k: &str| -> Result<usize, String> {
            o.get(k).and_then(Json::as_usize).ok_or_else(|| format!("missing {k}"))
        };
        let meta = TinyModelMeta {
            layers: get(model, "layers")?,
            d_model: get(model, "d_model")?,
            heads: get(model, "heads")?,
            kv_heads: get(model, "kv_heads")?,
            head_dim: get(model, "head_dim")?,
            ffn: get(model, "ffn")?,
            vocab: get(model, "vocab")?,
        };
        let batch_sizes = j
            .get("batch_sizes")
            .and_then(Json::as_arr)
            .ok_or("missing batch_sizes")?
            .iter()
            .filter_map(Json::as_usize)
            .collect();
        let mut artifacts = Vec::new();
        for a in j.get("artifacts").and_then(Json::as_arr).ok_or("missing artifacts")? {
            let name = a.get("name").and_then(Json::as_str).ok_or("artifact name")?.to_string();
            let file = a.get("file").and_then(Json::as_str).ok_or("artifact file")?;
            let mut inputs = Vec::new();
            for i in a.get("inputs").and_then(Json::as_arr).ok_or("artifact inputs")? {
                let shape = i
                    .get("shape")
                    .and_then(Json::as_arr)
                    .ok_or("input shape")?
                    .iter()
                    .filter_map(Json::as_usize)
                    .collect();
                let ty = match i.get("dtype").and_then(Json::as_str) {
                    Some("i32") => ArgType::I32,
                    _ => ArgType::F32,
                };
                inputs.push(ArgSpec { shape, ty });
            }
            let outputs = a.get("outputs").and_then(Json::as_usize).unwrap_or(1);
            artifacts.push(ArtifactSpec { name, path: dir.join(file), inputs, outputs });
        }
        Ok(Manifest { model: meta, s_max: get(&j, "s_max")?, tile_n: get(&j, "tile_n")?, batch_sizes, artifacts })
    }

    pub fn find(&self, name: &str) -> Option<(usize, &ArtifactSpec)> {
        self.artifacts.iter().enumerate().find(|(_, a)| a.name == name)
    }

    /// Default artifacts directory: `$MPK_ARTIFACTS`, else `./artifacts`,
    /// else the repo-root `artifacts/` anchored at the crate directory
    /// (compile-time `CARGO_MANIFEST_DIR`, *not* the CWD — a CWD-relative
    /// guess could silently pick up a foreign directory). The crate
    /// lives in `rust/` while the AOT pipeline writes artifacts at the
    /// repo root, so `cargo test` and examples run from the crate
    /// directory still find them.
    pub fn default_dir() -> PathBuf {
        if let Ok(p) = std::env::var("MPK_ARTIFACTS") {
            return PathBuf::from(p);
        }
        let local = PathBuf::from("artifacts");
        if !local.is_dir() {
            let repo_root = Path::new(env!("CARGO_MANIFEST_DIR")).join("../artifacts");
            if repo_root.is_dir() {
                return repo_root;
            }
        }
        local
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // These tests require `make artifacts` to have run; they are the
    // integration contract between aot.py and the rust loader.
    fn manifest() -> Option<Manifest> {
        let dir = Manifest::default_dir();
        Manifest::load(&dir).ok()
    }

    #[test]
    fn manifest_loads_and_matches_tiny_config() {
        let Some(m) = manifest() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        assert_eq!(m.model.layers, 4);
        assert_eq!(m.model.d_model, 256);
        assert_eq!(m.model.q_dim(), 256);
        assert_eq!(m.model.kv_dim(), 128);
        assert_eq!(m.batch_sizes, vec![1, 2, 4, 8]);
        assert!(m.s_max >= 16);
    }

    #[test]
    fn expected_artifacts_present_with_files() {
        let Some(m) = manifest() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        for b in &m.batch_sizes {
            for name in [
                format!("matmul_b{b}_k256_n128"),
                format!("matmul_b{b}_k512_n128"),
                format!("rmsnorm_b{b}"),
                format!("swiglu_b{b}"),
                format!("add_b{b}"),
                format!("embed_b{b}"),
                format!("ref_decode_b{b}"),
            ] {
                let (_, a) = m.find(&name).unwrap_or_else(|| panic!("missing artifact {name}"));
                assert!(a.path.exists(), "file missing for {name}");
            }
        }
        let (_, attn) = m.find("attn_q1").expect("attn_q1");
        assert_eq!(attn.inputs.len(), 4);
        assert_eq!(attn.inputs[3].ty, ArgType::I32);
    }

    #[test]
    fn ref_decode_signature_arity() {
        let Some(m) = manifest() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let (_, r) = m.find("ref_decode_b1").unwrap();
        // ids + 2L caches + cur_len + embed + 6L weights + final + head
        assert_eq!(r.inputs.len(), 1 + 2 * 4 + 1 + 1 + 6 * 4 + 2);
        assert_eq!(r.outputs, 1 + 2 * 4);
    }
}
