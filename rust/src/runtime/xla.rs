//! Offline stand-in for the `xla` PJRT binding.
//!
//! The crate is stdlib-only by policy (the dev/CI environment is
//! offline), but the PJRT execution backend
//! (`runtime::backend::pjrt`) is written against the `xla` crate's
//! PJRT surface: `Rc`-based thread-confined clients, HLO-text
//! compilation, literal marshalling. This module pins that exact
//! surface so the backend compiles everywhere. Every entry point that
//! would need a real device fails at **client construction**
//! ([`PjRtClient::cpu`]) with a descriptive error, which surfaces
//! through the pool's ready channel as a
//! `PjrtBackend::session` construction failure — before any request
//! is queued.
//!
//! Swapping in the real binding is a one-line change in
//! `runtime/backend/pjrt.rs` (import the external crate instead of
//! this module); nothing else in the crate touches these types. The
//! stub never blocks real-numerics testing: the native CPU backend
//! (`runtime::backend::cpu`) is the default and runs the full decode
//! vocabulary with no artifacts and no PJRT library, so only tests
//! specifically pinning PJRT behavior touch this module — and those
//! tolerate either the stub's construction error or a vendored
//! binding's success.

use std::fmt;
use std::rc::Rc;

/// Stringly error type mirroring the binding's (`Display`-able, so
/// callers' `map_err(|e| e.to_string())` works unchanged).
#[derive(Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

const UNAVAILABLE: &str = "PJRT backend unavailable: this build uses the offline `xla` stub \
     (rust/src/runtime/xla.rs); vendor the real binding to execute artifacts";

/// Thread-confined PJRT client. `Rc`-based and deliberately `!Send`,
/// matching the real binding — the pool gives each executor thread its
/// own client and never shares one across threads.
pub struct PjRtClient {
    _not_send: Rc<()>,
}

impl PjRtClient {
    /// Always fails in the stub: there is no backend to construct.
    pub fn cpu() -> Result<PjRtClient, Error> {
        Err(Error(UNAVAILABLE.into()))
    }

    pub fn compile(&self, _c: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        Err(Error(UNAVAILABLE.into()))
    }
}

/// Parsed HLO module (the text artifacts written by `make artifacts`).
pub struct HloModuleProto {
    _text: String,
}

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto, Error> {
        std::fs::read_to_string(path)
            .map(|text| HloModuleProto { _text: text })
            .map_err(|e| Error(format!("{path}: {e}")))
    }
}

/// An HLO computation ready for compilation.
pub struct XlaComputation {
    _proto: (),
}

impl XlaComputation {
    pub fn from_proto(_p: &HloModuleProto) -> XlaComputation {
        XlaComputation { _proto: () }
    }
}

/// A compiled executable owned by one client (and thus one thread).
pub struct PjRtLoadedExecutable {
    _not_send: Rc<()>,
}

impl PjRtLoadedExecutable {
    /// Run with the given argument literals; the result nesting mirrors
    /// the binding's per-device, per-output buffer layout.
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        Err(Error(UNAVAILABLE.into()))
    }
}

/// Device-resident buffer handle returned by `execute`.
pub struct PjRtBuffer {
    _not_send: Rc<()>,
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        Err(Error(UNAVAILABLE.into()))
    }
}

/// Element types a literal can carry across the pool boundary.
pub trait NativeType: Copy {
    fn wrap(data: Vec<Self>) -> LiteralData;
    fn unwrap(data: &LiteralData) -> Option<&[Self]>;
}

impl NativeType for f32 {
    fn wrap(data: Vec<f32>) -> LiteralData {
        LiteralData::F32(data)
    }

    fn unwrap(data: &LiteralData) -> Option<&[f32]> {
        match data {
            LiteralData::F32(v) => Some(v),
            _ => None,
        }
    }
}

impl NativeType for i32 {
    fn wrap(data: Vec<i32>) -> LiteralData {
        LiteralData::I32(data)
    }

    fn unwrap(data: &LiteralData) -> Option<&[i32]> {
        match data {
            LiteralData::I32(v) => Some(v),
            _ => None,
        }
    }
}

/// Backing storage of a literal: flat typed data or a tuple of parts.
pub enum LiteralData {
    F32(Vec<f32>),
    I32(Vec<i32>),
    Tuple(Vec<Literal>),
}

/// Host literal: typed data plus a shape.
pub struct Literal {
    data: LiteralData,
    dims: Vec<i64>,
}

impl Literal {
    /// Rank-1 literal over a typed slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal { data: T::wrap(data.to_vec()), dims: vec![data.len() as i64] }
    }

    /// Tuple literal (the artifact output container).
    pub fn tuple(parts: Vec<Literal>) -> Literal {
        let n = parts.len() as i64;
        Literal { data: LiteralData::Tuple(parts), dims: vec![n] }
    }

    pub fn shape(&self) -> &[i64] {
        &self.dims
    }

    pub fn reshape(self, dims: &[i64]) -> Result<Literal, Error> {
        let have = match &self.data {
            LiteralData::F32(v) => v.len(),
            LiteralData::I32(v) => v.len(),
            LiteralData::Tuple(_) => return Err(Error("cannot reshape a tuple literal".into())),
        };
        let want: i64 = dims.iter().product();
        if want < 0 || want as usize != have {
            return Err(Error(format!("reshape {have} elements to {dims:?}")));
        }
        Ok(Literal { data: self.data, dims: dims.to_vec() })
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>, Error> {
        match self.data {
            LiteralData::Tuple(parts) => Ok(parts),
            _ => Err(Error("literal is not a tuple".into())),
        }
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>, Error> {
        T::unwrap(&self.data).map(<[T]>::to_vec).ok_or_else(|| Error("literal dtype mismatch".into()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_construction_reports_stub() {
        let err = PjRtClient::cpu().err().expect("stub must not hand out a client");
        assert!(err.to_string().contains("stub"), "{err}");
    }

    #[test]
    fn literal_roundtrip_f32_and_i32() {
        let f = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0][..]).reshape(&[2, 2]).unwrap();
        assert_eq!(f.shape(), &[2, 2]);
        assert_eq!(f.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(f.to_vec::<i32>().is_err(), "dtype mismatch must not reinterpret");
        let i = Literal::vec1(&[7i32, 8][..]);
        assert_eq!(i.to_vec::<i32>().unwrap(), vec![7, 8]);
    }

    #[test]
    fn reshape_rejects_numel_mismatch() {
        assert!(Literal::vec1(&[0.0f32; 6][..]).reshape(&[4, 2]).is_err());
    }

    #[test]
    fn tuple_roundtrip() {
        let t = Literal::tuple(vec![Literal::vec1(&[1.0f32][..]), Literal::vec1(&[2i32][..])]);
        assert!(Literal::vec1(&[0.0f32][..]).to_tuple().is_err());
        let parts = t.to_tuple().unwrap();
        assert_eq!(parts.len(), 2);
        assert_eq!(parts[0].to_vec::<f32>().unwrap(), vec![1.0]);
        assert_eq!(parts[1].to_vec::<i32>().unwrap(), vec![2]);
    }
}
