//! PJRT execution backend: compiles the HLO text artifacts written by
//! `make artifacts` through the `xla` binding and executes them on a
//! thread-confined CPU client. Offline builds ship the in-tree stub
//! binding ([`crate::runtime::xla`]), whose client constructor fails —
//! so this backend reports itself unavailable at session construction
//! (surfaced through the pool's ready channel) until the real binding
//! is vendored. **Not** artifact-free: the artifacts directory must
//! exist, so [`Manifest::resolve`](crate::runtime::Manifest::resolve)
//! never falls back to the builtin manifest for this backend.
//!
//! Like the CPU backend, this module contains no `unsafe`: inputs
//! arrive as safe [`In`] slices (the pool re-materializes its erased
//! pointers before dispatch) and results are scattered through
//! [`OutView::copy_from`].

use super::{check_inputs, BackendKind, BackendSession, ExecBackend, In};
use crate::runtime::manifest::Manifest;
use crate::runtime::pool::{OutView, PoolError};
use crate::runtime::xla;
use std::sync::Arc;

/// The PJRT backend handle. Stateless — clients and compiled
/// executables are per-thread, inside [`PjrtSession`].
pub struct PjrtBackend;

impl ExecBackend for PjrtBackend {
    fn kind(&self) -> BackendKind {
        BackendKind::Pjrt
    }

    fn session(&self, manifest: Arc<Manifest>) -> Result<Box<dyn BackendSession>, PoolError> {
        // Own client + own compiled copies of every artifact: the
        // binding's client is `Rc`-based (!Send), which is exactly why
        // sessions are thread-confined.
        let client = xla::PjRtClient::cpu().map_err(|e| PoolError(e.to_string()))?;
        let exes = (0..manifest.artifacts.len()).map(|_| None).collect();
        Ok(Box::new(PjrtSession { manifest, client, exes }))
    }
}

/// Per-thread PJRT state: the client plus lazily compiled executables
/// (compiling all ~30 artifacts up front costs tens of seconds; a
/// typical run touches a handful).
pub struct PjrtSession {
    manifest: Arc<Manifest>,
    client: xla::PjRtClient,
    exes: Vec<Option<xla::PjRtLoadedExecutable>>,
}

impl PjrtSession {
    /// Compile-if-needed, marshal literals, execute, and flatten the
    /// result tuple to f32 parts — shared by both execute paths.
    fn run(&mut self, artifact: usize, inputs: &[In<'_>]) -> Result<Vec<Vec<f32>>, PoolError> {
        self.prepare(artifact)?;
        let manifest = Arc::clone(&self.manifest);
        let spec = &manifest.artifacts[artifact];
        check_inputs(spec, inputs)?;
        let mut literals = Vec::with_capacity(inputs.len());
        for (v, s) in inputs.iter().zip(&spec.inputs) {
            let dims: Vec<i64> = s.shape.iter().map(|&d| d as i64).collect();
            let lit = match v {
                In::F32(d) => xla::Literal::vec1(*d),
                In::I32(d) => xla::Literal::vec1(*d),
            };
            literals.push(lit.reshape(&dims).map_err(|e| PoolError(e.to_string()))?);
        }
        let out = self.exes[artifact]
            .as_ref()
            .expect("prepared above")
            .execute::<xla::Literal>(&literals)
            .map_err(|e| PoolError(e.to_string()))?;
        let tuple = out[0][0].to_literal_sync().map_err(|e| PoolError(e.to_string()))?;
        let parts = tuple.to_tuple().map_err(|e| PoolError(e.to_string()))?;
        if parts.len() != spec.outputs {
            return Err(PoolError(format!(
                "{}: expected {} outputs, got {}",
                spec.name,
                spec.outputs,
                parts.len()
            )));
        }
        parts
            .into_iter()
            .map(|p| p.to_vec::<f32>().map_err(|e| PoolError(e.to_string())))
            .collect()
    }
}

impl BackendSession for PjrtSession {
    fn prepare(&mut self, artifact: usize) -> Result<(), PoolError> {
        let manifest = Arc::clone(&self.manifest);
        let spec = manifest
            .artifacts
            .get(artifact)
            .ok_or_else(|| PoolError(format!("artifact index {artifact} out of range")))?;
        if self.exes[artifact].is_some() {
            return Ok(());
        }
        let path = spec.path.to_str().ok_or_else(|| PoolError("non-utf8 path".into()))?;
        let proto = xla::HloModuleProto::from_text_file(path)
            .map_err(|e| PoolError(format!("{}: {e}", spec.name)))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe =
            self.client.compile(&comp).map_err(|e| PoolError(format!("compile {}: {e}", spec.name)))?;
        self.exes[artifact] = Some(exe);
        Ok(())
    }

    fn execute(&mut self, artifact: usize, inputs: &[In<'_>]) -> Result<Vec<Vec<f32>>, PoolError> {
        self.run(artifact, inputs)
    }

    fn execute_into(
        &mut self,
        artifact: usize,
        inputs: &[In<'_>],
        outs: &mut [OutView<'_>],
    ) -> Result<(), PoolError> {
        let name = &self.manifest.artifacts.get(artifact).map(|s| s.name.clone()).unwrap_or_default();
        let parts = self.run(artifact, inputs)?;
        // validate *every* destination before writing any element — a
        // failed call must never leave a partial write.
        if parts.len() != outs.len() {
            return Err(PoolError(format!(
                "{name}: expected {} output destinations, got {}",
                parts.len(),
                outs.len()
            )));
        }
        for (i, (p, d)) in parts.iter().zip(outs.iter()).enumerate() {
            if p.len() != d.len() {
                return Err(PoolError(format!(
                    "{name}: output {i} numel mismatch: artifact produced {}, destination holds {}",
                    p.len(),
                    d.len()
                )));
            }
        }
        for (p, d) in parts.iter().zip(outs.iter_mut()) {
            d.copy_from(p);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_build_reports_unavailable_at_session_construction() {
        // The offline stub fails at client creation; a vendored real
        // binding would succeed here and the conformance suite would
        // then cover this backend too.
        match PjrtBackend.session(Arc::new(Manifest::builtin())) {
            Err(e) => assert!(e.0.contains("stub"), "unexpected stub error: {e}"),
            Ok(_) => eprintln!("real PJRT binding present; stub test vacuous"),
        }
    }
}
