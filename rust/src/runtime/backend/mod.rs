//! Pluggable execution backends for [`ExecPool`](crate::runtime::ExecPool).
//!
//! The pool owns the *protocol* — lifetime-erased request channels,
//! validation against the [`Manifest`], zero-copy output scatter — and
//! delegates the *numerics* to a backend selected at construction time.
//! Two backends are registered:
//!
//! * [`cpu::CpuBackend`] — native Rust kernels for the full tiny-model
//!   artifact vocabulary (embedding lookup, rmsnorm, tiled matmul, GQA
//!   attention with online softmax, residual add, swiglu, and the fused
//!   `ref_decode_b{b}` reference artifact). **Artifact-free**: it
//!   executes straight from the [`ArtifactSpec`] signatures, so it
//!   needs neither `make artifacts` nor a PJRT library, and it is the
//!   in-container default.
//! * [`pjrt::PjrtBackend`] — compiles the HLO text artifacts through
//!   [`crate::runtime::xla`]. Offline builds ship a stub `xla` module
//!   whose client constructor fails, so this backend reports itself
//!   unavailable until a real PJRT build is vendored.
//!
//! # Adding a backend
//!
//! 1. Implement [`ExecBackend`] (thread-safe identity + capability
//!    metadata, plus a [`ExecBackend::session`] factory) and
//!    [`BackendSession`] (the per-executor-thread state: prepared
//!    artifacts, scratch buffers, device handles — deliberately **not**
//!    `Send`, each pool thread builds its own).
//! 2. Add a variant to [`BackendKind`] and register the backend in
//!    [`registry`].
//! 3. Run the backend-conformance suite
//!    (`rust/tests/backend_conformance.rs`): it iterates the registry
//!    and checks per-op golden vectors, decode agreement with the task
//!    binder, and `execute_into` partial-write protection against every
//!    backend that reports itself available.
//!
//! Backends receive inputs as safe [`In`] slices and write results
//! through the safe run-wise accessors on
//! [`OutView`](crate::runtime::OutView) — all pointer reconstruction
//! stays inside the audited `runtime/pool.rs`, so backend
//! implementations contain no `unsafe`.

use crate::runtime::manifest::{ArgType, ArtifactSpec, Manifest};
use crate::runtime::pool::{OutView, PoolError};
use std::sync::{Arc, OnceLock};

pub mod cpu;
pub mod pjrt;

/// Which execution backend an [`ExecPool`](crate::runtime::ExecPool)
/// dispatches to. `Cpu` is the default: it is the only backend that
/// works in a bare container (no artifacts dir, no PJRT library).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum BackendKind {
    /// Native Rust kernels; artifact-free.
    #[default]
    Cpu,
    /// PJRT via the `xla` module (the offline stub until vendored).
    Pjrt,
}

impl BackendKind {
    /// Reads `MPK_BACKEND` (`cpu` / `pjrt`); anything else — including
    /// the variable being unset — selects the CPU backend.
    pub fn from_env() -> BackendKind {
        match std::env::var("MPK_BACKEND").as_deref() {
            Ok("pjrt") => BackendKind::Pjrt,
            _ => BackendKind::Cpu,
        }
    }

    /// Parses a CLI flag value; `None` for unknown names.
    pub fn parse(s: &str) -> Option<BackendKind> {
        match s {
            "cpu" => Some(BackendKind::Cpu),
            "pjrt" => Some(BackendKind::Pjrt),
            _ => None,
        }
    }

    /// Stable lowercase identity, used to tag `BENCH_*.json` records.
    pub fn name(self) -> &'static str {
        match self {
            BackendKind::Cpu => "cpu",
            BackendKind::Pjrt => "pjrt",
        }
    }

    /// `true` when the backend executes straight from the manifest's
    /// [`ArtifactSpec`](crate::runtime::ArtifactSpec) signatures and
    /// never opens the artifact files, so
    /// [`Manifest::resolve`](crate::runtime::Manifest::resolve) may
    /// fall back to the compiled-in [`Manifest::builtin`] manifest.
    pub fn artifact_free(self) -> bool {
        matches!(self, BackendKind::Cpu)
    }
}

/// One input argument, already validated against the artifact's
/// [`ArgSpec`](crate::runtime::ArgSpec) by the pool: the dtype matches
/// and the length equals the spec's numel. The pool materializes these
/// from its lifetime-erased channel payload on the executor thread.
#[derive(Clone, Copy, Debug)]
pub enum In<'a> {
    F32(&'a [f32]),
    I32(&'a [i32]),
}

impl<'a> In<'a> {
    pub fn len(&self) -> usize {
        match self {
            In::F32(d) => d.len(),
            In::I32(d) => d.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The f32 payload, or a typed error when the argument is i32.
    pub fn as_f32(&self) -> Result<&'a [f32], PoolError> {
        match self {
            In::F32(d) => Ok(d),
            In::I32(_) => Err(PoolError("expected f32 input, got i32".into())),
        }
    }

    /// The i32 payload, or a typed error when the argument is f32.
    pub fn as_i32(&self) -> Result<&'a [i32], PoolError> {
        match self {
            In::I32(d) => Ok(d),
            In::F32(_) => Err(PoolError("expected i32 input, got f32".into())),
        }
    }
}

/// Thread-safe backend handle: identity/capability metadata plus a
/// factory for per-thread sessions. Registered once in [`registry`]
/// and shared by every pool that selects it.
pub trait ExecBackend: Send + Sync {
    /// Which [`BackendKind`] this backend implements.
    fn kind(&self) -> BackendKind;

    /// Stable identity used in logs and `BENCH_*.json` records.
    fn name(&self) -> &'static str {
        self.kind().name()
    }

    /// See [`BackendKind::artifact_free`].
    fn artifact_free(&self) -> bool {
        self.kind().artifact_free()
    }

    /// Builds the per-executor-thread session. Called once per pool
    /// thread; the error (device/library unavailable, unsupported
    /// artifact vocabulary) surfaces through the pool's ready channel
    /// as a construction failure.
    fn session(&self, manifest: Arc<Manifest>) -> Result<Box<dyn BackendSession>, PoolError>;
}

/// Per-thread execution state: prepared artifacts, scratch buffers,
/// device handles. Deliberately **not** `Send` — each executor thread
/// owns one session for its lifetime, which is what lets backends keep
/// thread-confined client handles (the PJRT client is `Rc`-based).
pub trait BackendSession {
    /// Prepares one artifact (compile HLO, parse the op out of the
    /// spec, size scratch). Lazy and idempotent: the pool calls it
    /// before every execute and the session caches the result, so only
    /// the first call per artifact does work.
    fn prepare(&mut self, artifact: usize) -> Result<(), PoolError>;

    /// Executes into freshly allocated output buffers (the validation
    /// path — the hot decode path uses [`Self::execute_into`]).
    fn execute(&mut self, artifact: usize, inputs: &[In<'_>]) -> Result<Vec<Vec<f32>>, PoolError>;

    /// Executes and scatters results directly into caller-owned
    /// destinations — the zero-copy decode path. Contract: **every**
    /// destination is validated (count, numel, run geometry) before
    /// the first element is written, so a failed call leaves the
    /// destinations untouched.
    fn execute_into(
        &mut self,
        artifact: usize,
        inputs: &[In<'_>],
        outs: &mut [OutView<'_>],
    ) -> Result<(), PoolError>;
}

/// Validate `inputs` against the artifact signature — count, per-input
/// numel, dtype. The pool runs the same checks before dispatch, but
/// backends re-validate defensively because sessions are also driven
/// directly (the conformance suite, `execute`'s self-call).
pub(crate) fn check_inputs(spec: &ArtifactSpec, inputs: &[In<'_>]) -> Result<(), PoolError> {
    if inputs.len() != spec.inputs.len() {
        return Err(PoolError(format!(
            "{}: expected {} inputs, got {}",
            spec.name,
            spec.inputs.len(),
            inputs.len()
        )));
    }
    for (i, (v, s)) in inputs.iter().zip(&spec.inputs).enumerate() {
        if v.len() != s.numel() {
            return Err(PoolError(format!(
                "{}: input {i} numel mismatch: {} vs {:?}",
                spec.name,
                v.len(),
                s.shape
            )));
        }
        let ok = matches!((v, s.ty), (In::F32(_), ArgType::F32) | (In::I32(_), ArgType::I32));
        if !ok {
            return Err(PoolError(format!("{}: input {i} dtype mismatch", spec.name)));
        }
    }
    Ok(())
}

/// The built-in backend registry: one shared handle per
/// [`BackendKind`], in declaration order. The conformance suite
/// iterates this to test every backend uniformly.
pub fn registry() -> &'static [Arc<dyn ExecBackend>] {
    static REGISTRY: OnceLock<Vec<Arc<dyn ExecBackend>>> = OnceLock::new();
    REGISTRY.get_or_init(|| vec![Arc::new(cpu::CpuBackend), Arc::new(pjrt::PjrtBackend)])
}

/// Looks up the registered backend for `kind`.
pub fn backend(kind: BackendKind) -> Arc<dyn ExecBackend> {
    registry()
        .iter()
        .find(|b| b.kind() == kind)
        .cloned()
        .expect("every BackendKind has a registered backend")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_covers_every_kind_exactly_once() {
        for kind in [BackendKind::Cpu, BackendKind::Pjrt] {
            let matches: Vec<_> = registry().iter().filter(|b| b.kind() == kind).collect();
            assert_eq!(matches.len(), 1, "{kind:?} must be registered exactly once");
            assert_eq!(backend(kind).kind(), kind);
            assert_eq!(backend(kind).name(), kind.name());
        }
    }

    #[test]
    fn kind_parse_and_identity_round_trip() {
        for kind in [BackendKind::Cpu, BackendKind::Pjrt] {
            assert_eq!(BackendKind::parse(kind.name()), Some(kind));
        }
        assert_eq!(BackendKind::parse("tpu"), None);
        assert_eq!(BackendKind::default(), BackendKind::Cpu);
        assert!(BackendKind::Cpu.artifact_free());
        assert!(!BackendKind::Pjrt.artifact_free());
    }

    #[test]
    fn in_accessors_are_typed() {
        let f = [1.0f32, 2.0];
        let i = [3i32];
        assert_eq!(In::F32(&f).len(), 2);
        assert!(!In::F32(&f).is_empty());
        assert_eq!(In::F32(&f).as_f32().unwrap(), &f);
        assert_eq!(In::I32(&i).as_i32().unwrap(), &i);
        assert!(In::F32(&f).as_i32().is_err());
        assert!(In::I32(&i).as_f32().is_err());
    }
}
