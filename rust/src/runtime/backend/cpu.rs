//! Native CPU execution backend: pure-Rust kernels for the tiny-model
//! artifact vocabulary, executing straight from the manifest's
//! [`ArtifactSpec`] signatures. No artifact files are opened and no
//! PJRT library is needed — this is the in-container default backend,
//! and the one that makes tier-1 run real decode numerics.
//!
//! Numerics are pinned to match the task binder bit-for-bit where both
//! paths run the same op (the conformance suite leans on this):
//!
//! * matmul accumulates k-ascending per output element, in column
//!   blocks of [`COL_BLOCK`] (= the manifest's `tile_n`), so a fused
//!   full-width call and a sequence of `tile_n`-wide tiled calls
//!   produce identical bits;
//! * GQA attention uses a single-pass **online softmax** (running max /
//!   running sum, rescale-on-new-max); the position-closure kernel
//!   [`attention_row_paged`] is the single implementation behind the
//!   `attn_q1` artifact, the fused `ref_decode` reference (both via the
//!   contiguous [`attention_row`] wrapper), *and* the binder's paged
//!   block-table path — so paged and contiguous decode agree bitwise by
//!   construction;
//! * rmsnorm is `x / sqrt(mean(x²) + 1e-6) * w`, swiglu is
//!   `silu(gate) · up` over a `[gate | up]`-packed row, and embedding
//!   ids are clamped into the vocab range.
//!
//! The hot path is allocation-free after warmup: `execute_into`
//! scatters each output row directly into the caller's arena-backed
//! [`OutView`] runs, and the only scratch (the attention accumulator)
//! is a reused per-session buffer. Every destination is validated —
//! count, numel, run geometry — before the first element is written,
//! so a failed call leaves destinations untouched. This module
//! contains no `unsafe`: all pointer reconstruction stays in the
//! audited `runtime/pool.rs`.

use super::{check_inputs, BackendKind, BackendSession, ExecBackend, In};
use crate::runtime::manifest::{ArtifactSpec, Manifest, TinyModelMeta};
use crate::runtime::pool::{OutView, PoolError};
use std::sync::Arc;

/// Column-block width for the streamed matmul — matches the artifact
/// set's `tile_n`, so blocking never changes accumulation order
/// relative to the tiled artifact calls.
const COL_BLOCK: usize = 128;

/// The native CPU backend handle. Stateless — per-thread state lives
/// in [`CpuSession`].
pub struct CpuBackend;

impl ExecBackend for CpuBackend {
    fn kind(&self) -> BackendKind {
        BackendKind::Cpu
    }

    fn session(&self, manifest: Arc<Manifest>) -> Result<Box<dyn BackendSession>, PoolError> {
        Ok(Box::new(CpuSession::new(manifest)))
    }
}

/// Which native kernel an artifact name maps to. Parsed lazily at
/// `prepare` time from the artifact *name* (the spec's shapes carry
/// every dimension the kernels need), so manifests may list artifacts
/// this backend cannot run — e.g. the MoE grouped-GEMM — as long as
/// nothing executes them.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum CpuOp {
    Embed,
    RmsNorm,
    MatMul,
    Attn,
    Add,
    SwiGlu,
    RefDecode,
}

fn classify(name: &str) -> Option<CpuOp> {
    if name.starts_with("embed_b") {
        Some(CpuOp::Embed)
    } else if name.starts_with("rmsnorm_b") {
        Some(CpuOp::RmsNorm)
    } else if name.starts_with("matmul_b") {
        Some(CpuOp::MatMul)
    } else if name.starts_with("attn_q") {
        Some(CpuOp::Attn)
    } else if name.starts_with("add_b") {
        Some(CpuOp::Add)
    } else if name.starts_with("swiglu_b") {
        Some(CpuOp::SwiGlu)
    } else if name.starts_with("ref_decode_b") {
        Some(CpuOp::RefDecode)
    } else {
        None
    }
}

/// Per-thread CPU execution state: the lazily parsed op table plus the
/// reused attention accumulator (the only hot-path scratch).
pub struct CpuSession {
    manifest: Arc<Manifest>,
    ops: Vec<Option<CpuOp>>,
    acc: Vec<f32>,
}

impl CpuSession {
    pub fn new(manifest: Arc<Manifest>) -> CpuSession {
        let n = manifest.artifacts.len();
        CpuSession { manifest, ops: vec![None; n], acc: Vec::new() }
    }
}

/// `spec.inputs[arg].shape[axis]`, as a typed error instead of a panic
/// when a (hand-written or foreign) manifest is malformed.
fn dim(spec: &ArtifactSpec, arg: usize, axis: usize) -> Result<usize, PoolError> {
    spec.inputs
        .get(arg)
        .and_then(|a| a.shape.get(axis))
        .copied()
        .ok_or_else(|| PoolError(format!("{}: input {arg} is missing dimension {axis}", spec.name)))
}

/// Per-output `(rows, row_width)` write plan, derived from the spec's
/// input signature alone — this is what lets the backend validate every
/// destination before computing anything.
fn plan(
    op: CpuOp,
    spec: &ArtifactSpec,
    model: &TinyModelMeta,
) -> Result<Vec<(usize, usize)>, PoolError> {
    match op {
        CpuOp::Embed => {
            let b = spec.inputs.first().map(|a| a.numel()).unwrap_or(0);
            Ok(vec![(b, dim(spec, 1, 1)?)])
        }
        CpuOp::RmsNorm => Ok(vec![(dim(spec, 0, 0)?, dim(spec, 0, 1)?)]),
        CpuOp::MatMul => Ok(vec![(dim(spec, 0, 0)?, dim(spec, 1, 1)?)]),
        CpuOp::Attn => {
            let _ = dim(spec, 3, 0)?; // cur_len input must exist
            Ok(vec![(1, spec.inputs[0].numel())])
        }
        CpuOp::Add => Ok(vec![(dim(spec, 0, 0)?, dim(spec, 0, 1)?)]),
        CpuOp::SwiGlu => {
            let (b, two_f) = (dim(spec, 0, 0)?, dim(spec, 0, 1)?);
            if two_f % 2 != 0 {
                return Err(PoolError(format!(
                    "{}: swiglu input width {two_f} is not even",
                    spec.name
                )));
            }
            Ok(vec![(b, two_f / 2)])
        }
        CpuOp::RefDecode => {
            let l = model.layers;
            if spec.inputs.len() != 5 + 8 * l {
                return Err(PoolError(format!(
                    "{}: reference decode expects {} inputs, manifest lists {}",
                    spec.name,
                    5 + 8 * l,
                    spec.inputs.len()
                )));
            }
            let b = spec.inputs[0].numel();
            let mut plan = vec![(b, model.vocab)];
            plan.extend(std::iter::repeat((b, model.kv_dim())).take(2 * l));
            Ok(plan)
        }
    }
}

/// Validate every destination against the write plan **before any
/// write**: arity, numel, and that the runs tile into whole rows (so
/// row writes never straddle a run boundary).
fn check_outs(
    name: &str,
    plan: &[(usize, usize)],
    outs: &[OutView<'_>],
) -> Result<(), PoolError> {
    if outs.len() != plan.len() {
        return Err(PoolError(format!(
            "{name}: expected {} output destinations, got {}",
            plan.len(),
            outs.len()
        )));
    }
    for (i, (&(rows, w), o)) in plan.iter().zip(outs).enumerate() {
        if o.len() != rows * w {
            return Err(PoolError(format!(
                "{name}: output {i} numel mismatch: artifact produced {}, destination holds {}",
                rows * w,
                o.len()
            )));
        }
        if w > 0 && o.run_len() % w != 0 {
            return Err(PoolError(format!(
                "{name}: output {i} runs of {} elements straddle rows of width {w}",
                o.run_len()
            )));
        }
    }
    Ok(())
}

fn silu(g: f32) -> f32 {
    g / (1.0 + (-g).exp())
}

/// `out = x / sqrt(mean(x²) + 1e-6) * w` over one row.
fn rmsnorm_row(x: &[f32], w: &[f32], out: &mut [f32]) {
    let mut ss = 0.0f32;
    for &v in x {
        ss += v * v;
    }
    let inv = 1.0 / (ss / x.len() as f32 + 1e-6).sqrt();
    for ((o, &xv), &wv) in out.iter_mut().zip(x).zip(w) {
        *o = xv * inv * wv;
    }
}

/// One output row of `x_row · w` where `w` is `[k, n]` row-major.
/// Accumulation is k-ascending per element — identical order whether a
/// caller asks for a `tile_n`-wide tile or the fused full width — and
/// the column blocking only changes *which* elements a pass touches,
/// never the per-element order, so tiled and fused calls agree bitwise.
fn matmul_row(x_row: &[f32], w: &[f32], n: usize, out_row: &mut [f32]) {
    out_row.fill(0.0);
    let mut j0 = 0;
    while j0 < n {
        let jw = COL_BLOCK.min(n - j0);
        let block = &mut out_row[j0..j0 + jw];
        for (k, &xv) in x_row.iter().enumerate() {
            let wrow = &w[k * n + j0..][..jw];
            for (o, &wv) in block.iter_mut().zip(wrow) {
                *o += xv * wv;
            }
        }
        j0 += jw;
    }
}

/// GQA geometry shared by the standalone attention artifact, the fused
/// reference decode, and the binder's paged attention path.
pub(crate) struct AttnShape {
    pub(crate) heads: usize,
    pub(crate) kv_heads: usize,
    pub(crate) head_dim: usize,
}

/// One request row of GQA decode attention over the first `valid`
/// cache positions, addressed **by position closure**: `k_at(s)` /
/// `v_at(s)` return position `s`'s full `kv_heads * head_dim` cache
/// row, wherever it lives. The contiguous artifact path wraps this
/// with stride arithmetic ([`attention_row`]); the paged serving path
/// resolves each position through a block table into
/// `SharedSlab::view_span` rows. Both walk positions in the same
/// ascending order through the same single-pass online softmax (per
/// head: running max `m`, running normalizer `l`, value accumulator;
/// on a new max, rescale both by `exp(old_m - new_m)`), so paged and
/// contiguous decode agree **bitwise** by construction. `q` holds the
/// row's query (`heads * head_dim` — callers slice the q columns out
/// of a fused qkv row). A `valid` of 0 (vacant batch row) writes
/// zeros: `out` is always fully overwritten.
pub(crate) fn attention_row_paged<'c>(
    shape: &AttnShape,
    q: &[f32],
    k_at: impl Fn(usize) -> &'c [f32],
    v_at: impl Fn(usize) -> &'c [f32],
    valid: usize,
    acc: &mut Vec<f32>,
    out: &mut [f32],
) {
    let hd = shape.head_dim;
    let group = (shape.heads / shape.kv_heads).max(1);
    let scale = 1.0 / (hd as f32).sqrt();
    acc.resize(hd, 0.0);
    for h in 0..shape.heads {
        let qh = &q[h * hd..][..hd];
        let kvh = h / group;
        let mut m = f32::NEG_INFINITY;
        let mut l = 0.0f32;
        acc.fill(0.0);
        for s in 0..valid {
            let krow = &k_at(s)[kvh * hd..][..hd];
            let mut dot = 0.0f32;
            for (&a, &b) in qh.iter().zip(krow) {
                dot += a * b;
            }
            let score = dot * scale;
            if score > m {
                // exp(-inf) == 0 covers the first iteration cleanly.
                let corr = (m - score).exp();
                l *= corr;
                for a in acc.iter_mut() {
                    *a *= corr;
                }
                m = score;
            }
            let p = (score - m).exp();
            l += p;
            let vrow = &v_at(s)[kvh * hd..][..hd];
            for (a, &v) in acc.iter_mut().zip(vrow) {
                *a += p * v;
            }
        }
        let oh = &mut out[h * hd..][..hd];
        if l > 0.0 {
            for (o, &a) in oh.iter_mut().zip(acc.iter()) {
                *o = a / l;
            }
        } else {
            oh.fill(0.0);
        }
    }
}

/// Contiguous-cache wrapper over [`attention_row_paged`]: caches are
/// `[s_max, kv_heads * head_dim]` row-major slices.
fn attention_row(
    shape: &AttnShape,
    q: &[f32],
    kc: &[f32],
    vc: &[f32],
    valid: usize,
    acc: &mut Vec<f32>,
    out: &mut [f32],
) {
    let kv_dim = shape.kv_heads * shape.head_dim;
    attention_row_paged(
        shape,
        q,
        |s| &kc[s * kv_dim..][..kv_dim],
        |s| &vc[s * kv_dim..][..kv_dim],
        valid,
        acc,
        out,
    );
}

/// Clamp a token id into the vocab range (matches the artifact set's
/// gather semantics: never fault on a bad id).
fn clamp_id(id: i32, vocab: usize) -> usize {
    (id.max(0) as usize).min(vocab.saturating_sub(1))
}

impl BackendSession for CpuSession {
    fn prepare(&mut self, artifact: usize) -> Result<(), PoolError> {
        let spec = self
            .manifest
            .artifacts
            .get(artifact)
            .ok_or_else(|| PoolError(format!("artifact index {artifact} out of range")))?;
        if self.ops[artifact].is_none() {
            let op = classify(&spec.name).ok_or_else(|| {
                PoolError(format!("{}: no native cpu kernel for this artifact", spec.name))
            })?;
            self.ops[artifact] = Some(op);
        }
        Ok(())
    }

    fn execute(&mut self, artifact: usize, inputs: &[In<'_>]) -> Result<Vec<Vec<f32>>, PoolError> {
        self.prepare(artifact)?;
        let manifest = Arc::clone(&self.manifest);
        let spec = &manifest.artifacts[artifact];
        let op = self.ops[artifact].expect("prepared above");
        let plan = plan(op, spec, &manifest.model)?;
        let mut bufs: Vec<Vec<f32>> = plan.iter().map(|&(r, w)| vec![0.0; r * w]).collect();
        let mut views: Vec<OutView<'_>> = bufs.iter_mut().map(|b| OutView::from_slice(b)).collect();
        self.execute_into(artifact, inputs, &mut views)?;
        drop(views);
        Ok(bufs)
    }

    fn execute_into(
        &mut self,
        artifact: usize,
        inputs: &[In<'_>],
        outs: &mut [OutView<'_>],
    ) -> Result<(), PoolError> {
        self.prepare(artifact)?;
        let manifest = Arc::clone(&self.manifest);
        let spec = &manifest.artifacts[artifact];
        let op = self.ops[artifact].expect("prepared above");
        check_inputs(spec, inputs)?;
        let plan = plan(op, spec, &manifest.model)?;
        check_outs(&spec.name, &plan, outs)?;
        // Everything below is infallible: inputs and all destinations
        // are fully validated, so a partial write can never be
        // observed.
        match op {
            CpuOp::Embed => {
                let ids = inputs[0].as_i32()?;
                let table = inputs[1].as_f32()?;
                let d = plan[0].1;
                let vocab = spec.inputs[1].shape[0];
                for (r, &id) in ids.iter().enumerate() {
                    let row = clamp_id(id, vocab);
                    outs[0].span_mut(r * d, d).copy_from_slice(&table[row * d..][..d]);
                }
            }
            CpuOp::RmsNorm => {
                let x = inputs[0].as_f32()?;
                let w = inputs[1].as_f32()?;
                let (rows, d) = plan[0];
                for r in 0..rows {
                    rmsnorm_row(&x[r * d..][..d], w, outs[0].span_mut(r * d, d));
                }
            }
            CpuOp::MatMul => {
                let x = inputs[0].as_f32()?;
                let w = inputs[1].as_f32()?;
                let k = dim(spec, 0, 1)?;
                let (rows, n) = plan[0];
                for r in 0..rows {
                    matmul_row(&x[r * k..][..k], w, n, outs[0].span_mut(r * n, n));
                }
            }
            CpuOp::Attn => {
                let q = inputs[0].as_f32()?;
                let kc = inputs[1].as_f32()?;
                let vc = inputs[2].as_f32()?;
                let s_max = dim(spec, 1, 0)?;
                let valid = (inputs[3].as_i32()?[0].max(0) as usize).min(s_max);
                let m = manifest.model;
                let shape =
                    AttnShape { heads: m.heads, kv_heads: m.kv_heads, head_dim: m.head_dim };
                let w = plan[0].1;
                attention_row(&shape, q, kc, vc, valid, &mut self.acc, outs[0].span_mut(0, w));
            }
            CpuOp::Add => {
                let a = inputs[0].as_f32()?;
                let b = inputs[1].as_f32()?;
                let (rows, d) = plan[0];
                for r in 0..rows {
                    let row = outs[0].span_mut(r * d, d);
                    for ((o, &x), &y) in row.iter_mut().zip(&a[r * d..][..d]).zip(&b[r * d..][..d])
                    {
                        *o = x + y;
                    }
                }
            }
            CpuOp::SwiGlu => {
                let x = inputs[0].as_f32()?;
                let (rows, f) = plan[0];
                for r in 0..rows {
                    let xr = &x[r * 2 * f..][..2 * f];
                    let (gate, up) = xr.split_at(f);
                    let row = outs[0].span_mut(r * f, f);
                    for ((o, &g), &u) in row.iter_mut().zip(gate).zip(up) {
                        *o = silu(g) * u;
                    }
                }
            }
            CpuOp::RefDecode => {
                ref_decode(&manifest.model, spec, inputs, outs, &mut self.acc)?;
            }
        }
        Ok(())
    }
}

/// The fused reference decode: the whole tiny-model forward pass in one
/// call, mirroring the compiled decode graph op for op — pre-norm
/// residual blocks, fused qkv with the binder's column split, KvAppend
/// semantics (caches are read *as stored* for positions `0..cur_len`
/// and this step's K/V is appended at `cur_len`), and the same
/// [`attention_row`] / [`matmul_row`] kernels as the per-op artifacts,
/// so binder decode and reference logits agree bitwise on this backend.
/// Outputs: `[logits, k_row per layer ×L, v_row per layer ×L]`. This is
/// the validation path, so per-call scratch allocation is fine.
fn ref_decode(
    model: &TinyModelMeta,
    spec: &ArtifactSpec,
    inputs: &[In<'_>],
    outs: &mut [OutView<'_>],
    acc: &mut Vec<f32>,
) -> Result<(), PoolError> {
    let ln = model.layers;
    let (d, qd, kvd) = (model.d_model, model.q_dim(), model.kv_dim());
    let (ffn, vocab) = (model.ffn, model.vocab);
    let shape = AttnShape { heads: model.heads, kv_heads: model.kv_heads, head_dim: model.head_dim };
    let ids = inputs[0].as_i32()?;
    let b = ids.len();
    let s_max = dim(spec, 1, 1)?; // caches are [b, s_max, kv_dim]
    let cur_len = (inputs[1 + 2 * ln].as_i32()?[0].max(0) as usize).min(s_max.saturating_sub(1));
    let embed = inputs[2 + 2 * ln].as_f32()?;

    let mut x = vec![0.0f32; b * d];
    for (r, &id) in ids.iter().enumerate() {
        x[r * d..][..d].copy_from_slice(&embed[clamp_id(id, vocab) * d..][..d]);
    }
    let mut normed = vec![0.0f32; b * d];
    let mut qkv = vec![0.0f32; b * (qd + 2 * kvd)];
    let mut attn = vec![0.0f32; b * qd];
    let mut proj = vec![0.0f32; b * d];
    let mut gu = vec![0.0f32; b * 2 * ffn];
    let mut act = vec![0.0f32; b * ffn];
    let mut kc = vec![0.0f32; s_max * kvd];
    let mut vc = vec![0.0f32; s_max * kvd];

    for layer in 0..ln {
        let base = 3 + 2 * ln + 6 * layer;
        let ln1 = inputs[base].as_f32()?;
        let wqkv = inputs[base + 1].as_f32()?;
        let wo = inputs[base + 2].as_f32()?;
        let ln2 = inputs[base + 3].as_f32()?;
        let wgu = inputs[base + 4].as_f32()?;
        let wdown = inputs[base + 5].as_f32()?;
        let kc_in = inputs[1 + layer].as_f32()?;
        let vc_in = inputs[1 + ln + layer].as_f32()?;

        // attention block: x + wo·attn(ln1(x))
        for r in 0..b {
            rmsnorm_row(&x[r * d..][..d], ln1, &mut normed[r * d..][..d]);
        }
        let qkv_w = qd + 2 * kvd;
        for r in 0..b {
            matmul_row(&normed[r * d..][..d], wqkv, qkv_w, &mut qkv[r * qkv_w..][..qkv_w]);
        }
        for r in 0..b {
            let qkv_r = &qkv[r * qkv_w..][..qkv_w];
            let k_new = &qkv_r[qd..qd + kvd];
            let v_new = &qkv_r[qd + kvd..];
            outs[1 + layer].span_mut(r * kvd, kvd).copy_from_slice(k_new);
            outs[1 + ln + layer].span_mut(r * kvd, kvd).copy_from_slice(v_new);
            // KvAppend semantics on a scratch copy of this row's cache.
            kc.copy_from_slice(&kc_in[r * s_max * kvd..][..s_max * kvd]);
            vc.copy_from_slice(&vc_in[r * s_max * kvd..][..s_max * kvd]);
            kc[cur_len * kvd..][..kvd].copy_from_slice(k_new);
            vc[cur_len * kvd..][..kvd].copy_from_slice(v_new);
            attention_row(&shape, &qkv_r[..qd], &kc, &vc, cur_len + 1, acc, &mut attn[r * qd..][..qd]);
        }
        for r in 0..b {
            matmul_row(&attn[r * qd..][..qd], wo, d, &mut proj[r * d..][..d]);
        }
        for (xv, &pv) in x.iter_mut().zip(&proj) {
            *xv += pv;
        }

        // MLP block: h + wdown·swiglu(ln2(h)·wgu)
        for r in 0..b {
            rmsnorm_row(&x[r * d..][..d], ln2, &mut normed[r * d..][..d]);
        }
        for r in 0..b {
            matmul_row(&normed[r * d..][..d], wgu, 2 * ffn, &mut gu[r * 2 * ffn..][..2 * ffn]);
        }
        for r in 0..b {
            let row = &gu[r * 2 * ffn..][..2 * ffn];
            let (gate, up) = row.split_at(ffn);
            for ((o, &g), &u) in act[r * ffn..][..ffn].iter_mut().zip(gate).zip(up) {
                *o = silu(g) * u;
            }
        }
        for r in 0..b {
            matmul_row(&act[r * ffn..][..ffn], wdown, d, &mut proj[r * d..][..d]);
        }
        for (xv, &pv) in x.iter_mut().zip(&proj) {
            *xv += pv;
        }
    }

    let final_norm = inputs[3 + 8 * ln].as_f32()?;
    let lm_head = inputs[4 + 8 * ln].as_f32()?;
    for r in 0..b {
        rmsnorm_row(&x[r * d..][..d], final_norm, &mut normed[r * d..][..d]);
    }
    for r in 0..b {
        matmul_row(&normed[r * d..][..d], lm_head, vocab, outs[0].span_mut(r * vocab, vocab));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn classify_covers_the_artifact_vocabulary() {
        assert_eq!(classify("embed_b4"), Some(CpuOp::Embed));
        assert_eq!(classify("rmsnorm_b1"), Some(CpuOp::RmsNorm));
        assert_eq!(classify("matmul_b2_k256_n128"), Some(CpuOp::MatMul));
        assert_eq!(classify("attn_q1"), Some(CpuOp::Attn));
        assert_eq!(classify("add_b8"), Some(CpuOp::Add));
        assert_eq!(classify("swiglu_b2"), Some(CpuOp::SwiGlu));
        assert_eq!(classify("ref_decode_b1"), Some(CpuOp::RefDecode));
        assert_eq!(classify("moe_gather_gemm_b8"), None);
    }

    #[test]
    fn unknown_artifact_fails_at_prepare_not_execute() {
        let mut m = Manifest::builtin();
        m.artifacts[0].name = "moe_gather_gemm_b8".into();
        let mut s = CpuSession::new(Arc::new(m));
        let err = s.prepare(0).unwrap_err();
        assert!(err.0.contains("no native cpu kernel"), "got: {err}");
        assert!(s.prepare(1).is_ok(), "other artifacts still prepare");
    }

    #[test]
    fn matmul_blocking_is_bit_identical_to_unblocked() {
        // fused width (512) crosses block boundaries; a plain k-outer
        // accumulation must produce the same bits.
        let k = 96;
        let n = 512;
        let mut rng = crate::util::XorShift64::new(5);
        let x: Vec<f32> = (0..k).map(|_| rng.unit_f32()).collect();
        let w: Vec<f32> = (0..k * n).map(|_| rng.unit_f32()).collect();
        let mut got = vec![0.0f32; n];
        matmul_row(&x, &w, n, &mut got);
        let mut want = vec![0.0f32; n];
        for (kk, &xv) in x.iter().enumerate() {
            for (o, &wv) in want.iter_mut().zip(&w[kk * n..(kk + 1) * n]) {
                *o += xv * wv;
            }
        }
        assert_eq!(got, want);
    }

    #[test]
    fn embedding_ids_are_clamped() {
        assert_eq!(clamp_id(-3, 10), 0);
        assert_eq!(clamp_id(4, 10), 4);
        assert_eq!(clamp_id(99, 10), 9);
    }

    #[test]
    fn paged_attention_over_scattered_blocks_is_bit_identical() {
        // Split a contiguous cache into 4-row blocks stored in shuffled
        // order; resolving positions through a block table must produce
        // the same bits as the contiguous wrapper.
        let shape = AttnShape { heads: 4, kv_heads: 2, head_dim: 8 };
        let kv_dim = 16;
        let valid = 11; // partial final block
        let rows = 12;
        let mut rng = crate::util::XorShift64::new(9);
        let q: Vec<f32> = (0..32).map(|_| rng.unit_f32()).collect();
        let kc: Vec<f32> = (0..rows * kv_dim).map(|_| rng.unit_f32()).collect();
        let vc: Vec<f32> = (0..rows * kv_dim).map(|_| rng.unit_f32()).collect();
        let mut want = vec![0.0f32; 32];
        let mut acc = Vec::new();
        attention_row(&shape, &q, &kc, &vc, valid, &mut acc, &mut want);

        let bt = 4;
        let table = [2usize, 0, 1]; // logical block -> physical block
        let mut pk = vec![0.0f32; rows * kv_dim];
        let mut pv = vec![0.0f32; rows * kv_dim];
        for (lb, &pb) in table.iter().enumerate() {
            let (src, dst) = (lb * bt * kv_dim, pb * bt * kv_dim);
            pk[dst..dst + bt * kv_dim].copy_from_slice(&kc[src..src + bt * kv_dim]);
            pv[dst..dst + bt * kv_dim].copy_from_slice(&vc[src..src + bt * kv_dim]);
        }
        let k_at = |s: usize| &pk[(table[s / bt] * bt + s % bt) * kv_dim..][..kv_dim];
        let v_at = |s: usize| &pv[(table[s / bt] * bt + s % bt) * kv_dim..][..kv_dim];
        let mut got = vec![1.0f32; 32];
        attention_row_paged(&shape, &q, k_at, v_at, valid, &mut acc, &mut got);
        assert_eq!(got, want);

        // valid == 0 (vacant row) fully overwrites the destination.
        let mut z = vec![7.0f32; 32];
        attention_row_paged(&shape, &q, k_at, v_at, 0, &mut acc, &mut z);
        assert!(z.iter().all(|&v| v == 0.0));
    }
}
