//! Lightweight runtime counters and histograms.

use std::sync::atomic::{AtomicU64, Ordering};

/// A set of atomic counters shared by workers/schedulers.
#[derive(Debug, Default)]
pub struct RuntimeMetrics {
    pub tasks_executed: AtomicU64,
    pub dummy_tasks: AtomicU64,
    pub jit_dispatches: AtomicU64,
    pub aot_hits: AtomicU64,
    pub events_activated: AtomicU64,
    pub worker_idle_spins: AtomicU64,
    pub sched_idle_spins: AtomicU64,
    /// Nanoseconds spent inside task bodies (summed across workers).
    pub task_ns: AtomicU64,
    /// Nanoseconds of scheduler dispatch work.
    pub sched_ns: AtomicU64,
}

impl RuntimeMetrics {
    pub fn inc(&self, c: &AtomicU64) {
        c.fetch_add(1, Ordering::Relaxed);
    }

    /// Zero all counters (one mega-kernel invocation = one measurement).
    pub fn reset(&self) {
        self.tasks_executed.store(0, Ordering::Relaxed);
        self.dummy_tasks.store(0, Ordering::Relaxed);
        self.jit_dispatches.store(0, Ordering::Relaxed);
        self.aot_hits.store(0, Ordering::Relaxed);
        self.events_activated.store(0, Ordering::Relaxed);
        self.worker_idle_spins.store(0, Ordering::Relaxed);
        self.sched_idle_spins.store(0, Ordering::Relaxed);
        self.task_ns.store(0, Ordering::Relaxed);
        self.sched_ns.store(0, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        MetricsSnapshot {
            tasks_executed: self.tasks_executed.load(Ordering::Relaxed),
            dummy_tasks: self.dummy_tasks.load(Ordering::Relaxed),
            jit_dispatches: self.jit_dispatches.load(Ordering::Relaxed),
            aot_hits: self.aot_hits.load(Ordering::Relaxed),
            events_activated: self.events_activated.load(Ordering::Relaxed),
            worker_idle_spins: self.worker_idle_spins.load(Ordering::Relaxed),
            sched_idle_spins: self.sched_idle_spins.load(Ordering::Relaxed),
            task_ns: self.task_ns.load(Ordering::Relaxed),
            sched_ns: self.sched_ns.load(Ordering::Relaxed),
        }
    }
}

/// Plain-data copy of the counters.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct MetricsSnapshot {
    pub tasks_executed: u64,
    pub dummy_tasks: u64,
    pub jit_dispatches: u64,
    pub aot_hits: u64,
    pub events_activated: u64,
    pub worker_idle_spins: u64,
    pub sched_idle_spins: u64,
    pub task_ns: u64,
    pub sched_ns: u64,
}

impl MetricsSnapshot {
    /// Scheduler overhead as a fraction of total accounted time — the
    /// paper reports 0.28% for its in-kernel scheduler (§6.6).
    pub fn sched_overhead(&self) -> f64 {
        let total = self.task_ns + self.sched_ns;
        if total == 0 {
            0.0
        } else {
            self.sched_ns as f64 / total as f64
        }
    }
}

/// Connection-level counters for the serving wire transport
/// (`serving::transport`), shared across the accept loop and every
/// per-connection thread. Same atomic-counter idiom as
/// [`RuntimeMetrics`]; snapshot with [`TransportMetrics::snapshot`].
#[derive(Debug, Default)]
pub struct TransportMetrics {
    /// Connections accepted into a serving thread.
    pub conns_accepted: AtomicU64,
    /// Connections refused at accept (listener cap, draining) or that
    /// failed setup.
    pub conns_rejected: AtomicU64,
    /// Connections fully closed (graceful or torn down).
    pub conns_closed: AtomicU64,
    /// Submissions accepted by the server over the wire.
    pub requests_submitted: AtomicU64,
    /// Submissions refused over the wire (shed, validation errors,
    /// per-connection in-flight cap, draining).
    pub requests_rejected: AtomicU64,
    /// Frames written to sockets.
    pub frames_sent: AtomicU64,
    /// Frames parsed off sockets.
    pub frames_received: AtomicU64,
    /// Outbound frames discarded: dead connections, injected wire
    /// faults, slow-consumer overflow, failed writes.
    pub frames_dropped: AtomicU64,
    /// Malformed/oversized/stalled inbound frames (each one tears its
    /// connection down).
    pub protocol_errors: AtomicU64,
    /// Connections shed under the `Shed` slow-reader policy.
    pub slow_consumer_closes: AtomicU64,
    /// Live requests force-cancelled because a drain deadline expired.
    pub drain_forced: AtomicU64,
}

impl TransportMetrics {
    pub fn inc(&self, c: &AtomicU64) {
        c.fetch_add(1, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> TransportSnapshot {
        TransportSnapshot {
            conns_accepted: self.conns_accepted.load(Ordering::Relaxed),
            conns_rejected: self.conns_rejected.load(Ordering::Relaxed),
            conns_closed: self.conns_closed.load(Ordering::Relaxed),
            requests_submitted: self.requests_submitted.load(Ordering::Relaxed),
            requests_rejected: self.requests_rejected.load(Ordering::Relaxed),
            frames_sent: self.frames_sent.load(Ordering::Relaxed),
            frames_received: self.frames_received.load(Ordering::Relaxed),
            frames_dropped: self.frames_dropped.load(Ordering::Relaxed),
            protocol_errors: self.protocol_errors.load(Ordering::Relaxed),
            slow_consumer_closes: self.slow_consumer_closes.load(Ordering::Relaxed),
            drain_forced: self.drain_forced.load(Ordering::Relaxed),
        }
    }
}

/// Plain-data snapshot of paged-KV pool occupancy — the operator's view
/// of KV capacity (`serving::paged::PagedKvPool::stats`, overlaid with
/// the engine's `prefill_chunks`, surfaced through `ServerStatus` and
/// the wire `Status` frame). Gauges (`blocks_free`/`blocks_shared`) are
/// instantaneous; the rest are cumulative. All-zero in the legacy
/// slot-contiguous mode except `blocks_total`/`blocks_free`, which the
/// accounting allocator also reports.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct KvPoolStats {
    /// Pool size in blocks.
    pub blocks_total: u64,
    /// Blocks on the free list right now.
    pub blocks_free: u64,
    /// Blocks currently referenced more than once (prefix sharing).
    pub blocks_shared: u64,
    /// Cumulative copy-on-write block copies.
    pub blocks_cowed: u64,
    /// Cumulative blocks mapped from the prefix index at admission.
    pub prefix_hits: u64,
    /// Cumulative extra prefill epochs run by the chunked-prefill
    /// scheduler.
    pub prefill_chunks: u64,
}

/// Plain-data copy of the transport counters.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TransportSnapshot {
    pub conns_accepted: u64,
    pub conns_rejected: u64,
    pub conns_closed: u64,
    pub requests_submitted: u64,
    pub requests_rejected: u64,
    pub frames_sent: u64,
    pub frames_received: u64,
    pub frames_dropped: u64,
    pub protocol_errors: u64,
    pub slow_consumer_closes: u64,
    pub drain_forced: u64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transport_counters_accumulate_and_snapshot() {
        let m = TransportMetrics::default();
        m.inc(&m.conns_accepted);
        m.inc(&m.frames_sent);
        m.inc(&m.frames_sent);
        m.drain_forced.fetch_add(3, Ordering::Relaxed);
        let s = m.snapshot();
        assert_eq!(s.conns_accepted, 1);
        assert_eq!(s.frames_sent, 2);
        assert_eq!(s.drain_forced, 3);
        assert_eq!(s.conns_rejected, 0);
        assert_eq!(s, m.snapshot(), "snapshot is a pure copy");
    }

    #[test]
    fn counters_accumulate() {
        let m = RuntimeMetrics::default();
        m.inc(&m.tasks_executed);
        m.inc(&m.tasks_executed);
        assert_eq!(m.snapshot().tasks_executed, 2);
    }

    #[test]
    fn sched_overhead_fraction() {
        let m = RuntimeMetrics::default();
        m.task_ns.store(9900, Ordering::Relaxed);
        m.sched_ns.store(100, Ordering::Relaxed);
        assert!((m.snapshot().sched_overhead() - 0.01).abs() < 1e-9);
    }
}
