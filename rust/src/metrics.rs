//! Lightweight runtime counters and histograms.

use std::sync::atomic::{AtomicU64, Ordering};

/// A set of atomic counters shared by workers/schedulers.
#[derive(Debug, Default)]
pub struct RuntimeMetrics {
    pub tasks_executed: AtomicU64,
    pub dummy_tasks: AtomicU64,
    pub jit_dispatches: AtomicU64,
    pub aot_hits: AtomicU64,
    pub events_activated: AtomicU64,
    pub worker_idle_spins: AtomicU64,
    pub sched_idle_spins: AtomicU64,
    /// Nanoseconds spent inside task bodies (summed across workers).
    pub task_ns: AtomicU64,
    /// Nanoseconds of scheduler dispatch work.
    pub sched_ns: AtomicU64,
}

impl RuntimeMetrics {
    pub fn inc(&self, c: &AtomicU64) {
        c.fetch_add(1, Ordering::Relaxed);
    }

    /// Zero all counters (one mega-kernel invocation = one measurement).
    pub fn reset(&self) {
        self.tasks_executed.store(0, Ordering::Relaxed);
        self.dummy_tasks.store(0, Ordering::Relaxed);
        self.jit_dispatches.store(0, Ordering::Relaxed);
        self.aot_hits.store(0, Ordering::Relaxed);
        self.events_activated.store(0, Ordering::Relaxed);
        self.worker_idle_spins.store(0, Ordering::Relaxed);
        self.sched_idle_spins.store(0, Ordering::Relaxed);
        self.task_ns.store(0, Ordering::Relaxed);
        self.sched_ns.store(0, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        MetricsSnapshot {
            tasks_executed: self.tasks_executed.load(Ordering::Relaxed),
            dummy_tasks: self.dummy_tasks.load(Ordering::Relaxed),
            jit_dispatches: self.jit_dispatches.load(Ordering::Relaxed),
            aot_hits: self.aot_hits.load(Ordering::Relaxed),
            events_activated: self.events_activated.load(Ordering::Relaxed),
            worker_idle_spins: self.worker_idle_spins.load(Ordering::Relaxed),
            sched_idle_spins: self.sched_idle_spins.load(Ordering::Relaxed),
            task_ns: self.task_ns.load(Ordering::Relaxed),
            sched_ns: self.sched_ns.load(Ordering::Relaxed),
        }
    }
}

/// Plain-data copy of the counters.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct MetricsSnapshot {
    pub tasks_executed: u64,
    pub dummy_tasks: u64,
    pub jit_dispatches: u64,
    pub aot_hits: u64,
    pub events_activated: u64,
    pub worker_idle_spins: u64,
    pub sched_idle_spins: u64,
    pub task_ns: u64,
    pub sched_ns: u64,
}

impl MetricsSnapshot {
    /// Scheduler overhead as a fraction of total accounted time — the
    /// paper reports 0.28% for its in-kernel scheduler (§6.6).
    pub fn sched_overhead(&self) -> f64 {
        let total = self.task_ns + self.sched_ns;
        if total == 0 {
            0.0
        } else {
            self.sched_ns as f64 / total as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = RuntimeMetrics::default();
        m.inc(&m.tasks_executed);
        m.inc(&m.tasks_executed);
        assert_eq!(m.snapshot().tasks_executed, 2);
    }

    #[test]
    fn sched_overhead_fraction() {
        let m = RuntimeMetrics::default();
        m.task_ns.store(9900, Ordering::Relaxed);
        m.sched_ns.store(100, Ordering::Relaxed);
        assert!((m.snapshot().sched_overhead() - 0.01).abs() < 1e-9);
    }
}
