//! MPK: a compiler and runtime for mega-kernelizing tensor programs.
//!
//! Rust + JAX + Pallas reproduction of the MPK paper (CMU, 2025). See
//! DESIGN.md for the full architecture. Quick tour:
//!
//! * [`ops`] — computation-graph IR (operators, tensors, tile regions).
//! * [`models`] — decode-iteration graph builders for the paper's models.
//! * [`tgraph`] — the MPK compiler: operator decomposition, dependency
//!   analysis, event fusion, normalization, linearization (§4).
//! * [`megakernel`] — the in-kernel parallel runtime, threaded: workers,
//!   schedulers, events, hybrid JIT/AOT launch, paged shared memory (§5).
//! * [`runtime`] / [`exec`] — PJRT-backed real-numerics execution of
//!   compiled tGraphs (HLO text artifacts built by `make artifacts`).
//! * [`sim`] — discrete-event GPU timing simulator regenerating the
//!   paper's figures on A100/H100/B200 roofline models.
//! * [`serving`] — the step-driven streaming serving API (§6.1): build
//!   an engine with `serving::ServeEngine::builder()`, `submit()`
//!   requests at any time, drive one decode iteration per `step()` and
//!   stream its `TokenEvent`s, `cancel()` mid-flight; continuous
//!   batching + paged KV + stable slots underneath, typed
//!   `serving::EngineError` throughout.
//! * [`moe`] — expert routing + hybrid workload balancer (§6.4).
//! * [`multigpu`] — tensor parallelism + collective decomposition (§6.5).
#![deny(rustdoc::broken_intra_doc_links)]
pub mod exec;
pub mod megakernel;
pub mod metrics;
pub mod models;
pub mod moe;
pub mod multigpu;
pub mod ops;
pub mod proputil;
pub mod runtime;
pub mod serving;
pub mod sim;
pub mod tgraph;
pub mod util;
