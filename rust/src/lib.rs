//! MPK: a compiler and runtime for mega-kernelizing tensor programs.
//!
//! Rust + JAX + Pallas reproduction of the MPK paper (CMU, 2025). See
//! DESIGN.md for the full architecture. Quick tour:
//!
//! * [`ops`] — computation-graph IR (operators, tensors, tile regions).
//! * [`models`] — decode-iteration graph builders for the paper's models.
//! * [`tgraph`] — the MPK compiler: operator decomposition, dependency
//!   analysis, event fusion, normalization, linearization (§4), and the
//!   static race/deadlock verifier ([`tgraph::verify`]) that re-derives
//!   every task's read/write footprint and checks it against the
//!   happens-before relation of the compiled task/event DAG — the
//!   machine-checked half of the aliasing contract that
//!   [`exec::store`]'s zero-copy memory model relies on (run it from
//!   the CLI with `mpk verify`).
//! * [`megakernel`] — the in-kernel parallel runtime, threaded: workers,
//!   schedulers, events, hybrid JIT/AOT launch, paged shared memory (§5).
//! * [`runtime`] / [`exec`] — real-numerics execution of compiled
//!   tGraphs through pluggable [`runtime::ExecBackend`]s: the native
//!   CPU backend (`runtime::backend::cpu`, artifact-free, the default —
//!   decode runs end to end with no artifacts dir and no PJRT library)
//!   and the PJRT backend (`runtime::backend::pjrt`, compiles the HLO
//!   text artifacts built by `make artifacts`). The
//!   [`runtime::ExecPool`] owns the typed execution-boundary protocol
//!   ([`runtime::PoolError`], zero-copy `execute_into` scatter);
//!   backends own only numerics.
//! * [`sim`] — discrete-event GPU timing simulator regenerating the
//!   paper's figures on A100/H100/B200 roofline models.
//! * [`serving`] — the overload-hardened serving stack (§6.1): spawn a
//!   `serving::ServeServer` (one thread owns the engine's `step()`
//!   loop), submit from any thread via `serving::ServerClient` with a
//!   `serving::Priority` class and a deadline, and read each request's
//!   `TokenEvent`s off its `serving::TokenStream` — bounded wait queue
//!   with typed shedding, deadlines as scheduled terminations, and
//!   fault-tolerant steps (retry, then quarantine the attributed
//!   request) underneath. The embeddable `serving::ServeEngine`
//!   (continuous batching + stable slots, typed `serving::EngineError`
//!   throughout) remains for callers that want to own the loop. KV
//!   memory is either a contiguous per-slot arena (the default) or —
//!   with `EngineBuilder::paged_kv` — the block-granular
//!   `serving::PagedKvPool` ([`serving::paged`]): per-request block
//!   tables over the shared slab, copy-on-write prefix sharing keyed
//!   by a rolling hash of full prompt blocks (a wave sharing a system
//!   prompt physically shares its prefix), chunked prefill that
//!   spreads long prompts across extra epochs without stalling decode,
//!   and typed `Shed` displacement on pool exhaustion — steady-state
//!   decode stays zero-copy and zero-alloc either way.
//!   `serving::ServeTransport` puts the server behind a TCP socket: a
//!   versioned length-prefixed frame protocol (`serving::wire`) with
//!   read/write deadlines, frame-size caps, per-connection
//!   backpressure, disconnect-cancels-requests, and a bounded graceful
//!   drain; the `Status` frame carries the KV pool gauges.
//! * [`moe`] — expert routing + hybrid workload balancer (§6.4).
//! * [`multigpu`] — tensor parallelism + collective decomposition (§6.5).
#![deny(rustdoc::broken_intra_doc_links)]
#![deny(unsafe_op_in_unsafe_fn)]
pub mod exec;
pub mod megakernel;
pub mod metrics;
pub mod models;
pub mod moe;
pub mod multigpu;
pub mod ops;
pub mod proputil;
pub mod runtime;
pub mod serving;
pub mod sim;
pub mod tgraph;
pub mod util;
