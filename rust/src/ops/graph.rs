//! The kernel-level computation graph (the MPK compiler's *input*).
//!
//! A [`CompGraph`] is a DAG of [`Op`]s over [`TensorMeta`] edges, built
//! with a small builder API. Graph inputs (activations) and parameters
//! are tensors with no producer. Validation checks single-producer /
//! shape-rank sanity, and `topo_order` yields a deterministic
//! topological ordering used by the decomposer and the baselines.

use super::op::{LaunchMode, Op, OpKind};
use super::tensor::{DType, TensorId, TensorMeta};
use std::collections::HashMap;

/// A tensor-program DAG.
#[derive(Clone, Debug, Default)]
pub struct CompGraph {
    pub tensors: Vec<TensorMeta>,
    pub ops: Vec<Op>,
    /// producer op id per tensor (None for graph inputs / params).
    pub producer: Vec<Option<usize>>,
    name_index: HashMap<String, TensorId>,
}

impl CompGraph {
    pub fn new() -> Self {
        Self::default()
    }

    /// Declare a graph input (activation fed each iteration).
    pub fn input(&mut self, name: &str, shape: Vec<usize>, dtype: DType) -> TensorId {
        self.add_tensor(name, shape, dtype, false)
    }

    /// Declare a parameter (weights resident in device memory).
    pub fn param(&mut self, name: &str, shape: Vec<usize>, dtype: DType) -> TensorId {
        self.add_tensor(name, shape, dtype, true)
    }

    fn add_tensor(&mut self, name: &str, shape: Vec<usize>, dtype: DType, is_param: bool) -> TensorId {
        let id = self.tensors.len();
        assert!(
            !self.name_index.contains_key(name),
            "duplicate tensor name: {name}"
        );
        self.tensors.push(TensorMeta { id, name: name.to_string(), shape, dtype, is_param });
        self.producer.push(None);
        self.name_index.insert(name.to_string(), id);
        id
    }

    /// Append an operator producing a fresh output tensor.
    pub fn op(
        &mut self,
        name: &str,
        kind: OpKind,
        inputs: &[TensorId],
        out_shape: Vec<usize>,
        dtype: DType,
    ) -> TensorId {
        let out = self.add_tensor(name, out_shape, dtype, false);
        let id = self.ops.len();
        self.ops.push(Op {
            id,
            name: name.to_string(),
            kind,
            inputs: inputs.to_vec(),
            output: out,
            partition_hint: None,
            launch_override: None,
        });
        self.producer[out] = Some(id);
        out
    }

    /// Set a partition hint on the most recently added op.
    pub fn hint_last(&mut self, hint: Vec<usize>) {
        let op = self.ops.last_mut().expect("no ops yet");
        op.partition_hint = Some(hint);
    }

    /// Force a launch mode on the most recently added op.
    pub fn launch_last(&mut self, mode: LaunchMode) {
        let op = self.ops.last_mut().expect("no ops yet");
        op.launch_override = Some(mode);
    }

    pub fn tensor(&self, id: TensorId) -> &TensorMeta {
        &self.tensors[id]
    }

    pub fn tensor_by_name(&self, name: &str) -> Option<&TensorMeta> {
        self.name_index.get(name).map(|&id| &self.tensors[id])
    }

    /// Consumers of a tensor: ops that list it among inputs.
    pub fn consumers(&self, t: TensorId) -> Vec<usize> {
        self.ops
            .iter()
            .filter(|op| op.inputs.contains(&t))
            .map(|op| op.id)
            .collect()
    }

    /// Input shapes of an op (cloned), in input order.
    pub fn in_shapes(&self, op: &Op) -> Vec<Vec<usize>> {
        op.inputs.iter().map(|&t| self.tensors[t].shape.clone()).collect()
    }

    /// Deterministic topological order of op ids (Kahn, stable by id).
    pub fn topo_order(&self) -> Vec<usize> {
        let n = self.ops.len();
        let mut indeg = vec![0usize; n];
        for op in &self.ops {
            // dedupe: an op may consume the same tensor several times
            // (fused QKV used as q/k/v, SwiGLU's packed gate‖up), but a
            // producer unblocks the consumer exactly once.
            let mut ins: Vec<_> = op.inputs.iter().filter(|&&t| self.producer[t].is_some()).collect();
            ins.sort_unstable();
            ins.dedup();
            indeg[op.id] = ins.len();
        }
        let mut ready: Vec<usize> = (0..n).filter(|&i| indeg[i] == 0).collect();
        ready.sort_unstable();
        let mut order = Vec::with_capacity(n);
        let mut qi = 0;
        while qi < ready.len() {
            let id = ready[qi];
            qi += 1;
            order.push(id);
            let out = self.ops[id].output;
            let mut newly: Vec<usize> = Vec::new();
            for c in self.consumers(out) {
                indeg[c] -= 1;
                if indeg[c] == 0 {
                    newly.push(c);
                }
            }
            newly.sort_unstable();
            ready.extend(newly);
        }
        assert_eq!(order.len(), n, "computation graph has a cycle");
        order
    }

    /// Structural validation: every op input exists, outputs have a
    /// unique producer, elementwise ops have matching input shapes.
    pub fn validate(&self) -> Result<(), String> {
        for op in &self.ops {
            for &t in &op.inputs {
                if t >= self.tensors.len() {
                    return Err(format!("op {}: missing input tensor {t}", op.name));
                }
            }
            if self.producer[op.output] != Some(op.id) {
                return Err(format!("op {}: output producer mismatch", op.name));
            }
            match op.kind {
                OpKind::Add => {
                    let a = &self.tensors[op.inputs[0]].shape;
                    let b = &self.tensors[op.inputs[1]].shape;
                    if a != b {
                        return Err(format!("op {}: Add shape mismatch {a:?} vs {b:?}", op.name));
                    }
                }
                OpKind::MatMul => {
                    let x = &self.tensors[op.inputs[0]].shape;
                    let w = &self.tensors[op.inputs[1]].shape;
                    if x[1] != w[0] {
                        return Err(format!("op {}: MatMul K mismatch {x:?} vs {w:?}", op.name));
                    }
                }
                _ => {}
            }
        }
        // topo_order asserts acyclicity.
        let _ = self.topo_order();
        Ok(())
    }

    /// Total modeled parameter bytes (drives the bandwidth lower bound of
    /// §6.3: decode latency ≥ param bytes / HBM bandwidth).
    pub fn param_bytes(&self) -> u64 {
        self.tensors.iter().filter(|t| t.is_param).map(|t| t.bytes() as u64).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_graph() -> CompGraph {
        let mut g = CompGraph::new();
        let x = g.input("x", vec![2, 8], DType::F32);
        let w1 = g.param("w1", vec![8, 16], DType::F32);
        let w2 = g.param("w2", vec![16, 8], DType::F32);
        let h = g.op("h", OpKind::MatMul, &[x, w1], vec![2, 16], DType::F32);
        let y = g.op("y", OpKind::MatMul, &[h, w2], vec![2, 8], DType::F32);
        let _z = g.op("z", OpKind::Add, &[y, x], vec![2, 8], DType::F32);
        g
    }

    #[test]
    fn build_and_validate() {
        let g = tiny_graph();
        assert!(g.validate().is_ok());
        assert_eq!(g.ops.len(), 3);
        assert_eq!(g.topo_order(), vec![0, 1, 2]);
    }

    #[test]
    fn consumers_and_producer() {
        let g = tiny_graph();
        let h = g.tensor_by_name("h").unwrap().id;
        assert_eq!(g.consumers(h), vec![1]);
        assert_eq!(g.producer[h], Some(0));
        let x = g.tensor_by_name("x").unwrap().id;
        assert_eq!(g.producer[x], None);
        assert_eq!(g.consumers(x), vec![0, 2]);
    }

    #[test]
    fn param_bytes_counts_only_params() {
        let g = tiny_graph();
        assert_eq!(g.param_bytes(), ((8 * 16 + 16 * 8) * 4) as u64);
    }

    #[test]
    #[should_panic(expected = "duplicate tensor name")]
    fn duplicate_names_rejected() {
        let mut g = CompGraph::new();
        g.input("x", vec![1], DType::F32);
        g.input("x", vec![1], DType::F32);
    }

    #[test]
    fn shape_mismatch_detected() {
        let mut g = CompGraph::new();
        let x = g.input("x", vec![2, 8], DType::F32);
        let w = g.param("w", vec![4, 16], DType::F32);
        g.op("bad", OpKind::MatMul, &[x, w], vec![2, 16], DType::F32);
        assert!(g.validate().is_err());
    }
}
