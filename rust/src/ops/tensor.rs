//! Tensor metadata and tile-region algebra.
//!
//! A [`Region`] is an axis-aligned hyper-rectangle of a tensor, written as
//! per-dimension half-open ranges. Regions are the currency of the MPK
//! compiler: operator decomposition partitions each operator's *output*
//! tensor into disjoint regions (one per task), and dependency analysis
//! introduces an event between two tasks iff the producer's output region
//! overlaps the consumer's input region (§4.1).

use std::fmt;

/// Element type of a tensor. The paper serves in bf16; our CPU/PJRT real
/// path runs f32 (the interpret-mode Pallas kernels are f32), while the
/// cost model accounts bytes with the *modeled* dtype.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum DType {
    F32,
    BF16,
    I32,
}

impl DType {
    /// Size in bytes of one element.
    pub fn size(self) -> usize {
        match self {
            DType::F32 | DType::I32 => 4,
            DType::BF16 => 2,
        }
    }
}

impl fmt::Display for DType {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            DType::F32 => write!(f, "f32"),
            DType::BF16 => write!(f, "bf16"),
            DType::I32 => write!(f, "i32"),
        }
    }
}

/// Identifier of a tensor within a [`crate::ops::CompGraph`].
pub type TensorId = usize;

/// Metadata for one tensor (an edge in the computation graph).
#[derive(Clone, Debug)]
pub struct TensorMeta {
    pub id: TensorId,
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
    /// True for weights/params: resident in device memory, never produced
    /// by an operator in the graph.
    pub is_param: bool,
}

impl TensorMeta {
    /// Total number of elements.
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    /// Total size in bytes under the modeled dtype.
    pub fn bytes(&self) -> usize {
        self.numel() * self.dtype.size()
    }

    /// The region covering the whole tensor.
    pub fn full_region(&self) -> Region {
        Region::full(&self.shape)
    }
}

/// An axis-aligned hyper-rectangle: `dims[i] = (start, end)` half-open.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct Region {
    pub dims: Vec<(usize, usize)>,
}

impl Region {
    /// Region covering an entire shape.
    pub fn full(shape: &[usize]) -> Self {
        Region { dims: shape.iter().map(|&s| (0, s)).collect() }
    }

    /// Build from explicit ranges.
    pub fn new(dims: Vec<(usize, usize)>) -> Self {
        Region { dims }
    }

    /// Number of dimensions.
    pub fn rank(&self) -> usize {
        self.dims.len()
    }

    /// Number of elements covered.
    pub fn numel(&self) -> usize {
        self.dims.iter().map(|&(s, e)| e.saturating_sub(s)).product()
    }

    /// True if any dimension is empty.
    pub fn is_empty(&self) -> bool {
        self.dims.iter().any(|&(s, e)| e <= s)
    }

    /// Hyper-rectangle intersection test. Regions of differing rank never
    /// overlap (they belong to tensors of different shapes and callers
    /// must not compare them, but we fail safe).
    pub fn overlaps(&self, other: &Region) -> bool {
        if self.rank() != other.rank() || self.is_empty() || other.is_empty() {
            return false;
        }
        self.dims
            .iter()
            .zip(other.dims.iter())
            .all(|(&(a0, a1), &(b0, b1))| a0 < b1 && b0 < a1)
    }

    /// True if `self` fully contains `other`.
    pub fn contains(&self, other: &Region) -> bool {
        if self.rank() != other.rank() {
            return false;
        }
        self.dims
            .iter()
            .zip(other.dims.iter())
            .all(|(&(a0, a1), &(b0, b1))| a0 <= b0 && b1 <= a1)
    }

    /// Extent (length) along dimension `d`.
    pub fn extent(&self, d: usize) -> usize {
        let (s, e) = self.dims[d];
        e.saturating_sub(s)
    }
}

impl fmt::Display for Region {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "[")?;
        for (i, (s, e)) in self.dims.iter().enumerate() {
            if i > 0 {
                write!(f, ", ")?;
            }
            write!(f, "{s}:{e}")?;
        }
        write!(f, "]")
    }
}

/// Split `extent` into `parts` contiguous, near-equal half-open ranges
/// (first `extent % parts` ranges get one extra element). `parts` is
/// clamped to `extent` so no range is empty.
pub fn split_ranges(extent: usize, parts: usize) -> Vec<(usize, usize)> {
    let parts = parts.clamp(1, extent.max(1));
    let base = extent / parts;
    let rem = extent % parts;
    let mut out = Vec::with_capacity(parts);
    let mut start = 0;
    for i in 0..parts {
        let len = base + usize::from(i < rem);
        out.push((start, start + len));
        start += len;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn region_overlap_basic() {
        let a = Region::new(vec![(0, 4), (0, 4)]);
        let b = Region::new(vec![(2, 6), (3, 8)]);
        let c = Region::new(vec![(4, 8), (0, 4)]);
        assert!(a.overlaps(&b));
        assert!(!a.overlaps(&c)); // touching at boundary: half-open, no overlap
        assert!(b.overlaps(&c));
    }

    #[test]
    fn region_contains() {
        let a = Region::new(vec![(0, 8), (0, 8)]);
        let b = Region::new(vec![(2, 4), (3, 8)]);
        assert!(a.contains(&b));
        assert!(!b.contains(&a));
        assert!(a.contains(&a));
    }

    #[test]
    fn empty_region_never_overlaps() {
        let a = Region::new(vec![(3, 3), (0, 4)]);
        let b = Region::new(vec![(0, 8), (0, 8)]);
        assert!(a.is_empty());
        assert!(!a.overlaps(&b));
        assert!(!b.overlaps(&a));
    }

    #[test]
    fn rank_mismatch_is_safe() {
        let a = Region::new(vec![(0, 4)]);
        let b = Region::new(vec![(0, 4), (0, 4)]);
        assert!(!a.overlaps(&b));
        assert!(!a.contains(&b));
    }

    #[test]
    fn split_ranges_covers_exactly() {
        for extent in [1usize, 7, 16, 100] {
            for parts in [1usize, 2, 3, 16, 200] {
                let r = split_ranges(extent, parts);
                assert_eq!(r[0].0, 0);
                assert_eq!(r.last().unwrap().1, extent);
                for w in r.windows(2) {
                    assert_eq!(w[0].1, w[1].0);
                    assert!(w[0].1 > w[0].0);
                }
            }
        }
    }

    #[test]
    fn split_ranges_near_equal() {
        let r = split_ranges(10, 3);
        assert_eq!(r, vec![(0, 4), (4, 7), (7, 10)]);
    }

    #[test]
    fn tensor_meta_bytes() {
        let t = TensorMeta {
            id: 0,
            name: "w".into(),
            shape: vec![4, 8],
            dtype: DType::BF16,
            is_param: true,
        };
        assert_eq!(t.numel(), 32);
        assert_eq!(t.bytes(), 64);
        assert_eq!(t.full_region(), Region::new(vec![(0, 4), (0, 8)]));
    }
}
