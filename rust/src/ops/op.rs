//! Operators of the computation graph.
//!
//! Each operator consumes input tensors and produces exactly one output
//! tensor. The [`OpKind`] carries everything the MPK compiler needs:
//! which output dimensions may be partitioned into tasks, how an output
//! tile maps back onto input regions (the core of §4.1 dependency
//! analysis), a roofline cost (flops + bytes) per tile, and whether the
//! operator's duration is data-dependent (→ JIT launch, §5.2).

use super::tensor::{Region, TensorId};

/// Task launch mode (§5.2). Operators with data-dependent durations are
/// JIT; everything else defaults to AOT to minimize dispatch overhead.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum LaunchMode {
    Jit,
    Aot,
}

/// The operator vocabulary needed for the paper's workloads (dense and
/// MoE transformer decode iterations, plus tensor-parallel collectives).
#[derive(Clone, Debug, PartialEq)]
pub enum OpKind {
    /// ids\[B\] × table\[V, D\] → \[B, D\]. Gather rows of the embedding table.
    Embedding,
    /// x\[B, D\] (+ weight\[D\]) → \[B, D\].
    RmsNorm,
    /// x\[B, K\] × w\[K, N\] → \[B, N\]. Linear layer / projection.
    MatMul,
    /// Decode attention over a KV cache of `kv_len` tokens per request:
    /// q\[B, Hq·dh\] (+ caches) → \[B, Hq·dh\]. `heads`/`kv_heads`/`head_dim`
    /// drive the cost model; duration is data-dependent (variable kv_len).
    Attention { heads: usize, kv_heads: usize, head_dim: usize, kv_len: usize },
    /// Append this step's K/V rows to the paged cache: elementwise-cheap.
    KvAppend,
    /// Elementwise a + b.
    Add,
    /// Elementwise silu(gate) * up.
    SwiGLU,
    /// Ring all-reduce across `world` devices; elementwise dependency on
    /// its input (each output tile depends only on the matching input
    /// tile — the Figure 4 fine-grained overlap enabler).
    AllReduce { world: usize },
    /// Top-k softmax router: x\[B, D\] × wg\[D, E\] → meta\[B, topk\].
    MoeRoute { experts: usize, topk: usize },
    /// Grouped expert GEMM: tokens routed to `expert` through w\[K, N\].
    /// `avg_tokens` is the compile-time load estimate for cost/partition.
    MoeExpertGemm { expert: usize, avg_tokens: usize },
    /// Weighted scatter-add of expert outputs back to token order.
    MoeCombine { topk: usize },
}

impl OpKind {
    /// Short mnemonic used in task names and reports (MM/AT/AR… as in
    /// the paper's figures).
    pub fn mnemonic(&self) -> &'static str {
        match self {
            OpKind::Embedding => "EMB",
            OpKind::RmsNorm => "RMS",
            OpKind::MatMul => "MM",
            OpKind::Attention { .. } => "AT",
            OpKind::KvAppend => "KV",
            OpKind::Add => "ADD",
            OpKind::SwiGLU => "GLU",
            OpKind::AllReduce { .. } => "AR",
            OpKind::MoeRoute { .. } => "RT",
            OpKind::MoeExpertGemm { .. } => "EXP",
            OpKind::MoeCombine { .. } => "CMB",
        }
    }

    /// True for inter-GPU communication operators (orange tasks in the
    /// paper's figures).
    pub fn is_comm(&self) -> bool {
        matches!(self, OpKind::AllReduce { .. })
    }

    /// Default launch mode (§5.2): operators whose execution time depends
    /// on runtime data are JIT. Attention (variable sequence length) and
    /// the expert GEMMs / combine (variable tokens-per-expert) qualify.
    pub fn default_launch(&self) -> LaunchMode {
        match self {
            OpKind::Attention { .. }
            | OpKind::MoeExpertGemm { .. }
            | OpKind::MoeCombine { .. } => LaunchMode::Jit,
            _ => LaunchMode::Aot,
        }
    }

    /// Map an output tile back to the input region it consumes
    /// (`input_idx` indexes the op's input list; `in_shape` is that
    /// input's shape). This implements the producer/consumer overlap test
    /// of §4.1: an event is inserted between tasks `(t1, t2)` iff
    /// `t1.out_region` overlaps `input_region(t2.out_region, i)`.
    pub fn input_region(&self, out: &Region, input_idx: usize, in_shape: &[usize]) -> Region {
        let full = Region::full(in_shape);
        match self {
            // Gather: ids rows match output rows; the table is read at
            // data-dependent rows → conservatively the whole table.
            OpKind::Embedding => {
                if input_idx == 0 {
                    Region::new(vec![(out.dims[0].0, out.dims[0].1)])
                } else {
                    full
                }
            }
            // Row-wise: x rows match output rows, weight fully read.
            OpKind::RmsNorm => {
                if input_idx == 0 {
                    Region::new(vec![out.dims[0], (0, in_shape[1])])
                } else {
                    full
                }
            }
            // out[r, c] reads x[r, :] and w[:, c].
            OpKind::MatMul => {
                if input_idx == 0 {
                    Region::new(vec![out.dims[0], (0, in_shape[1])])
                } else {
                    Region::new(vec![(0, in_shape[0]), out.dims[1]])
                }
            }
            // Attention tasks tile [request rows × head groups]: the q
            // slice follows the output columns (input 0), while KV cache
            // inputs are read conservatively in full for the task's rows
            // (cache layout interleaves kv-heads; caches are state
            // tensors, so precision there does not cost concurrency).
            OpKind::Attention { .. } => {
                if input_idx == 0 {
                    // q (or fused qkv) rows for the task's requests; full
                    // width — fused-append tasks also read this step's
                    // K/V columns.
                    Region::new(vec![out.dims[0], (0, in_shape[1])])
                } else {
                    let mut dims = vec![out.dims[0]];
                    for &s in &in_shape[1..] {
                        dims.push((0, s));
                    }
                    Region::new(dims)
                }
            }
            // Row-wise append into the cache.
            OpKind::KvAppend => {
                let mut dims = vec![out.dims[0]];
                for &s in &in_shape[1..] {
                    dims.push((0, s));
                }
                Region::new(dims)
            }
            // Elementwise: identical region.
            OpKind::Add => out.clone(),
            // Gate/up are packed side by side in one input of width 2F:
            // an output tile [r, c0:c1] reads [r, c0:c1] and
            // [r, F+c0:F+c1]. Regions are single rectangles, so we use
            // the conservative row-aligned full-width region (correct,
            // slightly over-synchronized).
            OpKind::SwiGLU => Region::new(vec![out.dims[0], (0, in_shape[1])]),
            // Elementwise collective: the fine-grained dependency that
            // lets AllReduce tiles start before the whole MatMul is done.
            OpKind::AllReduce { .. } => out.clone(),
            // Router reads its token rows fully, gate weight fully.
            OpKind::MoeRoute { .. } => {
                if input_idx == 0 {
                    Region::new(vec![out.dims[0], (0, in_shape[1])])
                } else {
                    full
                }
            }
            // Expert GEMM: which tokens reach the expert is data-
            // dependent → conservatively all token rows of x / the route
            // meta, full weight tile columns.
            OpKind::MoeExpertGemm { .. } => full,
            // Combine: reads expert outputs at data-dependent rows.
            OpKind::MoeCombine { .. } => full,
        }
    }

    /// Floating-point operations to produce `out` (modeled).
    pub fn flops(&self, out: &Region, in_shapes: &[Vec<usize>]) -> u64 {
        let n = out.numel() as u64;
        match self {
            OpKind::Embedding | OpKind::KvAppend => 0,
            OpKind::RmsNorm => 4 * n,
            OpKind::MatMul => {
                let k = in_shapes[0][1] as u64;
                2 * n * k
            }
            OpKind::Attention { kv_heads, head_dim, kv_len, heads } => {
                // QK^T + PV over kv_len for the head slice this tile
                // covers (FlashDecoding-style split across head groups).
                let rows = out.extent(0) as u64;
                let q_dim = (*heads * *head_dim) as u64;
                let frac = out.extent(1) as f64 / q_dim.max(1) as f64;
                let _ = kv_heads;
                let full = 4 * rows * (*heads as u64) * (*head_dim as u64) * (*kv_len as u64);
                (full as f64 * frac) as u64
            }
            OpKind::Add => n,
            OpKind::SwiGLU => 4 * n,
            OpKind::AllReduce { world } => n * (*world as u64 - 1).max(1),
            OpKind::MoeRoute { experts, .. } => {
                let rows = out.extent(0) as u64;
                let d = in_shapes[0][1] as u64;
                2 * rows * d * (*experts as u64)
            }
            OpKind::MoeExpertGemm { avg_tokens, .. } => {
                let k = in_shapes[1][0] as u64;
                let ncols = out.extent(1) as u64;
                2 * (*avg_tokens as u64) * k * ncols
            }
            OpKind::MoeCombine { topk } => n * (*topk as u64) * 2,
        }
    }

    /// Device-memory bytes moved (read + write) to produce `out`, with
    /// `elem` bytes per element. Dominant term for decode is weight
    /// streaming, which is what makes LLM decode bandwidth-bound.
    pub fn bytes(&self, out: &Region, in_shapes: &[Vec<usize>], elem: usize) -> u64 {
        let write = (out.numel() * elem) as u64;
        let read: u64 = match self {
            OpKind::Embedding => (out.numel() * elem) as u64,
            OpKind::RmsNorm => (out.numel() * elem + in_shapes[1].iter().product::<usize>() * elem) as u64,
            OpKind::MatMul => {
                let rows = out.extent(0);
                let k = in_shapes[0][1];
                let cols = out.extent(1);
                ((rows * k + k * cols) * elem) as u64
            }
            OpKind::Attention { kv_heads, head_dim, kv_len, heads } => {
                // each head-group tile streams its share of the KV cache.
                let rows = out.extent(0);
                let q_dim = heads * head_dim;
                let frac = out.extent(1) as f64 / q_dim.max(1) as f64;
                let kv_bytes = (2 * kv_heads * head_dim * kv_len) as f64 * frac;
                ((rows as f64 * (out.extent(1) as f64 + kv_bytes)) * elem as f64) as u64
            }
            OpKind::KvAppend => (2 * out.numel() * elem) as u64,
            OpKind::Add | OpKind::SwiGLU => (2 * out.numel() * elem) as u64,
            OpKind::AllReduce { world } => {
                // ring: each element crosses the link 2(w-1)/w times;
                // count local read+write once here, link cost modeled by
                // the interconnect.
                let w = *world as u64;
                (out.numel() as u64 * elem as u64) * 2 * (w - 1).max(1) / w.max(1)
            }
            OpKind::MoeRoute { experts, .. } => {
                let rows = out.extent(0);
                let d = in_shapes[0][1];
                ((rows * d + d * experts) * elem) as u64
            }
            OpKind::MoeExpertGemm { avg_tokens, .. } => {
                let k = in_shapes[1][0];
                let cols = out.extent(1);
                ((avg_tokens * k + k * cols) * elem) as u64
            }
            OpKind::MoeCombine { topk } => (out.numel() * topk * elem) as u64,
        };
        read + write
    }
}

/// One operator instance in a [`crate::ops::CompGraph`].
#[derive(Clone, Debug)]
pub struct Op {
    pub id: usize,
    pub name: String,
    pub kind: OpKind,
    pub inputs: Vec<TensorId>,
    pub output: TensorId,
    /// Optional user partition hint: desired number of tiles along each
    /// output dimension (§4.1 "interface for custom partitioning").
    pub partition_hint: Option<Vec<usize>>,
    /// Optional launch-mode override; `None` → [`OpKind::default_launch`].
    pub launch_override: Option<LaunchMode>,
}

impl Op {
    /// Effective launch mode for the op's tasks.
    pub fn launch(&self) -> LaunchMode {
        self.launch_override.unwrap_or_else(|| self.kind.default_launch())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_input_regions() {
        let out = Region::new(vec![(2, 4), (8, 16)]);
        let k = OpKind::MatMul;
        // x[B=8, K=32]
        assert_eq!(k.input_region(&out, 0, &[8, 32]), Region::new(vec![(2, 4), (0, 32)]));
        // w[K=32, N=64]
        assert_eq!(k.input_region(&out, 1, &[32, 64]), Region::new(vec![(0, 32), (8, 16)]));
    }

    #[test]
    fn allreduce_is_elementwise() {
        let out = Region::new(vec![(0, 2), (4, 8)]);
        let k = OpKind::AllReduce { world: 4 };
        assert_eq!(k.input_region(&out, 0, &[2, 16]), out);
    }

    #[test]
    fn matmul_disjoint_col_tiles_do_not_share_weight_cols() {
        let k = OpKind::MatMul;
        let t1 = Region::new(vec![(0, 2), (0, 8)]);
        let t2 = Region::new(vec![(0, 2), (8, 16)]);
        let w1 = k.input_region(&t1, 1, &[32, 16]);
        let w2 = k.input_region(&t2, 1, &[32, 16]);
        assert!(!w1.overlaps(&w2));
    }

    #[test]
    fn default_launch_modes() {
        assert_eq!(OpKind::MatMul.default_launch(), LaunchMode::Aot);
        assert_eq!(
            OpKind::Attention { heads: 8, kv_heads: 2, head_dim: 64, kv_len: 128 }.default_launch(),
            LaunchMode::Jit
        );
        assert_eq!(OpKind::MoeExpertGemm { expert: 0, avg_tokens: 4 }.default_launch(), LaunchMode::Jit);
    }

    #[test]
    fn matmul_flops_and_bytes() {
        let out = Region::new(vec![(0, 1), (0, 64)]);
        let shapes = vec![vec![1, 128], vec![128, 64]];
        assert_eq!(OpKind::MatMul.flops(&out, &shapes), 2 * 64 * 128);
        // read x (1×128) + w (128×64), write 64, 2 bytes each
        assert_eq!(OpKind::MatMul.bytes(&out, &shapes, 2), ((128 + 128 * 64 + 64) * 2) as u64);
    }
}
