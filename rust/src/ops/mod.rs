//! Computation-graph IR: tensors, tile regions, operators, DAG builder.
pub mod graph;
pub mod op;
pub mod tensor;

pub use graph::CompGraph;
pub use op::{LaunchMode, Op, OpKind};
pub use tensor::{split_ranges, DType, Region, TensorId, TensorMeta};
