//! The step-driven streaming surface: what one decode iteration reports.
//!
//! [`ServeEngine::step`](crate::serving::ServeEngine::step) returns a
//! [`StepOutcome`] — the batch that ran plus a [`TokenEvent`] for every
//! request that produced (or terminally failed to produce) a token this
//! iteration. Streaming front-ends forward events as they arrive;
//! batch callers let [`ServeEngine::serve`](crate::serving::ServeEngine::serve)
//! drain the loop and collect outputs at the end.

/// Why a request stopped producing tokens.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FinishReason {
    /// Generated its full `max_new_tokens` budget.
    MaxTokens,
    /// Emitted the engine's end-of-sequence token (the EOS token itself
    /// is included in the output, carried by the terminal event).
    Eos,
    /// Cancelled between steps via
    /// [`ServeEngine::cancel`](crate::serving::ServeEngine::cancel).
    Cancelled,
    /// The request's deadline passed before it finished. The server
    /// front-end enforces deadlines as scheduled terminations
    /// ([`ServeEngine::terminate`](crate::serving::ServeEngine::terminate)
    /// with this reason): the request retires exactly like a
    /// cancellation, keeping whatever it generated so far.
    DeadlineExceeded,
    /// Shed under resource pressure, keeping whatever it generated so
    /// far. Two paths raise it: the server's bounded wait queue evicts
    /// an accepted request to admit a higher-priority one under
    /// overload, and — with the paged KV pool
    /// ([`EngineBuilder::paged_kv`](crate::serving::EngineBuilder::paged_kv))
    /// — a mid-decode request that needs one more block from an
    /// exhausted pool is displaced so the surviving batch keeps its
    /// zero-copy decode guarantee. Only requests that were *accepted*
    /// (queued, stream handed out) are shed with a terminal event; a
    /// submission refused outright gets the synchronous
    /// [`EngineError::Overloaded`](crate::serving::EngineError::Overloaded)
    /// rejection instead.
    Shed,
    /// Quarantined by the fault-recovery path: repeated epoch failures
    /// were attributed to this request, so the engine retired it to
    /// protect the rest of the batch instead of tearing itself down.
    Failed,
}

/// One streamed notification for one request.
///
/// A request emits one `TokenEvent` per iteration once it is past
/// prefill (prompt-consuming iterations emit nothing — their logits
/// belong to prompt positions). The last event carries
/// `finish: Some(_)`; exactly one terminal event is emitted per
/// request. Terminations that produce no token — cancellation,
/// deadline expiry, shedding, quarantine — emit a terminal event with
/// `token: None`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TokenEvent {
    /// Request id (as passed to `submit`).
    pub request: u64,
    /// The token decoded this iteration; `None` on tokenless terminal
    /// events (`Cancelled` / `DeadlineExceeded` / `Shed` / `Failed`).
    pub token: Option<i32>,
    /// Set on the request's terminal event, absent while it streams.
    pub finish: Option<FinishReason>,
}

/// What one [`ServeEngine::step`](crate::serving::ServeEngine::step)
/// call did.
#[derive(Clone, Debug, Default)]
pub struct StepOutcome {
    /// Per-request events this iteration: one per active request past
    /// prefill, plus terminal notices for requests cancelled since the
    /// previous step.
    pub events: Vec<TokenEvent>,
    /// Active requests that decoded this iteration; `0` means the step
    /// was idle (no slot occupied after retire/admit — nothing ran).
    pub ran: usize,
}

impl StepOutcome {
    /// True when the step ran no decode iteration (the engine was
    /// empty). Pending cancellation events may still be delivered on an
    /// idle step, so check [`StepOutcome::events`] regardless.
    pub fn is_idle(&self) -> bool {
        self.ran == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn outcome_idle_iff_nothing_ran() {
        let idle = StepOutcome::default();
        assert!(idle.is_idle());
        let busy = StepOutcome { events: Vec::new(), ran: 2 };
        assert!(!busy.is_idle());
        // cancellation notices can ride an otherwise idle step.
        let notice = StepOutcome {
            events: vec![TokenEvent { request: 9, token: None, finish: Some(FinishReason::Cancelled) }],
            ran: 0,
        };
        assert!(notice.is_idle());
        assert_eq!(notice.events[0].finish, Some(FinishReason::Cancelled));
    }
}
