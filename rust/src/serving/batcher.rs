//! Requests and continuous batching (§6.1, Orca-style) over **stable
//! slots**.
//!
//! Each decode iteration the engine (1) retires finished requests,
//! (2) admits waiting requests while KV blocks and batch slots allow,
//! and (3) picks the specialized tGraph for the next power-of-two batch
//! size. In the paper this bookkeeping runs *inside* the mega-kernel as
//! the start event's task; here it is the host-side `IterPrep`
//! counterpart driving the same state.
//!
//! # Slot policy: lowest-free-slot, no implicit compaction
//!
//! An active request keeps the slot it was admitted into until it
//! retires — retirements free the slot but never move a survivor.
//! Because every batch-size specialization aliases one shared max-batch
//! KV arena keyed by slot, stable slots make `kv_rows_migrated`
//! *structurally* zero: there is no code path that relocates a live
//! request's cache rows behind the engine's back. The cost is
//! fragmentation: after retirements the highest occupied slot (not the
//! active count) bounds which specialized graph must run, so the engine
//! occasionally executes the next-larger graph than the active count
//! strictly needs. New admissions take the **lowest** free slot, so
//! fragmentation heals through churn instead of through copies.
//!
//! The one sanctioned exception is *deliberate* anti-fragmentation
//! compaction: when the engine's opt-in flag is set, it asks
//! [`Batcher::compaction_candidate`] whether relocating exactly one
//! request (highest occupied slot → lowest free slot) would let the
//! specialized-graph batch drop a whole power of two, applies the slot
//! move via [`Batcher::relocate`], and pays the KV row copy itself —
//! counted honestly in `kv_rows_migrated`, never silent.

use crate::serving::error::EngineError;
use crate::serving::kvcache::KvAllocator;
use crate::serving::paged::PagedKvPool;
use crate::serving::step::FinishReason;
use std::collections::{HashSet, VecDeque};

/// The batcher's KV capacity backend — one of two admission regimes
/// behind a uniform accounting surface:
///
/// * [`KvPool::Slab`]: the legacy slot-contiguous mode. Admission
///   reserves a request's **worst case** (`prompt + max_new_tokens`)
///   up front; block ids are pure accounting (rows live in the arena
///   slot).
/// * [`KvPool::Paged`]: block tables over the same arena. Admission
///   reserves **prompt-length blocks only** (shared prefix blocks are
///   mapped, not allocated) and decode grows on demand — which is what
///   makes overcommit possible, and why mid-decode exhaustion must
///   shed a victim instead of panicking.
pub enum KvPool {
    Slab(KvAllocator),
    Paged(PagedKvPool),
}

impl KvPool {
    pub fn free_blocks(&self) -> usize {
        match self {
            KvPool::Slab(a) => a.free_blocks(),
            KvPool::Paged(p) => p.free_blocks(),
        }
    }

    pub fn total_blocks(&self) -> usize {
        match self {
            KvPool::Slab(a) => a.total_blocks(),
            KvPool::Paged(p) => p.total_blocks(),
        }
    }

    pub fn block_tokens(&self) -> usize {
        match self {
            KvPool::Slab(a) => a.block_tokens,
            KvPool::Paged(p) => p.block_tokens(),
        }
    }

    /// Blocks needed to hold `tokens` tokens (identical `div_ceil`
    /// rounding in both modes — the validate boundary tests pin this).
    pub fn blocks_for(&self, tokens: usize) -> usize {
        match self {
            KvPool::Slab(a) => a.blocks_for(tokens),
            KvPool::Paged(p) => p.blocks_for(tokens),
        }
    }

    pub fn held_by(&self, req: u64) -> usize {
        match self {
            KvPool::Slab(a) => a.held_by(req),
            KvPool::Paged(p) => p.held_by(req),
        }
    }

    pub fn release(&mut self, req: u64) -> usize {
        match self {
            KvPool::Slab(a) => a.release(req),
            KvPool::Paged(p) => p.release(req),
        }
    }

    /// The paged pool, when this batcher runs paged (the engine's
    /// growth/COW/promotion calls live there; `None` ⇒ legacy mode).
    pub fn paged(&self) -> Option<&PagedKvPool> {
        match self {
            KvPool::Slab(_) => None,
            KvPool::Paged(p) => Some(p),
        }
    }

    pub fn paged_mut(&mut self) -> Option<&mut PagedKvPool> {
        match self {
            KvPool::Slab(_) => None,
            KvPool::Paged(p) => Some(p),
        }
    }
}

/// A generation request.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<i32>,
    pub max_new_tokens: usize,
    /// Tokens generated so far.
    pub generated: Vec<i32>,
    /// Prompt tokens already consumed (prefill progress).
    pub prompt_pos: usize,
    /// Cache length (tokens already appended).
    pub cache_len: usize,
    /// Batch slot while active. Stable: assigned at admission, held
    /// until retirement (or moved once by a deliberate compaction pass).
    pub slot: Option<usize>,
    /// Terminal state, once reached: set by the engine at harvest
    /// (max-tokens / EOS) or by cancellation. A request with a finish
    /// reason retires at the next scheduling step.
    pub finish: Option<FinishReason>,
}

impl Request {
    pub fn new(id: u64, prompt: Vec<i32>, max_new_tokens: usize) -> Self {
        assert!(!prompt.is_empty(), "empty prompt");
        Request {
            id,
            prompt,
            max_new_tokens,
            generated: Vec::new(),
            prompt_pos: 0,
            cache_len: 0,
            slot: None,
            finish: None,
        }
    }

    /// Next token to feed the model: prompt token during prefill, last
    /// generated token during decode.
    pub fn next_input(&self) -> i32 {
        if self.prompt_pos < self.prompt.len() {
            self.prompt[self.prompt_pos]
        } else {
            *self.generated.last().unwrap_or(&0)
        }
    }

    /// True while still consuming the prompt.
    pub fn in_prefill(&self) -> bool {
        self.prompt_pos < self.prompt.len()
    }

    pub fn finished(&self) -> bool {
        self.finish.is_some() || self.generated.len() >= self.max_new_tokens
    }

    /// Total tokens this request will hold in cache after this step.
    pub fn tokens_after_step(&self) -> usize {
        self.cache_len + 1
    }
}

/// Continuous batcher over a bounded slot array with stable slots.
pub struct Batcher {
    pub max_batch: usize,
    pub max_seq: usize,
    waiting: VecDeque<Request>,
    /// Active requests, unordered (retirement uses `swap_remove`) —
    /// each request carries its own stable `slot`; never index this by
    /// slot.
    pub active: Vec<Request>,
    /// Retired requests (natural finish or cancellation), accumulated
    /// until the caller drains them (`ServeEngine::take_finished`) —
    /// long-lived streaming callers must drain periodically or this
    /// grows with every request ever served.
    pub finished: Vec<Request>,
    pub kv: KvPool,
    /// slot → occupying request id. The allocator state: admission
    /// claims the lowest `None`, retirement clears its entry, nothing
    /// else ever writes it.
    slots: Vec<Option<u64>>,
    /// Every id currently known to this batcher: waiting, active, or
    /// finished-but-undrained. Ids key KV residency, slots, and the
    /// output map, so a duplicate is rejected at submit — O(1). Pruned
    /// when retired requests are drained via [`Batcher::take_finished`]
    /// (the caller has taken ownership of the outputs, so the id no
    /// longer keys anything here), which bounds this set by
    /// `waiting + active + undrained-finished` instead of letting it
    /// grow with every request ever served.
    known_ids: HashSet<u64>,
}

impl Batcher {
    pub fn new(max_batch: usize, max_seq: usize, kv: KvAllocator) -> Self {
        Self::with_pool(max_batch, max_seq, KvPool::Slab(kv))
    }

    /// A batcher running paged admission: prompt-only reservation,
    /// prefix sharing, on-demand decode growth.
    pub fn new_paged(max_batch: usize, max_seq: usize, pool: PagedKvPool) -> Self {
        Self::with_pool(max_batch, max_seq, KvPool::Paged(pool))
    }

    fn with_pool(max_batch: usize, max_seq: usize, kv: KvPool) -> Self {
        Batcher {
            max_batch,
            max_seq,
            waiting: VecDeque::new(),
            active: Vec::new(),
            finished: Vec::new(),
            kv,
            slots: vec![None; max_batch],
            known_ids: HashSet::new(),
        }
    }

    /// Queue a request, or reject it if it can never be served safely:
    /// client-supplied input must not abort the engine *or* vanish, so
    /// an oversized request (beyond `max_seq`, or whose worst-case KV
    /// demand exceeds the whole block pool — it would wait forever and
    /// stall everything queued behind it) — or a duplicate id, which
    /// would alias another request's KV residency and slot — is an
    /// `Err`, not a panic or a silent drop.
    pub fn submit(&mut self, r: Request) -> Result<(), EngineError> {
        self.validate(&r)?;
        self.known_ids.insert(r.id);
        self.waiting.push_back(r);
        Ok(())
    }

    /// The submit-time checks without the submit: would this request be
    /// accepted right now? Non-mutating, so an admission-control layer
    /// (the server front-end) can reject unservable requests
    /// synchronously *before* queueing them in its own wait queue.
    /// Check order matches [`Batcher::submit`] exactly, so the two
    /// always agree on which typed error a request gets.
    pub fn validate(&self, r: &Request) -> Result<(), EngineError> {
        if r.max_new_tokens == 0 {
            // zero budget can never emit a terminal event: the request
            // would retire silently (or, with a 1-token prompt, decode
            // a token nobody asked for) — refuse it up front.
            return Err(EngineError::ZeroBudget { id: r.id });
        }
        let worst = r.prompt.len() + r.max_new_tokens;
        if worst > self.max_seq {
            return Err(EngineError::RequestTooLong { id: r.id, worst, max_seq: self.max_seq });
        }
        let need = self.kv.blocks_for(worst);
        if need > self.kv.total_blocks() {
            return Err(EngineError::KvPoolExceeded {
                id: r.id,
                worst,
                need_blocks: need,
                pool_blocks: self.kv.total_blocks(),
            });
        }
        if self.known_ids.contains(&r.id) {
            return Err(EngineError::DuplicateId { id: r.id });
        }
        Ok(())
    }

    /// Cancel a request *now*: a waiting request leaves the queue, an
    /// active one is retired on the spot — its slot cleared and its KV
    /// blocks released immediately, so the very next scheduling step can
    /// re-issue both. The request lands in `finished` with
    /// `finish: Some(Cancelled)` and whatever it generated so far.
    ///
    /// Typed refusals: an id this batcher never accepted is
    /// [`EngineError::UnknownRequest`]; one already terminal (retired,
    /// or its natural finish already recorded and awaiting retirement)
    /// is [`EngineError::AlreadyFinished`] — its terminal event has
    /// already been (or will be) emitted, and a second one must not be.
    pub fn cancel(&mut self, id: u64) -> Result<(), EngineError> {
        self.terminate(id, FinishReason::Cancelled)
    }

    /// The general form of [`Batcher::cancel`]: retire a request *now*
    /// with an arbitrary terminal reason. Cancellation, deadline expiry
    /// (`DeadlineExceeded`) and fault quarantine (`Failed`) are the
    /// same state transition — leave the queue or free the slot + KV
    /// blocks immediately, land in `finished` with partial output —
    /// differing only in the reason stamped on the terminal event.
    pub fn terminate(&mut self, id: u64, reason: FinishReason) -> Result<(), EngineError> {
        if let Some(pos) = self.waiting.iter().position(|r| r.id == id) {
            let mut r = self.waiting.remove(pos).expect("position came from the queue");
            r.finish = Some(reason);
            self.finished.push(r);
            return Ok(());
        }
        if let Some(pos) = self.active.iter().position(|r| r.id == id) {
            if self.active[pos].finished() {
                return Err(EngineError::AlreadyFinished { id });
            }
            let mut r = self.active.swap_remove(pos);
            self.kv.release(id);
            let slot = r.slot.take().expect("active request without slot");
            debug_assert_eq!(self.slots[slot], Some(id), "slot table out of sync");
            self.slots[slot] = None;
            r.finish = Some(reason);
            self.finished.push(r);
            return Ok(());
        }
        if self.known_ids.contains(&id) {
            Err(EngineError::AlreadyFinished { id })
        } else {
            Err(EngineError::UnknownRequest { id })
        }
    }

    /// Drain the retired-request list, releasing the drained ids for
    /// reuse.
    ///
    /// # Id-reuse semantics
    ///
    /// An id is reserved from `submit` until the drain that hands its
    /// retired request to the caller: while reserved, resubmission is a
    /// typed [`EngineError::DuplicateId`] (the id still keys a slot, KV
    /// residency, or an undrained output). After the drain the caller
    /// owns the output and the id keys nothing here, so a *new* request
    /// may legally reuse it — from the batcher's perspective it is a
    /// fresh request. Callers that key long-lived state by id across
    /// drains (dashboards, logs) must disambiguate reuse themselves.
    pub fn take_finished(&mut self) -> Vec<Request> {
        let drained = std::mem::take(&mut self.finished);
        for r in &drained {
            self.known_ids.remove(&r.id);
        }
        drained
    }

    pub fn pending(&self) -> usize {
        self.waiting.len()
    }

    pub fn has_work(&self) -> bool {
        !self.waiting.is_empty() || !self.active.is_empty()
    }

    /// Lowest unoccupied slot, if any.
    fn lowest_free_slot(&self) -> Option<usize> {
        self.slots.iter().position(Option::is_none)
    }

    /// One past the highest occupied slot (0 when idle). Because slots
    /// are never compacted this — not `active.len()` — is what the
    /// specialized graph must cover.
    pub fn slot_bound(&self) -> usize {
        self.slots.iter().rposition(Option::is_some).map_or(0, |i| i + 1)
    }

    /// One scheduling step: retire finished, admit waiting (§6.1 order).
    /// Returns ids of requests retired this step. Survivors keep their
    /// slots; freed slots are immediately reusable (lowest first).
    pub fn step_admission(&mut self) -> Vec<u64> {
        // 1. retire: free the slot, never touch survivors.
        let mut retired = Vec::new();
        let mut i = 0;
        while i < self.active.len() {
            if self.active[i].finished() {
                let mut r = self.active.swap_remove(i);
                self.kv.release(r.id);
                let slot = r.slot.take().expect("active request without slot");
                debug_assert_eq!(self.slots[slot], Some(r.id), "slot table out of sync");
                self.slots[slot] = None;
                retired.push(r.id);
                self.finished.push(r);
            } else {
                i += 1;
            }
        }
        // 2. admit into the lowest free slot while slots + KV blocks
        // allow. Slab mode reserves the worst case up front; paged
        // mode reserves prompt blocks only (shared prefix blocks are
        // mapped in for free) and the request resumes prefill past the
        // shared prefix.
        while let Some(slot) = self.lowest_free_slot() {
            let Some(front) = self.waiting.front() else { break };
            let mut r = match &mut self.kv {
                KvPool::Slab(a) => {
                    let worst = front.prompt.len() + front.max_new_tokens;
                    if !a.ensure(front.id, worst) {
                        break; // KV pressure: wait for retirements
                    }
                    self.waiting.pop_front().unwrap()
                }
                KvPool::Paged(p) => {
                    let Some(adm) = p.admit(front.id, &front.prompt) else {
                        break; // pool exhausted even after eviction
                    };
                    let mut r = self.waiting.pop_front().unwrap();
                    // shared prefix rows are already in cache: resume
                    // prefill at the first unshared token (always ≥ 1
                    // prompt token left — `resume` clamps to P−1).
                    r.prompt_pos = adm.resume;
                    r.cache_len = adm.resume;
                    r
                }
            };
            r.slot = Some(slot);
            self.slots[slot] = Some(r.id);
            self.active.push(r);
        }
        retired
    }

    /// Specialized-graph batch size for the current active set: the next
    /// power of two covering the highest occupied **slot** (§6.1 "powers
    /// of two up to the maximum batch size"), since slots are stable and
    /// may be fragmented after retirements. Returns **0** for an empty
    /// active set — `0.next_power_of_two()` is 1, and running a batch-1
    /// graph with no work is not a real iteration; the decode loop skips
    /// it.
    pub fn graph_batch(&self) -> usize {
        match self.slot_bound() {
            0 => 0,
            // slot_bound ≤ max_batch by construction (the slot table
            // has exactly max_batch entries), so no clamp is needed.
            b => b.next_power_of_two(),
        }
    }

    /// The single relocation the anti-fragmentation policy would apply,
    /// if it pays for itself: move the request at the **highest**
    /// occupied slot into the **lowest** free slot, but only when that
    /// drops [`Batcher::graph_batch`] to a smaller power of two —
    /// otherwise the copy buys nothing the lazy policy wouldn't get for
    /// free through churn. Returns `(id, src_slot, dst_slot)`; purely a
    /// probe, nothing is moved. The caller (the engine, behind its
    /// opt-in flag) applies it with [`Batcher::relocate`] *and* moves
    /// the KV rows, in that lockstep order.
    pub fn compaction_candidate(&self) -> Option<(u64, usize, usize)> {
        let bound = self.slot_bound();
        if bound <= 1 {
            return None; // empty, or already as low as slots go
        }
        let src = bound - 1; // highest occupied slot, by definition of bound
        let dst = self.lowest_free_slot()?;
        if dst >= src {
            return None; // no hole below the top occupant
        }
        // bound after the move: the highest slot that would still be
        // occupied below src (dst itself qualifies — it gains the
        // occupant), plus one. dst < src, so the rposition always hits.
        let new_bound =
            (0..src).rposition(|s| self.slots[s].is_some() || s == dst).expect("dst < src") + 1;
        if new_bound.next_power_of_two() >= bound.next_power_of_two() {
            return None; // would not drop a whole power of two
        }
        Some((self.slots[src].expect("bound slot occupied"), src, dst))
    }

    /// Apply a deliberate slot relocation decided by a compaction
    /// policy: move active request `id` to the free slot `dst`,
    /// updating the slot table and the request's own slot. This is the
    /// *only* way a live request changes slot; the caller owns moving
    /// the KV rows to match (and updating residency) before the next
    /// iteration stages by slot. Returns the vacated source slot.
    pub fn relocate(&mut self, id: u64, dst: usize) -> usize {
        assert!(dst < self.max_batch, "relocation target {dst} out of bounds");
        assert!(self.slots[dst].is_none(), "relocation target slot {dst} occupied");
        let r = self
            .active
            .iter_mut()
            .find(|r| r.id == id)
            .unwrap_or_else(|| panic!("relocating inactive request {id}"));
        let src = r.slot.expect("active request without slot");
        r.slot = Some(dst);
        self.slots[src] = None;
        self.slots[dst] = Some(id);
        src
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn batcher(max_batch: usize, blocks: usize) -> Batcher {
        Batcher::new(max_batch, 64, KvAllocator::new(blocks, 8))
    }

    fn req(id: u64, prompt_len: usize, gen: usize) -> Request {
        Request::new(id, (0..prompt_len as i32).collect(), gen)
    }

    /// Finish the active request with the given id.
    fn finish(b: &mut Batcher, id: u64) {
        let r = b.active.iter_mut().find(|r| r.id == id).unwrap();
        while r.generated.len() < r.max_new_tokens {
            r.generated.push(0);
        }
    }

    #[test]
    fn admits_up_to_batch_capacity() {
        let mut b = batcher(2, 100);
        for i in 0..4 {
            b.submit(req(i, 4, 4)).unwrap();
        }
        b.step_admission();
        assert_eq!(b.active.len(), 2);
        assert_eq!(b.pending(), 2);
        assert_eq!(b.active[0].slot, Some(0));
        assert_eq!(b.active[1].slot, Some(1));
    }

    #[test]
    fn kv_pressure_blocks_admission() {
        // 2 blocks of 8 tokens = 16 tokens capacity; each request needs
        // 8+8 = 16 → only one fits.
        let mut b = batcher(4, 2);
        b.submit(req(1, 8, 8)).unwrap();
        b.submit(req(2, 8, 8)).unwrap();
        b.step_admission();
        assert_eq!(b.active.len(), 1);
        assert_eq!(b.pending(), 1);
    }

    #[test]
    fn retirement_frees_kv_and_admits_next() {
        let mut b = batcher(4, 2);
        b.submit(req(1, 8, 1)).unwrap();
        b.submit(req(2, 8, 8)).unwrap();
        b.step_admission();
        assert_eq!(b.active.len(), 1);
        // finish request 1
        b.active[0].generated.push(42);
        let retired = b.step_admission();
        assert_eq!(retired, vec![1]);
        assert_eq!(b.active.len(), 1);
        assert_eq!(b.active[0].id, 2);
        // freed slot 0 is the lowest free slot → reused immediately.
        assert_eq!(b.active[0].slot, Some(0));
        assert_eq!(b.kv.held_by(1), 0);
    }

    #[test]
    fn survivors_keep_slots_across_retirement() {
        let mut b = batcher(4, 100);
        for i in 0..3 {
            b.submit(req(i, 2, 4)).unwrap();
        }
        b.step_admission();
        // retire the middle slot; neighbours must not move.
        finish(&mut b, 1);
        let retired = b.step_admission();
        assert_eq!(retired, vec![1]);
        let slot_of = |b: &Batcher, id: u64| b.active.iter().find(|r| r.id == id).unwrap().slot;
        assert_eq!(slot_of(&b, 0), Some(0));
        assert_eq!(slot_of(&b, 2), Some(2), "survivor must not be compacted");
        assert_eq!(b.slot_bound(), 3, "highest occupied slot bounds the graph");
        // the hole is filled by the next admission, lowest-first.
        b.submit(req(9, 2, 4)).unwrap();
        b.step_admission();
        assert_eq!(slot_of(&b, 9), Some(1));
        assert_eq!(slot_of(&b, 0), Some(0));
        assert_eq!(slot_of(&b, 2), Some(2));
    }

    #[test]
    fn graph_batch_covers_fragmented_slots() {
        let mut b = batcher(8, 1000);
        for i in 0..3 {
            b.submit(req(i, 2, 4)).unwrap();
        }
        b.step_admission();
        assert_eq!(b.graph_batch(), 4, "3 occupied slots → batch-4 graph");
        // retire slots 0 and 1: one survivor at slot 2 still needs the
        // batch-4 graph (the accepted cost of never moving rows).
        finish(&mut b, 0);
        finish(&mut b, 1);
        b.step_admission();
        assert_eq!(b.active.len(), 1);
        assert_eq!(b.slot_bound(), 3);
        assert_eq!(b.graph_batch(), 4);
    }

    #[test]
    fn graph_batch_is_power_of_two() {
        let mut b = batcher(8, 1000);
        for i in 0..5 {
            b.submit(req(i, 2, 2)).unwrap();
        }
        b.step_admission();
        assert_eq!(b.active.len(), 5);
        assert_eq!(b.graph_batch(), 8);
    }

    #[test]
    fn graph_batch_zero_when_idle() {
        let b = batcher(4, 100);
        assert_eq!(b.graph_batch(), 0, "no active slots → no graph to run");
        let mut b = batcher(4, 100);
        b.submit(req(1, 2, 1)).unwrap();
        b.step_admission();
        assert_eq!(b.graph_batch(), 1);
        finish(&mut b, 1);
        b.step_admission();
        assert_eq!(b.graph_batch(), 0, "all retired → back to 0");
    }

    #[test]
    fn prefill_then_decode_inputs() {
        let mut r = req(1, 3, 2);
        assert!(r.in_prefill());
        assert_eq!(r.next_input(), 0);
        r.prompt_pos = 2;
        assert_eq!(r.next_input(), 2);
        r.prompt_pos = 3;
        r.generated.push(99);
        assert!(!r.in_prefill());
        assert_eq!(r.next_input(), 99);
    }

    #[test]
    fn request_larger_than_kv_pool_rejected_not_dropped() {
        // 2 blocks × 8 tokens = 16-token pool; a 17-token worst case
        // passes max_seq but could never be admitted — accepting it
        // would stall the queue forever and silently drop the request.
        let mut b = batcher(4, 2);
        let err = b.submit(req(1, 9, 8)).unwrap_err();
        assert!(
            matches!(err, EngineError::KvPoolExceeded { id: 1, worst: 17, need_blocks: 3, pool_blocks: 2 }),
            "got: {err}"
        );
        assert!(!b.has_work());
        // exactly pool-sized is fine.
        b.submit(req(2, 8, 8)).unwrap();
        assert_eq!(b.pending(), 1);
    }

    #[test]
    fn duplicate_request_id_rejected() {
        let mut b = batcher(4, 100);
        b.submit(req(7, 2, 2)).unwrap();
        let is_dup = |e: EngineError| matches!(e, EngineError::DuplicateId { id: 7 });
        // duplicate while waiting.
        assert!(is_dup(b.submit(req(7, 2, 2)).unwrap_err()));
        b.step_admission();
        // duplicate while active: would alias request 7's slot and KV
        // residency (keyed by id) — must be rejected, not admitted.
        assert!(is_dup(b.submit(req(7, 2, 2)).unwrap_err()));
        finish(&mut b, 7);
        b.step_admission();
        // duplicate after retirement: outputs are keyed by id too.
        assert!(is_dup(b.submit(req(7, 2, 2)).unwrap_err()));
        // a fresh id is unaffected.
        b.submit(req(8, 2, 2)).unwrap();
    }

    #[test]
    fn oversized_request_rejected_not_panicked() {
        let mut b = batcher(1, 100);
        let err = b.submit(req(1, 60, 10)).unwrap_err();
        assert!(
            matches!(err, EngineError::RequestTooLong { id: 1, worst: 70, max_seq: 64 }),
            "got: {err}"
        );
        assert_eq!(b.pending(), 0, "rejected request must not be queued");
        assert!(!b.has_work());
        // a legal request right after is unaffected.
        b.submit(req(2, 30, 30)).unwrap();
        assert_eq!(b.pending(), 1);
    }

    #[test]
    fn zero_budget_request_rejected_without_burning_its_id() {
        let mut b = batcher(2, 100);
        assert!(matches!(b.submit(req(1, 2, 0)).unwrap_err(), EngineError::ZeroBudget { id: 1 }));
        assert!(!b.has_work());
        // the rejection happens before the id is recorded, so the
        // client can resubmit with a real budget.
        b.submit(req(1, 2, 1)).unwrap();
        assert_eq!(b.pending(), 1);
    }

    #[test]
    fn cancel_waiting_request_never_admits() {
        let mut b = batcher(1, 100);
        b.submit(req(1, 2, 4)).unwrap();
        b.submit(req(2, 2, 4)).unwrap();
        b.step_admission(); // 1 active, 2 waiting
        b.cancel(2).unwrap();
        assert_eq!(b.pending(), 0);
        let cancelled = b.finished.iter().find(|r| r.id == 2).unwrap();
        assert_eq!(cancelled.finish, Some(FinishReason::Cancelled));
        assert!(cancelled.generated.is_empty());
        // the slot table never saw request 2.
        assert_eq!(b.active.len(), 1);
        assert_eq!(b.active[0].id, 1);
    }

    #[test]
    fn cancel_active_frees_slot_and_kv_immediately() {
        // 4 blocks of 8 = 32 tokens; each request reserves 16 worst-case
        // → two admit, the third waits on KV pressure.
        let mut b = batcher(4, 4);
        for i in 1..=3 {
            b.submit(req(i, 8, 8)).unwrap();
        }
        b.step_admission();
        assert_eq!(b.active.len(), 2);
        assert_eq!(b.pending(), 1);
        let free_before = b.kv.free_blocks();
        b.cancel(1).unwrap();
        // blocks back *now*, not at the next scheduling step...
        assert_eq!(b.kv.free_blocks(), free_before + 2);
        assert_eq!(b.kv.held_by(1), 0);
        // ...and the freed slot 0 is the next admission target.
        b.step_admission();
        let r3 = b.active.iter().find(|r| r.id == 3).unwrap();
        assert_eq!(r3.slot, Some(0));
        // the survivor never moved.
        assert_eq!(b.active.iter().find(|r| r.id == 2).unwrap().slot, Some(1));
    }

    #[test]
    fn cancel_rejects_unknown_and_terminal_ids() {
        let mut b = batcher(2, 100);
        assert!(matches!(b.cancel(5).unwrap_err(), EngineError::UnknownRequest { id: 5 }));
        b.submit(req(5, 2, 1)).unwrap();
        b.step_admission();
        // naturally finished but not yet retired: terminal already.
        finish(&mut b, 5);
        assert!(matches!(b.cancel(5).unwrap_err(), EngineError::AlreadyFinished { id: 5 }));
        b.step_admission(); // retires 5
        assert!(matches!(b.cancel(5).unwrap_err(), EngineError::AlreadyFinished { id: 5 }));
        // double-cancel is AlreadyFinished too.
        b.submit(req(6, 2, 9)).unwrap();
        b.step_admission();
        b.cancel(6).unwrap();
        assert!(matches!(b.cancel(6).unwrap_err(), EngineError::AlreadyFinished { id: 6 }));
    }

    #[test]
    fn compaction_candidate_fires_only_on_power_of_two_drop() {
        let mut b = batcher(8, 1000);
        for i in 0..5 {
            b.submit(req(i, 2, 4)).unwrap();
        }
        b.step_admission();
        assert_eq!(b.graph_batch(), 8);
        // no hole below the top occupant → nothing to move.
        assert_eq!(b.compaction_candidate(), None);
        // retire slot 2: bound stays 5, moving slot 4 → 2 gives bound 4,
        // and next_pow2 goes 8 → 4: worth one move.
        finish(&mut b, 2);
        b.step_admission();
        assert_eq!(b.compaction_candidate(), Some((4, 4, 2)));
        // retire slot 0 too: candidate moves the highest occupant into
        // the *lowest* hole.
        finish(&mut b, 0);
        b.step_admission();
        assert_eq!(b.compaction_candidate(), Some((4, 4, 0)));
        // a hole that doesn't change the power of two is left alone:
        // occupants at 1, 3 (bound 4, gb 4); moving 3 → 0 gives bound 2,
        // gb 2 < 4 → fires. But occupants at 0, 1, 3 (bound 4): moving
        // 3 → 2 keeps bound 3, gb 4 → must not fire.
        let mut b = batcher(8, 1000);
        for i in 0..4 {
            b.submit(req(i, 2, 4)).unwrap();
        }
        b.step_admission();
        finish(&mut b, 2);
        b.step_admission();
        assert_eq!(b.compaction_candidate(), None, "gb would stay 4 — copy buys nothing");
    }

    #[test]
    fn relocate_applies_the_probe_result() {
        let mut b = batcher(8, 1000);
        for i in 0..5 {
            b.submit(req(i, 2, 4)).unwrap();
        }
        b.step_admission();
        finish(&mut b, 1);
        b.step_admission();
        let (id, src, dst) = b.compaction_candidate().unwrap();
        assert_eq!((id, src, dst), (4, 4, 1));
        assert_eq!(b.relocate(id, dst), src);
        assert_eq!(b.active.iter().find(|r| r.id == 4).unwrap().slot, Some(1));
        assert_eq!(b.slot_bound(), 4);
        assert_eq!(b.graph_batch(), 4, "one move halved the specialized graph");
        // idempotence of the policy: no further candidate.
        assert_eq!(b.compaction_candidate(), None);
    }

    #[test]
    fn take_finished_prunes_ids_for_reuse() {
        // regression: known_ids was never pruned — a slow leak under
        // continuous traffic, and drained ids stayed burned forever.
        let mut b = batcher(2, 100);
        b.submit(req(1, 2, 1)).unwrap();
        b.step_admission();
        finish(&mut b, 1);
        b.step_admission();
        // finished but undrained: the id still keys the output.
        assert!(matches!(b.submit(req(1, 2, 1)).unwrap_err(), EngineError::DuplicateId { id: 1 }));
        let drained = b.take_finished();
        assert_eq!(drained.len(), 1);
        assert!(b.finished.is_empty());
        // drained: the id keys nothing here any more — reusable.
        b.submit(req(1, 3, 2)).unwrap();
        assert_eq!(b.pending(), 1);
        // the reused id is a fresh request with fresh bookkeeping.
        b.step_admission();
        assert_eq!(b.active[0].slot, Some(0));
        assert_eq!(b.active[0].generated.len(), 0);
        // cancelled ids free up through the same drain.
        b.submit(req(2, 2, 4)).unwrap();
        b.cancel(2).unwrap();
        assert!(matches!(b.submit(req(2, 2, 4)).unwrap_err(), EngineError::DuplicateId { id: 2 }));
        b.take_finished();
        b.submit(req(2, 2, 4)).unwrap();
    }

    #[test]
    fn terminate_stamps_the_given_reason() {
        let mut b = batcher(2, 100);
        b.submit(req(1, 8, 8)).unwrap();
        b.submit(req(2, 8, 8)).unwrap();
        b.submit(req(3, 8, 8)).unwrap(); // waits: 2 slots
        b.step_admission();
        let free_before = b.kv.free_blocks();
        // active → slot + KV released now, reason preserved.
        b.terminate(1, FinishReason::Failed).unwrap();
        assert!(b.kv.free_blocks() > free_before);
        assert_eq!(b.finished.iter().find(|r| r.id == 1).unwrap().finish, Some(FinishReason::Failed));
        // waiting → leaves the queue with the given reason.
        b.terminate(3, FinishReason::DeadlineExceeded).unwrap();
        assert_eq!(b.pending(), 0);
        assert_eq!(
            b.finished.iter().find(|r| r.id == 3).unwrap().finish,
            Some(FinishReason::DeadlineExceeded)
        );
        // typed refusals match cancel's.
        assert!(matches!(
            b.terminate(1, FinishReason::Failed).unwrap_err(),
            EngineError::AlreadyFinished { id: 1 }
        ));
        assert!(matches!(
            b.terminate(9, FinishReason::Shed).unwrap_err(),
            EngineError::UnknownRequest { id: 9 }
        ));
    }

    #[test]
    fn validate_is_nonmutating_and_matches_submit() {
        let mut b = batcher(2, 2);
        let ok = req(1, 2, 2);
        b.validate(&ok).unwrap();
        assert!(!b.has_work(), "validate must not queue");
        b.submit(ok).unwrap();
        // every rejection class agrees with submit, in the same order.
        assert!(matches!(b.validate(&req(2, 2, 0)).unwrap_err(), EngineError::ZeroBudget { id: 2 }));
        assert!(matches!(b.validate(&req(2, 60, 10)).unwrap_err(), EngineError::RequestTooLong { .. }));
        assert!(matches!(b.validate(&req(2, 9, 8)).unwrap_err(), EngineError::KvPoolExceeded { .. }));
        assert!(matches!(b.validate(&req(1, 2, 2)).unwrap_err(), EngineError::DuplicateId { id: 1 }));
    }

    /// Paged batcher over a small arena: `slots` 64-token slots of
    /// 8-token blocks → `slots * 8` pool blocks. `max_seq` is set above
    /// the pool's token capacity so the KvPoolExceeded check (not
    /// RequestTooLong) is the binding constraint under test.
    fn paged_batcher(max_batch: usize, slots: usize) -> Batcher {
        let arena = crate::serving::kvcache::KvArena::new(2, slots, 64, 4);
        Batcher::new_paged(max_batch, 128, PagedKvPool::over(&arena, 8))
    }

    #[test]
    fn paged_admission_reserves_prompt_blocks_only() {
        let mut b = paged_batcher(4, 1); // 8 blocks
        b.submit(req(1, 16, 32)).unwrap(); // worst case 48 tokens = 6 blocks
        b.step_admission();
        assert_eq!(b.active.len(), 1);
        assert_eq!(b.kv.held_by(1), 2, "16-token prompt = 2 blocks, not the worst case");
        assert_eq!(b.kv.free_blocks(), 6);
        // a cold prompt starts prefill from the beginning.
        assert_eq!(b.active[0].prompt_pos, 0);
        assert_eq!(b.active[0].cache_len, 0);
    }

    #[test]
    fn paged_validate_rejects_at_exact_block_boundary() {
        // 8 blocks × 8 tokens = 64-token pool capacity; max_seq is 128
        // so the pool check is what binds. The off-by-one at the exact
        // boundary is the regression under test: worst == 64 must be
        // accepted (blocks_for(64) == 8 == pool), worst == 65 must be a
        // typed KvPoolExceeded (blocks_for rounds 65 up to 9).
        let mut b = paged_batcher(2, 1);
        b.submit(req(1, 32, 32)).unwrap(); // exactly pool-sized
        let err = b.submit(req(2, 33, 32)).unwrap_err();
        assert!(
            matches!(
                err,
                EngineError::KvPoolExceeded { id: 2, worst: 65, need_blocks: 9, pool_blocks: 8 }
            ),
            "got: {err}"
        );
        // one token under the boundary in the other direction too.
        b.validate(&req(3, 31, 33)).unwrap(); // worst 64 again
        assert!(matches!(
            b.validate(&req(3, 31, 34)).unwrap_err(),
            EngineError::KvPoolExceeded { worst: 65, .. }
        ));
    }

    #[test]
    fn paged_admission_waits_under_pool_pressure_without_leaking() {
        let mut b = paged_batcher(4, 1); // 8 blocks
        b.submit(req(1, 48, 8)).unwrap(); // 6 blocks of prompt
        b.submit(req(2, 32, 8)).unwrap(); // 4 more: cannot fit
        b.step_admission();
        assert_eq!(b.active.len(), 1);
        assert_eq!(b.pending(), 1, "second request waits, is not dropped");
        assert_eq!(b.kv.held_by(2), 0, "failed paged admission must not leak blocks");
        b.cancel(1).unwrap();
        b.step_admission();
        assert_eq!(b.active.len(), 1);
        assert_eq!(b.active[0].id, 2);
    }

    #[test]
    fn paged_admission_resumes_past_a_shared_prefix() {
        let mut b = paged_batcher(2, 2);
        let prompt: Vec<i32> = (0..16).collect();
        b.submit(Request::new(1, prompt.clone(), 4)).unwrap();
        b.step_admission();
        // simulate request 1's prefill publishing both prompt blocks.
        let p = b.kv.paged_mut().unwrap();
        for pos in 0..16 {
            assert_ne!(p.ensure_append(1, pos), crate::serving::paged::Append::Exhausted);
            p.promote(1, &prompt, pos + 1);
        }
        b.cancel(1).unwrap();
        b.take_finished();
        let alloc_before = b.kv.paged().unwrap().blocks_allocated();
        b.submit(Request::new(2, prompt.clone(), 4)).unwrap();
        b.step_admission();
        let r = &b.active[0];
        assert_eq!(r.id, 2);
        assert_eq!(r.prompt_pos, 15, "resume clamps to the last prompt token");
        assert_eq!(r.cache_len, 15);
        assert!(r.in_prefill(), "the resumed request still runs ≥ 1 prefill step");
        assert_eq!(
            b.kv.paged().unwrap().blocks_allocated(),
            alloc_before,
            "a fully shared prompt allocates nothing at admission"
        );
        assert!(b.kv.paged().unwrap().shared_blocks() >= 2);
    }
}
