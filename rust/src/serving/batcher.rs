//! Requests and continuous batching (§6.1, Orca-style).
//!
//! Each decode iteration the engine (1) retires finished requests,
//! (2) admits waiting requests while KV blocks and batch slots allow,
//! and (3) picks the specialized tGraph for the next power-of-two batch
//! size. In the paper this bookkeeping runs *inside* the mega-kernel as
//! the start event's task; here it is the host-side `IterPrep`
//! counterpart driving the same state.

use crate::serving::kvcache::KvAllocator;
use std::collections::VecDeque;

/// A generation request.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<i32>,
    pub max_new_tokens: usize,
    /// Tokens generated so far.
    pub generated: Vec<i32>,
    /// Prompt tokens already consumed (prefill progress).
    pub prompt_pos: usize,
    /// Cache length (tokens already appended).
    pub cache_len: usize,
    /// Batch slot while active.
    pub slot: Option<usize>,
}

impl Request {
    pub fn new(id: u64, prompt: Vec<i32>, max_new_tokens: usize) -> Self {
        assert!(!prompt.is_empty(), "empty prompt");
        Request { id, prompt, max_new_tokens, generated: Vec::new(), prompt_pos: 0, cache_len: 0, slot: None }
    }

    /// Next token to feed the model: prompt token during prefill, last
    /// generated token during decode.
    pub fn next_input(&self) -> i32 {
        if self.prompt_pos < self.prompt.len() {
            self.prompt[self.prompt_pos]
        } else {
            *self.generated.last().unwrap_or(&0)
        }
    }

    /// True while still consuming the prompt.
    pub fn in_prefill(&self) -> bool {
        self.prompt_pos < self.prompt.len()
    }

    pub fn finished(&self) -> bool {
        self.generated.len() >= self.max_new_tokens
    }

    /// Total tokens this request will hold in cache after this step.
    pub fn tokens_after_step(&self) -> usize {
        self.cache_len + 1
    }
}

/// Continuous batcher over a bounded slot array.
pub struct Batcher {
    pub max_batch: usize,
    pub max_seq: usize,
    waiting: VecDeque<Request>,
    pub active: Vec<Request>,
    pub finished: Vec<Request>,
    pub kv: KvAllocator,
}

impl Batcher {
    pub fn new(max_batch: usize, max_seq: usize, kv: KvAllocator) -> Self {
        Batcher { max_batch, max_seq, waiting: VecDeque::new(), active: Vec::new(), finished: Vec::new(), kv }
    }

    pub fn submit(&mut self, r: Request) {
        assert!(
            r.prompt.len() + r.max_new_tokens <= self.max_seq,
            "request {} exceeds max_seq {}",
            r.id,
            self.max_seq
        );
        self.waiting.push_back(r);
    }

    pub fn pending(&self) -> usize {
        self.waiting.len()
    }

    pub fn has_work(&self) -> bool {
        !self.waiting.is_empty() || !self.active.is_empty()
    }

    /// One scheduling step: retire finished, admit waiting (§6.1 order).
    /// Returns ids of requests retired this step.
    pub fn step_admission(&mut self) -> Vec<u64> {
        // 1. retire
        let mut retired = Vec::new();
        let mut i = 0;
        while i < self.active.len() {
            if self.active[i].finished() {
                let mut r = self.active.swap_remove(i);
                self.kv.release(r.id);
                r.slot = None;
                retired.push(r.id);
                self.finished.push(r);
            } else {
                i += 1;
            }
        }
        // 2. admit while slots + KV blocks allow (worst-case reservation).
        while self.active.len() < self.max_batch {
            let Some(front) = self.waiting.front() else { break };
            let worst = front.prompt.len() + front.max_new_tokens;
            if !self.kv.ensure(front.id, worst) {
                break; // KV pressure: wait for retirements
            }
            let mut r = self.waiting.pop_front().unwrap();
            r.slot = None; // assigned by compaction below
            self.active.push(r);
        }
        // 3. compact slots: active requests occupy slots 0..n in order.
        for (slot, r) in self.active.iter_mut().enumerate() {
            r.slot = Some(slot);
        }
        retired
    }

    /// Specialized-graph batch size for the current active set: next
    /// power of two (§6.1 "powers of two up to the maximum batch size").
    pub fn graph_batch(&self) -> usize {
        self.active.len().next_power_of_two().min(self.max_batch.next_power_of_two())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn batcher(max_batch: usize, blocks: usize) -> Batcher {
        Batcher::new(max_batch, 64, KvAllocator::new(blocks, 8))
    }

    fn req(id: u64, prompt_len: usize, gen: usize) -> Request {
        Request::new(id, (0..prompt_len as i32).collect(), gen)
    }

    #[test]
    fn admits_up_to_batch_capacity() {
        let mut b = batcher(2, 100);
        for i in 0..4 {
            b.submit(req(i, 4, 4));
        }
        b.step_admission();
        assert_eq!(b.active.len(), 2);
        assert_eq!(b.pending(), 2);
        assert_eq!(b.active[0].slot, Some(0));
        assert_eq!(b.active[1].slot, Some(1));
    }

    #[test]
    fn kv_pressure_blocks_admission() {
        // 2 blocks of 8 tokens = 16 tokens capacity; each request needs
        // 8+8 = 16 → only one fits.
        let mut b = batcher(4, 2);
        b.submit(req(1, 8, 8));
        b.submit(req(2, 8, 8));
        b.step_admission();
        assert_eq!(b.active.len(), 1);
        assert_eq!(b.pending(), 1);
    }

    #[test]
    fn retirement_frees_kv_and_admits_next() {
        let mut b = batcher(4, 2);
        b.submit(req(1, 8, 1));
        b.submit(req(2, 8, 8));
        b.step_admission();
        assert_eq!(b.active.len(), 1);
        // finish request 1
        b.active[0].generated.push(42);
        let retired = b.step_admission();
        assert_eq!(retired, vec![1]);
        assert_eq!(b.active.len(), 1);
        assert_eq!(b.active[0].id, 2);
        assert_eq!(b.kv.held_by(1), 0);
    }

    #[test]
    fn graph_batch_is_power_of_two() {
        let mut b = batcher(8, 1000);
        for i in 0..5 {
            b.submit(req(i, 2, 2));
        }
        b.step_admission();
        assert_eq!(b.active.len(), 5);
        assert_eq!(b.graph_batch(), 8);
    }

    #[test]
    fn prefill_then_decode_inputs() {
        let mut r = req(1, 3, 2);
        assert!(r.in_prefill());
        assert_eq!(r.next_input(), 0);
        r.prompt_pos = 2;
        assert_eq!(r.next_input(), 2);
        r.prompt_pos = 3;
        r.generated.push(99);
        assert!(!r.in_prefill());
        assert_eq!(r.next_input(), 99);
    }

    #[test]
    #[should_panic(expected = "exceeds max_seq")]
    fn oversized_request_rejected() {
        let mut b = batcher(1, 100);
        b.submit(req(1, 60, 10));
    }
}
