//! Requests and continuous batching (§6.1, Orca-style) over **stable
//! slots**.
//!
//! Each decode iteration the engine (1) retires finished requests,
//! (2) admits waiting requests while KV blocks and batch slots allow,
//! and (3) picks the specialized tGraph for the next power-of-two batch
//! size. In the paper this bookkeeping runs *inside* the mega-kernel as
//! the start event's task; here it is the host-side `IterPrep`
//! counterpart driving the same state.
//!
//! # Slot policy: lowest-free-slot, no compaction
//!
//! An active request keeps the slot it was admitted into until it
//! retires — retirements free the slot but never move a survivor.
//! Because every batch-size specialization aliases one shared max-batch
//! KV arena keyed by slot, stable slots make `kv_rows_migrated`
//! *structurally* zero: there is no code path that relocates a live
//! request's cache rows. The cost is fragmentation: after retirements
//! the highest occupied slot (not the active count) bounds which
//! specialized graph must run, so the engine occasionally executes the
//! next-larger graph than the active count strictly needs. New
//! admissions take the **lowest** free slot, so fragmentation heals
//! through churn instead of through copies.

use crate::serving::kvcache::KvAllocator;
use std::collections::{HashSet, VecDeque};

/// A generation request.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<i32>,
    pub max_new_tokens: usize,
    /// Tokens generated so far.
    pub generated: Vec<i32>,
    /// Prompt tokens already consumed (prefill progress).
    pub prompt_pos: usize,
    /// Cache length (tokens already appended).
    pub cache_len: usize,
    /// Batch slot while active. Stable: assigned at admission, held
    /// until retirement.
    pub slot: Option<usize>,
}

impl Request {
    pub fn new(id: u64, prompt: Vec<i32>, max_new_tokens: usize) -> Self {
        assert!(!prompt.is_empty(), "empty prompt");
        Request { id, prompt, max_new_tokens, generated: Vec::new(), prompt_pos: 0, cache_len: 0, slot: None }
    }

    /// Next token to feed the model: prompt token during prefill, last
    /// generated token during decode.
    pub fn next_input(&self) -> i32 {
        if self.prompt_pos < self.prompt.len() {
            self.prompt[self.prompt_pos]
        } else {
            *self.generated.last().unwrap_or(&0)
        }
    }

    /// True while still consuming the prompt.
    pub fn in_prefill(&self) -> bool {
        self.prompt_pos < self.prompt.len()
    }

    pub fn finished(&self) -> bool {
        self.generated.len() >= self.max_new_tokens
    }

    /// Total tokens this request will hold in cache after this step.
    pub fn tokens_after_step(&self) -> usize {
        self.cache_len + 1
    }
}

/// Continuous batcher over a bounded slot array with stable slots.
pub struct Batcher {
    pub max_batch: usize,
    pub max_seq: usize,
    waiting: VecDeque<Request>,
    /// Active requests, unordered (retirement uses `swap_remove`) —
    /// each request carries its own stable `slot`; never index this by
    /// slot.
    pub active: Vec<Request>,
    pub finished: Vec<Request>,
    pub kv: KvAllocator,
    /// slot → occupying request id. The allocator state: admission
    /// claims the lowest `None`, retirement clears its entry, nothing
    /// else ever writes it.
    slots: Vec<Option<u64>>,
    /// Every id this batcher has ever accepted (waiting, active, or
    /// finished). Ids key KV residency, slots, and the output map, so a
    /// duplicate is rejected at submit — O(1), never pruned (finished
    /// requests keep their ids reserved).
    known_ids: HashSet<u64>,
}

impl Batcher {
    pub fn new(max_batch: usize, max_seq: usize, kv: KvAllocator) -> Self {
        Batcher {
            max_batch,
            max_seq,
            waiting: VecDeque::new(),
            active: Vec::new(),
            finished: Vec::new(),
            kv,
            slots: vec![None; max_batch],
            known_ids: HashSet::new(),
        }
    }

    /// Queue a request, or reject it if it can never be served safely:
    /// client-supplied input must not abort the engine *or* vanish, so
    /// an oversized request (beyond `max_seq`, or whose worst-case KV
    /// demand exceeds the whole block pool — it would wait forever and
    /// stall everything queued behind it) — or a duplicate id, which
    /// would alias another request's KV residency and slot — is an
    /// `Err`, not a panic or a silent drop.
    pub fn submit(&mut self, r: Request) -> Result<(), String> {
        let worst = r.prompt.len() + r.max_new_tokens;
        if worst > self.max_seq {
            return Err(format!(
                "request {} rejected: worst-case {} tokens exceeds max_seq {}",
                r.id, worst, self.max_seq
            ));
        }
        let need = self.kv.blocks_for(worst);
        if need > self.kv.total_blocks() {
            return Err(format!(
                "request {} rejected: worst-case {worst} tokens needs {need} KV blocks, pool has {}",
                r.id,
                self.kv.total_blocks()
            ));
        }
        if !self.known_ids.insert(r.id) {
            return Err(format!("request id {} rejected: already known to this batcher", r.id));
        }
        self.waiting.push_back(r);
        Ok(())
    }

    pub fn pending(&self) -> usize {
        self.waiting.len()
    }

    pub fn has_work(&self) -> bool {
        !self.waiting.is_empty() || !self.active.is_empty()
    }

    /// Lowest unoccupied slot, if any.
    fn lowest_free_slot(&self) -> Option<usize> {
        self.slots.iter().position(Option::is_none)
    }

    /// One past the highest occupied slot (0 when idle). Because slots
    /// are never compacted this — not `active.len()` — is what the
    /// specialized graph must cover.
    pub fn slot_bound(&self) -> usize {
        self.slots.iter().rposition(Option::is_some).map_or(0, |i| i + 1)
    }

    /// One scheduling step: retire finished, admit waiting (§6.1 order).
    /// Returns ids of requests retired this step. Survivors keep their
    /// slots; freed slots are immediately reusable (lowest first).
    pub fn step_admission(&mut self) -> Vec<u64> {
        // 1. retire: free the slot, never touch survivors.
        let mut retired = Vec::new();
        let mut i = 0;
        while i < self.active.len() {
            if self.active[i].finished() {
                let mut r = self.active.swap_remove(i);
                self.kv.release(r.id);
                let slot = r.slot.take().expect("active request without slot");
                debug_assert_eq!(self.slots[slot], Some(r.id), "slot table out of sync");
                self.slots[slot] = None;
                retired.push(r.id);
                self.finished.push(r);
            } else {
                i += 1;
            }
        }
        // 2. admit into the lowest free slot while slots + KV blocks
        // allow (worst-case reservation).
        while let Some(slot) = self.lowest_free_slot() {
            let Some(front) = self.waiting.front() else { break };
            let worst = front.prompt.len() + front.max_new_tokens;
            if !self.kv.ensure(front.id, worst) {
                break; // KV pressure: wait for retirements
            }
            let mut r = self.waiting.pop_front().unwrap();
            r.slot = Some(slot);
            self.slots[slot] = Some(r.id);
            self.active.push(r);
        }
        retired
    }

    /// Specialized-graph batch size for the current active set: the next
    /// power of two covering the highest occupied **slot** (§6.1 "powers
    /// of two up to the maximum batch size"), since slots are stable and
    /// may be fragmented after retirements. Returns **0** for an empty
    /// active set — `0.next_power_of_two()` is 1, and running a batch-1
    /// graph with no work is not a real iteration; the decode loop skips
    /// it.
    pub fn graph_batch(&self) -> usize {
        match self.slot_bound() {
            0 => 0,
            // slot_bound ≤ max_batch by construction (the slot table
            // has exactly max_batch entries), so no clamp is needed.
            b => b.next_power_of_two(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn batcher(max_batch: usize, blocks: usize) -> Batcher {
        Batcher::new(max_batch, 64, KvAllocator::new(blocks, 8))
    }

    fn req(id: u64, prompt_len: usize, gen: usize) -> Request {
        Request::new(id, (0..prompt_len as i32).collect(), gen)
    }

    /// Finish the active request with the given id.
    fn finish(b: &mut Batcher, id: u64) {
        let r = b.active.iter_mut().find(|r| r.id == id).unwrap();
        while r.generated.len() < r.max_new_tokens {
            r.generated.push(0);
        }
    }

    #[test]
    fn admits_up_to_batch_capacity() {
        let mut b = batcher(2, 100);
        for i in 0..4 {
            b.submit(req(i, 4, 4)).unwrap();
        }
        b.step_admission();
        assert_eq!(b.active.len(), 2);
        assert_eq!(b.pending(), 2);
        assert_eq!(b.active[0].slot, Some(0));
        assert_eq!(b.active[1].slot, Some(1));
    }

    #[test]
    fn kv_pressure_blocks_admission() {
        // 2 blocks of 8 tokens = 16 tokens capacity; each request needs
        // 8+8 = 16 → only one fits.
        let mut b = batcher(4, 2);
        b.submit(req(1, 8, 8)).unwrap();
        b.submit(req(2, 8, 8)).unwrap();
        b.step_admission();
        assert_eq!(b.active.len(), 1);
        assert_eq!(b.pending(), 1);
    }

    #[test]
    fn retirement_frees_kv_and_admits_next() {
        let mut b = batcher(4, 2);
        b.submit(req(1, 8, 1)).unwrap();
        b.submit(req(2, 8, 8)).unwrap();
        b.step_admission();
        assert_eq!(b.active.len(), 1);
        // finish request 1
        b.active[0].generated.push(42);
        let retired = b.step_admission();
        assert_eq!(retired, vec![1]);
        assert_eq!(b.active.len(), 1);
        assert_eq!(b.active[0].id, 2);
        // freed slot 0 is the lowest free slot → reused immediately.
        assert_eq!(b.active[0].slot, Some(0));
        assert_eq!(b.kv.held_by(1), 0);
    }

    #[test]
    fn survivors_keep_slots_across_retirement() {
        let mut b = batcher(4, 100);
        for i in 0..3 {
            b.submit(req(i, 2, 4)).unwrap();
        }
        b.step_admission();
        // retire the middle slot; neighbours must not move.
        finish(&mut b, 1);
        let retired = b.step_admission();
        assert_eq!(retired, vec![1]);
        let slot_of = |b: &Batcher, id: u64| b.active.iter().find(|r| r.id == id).unwrap().slot;
        assert_eq!(slot_of(&b, 0), Some(0));
        assert_eq!(slot_of(&b, 2), Some(2), "survivor must not be compacted");
        assert_eq!(b.slot_bound(), 3, "highest occupied slot bounds the graph");
        // the hole is filled by the next admission, lowest-first.
        b.submit(req(9, 2, 4)).unwrap();
        b.step_admission();
        assert_eq!(slot_of(&b, 9), Some(1));
        assert_eq!(slot_of(&b, 0), Some(0));
        assert_eq!(slot_of(&b, 2), Some(2));
    }

    #[test]
    fn graph_batch_covers_fragmented_slots() {
        let mut b = batcher(8, 1000);
        for i in 0..3 {
            b.submit(req(i, 2, 4)).unwrap();
        }
        b.step_admission();
        assert_eq!(b.graph_batch(), 4, "3 occupied slots → batch-4 graph");
        // retire slots 0 and 1: one survivor at slot 2 still needs the
        // batch-4 graph (the accepted cost of never moving rows).
        finish(&mut b, 0);
        finish(&mut b, 1);
        b.step_admission();
        assert_eq!(b.active.len(), 1);
        assert_eq!(b.slot_bound(), 3);
        assert_eq!(b.graph_batch(), 4);
    }

    #[test]
    fn graph_batch_is_power_of_two() {
        let mut b = batcher(8, 1000);
        for i in 0..5 {
            b.submit(req(i, 2, 2)).unwrap();
        }
        b.step_admission();
        assert_eq!(b.active.len(), 5);
        assert_eq!(b.graph_batch(), 8);
    }

    #[test]
    fn graph_batch_zero_when_idle() {
        let b = batcher(4, 100);
        assert_eq!(b.graph_batch(), 0, "no active slots → no graph to run");
        let mut b = batcher(4, 100);
        b.submit(req(1, 2, 1)).unwrap();
        b.step_admission();
        assert_eq!(b.graph_batch(), 1);
        finish(&mut b, 1);
        b.step_admission();
        assert_eq!(b.graph_batch(), 0, "all retired → back to 0");
    }

    #[test]
    fn prefill_then_decode_inputs() {
        let mut r = req(1, 3, 2);
        assert!(r.in_prefill());
        assert_eq!(r.next_input(), 0);
        r.prompt_pos = 2;
        assert_eq!(r.next_input(), 2);
        r.prompt_pos = 3;
        r.generated.push(99);
        assert!(!r.in_prefill());
        assert_eq!(r.next_input(), 99);
    }

    #[test]
    fn request_larger_than_kv_pool_rejected_not_dropped() {
        // 2 blocks × 8 tokens = 16-token pool; a 17-token worst case
        // passes max_seq but could never be admitted — accepting it
        // would stall the queue forever and silently drop the request.
        let mut b = batcher(4, 2);
        let err = b.submit(req(1, 9, 8)).unwrap_err();
        assert!(err.contains("KV blocks"), "got: {err}");
        assert!(!b.has_work());
        // exactly pool-sized is fine.
        b.submit(req(2, 8, 8)).unwrap();
        assert_eq!(b.pending(), 1);
    }

    #[test]
    fn duplicate_request_id_rejected() {
        let mut b = batcher(4, 100);
        b.submit(req(7, 2, 2)).unwrap();
        // duplicate while waiting.
        assert!(b.submit(req(7, 2, 2)).unwrap_err().contains("already known"));
        b.step_admission();
        // duplicate while active: would alias request 7's slot and KV
        // residency (keyed by id) — must be rejected, not admitted.
        assert!(b.submit(req(7, 2, 2)).unwrap_err().contains("already known"));
        finish(&mut b, 7);
        b.step_admission();
        // duplicate after retirement: outputs are keyed by id too.
        assert!(b.submit(req(7, 2, 2)).unwrap_err().contains("already known"));
        // a fresh id is unaffected.
        b.submit(req(8, 2, 2)).unwrap();
    }

    #[test]
    fn oversized_request_rejected_not_panicked() {
        let mut b = batcher(1, 100);
        let err = b.submit(req(1, 60, 10)).unwrap_err();
        assert!(err.contains("exceeds max_seq"), "got: {err}");
        assert_eq!(b.pending(), 0, "rejected request must not be queued");
        assert!(!b.has_work());
        // a legal request right after is unaffected.
        b.submit(req(2, 30, 30)).unwrap();
        assert_eq!(b.pending(), 1);
    }
}
