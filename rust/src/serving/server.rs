//! The serving front-end: one thread owns the engine; clients talk to
//! it over channels.
//!
//! [`ServeEngine`] is single-threaded by design — its hot path mutates
//! arenas, slots, and the resident kernel with no interior locking.
//! [`ServeServer`] puts that engine on **one dedicated thread** that
//! loops [`StepEngine::step`]; any number of [`ServerClient`] handles
//! (cheap clones, any thread) submit, cancel, and query over an mpsc
//! command channel. Per-request tokens are fanned out from each step's
//! [`StepOutcome`] events to the submitting client's [`TokenStream`].
//! No async runtime, no locks around the engine — the thread *is* the
//! serialization point, exactly like the single CUDA stream the paper's
//! megakernel owns.
//!
//! # Overload control
//!
//! Admission is governed end to end, so saturation degrades loudly and
//! fairly instead of queueing without bound:
//!
//! * **Bounded wait queue** — accepted requests wait in a server-side
//!   queue of at most [`ServerConfig::queue_depth`]; engine admission
//!   refills slots from its front each tick.
//! * **Typed shedding** — a submission that finds the queue full either
//!   displaces a strictly lower-[`Priority`] queued request (which gets
//!   a terminal [`FinishReason::Shed`] event on its stream) or is
//!   refused synchronously with [`EngineError::Overloaded`]. Both are
//!   typed outcomes, never engine errors.
//! * **Priority classes** — [`Priority::Interactive`] enqueues ahead of
//!   [`Priority::Batch`] and displaces it under overload; within a
//!   class, FIFO.
//! * **Deadlines** — [`SubmitOptions::deadline`] is enforced *by the
//!   server* as a scheduled termination: a queued request whose
//!   deadline passes never reaches the engine; an admitted one is
//!   terminated between steps via [`StepEngine::terminate`]. Either way
//!   the stream ends with a terminal
//!   [`FinishReason::DeadlineExceeded`] event carrying whatever was
//!   generated — a deadline is an outcome, not an error.
//!
//! # Failure containment
//!
//! The engine's own recovery (retry + quarantine, see
//! [`crate::serving::fault`]) absorbs epoch failures without the server
//! noticing beyond terminal `Failed` events on the affected streams.
//! Only if a step fails *persistently and unattributably* does the
//! serving thread die — and then it fails every live stream with a
//! terminal event, records the error in [`ServerReport::fatal`], and
//! exits; clients never hang on a silently dead server.
//!
//! ```no_run
//! use mpk::serving::{Priority, Request, ServeEngine, ServeServer, ServerConfig, SubmitOptions};
//! use std::time::Duration;
//!
//! let server = ServeServer::spawn(
//!     ServeEngine::builder().max_batch(4),
//!     ServerConfig::default(),
//! ).expect("needs `make artifacts` and a PJRT backend");
//! let client = server.client();
//! let stream = client.submit_with(
//!     Request::new(1, vec![5, 9], 16),
//!     SubmitOptions { priority: Priority::Interactive, deadline: Some(Duration::from_secs(2)) },
//! ).unwrap();
//! for event in stream {
//!     println!("req {} -> {:?} {:?}", event.request, event.token, event.finish);
//! }
//! let report = server.shutdown();
//! assert!(report.fatal.is_none());
//! ```

use crate::metrics::KvPoolStats;
use crate::serving::batcher::Request;
use crate::serving::engine::{EngineBuilder, ServeEngine, ServeStats};
use crate::serving::error::EngineError;
use crate::serving::step::{FinishReason, StepOutcome, TokenEvent};
use std::collections::{HashMap, VecDeque};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender, TryRecvError};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// What the serving thread needs from an engine: the step-driven
/// surface of [`ServeEngine`], abstracted so the server loop (and its
/// tests) can run against a lightweight mock
/// ([`MockEngine`](crate::serving::mock::MockEngine)) without artifacts
/// or a backend. `Send` is a supertrait because the engine moves onto
/// the serving thread.
pub trait StepEngine: Send {
    /// Queue a request for admission at the next step.
    fn submit(&mut self, r: Request) -> Result<(), EngineError>;
    /// Would `submit` accept this request right now? Non-mutating.
    fn validate(&self, r: &Request) -> Result<(), EngineError>;
    /// Retire a request now with the given terminal reason; its
    /// terminal event rides the next step's outcome.
    fn terminate(&mut self, id: u64, reason: FinishReason) -> Result<(), EngineError>;
    /// One decode iteration.
    fn step(&mut self) -> Result<StepOutcome, EngineError>;
    /// True while the engine holds work or undelivered terminal events.
    fn has_work(&self) -> bool;
    /// Concurrent-request ceiling (batch slots).
    fn capacity(&self) -> usize;
    /// Requests currently inside the engine (active + waiting).
    fn in_flight(&self) -> usize;
    /// Drain retired requests, releasing their ids for reuse.
    fn take_finished(&mut self) -> Vec<Request>;
    /// Close and return the current stats window.
    fn take_stats(&mut self) -> ServeStats;
    /// KV-pool occupancy snapshot for the status surface. Defaults to
    /// all-zero for engines without a paged pool (mocks, adapters).
    fn kv_status(&self) -> KvPoolStats {
        KvPoolStats::default()
    }
}

impl StepEngine for ServeEngine {
    fn submit(&mut self, r: Request) -> Result<(), EngineError> {
        ServeEngine::submit(self, r)
    }
    fn validate(&self, r: &Request) -> Result<(), EngineError> {
        ServeEngine::validate(self, r)
    }
    fn terminate(&mut self, id: u64, reason: FinishReason) -> Result<(), EngineError> {
        ServeEngine::terminate(self, id, reason)
    }
    fn step(&mut self) -> Result<StepOutcome, EngineError> {
        ServeEngine::step(self)
    }
    fn has_work(&self) -> bool {
        ServeEngine::has_work(self)
    }
    fn capacity(&self) -> usize {
        ServeEngine::capacity(self)
    }
    fn in_flight(&self) -> usize {
        ServeEngine::in_flight(self)
    }
    fn take_finished(&mut self) -> Vec<Request> {
        ServeEngine::take_finished(self)
    }
    fn take_stats(&mut self) -> ServeStats {
        ServeEngine::take_stats(self)
    }
    fn kv_status(&self) -> KvPoolStats {
        ServeEngine::kv_status(self)
    }
}

/// Admission priority class. [`Priority::Interactive`] enqueues ahead
/// of [`Priority::Batch`] and displaces it when the wait queue is full;
/// within a class, admission is FIFO. The derived order makes the
/// *smaller* variant outrank the larger one.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Priority {
    /// Latency-sensitive traffic (the default).
    #[default]
    Interactive,
    /// Throughput traffic: first to wait, first to be shed.
    Batch,
}

/// Per-submission options for [`ServerClient::submit_with`].
#[derive(Clone, Copy, Debug, Default)]
pub struct SubmitOptions {
    /// Admission class under load; see [`Priority`].
    pub priority: Priority,
    /// Relative deadline, measured from acceptance. When it passes
    /// before the request finishes, the server terminates it with
    /// [`FinishReason::DeadlineExceeded`] (keeping partial output);
    /// `None` means no deadline.
    pub deadline: Option<Duration>,
}

/// Server shape knobs.
#[derive(Clone, Copy, Debug)]
pub struct ServerConfig {
    /// Bound on the server-side wait queue. A submission beyond it is
    /// shed (displacement or [`EngineError::Overloaded`]); the engine's
    /// own slot count bounds what runs concurrently.
    pub queue_depth: usize,
    /// How long the serving thread blocks for a command when fully
    /// idle. Bounds shutdown latency, not correctness — while work or
    /// commands exist the loop never sleeps.
    pub idle_poll: Duration,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig { queue_depth: 64, idle_poll: Duration::from_millis(1) }
    }
}

/// Counters the serving thread hands back at
/// [`ServeServer::shutdown`].
///
/// # Drain semantics
///
/// `shutdown()` *drains*: the server stops accepting new submissions
/// (each is refused with [`EngineError::ServerClosed`]) but keeps
/// stepping until every already-accepted request reaches its terminal
/// event, so `finished` accounts for **every** request ever accepted —
/// none are silently abandoned. The network layer builds its bounded
/// variant on top of this: `serving::transport::ServeTransport::drain`
/// first force-cancels whatever its deadline cuts off (each cancel
/// still produces a terminal event, counted in `finished`), then
/// calls `shutdown()` and embeds this report in its `DrainReport`.
/// Consequently `finished == submitted` holds after *any* drain path,
/// bounded or not — the reconciliation invariant the chaos tests
/// check.
#[derive(Clone, Debug, Default)]
pub struct ServerReport {
    /// Terminal events delivered, any reason — every accepted request
    /// ends in exactly one of these.
    pub finished: usize,
    /// Accepted-then-displaced requests (terminal
    /// [`FinishReason::Shed`]).
    pub shed: usize,
    /// Synchronous [`EngineError::Overloaded`] refusals (never
    /// accepted, so not part of [`ServerReport::finished`]).
    pub rejected: usize,
    /// Terminal [`FinishReason::DeadlineExceeded`] deliveries.
    pub deadline_expired: usize,
    /// Terminal [`FinishReason::Failed`] deliveries (fault quarantine,
    /// or the fatal-path broadcast).
    pub quarantined: usize,
    /// Set when the serving thread died on a persistent unattributable
    /// step failure (after failing every live stream); `None` on a
    /// graceful shutdown.
    pub fatal: Option<EngineError>,
    /// The engine's final stats window.
    pub stats: ServeStats,
}

/// Live queue/slot occupancy, via [`ServerClient::status`].
#[derive(Clone, Copy, Debug, Default)]
pub struct ServerStatus {
    pub queued: usize,
    pub in_flight: usize,
    pub capacity: usize,
    pub finished: usize,
    pub shed: usize,
    pub rejected: usize,
    /// KV-pool occupancy (paged mode; all-zero for engines without a
    /// pool). See [`KvPoolStats`].
    pub kv: KvPoolStats,
}

/// A per-request event stream: everything the engine emits for one
/// request, ending with exactly one terminal event (`finish: Some(_)`).
/// Iterate it, or use [`TokenStream::collect_output`].
///
/// The stream is **fused on the terminal event**: once an event with
/// `finish: Some(_)` has been consumed, every further [`TokenStream::recv`]
/// returns [`EngineError::ServerClosed`] immediately and iteration
/// yields `None` — deterministically, from the stream's own state. (It
/// previously blocked on the channel until the serving thread dropped
/// its sender, so iterating after [`ServeServer::shutdown`] raced the
/// fan-out thread.) A disconnect *without* a terminal event — the
/// serving thread panicked — surfaces as the same
/// [`EngineError::ServerClosed`].
pub struct TokenStream {
    id: u64,
    rx: Receiver<TokenEvent>,
    /// Set once the terminal event has been consumed (or the channel
    /// disconnected): the fuse that makes post-terminal reads
    /// deterministic.
    done: bool,
}

impl TokenStream {
    /// The request id this stream belongs to.
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Block for the next event. Returns [`EngineError::ServerClosed`]
    /// once the terminal event has been consumed, or if the server
    /// died without delivering one.
    pub fn recv(&mut self) -> Result<TokenEvent, EngineError> {
        if self.done {
            return Err(EngineError::ServerClosed);
        }
        match self.rx.recv() {
            Ok(ev) => {
                if ev.finish.is_some() {
                    self.done = true;
                }
                Ok(ev)
            }
            Err(_) => {
                self.done = true;
                Err(EngineError::ServerClosed)
            }
        }
    }

    /// Drain the stream to its terminal event: the tokens generated and
    /// the finish reason (`None` only if the server died without
    /// delivering one).
    pub fn collect_output(mut self) -> (Vec<i32>, Option<FinishReason>) {
        let mut tokens = Vec::new();
        let mut finish = None;
        while let Ok(ev) = self.recv() {
            if let Some(t) = ev.token {
                tokens.push(t);
            }
            if ev.finish.is_some() {
                finish = ev.finish;
                break;
            }
        }
        (tokens, finish)
    }
}

impl Iterator for TokenStream {
    type Item = TokenEvent;
    /// Yields events up to and including the terminal one, then `None`
    /// — fused, so iterating a finished stream after
    /// [`ServeServer::shutdown`] terminates immediately instead of
    /// racing the serving thread's sender drop.
    fn next(&mut self) -> Option<TokenEvent> {
        self.recv().ok()
    }
}

/// A cheap, cloneable handle for talking to the serving thread from any
/// thread. Every call is a synchronous RPC over the command channel;
/// once the server is gone, calls return [`EngineError::ServerClosed`].
#[derive(Clone)]
pub struct ServerClient {
    tx: Sender<Command>,
}

impl ServerClient {
    /// Submit with default options (interactive, no deadline). See
    /// [`ServerClient::submit_with`].
    pub fn submit(&self, r: Request) -> Result<TokenStream, EngineError> {
        self.submit_with(r, SubmitOptions::default())
    }

    /// Submit a request; on acceptance the returned [`TokenStream`]
    /// carries its events. Typed synchronous refusals: the engine's
    /// validation errors ([`EngineError::RequestTooLong`] etc.),
    /// [`EngineError::DuplicateId`] for an id with a live stream,
    /// [`EngineError::Overloaded`] when the wait queue is full and
    /// nothing queued outranks this submission, and
    /// [`EngineError::ServerClosed`] after shutdown.
    pub fn submit_with(&self, r: Request, opts: SubmitOptions) -> Result<TokenStream, EngineError> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Command::Submit { req: r, opts, reply })
            .map_err(|_| EngineError::ServerClosed)?;
        rx.recv().map_err(|_| EngineError::ServerClosed)?
    }

    /// Cancel a request wherever it is — server queue, engine queue, or
    /// active. Its stream ends with a terminal
    /// [`FinishReason::Cancelled`] event. Same typed refusals as
    /// [`ServeEngine::cancel`].
    pub fn cancel(&self, id: u64) -> Result<(), EngineError> {
        let (reply, rx) = mpsc::channel();
        self.tx.send(Command::Cancel { id, reply }).map_err(|_| EngineError::ServerClosed)?;
        rx.recv().map_err(|_| EngineError::ServerClosed)?
    }

    /// Snapshot of queue/slot occupancy and shed counters.
    pub fn status(&self) -> Result<ServerStatus, EngineError> {
        let (reply, rx) = mpsc::channel();
        self.tx.send(Command::Status { reply }).map_err(|_| EngineError::ServerClosed)?;
        rx.recv().map_err(|_| EngineError::ServerClosed)
    }
}

enum Command {
    Submit { req: Request, opts: SubmitOptions, reply: Sender<Result<TokenStream, EngineError>> },
    Cancel { id: u64, reply: Sender<Result<(), EngineError>> },
    Status { reply: Sender<ServerStatus> },
    Shutdown,
}

/// The serving thread handle. Dropping it shuts the server down
/// (best-effort, discarding the report); call [`ServeServer::shutdown`]
/// to drain gracefully and get the [`ServerReport`].
pub struct ServeServer {
    tx: Sender<Command>,
    thread: Option<JoinHandle<ServerReport>>,
}

impl ServeServer {
    /// Build the engine from `builder` **on the caller's thread** — so
    /// configuration and resource errors surface synchronously as
    /// typed errors, not as a dead serving thread — then start the
    /// serving loop with it.
    pub fn spawn(builder: EngineBuilder, cfg: ServerConfig) -> Result<ServeServer, EngineError> {
        let engine = builder.build()?;
        Ok(Self::spawn_with(engine, cfg))
    }

    /// Start the serving loop over any [`StepEngine`] — the real
    /// engine, or a mock for testing the front-end without artifacts.
    pub fn spawn_with<E: StepEngine + 'static>(engine: E, cfg: ServerConfig) -> ServeServer {
        let (tx, rx) = mpsc::channel();
        let thread = std::thread::Builder::new()
            .name("mpk-serve".into())
            .spawn(move || ServerState::new(engine, cfg).run(rx))
            .expect("failed to spawn serving thread");
        ServeServer { tx, thread: Some(thread) }
    }

    /// A new client handle (clone freely, hand to any thread).
    pub fn client(&self) -> ServerClient {
        ServerClient { tx: self.tx.clone() }
    }

    /// Graceful shutdown: stop accepting submissions, drain everything
    /// queued and in flight to its terminal event, then join the thread
    /// and return its [`ServerReport`].
    pub fn shutdown(mut self) -> ServerReport {
        let _ = self.tx.send(Command::Shutdown);
        match self.thread.take().expect("thread present until shutdown").join() {
            Ok(report) => report,
            // the serving thread panicked: synthesize the failure
            // instead of propagating the panic into the caller.
            Err(_) => ServerReport { fatal: Some(EngineError::ServerClosed), ..Default::default() },
        }
    }
}

impl Drop for ServeServer {
    fn drop(&mut self) {
        if let Some(thread) = self.thread.take() {
            let _ = self.tx.send(Command::Shutdown);
            let _ = thread.join();
        }
    }
}

/// An accepted-but-not-yet-admitted request in the server's wait queue.
struct Queued {
    req: Request,
    priority: Priority,
    /// Absolute deadline (acceptance time + relative deadline).
    deadline: Option<Instant>,
}

/// Everything the serving thread owns.
struct ServerState<E: StepEngine> {
    engine: E,
    cfg: ServerConfig,
    /// Bounded wait queue, kept sorted by priority class (stable FIFO
    /// within a class).
    queue: VecDeque<Queued>,
    /// Live per-request event senders — every accepted request has one
    /// from acceptance until its terminal event.
    streams: HashMap<u64, Sender<TokenEvent>>,
    /// Absolute deadlines of requests already handed to the engine.
    deadlines: HashMap<u64, Instant>,
    report: ServerReport,
    closing: bool,
}

impl<E: StepEngine> ServerState<E> {
    fn new(engine: E, cfg: ServerConfig) -> Self {
        ServerState {
            engine,
            cfg,
            queue: VecDeque::new(),
            streams: HashMap::new(),
            deadlines: HashMap::new(),
            report: ServerReport::default(),
            closing: false,
        }
    }

    /// The serving loop: drain commands → expire deadlines → admit →
    /// step and fan out → (idle) block briefly for the next command.
    fn run(mut self, rx: Receiver<Command>) -> ServerReport {
        loop {
            loop {
                match rx.try_recv() {
                    Ok(cmd) => self.handle(cmd),
                    Err(TryRecvError::Empty) => break,
                    Err(TryRecvError::Disconnected) => {
                        self.closing = true;
                        break;
                    }
                }
            }
            self.expire_deadlines(Instant::now());
            self.admit();
            if self.engine.has_work() {
                match self.engine.step() {
                    Ok(outcome) => {
                        for ev in outcome.events {
                            self.deliver(ev);
                        }
                        // release retired ids promptly so clients can
                        // reuse them (and the engine's finished list
                        // stays bounded).
                        self.engine.take_finished();
                    }
                    Err(err) => return self.fail_fatally(err),
                }
            } else if self.closing {
                break;
            } else {
                match rx.recv_timeout(self.cfg.idle_poll) {
                    Ok(cmd) => self.handle(cmd),
                    Err(RecvTimeoutError::Timeout) => {}
                    Err(RecvTimeoutError::Disconnected) => self.closing = true,
                }
            }
        }
        // graceful exit: nothing queued, engine drained. Any stream
        // still open here is a bookkeeping leak — fail it with a
        // terminal event rather than leaving its client blocked on a
        // vanished sender.
        let leaked: Vec<u64> = self.streams.keys().copied().collect();
        for id in leaked {
            self.finish_local(id, FinishReason::Failed);
        }
        self.report.stats = self.engine.take_stats();
        self.report
    }

    fn handle(&mut self, cmd: Command) {
        match cmd {
            Command::Submit { req, opts, reply } => {
                let res = self.accept(req, opts);
                let _ = reply.send(res);
            }
            Command::Cancel { id, reply } => {
                let res = self.cancel(id);
                let _ = reply.send(res);
            }
            Command::Status { reply } => {
                let _ = reply.send(ServerStatus {
                    queued: self.queue.len(),
                    in_flight: self.engine.in_flight(),
                    capacity: self.engine.capacity(),
                    finished: self.report.finished,
                    shed: self.report.shed,
                    rejected: self.report.rejected,
                    kv: self.engine.kv_status(),
                });
            }
            Command::Shutdown => self.closing = true,
        }
    }

    /// Admission control for one submission: duplicate and engine
    /// validation first (both non-mutating), then the bounded-queue
    /// policy — displace a strictly lower-priority queued request or
    /// refuse with [`EngineError::Overloaded`] — then enqueue by
    /// priority class and hand back the stream.
    fn accept(&mut self, req: Request, opts: SubmitOptions) -> Result<TokenStream, EngineError> {
        if self.closing {
            return Err(EngineError::ServerClosed);
        }
        let id = req.id;
        if self.streams.contains_key(&id) {
            return Err(EngineError::DuplicateId { id });
        }
        self.engine.validate(&req)?;
        if self.queue.len() >= self.cfg.queue_depth {
            // shed order: the most recently enqueued request of a
            // strictly lower class — Batch pays before Interactive,
            // and older waiters outlive newer ones.
            match self.queue.iter().rposition(|q| q.priority > opts.priority) {
                Some(pos) => {
                    let victim = self.queue.remove(pos).expect("position from iterator");
                    self.finish_local(victim.req.id, FinishReason::Shed);
                }
                None => {
                    self.report.rejected += 1;
                    return Err(EngineError::Overloaded { id, queue_depth: self.cfg.queue_depth });
                }
            }
        }
        let (tx, rx) = mpsc::channel();
        self.streams.insert(id, tx);
        let deadline = opts.deadline.map(|d| Instant::now() + d);
        // insert after the last entry of the same or a higher class:
        // FIFO within a class, Interactive ahead of Batch.
        let pos = self
            .queue
            .iter()
            .rposition(|q| q.priority <= opts.priority)
            .map_or(0, |p| p + 1);
        self.queue.insert(pos, Queued { req, priority: opts.priority, deadline });
        Ok(TokenStream { id, rx, done: false })
    }

    fn cancel(&mut self, id: u64) -> Result<(), EngineError> {
        // still in the server's wait queue: never reached the engine.
        if let Some(pos) = self.queue.iter().position(|q| q.req.id == id) {
            self.queue.remove(pos);
            self.finish_local(id, FinishReason::Cancelled);
            return Ok(());
        }
        // inside the engine (waiting or active): its terminal event
        // arrives through the next step's outcome.
        self.engine.terminate(id, FinishReason::Cancelled)
    }

    /// Enforce deadlines as scheduled terminations. Queued requests
    /// finish locally (they never reach the engine); admitted ones are
    /// terminated in the engine and their terminal event arrives
    /// through the next step.
    fn expire_deadlines(&mut self, now: Instant) {
        let mut i = 0;
        while i < self.queue.len() {
            if self.queue[i].deadline.is_some_and(|d| d <= now) {
                let q = self.queue.remove(i).expect("index in bounds");
                self.finish_local(q.req.id, FinishReason::DeadlineExceeded);
            } else {
                i += 1;
            }
        }
        let expired: Vec<u64> = self
            .deadlines
            .iter()
            .filter(|(_, &d)| d <= now)
            .map(|(&id, _)| id)
            .collect();
        for id in expired {
            self.deadlines.remove(&id);
            // AlreadyFinished means the request beat its deadline to a
            // terminal state this very tick — nothing to do.
            let _ = self.engine.terminate(id, FinishReason::DeadlineExceeded);
        }
    }

    /// Refill engine slots from the front of the wait queue.
    fn admit(&mut self) {
        while self.engine.in_flight() < self.engine.capacity() {
            let Some(q) = self.queue.pop_front() else { break };
            let id = q.req.id;
            match self.engine.submit(q.req) {
                Ok(()) => {
                    if let Some(d) = q.deadline {
                        self.deadlines.insert(id, d);
                    }
                }
                // validated at acceptance, so this is unreachable in
                // practice — but a request must never vanish without a
                // terminal event, so fail its stream rather than drop.
                Err(_) => self.finish_local(id, FinishReason::Failed),
            }
        }
    }

    /// Deliver one engine event to its stream; a terminal event closes
    /// the stream (dropping the sender ends the client's iterator) and
    /// updates the report counters.
    fn deliver(&mut self, ev: TokenEvent) {
        let id = ev.request;
        let finish = ev.finish;
        if let Some(tx) = self.streams.get(&id) {
            // a client that dropped its stream stops receiving; the
            // request still runs to its terminal state (cancel is the
            // explicit way to stop paying for it).
            let _ = tx.send(ev);
        }
        if let Some(reason) = finish {
            self.streams.remove(&id);
            self.deadlines.remove(&id);
            self.report.finished += 1;
            match reason {
                FinishReason::Shed => self.report.shed += 1,
                FinishReason::DeadlineExceeded => self.report.deadline_expired += 1,
                FinishReason::Failed => self.report.quarantined += 1,
                _ => {}
            }
        }
    }

    /// Terminate a request that never reached (or never re-reaches) the
    /// engine: synthesize its terminal event server-side.
    fn finish_local(&mut self, id: u64, reason: FinishReason) {
        self.deliver(TokenEvent { request: id, token: None, finish: Some(reason) });
    }

    /// A step failed beyond recovery: fail every live stream with a
    /// terminal event (no client may hang), record the error, and hand
    /// back the report.
    fn fail_fatally(mut self, err: EngineError) -> ServerReport {
        self.queue.clear();
        let live: Vec<u64> = self.streams.keys().copied().collect();
        for id in live {
            self.finish_local(id, FinishReason::Failed);
        }
        self.report.fatal = Some(err);
        self.report.stats = self.engine.take_stats();
        self.report
    }
}
