//! KV accounting for the legacy slot-contiguous mode, and the shared
//! max-batch KV arena both modes store into.
//!
//! [`KvAllocator`] is the **accounting-only** block allocator behind
//! slot-contiguous admission control: cache memory is *counted* in
//! fixed-size blocks of `block_tokens` tokens (a request is admitted
//! only if its worst-case block demand fits; blocks free when it
//! retires), but block ids never address storage — a request's rows
//! physically live in its arena slot. The true paged mode, where block
//! tables *do* address storage and enable copy-on-write prefix sharing,
//! is [`crate::serving::paged::PagedKvPool`].
//!
//! The [`KvArena`] is the storage both account for: **one**
//! `[slots, s_max, kv_dim]` K and V segment per layer, sized for the
//! maximum batch, shared (via [`SharedSlab`] aliasing) by every
//! batch-size-specialized session store. A batch-`b` session's
//! `l{l}.kcache` tensor is exactly the first `b` slots of the layer's
//! segment, so switching specializations re-interprets the same memory
//! — pointer arithmetic, not row migration. Since the batcher moved to
//! stable slots (lowest-free-slot admission, no implicit compaction), a
//! request's rows stay put for its whole lifetime and decode moves zero
//! rows structurally; [`KvArena::move_slot`] (one memcpy per layer
//! segment) is the relocation primitive behind the engine's **opt-in**
//! anti-fragmentation pass (relocate one request when it drops the
//! specialized graph a whole power of two, counted in
//! `kv_rows_migrated`) — any *undeliberate* remap is still an invariant
//! violation the engine surfaces as a typed error, never a silent
//! repair. With paging on, slot compaction is obsolete (a relocation
//! would be a block-table rewrite) and the whole
//! `move_slot`/`compaction_candidate`/`relocate` path is **legacy-only
//! and unreachable** — the builder rejects `compaction` + `paged_kv`
//! up front and the engine's compaction pass asserts paging is off.

use crate::exec::store::SharedSlab;

/// Block-grained KV allocator.
#[derive(Debug)]
pub struct KvAllocator {
    total_blocks: usize,
    free: Vec<usize>,
    /// blocks held per request id.
    held: std::collections::HashMap<u64, Vec<usize>>,
    pub block_tokens: usize,
}

impl KvAllocator {
    pub fn new(total_blocks: usize, block_tokens: usize) -> Self {
        KvAllocator {
            total_blocks,
            free: (0..total_blocks).rev().collect(),
            held: Default::default(),
            block_tokens: block_tokens.max(1),
        }
    }

    pub fn free_blocks(&self) -> usize {
        self.free.len()
    }

    pub fn total_blocks(&self) -> usize {
        self.total_blocks
    }

    /// Blocks needed to hold `tokens` tokens.
    pub fn blocks_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.block_tokens)
    }

    /// Ensure `req` holds enough blocks for `tokens` tokens; allocates
    /// the difference. Returns false (no change) if the pool is short.
    pub fn ensure(&mut self, req: u64, tokens: usize) -> bool {
        let need = self.blocks_for(tokens);
        let have = self.held.get(&req).map_or(0, |v| v.len());
        if need <= have {
            return true;
        }
        let want = need - have;
        if self.free.len() < want {
            return false;
        }
        let entry = self.held.entry(req).or_default();
        for _ in 0..want {
            entry.push(self.free.pop().unwrap());
        }
        true
    }

    /// Release all blocks of a retired request.
    pub fn release(&mut self, req: u64) -> usize {
        match self.held.remove(&req) {
            Some(blocks) => {
                let n = blocks.len();
                self.free.extend(blocks);
                n
            }
            None => 0,
        }
    }

    /// Blocks currently held by a request.
    pub fn held_by(&self, req: u64) -> usize {
        self.held.get(&req).map_or(0, |v| v.len())
    }
}

/// The shared max-batch KV arena: per-layer K/V segments in one
/// [`SharedSlab`] that every batch-size-specialized session store
/// aliases. Layout (element offsets): layer `l`'s K segment starts at
/// `2·l·slots·s_max·kv_dim`, its V segment one segment later; within a
/// segment, slot `s` occupies the contiguous `[s·s_max·kv_dim,
/// (s+1)·s_max·kv_dim)` span.
pub struct KvArena {
    slab: SharedSlab,
    layers: usize,
    slots: usize,
    s_max: usize,
    kv_dim: usize,
}

impl KvArena {
    pub fn new(layers: usize, slots: usize, s_max: usize, kv_dim: usize) -> Self {
        assert!(layers > 0 && slots > 0 && s_max > 0 && kv_dim > 0);
        KvArena {
            slab: SharedSlab::new(layers * 2 * slots * s_max * kv_dim),
            layers,
            slots,
            s_max,
            kv_dim,
        }
    }

    fn seg(&self) -> usize {
        self.slots * self.s_max * self.kv_dim
    }

    /// Element offset of layer `l`'s K segment within the slab.
    pub fn k_offset(&self, l: usize) -> usize {
        assert!(l < self.layers);
        2 * l * self.seg()
    }

    /// Element offset of layer `l`'s V segment within the slab.
    pub fn v_offset(&self, l: usize) -> usize {
        assert!(l < self.layers);
        (2 * l + 1) * self.seg()
    }

    /// Handle to the backing slab (for aliasing into session stores).
    pub fn slab(&self) -> SharedSlab {
        self.slab.clone()
    }

    pub fn slots(&self) -> usize {
        self.slots
    }

    pub fn layers(&self) -> usize {
        self.layers
    }

    /// Rows per slot (the geometry the paged pool re-partitions into
    /// blocks — `block_tokens` must divide this).
    pub fn s_max(&self) -> usize {
        self.s_max
    }

    /// Elements per cached row.
    pub fn kv_dim(&self) -> usize {
        self.kv_dim
    }

    /// **Legacy-only** (slot-contiguous mode): move the first `rows`
    /// cached rows of slot `src` into slot `dst` across every layer's
    /// K and V segments. Unreachable with paging on — block tables make
    /// relocation a table rewrite, the builder rejects the
    /// `compaction` + `paged_kv` combination, and the engine's
    /// compaction pass `debug_assert`s the pool is not paged.
    ///
    /// One contiguous memcpy per segment. Returns rows moved × layers
    /// — the engine's
    /// `kv_rows_migrated` unit. A `src == dst` move is a **no-op
    /// returning 0**: the rows are already home, nothing is copied and
    /// nothing is counted (a compaction policy that resolves a slot to
    /// itself must not trip `SharedSlab::copy_within`'s disjointness
    /// contract with a self-overlapping copy). The default serving path
    /// never calls this; the engine's opt-in anti-fragmentation pass is
    /// its one deliberate caller (exactly one request per step, into a
    /// known-free slot). Callers doing multiple moves own the ordering
    /// problem (a destination may be another pending move's source).
    pub fn move_slot(&self, src: usize, dst: usize, rows: usize) -> usize {
        assert!(src < self.slots && dst < self.slots, "bad slot move {src}->{dst}");
        assert!(rows <= self.s_max, "slot move rows {rows} > s_max {}", self.s_max);
        if rows == 0 || src == dst {
            return 0;
        }
        let slot_span = self.s_max * self.kv_dim;
        let n = rows * self.kv_dim;
        for l in 0..self.layers {
            for base in [self.k_offset(l), self.v_offset(l)] {
                self.slab.copy_within(base + src * slot_span, base + dst * slot_span, n);
            }
        }
        rows * self.layers
    }
}

/// Tracks which arena slot holds each active request's authoritative KV
/// rows.
///
/// The serving engine keeps KV resident in the shared [`KvArena`]
/// across decode iterations *and* across batch-size specializations
/// (every session store aliases the same slab): the in-kernel
/// `KvAppend` task writes each new row in place. With stable batcher
/// slots a request's home never changes between admission and
/// retirement, so this map is written once per request and otherwise
/// serves as the invariant check that no slot remap slipped back in.
#[derive(Debug, Default)]
pub struct KvResidency {
    /// request id → arena slot.
    home: std::collections::HashMap<u64, usize>,
}

impl KvResidency {
    /// Which arena slot `req`'s KV rows currently occupy, if any.
    pub fn home(&self, req: u64) -> Option<usize> {
        self.home.get(&req).copied()
    }

    /// Record that `req`'s rows live at `slot` (on admission; with
    /// stable batcher slots this is the only write per request).
    pub fn set(&mut self, req: u64, slot: usize) {
        self.home.insert(req, slot);
    }

    /// Forget a retired request; its arena rows become dead data that
    /// the next occupant of the slot overwrites lazily.
    pub fn evict(&mut self, req: u64) -> Option<usize> {
        self.home.remove(&req)
    }

    /// Number of requests with resident KV rows.
    pub fn resident_count(&self) -> usize {
        self.home.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn residency_set_move_evict() {
        let mut r = KvResidency::default();
        assert_eq!(r.home(7), None);
        r.set(7, 2);
        assert_eq!(r.home(7), Some(2));
        // relocation (the engine's fallback path only)
        r.set(7, 0);
        assert_eq!(r.home(7), Some(0));
        assert_eq!(r.resident_count(), 1);
        assert_eq!(r.evict(7), Some(0));
        assert_eq!(r.evict(7), None);
        assert_eq!(r.resident_count(), 0);
    }

    #[test]
    fn arena_layout_is_disjoint_and_covering() {
        let a = KvArena::new(3, 4, 8, 2);
        let seg = 4 * 8 * 2;
        let mut offs: Vec<usize> = (0..3).flat_map(|l| [a.k_offset(l), a.v_offset(l)]).collect();
        offs.sort_unstable();
        // segments tile the slab exactly: 6 segments, stride `seg`.
        assert_eq!(offs, (0..6).map(|i| i * seg).collect::<Vec<_>>());
        assert_eq!(a.slab().len(), 6 * seg);
    }

    #[test]
    fn arena_move_slot_moves_rows_every_layer() {
        let a = KvArena::new(2, 4, 4, 2);
        let slab = a.slab();
        // paint slot 3, rows 0..2 in every segment with layer-tagged data
        // (row-major: slot 3's first two rows = 4 elements).
        let slot_span = 4 * 2;
        for l in 0..2 {
            for (si, base) in [a.k_offset(l), a.v_offset(l)].into_iter().enumerate() {
                let tag = (l * 10 + si) as f32;
                let rows: Vec<f32> = (0..4).map(|e| tag + e as f32).collect();
                slab.write(base + 3 * slot_span, &rows);
            }
        }
        let moved = a.move_slot(3, 1, 2);
        assert_eq!(moved, 2 * 2, "rows × layers");
        for l in 0..2 {
            for (si, base) in [a.k_offset(l), a.v_offset(l)].into_iter().enumerate() {
                let tag = (l * 10 + si) as f32;
                let got = slab.read(base + slot_span, 4);
                assert_eq!(got, (0..4).map(|e| tag + e as f32).collect::<Vec<_>>());
            }
        }
        // zero-row move is free.
        assert_eq!(a.move_slot(0, 2, 0), 0);
    }

    #[test]
    fn move_slot_to_itself_is_a_noop() {
        // regression: a self-move used to be rejected outright (and
        // without the guard would have reached SharedSlab::copy_within
        // with identical, fully overlapping ranges, which asserts on
        // non-disjoint copies). It must instead count zero rows and
        // leave every byte in place.
        let a = KvArena::new(2, 4, 4, 2);
        let slab = a.slab();
        let slot_span = 4 * 2;
        for l in 0..2 {
            for (si, base) in [a.k_offset(l), a.v_offset(l)].into_iter().enumerate() {
                let tag = (l * 10 + si) as f32;
                let rows: Vec<f32> = (0..slot_span).map(|e| tag + e as f32).collect();
                slab.write(base + 2 * slot_span, &rows);
            }
        }
        let before = slab.read(0, slab.len());
        assert_eq!(a.move_slot(2, 2, 4), 0, "self-move must move no rows");
        assert_eq!(slab.read(0, slab.len()), before, "self-move must not touch the arena");
        // bounds are still enforced on the degenerate path.
        assert_eq!(a.move_slot(3, 3, 0), 0);
    }

    #[test]
    fn allocate_grow_release() {
        let mut a = KvAllocator::new(10, 4);
        assert!(a.ensure(1, 4)); // 1 block
        assert_eq!(a.held_by(1), 1);
        assert!(a.ensure(1, 5)); // grows to 2
        assert_eq!(a.held_by(1), 2);
        assert!(a.ensure(1, 5)); // idempotent
        assert_eq!(a.free_blocks(), 8);
        assert_eq!(a.release(1), 2);
        assert_eq!(a.free_blocks(), 10);
    }

    #[test]
    fn admission_fails_when_pool_short() {
        let mut a = KvAllocator::new(2, 4);
        assert!(a.ensure(1, 8)); // takes both
        assert!(!a.ensure(2, 1), "should refuse when empty");
        // failed ensure must not leak partial allocations.
        assert_eq!(a.held_by(2), 0);
        a.release(1);
        assert!(a.ensure(2, 1));
    }

    #[test]
    fn blocks_for_rounds_up() {
        let a = KvAllocator::new(1, 16);
        assert_eq!(a.blocks_for(1), 1);
        assert_eq!(a.blocks_for(16), 1);
        assert_eq!(a.blocks_for(17), 2);
        assert_eq!(a.blocks_for(0), 0);
    }

    #[test]
    fn no_double_release() {
        let mut a = KvAllocator::new(4, 4);
        a.ensure(9, 16);
        assert_eq!(a.release(9), 4);
        assert_eq!(a.release(9), 0);
        assert_eq!(a.free_blocks(), 4);
    }
}
