//! Paged KV-cache block allocator (§6.1 / PagedAttention-class).
//!
//! Physical cache memory is divided into fixed-size blocks of
//! `block_tokens` tokens; each active request holds a growing list of
//! blocks per layer. The serving engine uses this for admission control
//! (a request is admitted only if its worst-case block demand fits) and
//! frees blocks when requests retire.

/// Block-grained KV allocator.
#[derive(Debug)]
pub struct KvAllocator {
    total_blocks: usize,
    free: Vec<usize>,
    /// blocks held per request id.
    held: std::collections::HashMap<u64, Vec<usize>>,
    pub block_tokens: usize,
}

impl KvAllocator {
    pub fn new(total_blocks: usize, block_tokens: usize) -> Self {
        KvAllocator {
            total_blocks,
            free: (0..total_blocks).rev().collect(),
            held: Default::default(),
            block_tokens: block_tokens.max(1),
        }
    }

    pub fn free_blocks(&self) -> usize {
        self.free.len()
    }

    pub fn total_blocks(&self) -> usize {
        self.total_blocks
    }

    /// Blocks needed to hold `tokens` tokens.
    pub fn blocks_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.block_tokens)
    }

    /// Ensure `req` holds enough blocks for `tokens` tokens; allocates
    /// the difference. Returns false (no change) if the pool is short.
    pub fn ensure(&mut self, req: u64, tokens: usize) -> bool {
        let need = self.blocks_for(tokens);
        let have = self.held.get(&req).map_or(0, |v| v.len());
        if need <= have {
            return true;
        }
        let want = need - have;
        if self.free.len() < want {
            return false;
        }
        let entry = self.held.entry(req).or_default();
        for _ in 0..want {
            entry.push(self.free.pop().unwrap());
        }
        true
    }

    /// Release all blocks of a retired request.
    pub fn release(&mut self, req: u64) -> usize {
        match self.held.remove(&req) {
            Some(blocks) => {
                let n = blocks.len();
                self.free.extend(blocks);
                n
            }
            None => 0,
        }
    }

    /// Blocks currently held by a request.
    pub fn held_by(&self, req: u64) -> usize {
        self.held.get(&req).map_or(0, |v| v.len())
    }
}

/// Tracks which batch-size-specialized session store (and slot within
/// it) holds each active request's authoritative KV rows.
///
/// The serving engine keeps KV resident in the `TensorStore` across
/// decode iterations: the in-kernel `KvAppend` task writes each new row
/// in place, so the engine copies cache data only when this map says a
/// request's rows live somewhere other than the slot the batcher just
/// assigned (admission to a different store, or slot compaction after a
/// retirement).
#[derive(Debug, Default)]
pub struct KvResidency {
    /// request id → (graph batch size of the session store, slot).
    home: std::collections::HashMap<u64, (usize, usize)>,
}

impl KvResidency {
    /// Where `req`'s KV rows currently live, if anywhere.
    pub fn home(&self, req: u64) -> Option<(usize, usize)> {
        self.home.get(&req).copied()
    }

    /// Record that `req`'s rows now live in store `graph_batch` at
    /// `slot` (after a migration, or on first admission).
    pub fn set(&mut self, req: u64, graph_batch: usize, slot: usize) {
        self.home.insert(req, (graph_batch, slot));
    }

    /// Forget a retired request; its store rows become dead data that
    /// the next occupant of the slot overwrites lazily.
    pub fn evict(&mut self, req: u64) -> Option<(usize, usize)> {
        self.home.remove(&req)
    }

    /// Number of requests with resident KV rows.
    pub fn resident_count(&self) -> usize {
        self.home.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn residency_set_move_evict() {
        let mut r = KvResidency::default();
        assert_eq!(r.home(7), None);
        r.set(7, 4, 2);
        assert_eq!(r.home(7), Some((4, 2)));
        // slot compaction within the same store
        r.set(7, 4, 0);
        assert_eq!(r.home(7), Some((4, 0)));
        // migration to a smaller specialized store
        r.set(7, 2, 1);
        assert_eq!(r.home(7), Some((2, 1)));
        assert_eq!(r.resident_count(), 1);
        assert_eq!(r.evict(7), Some((2, 1)));
        assert_eq!(r.evict(7), None);
        assert_eq!(r.resident_count(), 0);
    }

    #[test]
    fn allocate_grow_release() {
        let mut a = KvAllocator::new(10, 4);
        assert!(a.ensure(1, 4)); // 1 block
        assert_eq!(a.held_by(1), 1);
        assert!(a.ensure(1, 5)); // grows to 2
        assert_eq!(a.held_by(1), 2);
        assert!(a.ensure(1, 5)); // idempotent
        assert_eq!(a.free_blocks(), 8);
        assert_eq!(a.release(1), 2);
        assert_eq!(a.free_blocks(), 10);
    }

    #[test]
    fn admission_fails_when_pool_short() {
        let mut a = KvAllocator::new(2, 4);
        assert!(a.ensure(1, 8)); // takes both
        assert!(!a.ensure(2, 1), "should refuse when empty");
        // failed ensure must not leak partial allocations.
        assert_eq!(a.held_by(2), 0);
        a.release(1);
        assert!(a.ensure(2, 1));
    }

    #[test]
    fn blocks_for_rounds_up() {
        let a = KvAllocator::new(1, 16);
        assert_eq!(a.blocks_for(1), 1);
        assert_eq!(a.blocks_for(16), 1);
        assert_eq!(a.blocks_for(17), 2);
        assert_eq!(a.blocks_for(0), 0);
    }

    #[test]
    fn no_double_release() {
        let mut a = KvAllocator::new(4, 4);
        a.ensure(9, 16);
        assert_eq!(a.release(9), 4);
        assert_eq!(a.release(9), 0);
        assert_eq!(a.free_blocks(), 4);
    }
}
