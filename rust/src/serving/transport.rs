//! The TCP transport: sockets in, [`ServerClient`] calls out.
//!
//! [`ServeTransport`] binds a stdlib [`TcpListener`] over a running
//! [`ServeServer`] and translates each connection into the same typed
//! calls an in-process caller makes — `submit_with`, `cancel`,
//! `status` — speaking the versioned frame protocol defined in
//! [`crate::serving::wire`]. No async runtime: one accept thread, and
//! per connection a reader thread (frames in), a writer thread (frames
//! out through a **bounded** queue), and one pump thread per live
//! request forwarding its [`TokenStream`] as `Token`/`Finish` frames.
//!
//! # Robustness model
//!
//! The failure modes this layer is built around, and what each maps
//! to:
//!
//! * **Slowloris / stalled peers** — sockets carry read and write
//!   deadlines; a frame that *starts* arriving must complete within
//!   [`TransportConfig::read_timeout`] or the connection is torn down
//!   with [`TransportError::Stalled`]. Oversized length prefixes are
//!   refused before the body is read ([`TransportConfig::max_frame`]).
//! * **Abusive or broken clients** — bytes that do not parse become a
//!   typed [`TransportError`], a best-effort
//!   [`CloseReason::Protocol`] close frame, and a teardown.
//! * **Backpressure, twice** — per-connection in-flight submissions
//!   are capped ([`TransportConfig::max_in_flight`]); past the cap a
//!   `Submit` is answered with a typed `Shed` frame (the wire form of
//!   [`EngineError::Overloaded`]) and never reaches the server. The
//!   outbound direction is a bounded queue of
//!   [`TransportConfig::outbound_depth`] frames with a configurable
//!   slow-reader policy ([`SlowReaderPolicy`]).
//! * **Disconnect mid-stream** — a dropped connection (EOF, reset,
//!   stall) cancels every request it still has live, so slots and KV
//!   blocks free immediately instead of decoding for a ghost.
//! * **Graceful drain** — [`ServeTransport::drain`] stops accepting,
//!   refuses new submissions, flushes live streams until a bounded
//!   deadline, force-cancels the rest, closes every connection with a
//!   [`CloseReason::Drain`] frame, and returns the final
//!   [`ServerReport`] plus transport counters in a [`DrainReport`].
//! * **Deterministic chaos** — [`TransportConfig::faults`] arms a
//!   seeded [`WireFaultPlan`] on the server's outbound path (truncate
//!   / corrupt / delay / drop); [`TransportClient::with_faults`] arms
//!   the same plan on a client. Both replay per seed.
//!
//! Every connection-level event lands in
//! [`TransportMetrics`](crate::metrics::TransportMetrics) —
//! accepted/rejected connections, submitted/rejected requests, frames
//! sent/received/dropped, slow-consumer closes, forced drains.

use crate::metrics::{TransportMetrics, TransportSnapshot};
use crate::serving::batcher::Request;
use crate::serving::error::EngineError;
use crate::serving::server::{
    ServeServer, ServerClient, ServerReport, SubmitOptions, TokenStream,
};
use crate::serving::step::FinishReason;
use crate::serving::wire::{
    self, ClientFrame, CloseReason, ServerFrame, TransportError, WireFault, WireFaultInjector,
    WireFaultPlan, DEFAULT_MAX_FRAME,
};
use std::collections::HashSet;
use std::io::{ErrorKind, Read, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{Receiver, SyncSender, TrySendError};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// What a pump does when the connection's bounded outbound queue is
/// full — i.e. the client reads slower than the engine decodes.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum SlowReaderPolicy {
    /// Block the pump until the writer drains a slot. The engine keeps
    /// decoding (the serving thread never blocks on a socket); only
    /// this request's *delivery* stalls, and memory stays bounded at
    /// `outbound_depth` frames. The default.
    #[default]
    Block,
    /// Shed the connection: tear it down with a typed
    /// [`CloseReason::SlowConsumer`] close frame (best-effort — the
    /// queue is full by definition) and cancel its live requests. For
    /// deployments that prefer freeing slots over waiting out a slow
    /// peer.
    Shed,
}

/// Transport shape knobs.
#[derive(Clone, Copy, Debug)]
pub struct TransportConfig {
    /// Max frame *body* bytes accepted from a peer; an oversized
    /// length prefix is refused before the body is read.
    pub max_frame: u32,
    /// Socket poll tick: how often blocked reads/writes wake to check
    /// teardown/drain flags. Bounds drain latency, not correctness.
    pub poll: Duration,
    /// Mid-frame stall budget (the slowloris guard): once a frame has
    /// started arriving, each silent gap beyond this tears the
    /// connection down with [`TransportError::Stalled`].
    pub read_timeout: Duration,
    /// Per-write deadline; a peer that stops draining its socket past
    /// this gets torn down (its live requests are cancelled).
    pub write_timeout: Duration,
    /// Per-connection cap on live (submitted, not yet terminal)
    /// requests; a `Submit` past it is answered with a `Shed` frame.
    pub max_in_flight: usize,
    /// Bound on the per-connection outbound frame queue.
    pub outbound_depth: usize,
    /// What to do when that queue fills; see [`SlowReaderPolicy`].
    pub slow_reader: SlowReaderPolicy,
    /// Listener-level connection cap; beyond it new connections get a
    /// [`CloseReason::Overloaded`] close frame.
    pub max_connections: usize,
    /// Seeded chaos on the server's outbound path (off by default).
    pub faults: WireFaultPlan,
}

impl Default for TransportConfig {
    fn default() -> Self {
        TransportConfig {
            max_frame: DEFAULT_MAX_FRAME,
            poll: Duration::from_millis(25),
            read_timeout: Duration::from_secs(2),
            write_timeout: Duration::from_secs(2),
            max_in_flight: 16,
            outbound_depth: 256,
            slow_reader: SlowReaderPolicy::default(),
            max_connections: 256,
            faults: WireFaultPlan::default(),
        }
    }
}

impl TransportConfig {
    fn validate(&self) -> Result<(), TransportError> {
        if self.max_frame < 32 {
            return Err(TransportError::Config { what: format!("max_frame {} below the 32-byte floor", self.max_frame) });
        }
        if self.max_in_flight == 0 || self.outbound_depth == 0 || self.max_connections == 0 {
            return Err(TransportError::Config {
                what: "max_in_flight, outbound_depth, and max_connections must be >= 1".into(),
            });
        }
        if self.poll.is_zero() || self.read_timeout.is_zero() || self.write_timeout.is_zero() {
            return Err(TransportError::Config { what: "poll and timeouts must be non-zero".into() });
        }
        self.faults.validate().map_err(|what| TransportError::Config { what })
    }
}

/// What [`ServeTransport::drain`] hands back: the server's final
/// report plus the transport's counters.
#[derive(Debug)]
pub struct DrainReport {
    /// The underlying server's shutdown report (terminal-event
    /// accounting, shed/rejected counters, final stats window).
    pub server: ServerReport,
    /// Transport counters at drain completion.
    pub transport: TransportSnapshot,
    /// Live requests force-cancelled because the drain deadline
    /// expired before their streams flushed; `0` on a fully graceful
    /// drain.
    pub forced: usize,
    /// Wall time the drain took (bounded by its deadline plus
    /// connection-join overhead).
    pub elapsed: Duration,
}

/// State shared by one connection's reader, writer, and pump threads
/// (and the drain path).
struct ConnShared {
    /// Hard teardown: stop reading, drop (don't write) queued frames,
    /// socket already shut down.
    dead: AtomicBool,
    /// Graceful close: reader exits at the next tick, writer flushes
    /// the queue and exits when all senders are gone.
    closing: AtomicBool,
    /// Request ids submitted on this connection that have not reached
    /// their terminal frame yet.
    live: Mutex<HashSet<u64>>,
    /// A handle to the socket for out-of-thread shutdown (teardown and
    /// forced drain); reader/writer own their own clones.
    sock: TcpStream,
    /// Outbound enqueue handle for the drain path (`Close` frames);
    /// taken and dropped by the reader's epilogue so the writer's
    /// recv loop can end.
    out_tx: Mutex<Option<SyncSender<Vec<u8>>>>,
}

/// Tear a connection down: exactly once, cancel everything it still
/// has live (freeing slots and KV immediately), and shut the socket so
/// blocked reads/writes unblock.
fn teardown(shared: &ConnShared, client: &ServerClient) {
    if shared.dead.swap(true, Ordering::SeqCst) {
        return;
    }
    let ids: Vec<u64> = {
        let mut live = shared.live.lock().expect("live set lock");
        live.drain().collect()
    };
    for id in ids {
        // AlreadyFinished / UnknownRequest just mean the request beat
        // the teardown to a terminal state — nothing to free.
        let _ = client.cancel(id);
    }
    let _ = shared.sock.shutdown(Shutdown::Both);
}

/// Policy-aware outbound enqueue handle, cloned into every pump.
#[derive(Clone)]
struct Outbound {
    tx: SyncSender<Vec<u8>>,
    policy: SlowReaderPolicy,
    shared: Arc<ConnShared>,
    client: ServerClient,
    metrics: Arc<TransportMetrics>,
}

impl Outbound {
    /// Queue a frame for the writer. Returns `false` when it could not
    /// be queued (connection dead, writer gone, or shed as a slow
    /// consumer — in which case the teardown already happened).
    fn send(&self, frame: &ServerFrame) -> bool {
        if self.shared.dead.load(Ordering::SeqCst) {
            self.metrics.inc(&self.metrics.frames_dropped);
            return false;
        }
        let bytes = wire::encode_server(frame);
        match self.policy {
            SlowReaderPolicy::Block => {
                if self.tx.send(bytes).is_err() {
                    self.metrics.inc(&self.metrics.frames_dropped);
                    return false;
                }
                true
            }
            SlowReaderPolicy::Shed => match self.tx.try_send(bytes) {
                Ok(()) => true,
                Err(TrySendError::Full(_)) => {
                    self.metrics.inc(&self.metrics.frames_dropped);
                    self.metrics.inc(&self.metrics.slow_consumer_closes);
                    // best-effort typed close; the queue is full, so
                    // this usually drops too — counted either way.
                    if self.tx.try_send(wire::encode_server(&ServerFrame::Close { reason: CloseReason::SlowConsumer })).is_err() {
                        self.metrics.inc(&self.metrics.frames_dropped);
                    }
                    teardown(&self.shared, &self.client);
                    false
                }
                Err(TrySendError::Disconnected(_)) => {
                    self.metrics.inc(&self.metrics.frames_dropped);
                    false
                }
            },
        }
    }
}

/// Transport-wide state shared with the accept loop and drain path.
struct TransportShared {
    cfg: TransportConfig,
    client: ServerClient,
    metrics: Arc<TransportMetrics>,
    /// Refuse new connections and new submissions.
    draining: AtomicBool,
    /// Accept loop exit flag.
    stopped: AtomicBool,
    conns: Mutex<Vec<ConnHandle>>,
}

struct ConnHandle {
    shared: Arc<ConnShared>,
    thread: JoinHandle<()>,
}

/// The TCP front door over a [`ServeServer`]; see the module docs.
pub struct ServeTransport {
    server: Option<ServeServer>,
    shared: Arc<TransportShared>,
    local_addr: SocketAddr,
    accept: Option<JoinHandle<()>>,
}

impl ServeTransport {
    /// Bind `addr` (e.g. `"127.0.0.1:0"` for an ephemeral port) over a
    /// running server and start accepting. The transport owns the
    /// server from here on; [`ServeTransport::drain`] shuts both down
    /// and returns the combined report.
    pub fn bind(
        addr: impl ToSocketAddrs,
        server: ServeServer,
        cfg: TransportConfig,
    ) -> Result<ServeTransport, TransportError> {
        cfg.validate()?;
        let listener = TcpListener::bind(addr)?;
        listener.set_nonblocking(true)?;
        let local_addr = listener.local_addr()?;
        let shared = Arc::new(TransportShared {
            cfg,
            client: server.client(),
            metrics: Arc::new(TransportMetrics::default()),
            draining: AtomicBool::new(false),
            stopped: AtomicBool::new(false),
            conns: Mutex::new(Vec::new()),
        });
        let accept = {
            let shared = Arc::clone(&shared);
            std::thread::Builder::new()
                .name("mpk-wire-accept".into())
                .spawn(move || accept_loop(listener, shared))
                .map_err(|e| TransportError::Io { what: e.to_string() })?
        };
        Ok(ServeTransport { server: Some(server), shared, local_addr, accept: Some(accept) })
    }

    /// The bound address (resolves the ephemeral port of `":0"` binds).
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// An in-process [`ServerClient`] to the same server the wire
    /// clients talk to.
    pub fn client(&self) -> ServerClient {
        self.shared.client.clone()
    }

    /// Snapshot of the transport counters.
    pub fn metrics(&self) -> TransportSnapshot {
        self.shared.metrics.snapshot()
    }

    /// Live requests across all connections (drain progress gauge).
    fn live_requests(&self) -> usize {
        let conns = self.shared.conns.lock().expect("conns lock");
        conns.iter().map(|c| c.shared.live.lock().expect("live set lock").len()).sum()
    }

    /// Graceful shutdown with a bounded deadline:
    ///
    /// 1. Stop accepting connections and refuse new submissions (a
    ///    `Submit` during drain is answered with a typed
    ///    [`EngineError::ServerClosed`] error frame).
    /// 2. Let live streams flush to their terminal frames until
    ///    `deadline` elapses; then force-cancel whatever remains
    ///    (counted in [`DrainReport::forced`]).
    /// 3. Close every connection — a [`CloseReason::Drain`] frame
    ///    where the writer is still healthy — and join all transport
    ///    threads.
    /// 4. Shut the server down and return the combined report.
    pub fn drain(mut self, deadline: Duration) -> DrainReport {
        let t0 = Instant::now();
        self.shared.draining.store(true, Ordering::SeqCst);
        let mut forced = 0usize;
        loop {
            if self.live_requests() == 0 {
                break;
            }
            if t0.elapsed() >= deadline {
                let conns = self.shared.conns.lock().expect("conns lock");
                for c in conns.iter() {
                    let n = c.shared.live.lock().expect("live set lock").len();
                    if n > 0 {
                        forced += n;
                        teardown(&c.shared, &self.shared.client);
                    }
                }
                self.shared.metrics.drain_forced.fetch_add(forced as u64, Ordering::Relaxed);
                break;
            }
            std::thread::sleep(Duration::from_millis(1));
        }
        // stop the accept loop, then close every connection.
        self.shared.stopped.store(true, Ordering::SeqCst);
        if let Some(a) = self.accept.take() {
            let _ = a.join();
        }
        let conns: Vec<ConnHandle> = {
            let mut guard = self.shared.conns.lock().expect("conns lock");
            std::mem::take(&mut *guard)
        };
        for c in &conns {
            if !c.shared.dead.load(Ordering::SeqCst) {
                // queue the goodbye while the writer still flushes,
                // then flip the graceful-close flag.
                if let Some(tx) = c.shared.out_tx.lock().expect("out_tx lock").as_ref() {
                    let _ = tx.try_send(wire::encode_server(&ServerFrame::Close {
                        reason: CloseReason::Drain,
                    }));
                }
            }
            c.shared.closing.store(true, Ordering::SeqCst);
        }
        for c in conns {
            let _ = c.thread.join();
        }
        let transport = self.shared.metrics.snapshot();
        let server = self.server.take().expect("server present until drain").shutdown();
        DrainReport { server, transport, forced, elapsed: t0.elapsed() }
    }
}

impl Drop for ServeTransport {
    /// Dropping without [`ServeTransport::drain`] is an abrupt stop:
    /// no flush deadline, every connection torn down. (After `drain`
    /// this is a no-op — the fields are already empty.)
    fn drop(&mut self) {
        self.shared.draining.store(true, Ordering::SeqCst);
        self.shared.stopped.store(true, Ordering::SeqCst);
        if let Some(a) = self.accept.take() {
            let _ = a.join();
        }
        let conns: Vec<ConnHandle> = {
            let mut guard = self.shared.conns.lock().expect("conns lock");
            std::mem::take(&mut *guard)
        };
        for c in &conns {
            teardown(&c.shared, &self.shared.client);
        }
        for c in conns {
            let _ = c.thread.join();
        }
        // `server` (if still present) drops after this body, shutting
        // the serving thread down once no connection can reach it.
    }
}

// ---------------------------------------------------------------------------
// accept loop

fn accept_loop(listener: TcpListener, shared: Arc<TransportShared>) {
    while !shared.stopped.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, _)) => {
                let mut conns = shared.conns.lock().expect("conns lock");
                conns.retain(|c| !c.thread.is_finished());
                if shared.draining.load(Ordering::SeqCst) {
                    shared.metrics.inc(&shared.metrics.conns_rejected);
                    refuse(stream, CloseReason::Drain);
                } else if conns.len() >= shared.cfg.max_connections {
                    shared.metrics.inc(&shared.metrics.conns_rejected);
                    refuse(stream, CloseReason::Overloaded);
                } else {
                    match spawn_conn(stream, &shared) {
                        Ok(h) => {
                            shared.metrics.inc(&shared.metrics.conns_accepted);
                            conns.push(h);
                        }
                        Err(_) => shared.metrics.inc(&shared.metrics.conns_rejected),
                    }
                }
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(_) => std::thread::sleep(Duration::from_millis(5)),
        }
    }
}

/// Refuse a connection with a typed close frame, best-effort.
fn refuse(mut stream: TcpStream, reason: CloseReason) {
    let _ = stream.set_write_timeout(Some(Duration::from_millis(200)));
    let _ = stream.write_all(&wire::encode_server(&ServerFrame::Close { reason }));
    let _ = stream.shutdown(Shutdown::Both);
}

fn spawn_conn(stream: TcpStream, t: &Arc<TransportShared>) -> std::io::Result<ConnHandle> {
    stream.set_read_timeout(Some(t.cfg.poll))?;
    stream.set_write_timeout(Some(t.cfg.write_timeout))?;
    let _ = stream.set_nodelay(true);
    let (tx, rx) = mpsc::sync_channel::<Vec<u8>>(t.cfg.outbound_depth);
    let shared = Arc::new(ConnShared {
        dead: AtomicBool::new(false),
        closing: AtomicBool::new(false),
        live: Mutex::new(HashSet::new()),
        sock: stream.try_clone()?,
        out_tx: Mutex::new(Some(tx.clone())),
    });
    let writer_stream = stream.try_clone()?;
    let handle = {
        let shared2 = Arc::clone(&shared);
        let t2 = Arc::clone(t);
        std::thread::Builder::new().name("mpk-wire-conn".into()).spawn(move || {
            run_conn(stream, writer_stream, tx, rx, shared2, t2);
        })?
    };
    Ok(ConnHandle { shared, thread: handle })
}

// ---------------------------------------------------------------------------
// per-connection reader (the connection's owning thread)

fn run_conn(
    mut stream: TcpStream,
    writer_stream: TcpStream,
    tx: SyncSender<Vec<u8>>,
    rx: Receiver<Vec<u8>>,
    shared: Arc<ConnShared>,
    t: Arc<TransportShared>,
) {
    let out = Outbound {
        tx,
        policy: t.cfg.slow_reader,
        shared: Arc::clone(&shared),
        client: t.client.clone(),
        metrics: Arc::clone(&t.metrics),
    };
    let writer = {
        let shared = Arc::clone(&shared);
        let client = t.client.clone();
        let metrics = Arc::clone(&t.metrics);
        let inj = t.cfg.faults.is_armed().then(|| WireFaultInjector::new(t.cfg.faults));
        std::thread::Builder::new()
            .name("mpk-wire-writer".into())
            .spawn(move || writer_loop(writer_stream, rx, shared, client, metrics, inj))
            .expect("failed to spawn writer thread")
    };
    let mut pumps: Vec<JoinHandle<()>> = Vec::new();

    loop {
        if shared.dead.load(Ordering::SeqCst) || shared.closing.load(Ordering::SeqCst) {
            break;
        }
        match read_frame_server(&mut stream, &shared, &t.cfg) {
            ReadOutcome::Idle => continue,
            ReadOutcome::Stopped => break,
            ReadOutcome::Eof => break,
            ReadOutcome::Frame(body) => {
                t.metrics.inc(&t.metrics.frames_received);
                match wire::decode_client(&body) {
                    Ok(frame) => handle_frame(frame, &out, &shared, &t, &mut pumps),
                    Err(_) => {
                        t.metrics.inc(&t.metrics.protocol_errors);
                        out.send(&ServerFrame::Close { reason: CloseReason::Protocol });
                        teardown(&shared, &t.client);
                        break;
                    }
                }
            }
            ReadOutcome::Failed(err) => {
                t.metrics.inc(&t.metrics.protocol_errors);
                // framing violations get a typed goodbye; raw socket
                // errors usually mean nobody is listening anymore.
                if !matches!(err, TransportError::Io { .. }) {
                    out.send(&ServerFrame::Close { reason: CloseReason::Protocol });
                }
                teardown(&shared, &t.client);
                break;
            }
        }
    }

    // Epilogue. A connection that still has live requests here went
    // away mid-stream (EOF, reset, stall, teardown): cancel them so
    // their slots and KV free now.
    if !shared.live.lock().expect("live set lock").is_empty() {
        teardown(&shared, &t.client);
    }
    // Pumps end once their terminal event arrives (the cancels above
    // guarantee one) or the server goes away.
    for p in pumps {
        let _ = p.join();
    }
    // Drop every outbound sender we control; the writer's recv loop
    // ends after flushing whatever is queued.
    shared.out_tx.lock().expect("out_tx lock").take();
    drop(out);
    let _ = writer.join();
    let _ = stream.shutdown(Shutdown::Both);
    t.metrics.inc(&t.metrics.conns_closed);
}

fn handle_frame(
    frame: ClientFrame,
    out: &Outbound,
    shared: &Arc<ConnShared>,
    t: &Arc<TransportShared>,
    pumps: &mut Vec<JoinHandle<()>>,
) {
    match frame {
        ClientFrame::Submit { id, priority, deadline_ms, max_new_tokens, prompt } => {
            if t.draining.load(Ordering::SeqCst) {
                t.metrics.inc(&t.metrics.requests_rejected);
                out.send(&ServerFrame::Error { id, err: EngineError::ServerClosed });
                return;
            }
            let in_flight = shared.live.lock().expect("live set lock").len();
            if in_flight >= t.cfg.max_in_flight {
                // connection-level backpressure: same typed shed the
                // server's wait queue uses, scoped to this connection.
                t.metrics.inc(&t.metrics.requests_rejected);
                out.send(&ServerFrame::Shed { id, queue_depth: t.cfg.max_in_flight as u32 });
                return;
            }
            let opts = SubmitOptions { priority, deadline: deadline_ms.map(Duration::from_millis) };
            match t.client.submit_with(Request::new(id, prompt, max_new_tokens as usize), opts) {
                Ok(stream) => {
                    shared.live.lock().expect("live set lock").insert(id);
                    t.metrics.inc(&t.metrics.requests_submitted);
                    out.send(&ServerFrame::Accepted { id });
                    let pump_out = out.clone();
                    let pump_shared = Arc::clone(shared);
                    match std::thread::Builder::new()
                        .name("mpk-wire-pump".into())
                        .spawn(move || pump(stream, pump_out, pump_shared))
                    {
                        Ok(h) => pumps.push(h),
                        Err(_) => {
                            // thread spawn failed: free the request
                            // rather than letting it decode unread.
                            shared.live.lock().expect("live set lock").remove(&id);
                            let _ = t.client.cancel(id);
                            out.send(&ServerFrame::Error { id, err: EngineError::ServerClosed });
                        }
                    }
                }
                Err(EngineError::Overloaded { id, queue_depth }) => {
                    t.metrics.inc(&t.metrics.requests_rejected);
                    out.send(&ServerFrame::Shed { id, queue_depth: queue_depth as u32 });
                }
                Err(err) => {
                    t.metrics.inc(&t.metrics.requests_rejected);
                    out.send(&ServerFrame::Error { id, err });
                }
            }
        }
        ClientFrame::Cancel { id } => {
            if let Err(err) = t.client.cancel(id) {
                out.send(&ServerFrame::Error { id, err });
            }
            // on success the terminal Cancelled finish frame arrives
            // through the request's pump.
        }
        ClientFrame::Status => match t.client.status() {
            Ok(s) => {
                out.send(&ServerFrame::Status {
                    queued: s.queued as u32,
                    in_flight: s.in_flight as u32,
                    capacity: s.capacity as u32,
                    finished: s.finished as u64,
                    shed: s.shed as u64,
                    rejected: s.rejected as u64,
                    kv_blocks_total: s.kv.blocks_total,
                    kv_blocks_free: s.kv.blocks_free,
                    kv_blocks_shared: s.kv.blocks_shared,
                    kv_blocks_cowed: s.kv.blocks_cowed,
                    kv_prefix_hits: s.kv.prefix_hits,
                    kv_prefill_chunks: s.kv.prefill_chunks,
                });
            }
            Err(err) => {
                out.send(&ServerFrame::Error { id: 0, err });
            }
        },
    }
}

/// Forward one request's [`TokenStream`] to the wire until its single
/// terminal event; then release the id from the connection's live set.
fn pump(mut stream: TokenStream, out: Outbound, shared: Arc<ConnShared>) {
    let id = stream.id();
    loop {
        match stream.recv() {
            Ok(ev) => {
                let terminal = ev.finish.is_some();
                if let Some(reason) = ev.finish {
                    out.send(&ServerFrame::Finish { id, token: ev.token, reason });
                } else if let Some(token) = ev.token {
                    out.send(&ServerFrame::Token { id, token });
                }
                if terminal {
                    break;
                }
            }
            Err(_) => {
                // server gone without a terminal event (fatal path):
                // typed error so the client never hangs.
                out.send(&ServerFrame::Error { id, err: EngineError::ServerClosed });
                break;
            }
        }
    }
    shared.live.lock().expect("live set lock").remove(&id);
}

// ---------------------------------------------------------------------------
// writer

fn writer_loop(
    mut stream: TcpStream,
    rx: Receiver<Vec<u8>>,
    shared: Arc<ConnShared>,
    client: ServerClient,
    metrics: Arc<TransportMetrics>,
    mut inj: Option<WireFaultInjector>,
) {
    // runs until every sender (reader, pumps, the drain handle) is
    // gone — so a graceful close flushes everything queued, while a
    // teardown (`dead`) drains the queue without writing.
    while let Ok(mut bytes) = rx.recv() {
        if shared.dead.load(Ordering::SeqCst) {
            metrics.inc(&metrics.frames_dropped);
            continue;
        }
        match inj.as_mut().and_then(|i| i.draw(bytes.len())) {
            Some(WireFault::Drop) => {
                metrics.inc(&metrics.frames_dropped);
                teardown(&shared, &client);
                continue;
            }
            Some(WireFault::Truncate { keep }) => {
                let _ = stream.write_all(&bytes[..keep]);
                metrics.inc(&metrics.frames_dropped);
                teardown(&shared, &client);
                continue;
            }
            Some(WireFault::Corrupt { at }) => bytes[at] ^= 0x40,
            Some(WireFault::Delay(d)) => std::thread::sleep(d),
            None => {}
        }
        match stream.write_all(&bytes) {
            Ok(()) => metrics.inc(&metrics.frames_sent),
            Err(_) => {
                // write deadline or broken pipe: the peer stopped
                // draining — tear down and stop paying for it.
                metrics.inc(&metrics.frames_dropped);
                teardown(&shared, &client);
            }
        }
    }
}

// ---------------------------------------------------------------------------
// deadline-aware frame reading (server side)

enum ReadOutcome {
    Frame(Vec<u8>),
    /// No byte arrived within a poll tick (between frames) — loop and
    /// re-check flags.
    Idle,
    /// Peer closed cleanly at a frame boundary.
    Eof,
    /// Teardown/close flag flipped while blocked.
    Stopped,
    Failed(TransportError),
}

enum Fill {
    Done,
    Idle,
    Eof { got: usize },
    Stopped,
    Err(TransportError),
}

/// Fill `buf` from the socket, waking every poll tick to check the
/// connection flags. `idle_ok` is true only before the first byte of a
/// frame — past that, silence beyond `read_timeout` is a stall.
fn fill(stream: &mut TcpStream, buf: &mut [u8], shared: &ConnShared, cfg: &TransportConfig, idle_ok: bool) -> Fill {
    let mut got = 0usize;
    let mut stalled_since: Option<Instant> = None;
    while got < buf.len() {
        if shared.dead.load(Ordering::SeqCst) || shared.closing.load(Ordering::SeqCst) {
            return Fill::Stopped;
        }
        match stream.read(&mut buf[got..]) {
            Ok(0) => return Fill::Eof { got },
            Ok(n) => {
                got += n;
                stalled_since = None;
            }
            Err(e) if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut) => {
                if got == 0 && idle_ok {
                    return Fill::Idle;
                }
                let since = *stalled_since.get_or_insert_with(Instant::now);
                if since.elapsed() >= cfg.read_timeout {
                    return Fill::Err(TransportError::Stalled {
                        ms: cfg.read_timeout.as_millis() as u64,
                    });
                }
            }
            Err(e) if e.kind() == ErrorKind::Interrupted => {}
            Err(e) => return Fill::Err(e.into()),
        }
    }
    Fill::Done
}

fn read_frame_server(stream: &mut TcpStream, shared: &ConnShared, cfg: &TransportConfig) -> ReadOutcome {
    let mut prefix = [0u8; 4];
    match fill(stream, &mut prefix, shared, cfg, true) {
        Fill::Done => {}
        Fill::Idle => return ReadOutcome::Idle,
        Fill::Eof { got: 0 } => return ReadOutcome::Eof,
        Fill::Eof { got } => return ReadOutcome::Failed(TransportError::Truncated { want: 4, got }),
        Fill::Stopped => return ReadOutcome::Stopped,
        Fill::Err(e) => return ReadOutcome::Failed(e),
    }
    let len = match wire::check_len(prefix, cfg.max_frame) {
        Ok(len) => len,
        Err(e) => return ReadOutcome::Failed(e),
    };
    let mut body = vec![0u8; len];
    match fill(stream, &mut body, shared, cfg, false) {
        Fill::Done => ReadOutcome::Frame(body),
        Fill::Idle => unreachable!("idle_ok is false mid-frame"),
        Fill::Eof { got } => ReadOutcome::Failed(TransportError::Truncated { want: len, got }),
        Fill::Stopped => ReadOutcome::Stopped,
        Fill::Err(e) => ReadOutcome::Failed(e),
    }
}

// ---------------------------------------------------------------------------
// loopback client

/// A minimal synchronous wire client: connect, submit, read frames.
/// Used by `mpk serve --listen` for its loopback demo traffic, by the
/// benches, and (with [`TransportClient::with_faults`]) as the chaos
/// half of the transport tests. Not a production client — one blocking
/// socket, no reconnect.
pub struct TransportClient {
    stream: TcpStream,
    max_frame: u32,
    faults: Option<WireFaultInjector>,
}

impl TransportClient {
    /// Connect with a 10s default read/write deadline (see
    /// [`TransportClient::set_read_timeout`]).
    pub fn connect(addr: impl ToSocketAddrs) -> Result<TransportClient, TransportError> {
        let stream = TcpStream::connect(addr)?;
        let _ = stream.set_nodelay(true);
        stream.set_read_timeout(Some(Duration::from_secs(10)))?;
        stream.set_write_timeout(Some(Duration::from_secs(10)))?;
        Ok(TransportClient { stream, max_frame: DEFAULT_MAX_FRAME, faults: None })
    }

    /// Arm seeded chaos on this client's outbound frames (truncate /
    /// corrupt / delay / drop, per [`WireFaultPlan`]).
    pub fn with_faults(mut self, plan: WireFaultPlan) -> TransportClient {
        plan.validate().expect("invalid wire fault plan");
        self.faults = plan.is_armed().then(|| WireFaultInjector::new(plan));
        self
    }

    /// Adjust the blocking-read deadline (e.g. for deliberately
    /// stalled readers in tests).
    pub fn set_read_timeout(&self, d: Duration) -> Result<(), TransportError> {
        self.stream.set_read_timeout(Some(d))?;
        Ok(())
    }

    /// Send one frame, applying any armed fault first. An injected
    /// `Drop`/`Truncate` closes the socket and reports a typed error.
    pub fn send(&mut self, frame: &ClientFrame) -> Result<(), TransportError> {
        let mut bytes = wire::encode_client(frame);
        match self.faults.as_mut().and_then(|i| i.draw(bytes.len())) {
            Some(WireFault::Drop) => {
                let _ = self.stream.shutdown(Shutdown::Both);
                return Err(TransportError::Io { what: "injected connection drop".into() });
            }
            Some(WireFault::Truncate { keep }) => {
                let _ = self.stream.write_all(&bytes[..keep]);
                let _ = self.stream.shutdown(Shutdown::Both);
                return Err(TransportError::Io { what: "injected truncated frame".into() });
            }
            Some(WireFault::Corrupt { at }) => bytes[at] ^= 0x40,
            Some(WireFault::Delay(d)) => std::thread::sleep(d),
            None => {}
        }
        self.stream.write_all(&bytes)?;
        Ok(())
    }

    /// Submit a request (fire-and-forget; the reply arrives as an
    /// `Accepted`/`Shed`/`Error` frame via [`TransportClient::next_frame`]).
    pub fn submit(
        &mut self,
        id: u64,
        prompt: Vec<i32>,
        max_new_tokens: u32,
        opts: SubmitOptions,
    ) -> Result<(), TransportError> {
        self.send(&ClientFrame::Submit {
            id,
            priority: opts.priority,
            deadline_ms: opts.deadline.map(|d| d.as_millis() as u64),
            max_new_tokens,
            prompt,
        })
    }

    /// Ask the server to cancel a live request.
    pub fn cancel(&mut self, id: u64) -> Result<(), TransportError> {
        self.send(&ClientFrame::Cancel { id })
    }

    /// Request a status snapshot (answered by a `Status` frame).
    pub fn request_status(&mut self) -> Result<(), TransportError> {
        self.send(&ClientFrame::Status)
    }

    /// Read the next server frame; `Ok(None)` on a clean EOF at a
    /// frame boundary.
    pub fn next_frame(&mut self) -> Result<Option<ServerFrame>, TransportError> {
        let mut prefix = [0u8; 4];
        let mut got = 0usize;
        while got < 4 {
            match self.stream.read(&mut prefix[got..]) {
                Ok(0) => {
                    if got == 0 {
                        return Ok(None);
                    }
                    return Err(TransportError::Truncated { want: 4, got });
                }
                Ok(n) => got += n,
                Err(e) if e.kind() == ErrorKind::Interrupted => {}
                Err(e) => return Err(e.into()),
            }
        }
        let len = wire::check_len(prefix, self.max_frame)?;
        let mut body = vec![0u8; len];
        let mut got = 0usize;
        while got < len {
            match self.stream.read(&mut body[got..]) {
                Ok(0) => return Err(TransportError::Truncated { want: len, got }),
                Ok(n) => got += n,
                Err(e) if e.kind() == ErrorKind::Interrupted => {}
                Err(e) => return Err(e.into()),
            }
        }
        Ok(Some(wire::decode_server(&body)?))
    }

    /// Submit one request and block until its terminal frame: the
    /// tokens generated plus the typed [`FinishReason`]. Typed
    /// failures come back as the same [`EngineError`] values an
    /// in-process caller gets (`Shed` frames as
    /// [`EngineError::Overloaded`], `Close` frames as
    /// [`EngineError::Transport`]).
    pub fn run(
        &mut self,
        id: u64,
        prompt: Vec<i32>,
        max_new_tokens: u32,
        opts: SubmitOptions,
    ) -> Result<(Vec<i32>, FinishReason), EngineError> {
        self.submit(id, prompt, max_new_tokens, opts)?;
        let mut tokens = Vec::new();
        loop {
            match self.next_frame()? {
                None => {
                    return Err(TransportError::Io {
                        what: "connection closed before the terminal frame".into(),
                    }
                    .into())
                }
                Some(ServerFrame::Token { id: tid, token }) if tid == id => tokens.push(token),
                Some(ServerFrame::Finish { id: fid, token, reason }) if fid == id => {
                    if let Some(t) = token {
                        tokens.push(t);
                    }
                    return Ok((tokens, reason));
                }
                Some(ServerFrame::Error { id: eid, err }) if eid == id || eid == 0 => {
                    return Err(err);
                }
                Some(ServerFrame::Shed { id: sid, queue_depth }) if sid == id => {
                    return Err(EngineError::Overloaded { id, queue_depth: queue_depth as usize });
                }
                Some(ServerFrame::Close { reason }) => {
                    return Err(TransportError::Closed { reason }.into());
                }
                // frames for other requests multiplexed on this
                // connection, or the Accepted ack: skip.
                Some(_) => {}
            }
        }
    }

    /// Drop the connection abruptly — no goodbye, no reads. The
    /// disconnect-mid-stream path in one call.
    pub fn abort(self) {
        let _ = self.stream.shutdown(Shutdown::Both);
    }
}
