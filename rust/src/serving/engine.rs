//! The serving engine: continuous batching over the real-numerics
//! megakernel (§6.1).
//!
//! Per decode iteration: retire/admit (the paper's start-event task),
//! pick the batch-size-specialized tGraph (powers of two), stage each
//! active request's KV rows and input token into that graph's store,
//! run the mega-kernel once, then harvest logits (greedy decoding) and
//! updated KV rows back into per-request state.

use crate::exec::binder::TileExecutor;
use crate::exec::real::{self, compile_real, init_weights};
use crate::exec::store::TensorStore;
use crate::megakernel::{MegaConfig, MegaKernel};
use crate::ops::Region;
use crate::runtime::pool::ExecPool;
use crate::runtime::Manifest;
use crate::serving::batcher::{Batcher, Request};
use crate::serving::kvcache::KvAllocator;
use crate::tgraph::CompiledGraph;
use std::collections::HashMap;
use std::time::{Duration, Instant};

/// One batch-size specialization: compiled graph + its tensor store.
struct Session {
    compiled: CompiledGraph,
    store: TensorStore,
}

/// Per-request physical KV rows ([S_MAX × kv_dim] per layer).
struct ReqCache {
    k: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
}

/// Serving statistics.
#[derive(Clone, Debug, Default)]
pub struct ServeStats {
    pub iterations: usize,
    pub tokens_generated: usize,
    pub total: Duration,
    pub iter_latencies: Vec<Duration>,
    /// Tokens in flight per iteration (batch-utilization curve).
    pub batch_sizes: Vec<usize>,
}

impl ServeStats {
    pub fn throughput_tok_s(&self) -> f64 {
        self.tokens_generated as f64 / self.total.as_secs_f64().max(1e-9)
    }

    pub fn p50_latency(&self) -> Duration {
        let mut v = self.iter_latencies.clone();
        if v.is_empty() {
            return Duration::ZERO;
        }
        v.sort();
        v[v.len() / 2]
    }
}

/// The engine.
pub struct ServeEngine {
    pub manifest: Manifest,
    pool: ExecPool,
    sessions: HashMap<usize, Session>,
    pub batcher: Batcher,
    caches: HashMap<u64, ReqCache>,
    mega: MegaConfig,
}

impl ServeEngine {
    /// Build an engine with specialized graphs for each manifest batch
    /// size. `max_batch` must be one of the manifest's batch sizes.
    pub fn create(max_batch: usize, pool_threads: usize, seed: u64, mega: MegaConfig) -> Result<Self, String> {
        let manifest = Manifest::load(&Manifest::default_dir())?;
        if !manifest.batch_sizes.contains(&max_batch) {
            return Err(format!("max_batch {max_batch} not among specialized sizes {:?}", manifest.batch_sizes));
        }
        let mut sessions = HashMap::new();
        for &b in manifest.batch_sizes.iter().filter(|&&b| b <= max_batch) {
            let compiled = compile_real(&manifest, b);
            let store = TensorStore::new(&compiled.graph);
            init_weights(&compiled.graph, &store, seed);
            sessions.insert(b, Session { compiled, store });
        }
        let pool = ExecPool::new(manifest.clone(), pool_threads)?;
        // one KV block = 8 tokens; pool sized for max_batch full seqs.
        let blocks = max_batch * manifest.s_max / 8;
        let batcher = Batcher::new(max_batch, manifest.s_max, KvAllocator::new(blocks, 8));
        Ok(ServeEngine { manifest, pool, sessions, batcher, caches: HashMap::new(), mega })
    }

    pub fn submit(&mut self, r: Request) {
        self.batcher.submit(r);
    }

    /// Drive everything to completion; returns per-request outputs and
    /// stats. Deterministic: greedy decoding, seeded weights.
    pub fn serve(&mut self) -> Result<(HashMap<u64, Vec<i32>>, ServeStats), String> {
        let mut stats = ServeStats::default();
        let t0 = Instant::now();
        let m = self.manifest.model;
        let (s_max, kv_dim, vocab) = (self.manifest.s_max, m.kv_dim(), m.vocab);

        while self.batcher.has_work() {
            for id in self.batcher.step_admission() {
                self.caches.remove(&id);
            }
            let active = self.batcher.active.len();
            if active == 0 {
                break;
            }
            let gb = self.batcher.graph_batch();
            let session = self.sessions.get(&gb).ok_or(format!("no session for batch {gb}"))?;
            let g = &session.compiled.graph;
            let store = &session.store;

            // stage inputs: ids, per-row lens, KV rows.
            let mut ids = vec![0i32; gb];
            let mut lens = vec![0usize; gb];
            for (slot, r) in self.batcher.active.iter().enumerate() {
                ids[slot] = r.next_input();
                lens[slot] = r.cache_len;
                let cache = self.caches.entry(r.id).or_insert_with(|| ReqCache {
                    k: vec![vec![0.0; s_max * kv_dim]; m.layers],
                    v: vec![vec![0.0; s_max * kv_dim]; m.layers],
                });
                for l in 0..m.layers {
                    let kt = g.tensor_by_name(&format!("l{l}.kcache")).unwrap().id;
                    let vt = g.tensor_by_name(&format!("l{l}.vcache")).unwrap().id;
                    let row = Region::new(vec![(slot, slot + 1), (0, s_max), (0, kv_dim)]);
                    store.write_tile(kt, &row, &cache.k[l]);
                    store.write_tile(vt, &row, &cache.v[l]);
                }
            }
            real::set_ids(g, store, &ids);

            // run the mega-kernel once.
            let kernel = MegaKernel::new(&session.compiled, self.mega);
            let exec = TileExecutor::new(g, store, &self.pool, gb);
            exec.set_row_lens(&lens);
            let it0 = Instant::now();
            kernel.run(&exec)?;
            if let Some(e) = exec.take_error() {
                return Err(e);
            }
            let lat = it0.elapsed();
            stats.iterations += 1;
            stats.iter_latencies.push(lat);
            stats.batch_sizes.push(active);

            // harvest: logits → next token; cache rows → request state.
            let logits = real::get_logits(g, store);
            for slot in 0..active {
                let r = &mut self.batcher.active[slot];
                let cache = self.caches.get_mut(&r.id).unwrap();
                for l in 0..m.layers {
                    let kt = g.tensor_by_name(&format!("l{l}.kcache")).unwrap().id;
                    let vt = g.tensor_by_name(&format!("l{l}.vcache")).unwrap().id;
                    let row = Region::new(vec![(slot, slot + 1), (0, s_max), (0, kv_dim)]);
                    cache.k[l] = store.read_tile(kt, &row);
                    cache.v[l] = store.read_tile(vt, &row);
                }
                r.cache_len += 1;
                let tok = real::argmax(&logits[slot * vocab..(slot + 1) * vocab]) as i32;
                if r.in_prefill() {
                    r.prompt_pos += 1;
                    if !r.in_prefill() {
                        r.generated.push(tok);
                        stats.tokens_generated += 1;
                    }
                } else {
                    r.generated.push(tok);
                    stats.tokens_generated += 1;
                }
            }
        }
        stats.total = t0.elapsed();
        let outputs = self
            .batcher
            .finished
            .iter()
            .map(|r| (r.id, r.generated.clone()))
            .collect();
        Ok((outputs, stats))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn have_artifacts() -> bool {
        Manifest::load(&Manifest::default_dir()).is_ok()
    }

    fn mega() -> MegaConfig {
        MegaConfig { workers: 4, schedulers: 1, ..Default::default() }
    }

    #[test]
    fn serves_batch_to_completion() {
        if !have_artifacts() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let mut e = ServeEngine::create(4, 2, 42, mega()).unwrap();
        for i in 0..3u64 {
            e.submit(Request::new(i, vec![(i as i32) + 1, 7], 4));
        }
        let (out, stats) = e.serve().unwrap();
        assert_eq!(out.len(), 3);
        for (_, toks) in &out {
            assert_eq!(toks.len(), 4);
            for &t in toks {
                assert!((0..512).contains(&t));
            }
        }
        assert_eq!(stats.tokens_generated, 12);
        assert!(stats.iterations >= 5, "prompt 2 + gen 4 - 1 overlap");
    }

    #[test]
    fn greedy_decode_is_deterministic() {
        if !have_artifacts() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let run = || {
            let mut e = ServeEngine::create(2, 2, 9, mega()).unwrap();
            e.submit(Request::new(0, vec![5, 6, 7], 5));
            e.serve().unwrap().0
        };
        assert_eq!(run()[&0], run()[&0]);
    }

    #[test]
    fn staggered_admission_continuous_batching() {
        if !have_artifacts() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        // more requests than slots: later ones admitted as earlier retire.
        let mut e = ServeEngine::create(2, 2, 11, mega()).unwrap();
        for i in 0..5u64 {
            e.submit(Request::new(i, vec![1 + i as i32], 2 + (i as usize % 2)));
        }
        let (out, stats) = e.serve().unwrap();
        assert_eq!(out.len(), 5);
        for (id, toks) in &out {
            assert_eq!(toks.len(), 2 + (*id as usize % 2), "req {id}");
        }
        // batch ramps: some iterations ran with 2 active requests.
        assert!(stats.batch_sizes.iter().any(|&b| b == 2));
    }

    #[test]
    fn single_request_matches_single_session_decode() {
        if !have_artifacts() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        // engine output for one request == direct RealSession loop.
        let mut e = ServeEngine::create(1, 2, 42, mega()).unwrap();
        e.submit(Request::new(0, vec![7], 3));
        let (out, _) = e.serve().unwrap();

        let s = crate::exec::real::RealSession::create(1, 2, 42).unwrap();
        let kernel = MegaKernel::new(&s.compiled, mega());
        let exec = TileExecutor::new(&s.compiled.graph, &s.store, &s.pool, 1);
        let mut ids = vec![7i32];
        let mut got = Vec::new();
        for step in 0..4 {
            real::set_ids(&s.compiled.graph, &s.store, &ids);
            crate::exec::real::run_iteration(&kernel, &exec, step).unwrap();
            let logits = real::get_logits(&s.compiled.graph, &s.store);
            let tok = real::argmax(&logits) as i32;
            if step >= 0 {
                got.push(tok);
            }
            ids = vec![tok];
        }
        // prompt len 1 → first iteration already yields generated[0].
        assert_eq!(out[&0], got[..3].to_vec());
    }
}
