//! The serving engine: a **step-driven streaming API** over the real-
//! numerics megakernel (§6.1), with a persistent runtime, resident KV,
//! stable batch slots, and a zero-copy decode hot path.
//!
//! # Lifecycle: build → submit → step → stream
//!
//! An engine is configured through [`EngineBuilder`] (named, validated
//! settings — batch ceiling, pool threads, seed, kernel shape, optional
//! EOS token, opt-in compaction) and then *driven* one decode iteration
//! at a time with [`ServeEngine::step`]: retire/admit → pick
//! specialization → stage by slot → re-arm the resident kernel →
//! harvest. Each step returns a [`StepOutcome`] carrying a
//! [`TokenEvent`] per request that produced a token (with a
//! [`FinishReason`] on its terminal event), so callers stream tokens as
//! they are decoded. Between steps the live set may change:
//! [`ServeEngine::submit`] queues new requests that admit into stable
//! slots at the next step (online admission), and
//! [`ServeEngine::cancel`] retires a request immediately — its slot and
//! KV blocks are free for the very next admission, and its terminal
//! `Cancelled` event rides the next outcome. [`ServeEngine::serve`]
//! survives as the batch-mode compat loop: drive `step()` until idle,
//! return per-request outputs plus the stats window.
//!
//! # The hot path underneath
//!
//! Each batch-size specialization is a long-lived [`Session`]: a tensor
//! arena holding activations, a [`PersistentMegaKernel`] whose
//! worker/scheduler threads park between iterations, a resident
//! `OwningTileExecutor`, and tensor ids resolved once at creation. All
//! sessions alias **one shared max-batch [`KvArena`]** for their KV
//! cache tensors and **one shared [`WeightArena`]** for their parameter
//! tensors — switching specializations re-interprets the same memory.
//! A request keeps its slot from admission to retirement, so no code
//! path moves KV rows implicitly: `kv_rows_migrated` stays structurally
//! zero unless the **opt-in** anti-fragmentation pass deliberately
//! relocates one request to drop the specialized graph a whole power of
//! two (every moved row is counted). The newly appended KV row is
//! written in-kernel by `KvAppend`; the engine never copies a tensor on
//! the decode path (asserted via the store's read-side counters), and
//! task results land directly in their destination arena tensors
//! through the pool's write-into boundary (`execute_into`) — the pool's
//! `output_allocs` counter stays at zero.
//!
//! Every fallible operation returns a typed [`EngineError`]; the
//! `exec`/`runtime`/`megakernel` boundary errors convert through `From`
//! shims (see `serving::error`).
//!
//! # Fault tolerance
//!
//! A failed epoch — watchdog timeout, executor panic, a task body
//! failing mid-epoch, or an injected fault from a builder-configured
//! [`FaultPlan`] — does **not** kill the step. [`ServeEngine::step`]
//! retries the epoch against the *same* resident kernel (arming drains
//! the stale queues, and a retried epoch is idempotent: staging inputs
//! are rewritten from request state that only advances at harvest, and
//! the KV row position is derived from that same state) with bounded
//! exponential backoff. When the retry budget is spent and the failures
//! are attributable to one request, that request is quarantined — a
//! terminal [`FinishReason::Failed`] event, every other request keeping
//! its slot and KV — and the step continues with the survivors. Only a
//! persistent *unattributable* failure surfaces as an error; the engine
//! is never torn down or rebuilt. See [`crate::serving::fault`].

use crate::exec::binder::{OwningTileExecutor, PagedKvMap};
use crate::exec::real::{self, compile_real, WeightArena};
use crate::exec::store::TensorStore;
use crate::megakernel::{MegaConfig, PersistentMegaKernel};
use crate::metrics::KvPoolStats;
use crate::ops::TensorId;
use crate::runtime::backend::BackendKind;
use crate::runtime::pool::ExecPool;
use crate::runtime::Manifest;
use crate::serving::batcher::{Batcher, Request};
use crate::serving::error::EngineError;
use crate::serving::fault::{Fault, FaultInjector, FaultPlan, Recovery, RecoveryAction};
use crate::serving::kvcache::{KvAllocator, KvArena, KvResidency};
use crate::serving::paged::{Append, PagedKvPool};
use crate::serving::step::{FinishReason, StepOutcome, TokenEvent};
use std::collections::HashMap;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// One batch-size specialization: tensor arena (activations only — KV
/// and weights aliased into the shared arenas), the persistent kernel,
/// the resident executor, and hot-path tensor ids resolved once at
/// creation.
struct Session {
    store: Arc<TensorStore>,
    kernel: PersistentMegaKernel,
    exec: OwningTileExecutor,
    token_ids: TensorId,
    logits: TensorId,
}

/// Per-request latency record: admission → first token, admission →
/// terminal event. `ttft` is `None` for a request that never produced
/// a token; `completion` is `None` while the request is still in
/// flight — and both stay `None` for a request cancelled out of the
/// waiting queue (it was never admitted, so there is no admission to
/// measure from; the record still exists, so every terminated request
/// is accounted for).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct RequestLatency {
    pub ttft: Option<Duration>,
    pub completion: Option<Duration>,
}

/// In-flight clock for an admitted request (engine-internal).
struct RequestClock {
    admitted: Instant,
    ttft: Option<Duration>,
}

/// Serving statistics for one stats window (reset by
/// [`ServeEngine::take_stats`]; [`ServeEngine::serve`] reports one
/// window per call).
#[derive(Clone, Debug, Default)]
pub struct ServeStats {
    pub iterations: usize,
    pub tokens_generated: usize,
    /// Wall-clock span of the window: first `step()` to the end of the
    /// latest one — includes any caller-side gaps between steps.
    pub total: Duration,
    /// Time actually spent inside `step()`. Throughput is computed
    /// against this, so a streaming caller that sleeps between steps
    /// does not see its throughput collapse toward zero.
    pub busy: Duration,
    pub iter_latencies: Vec<Duration>,
    /// Tokens in flight per iteration (batch-utilization curve).
    pub batch_sizes: Vec<usize>,
    /// K/V rows moved within the shared max-batch arena, summed over
    /// layers. With stable slots this is structurally zero — requests
    /// keep their slot from admission to retirement — except for the
    /// opt-in anti-fragmentation pass, whose single deliberate
    /// relocation per step is counted here honestly. With compaction
    /// off the tests assert it stays 0.
    pub kv_rows_migrated: usize,
    /// Per-request latency keyed by request id: admission → first
    /// token (TTFT) and admission → terminal event (completion).
    pub request_latency: HashMap<u64, RequestLatency>,
    /// Epoch attempts that failed — genuine or injected — and went
    /// through the recovery path (retry / quarantine / surface). Zero
    /// in healthy operation.
    pub faulted_epochs: usize,
    /// Requests retired with a terminal [`FinishReason::Failed`] by the
    /// quarantine path: repeated epoch failures were attributed to
    /// them, so the engine sacrificed them to keep the batch serving.
    pub requests_quarantined: usize,
    /// Paged mode: free blocks in the pool after the window's latest
    /// step (instantaneous gauge; 0 in legacy slot-contiguous mode).
    pub kv_blocks_free: u64,
    /// Paged mode: peak count of blocks referenced more than once
    /// (prefix sharing) observed during the window.
    pub kv_blocks_shared: u64,
    /// Paged mode: copy-on-write block copies performed during the
    /// window — the one honest, counted exception to the zero-copy
    /// decode invariant (a write landing in a shared block pays
    /// exactly one block copy).
    pub kv_blocks_cowed: u64,
    /// Paged mode: extra prefill epochs run by the chunked-prefill
    /// scheduler during the window (see
    /// [`EngineBuilder::prefill_chunk`]).
    pub prefill_chunks: u64,
}

impl ServeStats {
    /// Decode throughput over **busy** time (time inside `step()`), not
    /// wall clock — see [`ServeStats::busy`].
    pub fn throughput_tok_s(&self) -> f64 {
        self.tokens_generated as f64 / self.busy.as_secs_f64().max(1e-9)
    }

    /// Nearest-rank quantile via `select_nth_unstable` — O(n), no full
    /// sort. Takes the sample vector by value because selection
    /// reorders in place.
    ///
    /// Nearest-rank definition: the smallest sample ≥ the requested
    /// fraction of the distribution, i.e. rank `⌈q·n⌉` (1-based). The
    /// earlier `floor((n-1)·q)` indexing under-reported tail quantiles
    /// — e.g. p99 of 10 samples picked the 9th, not the 10th.
    fn nearest_rank(mut v: Vec<Duration>, q: f64) -> Duration {
        let n = v.len();
        if n == 0 {
            return Duration::ZERO;
        }
        let rank = (q * n as f64).ceil() as usize;
        let idx = rank.clamp(1, n) - 1;
        let (_, nth, _) = v.select_nth_unstable(idx);
        *nth
    }

    fn latency_quantile(&self, q: f64) -> Duration {
        Self::nearest_rank(self.iter_latencies.clone(), q)
    }

    pub fn p50_latency(&self) -> Duration {
        self.latency_quantile(0.50)
    }

    pub fn p99_latency(&self) -> Duration {
        self.latency_quantile(0.99)
    }

    fn ttft_samples(&self) -> Vec<Duration> {
        self.request_latency.values().filter_map(|l| l.ttft).collect()
    }

    fn completion_samples(&self) -> Vec<Duration> {
        self.request_latency.values().filter_map(|l| l.completion).collect()
    }

    /// Time-to-first-token quantile across this window's requests
    /// (admission → first [`TokenEvent`]), nearest-rank.
    pub fn ttft_quantile(&self, q: f64) -> Duration {
        Self::nearest_rank(self.ttft_samples(), q)
    }

    pub fn ttft_p50(&self) -> Duration {
        self.ttft_quantile(0.50)
    }

    pub fn ttft_p99(&self) -> Duration {
        self.ttft_quantile(0.99)
    }

    /// Completion-latency quantile across this window's requests
    /// (admission → terminal event), nearest-rank.
    pub fn completion_quantile(&self, q: f64) -> Duration {
        Self::nearest_rank(self.completion_samples(), q)
    }

    pub fn completion_p50(&self) -> Duration {
        self.completion_quantile(0.50)
    }

    pub fn completion_p99(&self) -> Duration {
        self.completion_quantile(0.99)
    }
}

/// Named, validated engine configuration — the only way to build a
/// [`ServeEngine`]. Config errors surface as
/// [`EngineError::InvalidConfig`] *before* any resource (manifest,
/// pool, threads, arenas) is touched.
///
/// ```no_run
/// use mpk::megakernel::MegaConfig;
/// use mpk::serving::ServeEngine;
///
/// let engine = ServeEngine::builder()
///     .max_batch(8)
///     .pool_threads(3)
///     .seed(42)
///     .mega(MegaConfig { workers: 6, schedulers: 2, ..Default::default() })
///     .eos_token(2)
///     .build()
///     .expect("engine build failed");
/// # let _ = engine;
/// ```
#[derive(Clone, Copy, Debug)]
pub struct EngineBuilder {
    max_batch: usize,
    pool_threads: usize,
    seed: u64,
    mega: MegaConfig,
    eos_token: Option<i32>,
    compaction: bool,
    step_retries: usize,
    retry_backoff: Duration,
    faults: FaultPlan,
    backend: BackendKind,
    paged_kv: bool,
    kv_block_tokens: usize,
    prefill_chunk: usize,
}

impl Default for EngineBuilder {
    fn default() -> Self {
        EngineBuilder {
            max_batch: 4,
            pool_threads: 2,
            seed: 42,
            mega: MegaConfig::default(),
            eos_token: None,
            compaction: false,
            step_retries: 2,
            retry_backoff: Duration::ZERO,
            faults: FaultPlan::default(),
            backend: BackendKind::from_env(),
            paged_kv: false,
            kv_block_tokens: 8,
            prefill_chunk: 0,
        }
    }
}

impl EngineBuilder {
    pub fn new() -> Self {
        Self::default()
    }

    /// Batch ceiling; must be one of the manifest's specialized sizes.
    pub fn max_batch(mut self, b: usize) -> Self {
        self.max_batch = b;
        self
    }

    /// Executor threads shared by every session.
    pub fn pool_threads(mut self, n: usize) -> Self {
        self.pool_threads = n;
        self
    }

    /// Execution backend (default: `MPK_BACKEND`, falling back to the
    /// native CPU backend — which needs no artifacts dir and no PJRT
    /// library, so an engine builds in a bare container).
    pub fn backend(mut self, kind: BackendKind) -> Self {
        self.backend = kind;
        self
    }

    /// Weight-synthesis seed (greedy decoding is deterministic per seed).
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Mega-kernel shape (workers / schedulers / watchdog timeout).
    pub fn mega(mut self, mega: MegaConfig) -> Self {
        self.mega = mega;
        self
    }

    /// Watchdog timeout for a single mega-kernel epoch — convenience
    /// over [`EngineBuilder::mega`] for callers that only tune the
    /// timeout. This bounds one *epoch*; per-request deadlines are the
    /// server front-end's job (scheduled terminations between steps).
    /// Must be nonzero; validated at [`EngineBuilder::build`].
    pub fn kernel_timeout(mut self, timeout: Duration) -> Self {
        self.mega.timeout = timeout;
        self
    }

    /// Retry budget for a failed epoch before recovery escalates to
    /// quarantine (attributable failures) or a surfaced error
    /// (unattributable). Default 2 — three attempts total per step.
    pub fn step_retries(mut self, n: usize) -> Self {
        self.step_retries = n;
        self
    }

    /// Base backoff slept before an epoch retry, doubling per
    /// consecutive failure up to an internal 100 ms cap. Default zero
    /// (retry immediately — right for tests and for failures that are
    /// not load-induced). Capped at 1 s by validation: the serving
    /// thread sleeps this, so a large value would stall every request.
    pub fn retry_backoff(mut self, backoff: Duration) -> Self {
        self.retry_backoff = backoff;
        self
    }

    /// Deterministic fault injection (chaos testing, off by default):
    /// seed-driven kernel/task failure rates and an optional poison
    /// request id. See [`FaultPlan`]. Injected failures exercise the
    /// *production* retry/quarantine path — nothing else in the engine
    /// knows injection exists.
    pub fn faults(mut self, plan: FaultPlan) -> Self {
        self.faults = plan;
        self
    }

    /// Optional end-of-sequence token: a request that decodes it stops
    /// with [`FinishReason::Eos`] (the EOS token is included in its
    /// output). Off by default.
    pub fn eos_token(mut self, tok: i32) -> Self {
        self.eos_token = Some(tok);
        self
    }

    /// Opt-in paged KV cache (off by default): block-granular
    /// allocation over the shared arena, copy-on-write prefix sharing
    /// across requests, and on-demand decode growth — admission
    /// reserves prompt-length blocks only, so short prompts with long
    /// generation budgets stop over-reserving. Requires the CPU
    /// backend (the artifact attention kernel cannot gather scattered
    /// cache blocks) and excludes [`EngineBuilder::compaction`] (slot
    /// compaction is the legacy anti-fragmentation pass — with paging
    /// there are no slot-contiguous rows to defragment). See
    /// [`crate::serving::paged`].
    pub fn paged_kv(mut self, on: bool) -> Self {
        self.paged_kv = on;
        self
    }

    /// Tokens per KV block in paged mode (default 8). Must be nonzero
    /// and divide the manifest's `s_max`; validated at
    /// [`EngineBuilder::build`]. Ignored with paging off.
    pub fn kv_block_tokens(mut self, bt: usize) -> Self {
        self.kv_block_tokens = bt;
        self
    }

    /// Chunked-prefill budget: up to this many *extra* kernel epochs
    /// per [`ServeEngine::step`] spent advancing prompts that are
    /// still deep in prefill (0 = off, the default; requires
    /// [`EngineBuilder::paged_kv`]). Long prompts reach their first
    /// token in `prompt_len / (chunk + 1)` steps instead of
    /// `prompt_len`, while concurrent decoders keep emitting exactly
    /// one token per step — extra epochs re-stage them idempotently
    /// and discard their logits.
    pub fn prefill_chunk(mut self, epochs: usize) -> Self {
        self.prefill_chunk = epochs;
        self
    }

    /// Opt-in anti-fragmentation compaction (off by default): when
    /// retirements leave the occupied slot bound a whole power of two
    /// above what one relocation would achieve, move exactly one
    /// request (highest slot → lowest free slot) per step, paying a
    /// bounded `KvArena::move_slot` that is counted in
    /// `kv_rows_migrated`. Off, the engine never moves a KV row.
    pub fn compaction(mut self, on: bool) -> Self {
        self.compaction = on;
        self
    }

    /// Validate the configuration, then build the engine: specialized
    /// sessions (graph + arena + persistent kernel + resident executor)
    /// for each manifest batch size up to `max_batch`, all aliasing one
    /// max-batch KV arena and one weight arena (weights synthesized
    /// exactly once, here).
    pub fn build(self) -> Result<ServeEngine, EngineError> {
        // config validation first: these fail without touching any
        // resource (no manifest read, no threads, no arenas).
        if self.max_batch == 0 {
            return Err(EngineError::InvalidConfig("max_batch must be >= 1".into()));
        }
        if self.pool_threads == 0 {
            return Err(EngineError::InvalidConfig("pool_threads must be >= 1".into()));
        }
        self.mega.validate().map_err(EngineError::InvalidConfig)?;
        self.faults.validate().map_err(EngineError::InvalidConfig)?;
        if self.retry_backoff > Duration::from_secs(1) {
            return Err(EngineError::InvalidConfig(format!(
                "retry_backoff {:?} above 1s would stall the serving thread",
                self.retry_backoff
            )));
        }
        if self.paged_kv {
            if !matches!(self.backend, BackendKind::Cpu) {
                return Err(EngineError::InvalidConfig(
                    "paged_kv requires the CPU backend: the fixed-shape attention \
                     artifact cannot gather a block-scattered cache"
                        .into(),
                ));
            }
            if self.compaction {
                return Err(EngineError::InvalidConfig(
                    "paged_kv excludes compaction: slot compaction is the legacy \
                     anti-fragmentation pass and has no slot-contiguous rows to move"
                        .into(),
                ));
            }
            if self.kv_block_tokens == 0 {
                return Err(EngineError::InvalidConfig("kv_block_tokens must be >= 1".into()));
            }
        } else if self.prefill_chunk > 0 {
            return Err(EngineError::InvalidConfig(
                "prefill_chunk requires paged_kv: chunked prefill stages KV through \
                 block tables"
                    .into(),
            ));
        }
        let manifest = Manifest::resolve(&Manifest::default_dir(), self.backend)?;
        if self.paged_kv && manifest.s_max % self.kv_block_tokens != 0 {
            return Err(EngineError::InvalidConfig(format!(
                "kv_block_tokens {} must divide s_max {}",
                self.kv_block_tokens, manifest.s_max
            )));
        }
        if !manifest.batch_sizes.contains(&self.max_batch) {
            return Err(EngineError::InvalidConfig(format!(
                "max_batch {} not among specialized sizes {:?}",
                self.max_batch, manifest.batch_sizes
            )));
        }
        if let Some(eos) = self.eos_token {
            if eos < 0 || eos as usize >= manifest.model.vocab {
                return Err(EngineError::InvalidConfig(format!(
                    "eos_token {eos} outside vocab 0..{}",
                    manifest.model.vocab
                )));
            }
        }
        let m = manifest.model;
        let pool = Arc::new(ExecPool::with_backend(manifest.clone(), self.pool_threads, self.backend)?);
        let kv_arena = KvArena::new(m.layers, self.max_batch, manifest.s_max, m.kv_dim());
        let mut specs: Vec<(usize, Arc<crate::tgraph::CompiledGraph>)> = Vec::new();
        for &b in manifest.batch_sizes.iter().filter(|&&b| b <= self.max_batch) {
            // a manifest/model mismatch degrades into EngineError here
            // instead of panicking the builder.
            specs.push((b, Arc::new(compile_real(&manifest, b)?)));
        }
        // one shared weight arena, initialized once: params are
        // batch-independent and name-seeded, so every specialization
        // aliases the same values instead of re-synthesizing them.
        let (_, max_compiled) =
            specs.iter().find(|(b, _)| *b == self.max_batch).expect("max_batch spec compiled");
        let weights = WeightArena::build(&max_compiled.graph);
        weights.init(&max_compiled.graph, self.seed);
        let mut sessions = HashMap::new();
        for (b, compiled) in specs {
            // hoist every per-iteration name lookup to creation time.
            let id = |name: &str| -> Result<TensorId, EngineError> {
                Ok(compiled
                    .graph
                    .tensor_by_name(name)
                    .ok_or_else(|| EngineError::Manifest(format!("missing tensor {name} in compiled graph")))?
                    .id)
            };
            // alias this session's KV tensors into the shared KV arena
            // (a batch-b cache tensor [b, s_max, kv_dim] is the first b
            // slots of the layer's [max_batch, s_max, kv_dim] segment)
            // and its param tensors into the shared weight arena.
            let mut aliases = weights.aliases_for(&compiled.graph);
            let mut kv_bases = Vec::new();
            for l in 0..m.layers {
                let kid = id(&format!("l{l}.kcache"))?;
                let vid = id(&format!("l{l}.vcache"))?;
                aliases.push((kid, kv_arena.slab(), kv_arena.k_offset(l)));
                aliases.push((vid, kv_arena.slab(), kv_arena.v_offset(l)));
                kv_bases.push((kid, kv_arena.k_offset(l)));
                kv_bases.push((vid, kv_arena.v_offset(l)));
            }
            let store = Arc::new(TensorStore::new_with_aliases(&compiled.graph, aliases));
            let token_ids = id("token_ids")?;
            let logits = id("lm_head")?;
            let kernel = PersistentMegaKernel::new(compiled.clone(), self.mega);
            let exec = OwningTileExecutor::new(compiled, store.clone(), pool.clone(), b);
            if self.paged_kv {
                // route this session's attention/KvAppend through the
                // block tables: physical blocks live at arena-absolute
                // offsets (they may lie beyond a small-batch session's
                // own cache-tensor bounds, so the binder addresses the
                // slab directly).
                exec.set_paged_geometry(PagedKvMap {
                    slab: kv_arena.slab(),
                    block_tokens: self.kv_block_tokens,
                    kv_dim: m.kv_dim(),
                    bases: kv_bases,
                });
            }
            sessions.insert(b, Session { store, kernel, exec, token_ids, logits });
        }
        let batcher = if self.paged_kv {
            // block-granular pool over the same arena the sessions
            // alias; admission reserves prompt-length blocks only.
            let pool = PagedKvPool::over(&kv_arena, self.kv_block_tokens);
            Batcher::new_paged(self.max_batch, manifest.s_max, pool)
        } else {
            // one KV block = 8 tokens; pool sized for max_batch full
            // seqs (accounting-only — see `serving::kvcache`).
            let blocks = self.max_batch * manifest.s_max / 8;
            Batcher::new(self.max_batch, manifest.s_max, KvAllocator::new(blocks, 8))
        };
        Ok(ServeEngine {
            manifest,
            pool,
            sessions,
            batcher,
            residency: KvResidency::default(),
            kv_arena,
            weights,
            eos_token: self.eos_token,
            compaction: self.compaction,
            faults: self.faults.is_armed().then(|| FaultInjector::new(self.faults)),
            recovery: Recovery::new(self.step_retries, self.retry_backoff),
            stats: ServeStats::default(),
            started: None,
            timing: HashMap::new(),
            pending_events: Vec::new(),
            ids_scratch: Vec::new(),
            lens_scratch: Vec::new(),
            prefill_chunk: self.prefill_chunk,
            cow_reported: 0,
            prefill_chunks_total: 0,
            spans_scratch: Vec::new(),
            flat_scratch: Vec::new(),
        })
    }
}

/// The engine.
pub struct ServeEngine {
    pub manifest: Manifest,
    pool: Arc<ExecPool>,
    sessions: HashMap<usize, Session>,
    pub batcher: Batcher,
    residency: KvResidency,
    kv_arena: KvArena,
    weights: WeightArena,
    eos_token: Option<i32>,
    compaction: bool,
    /// Armed fault injector (`None` unless the builder's [`FaultPlan`]
    /// can inject anything — the healthy hot path pays nothing).
    faults: Option<FaultInjector>,
    /// Retry/quarantine state machine for failed epochs.
    recovery: Recovery,
    /// Accumulating stats window (see [`ServeEngine::take_stats`]).
    stats: ServeStats,
    /// Start of the current stats window (first `step()` after a reset).
    started: Option<Instant>,
    /// In-flight clocks, admission → terminal event.
    timing: HashMap<u64, RequestClock>,
    /// Terminal notices queued between steps (cancellations); drained
    /// into the next [`StepOutcome`].
    pending_events: Vec<TokenEvent>,
    /// Per-iteration staging scratch, reused across steps.
    ids_scratch: Vec<i32>,
    lens_scratch: Vec<usize>,
    /// Chunked-prefill budget: extra kernel epochs per step (paged
    /// mode only; 0 = off).
    prefill_chunk: usize,
    /// COW watermark: pool `cowed_total()` already folded into a stats
    /// window — [`ServeEngine::take_stats`] resets the window, the
    /// watermark keeps the per-window deltas honest.
    cow_reported: u64,
    /// Lifetime chunked-prefill epochs (the status surface reports
    /// this; per-window counts live in [`ServeStats::prefill_chunks`]).
    prefill_chunks_total: u64,
    /// Per-epoch block-table staging scratch (paged mode), reused so a
    /// steady-state epoch stages with zero allocations: `spans[slot]`
    /// is the `(start, len)` slice of `flat` holding that row's table.
    spans_scratch: Vec<(usize, usize)>,
    flat_scratch: Vec<usize>,
}

impl ServeEngine {
    /// Start configuring an engine. See [`EngineBuilder`].
    pub fn builder() -> EngineBuilder {
        EngineBuilder::new()
    }

    /// Queue a request. Legal at any point in the engine's life — a
    /// request submitted between steps admits into a stable slot at the
    /// next [`ServeEngine::step`] (online admission). Typed rejections
    /// ([`EngineError::RequestTooLong`] / [`EngineError::KvPoolExceeded`]
    /// / [`EngineError::DuplicateId`]) leave the engine serving: client
    /// input must never abort a serving process.
    pub fn submit(&mut self, r: Request) -> Result<(), EngineError> {
        self.batcher.submit(r)
    }

    /// Would this request be accepted right now? The submit-time checks
    /// without the submit — non-mutating, same typed rejections in the
    /// same order. An admission-control layer (the server front-end)
    /// uses this to refuse unservable requests synchronously *before*
    /// queueing them in its own wait queue.
    pub fn validate(&self, r: &Request) -> Result<(), EngineError> {
        self.batcher.validate(r)
    }

    /// Cancel a request *now*: waiting requests leave the queue, active
    /// ones retire on the spot — slot and KV blocks are free for the
    /// very next admission. The terminal
    /// [`FinishReason::Cancelled`] event (no token) is delivered by the
    /// next [`ServeEngine::step`]. Whatever the request generated
    /// before cancellation stays available in its output.
    pub fn cancel(&mut self, id: u64) -> Result<(), EngineError> {
        self.terminate(id, FinishReason::Cancelled)
    }

    /// The general form of [`ServeEngine::cancel`]: retire a request
    /// *now* with the given terminal reason. The server front-end
    /// enforces deadlines ([`FinishReason::DeadlineExceeded`]) and
    /// displacement shedding ([`FinishReason::Shed`]) through this —
    /// both are the cancellation state transition with a different
    /// reason stamped on the terminal event, never an engine error.
    /// Same typed refusals as `cancel`
    /// ([`EngineError::UnknownRequest`] /
    /// [`EngineError::AlreadyFinished`]).
    pub fn terminate(&mut self, id: u64, reason: FinishReason) -> Result<(), EngineError> {
        self.batcher.terminate(id, reason)?;
        self.residency.evict(id);
        Self::close_clock(&mut self.timing, &mut self.stats.request_latency, id, Instant::now());
        self.pending_events.push(TokenEvent { request: id, token: None, finish: Some(reason) });
        Ok(())
    }

    /// Close a request's latency clock into the stats window — the one
    /// place a [`RequestLatency`] record is written. With a running
    /// clock (the request was admitted), record admission → `now`. With
    /// none, either the record was already closed at the terminal event
    /// (keep it) or the request terminated straight out of the waiting
    /// queue (record an empty entry, so every terminated request is
    /// accounted for). Takes the two maps rather than `&mut self` so
    /// the harvest loop can call it while iterating the batcher.
    fn close_clock(
        timing: &mut HashMap<u64, RequestClock>,
        latency: &mut HashMap<u64, RequestLatency>,
        id: u64,
        now: Instant,
    ) {
        match timing.remove(&id) {
            Some(clock) => {
                latency.insert(
                    id,
                    RequestLatency {
                        ttft: clock.ttft,
                        completion: Some(now.duration_since(clock.admitted)),
                    },
                );
            }
            None => {
                latency.entry(id).or_default();
            }
        }
    }

    /// True while the engine holds work or undelivered terminal events
    /// — the natural `step()` loop condition.
    pub fn has_work(&self) -> bool {
        self.batcher.has_work() || !self.pending_events.is_empty()
    }

    /// Concurrent-request ceiling: the slot count (`max_batch`). The
    /// server front-end admits from its wait queue while
    /// [`ServeEngine::in_flight`] is below this.
    pub fn capacity(&self) -> usize {
        self.batcher.max_batch
    }

    /// Requests currently inside the engine: active plus waiting-to-
    /// admit. (Finished-but-undrained requests hold no slot and are not
    /// counted.)
    pub fn in_flight(&self) -> usize {
        self.batcher.active.len() + self.batcher.pending()
    }

    /// Drain the retired-request list. Finished requests (prompt,
    /// generated tokens, finish reason) accumulate until drained so the
    /// batch-mode [`ServeEngine::serve`] can report cumulative outputs
    /// — a **long-lived streaming caller must drain periodically** or
    /// retired requests pile up for the life of the engine. Draining
    /// also releases the drained ids for reuse (see
    /// [`Batcher::take_finished`] for the exact id-reuse semantics).
    pub fn take_finished(&mut self) -> Vec<Request> {
        self.batcher.take_finished()
    }

    /// The engine's exec pool (shared by every session's executor).
    pub fn pool(&self) -> &ExecPool {
        &self.pool
    }

    /// The shared max-batch KV arena every session aliases (the engine
    /// owns it; sessions hold slab handles).
    pub fn kv_arena(&self) -> &KvArena {
        &self.kv_arena
    }

    /// Times the shared weight arena has been initialized — exactly 1
    /// regardless of how many batch-size specializations exist.
    pub fn weight_init_runs(&self) -> u64 {
        self.weights.init_runs()
    }

    /// Elements in the shared weight arena (the only weight storage —
    /// per-session stores hold activations only).
    pub fn weight_arena_len(&self) -> usize {
        self.weights.len()
    }

    /// Output buffers allocated at the exec-pool boundary over this
    /// engine's lifetime. The persistent-kernel task bodies hand the
    /// pool mutable arena destinations (`execute_into`), so serving
    /// keeps this at zero — the allocating `execute` reply survives
    /// only on validation paths (`run_reference`), which this engine
    /// never takes.
    pub fn output_allocs(&self) -> usize {
        self.pool.output_allocs()
    }

    /// Sum of read-side `(allocs, bytes_copied)` store counters across
    /// all session arenas — the zero-copy invariant: steady-state
    /// serving leaves both at zero (weight/token staging and in-place
    /// kernel writes are not counted; see `exec::store`).
    pub fn store_counters(&self) -> (u64, u64) {
        self.sessions.values().fold((0, 0), |(a, b), s| {
            let c = s.store.counters();
            (a + c.allocs, b + c.bytes_copied)
        })
    }

    /// The accumulating stats window (read-only; see
    /// [`ServeEngine::take_stats`] to close a window).
    pub fn stats(&self) -> &ServeStats {
        &self.stats
    }

    /// Operator snapshot of KV capacity ([`KvPoolStats`], the status
    /// surface — `ServerStatus` and the wire `Status` frame carry it):
    /// pool occupancy plus the cumulative sharing/COW/chunked-prefill
    /// counters, independent of stats-window resets. Legacy mode
    /// reports pool size and free count from the accounting allocator
    /// and zeros elsewhere.
    pub fn kv_status(&self) -> KvPoolStats {
        match self.batcher.kv.paged() {
            Some(p) => {
                let mut s = p.stats();
                s.prefill_chunks = self.prefill_chunks_total;
                s
            }
            None => KvPoolStats {
                blocks_total: self.batcher.kv.total_blocks() as u64,
                blocks_free: self.batcher.kv.free_blocks() as u64,
                ..KvPoolStats::default()
            },
        }
    }

    /// Close the current stats window: return everything accumulated
    /// since the last reset and start a fresh window. Streaming callers
    /// snapshot between bursts; [`ServeEngine::serve`] reports exactly
    /// one window per call. In-flight request clocks survive the reset
    /// (a request admitted in one window completes its latency record
    /// in the window that retires it).
    pub fn take_stats(&mut self) -> ServeStats {
        self.started = None;
        std::mem::take(&mut self.stats)
    }

    /// Record where each active request's KV rows live. With stable
    /// slots a request's arena home *is* its batcher slot for its whole
    /// lifetime — plus at most one deliberate compaction move, which
    /// updates residency in lockstep before this check runs. A mismatch
    /// therefore means a batcher change reintroduced slot remaps — an
    /// internal invariant violation, not something to "repair": a set
    /// of conflicting moves applied in arbitrary order could overwrite
    /// live rows, so the engine refuses with a typed
    /// [`EngineError::SlotRemap`]. Returns the row count so
    /// `kv_rows_migrated` keeps its unit (always `Ok(0)` — deliberate
    /// relocations are counted where they happen).
    fn reconcile_residency(&mut self) -> Result<usize, EngineError> {
        for r in &self.batcher.active {
            let slot = r.slot.expect("active request without slot");
            match self.residency.home(r.id) {
                Some(cur) if cur == slot => {}
                Some(cur) => {
                    return Err(EngineError::SlotRemap { id: r.id, from: cur, to: slot });
                }
                None => self.residency.set(r.id, slot),
            }
        }
        Ok(0)
    }

    /// The opt-in anti-fragmentation pass: at most one relocation per
    /// step, and only when it drops the specialized graph a whole power
    /// of two. Applies the batcher's probe result, moves the KV rows
    /// through the dormant relocation primitive, and updates residency
    /// deliberately — returning the moved-row count so the caller adds
    /// it to `kv_rows_migrated` (honest accounting, never silent).
    fn maybe_compact(&mut self) -> usize {
        // legacy-only: the builder rejects paged_kv + compaction, so
        // the slot-relocation path is unreachable with paging on.
        debug_assert!(
            self.batcher.kv.paged().is_none(),
            "compaction pass reached with paging on (builder gate bypassed)"
        );
        let Some((id, src, dst)) = self.batcher.compaction_candidate() else {
            return 0;
        };
        let rows = self
            .batcher
            .active
            .iter()
            .find(|r| r.id == id)
            .expect("compaction candidate is active")
            .cache_len;
        let vacated = self.batcher.relocate(id, dst);
        debug_assert_eq!(vacated, src, "probe and apply disagree");
        let moved = self.kv_arena.move_slot(src, dst, rows);
        self.residency.set(id, dst);
        moved
    }

    /// Retire a request the recovery path blamed for repeated epoch
    /// failures: terminal [`FinishReason::Failed`], slot and KV blocks
    /// freed immediately, partial output preserved — every *other*
    /// request keeps its slot and resident KV untouched. The terminal
    /// event goes straight into this step's outcome (the step is still
    /// in progress; nothing to defer).
    fn quarantine(&mut self, id: u64, events: &mut Vec<TokenEvent>) {
        // the victim was chosen among still-active requests, so this
        // cannot fail; tolerate a bookkeeping surprise over panicking
        // inside the recovery path.
        if self.batcher.terminate(id, FinishReason::Failed).is_err() {
            return;
        }
        self.residency.evict(id);
        Self::close_clock(&mut self.timing, &mut self.stats.request_latency, id, Instant::now());
        self.stats.requests_quarantined += 1;
        events.push(TokenEvent { request: id, token: None, finish: Some(FinishReason::Failed) });
    }

    /// Pre-epoch paged pass: secure a writable block for every active
    /// row's KvAppend this epoch — on-demand growth across a block
    /// boundary, or the copy-on-write block copy when the target is
    /// shared (the subsystem's one counted copy; runs while the kernel
    /// is quiesced, so readers never observe a half-copied block).
    /// Returns the ids the pool could not serve — a pool exhausted
    /// mid-decode is a typed displacement outcome, never a panic.
    /// Idempotent: a retried epoch finds every block `Ready`.
    fn ensure_paged_appends(&mut self) -> Vec<u64> {
        let mut shed = Vec::new();
        for i in 0..self.batcher.active.len() {
            let (id, pos) = {
                let r = &self.batcher.active[i];
                (r.id, r.cache_len)
            };
            let pool = self.batcher.kv.paged_mut().expect("paged mode checked by caller");
            match pool.ensure_append(id, pos) {
                Append::Ready | Append::Grew | Append::Cowed => {}
                Append::Exhausted => shed.push(id),
            }
        }
        shed
    }

    /// Rebuild the per-slot block-table staging buffers (reused across
    /// epochs) from the paged pool: `spans[slot]` names `flat[start..
    /// start + len]` as row `slot`'s table. Vacant slots keep an empty
    /// span — the binder decodes them as zero-valid rows and skips
    /// their appends.
    fn stage_block_tables(
        batcher: &Batcher,
        spans: &mut Vec<(usize, usize)>,
        flat: &mut Vec<usize>,
        gb: usize,
    ) {
        let pool = batcher.kv.paged().expect("paged mode checked by caller");
        spans.clear();
        spans.resize(gb, (0, 0));
        flat.clear();
        for r in &batcher.active {
            let slot = r.slot.expect("active request without slot");
            let table = pool.table(r.id).expect("active paged request has a block table");
            spans[slot] = (flat.len(), table.len());
            flat.extend_from_slice(table);
        }
    }

    /// Chunked prefill (paged mode, opt-in): run up to `prefill_chunk`
    /// *extra* kernel epochs inside the current step, advancing only
    /// rows still deep in prefill (two or more prompt tokens left, so
    /// the step's final epoch below stays the one that crosses them out
    /// of prefill and emits). Rows not advanced — decoders, prompts on
    /// their last token — are re-staged idempotently: KvAppend rewrites
    /// the same position with the same bytes and their logits are
    /// recomputed and discarded, so decode cadence stays exactly one
    /// token per step no matter how much prefill runs alongside. Extra
    /// epochs draw no injected fault and do not retry: the main
    /// epoch's recovery machinery guards the token-producing path, and
    /// a genuine failure here surfaces immediately.
    fn run_prefill_chunks(&mut self) -> Result<(), EngineError> {
        for _ in 0..self.prefill_chunk {
            if !self.batcher.active.iter().any(|r| r.prompt_pos + 1 < r.prompt.len()) {
                break;
            }
            let gb = self.batcher.graph_batch();
            if gb == 0 {
                break;
            }
            if !self.sessions.contains_key(&gb) {
                return Err(EngineError::NoSession { batch: gb });
            }
            let shed = self.ensure_paged_appends();
            if !shed.is_empty() {
                for id in shed {
                    let _ = self.terminate(id, FinishReason::Shed);
                }
                continue; // freed blocks may unblock the survivors
            }
            self.ids_scratch.clear();
            self.ids_scratch.resize(gb, 0);
            self.lens_scratch.clear();
            self.lens_scratch.resize(gb, 0);
            for r in &self.batcher.active {
                let slot = r.slot.expect("active request without slot");
                self.ids_scratch[slot] = r.next_input();
                self.lens_scratch[slot] = r.cache_len;
            }
            Self::stage_block_tables(
                &self.batcher,
                &mut self.spans_scratch,
                &mut self.flat_scratch,
                gb,
            );
            let session = self.sessions.get_mut(&gb).expect("session presence checked above");
            real::set_ids_at(&session.store, session.token_ids, &self.ids_scratch);
            session.exec.set_row_lens(&self.lens_scratch);
            session.exec.set_block_tables(&self.spans_scratch, &self.flat_scratch);
            session.kernel.run(&session.exec)?;
            if let Some(e) = session.exec.take_error() {
                return Err(e.into());
            }
            self.stats.prefill_chunks += 1;
            self.prefill_chunks_total += 1;
            // partial harvest: advance the deep-prefill rows only, and
            // publish prompt blocks that just filled.
            let bt = self.batcher.kv.block_tokens();
            for i in 0..self.batcher.active.len() {
                let r = &mut self.batcher.active[i];
                if r.prompt_pos + 1 >= r.prompt.len() {
                    continue;
                }
                r.cache_len += 1;
                r.prompt_pos += 1;
                if r.cache_len % bt == 0 && r.cache_len <= r.prompt.len() {
                    let pool = self.batcher.kv.paged_mut().expect("paged mode checked above");
                    pool.promote(r.id, &r.prompt, r.cache_len);
                }
            }
        }
        Ok(())
    }

    /// Refresh the paged-KV window stats: the instantaneous free-block
    /// gauge, the window's sharing peak, and the COW delta since the
    /// last sync (via the `cow_reported` watermark, so window resets
    /// never double- or under-count). No-op in legacy mode.
    fn sync_kv_gauges(&mut self) {
        let Some(p) = self.batcher.kv.paged() else { return };
        let s = p.stats();
        self.stats.kv_blocks_free = s.blocks_free;
        self.stats.kv_blocks_shared = self.stats.kv_blocks_shared.max(s.blocks_shared);
        let cowed = p.cowed_total();
        self.stats.kv_blocks_cowed += cowed - self.cow_reported;
        self.cow_reported = cowed;
    }

    /// One decode iteration — the re-entrant core the whole serving
    /// surface is built on: retire finished requests and admit waiting
    /// ones into stable slots, optionally compact, pick the
    /// specialization covering the highest occupied slot, stage each
    /// request's token at its slot, re-arm the resident kernel, and
    /// harvest one token per past-prefill request.
    ///
    /// Returns the iteration's [`StepOutcome`]: per-request
    /// [`TokenEvent`]s (terminal ones carry a [`FinishReason`]), plus
    /// any `Cancelled` notices queued since the previous step. An idle
    /// step (nothing admitted) returns `ran == 0` and runs no kernel.
    ///
    /// A request whose terminal event was emitted this step still
    /// occupies its slot until the next step's retire phase frees it —
    /// call `step()` again (or `serve()` to completion) to reclaim it.
    pub fn step(&mut self) -> Result<StepOutcome, EngineError> {
        let t_step = Instant::now();
        if self.started.is_none() {
            self.started = Some(t_step);
        }
        let mut events: Vec<TokenEvent> = Vec::new();
        let vocab = self.manifest.model.vocab;

        // 1. retire finished, admit waiting (the paper's start-event
        // task). A retired request's latency record was written when
        // its terminal event was emitted (harvest or cancel); the
        // removal here is defensive, so the record stays right even if
        // a request ever retired without one.
        for id in self.batcher.step_admission() {
            self.residency.evict(id);
            Self::close_clock(&mut self.timing, &mut self.stats.request_latency, id, t_step);
        }
        // 2. opt-in anti-fragmentation: one deliberate, counted move.
        if self.compaction {
            let moved = self.maybe_compact();
            self.stats.kv_rows_migrated += moved;
        }
        // 3. admission clocks for newly active requests.
        for r in &self.batcher.active {
            self.timing
                .entry(r.id)
                .or_insert(RequestClock { admitted: t_step, ttft: None });
        }
        // 3b. chunked prefill (paged, opt-in): spend the chunk budget
        // advancing long prompts with extra epochs before the step's
        // one token-producing epoch below.
        if self.prefill_chunk > 0 && self.batcher.kv.paged().is_some() {
            self.run_prefill_chunks()?;
        }
        // 4+5. stage and run, with recovery: each attempt restages from
        // request state (which only advances at harvest, so a retried
        // epoch is idempotent — KvAppend rewrites the same positions)
        // and re-arms the *same* resident kernel. A failed attempt goes
        // through the recovery state machine: bounded-backoff retry,
        // then quarantine of the blamed request (restage without it and
        // keep going), then — only for persistent unattributable
        // failures — a surfaced error. The engine is never rebuilt.
        let mut first_attempt = true;
        let (gb, lat) = loop {
            // graph_batch is 0 exactly when no slot is occupied — and
            // then only when nothing is waiting either: submit rejects
            // any request whose worst case exceeds the whole KV pool,
            // so a lone waiting request always admits into an empty
            // batcher. The idle return is a clean no-op, not a drop.
            // (After a quarantine emptied the batch mid-step, waiting
            // requests admit at the *next* step — idle is still clean.)
            let gb = self.batcher.graph_batch();
            if gb == 0 {
                debug_assert!(
                    !first_attempt || self.batcher.pending() == 0,
                    "accepted request stuck unadmittable"
                );
                self.sync_kv_gauges();
                self.stats.busy += t_step.elapsed();
                self.stats.total = self.started.expect("window started above").elapsed();
                let events = self.drain_pending(events);
                return Ok(StepOutcome { events, ran: 0 });
            }
            first_attempt = false;
            if !self.sessions.contains_key(&gb) {
                return Err(EngineError::NoSession { batch: gb });
            }

            // KV stays resident at each request's stable slot of the
            // shared arena — zero rows moved outside the deliberate
            // pass above.
            let migrated = self.reconcile_residency()?;
            self.stats.kv_rows_migrated += migrated;

            // paged: grow/COW each row's append target before the
            // epoch. Exhaustion displaces the victims with a typed
            // terminal `Shed` — never a panic — and restages without
            // them (their freed blocks may be exactly what lets the
            // survivors run).
            if self.batcher.kv.paged().is_some() {
                let shed = self.ensure_paged_appends();
                if !shed.is_empty() {
                    for id in shed {
                        let _ = self.terminate(id, FinishReason::Shed);
                    }
                    continue;
                }
            }

            // stage inputs by slot index into reused scratch: this
            // iteration's token per occupied row, row cache lengths.
            // Vacant slots (stable slots fragment after retirements)
            // decode token 0 into dead arena rows that the slot's next
            // occupant overwrites from position 0 — their logits are
            // never read.
            self.ids_scratch.clear();
            self.ids_scratch.resize(gb, 0);
            self.lens_scratch.clear();
            self.lens_scratch.resize(gb, 0);
            for r in &self.batcher.active {
                let slot = r.slot.expect("active request without slot");
                self.ids_scratch[slot] = r.next_input();
                self.lens_scratch[slot] = r.cache_len;
            }
            // draw this attempt's injected fault (if a plan is armed)
            // before touching the kernel, over exactly what is staged.
            let fault = match self.faults.as_mut() {
                Some(inj) => inj.draw(&self.batcher.active),
                None => None,
            };
            let session = self.sessions.get_mut(&gb).expect("session presence checked above");
            real::set_ids_at(&session.store, session.token_ids, &self.ids_scratch);

            // re-arm the resident mega-kernel through the session's
            // long-lived executor: no thread spawn/join, no kernel or
            // executor construction, no name lookups on this path.
            session.exec.set_row_lens(&self.lens_scratch);
            if self.batcher.kv.paged().is_some() {
                Self::stage_block_tables(
                    &self.batcher,
                    &mut self.spans_scratch,
                    &mut self.flat_scratch,
                    gb,
                );
                session.exec.set_block_tables(&self.spans_scratch, &self.flat_scratch);
            }
            let it0 = Instant::now();
            let failure: Option<(EngineError, Option<u64>)> = match fault {
                // an injected epoch failure models a wedged epoch (the
                // watchdog fired before the end event): the kernel is
                // not run, exactly as a timed-out epoch yields nothing.
                Some(Fault::Epoch) => {
                    Some((EngineError::Kernel("injected epoch failure (fault plan)".into()), None))
                }
                // an injected task failure models a task body dying in
                // an otherwise-completed epoch: run the real epoch,
                // then fail its harvest, blaming the victim. A genuine
                // failure on the same attempt takes precedence (and
                // still blames the victim — attribution is the point).
                Some(Fault::Task { victim }) => {
                    let err = match session.kernel.run(&session.exec) {
                        Ok(()) => {
                            let _ = session.exec.take_error();
                            EngineError::Task(format!(
                                "injected task failure in request {victim}'s row (fault plan)"
                            ))
                        }
                        Err(e) => e.into(),
                    };
                    Some((err, Some(victim)))
                }
                None => match session.kernel.run(&session.exec) {
                    Ok(()) => session.exec.take_error().map(|e| (e.into(), None)),
                    Err(e) => Some((e.into(), None)),
                },
            };
            match failure {
                None => {
                    self.recovery.on_success();
                    break (gb, it0.elapsed());
                }
                Some((err, victim)) => {
                    self.stats.faulted_epochs += 1;
                    let action = self
                        .recovery
                        .on_failure(victim, |id| self.batcher.active.iter().any(|r| r.id == id));
                    match action {
                        RecoveryAction::Retry(backoff) => {
                            if !backoff.is_zero() {
                                std::thread::sleep(backoff);
                            }
                        }
                        RecoveryAction::Quarantine(id) => self.quarantine(id, &mut events),
                        RecoveryAction::GiveUp => return Err(err),
                    }
                }
            }
        };
        let active = self.batcher.active.len();
        self.stats.iterations += 1;
        self.stats.iter_latencies.push(lat);
        self.stats.batch_sizes.push(active);

        // 6. harvest: each request's logits row (at its slot) → next
        // token, through a borrowed arena view (no copy). KV needs no
        // read-back — KvAppend already wrote this step's row in the
        // resident arena. Every emitted token becomes an event; EOS and
        // exhausted budgets become terminal events (EOS wins a tie).
        let now = Instant::now();
        let session = self.sessions.get(&gb).expect("session ran above");
        let logits = session.store.view(session.logits);
        let paged_bt = self.batcher.kv.paged().map(|p| p.block_tokens());
        for r in self.batcher.active.iter_mut() {
            let slot = r.slot.expect("active request without slot");
            r.cache_len += 1;
            // paged: a prompt block that just filled with prefill rows
            // becomes publishable — register it so later admissions
            // with the same prefix map it instead of re-prefilling.
            if let Some(bt) = paged_bt {
                if r.cache_len % bt == 0 && r.cache_len <= r.prompt.len() {
                    let pool = self.batcher.kv.paged_mut().expect("paged mode checked above");
                    pool.promote(r.id, &r.prompt, r.cache_len);
                }
            }
            let tok = real::argmax(&logits[slot * vocab..(slot + 1) * vocab]) as i32;
            let emitted = if r.in_prefill() {
                r.prompt_pos += 1;
                if r.in_prefill() {
                    false
                } else {
                    r.generated.push(tok);
                    true
                }
            } else {
                r.generated.push(tok);
                true
            };
            if !emitted {
                continue;
            }
            self.stats.tokens_generated += 1;
            let clock = self.timing.get_mut(&r.id).expect("active request has a clock");
            if clock.ttft.is_none() {
                clock.ttft = Some(now.duration_since(clock.admitted));
            }
            let finish = if self.eos_token == Some(tok) {
                Some(FinishReason::Eos)
            } else if r.generated.len() >= r.max_new_tokens {
                Some(FinishReason::MaxTokens)
            } else {
                None
            };
            if let Some(reason) = finish {
                r.finish = Some(reason);
                Self::close_clock(&mut self.timing, &mut self.stats.request_latency, r.id, now);
            }
            events.push(TokenEvent { request: r.id, token: Some(tok), finish });
        }
        self.sync_kv_gauges();
        self.stats.busy += t_step.elapsed();
        self.stats.total = self.started.expect("window started above").elapsed();
        let events = self.drain_pending(events);
        Ok(StepOutcome { events, ran: active })
    }

    /// Prepend the terminal notices queued since the previous step
    /// (cancellations) to this step's fresh events. Called only on the
    /// success paths of [`ServeEngine::step`]: if a step fails, queued
    /// notices stay queued and are delivered by the next successful
    /// step instead of being dropped with the error.
    fn drain_pending(&mut self, fresh: Vec<TokenEvent>) -> Vec<TokenEvent> {
        if self.pending_events.is_empty() {
            return fresh;
        }
        let mut all = std::mem::take(&mut self.pending_events);
        all.extend(fresh);
        all
    }

    /// Batch-mode compat: drive [`ServeEngine::step`] until idle and
    /// return per-request outputs plus this call's stats window.
    /// Deterministic — greedy decoding, seeded weights — and (with EOS
    /// and compaction off) output-identical to the pre-step-API
    /// batch-to-completion loop. Outputs cover every request finished
    /// since the last [`ServeEngine::take_finished`] drain (the
    /// finished list is cumulative until drained).
    pub fn serve(&mut self) -> Result<(HashMap<u64, Vec<i32>>, ServeStats), EngineError> {
        let _ = self.take_stats(); // fresh window: serve() reports this call only
        while self.has_work() {
            let outcome = self.step()?;
            if outcome.is_idle() && self.batcher.has_work() {
                // unadmittable waiting work — unreachable via the
                // submit invariant (debug-asserted in step); exit
                // cleanly rather than livelock.
                break;
            }
        }
        let stats = self.take_stats();
        let outputs = self
            .batcher
            .finished
            .iter()
            .map(|r| (r.id, r.generated.clone()))
            .collect();
        Ok((outputs, stats))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::binder::TileExecutor;

    fn mega() -> MegaConfig {
        MegaConfig { workers: 4, schedulers: 1, ..Default::default() }
    }

    fn engine(max_batch: usize, seed: u64) -> ServeEngine {
        ServeEngine::builder()
            .max_batch(max_batch)
            .pool_threads(2)
            .seed(seed)
            .mega(mega())
            .build()
            .unwrap()
    }

    /// Drive `step()` to idle, collecting every event.
    fn drain(e: &mut ServeEngine) -> Vec<TokenEvent> {
        let mut events = Vec::new();
        let mut guard = 0;
        while e.has_work() {
            guard += 1;
            assert!(guard < 10_000, "step loop livelock");
            events.extend(e.step().unwrap().events);
        }
        events
    }

    #[test]
    fn builder_validation_is_typed_and_resource_free() {
        // config errors surface before any manifest/pool work — these
        // run (and must pass) without artifacts or a backend.
        let err = ServeEngine::builder().pool_threads(0).build().unwrap_err();
        assert!(matches!(err, EngineError::InvalidConfig(_)), "got: {err}");
        let err = ServeEngine::builder().max_batch(0).build().unwrap_err();
        assert!(matches!(err, EngineError::InvalidConfig(_)), "got: {err}");
        let err = ServeEngine::builder()
            .mega(MegaConfig { workers: 0, ..Default::default() })
            .build()
            .unwrap_err();
        assert!(matches!(err, EngineError::InvalidConfig(_)), "got: {err}");
    }

    #[test]
    fn builder_validates_recovery_and_fault_knobs() {
        // like the other config checks these fail before any resource
        // is touched — no artifacts, no backend, no threads.
        let err = ServeEngine::builder().kernel_timeout(Duration::ZERO).build().unwrap_err();
        assert!(
            matches!(&err, EngineError::InvalidConfig(m) if m.contains("timeout")),
            "got: {err}"
        );
        let err = ServeEngine::builder()
            .faults(FaultPlan { kernel_rate: 2.0, ..Default::default() })
            .build()
            .unwrap_err();
        assert!(
            matches!(&err, EngineError::InvalidConfig(m) if m.contains("kernel_rate")),
            "got: {err}"
        );
        let err = ServeEngine::builder()
            .faults(FaultPlan { task_rate: -0.5, ..Default::default() })
            .build()
            .unwrap_err();
        assert!(
            matches!(&err, EngineError::InvalidConfig(m) if m.contains("task_rate")),
            "got: {err}"
        );
        let err =
            ServeEngine::builder().retry_backoff(Duration::from_secs(5)).build().unwrap_err();
        assert!(
            matches!(&err, EngineError::InvalidConfig(m) if m.contains("retry_backoff")),
            "got: {err}"
        );
    }

    #[test]
    fn builder_rejects_unspecialized_batch_and_bad_eos() {
        let err = ServeEngine::builder().max_batch(3).mega(mega()).build().unwrap_err();
        assert!(
            matches!(&err, EngineError::InvalidConfig(m) if m.contains("specialized sizes")),
            "got: {err}"
        );
        let err = ServeEngine::builder().max_batch(2).mega(mega()).eos_token(-1).build().unwrap_err();
        assert!(
            matches!(&err, EngineError::InvalidConfig(m) if m.contains("vocab")),
            "got: {err}"
        );
    }

    #[test]
    fn serves_batch_to_completion() {
        let mut e = engine(4, 42);
        for i in 0..3u64 {
            e.submit(Request::new(i, vec![(i as i32) + 1, 7], 4)).unwrap();
        }
        let (out, stats) = e.serve().unwrap();
        assert_eq!(out.len(), 3);
        for (_, toks) in &out {
            assert_eq!(toks.len(), 4);
            for &t in toks {
                assert!((0..512).contains(&t));
            }
        }
        assert_eq!(stats.tokens_generated, 12);
        assert!(stats.iterations >= 5, "prompt 2 + gen 4 - 1 overlap");
        // slots are stable: no KV rows ever move in the arena.
        assert_eq!(stats.kv_rows_migrated, 0, "steady batch migrated KV rows");
        // the busy/total split: busy time is real and bounded by wall.
        assert!(stats.busy > Duration::ZERO && stats.busy <= stats.total);
        // per-request latency recorded for the whole wave.
        assert_eq!(stats.request_latency.len(), 3);
        for (id, lat) in &stats.request_latency {
            let ttft = lat.ttft.unwrap_or_else(|| panic!("req {id} missing ttft"));
            let done = lat.completion.unwrap_or_else(|| panic!("req {id} missing completion"));
            assert!(ttft <= done, "req {id}: ttft {ttft:?} > completion {done:?}");
        }
        assert!(stats.ttft_p50() <= stats.completion_p99());
    }

    #[test]
    fn step_streaming_matches_serve_and_supports_midflight_submit() {
        // streaming engine: request 1 arrives mid-flight, after request
        // 0 has already decoded a couple of steps.
        let mut a = engine(2, 42);
        a.submit(Request::new(0, vec![3, 11], 4)).unwrap();
        let mut events = Vec::new();
        for _ in 0..3 {
            events.extend(a.step().unwrap().events);
        }
        a.submit(Request::new(1, vec![9], 3)).unwrap();
        events.extend(drain(&mut a));

        // batch engine: everything submitted up front.
        let mut b = engine(2, 42);
        b.submit(Request::new(0, vec![3, 11], 4)).unwrap();
        b.submit(Request::new(1, vec![9], 3)).unwrap();
        let (out, _) = b.serve().unwrap();

        // per-request event streams equal the batch outputs (row
        // independence: a request's tokens do not depend on when its
        // neighbours were admitted).
        for id in [0u64, 1] {
            let stream: Vec<i32> =
                events.iter().filter(|ev| ev.request == id).map(|ev| ev.token.unwrap()).collect();
            assert_eq!(stream, out[&id], "req {id} stream != batch output");
            let terminal: Vec<_> =
                events.iter().filter(|ev| ev.request == id && ev.finish.is_some()).collect();
            assert_eq!(terminal.len(), 1, "req {id} needs exactly one terminal event");
            assert_eq!(terminal[0].finish, Some(FinishReason::MaxTokens));
            assert_eq!(terminal[0].token, Some(*out[&id].last().unwrap()));
        }
        // the streamed path is as zero-copy as the batch path.
        assert_eq!(a.store_counters(), (0, 0));
        assert_eq!(a.output_allocs(), 0);
        assert_eq!(a.stats().kv_rows_migrated, 0);
        // idle steps on a drained engine are clean no-ops.
        let idle = a.step().unwrap();
        assert!(idle.is_idle() && idle.events.is_empty());
    }

    #[test]
    fn eos_token_stops_generation_early() {
        // discover what this prompt decodes first under this seed, then
        // build an engine that treats that token as EOS.
        let mut probe = engine(1, 42);
        probe.submit(Request::new(0, vec![7], 3)).unwrap();
        let (out, _) = probe.serve().unwrap();
        let first = out[&0][0];

        let mut e = ServeEngine::builder()
            .max_batch(1)
            .pool_threads(2)
            .seed(42)
            .mega(mega())
            .eos_token(first)
            .build()
            .unwrap();
        e.submit(Request::new(0, vec![7], 5)).unwrap();
        let events = drain(&mut e);
        assert_eq!(
            events,
            vec![TokenEvent { request: 0, token: Some(first), finish: Some(FinishReason::Eos) }],
            "EOS must stop the stream at one token"
        );
        let done = &e.batcher.finished[0];
        assert_eq!(done.generated, vec![first], "EOS token is included in the output");
        assert_eq!(done.finish, Some(FinishReason::Eos));
    }

    #[test]
    fn cancel_frees_kv_and_slot_and_emits_terminal_event() {
        let mut e = engine(2, 42);
        e.submit(Request::new(0, vec![5, 6], 6)).unwrap();
        e.submit(Request::new(1, vec![9], 6)).unwrap();
        let mut events = Vec::new();
        for _ in 0..3 {
            events.extend(e.step().unwrap().events);
        }
        assert!(e.batcher.kv.held_by(0) > 0, "active request holds KV blocks");
        let free_before = e.batcher.kv.free_blocks();
        e.cancel(0).unwrap();
        // KV blocks and residency are released immediately, not at the
        // next step.
        assert_eq!(e.batcher.kv.held_by(0), 0);
        assert!(e.batcher.kv.free_blocks() > free_before);
        // the terminal event rides the next outcome, tokenless.
        let out = e.step().unwrap();
        assert!(
            out.events.contains(&TokenEvent {
                request: 0,
                token: None,
                finish: Some(FinishReason::Cancelled)
            }),
            "missing cancellation notice in {:?}",
            out.events
        );
        events.extend(out.events);
        // partial output survives; the survivor decodes to completion.
        events.extend(drain(&mut e));
        let survivor: Vec<i32> =
            events.iter().filter(|ev| ev.request == 1).filter_map(|ev| ev.token).collect();
        assert_eq!(survivor.len(), 6);
        let cancelled = e.batcher.finished.iter().find(|r| r.id == 0).unwrap();
        assert_eq!(cancelled.finish, Some(FinishReason::Cancelled));
        assert!(cancelled.generated.len() < 6, "cancelled request must stop early");
        // typed refusals for re-cancel and unknown ids.
        assert!(matches!(e.cancel(0).unwrap_err(), EngineError::AlreadyFinished { id: 0 }));
        assert!(matches!(e.cancel(77).unwrap_err(), EngineError::UnknownRequest { id: 77 }));
        // a freed slot admits new work mid-flight.
        e.submit(Request::new(2, vec![4], 2)).unwrap();
        let events = drain(&mut e);
        assert_eq!(events.iter().filter(|ev| ev.request == 2).filter_map(|ev| ev.token).count(), 2);
        // cancellation + churn still never copies or migrates.
        assert_eq!(e.store_counters(), (0, 0));
        assert_eq!(e.output_allocs(), 0);
        assert_eq!(e.stats().kv_rows_migrated, 0);
        // the cancelled request's latency record closed at cancel time.
        let lat = e.stats().request_latency[&0];
        assert!(lat.completion.is_some());

        // cancel a request still in the waiting queue: it terminates
        // with an event and an (empty) latency record — never admitted,
        // so there is no admission-based time to measure, but the
        // request is still accounted for.
        e.submit(Request::new(10, vec![5], 3)).unwrap();
        e.submit(Request::new(11, vec![6], 3)).unwrap();
        e.submit(Request::new(12, vec![7], 3)).unwrap(); // waits: 2 slots
        e.cancel(12).unwrap();
        let events = drain(&mut e);
        assert!(events.contains(&TokenEvent {
            request: 12,
            token: None,
            finish: Some(FinishReason::Cancelled)
        }));
        assert!(events.iter().all(|ev| ev.request != 12 || ev.token.is_none()));
        assert_eq!(e.stats().request_latency[&12], RequestLatency::default());

        // streaming callers reclaim retired requests via the drain API;
        // drained ids are released for reuse (undrained ones stay
        // reserved — see the batcher's id-reuse semantics).
        let done = e.take_finished();
        assert_eq!(done.len(), 6, "0..2 plus 10..12 retired on this engine");
        assert!(e.batcher.finished.is_empty());
        e.submit(Request::new(0, vec![1], 1)).unwrap();
        let events = drain(&mut e);
        assert_eq!(
            events.iter().filter(|ev| ev.request == 0 && ev.finish.is_some()).count(),
            1,
            "reused id serves as a fresh request"
        );
    }

    #[test]
    fn fault_injection_recovers_and_quarantines() {
        // baseline: what the survivor decodes on a healthy engine.
        let mut clean = engine(2, 42);
        clean.submit(Request::new(1, vec![9], 4)).unwrap();
        let (base, _) = clean.serve().unwrap();

        // poisoned engine: request 0 fails every epoch it is staged in;
        // retry budget 1 → two failed attempts, then quarantine.
        let mut e = ServeEngine::builder()
            .max_batch(2)
            .pool_threads(2)
            .seed(42)
            .mega(mega())
            .step_retries(1)
            .faults(FaultPlan { poison: Some(0), ..Default::default() })
            .build()
            .unwrap();
        e.submit(Request::new(0, vec![5, 6], 6)).unwrap();
        e.submit(Request::new(1, vec![9], 4)).unwrap();
        let events = drain(&mut e);

        // the poisoned request got exactly one terminal event: Failed,
        // tokenless.
        let poisoned: Vec<_> = events.iter().filter(|ev| ev.request == 0).collect();
        assert_eq!(poisoned.len(), 1, "got {poisoned:?}");
        assert_eq!(poisoned[0].finish, Some(FinishReason::Failed));
        assert_eq!(poisoned[0].token, None);
        let q = e.batcher.finished.iter().find(|r| r.id == 0).unwrap();
        assert_eq!(q.finish, Some(FinishReason::Failed));

        // the survivor kept its slot and KV across the recovery and
        // decodes exactly what it would on a healthy engine.
        let survivor: Vec<i32> =
            events.iter().filter(|ev| ev.request == 1).filter_map(|ev| ev.token).collect();
        assert_eq!(survivor, base[&1], "recovery disturbed an unaffected request");

        // recovery accounting: 2 failed attempts, 1 quarantine — and
        // the engine (kernels, sessions, arenas) was never rebuilt.
        assert_eq!(e.stats().faulted_epochs, 2, "retry budget 1 → two failed attempts");
        assert_eq!(e.stats().requests_quarantined, 1);

        // the recovery path preserves the zero-copy/zero-move invariant.
        assert_eq!(e.store_counters(), (0, 0));
        assert_eq!(e.output_allocs(), 0);
        assert_eq!(e.stats().kv_rows_migrated, 0);

        // the engine keeps serving new work afterwards.
        e.submit(Request::new(7, vec![3], 2)).unwrap();
        let events = drain(&mut e);
        assert_eq!(events.iter().filter(|ev| ev.request == 7).filter_map(|ev| ev.token).count(), 2);
    }

    #[test]
    fn random_fault_rates_recover_without_losing_requests() {
        // epoch-level faults at a healthy-retry rate: every request
        // still finishes (faults are unattributable, so nothing is
        // quarantined as long as the retry budget absorbs the streak —
        // budget 16 makes a 17-failure streak at rate 0.3 impossible
        // in practice, so the test is not seed-sensitive).
        let mut e = ServeEngine::builder()
            .max_batch(4)
            .pool_threads(2)
            .seed(42)
            .mega(mega())
            .step_retries(16)
            .faults(FaultPlan { seed: 11, kernel_rate: 0.3, ..Default::default() })
            .build()
            .unwrap();
        for i in 0..4u64 {
            e.submit(Request::new(i, vec![(i as i32) + 1, 7], 3)).unwrap();
        }
        let (out, stats) = e.serve().unwrap();
        assert_eq!(out.len(), 4);
        for (id, toks) in &out {
            assert_eq!(toks.len(), 3, "req {id} lost tokens to recovery");
        }
        assert!(stats.faulted_epochs > 0, "30% rate never fired");
        assert_eq!(stats.requests_quarantined, 0, "epoch faults must not quarantine");
        assert_eq!(e.store_counters(), (0, 0));
        assert_eq!(stats.kv_rows_migrated, 0);
    }

    #[test]
    fn compaction_relocates_once_counted_and_output_identical() {
        let build = |compaction: bool| {
            ServeEngine::builder()
                .max_batch(8)
                .pool_threads(2)
                .seed(42)
                .mega(mega())
                .compaction(compaction)
                .build()
                .unwrap()
        };
        let submit_wave = |e: &mut ServeEngine| {
            // slots 0..4; the four short requests retire together and
            // strand the long one at slot 4 — bound 5 forces the
            // batch-8 graph until compaction moves it down.
            for i in 0..5u64 {
                e.submit(Request::new(i, vec![2 + i as i32], if i == 4 { 6 } else { 1 })).unwrap();
            }
        };
        let mut on = build(true);
        submit_wave(&mut on);
        let (out_on, stats_on) = on.serve().unwrap();
        assert!(stats_on.kv_rows_migrated > 0, "compaction never fired");
        assert!(
            stats_on.batch_sizes.iter().any(|&b| b == 1),
            "post-compaction iterations should run small"
        );

        let mut off = build(false);
        submit_wave(&mut off);
        let (out_off, stats_off) = off.serve().unwrap();
        assert_eq!(stats_off.kv_rows_migrated, 0, "flag off must never move a row");
        // relocation must not change what anyone decodes.
        assert_eq!(out_on, out_off, "compaction changed outputs");
        assert_eq!(out_on[&4].len(), 6);
    }

    #[test]
    fn steady_state_decode_is_zero_copy() {
        // a uniform wave (same prompt + generation lengths) is admitted
        // together and retired together: the whole run is the steady
        // state the zero-copy invariant promises.
        let mut e = engine(4, 42);
        for i in 0..4u64 {
            e.submit(Request::new(i, vec![(i as i32) + 1, 9], 5)).unwrap();
        }
        let (out, stats) = e.serve().unwrap();
        assert_eq!(out.len(), 4);
        assert_eq!(stats.kv_rows_migrated, 0, "arena moved rows in steady state");
        let (allocs, bytes) = e.store_counters();
        assert_eq!(allocs, 0, "decode hot path materialized an input buffer");
        assert_eq!(bytes, 0, "decode hot path copied tensor data");
        assert_eq!(e.output_allocs(), 0, "decode hot path received an allocated output buffer");
    }

    #[test]
    fn churned_decode_is_allocation_free_after_warmup() {
        // staggered admit/retire churn: requests with different prompt
        // and generation lengths retire one by one while later
        // submissions admit into the freed slots, forcing batch-size
        // transitions in both directions. The first wave doubles as
        // warm-up (per-worker scratch growth, lazy artifact compiles);
        // from then on every counter that could betray a hidden
        // allocation, copy, or row move must stay frozen.
        let mut e = engine(4, 42);
        for i in 0..3u64 {
            e.submit(Request::new(i, vec![(i as i32) + 1; 1 + i as usize], 2 + i as usize)).unwrap();
        }
        let (_, warm) = e.serve().unwrap();
        assert_eq!(warm.kv_rows_migrated, 0);
        // post-warmup baseline (store counters should already be zero —
        // the stricter claim — but the churn assertion below only needs
        // them frozen).
        let (a0, b0) = e.store_counters();
        assert_eq!((a0, b0), (0, 0), "warm-up wave itself copied tensor data");
        let out0 = e.output_allocs();
        assert_eq!(out0, 0, "warm-up wave itself allocated output buffers");

        // churn wave: more requests than slots, staggered lengths.
        for i in 10..16u64 {
            e.submit(Request::new(i, vec![(i as i32) % 7 + 1; 1 + (i as usize % 3)], 1 + (i as usize % 4)))
                .unwrap();
        }
        let (out, stats) = e.serve().unwrap();
        // finished accumulates across waves: 3 warm-up + 6 churn.
        assert_eq!(out.len(), 9);
        assert!(stats.batch_sizes.iter().any(|&b| b >= 3), "churn never filled the batch");
        assert_eq!(stats.kv_rows_migrated, 0, "churn migrated KV rows");
        let (allocs, bytes) = e.store_counters();
        assert_eq!((allocs, bytes), (0, 0), "churned decode copied tensor data");
        assert_eq!(e.output_allocs(), out0, "churned decode allocated output buffers");
    }

    #[test]
    fn retirements_do_not_migrate_kv() {
        // staggered generation lengths: requests retire one at a time
        // while the rest keep decoding. Under prefix compaction every
        // retirement remapped the survivors' slots and moved their KV
        // rows; with stable slots the counter must stay at zero across
        // retirements — not just across batch-size transitions.
        let mut e = engine(4, 42);
        for i in 0..4u64 {
            e.submit(Request::new(i, vec![(i as i32) + 1, 3], 2 + i as usize)).unwrap();
        }
        let (out, stats) = e.serve().unwrap();
        assert_eq!(out.len(), 4);
        for (id, toks) in &out {
            assert_eq!(toks.len(), 2 + *id as usize, "req {id}");
        }
        assert_eq!(stats.kv_rows_migrated, 0, "retirement migrated KV rows");
        let (allocs, bytes) = e.store_counters();
        assert_eq!((allocs, bytes), (0, 0), "decode hot path copied tensor data");
        // the batch ramps down as requests retire.
        assert!(stats.batch_sizes.iter().any(|&b| b < 4));
    }

    #[test]
    fn weights_initialized_once_and_shared() {
        // four specializations (1, 2, 4, 8) — still one weight init and
        // one weight allocation.
        let e = engine(8, 42);
        assert_eq!(e.sessions.len(), 4);
        assert_eq!(e.weight_init_runs(), 1, "weights synthesized more than once");
        // every session's embed table is the *same memory*.
        let ptrs: Vec<_> = e
            .sessions
            .values()
            .map(|s| {
                let id = s.exec.graph().graph.tensor_by_name("embed.weight").unwrap().id;
                s.store.view(id).as_ptr()
            })
            .collect();
        assert!(ptrs.windows(2).all(|w| w[0] == w[1]), "weight tensors not aliased");
        // no session's own slab is large enough to be hiding a weight
        // copy: activations are strictly smaller than the params.
        for s in e.sessions.values() {
            assert!(
                s.store.owned_len() < e.weight_arena_len(),
                "session store still packs a private weight copy"
            );
        }
    }

    #[test]
    fn oversized_request_is_rejected_not_fatal() {
        let mut e = engine(2, 5);
        let s_max = e.manifest.s_max;
        let err = e.submit(Request::new(0, vec![1; s_max], 1)).unwrap_err();
        assert!(matches!(err, EngineError::RequestTooLong { id: 0, .. }), "got: {err}");
        // the engine keeps serving legal requests afterwards.
        e.submit(Request::new(1, vec![5], 2)).unwrap();
        let (out, _) = e.serve().unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[&1].len(), 2);
    }

    #[test]
    fn batch_size_transitions_do_not_migrate_kv() {
        // second wave admitted after the first fully retires: the batch
        // size transitions 2 → 0 → 1 but no surviving request ever
        // changes slot, so the shared arena moves nothing.
        let mut e = engine(2, 13);
        e.submit(Request::new(0, vec![3, 4], 3)).unwrap();
        e.submit(Request::new(1, vec![5, 6], 3)).unwrap();
        e.submit(Request::new(2, vec![7], 2)).unwrap();
        let (out, stats) = e.serve().unwrap();
        assert_eq!(out.len(), 3);
        assert!(stats.batch_sizes.contains(&2) && stats.batch_sizes.contains(&1));
        assert_eq!(stats.kv_rows_migrated, 0, "batch transition migrated KV rows");
    }

    #[test]
    fn greedy_decode_is_deterministic() {
        let run = || {
            let mut e = engine(2, 9);
            e.submit(Request::new(0, vec![5, 6, 7], 5)).unwrap();
            e.serve().unwrap().0
        };
        assert_eq!(run()[&0], run()[&0]);
    }

    #[test]
    fn staggered_admission_continuous_batching() {
        // more requests than slots: later ones admitted as earlier retire.
        let mut e = engine(2, 11);
        for i in 0..5u64 {
            e.submit(Request::new(i, vec![1 + i as i32], 2 + (i as usize % 2))).unwrap();
        }
        let (out, stats) = e.serve().unwrap();
        assert_eq!(out.len(), 5);
        for (id, toks) in &out {
            assert_eq!(toks.len(), 2 + (*id as usize % 2), "req {id}");
        }
        // batch ramps: some iterations ran with 2 active requests.
        assert!(stats.batch_sizes.iter().any(|&b| b == 2));
        // churn through retirements and re-admissions never moves rows.
        assert_eq!(stats.kv_rows_migrated, 0);
    }

    #[test]
    fn single_request_matches_single_session_decode() {
        // engine output for one request == direct RealSession loop.
        let mut e = engine(1, 42);
        e.submit(Request::new(0, vec![7], 3)).unwrap();
        let (out, _) = e.serve().unwrap();

        let s = crate::exec::real::RealSession::create(1, 2, 42).unwrap();
        let mut kernel = s.persistent_kernel(4, 1);
        let exec = TileExecutor::new(&s.compiled.graph, &s.store, &s.pool, 1);
        let mut ids = vec![7i32];
        let mut got = Vec::new();
        for step in 0..4 {
            real::set_ids(&s.compiled.graph, &s.store, &ids).unwrap();
            crate::exec::real::run_iteration(&mut kernel, &exec, step).unwrap();
            let logits = real::get_logits(&s.compiled.graph, &s.store).unwrap();
            let tok = real::argmax(&logits) as i32;
            got.push(tok);
            ids = vec![tok];
        }
        // prompt len 1 → first iteration already yields generated[0].
        assert_eq!(out[&0], got[..3].to_vec());
    }

    #[test]
    fn stats_quantiles_nearest_rank() {
        let mut s = ServeStats::default();
        assert_eq!(s.p50_latency(), Duration::ZERO);
        assert_eq!(s.p99_latency(), Duration::ZERO);
        s.iter_latencies = (1..=100).map(Duration::from_millis).collect();
        assert_eq!(s.p50_latency(), Duration::from_millis(50));
        assert_eq!(s.p99_latency(), Duration::from_millis(99));
        // selection must not depend on input order.
        s.iter_latencies.reverse();
        assert_eq!(s.p50_latency(), Duration::from_millis(50));
        assert_eq!(s.p99_latency(), Duration::from_millis(99));
        // nearest-rank on a small sample: p99 of 10 is the max — the
        // old floor((n-1)·q) indexing returned the 9th of 10 here.
        s.iter_latencies = (1..=10).map(Duration::from_millis).collect();
        assert_eq!(s.p99_latency(), Duration::from_millis(10));
        assert_eq!(s.p50_latency(), Duration::from_millis(5));
        // single sample: every quantile is that sample.
        s.iter_latencies = vec![Duration::from_millis(3)];
        assert_eq!(s.p50_latency(), Duration::from_millis(3));
        assert_eq!(s.p99_latency(), Duration::from_millis(3));
    }

    #[test]
    fn stats_request_latency_quantiles() {
        let mut s = ServeStats::default();
        assert_eq!(s.ttft_p99(), Duration::ZERO);
        assert_eq!(s.completion_p50(), Duration::ZERO);
        for i in 1..=10u64 {
            s.request_latency.insert(
                i,
                RequestLatency {
                    ttft: Some(Duration::from_millis(i)),
                    completion: Some(Duration::from_millis(10 * i)),
                },
            );
        }
        // a cancelled-before-first-token request contributes a
        // completion sample but no ttft sample.
        s.request_latency
            .insert(99, RequestLatency { ttft: None, completion: Some(Duration::from_millis(1)) });
        assert_eq!(s.ttft_p50(), Duration::from_millis(5));
        assert_eq!(s.ttft_p99(), Duration::from_millis(10));
        assert_eq!(s.completion_p99(), Duration::from_millis(100));
        // 11 completion samples: 1, 10, 20, ..., 100 → p50 is the 6th.
        assert_eq!(s.completion_p50(), Duration::from_millis(50));
    }

    #[test]
    fn throughput_uses_busy_time_not_wall_clock() {
        // a streaming caller that sleeps between steps accumulates wall
        // time but not busy time; throughput must not collapse.
        let s = ServeStats {
            tokens_generated: 100,
            busy: Duration::from_secs(1),
            total: Duration::from_secs(100),
            ..Default::default()
        };
        assert!((s.throughput_tok_s() - 100.0).abs() < 1e-6, "got {}", s.throughput_tok_s());
    }

    fn paged_engine(max_batch: usize, seed: u64) -> ServeEngine {
        ServeEngine::builder()
            .max_batch(max_batch)
            .pool_threads(2)
            .seed(seed)
            .mega(mega())
            .backend(BackendKind::Cpu)
            .paged_kv(true)
            .build()
            .unwrap()
    }

    #[test]
    fn paged_builder_gates_are_typed() {
        let base =
            || ServeEngine::builder().max_batch(2).mega(mega()).backend(BackendKind::Cpu).paged_kv(true);
        let err = base().compaction(true).build().unwrap_err();
        assert!(
            matches!(&err, EngineError::InvalidConfig(m) if m.contains("compaction")),
            "got: {err}"
        );
        let err = base().kv_block_tokens(0).build().unwrap_err();
        assert!(
            matches!(&err, EngineError::InvalidConfig(m) if m.contains("kv_block_tokens")),
            "got: {err}"
        );
        let err = base().kv_block_tokens(7).build().unwrap_err();
        assert!(
            matches!(&err, EngineError::InvalidConfig(m) if m.contains("divide")),
            "got: {err}"
        );
        let err = ServeEngine::builder()
            .max_batch(2)
            .mega(mega())
            .backend(BackendKind::Pjrt)
            .paged_kv(true)
            .build()
            .unwrap_err();
        assert!(
            matches!(&err, EngineError::InvalidConfig(m) if m.contains("CPU backend")),
            "got: {err}"
        );
        let err = ServeEngine::builder()
            .max_batch(2)
            .mega(mega())
            .backend(BackendKind::Cpu)
            .prefill_chunk(2)
            .build()
            .unwrap_err();
        assert!(
            matches!(&err, EngineError::InvalidConfig(m) if m.contains("prefill_chunk")),
            "got: {err}"
        );
    }

    #[test]
    fn paged_decode_matches_legacy_and_stays_zero_copy() {
        // same requests through block-table indirection and through the
        // slot-contiguous legacy path: bit-identical tokens, and the
        // paged path holds the same four zero counters in steady state.
        let run = |paged: bool| {
            let mut e = ServeEngine::builder()
                .max_batch(4)
                .pool_threads(2)
                .seed(42)
                .mega(mega())
                .backend(BackendKind::Cpu)
                .paged_kv(paged)
                .build()
                .unwrap();
            for i in 0..4u64 {
                e.submit(Request::new(i, vec![(i as i32) + 1, 9, 4], 5)).unwrap();
            }
            let (out, stats) = e.serve().unwrap();
            assert_eq!(e.store_counters(), (0, 0), "paged={paged}: decode copied tensor data");
            assert_eq!(e.output_allocs(), 0, "paged={paged}: decode allocated output buffers");
            assert_eq!(stats.kv_rows_migrated, 0, "paged={paged}: decode migrated KV rows");
            out
        };
        assert_eq!(run(true), run(false), "paged decode diverged from slot-contiguous decode");
    }

    #[test]
    fn shared_system_prompt_wave_shares_blocks_and_cows_honestly() {
        // 32 requests behind one 16-token system prompt (two full
        // 8-token blocks) through an 8-slot engine: the first wave
        // publishes the prompt's blocks; every later admission maps
        // them (refcount bump, no copy), resumes past the shared
        // prefix, and pays exactly one COW copy when its first append
        // lands in the shared tail block.
        let mut e = paged_engine(8, 42);
        let sys: Vec<i32> = (0..16).map(|i| (i % 7) + 1).collect();
        for i in 0..32u64 {
            e.submit(Request::new(i, sys.clone(), 4)).unwrap();
        }
        let (out, stats) = e.serve().unwrap();
        assert_eq!(out.len(), 32);
        for i in 1..32u64 {
            assert_eq!(out[&i], out[&0], "req {i}: shared-prefix decode diverged");
        }
        let pool = e.batcher.kv.paged().unwrap();
        // per-request worst case is 3 blocks (16 prompt + 4 generated
        // rows); 32 requests would cost 96 without sharing.
        assert!(
            pool.blocks_allocated() < 96,
            "allocated {} blocks — prefix sharing never kicked in",
            pool.blocks_allocated()
        );
        assert!(pool.prefix_hits() > 0, "no admission mapped a shared block");
        assert!(stats.kv_blocks_shared > 0, "sharing gauge never saw refcount >= 2");
        assert!(stats.kv_blocks_cowed > 0, "appends into shared tail blocks never COWed");
        // COW block copies are the *only* copies — the store/pool
        // counters that guard the decode hot path stay at zero.
        assert_eq!(e.store_counters(), (0, 0));
        assert_eq!(e.output_allocs(), 0);
        assert_eq!(stats.kv_rows_migrated, 0);
        pool.check_invariants().unwrap();
    }

    #[test]
    fn pool_exhaustion_mid_decode_sheds_typed_never_panics() {
        // 2 slots * (s_max 64 / bt 8) = 16 blocks. The engine's own
        // sizing can never exhaust (validation bounds every admission
        // by the whole pool), so starve it deliberately with fake
        // pool-level reservations until a mid-decode growth has
        // nowhere to go.
        let mut e = paged_engine(2, 42);
        e.submit(Request::new(0, vec![1, 2, 3, 4], 8)).unwrap();
        e.step().unwrap(); // admits; block 0 covers cache rows 0..8
        {
            let pool = e.batcher.kv.paged_mut().unwrap();
            let mut filler = 900u64;
            while pool.free_blocks() > 0 {
                let take = pool.free_blocks().min(7) * pool.block_tokens();
                assert!(pool.admit(filler, &vec![1; take]).is_some());
                filler += 1;
            }
        }
        // decode crosses into block 1 at position 8: growth fails, the
        // victim is displaced with a typed terminal event — no panic,
        // partial output preserved.
        let mut shed = None;
        for _ in 0..12 {
            let out = e.step().unwrap();
            if let Some(ev) = out.events.iter().find(|ev| ev.finish == Some(FinishReason::Shed)) {
                shed = Some(ev.clone());
                break;
            }
        }
        let ev = shed.expect("exhausted pool never shed the victim");
        assert_eq!(ev.request, 0);
        assert_eq!(ev.token, None);
        let done = e.batcher.finished.iter().find(|r| r.id == 0).unwrap();
        assert_eq!(done.finish, Some(FinishReason::Shed));
        assert!(!done.generated.is_empty(), "partial output must survive displacement");
        // releasing the fake reservations un-wedges the engine.
        {
            let pool = e.batcher.kv.paged_mut().unwrap();
            for f in 900..910u64 {
                let _ = pool.release(f);
            }
            pool.check_invariants().unwrap();
        }
        e.submit(Request::new(1, vec![5], 2)).unwrap();
        let events = drain(&mut e);
        assert_eq!(events.iter().filter(|ev| ev.request == 1).filter_map(|ev| ev.token).count(), 2);
    }

    #[test]
    fn chunked_prefill_speeds_ttft_without_stalling_decode() {
        // a short request decodes alone for a few steps, then a long
        // prompt arrives mid-flight. With a chunk budget the long
        // prompt prefills several positions per step; the established
        // decoder must keep emitting exactly one token every step.
        let long_prompt: Vec<i32> = (0..40).map(|i| (i % 11) + 1).collect();
        let run = |chunk: usize| {
            let mut e = ServeEngine::builder()
                .max_batch(2)
                .pool_threads(2)
                .seed(42)
                .mega(mega())
                .backend(BackendKind::Cpu)
                .paged_kv(true)
                .prefill_chunk(chunk)
                .build()
                .unwrap();
            e.submit(Request::new(0, vec![3, 11], 24)).unwrap();
            for _ in 0..3 {
                e.step().unwrap();
            }
            e.submit(Request::new(1, long_prompt.clone(), 4)).unwrap();
            let mut events = Vec::new();
            let mut decode_per_step = Vec::new();
            let mut first_long_token_step = None;
            let mut steps = 0usize;
            while e.has_work() {
                steps += 1;
                assert!(steps < 200, "step loop livelock");
                let out = e.step().unwrap();
                decode_per_step.push(
                    out.events.iter().filter(|ev| ev.request == 0 && ev.token.is_some()).count(),
                );
                if first_long_token_step.is_none()
                    && out.events.iter().any(|ev| ev.request == 1 && ev.token.is_some())
                {
                    first_long_token_step = Some(steps);
                }
                events.extend(out.events);
            }
            let stats = e.take_stats();
            assert_eq!(e.store_counters(), (0, 0), "chunk={chunk}: prefill copied tensor data");
            assert_eq!(e.output_allocs(), 0, "chunk={chunk}: prefill allocated outputs");
            let per_req = |id: u64| -> Vec<i32> {
                events.iter().filter(|ev| ev.request == id).filter_map(|ev| ev.token).collect()
            };
            (per_req(0), per_req(1), first_long_token_step.unwrap(), decode_per_step, stats)
        };
        let (d0, l0, ttft0, _, s0) = run(0);
        let (d4, l4, ttft4, cadence, s4) = run(4);
        // chunking changes *when* the long prompt finishes prefill,
        // never *what* anyone decodes.
        assert_eq!(d0, d4, "chunked prefill disturbed the concurrent decoder's tokens");
        assert_eq!(l0, l4, "chunked prefill changed the long prompt's continuation");
        assert!(
            ttft4 < ttft0,
            "chunk budget 4 did not speed first token: {ttft4} vs {ttft0} steps"
        );
        assert_eq!(s0.prefill_chunks, 0, "chunking off must run no extra epochs");
        assert!(s4.prefill_chunks > 0, "chunking on never ran an extra epoch");
        // decode cadence: the short request emits exactly one token in
        // every step until its terminal event, chunked prefill or not.
        let last_decode_step =
            cadence.iter().rposition(|&n| n > 0).expect("decoder emitted nothing");
        assert!(
            cadence[..=last_decode_step].iter().all(|&n| n == 1),
            "decode cadence broke under concurrent chunked prefill: {cadence:?}"
        );
    }

    #[test]
    fn kv_status_surfaces_pool_occupancy_and_prefill_counters() {
        let mut e = paged_engine(2, 42);
        let s0 = e.kv_status();
        assert_eq!(s0.blocks_total, 16, "2 slots * 64 tokens / 8-token blocks");
        assert_eq!(s0.blocks_free, 16);
        e.submit(Request::new(0, vec![1; 9], 3)).unwrap();
        e.step().unwrap();
        let s1 = e.kv_status();
        assert_eq!(s1.blocks_free, 14, "a 9-token prompt reserves exactly two blocks");
        // the legacy engine reports capacity through the same surface,
        // with the paged-only counters at zero.
        let l = engine(2, 42);
        let ls = l.kv_status();
        assert_eq!(ls.blocks_total, 16);
        assert_eq!(ls.blocks_free, 16);
        assert_eq!(ls.blocks_shared, 0);
        assert_eq!(ls.prefill_chunks, 0);
    }
}
