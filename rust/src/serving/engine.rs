//! The serving engine: continuous batching over the real-numerics
//! megakernel (§6.1), with a persistent runtime and resident KV.
//!
//! Each batch-size specialization is a long-lived [`Session`]: the
//! compiled graph (shared via `Arc` with its kernel), the tensor store
//! holding weights *and the KV cache*, a [`PersistentMegaKernel`] whose
//! worker/scheduler threads park between iterations, and tensor-id
//! tables resolved once at creation.
//!
//! Per decode iteration: retire/admit (the paper's start-event task),
//! pick the batch-size-specialized session (powers of two), reconcile
//! KV residency — the cache lives in the `TensorStore` across
//! iterations, so rows are copied only when a request was admitted into
//! a different store or its slot moved during compaction — stage the
//! input tokens, re-arm the resident kernel, then harvest logits
//! (greedy decoding). The newly appended KV row is written in-kernel by
//! `KvAppend`; the engine never round-trips full cache tensors.

use crate::exec::binder::TileExecutor;
use crate::exec::real::{self, compile_real, init_weights};
use crate::exec::store::TensorStore;
use crate::megakernel::{MegaConfig, PersistentMegaKernel};
use crate::ops::{Region, TensorId};
use crate::runtime::pool::ExecPool;
use crate::runtime::Manifest;
use crate::serving::batcher::{Batcher, Request};
use crate::serving::kvcache::{KvAllocator, KvResidency};
use crate::tgraph::CompiledGraph;
use std::collections::HashMap;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// One batch-size specialization: compiled graph, its tensor store
/// (weights + resident KV), the persistent kernel, and hot-path tensor
/// ids resolved once at creation.
struct Session {
    compiled: Arc<CompiledGraph>,
    store: TensorStore,
    kernel: PersistentMegaKernel,
    /// Per-layer `(kcache, vcache)` tensor ids.
    kv_ids: Vec<(TensorId, TensorId)>,
    token_ids: TensorId,
    logits: TensorId,
}

/// Serving statistics.
#[derive(Clone, Debug, Default)]
pub struct ServeStats {
    pub iterations: usize,
    pub tokens_generated: usize,
    pub total: Duration,
    pub iter_latencies: Vec<Duration>,
    /// Tokens in flight per iteration (batch-utilization curve).
    pub batch_sizes: Vec<usize>,
    /// K/V rows copied between (or within) session stores on admission
    /// or slot remap, summed over layers. Zero on a steady-state
    /// iteration — the residency check that the hot path stages only
    /// the in-kernel-appended row.
    pub kv_rows_migrated: usize,
}

impl ServeStats {
    pub fn throughput_tok_s(&self) -> f64 {
        self.tokens_generated as f64 / self.total.as_secs_f64().max(1e-9)
    }

    /// `q`-quantile of per-iteration latency via `select_nth_unstable`
    /// — O(n), no full sort. One clone of the latency vector is still
    /// needed because selection reorders in place.
    fn latency_quantile(&self, q: f64) -> Duration {
        if self.iter_latencies.is_empty() {
            return Duration::ZERO;
        }
        let mut v = self.iter_latencies.clone();
        let idx = (((v.len() - 1) as f64) * q).floor() as usize;
        let (_, nth, _) = v.select_nth_unstable(idx);
        *nth
    }

    pub fn p50_latency(&self) -> Duration {
        self.latency_quantile(0.50)
    }

    pub fn p99_latency(&self) -> Duration {
        self.latency_quantile(0.99)
    }
}

/// The engine.
pub struct ServeEngine {
    pub manifest: Manifest,
    pool: ExecPool,
    sessions: HashMap<usize, Session>,
    pub batcher: Batcher,
    residency: KvResidency,
}

impl ServeEngine {
    /// Build an engine with specialized sessions (graph + store +
    /// persistent kernel) for each manifest batch size up to
    /// `max_batch`. `max_batch` must be one of the manifest's sizes.
    pub fn create(max_batch: usize, pool_threads: usize, seed: u64, mega: MegaConfig) -> Result<Self, String> {
        let manifest = Manifest::load(&Manifest::default_dir())?;
        if !manifest.batch_sizes.contains(&max_batch) {
            return Err(format!("max_batch {max_batch} not among specialized sizes {:?}", manifest.batch_sizes));
        }
        let m = manifest.model;
        let mut sessions = HashMap::new();
        for &b in manifest.batch_sizes.iter().filter(|&&b| b <= max_batch) {
            let compiled = Arc::new(compile_real(&manifest, b));
            let store = TensorStore::new(&compiled.graph);
            init_weights(&compiled.graph, &store, seed);
            // hoist every per-iteration name lookup to creation time.
            let id = |name: &str| -> Result<TensorId, String> {
                Ok(compiled.graph.tensor_by_name(name).ok_or_else(|| format!("missing tensor {name}"))?.id)
            };
            let kv_ids = (0..m.layers)
                .map(|l| Ok((id(&format!("l{l}.kcache"))?, id(&format!("l{l}.vcache"))?)))
                .collect::<Result<Vec<_>, String>>()?;
            let token_ids = id("token_ids")?;
            let logits = id("lm_head")?;
            let kernel = PersistentMegaKernel::new(compiled.clone(), mega);
            sessions.insert(b, Session { compiled, store, kernel, kv_ids, token_ids, logits });
        }
        let pool = ExecPool::new(manifest.clone(), pool_threads)?;
        // one KV block = 8 tokens; pool sized for max_batch full seqs.
        let blocks = max_batch * manifest.s_max / 8;
        let batcher = Batcher::new(max_batch, manifest.s_max, KvAllocator::new(blocks, 8));
        Ok(ServeEngine { manifest, pool, sessions, batcher, residency: KvResidency::default() })
    }

    pub fn submit(&mut self, r: Request) {
        self.batcher.submit(r);
    }

    /// Make every active request's KV rows resident in session `gb` at
    /// its assigned batcher slot, copying only on admission to a
    /// different store or slot compaction; returns rows moved (×layers).
    ///
    /// Iterates in ascending slot order, which makes in-store
    /// compaction safe without double-buffering: survivors only ever
    /// move to *lower* slots (the batcher compacts with `swap_remove`
    /// then reassigns 0..n in order), so if some move's destination
    /// aliases another request's source slot, that request sits at a
    /// lower destination and is migrated — its source read — first.
    fn reconcile_residency(&mut self, gb: usize, kv_dim: usize) -> usize {
        let layers = self.manifest.model.layers;
        let mut moved = 0usize;
        for (slot, r) in self.batcher.active.iter().enumerate() {
            let cur = self.residency.home(r.id);
            if cur == Some((gb, slot)) {
                continue;
            }
            if let Some((hgb, hslot)) = cur {
                let rows = r.cache_len;
                if rows > 0 {
                    // run-by-run copy, no staging buffer: intra-store
                    // compaction (hgb == gb, disjoint slots) and
                    // cross-store migration share one path.
                    let dst_r = Region::new(vec![(slot, slot + 1), (0, rows), (0, kv_dim)]);
                    let src_r = Region::new(vec![(hslot, hslot + 1), (0, rows), (0, kv_dim)]);
                    let sh = &self.sessions[&hgb];
                    let sd = &self.sessions[&gb];
                    for l in 0..layers {
                        let (skt, svt) = sh.kv_ids[l];
                        let (dkt, dvt) = sd.kv_ids[l];
                        sd.store.copy_tile_from(dkt, &dst_r, &sh.store, skt, &src_r);
                        sd.store.copy_tile_from(dvt, &dst_r, &sh.store, svt, &src_r);
                    }
                    moved += rows * layers;
                }
            }
            self.residency.set(r.id, gb, slot);
        }
        moved
    }

    /// Drive everything to completion; returns per-request outputs and
    /// stats. Deterministic: greedy decoding, seeded weights.
    pub fn serve(&mut self) -> Result<(HashMap<u64, Vec<i32>>, ServeStats), String> {
        let mut stats = ServeStats::default();
        let t0 = Instant::now();
        let m = self.manifest.model;
        let (kv_dim, vocab) = (m.kv_dim(), m.vocab);

        while self.batcher.has_work() {
            for id in self.batcher.step_admission() {
                self.residency.evict(id);
            }
            let active = self.batcher.active.len();
            if active == 0 {
                break;
            }
            let gb = self.batcher.graph_batch();
            if !self.sessions.contains_key(&gb) {
                return Err(format!("no session for batch {gb}"));
            }

            // KV stays resident in the store: copy rows only on
            // admit/slot-remap (zero rows on a steady-state iteration).
            stats.kv_rows_migrated += self.reconcile_residency(gb, kv_dim);

            // stage inputs: this iteration's token per row, row lengths.
            let mut ids = vec![0i32; gb];
            let mut lens = vec![0usize; gb];
            for (slot, r) in self.batcher.active.iter().enumerate() {
                ids[slot] = r.next_input();
                lens[slot] = r.cache_len;
            }
            let session = self.sessions.get_mut(&gb).unwrap();
            real::set_ids_at(&session.store, session.token_ids, &ids);

            // re-arm the resident mega-kernel: no thread spawn/join, no
            // kernel construction, no name lookups on this path.
            let exec = TileExecutor::new(&session.compiled.graph, &session.store, &self.pool, gb);
            exec.set_row_lens(&lens);
            let it0 = Instant::now();
            session.kernel.run(&exec)?;
            if let Some(e) = exec.take_error() {
                return Err(e);
            }
            let lat = it0.elapsed();
            stats.iterations += 1;
            stats.iter_latencies.push(lat);
            stats.batch_sizes.push(active);

            // harvest: logits → next token. KV needs no read-back —
            // KvAppend already wrote this step's row in the resident
            // cache.
            let logits = real::logits_at(&session.store, session.logits);
            for slot in 0..active {
                let r = &mut self.batcher.active[slot];
                r.cache_len += 1;
                let tok = real::argmax(&logits[slot * vocab..(slot + 1) * vocab]) as i32;
                if r.in_prefill() {
                    r.prompt_pos += 1;
                    if !r.in_prefill() {
                        r.generated.push(tok);
                        stats.tokens_generated += 1;
                    }
                } else {
                    r.generated.push(tok);
                    stats.tokens_generated += 1;
                }
            }
        }
        stats.total = t0.elapsed();
        let outputs = self
            .batcher
            .finished
            .iter()
            .map(|r| (r.id, r.generated.clone()))
            .collect();
        Ok((outputs, stats))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn have_artifacts() -> bool {
        Manifest::load(&Manifest::default_dir()).is_ok()
    }

    fn mega() -> MegaConfig {
        MegaConfig { workers: 4, schedulers: 1, ..Default::default() }
    }

    #[test]
    fn serves_batch_to_completion() {
        if !have_artifacts() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let mut e = ServeEngine::create(4, 2, 42, mega()).unwrap();
        for i in 0..3u64 {
            e.submit(Request::new(i, vec![(i as i32) + 1, 7], 4));
        }
        let (out, stats) = e.serve().unwrap();
        assert_eq!(out.len(), 3);
        for (_, toks) in &out {
            assert_eq!(toks.len(), 4);
            for &t in toks {
                assert!((0..512).contains(&t));
            }
        }
        assert_eq!(stats.tokens_generated, 12);
        assert!(stats.iterations >= 5, "prompt 2 + gen 4 - 1 overlap");
        // all requests admitted at once into one session and never
        // remapped: no KV rows should ever have been copied.
        assert_eq!(stats.kv_rows_migrated, 0, "steady batch migrated KV rows");
    }

    #[test]
    fn greedy_decode_is_deterministic() {
        if !have_artifacts() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let run = || {
            let mut e = ServeEngine::create(2, 2, 9, mega()).unwrap();
            e.submit(Request::new(0, vec![5, 6, 7], 5));
            e.serve().unwrap().0
        };
        assert_eq!(run()[&0], run()[&0]);
    }

    #[test]
    fn staggered_admission_continuous_batching() {
        if !have_artifacts() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        // more requests than slots: later ones admitted as earlier retire.
        let mut e = ServeEngine::create(2, 2, 11, mega()).unwrap();
        for i in 0..5u64 {
            e.submit(Request::new(i, vec![1 + i as i32], 2 + (i as usize % 2)));
        }
        let (out, stats) = e.serve().unwrap();
        assert_eq!(out.len(), 5);
        for (id, toks) in &out {
            assert_eq!(toks.len(), 2 + (*id as usize % 2), "req {id}");
        }
        // batch ramps: some iterations ran with 2 active requests.
        assert!(stats.batch_sizes.iter().any(|&b| b == 2));
    }

    #[test]
    fn single_request_matches_single_session_decode() {
        if !have_artifacts() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        // engine output for one request == direct RealSession loop.
        let mut e = ServeEngine::create(1, 2, 42, mega()).unwrap();
        e.submit(Request::new(0, vec![7], 3));
        let (out, _) = e.serve().unwrap();

        let s = crate::exec::real::RealSession::create(1, 2, 42).unwrap();
        let kernel = crate::megakernel::MegaKernel::new(&s.compiled, mega());
        let exec = TileExecutor::new(&s.compiled.graph, &s.store, &s.pool, 1);
        let mut ids = vec![7i32];
        let mut got = Vec::new();
        for step in 0..4 {
            real::set_ids(&s.compiled.graph, &s.store, &ids);
            crate::exec::real::run_iteration(&kernel, &exec, step).unwrap();
            let logits = real::get_logits(&s.compiled.graph, &s.store);
            let tok = real::argmax(&logits) as i32;
            if step >= 0 {
                got.push(tok);
            }
            ids = vec![tok];
        }
        // prompt len 1 → first iteration already yields generated[0].
        assert_eq!(out[&0], got[..3].to_vec());
    }

    #[test]
    fn stats_quantiles() {
        let mut s = ServeStats::default();
        assert_eq!(s.p50_latency(), Duration::ZERO);
        assert_eq!(s.p99_latency(), Duration::ZERO);
        s.iter_latencies = (1..=100).map(Duration::from_millis).collect();
        assert_eq!(s.p50_latency(), Duration::from_millis(50));
        assert_eq!(s.p99_latency(), Duration::from_millis(99));
        // selection must not depend on input order.
        s.iter_latencies.reverse();
        assert_eq!(s.p50_latency(), Duration::from_millis(50));
        assert_eq!(s.p99_latency(), Duration::from_millis(99));
    }
}
