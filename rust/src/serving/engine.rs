//! The serving engine: continuous batching over the real-numerics
//! megakernel (§6.1), with a persistent runtime, resident KV, and a
//! zero-copy decode hot path.
//!
//! Each batch-size specialization is a long-lived [`Session`]: a tensor
//! arena holding weights and activations, a [`PersistentMegaKernel`]
//! whose worker/scheduler threads park between iterations, a resident
//! `OwningTileExecutor`, and tensor ids resolved once at creation. All
//! sessions alias **one shared max-batch [`KvArena`]** for their KV
//! cache tensors: a batch-`b` graph's `l{l}.kcache` is the first `b`
//! slots of the arena's layer segment, so switching specializations
//! re-interprets the same memory instead of migrating rows.
//!
//! Per decode iteration: retire/admit (the paper's start-event task),
//! pick the batch-size-specialized session (powers of two), reconcile
//! KV residency — rows move only on slot compaction after a retirement,
//! never on a batch-size transition — stage the input tokens, re-arm
//! the resident kernel, then harvest logits through a borrowed arena
//! view (greedy decoding). The newly appended KV row is written
//! in-kernel by `KvAppend`; the engine never copies a tensor on the
//! steady-state path (asserted via the store's read-side counters).

use crate::exec::binder::OwningTileExecutor;
use crate::exec::real::{self, compile_real, init_weights};
use crate::exec::store::TensorStore;
use crate::megakernel::{MegaConfig, PersistentMegaKernel};
use crate::ops::TensorId;
use crate::runtime::pool::ExecPool;
use crate::runtime::Manifest;
use crate::serving::batcher::{Batcher, Request};
use crate::serving::kvcache::{KvAllocator, KvArena, KvResidency};
use std::collections::HashMap;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// One batch-size specialization: tensor arena (weights + activations,
/// KV aliased into the shared arena), the persistent kernel, the
/// resident executor, and hot-path tensor ids resolved once at creation.
struct Session {
    store: Arc<TensorStore>,
    kernel: PersistentMegaKernel,
    exec: OwningTileExecutor,
    token_ids: TensorId,
    logits: TensorId,
}

/// Serving statistics.
#[derive(Clone, Debug, Default)]
pub struct ServeStats {
    pub iterations: usize,
    pub tokens_generated: usize,
    pub total: Duration,
    pub iter_latencies: Vec<Duration>,
    /// Tokens in flight per iteration (batch-utilization curve).
    pub batch_sizes: Vec<usize>,
    /// K/V rows moved within the shared max-batch arena on slot
    /// compaction after a retirement, summed over layers. Zero on a
    /// steady-state iteration — and zero across batch-size transitions,
    /// because every specialization aliases the same arena.
    pub kv_rows_migrated: usize,
}

impl ServeStats {
    pub fn throughput_tok_s(&self) -> f64 {
        self.tokens_generated as f64 / self.total.as_secs_f64().max(1e-9)
    }

    /// `q`-quantile of per-iteration latency via `select_nth_unstable`
    /// — O(n), no full sort. One clone of the latency vector is still
    /// needed because selection reorders in place.
    fn latency_quantile(&self, q: f64) -> Duration {
        if self.iter_latencies.is_empty() {
            return Duration::ZERO;
        }
        let mut v = self.iter_latencies.clone();
        let idx = (((v.len() - 1) as f64) * q).floor() as usize;
        let (_, nth, _) = v.select_nth_unstable(idx);
        *nth
    }

    pub fn p50_latency(&self) -> Duration {
        self.latency_quantile(0.50)
    }

    pub fn p99_latency(&self) -> Duration {
        self.latency_quantile(0.99)
    }
}

/// The engine.
pub struct ServeEngine {
    pub manifest: Manifest,
    pool: Arc<ExecPool>,
    sessions: HashMap<usize, Session>,
    pub batcher: Batcher,
    residency: KvResidency,
    kv_arena: KvArena,
}

impl ServeEngine {
    /// Build an engine with specialized sessions (graph + arena +
    /// persistent kernel + resident executor) for each manifest batch
    /// size up to `max_batch`, all aliasing one max-batch KV arena.
    /// `max_batch` must be one of the manifest's sizes.
    pub fn create(max_batch: usize, pool_threads: usize, seed: u64, mega: MegaConfig) -> Result<Self, String> {
        let manifest = Manifest::load(&Manifest::default_dir())?;
        if !manifest.batch_sizes.contains(&max_batch) {
            return Err(format!("max_batch {max_batch} not among specialized sizes {:?}", manifest.batch_sizes));
        }
        let m = manifest.model;
        let pool = Arc::new(ExecPool::new(manifest.clone(), pool_threads)?);
        let kv_arena = KvArena::new(m.layers, max_batch, manifest.s_max, m.kv_dim());
        let mut sessions = HashMap::new();
        for &b in manifest.batch_sizes.iter().filter(|&&b| b <= max_batch) {
            let compiled = Arc::new(compile_real(&manifest, b));
            // hoist every per-iteration name lookup to creation time.
            let id = |name: &str| -> Result<TensorId, String> {
                Ok(compiled.graph.tensor_by_name(name).ok_or_else(|| format!("missing tensor {name}"))?.id)
            };
            // alias this session's KV tensors into the shared arena: a
            // batch-b cache tensor [b, s_max, kv_dim] is the first b
            // slots of the layer's [max_batch, s_max, kv_dim] segment.
            let mut aliases = Vec::with_capacity(2 * m.layers);
            for l in 0..m.layers {
                aliases.push((id(&format!("l{l}.kcache"))?, kv_arena.slab(), kv_arena.k_offset(l)));
                aliases.push((id(&format!("l{l}.vcache"))?, kv_arena.slab(), kv_arena.v_offset(l)));
            }
            let store = Arc::new(TensorStore::new_with_aliases(&compiled.graph, aliases));
            init_weights(&compiled.graph, &store, seed);
            let token_ids = id("token_ids")?;
            let logits = id("lm_head")?;
            let kernel = PersistentMegaKernel::new(compiled.clone(), mega);
            let exec = OwningTileExecutor::new(compiled, store.clone(), pool.clone(), b);
            sessions.insert(b, Session { store, kernel, exec, token_ids, logits });
        }
        // one KV block = 8 tokens; pool sized for max_batch full seqs.
        let blocks = max_batch * manifest.s_max / 8;
        let batcher = Batcher::new(max_batch, manifest.s_max, KvAllocator::new(blocks, 8));
        Ok(ServeEngine {
            manifest,
            pool,
            sessions,
            batcher,
            residency: KvResidency::default(),
            kv_arena,
        })
    }

    pub fn submit(&mut self, r: Request) {
        self.batcher.submit(r);
    }

    /// The engine's PJRT pool (shared by every session's executor).
    pub fn pool(&self) -> &ExecPool {
        &self.pool
    }

    /// Sum of read-side `(allocs, bytes_copied)` store counters across
    /// all session arenas — the zero-copy invariant: steady-state
    /// serving leaves both at zero (weight/token staging and in-place
    /// kernel writes are not counted; see `exec::store`).
    pub fn store_counters(&self) -> (u64, u64) {
        self.sessions.values().fold((0, 0), |(a, b), s| {
            let c = s.store.counters();
            (a + c.allocs, b + c.bytes_copied)
        })
    }

    /// Make every active request's KV rows resident at its assigned
    /// batcher slot of the shared arena; returns rows moved (×layers).
    /// Batch-size transitions are free — every session aliases the same
    /// arena — so rows move only on slot compaction after a retirement.
    ///
    /// Iterates in ascending slot order, which makes compaction safe
    /// without double-buffering: survivors only ever move to *lower*
    /// slots (the batcher compacts with `swap_remove` then reassigns
    /// 0..n in order), so if some move's destination aliases another
    /// request's source slot, that request sits at a lower destination
    /// and is moved — its source read — first.
    fn reconcile_residency(&mut self) -> usize {
        let mut moved = 0usize;
        for (slot, r) in self.batcher.active.iter().enumerate() {
            match self.residency.home(r.id) {
                Some(cur) if cur == slot => {}
                Some(cur) => {
                    // the single-pass ascending walk is only sound while
                    // survivors move strictly downward — pin the batcher
                    // invariant this relies on.
                    debug_assert!(
                        cur > slot,
                        "compaction moved a survivor upward ({cur} -> {slot}); \
                         reconcile_residency's ordering argument no longer holds"
                    );
                    moved += self.kv_arena.move_slot(cur, slot, r.cache_len);
                    self.residency.set(r.id, slot);
                }
                None => self.residency.set(r.id, slot),
            }
        }
        moved
    }

    /// Drive everything to completion; returns per-request outputs and
    /// stats. Deterministic: greedy decoding, seeded weights.
    pub fn serve(&mut self) -> Result<(HashMap<u64, Vec<i32>>, ServeStats), String> {
        let mut stats = ServeStats::default();
        let t0 = Instant::now();
        let vocab = self.manifest.model.vocab;

        while self.batcher.has_work() {
            for id in self.batcher.step_admission() {
                self.residency.evict(id);
            }
            let active = self.batcher.active.len();
            if active == 0 {
                break;
            }
            let gb = self.batcher.graph_batch();
            if !self.sessions.contains_key(&gb) {
                return Err(format!("no session for batch {gb}"));
            }

            // KV stays resident in the shared arena: rows move only on
            // slot compaction (zero on a steady-state iteration, zero
            // on batch-size transitions).
            stats.kv_rows_migrated += self.reconcile_residency();

            // stage inputs: this iteration's token per row, row lengths.
            let mut ids = vec![0i32; gb];
            let mut lens = vec![0usize; gb];
            for (slot, r) in self.batcher.active.iter().enumerate() {
                ids[slot] = r.next_input();
                lens[slot] = r.cache_len;
            }
            let session = self.sessions.get_mut(&gb).unwrap();
            real::set_ids_at(&session.store, session.token_ids, &ids);

            // re-arm the resident mega-kernel through the session's
            // long-lived executor: no thread spawn/join, no kernel or
            // executor construction, no name lookups on this path.
            session.exec.set_row_lens(&lens);
            let it0 = Instant::now();
            session.kernel.run(&session.exec)?;
            if let Some(e) = session.exec.take_error() {
                return Err(e);
            }
            let lat = it0.elapsed();
            stats.iterations += 1;
            stats.iter_latencies.push(lat);
            stats.batch_sizes.push(active);

            // harvest: logits → next token, through a borrowed arena
            // view (no copy). KV needs no read-back — KvAppend already
            // wrote this step's row in the resident arena.
            let logits = session.store.view(session.logits);
            for slot in 0..active {
                let r = &mut self.batcher.active[slot];
                r.cache_len += 1;
                let tok = real::argmax(&logits[slot * vocab..(slot + 1) * vocab]) as i32;
                if r.in_prefill() {
                    r.prompt_pos += 1;
                    if !r.in_prefill() {
                        r.generated.push(tok);
                        stats.tokens_generated += 1;
                    }
                } else {
                    r.generated.push(tok);
                    stats.tokens_generated += 1;
                }
            }
        }
        stats.total = t0.elapsed();
        let outputs = self
            .batcher
            .finished
            .iter()
            .map(|r| (r.id, r.generated.clone()))
            .collect();
        Ok((outputs, stats))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::binder::TileExecutor;

    fn have_artifacts() -> bool {
        Manifest::load(&Manifest::default_dir()).is_ok()
    }

    fn mega() -> MegaConfig {
        MegaConfig { workers: 4, schedulers: 1, ..Default::default() }
    }

    #[test]
    fn serves_batch_to_completion() {
        if !have_artifacts() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let mut e = ServeEngine::create(4, 2, 42, mega()).unwrap();
        for i in 0..3u64 {
            e.submit(Request::new(i, vec![(i as i32) + 1, 7], 4));
        }
        let (out, stats) = e.serve().unwrap();
        assert_eq!(out.len(), 3);
        for (_, toks) in &out {
            assert_eq!(toks.len(), 4);
            for &t in toks {
                assert!((0..512).contains(&t));
            }
        }
        assert_eq!(stats.tokens_generated, 12);
        assert!(stats.iterations >= 5, "prompt 2 + gen 4 - 1 overlap");
        // all requests admitted at once and never remapped: no KV rows
        // should ever have moved in the arena.
        assert_eq!(stats.kv_rows_migrated, 0, "steady batch migrated KV rows");
    }

    #[test]
    fn steady_state_decode_is_zero_copy() {
        if !have_artifacts() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        // a uniform wave (same prompt + generation lengths) is admitted
        // together and retired together: the whole run is the steady
        // state the zero-copy invariant promises.
        let mut e = ServeEngine::create(4, 2, 42, mega()).unwrap();
        for i in 0..4u64 {
            e.submit(Request::new(i, vec![(i as i32) + 1, 9], 5));
        }
        let (out, stats) = e.serve().unwrap();
        assert_eq!(out.len(), 4);
        assert_eq!(stats.kv_rows_migrated, 0, "arena moved rows in steady state");
        let (allocs, bytes) = e.store_counters();
        assert_eq!(allocs, 0, "decode hot path materialized an input buffer");
        assert_eq!(bytes, 0, "decode hot path copied tensor data");
    }

    #[test]
    fn batch_size_transitions_do_not_migrate_kv() {
        if !have_artifacts() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        // second wave admitted after the first fully retires: the batch
        // size transitions 2 → 0 → 1 but no surviving request ever
        // changes slot, so the shared arena moves nothing.
        let mut e = ServeEngine::create(2, 2, 13, mega()).unwrap();
        e.submit(Request::new(0, vec![3, 4], 3));
        e.submit(Request::new(1, vec![5, 6], 3));
        e.submit(Request::new(2, vec![7], 2));
        let (out, stats) = e.serve().unwrap();
        assert_eq!(out.len(), 3);
        assert!(stats.batch_sizes.contains(&2) && stats.batch_sizes.contains(&1));
        assert_eq!(stats.kv_rows_migrated, 0, "batch transition migrated KV rows");
    }

    #[test]
    fn greedy_decode_is_deterministic() {
        if !have_artifacts() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let run = || {
            let mut e = ServeEngine::create(2, 2, 9, mega()).unwrap();
            e.submit(Request::new(0, vec![5, 6, 7], 5));
            e.serve().unwrap().0
        };
        assert_eq!(run()[&0], run()[&0]);
    }

    #[test]
    fn staggered_admission_continuous_batching() {
        if !have_artifacts() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        // more requests than slots: later ones admitted as earlier retire.
        let mut e = ServeEngine::create(2, 2, 11, mega()).unwrap();
        for i in 0..5u64 {
            e.submit(Request::new(i, vec![1 + i as i32], 2 + (i as usize % 2)));
        }
        let (out, stats) = e.serve().unwrap();
        assert_eq!(out.len(), 5);
        for (id, toks) in &out {
            assert_eq!(toks.len(), 2 + (*id as usize % 2), "req {id}");
        }
        // batch ramps: some iterations ran with 2 active requests.
        assert!(stats.batch_sizes.iter().any(|&b| b == 2));
    }

    #[test]
    fn single_request_matches_single_session_decode() {
        if !have_artifacts() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        // engine output for one request == direct RealSession loop.
        let mut e = ServeEngine::create(1, 2, 42, mega()).unwrap();
        e.submit(Request::new(0, vec![7], 3));
        let (out, _) = e.serve().unwrap();

        let s = crate::exec::real::RealSession::create(1, 2, 42).unwrap();
        let mut kernel = s.persistent_kernel(4, 1);
        let exec = TileExecutor::new(&s.compiled.graph, &s.store, &s.pool, 1);
        let mut ids = vec![7i32];
        let mut got = Vec::new();
        for step in 0..4 {
            real::set_ids(&s.compiled.graph, &s.store, &ids);
            crate::exec::real::run_iteration(&mut kernel, &exec, step).unwrap();
            let logits = real::get_logits(&s.compiled.graph, &s.store);
            let tok = real::argmax(&logits) as i32;
            got.push(tok);
            ids = vec![tok];
        }
        // prompt len 1 → first iteration already yields generated[0].
        assert_eq!(out[&0], got[..3].to_vec());
    }

    #[test]
    fn stats_quantiles() {
        let mut s = ServeStats::default();
        assert_eq!(s.p50_latency(), Duration::ZERO);
        assert_eq!(s.p99_latency(), Duration::ZERO);
        s.iter_latencies = (1..=100).map(Duration::from_millis).collect();
        assert_eq!(s.p50_latency(), Duration::from_millis(50));
        assert_eq!(s.p99_latency(), Duration::from_millis(99));
        // selection must not depend on input order.
        s.iter_latencies.reverse();
        assert_eq!(s.p50_latency(), Duration::from_millis(50));
        assert_eq!(s.p99_latency(), Duration::from_millis(99));
    }
}
