//! The serving engine: continuous batching over the real-numerics
//! megakernel (§6.1), with a persistent runtime, resident KV, stable
//! batch slots, and a zero-copy decode hot path.
//!
//! Each batch-size specialization is a long-lived [`Session`]: a tensor
//! arena holding activations, a [`PersistentMegaKernel`] whose
//! worker/scheduler threads park between iterations, a resident
//! `OwningTileExecutor`, and tensor ids resolved once at creation. All
//! sessions alias **one shared max-batch [`KvArena`]** for their KV
//! cache tensors (a batch-`b` graph's `l{l}.kcache` is the first `b`
//! slots of the arena's layer segment) and **one shared
//! [`WeightArena`]** for their parameter tensors (initialized once at
//! `create`, read-only thereafter) — switching specializations
//! re-interprets the same memory, and weight memory does not scale with
//! the number of specializations.
//!
//! Per decode iteration: retire/admit (the paper's start-event task),
//! pick the batch-size-specialized session covering the highest
//! occupied **slot** (powers of two — slots are stable, so after
//! retirements the occupied set may be fragmented and the engine
//! accepts occasionally running the next-larger graph), stage each
//! request's token at its slot index, re-arm the resident kernel, then
//! harvest each request's logits row through a borrowed arena view
//! (greedy decoding). A request keeps its slot from admission to
//! retirement, so no code path moves KV rows: `kv_rows_migrated` is
//! structurally zero, not merely zero in steady state. The newly
//! appended KV row is written in-kernel by `KvAppend`; the engine never
//! copies a tensor on the decode path (asserted via the store's
//! read-side counters), and task results land *directly* in their
//! destination arena tensors through the pool's write-into boundary
//! (`execute_into`) — the pool's `output_allocs` counter stays at zero,
//! closing the last per-task allocation on the decode hot path.

use crate::exec::binder::OwningTileExecutor;
use crate::exec::real::{self, compile_real, WeightArena};
use crate::exec::store::TensorStore;
use crate::megakernel::{MegaConfig, PersistentMegaKernel};
use crate::ops::TensorId;
use crate::runtime::pool::ExecPool;
use crate::runtime::Manifest;
use crate::serving::batcher::{Batcher, Request};
use crate::serving::kvcache::{KvAllocator, KvArena, KvResidency};
use std::collections::HashMap;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// One batch-size specialization: tensor arena (activations only — KV
/// and weights aliased into the shared arenas), the persistent kernel,
/// the resident executor, and hot-path tensor ids resolved once at
/// creation.
struct Session {
    store: Arc<TensorStore>,
    kernel: PersistentMegaKernel,
    exec: OwningTileExecutor,
    token_ids: TensorId,
    logits: TensorId,
}

/// Serving statistics.
#[derive(Clone, Debug, Default)]
pub struct ServeStats {
    pub iterations: usize,
    pub tokens_generated: usize,
    pub total: Duration,
    pub iter_latencies: Vec<Duration>,
    /// Tokens in flight per iteration (batch-utilization curve).
    pub batch_sizes: Vec<usize>,
    /// K/V rows moved within the shared max-batch arena, summed over
    /// layers. With stable slots this is structurally zero — requests
    /// keep their slot from admission to retirement and every
    /// specialization aliases the same arena, so neither retirements
    /// nor batch-size transitions move rows. Kept as a counter so the
    /// tests can assert the invariant instead of trusting it.
    pub kv_rows_migrated: usize,
}

impl ServeStats {
    pub fn throughput_tok_s(&self) -> f64 {
        self.tokens_generated as f64 / self.total.as_secs_f64().max(1e-9)
    }

    /// `q`-quantile of per-iteration latency via `select_nth_unstable`
    /// — O(n), no full sort. One clone of the latency vector is still
    /// needed because selection reorders in place.
    ///
    /// Nearest-rank definition: the smallest sample ≥ the requested
    /// fraction of the distribution, i.e. rank `⌈q·n⌉` (1-based). The
    /// earlier `floor((n-1)·q)` indexing under-reported tail quantiles
    /// — e.g. p99 of 10 samples picked the 9th, not the 10th.
    fn latency_quantile(&self, q: f64) -> Duration {
        let n = self.iter_latencies.len();
        if n == 0 {
            return Duration::ZERO;
        }
        let rank = (q * n as f64).ceil() as usize;
        let idx = rank.clamp(1, n) - 1;
        let mut v = self.iter_latencies.clone();
        let (_, nth, _) = v.select_nth_unstable(idx);
        *nth
    }

    pub fn p50_latency(&self) -> Duration {
        self.latency_quantile(0.50)
    }

    pub fn p99_latency(&self) -> Duration {
        self.latency_quantile(0.99)
    }
}

/// The engine.
pub struct ServeEngine {
    pub manifest: Manifest,
    pool: Arc<ExecPool>,
    sessions: HashMap<usize, Session>,
    pub batcher: Batcher,
    residency: KvResidency,
    kv_arena: KvArena,
    weights: WeightArena,
}

impl ServeEngine {
    /// Build an engine with specialized sessions (graph + arena +
    /// persistent kernel + resident executor) for each manifest batch
    /// size up to `max_batch`, all aliasing one max-batch KV arena and
    /// one weight arena (weights synthesized exactly once, here).
    /// `max_batch` must be one of the manifest's sizes.
    pub fn create(max_batch: usize, pool_threads: usize, seed: u64, mega: MegaConfig) -> Result<Self, String> {
        let manifest = Manifest::load(&Manifest::default_dir())?;
        if !manifest.batch_sizes.contains(&max_batch) {
            return Err(format!("max_batch {max_batch} not among specialized sizes {:?}", manifest.batch_sizes));
        }
        let m = manifest.model;
        let pool = Arc::new(ExecPool::new(manifest.clone(), pool_threads)?);
        let kv_arena = KvArena::new(m.layers, max_batch, manifest.s_max, m.kv_dim());
        let specs: Vec<(usize, Arc<crate::tgraph::CompiledGraph>)> = manifest
            .batch_sizes
            .iter()
            .filter(|&&b| b <= max_batch)
            .map(|&b| (b, Arc::new(compile_real(&manifest, b))))
            .collect();
        // one shared weight arena, initialized once: params are
        // batch-independent and name-seeded, so every specialization
        // aliases the same values instead of re-synthesizing them.
        let (_, max_compiled) =
            specs.iter().find(|(b, _)| *b == max_batch).expect("max_batch spec compiled");
        let weights = WeightArena::build(&max_compiled.graph);
        weights.init(&max_compiled.graph, seed);
        let mut sessions = HashMap::new();
        for (b, compiled) in specs {
            // hoist every per-iteration name lookup to creation time.
            let id = |name: &str| -> Result<TensorId, String> {
                Ok(compiled.graph.tensor_by_name(name).ok_or_else(|| format!("missing tensor {name}"))?.id)
            };
            // alias this session's KV tensors into the shared KV arena
            // (a batch-b cache tensor [b, s_max, kv_dim] is the first b
            // slots of the layer's [max_batch, s_max, kv_dim] segment)
            // and its param tensors into the shared weight arena.
            let mut aliases = weights.aliases_for(&compiled.graph);
            for l in 0..m.layers {
                aliases.push((id(&format!("l{l}.kcache"))?, kv_arena.slab(), kv_arena.k_offset(l)));
                aliases.push((id(&format!("l{l}.vcache"))?, kv_arena.slab(), kv_arena.v_offset(l)));
            }
            let store = Arc::new(TensorStore::new_with_aliases(&compiled.graph, aliases));
            let token_ids = id("token_ids")?;
            let logits = id("lm_head")?;
            let kernel = PersistentMegaKernel::new(compiled.clone(), mega);
            let exec = OwningTileExecutor::new(compiled, store.clone(), pool.clone(), b);
            sessions.insert(b, Session { store, kernel, exec, token_ids, logits });
        }
        // one KV block = 8 tokens; pool sized for max_batch full seqs.
        let blocks = max_batch * manifest.s_max / 8;
        let batcher = Batcher::new(max_batch, manifest.s_max, KvAllocator::new(blocks, 8));
        Ok(ServeEngine {
            manifest,
            pool,
            sessions,
            batcher,
            residency: KvResidency::default(),
            kv_arena,
            weights,
        })
    }

    /// Queue a request; a request whose worst-case length exceeds the
    /// engine's `max_seq`, or whose id duplicates one this engine has
    /// seen, is rejected (client input must not abort a serving
    /// process — and residency/outputs are keyed by id).
    pub fn submit(&mut self, r: Request) -> Result<(), String> {
        self.batcher.submit(r)
    }

    /// The engine's PJRT pool (shared by every session's executor).
    pub fn pool(&self) -> &ExecPool {
        &self.pool
    }

    /// The shared max-batch KV arena every session aliases (the engine
    /// owns it; sessions hold slab handles).
    pub fn kv_arena(&self) -> &KvArena {
        &self.kv_arena
    }

    /// Times the shared weight arena has been initialized — exactly 1
    /// regardless of how many batch-size specializations exist.
    pub fn weight_init_runs(&self) -> u64 {
        self.weights.init_runs()
    }

    /// Elements in the shared weight arena (the only weight storage —
    /// per-session stores hold activations only).
    pub fn weight_arena_len(&self) -> usize {
        self.weights.len()
    }

    /// Output buffers allocated at the PJRT pool boundary over this
    /// engine's lifetime. The persistent-kernel task bodies hand the
    /// pool mutable arena destinations (`execute_into`), so serving
    /// keeps this at zero — the allocating `execute` reply survives
    /// only on validation paths (`run_reference`), which this engine
    /// never takes.
    pub fn output_allocs(&self) -> usize {
        self.pool.output_allocs()
    }

    /// Sum of read-side `(allocs, bytes_copied)` store counters across
    /// all session arenas — the zero-copy invariant: steady-state
    /// serving leaves both at zero (weight/token staging and in-place
    /// kernel writes are not counted; see `exec::store`).
    pub fn store_counters(&self) -> (u64, u64) {
        self.sessions.values().fold((0, 0), |(a, b), s| {
            let c = s.store.counters();
            (a + c.allocs, b + c.bytes_copied)
        })
    }

    /// Record where each active request's KV rows live. With stable
    /// slots a request's arena home *is* its batcher slot for its whole
    /// lifetime, so this only ever inserts on admission. A mismatch
    /// means a batcher change reintroduced slot remaps — an internal
    /// invariant violation, not something to "repair": a set of
    /// conflicting moves applied in arbitrary order could overwrite
    /// live rows (the old compaction path needed an ascending-walk
    /// ordering argument for exactly this), so the engine refuses and
    /// errors out instead. Always `Ok(0)` today; returns the row count
    /// so `kv_rows_migrated` keeps its unit if a deliberate relocation
    /// policy (e.g. anti-fragmentation compaction) is ever added.
    fn reconcile_residency(&mut self) -> Result<usize, String> {
        for r in &self.batcher.active {
            let slot = r.slot.expect("active request without slot");
            match self.residency.home(r.id) {
                Some(cur) if cur == slot => {}
                Some(cur) => {
                    return Err(format!(
                        "request {} moved slot {cur} -> {slot} despite stable-slot batching \
                         (batcher invariant violation; refusing to relocate live KV rows)",
                        r.id
                    ));
                }
                None => self.residency.set(r.id, slot),
            }
        }
        Ok(0)
    }

    /// Drive everything to completion; returns per-request outputs and
    /// stats. Deterministic: greedy decoding, seeded weights.
    pub fn serve(&mut self) -> Result<(HashMap<u64, Vec<i32>>, ServeStats), String> {
        let mut stats = ServeStats::default();
        let t0 = Instant::now();
        let vocab = self.manifest.model.vocab;

        while self.batcher.has_work() {
            for id in self.batcher.step_admission() {
                self.residency.evict(id);
            }
            // graph_batch is 0 exactly when no slot is occupied — and
            // then only when nothing is waiting either: submit rejects
            // any request whose worst case exceeds the whole KV pool,
            // so a lone waiting request always admits into an empty
            // batcher. The break is a clean idle exit, not a drop.
            let gb = self.batcher.graph_batch();
            if gb == 0 {
                debug_assert_eq!(self.batcher.pending(), 0, "accepted request stuck unadmittable");
                break;
            }
            if !self.sessions.contains_key(&gb) {
                return Err(format!("no session for batch {gb}"));
            }
            let active = self.batcher.active.len();

            // KV stays resident at each request's stable slot of the
            // shared arena — structurally zero rows moved.
            stats.kv_rows_migrated += self.reconcile_residency()?;

            // stage inputs by slot index: this iteration's token per
            // occupied row, row cache lengths. Vacant slots (stable
            // slots fragment after retirements) decode token 0 into
            // dead arena rows that the slot's next occupant overwrites
            // from position 0 — their logits are never read.
            let mut ids = vec![0i32; gb];
            let mut lens = vec![0usize; gb];
            for r in &self.batcher.active {
                let slot = r.slot.expect("active request without slot");
                ids[slot] = r.next_input();
                lens[slot] = r.cache_len;
            }
            let session = self.sessions.get_mut(&gb).unwrap();
            real::set_ids_at(&session.store, session.token_ids, &ids);

            // re-arm the resident mega-kernel through the session's
            // long-lived executor: no thread spawn/join, no kernel or
            // executor construction, no name lookups on this path.
            session.exec.set_row_lens(&lens);
            let it0 = Instant::now();
            session.kernel.run(&session.exec)?;
            if let Some(e) = session.exec.take_error() {
                return Err(e);
            }
            let lat = it0.elapsed();
            stats.iterations += 1;
            stats.iter_latencies.push(lat);
            stats.batch_sizes.push(active);

            // harvest: each request's logits row (at its slot) → next
            // token, through a borrowed arena view (no copy). KV needs
            // no read-back — KvAppend already wrote this step's row in
            // the resident arena.
            let logits = session.store.view(session.logits);
            for r in self.batcher.active.iter_mut() {
                let slot = r.slot.expect("active request without slot");
                r.cache_len += 1;
                let tok = real::argmax(&logits[slot * vocab..(slot + 1) * vocab]) as i32;
                if r.in_prefill() {
                    r.prompt_pos += 1;
                    if !r.in_prefill() {
                        r.generated.push(tok);
                        stats.tokens_generated += 1;
                    }
                } else {
                    r.generated.push(tok);
                    stats.tokens_generated += 1;
                }
            }
        }
        stats.total = t0.elapsed();
        let outputs = self
            .batcher
            .finished
            .iter()
            .map(|r| (r.id, r.generated.clone()))
            .collect();
        Ok((outputs, stats))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::binder::TileExecutor;

    /// True when the AOT artifacts *and* a working PJRT backend exist
    /// (an offline build runs the stub `runtime::xla` binding, whose
    /// client construction always fails — skip, don't panic).
    fn have_runtime() -> bool {
        match Manifest::load(&Manifest::default_dir()) {
            Ok(m) => match ExecPool::new(m, 1) {
                Ok(_) => true,
                Err(e) => {
                    eprintln!("skipping: PJRT backend unavailable ({e})");
                    false
                }
            },
            Err(_) => false,
        }
    }

    fn mega() -> MegaConfig {
        MegaConfig { workers: 4, schedulers: 1, ..Default::default() }
    }

    #[test]
    fn serves_batch_to_completion() {
        if !have_runtime() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let mut e = ServeEngine::create(4, 2, 42, mega()).unwrap();
        for i in 0..3u64 {
            e.submit(Request::new(i, vec![(i as i32) + 1, 7], 4)).unwrap();
        }
        let (out, stats) = e.serve().unwrap();
        assert_eq!(out.len(), 3);
        for (_, toks) in &out {
            assert_eq!(toks.len(), 4);
            for &t in toks {
                assert!((0..512).contains(&t));
            }
        }
        assert_eq!(stats.tokens_generated, 12);
        assert!(stats.iterations >= 5, "prompt 2 + gen 4 - 1 overlap");
        // slots are stable: no KV rows ever move in the arena.
        assert_eq!(stats.kv_rows_migrated, 0, "steady batch migrated KV rows");
    }

    #[test]
    fn steady_state_decode_is_zero_copy() {
        if !have_runtime() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        // a uniform wave (same prompt + generation lengths) is admitted
        // together and retired together: the whole run is the steady
        // state the zero-copy invariant promises.
        let mut e = ServeEngine::create(4, 2, 42, mega()).unwrap();
        for i in 0..4u64 {
            e.submit(Request::new(i, vec![(i as i32) + 1, 9], 5)).unwrap();
        }
        let (out, stats) = e.serve().unwrap();
        assert_eq!(out.len(), 4);
        assert_eq!(stats.kv_rows_migrated, 0, "arena moved rows in steady state");
        let (allocs, bytes) = e.store_counters();
        assert_eq!(allocs, 0, "decode hot path materialized an input buffer");
        assert_eq!(bytes, 0, "decode hot path copied tensor data");
        assert_eq!(e.output_allocs(), 0, "decode hot path received an allocated output buffer");
    }

    #[test]
    fn churned_decode_is_allocation_free_after_warmup() {
        if !have_runtime() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        // staggered admit/retire churn: requests with different prompt
        // and generation lengths retire one by one while later
        // submissions admit into the freed slots, forcing batch-size
        // transitions in both directions. The first wave doubles as
        // warm-up (per-worker scratch growth, lazy artifact compiles);
        // from then on every counter that could betray a hidden
        // allocation, copy, or row move must stay frozen.
        let mut e = ServeEngine::create(4, 2, 42, mega()).unwrap();
        for i in 0..3u64 {
            e.submit(Request::new(i, vec![(i as i32) + 1; 1 + i as usize], 2 + i as usize)).unwrap();
        }
        let (_, warm) = e.serve().unwrap();
        assert_eq!(warm.kv_rows_migrated, 0);
        // post-warmup baseline (store counters should already be zero —
        // the stricter claim — but the churn assertion below only needs
        // them frozen).
        let (a0, b0) = e.store_counters();
        assert_eq!((a0, b0), (0, 0), "warm-up wave itself copied tensor data");
        let out0 = e.output_allocs();
        assert_eq!(out0, 0, "warm-up wave itself allocated output buffers");

        // churn wave: more requests than slots, staggered lengths.
        for i in 10..16u64 {
            e.submit(Request::new(i, vec![(i as i32) % 7 + 1; 1 + (i as usize % 3)], 1 + (i as usize % 4)))
                .unwrap();
        }
        let (out, stats) = e.serve().unwrap();
        // finished accumulates across waves: 3 warm-up + 6 churn.
        assert_eq!(out.len(), 9);
        assert!(stats.batch_sizes.iter().any(|&b| b >= 3), "churn never filled the batch");
        assert_eq!(stats.kv_rows_migrated, 0, "churn migrated KV rows");
        let (allocs, bytes) = e.store_counters();
        assert_eq!((allocs, bytes), (0, 0), "churned decode copied tensor data");
        assert_eq!(e.output_allocs(), out0, "churned decode allocated output buffers");
    }

    #[test]
    fn retirements_do_not_migrate_kv() {
        if !have_runtime() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        // staggered generation lengths: requests retire one at a time
        // while the rest keep decoding. Under prefix compaction every
        // retirement remapped the survivors' slots and moved their KV
        // rows; with stable slots the counter must stay at zero across
        // retirements — not just across batch-size transitions.
        let mut e = ServeEngine::create(4, 2, 42, mega()).unwrap();
        for i in 0..4u64 {
            e.submit(Request::new(i, vec![(i as i32) + 1, 3], 2 + i as usize)).unwrap();
        }
        let (out, stats) = e.serve().unwrap();
        assert_eq!(out.len(), 4);
        for (id, toks) in &out {
            assert_eq!(toks.len(), 2 + *id as usize, "req {id}");
        }
        assert_eq!(stats.kv_rows_migrated, 0, "retirement migrated KV rows");
        let (allocs, bytes) = e.store_counters();
        assert_eq!((allocs, bytes), (0, 0), "decode hot path copied tensor data");
        // the batch ramps down as requests retire.
        assert!(stats.batch_sizes.iter().any(|&b| b < 4));
    }

    #[test]
    fn weights_initialized_once_and_shared() {
        if !have_runtime() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        // four specializations (1, 2, 4, 8) — still one weight init and
        // one weight allocation.
        let e = ServeEngine::create(8, 2, 42, mega()).unwrap();
        assert_eq!(e.sessions.len(), 4);
        assert_eq!(e.weight_init_runs(), 1, "weights synthesized more than once");
        // every session's embed table is the *same memory*.
        let ptrs: Vec<_> = e
            .sessions
            .values()
            .map(|s| {
                let id = s.exec.graph().graph.tensor_by_name("embed.weight").unwrap().id;
                s.store.view(id).as_ptr()
            })
            .collect();
        assert!(ptrs.windows(2).all(|w| w[0] == w[1]), "weight tensors not aliased");
        // no session's own slab is large enough to be hiding a weight
        // copy: activations are strictly smaller than the params.
        for s in e.sessions.values() {
            assert!(
                s.store.owned_len() < e.weight_arena_len(),
                "session store still packs a private weight copy"
            );
        }
    }

    #[test]
    fn oversized_request_is_rejected_not_fatal() {
        if !have_runtime() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let mut e = ServeEngine::create(2, 2, 5, mega()).unwrap();
        let s_max = e.manifest.s_max;
        let err = e.submit(Request::new(0, vec![1; s_max], 1)).unwrap_err();
        assert!(err.contains("exceeds max_seq"), "got: {err}");
        // the engine keeps serving legal requests afterwards.
        e.submit(Request::new(1, vec![5], 2)).unwrap();
        let (out, _) = e.serve().unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[&1].len(), 2);
    }

    #[test]
    fn batch_size_transitions_do_not_migrate_kv() {
        if !have_runtime() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        // second wave admitted after the first fully retires: the batch
        // size transitions 2 → 0 → 1 but no surviving request ever
        // changes slot, so the shared arena moves nothing.
        let mut e = ServeEngine::create(2, 2, 13, mega()).unwrap();
        e.submit(Request::new(0, vec![3, 4], 3)).unwrap();
        e.submit(Request::new(1, vec![5, 6], 3)).unwrap();
        e.submit(Request::new(2, vec![7], 2)).unwrap();
        let (out, stats) = e.serve().unwrap();
        assert_eq!(out.len(), 3);
        assert!(stats.batch_sizes.contains(&2) && stats.batch_sizes.contains(&1));
        assert_eq!(stats.kv_rows_migrated, 0, "batch transition migrated KV rows");
    }

    #[test]
    fn greedy_decode_is_deterministic() {
        if !have_runtime() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let run = || {
            let mut e = ServeEngine::create(2, 2, 9, mega()).unwrap();
            e.submit(Request::new(0, vec![5, 6, 7], 5)).unwrap();
            e.serve().unwrap().0
        };
        assert_eq!(run()[&0], run()[&0]);
    }

    #[test]
    fn staggered_admission_continuous_batching() {
        if !have_runtime() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        // more requests than slots: later ones admitted as earlier retire.
        let mut e = ServeEngine::create(2, 2, 11, mega()).unwrap();
        for i in 0..5u64 {
            e.submit(Request::new(i, vec![1 + i as i32], 2 + (i as usize % 2))).unwrap();
        }
        let (out, stats) = e.serve().unwrap();
        assert_eq!(out.len(), 5);
        for (id, toks) in &out {
            assert_eq!(toks.len(), 2 + (*id as usize % 2), "req {id}");
        }
        // batch ramps: some iterations ran with 2 active requests.
        assert!(stats.batch_sizes.iter().any(|&b| b == 2));
        // churn through retirements and re-admissions never moves rows.
        assert_eq!(stats.kv_rows_migrated, 0);
    }

    #[test]
    fn single_request_matches_single_session_decode() {
        if !have_runtime() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        // engine output for one request == direct RealSession loop.
        let mut e = ServeEngine::create(1, 2, 42, mega()).unwrap();
        e.submit(Request::new(0, vec![7], 3)).unwrap();
        let (out, _) = e.serve().unwrap();

        let s = crate::exec::real::RealSession::create(1, 2, 42).unwrap();
        let mut kernel = s.persistent_kernel(4, 1);
        let exec = TileExecutor::new(&s.compiled.graph, &s.store, &s.pool, 1);
        let mut ids = vec![7i32];
        let mut got = Vec::new();
        for step in 0..4 {
            real::set_ids(&s.compiled.graph, &s.store, &ids);
            crate::exec::real::run_iteration(&mut kernel, &exec, step).unwrap();
            let logits = real::get_logits(&s.compiled.graph, &s.store);
            let tok = real::argmax(&logits) as i32;
            got.push(tok);
            ids = vec![tok];
        }
        // prompt len 1 → first iteration already yields generated[0].
        assert_eq!(out[&0], got[..3].to_vec());
    }

    #[test]
    fn stats_quantiles_nearest_rank() {
        let mut s = ServeStats::default();
        assert_eq!(s.p50_latency(), Duration::ZERO);
        assert_eq!(s.p99_latency(), Duration::ZERO);
        s.iter_latencies = (1..=100).map(Duration::from_millis).collect();
        assert_eq!(s.p50_latency(), Duration::from_millis(50));
        assert_eq!(s.p99_latency(), Duration::from_millis(99));
        // selection must not depend on input order.
        s.iter_latencies.reverse();
        assert_eq!(s.p50_latency(), Duration::from_millis(50));
        assert_eq!(s.p99_latency(), Duration::from_millis(99));
        // nearest-rank on a small sample: p99 of 10 is the max — the
        // old floor((n-1)·q) indexing returned the 9th of 10 here.
        s.iter_latencies = (1..=10).map(Duration::from_millis).collect();
        assert_eq!(s.p99_latency(), Duration::from_millis(10));
        assert_eq!(s.p50_latency(), Duration::from_millis(5));
        // single sample: every quantile is that sample.
        s.iter_latencies = vec![Duration::from_millis(3)];
        assert_eq!(s.p50_latency(), Duration::from_millis(3));
        assert_eq!(s.p99_latency(), Duration::from_millis(3));
    }
}
