//! Typed serving errors.
//!
//! Every fallible operation on the serving surface — building an engine,
//! submitting, cancelling, stepping — returns [`EngineError`] instead of
//! a bare `String`, so callers can *dispatch* on what went wrong (retry
//! a [`EngineError::KvPoolExceeded`] later, report a
//! [`EngineError::RequestTooLong`] to the client, crash on a
//! [`EngineError::SlotRemap`]) instead of grepping messages.
//!
//! The layers underneath keep their own boundary error types —
//! [`ManifestError`] and [`PoolError`] from `runtime`, [`KernelError`]
//! from `megakernel`, [`TaskError`] from `exec` — and convert into
//! `EngineError` through the `From` shims below, so `?` stays fluent in
//! the engine without the serving layer re-stringifying anything.

use crate::exec::binder::TaskError;
use crate::megakernel::runtime::KernelError;
use crate::runtime::manifest::ManifestError;
use crate::runtime::pool::PoolError;

/// What can go wrong on the serving surface.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum EngineError {
    /// [`EngineBuilder`](crate::serving::EngineBuilder) configuration
    /// rejected before any resource was constructed.
    InvalidConfig(String),
    /// Manifest loading / artifact discovery failed (`runtime` boundary).
    Manifest(String),
    /// Exec-pool / backend construction failed (`runtime` boundary).
    Pool(String),
    /// A mega-kernel epoch failed — timeout or executor panic
    /// (`megakernel` boundary).
    Kernel(String),
    /// A task body failed during an otherwise-completed epoch, harvested
    /// from the executor (`exec` boundary).
    Task(String),
    /// Submitted request asks for zero new tokens: it could never emit
    /// a terminal [`TokenEvent`](crate::serving::TokenEvent), so it is
    /// rejected up front instead of silently retiring event-less.
    ZeroBudget { id: u64 },
    /// Submitted request's worst case exceeds the engine's `max_seq`.
    RequestTooLong { id: u64, worst: usize, max_seq: usize },
    /// Submitted request's worst-case KV demand exceeds the whole block
    /// pool — it could never be admitted and would stall the queue.
    KvPoolExceeded { id: u64, worst: usize, need_blocks: usize, pool_blocks: usize },
    /// Request id already known to this engine (waiting, active, or
    /// finished) — ids key slots, KV residency, and outputs.
    DuplicateId { id: u64 },
    /// `cancel` of an id this engine has never seen.
    UnknownRequest { id: u64 },
    /// `cancel` of a request that already reached a terminal state
    /// (retired, or its terminal event is already emitted).
    AlreadyFinished { id: u64 },
    /// Load shed at admission: the server's bounded wait queue is full
    /// and the submission does not outrank anything already queued.
    /// This is the *synchronous* rejection of a brand-new request —
    /// a request that was accepted and later displaced by a
    /// higher-priority arrival is shed with a terminal
    /// [`FinishReason::Shed`](crate::serving::FinishReason::Shed) event
    /// instead. Retryable by the client after backoff.
    Overloaded { id: u64, queue_depth: usize },
    /// The serving thread has shut down (or died): the
    /// [`ServerClient`](crate::serving::ServerClient) handle outlived
    /// the server it talks to.
    ServerClosed,
    /// Batcher invariant violation: a live request's slot changed
    /// outside a deliberate compaction move. The engine refuses to
    /// relocate KV rows it did not plan to move.
    SlotRemap { id: u64, from: usize, to: usize },
    /// No compiled batch-size specialization covers this batch.
    NoSession { batch: usize },
    /// Wire-transport failure surfaced into the serving layer
    /// (`serving::wire` boundary): framing, protocol, or socket I/O.
    /// Produced by the `From<TransportError>` shim so transport code
    /// can `?` into engine-error contexts without re-stringifying.
    Transport(String),
}

impl std::fmt::Display for EngineError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            EngineError::InvalidConfig(m) => write!(f, "invalid engine config: {m}"),
            EngineError::Manifest(m) => write!(f, "manifest: {m}"),
            EngineError::Pool(m) => write!(f, "exec pool: {m}"),
            EngineError::Kernel(m) => write!(f, "mega-kernel: {m}"),
            EngineError::Task(m) => write!(f, "task execution: {m}"),
            EngineError::ZeroBudget { id } => {
                write!(f, "request {id} rejected: max_new_tokens must be >= 1")
            }
            EngineError::RequestTooLong { id, worst, max_seq } => write!(
                f,
                "request {id} rejected: worst-case {worst} tokens exceeds max_seq {max_seq}"
            ),
            EngineError::KvPoolExceeded { id, worst, need_blocks, pool_blocks } => write!(
                f,
                "request {id} rejected: worst-case {worst} tokens needs {need_blocks} KV blocks, \
                 pool has {pool_blocks}"
            ),
            EngineError::DuplicateId { id } => {
                write!(f, "request id {id} rejected: already known to this engine")
            }
            EngineError::Overloaded { id, queue_depth } => write!(
                f,
                "request {id} shed at admission: wait queue full ({queue_depth} deep) and \
                 nothing queued outranks it — retry after backoff"
            ),
            EngineError::ServerClosed => write!(f, "serving thread has shut down"),
            EngineError::UnknownRequest { id } => write!(f, "request {id} is unknown to this engine"),
            EngineError::AlreadyFinished { id } => write!(f, "request {id} already finished"),
            EngineError::SlotRemap { id, from, to } => write!(
                f,
                "request {id} moved slot {from} -> {to} despite stable-slot batching \
                 (batcher invariant violation; refusing to relocate live KV rows)"
            ),
            EngineError::NoSession { batch } => {
                write!(f, "no compiled session covers batch {batch}")
            }
            EngineError::Transport(m) => write!(f, "transport: {m}"),
        }
    }
}

impl std::error::Error for EngineError {}

impl From<ManifestError> for EngineError {
    fn from(e: ManifestError) -> Self {
        EngineError::Manifest(e.to_string())
    }
}

impl From<PoolError> for EngineError {
    fn from(e: PoolError) -> Self {
        EngineError::Pool(e.0)
    }
}

impl From<KernelError> for EngineError {
    fn from(e: KernelError) -> Self {
        EngineError::Kernel(e.0)
    }
}

impl From<TaskError> for EngineError {
    fn from(e: TaskError) -> Self {
        EngineError::Task(e.0)
    }
}

impl From<crate::serving::wire::TransportError> for EngineError {
    fn from(e: crate::serving::wire::TransportError) -> Self {
        EngineError::Transport(e.to_string())
    }
}

/// Legacy shim: contexts still speaking `Result<_, String>` (property
/// harness closures, examples) can `?` an `EngineError` straight
/// through.
impl From<EngineError> for String {
    fn from(e: EngineError) -> String {
        e.to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn boundary_shims_tag_their_layer() {
        assert_eq!(
            EngineError::from(ManifestError::Load { detail: "missing".into() }),
            EngineError::Manifest("missing".into())
        );
        let mm = ManifestError::ModelMismatch { manifest: "A".into(), builtin: "B".into() };
        assert!(
            matches!(&EngineError::from(mm), EngineError::Manifest(m) if m.contains("does not match")),
        );
        assert_eq!(EngineError::from(PoolError("no backend".into())), EngineError::Pool("no backend".into()));
        assert_eq!(EngineError::from(KernelError("timed out".into())), EngineError::Kernel("timed out".into()));
        assert_eq!(EngineError::from(TaskError("task 3".into())), EngineError::Task("task 3".into()));
        let wire = crate::serving::wire::TransportError::FrameTooLarge { len: 99, cap: 8 };
        let e = EngineError::from(wire.clone());
        assert_eq!(e, EngineError::Transport(wire.to_string()));
        assert!(e.to_string().starts_with("transport: "), "got: {e}");
    }

    #[test]
    fn display_is_actionable_and_string_shim_matches() {
        let e = EngineError::RequestTooLong { id: 7, worst: 80, max_seq: 64 };
        let s = e.to_string();
        assert!(s.contains("request 7") && s.contains("80") && s.contains("max_seq 64"), "got: {s}");
        assert_eq!(String::from(e), s);

        let e = EngineError::SlotRemap { id: 3, from: 1, to: 0 };
        assert!(e.to_string().contains("slot 1 -> 0"), "got: {e}");

        let e = EngineError::KvPoolExceeded { id: 1, worst: 90, need_blocks: 12, pool_blocks: 8 };
        assert!(e.to_string().contains("12 KV blocks"), "got: {e}");

        // overload shedding is a typed, retryable rejection — the
        // message must say so and carry the queue bound.
        let e = EngineError::Overloaded { id: 9, queue_depth: 64 };
        let s = e.to_string();
        assert!(s.contains("request 9") && s.contains("64") && s.contains("retry"), "got: {s}");
        assert!(EngineError::ServerClosed.to_string().contains("shut down"));
    }
}
