//! A backend-free [`StepEngine`] for testing the server front-end.
//!
//! [`MockEngine`] runs the *real* admission machinery — the same
//! [`Batcher`] (stable slots, KV block accounting, typed rejections)
//! and the same fault-injection/recovery state machine
//! ([`crate::serving::fault`]) as [`ServeEngine`] — but replaces the
//! mega-kernel epoch with a deterministic fake decode. That makes every
//! overload/deadline/shed/fault behavior of [`ServeServer`] testable
//! without AOT artifacts, a PJRT backend, or kernel threads.
//!
//! Fake decode semantics: each step emits one token per past-prefill
//! request, and the token *value* is the engine's global step counter.
//! Two useful consequences for assertions: (a) outputs are
//! deterministic, and (b) token values totally order the steps — a
//! request admitted earlier carries numerically smaller tokens, so
//! priority-ordering tests can compare streams directly. Prefill is
//! modeled faithfully (prompt-consuming steps emit nothing), finishes
//! are [`FinishReason::MaxTokens`] only (no EOS).
//!
//! [`ServeEngine`]: crate::serving::ServeEngine
//! [`ServeServer`]: crate::serving::ServeServer

use crate::serving::batcher::{Batcher, Request};
use crate::serving::engine::ServeStats;
use crate::serving::error::EngineError;
use crate::serving::fault::{Fault, FaultInjector, FaultPlan, Recovery, RecoveryAction};
use crate::serving::kvcache::KvAllocator;
use crate::serving::server::StepEngine;
use crate::serving::step::{FinishReason, StepOutcome, TokenEvent};
use std::time::Duration;

/// Backend-free step engine over the real batcher and recovery
/// machinery; see the module docs.
pub struct MockEngine {
    batcher: Batcher,
    faults: Option<FaultInjector>,
    recovery: Recovery,
    /// Terminal notices queued between steps (terminate), like the real
    /// engine's pending-events list.
    pending: Vec<TokenEvent>,
    /// Global step counter — doubles as the next token value.
    step_count: i32,
    stats: ServeStats,
}

impl MockEngine {
    /// A mock with `capacity` slots, `max_seq` 512, and a KV pool sized
    /// so admission is slot-bound, not block-bound (the interesting
    /// pressure for server tests is the slot/queue interplay).
    pub fn new(capacity: usize) -> MockEngine {
        assert!(capacity >= 1, "capacity must be >= 1");
        let max_seq = 512;
        let kv = KvAllocator::new(capacity * max_seq / 8, 8);
        MockEngine {
            batcher: Batcher::new(capacity, max_seq, kv),
            faults: None,
            recovery: Recovery::new(2, Duration::ZERO),
            pending: Vec::new(),
            step_count: 0,
            stats: ServeStats::default(),
        }
    }

    /// Arm deterministic fault injection with a retry budget — same
    /// semantics as the real engine's builder knobs (`faults` +
    /// `step_retries`), with zero backoff (mock steps are instant).
    pub fn with_faults(mut self, plan: FaultPlan, step_retries: usize) -> MockEngine {
        plan.validate().expect("invalid fault plan");
        self.faults = plan.is_armed().then(|| FaultInjector::new(plan));
        self.recovery = Recovery::new(step_retries, Duration::ZERO);
        self
    }

    /// Total KV blocks in the pool (for conservation assertions).
    pub fn kv_total_blocks(&self) -> usize {
        self.batcher.kv.total_blocks()
    }

    /// Currently free KV blocks (equals
    /// [`MockEngine::kv_total_blocks`] whenever no request is active).
    pub fn kv_free_blocks(&self) -> usize {
        self.batcher.kv.free_blocks()
    }

    /// Slots of the currently active requests (for uniqueness and
    /// stability assertions).
    pub fn active_slots(&self) -> Vec<(u64, usize)> {
        self.batcher
            .active
            .iter()
            .map(|r| (r.id, r.slot.expect("active request without slot")))
            .collect()
    }
}

impl StepEngine for MockEngine {
    fn submit(&mut self, r: Request) -> Result<(), EngineError> {
        self.batcher.submit(r)
    }

    fn validate(&self, r: &Request) -> Result<(), EngineError> {
        self.batcher.validate(r)
    }

    fn terminate(&mut self, id: u64, reason: FinishReason) -> Result<(), EngineError> {
        self.batcher.terminate(id, reason)?;
        self.pending.push(TokenEvent { request: id, token: None, finish: Some(reason) });
        Ok(())
    }

    fn step(&mut self) -> Result<StepOutcome, EngineError> {
        let mut events: Vec<TokenEvent> = std::mem::take(&mut self.pending);
        self.batcher.step_admission();

        // the same recovery protocol as the real engine, minus the
        // backoff sleeps: draw a fault per attempt over what is staged,
        // retry, quarantine the blamed request, or give up.
        loop {
            if self.batcher.active.is_empty() {
                return Ok(StepOutcome { events, ran: 0 });
            }
            let fault = match self.faults.as_mut() {
                Some(inj) => inj.draw(&self.batcher.active),
                None => None,
            };
            let Some(fault) = fault else {
                self.recovery.on_success();
                break;
            };
            self.stats.faulted_epochs += 1;
            let victim = match fault {
                Fault::Task { victim } => Some(victim),
                Fault::Epoch => None,
            };
            let action = self
                .recovery
                .on_failure(victim, |id| self.batcher.active.iter().any(|r| r.id == id));
            match action {
                RecoveryAction::Retry(_) => {}
                RecoveryAction::Quarantine(id) => {
                    let _ = self.batcher.terminate(id, FinishReason::Failed);
                    self.stats.requests_quarantined += 1;
                    events.push(TokenEvent {
                        request: id,
                        token: None,
                        finish: Some(FinishReason::Failed),
                    });
                }
                RecoveryAction::GiveUp => {
                    // undelivered notices stay queued, like the real
                    // engine's failed step.
                    self.pending = events;
                    return Err(EngineError::Kernel("mock epoch failed beyond recovery".into()));
                }
            }
        }

        // fake decode: one step advances every active request exactly
        // like the real harvest (prefill consumes the prompt silently),
        // with the step counter as the token value.
        self.step_count += 1;
        let tok = self.step_count;
        let ran = self.batcher.active.len();
        for r in self.batcher.active.iter_mut() {
            r.cache_len += 1;
            let emitted = if r.in_prefill() {
                r.prompt_pos += 1;
                if r.in_prefill() {
                    false
                } else {
                    r.generated.push(tok);
                    true
                }
            } else {
                r.generated.push(tok);
                true
            };
            if !emitted {
                continue;
            }
            self.stats.tokens_generated += 1;
            let finish = if r.generated.len() >= r.max_new_tokens {
                r.finish = Some(FinishReason::MaxTokens);
                Some(FinishReason::MaxTokens)
            } else {
                None
            };
            events.push(TokenEvent { request: r.id, token: Some(tok), finish });
        }
        self.stats.iterations += 1;
        Ok(StepOutcome { events, ran })
    }

    fn has_work(&self) -> bool {
        self.batcher.has_work() || !self.pending.is_empty()
    }

    fn capacity(&self) -> usize {
        self.batcher.max_batch
    }

    fn in_flight(&self) -> usize {
        self.batcher.active.len() + self.batcher.pending()
    }

    fn take_finished(&mut self) -> Vec<Request> {
        self.batcher.take_finished()
    }

    fn take_stats(&mut self) -> ServeStats {
        std::mem::take(&mut self.stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Drive the mock to idle, collecting events.
    fn drain(e: &mut MockEngine) -> Vec<TokenEvent> {
        let mut events = Vec::new();
        let mut guard = 0;
        while e.has_work() {
            guard += 1;
            assert!(guard < 10_000, "mock step livelock");
            events.extend(e.step().unwrap().events);
        }
        events
    }

    #[test]
    fn mock_decodes_deterministically_with_step_tokens() {
        let mut e = MockEngine::new(2);
        e.submit(Request::new(1, vec![7], 3)).unwrap();
        e.submit(Request::new(2, vec![7, 8], 2)).unwrap();
        let events = drain(&mut e);
        let toks = |id: u64| -> Vec<i32> {
            events.iter().filter(|ev| ev.request == id).filter_map(|ev| ev.token).collect()
        };
        // prompt 1: emits from step 1. prompt 2: first emission step 2.
        assert_eq!(toks(1), vec![1, 2, 3]);
        assert_eq!(toks(2), vec![2, 3]);
        for id in [1, 2] {
            let terminals =
                events.iter().filter(|ev| ev.request == id && ev.finish.is_some()).count();
            assert_eq!(terminals, 1, "req {id}");
        }
        // all KV released once everything retired.
        assert_eq!(e.kv_free_blocks(), e.kv_total_blocks());
    }

    #[test]
    fn mock_terminate_queues_a_tokenless_notice() {
        let mut e = MockEngine::new(2);
        e.submit(Request::new(1, vec![3], 8)).unwrap();
        e.step().unwrap();
        StepEngine::terminate(&mut e, 1, FinishReason::DeadlineExceeded).unwrap();
        let out = e.step().unwrap();
        assert!(out.events.contains(&TokenEvent {
            request: 1,
            token: None,
            finish: Some(FinishReason::DeadlineExceeded)
        }));
        assert_eq!(e.kv_free_blocks(), e.kv_total_blocks());
    }

    #[test]
    fn mock_poison_quarantines_and_survivors_continue() {
        let mut e = MockEngine::new(2)
            .with_faults(FaultPlan { poison: Some(1), ..Default::default() }, 1);
        e.submit(Request::new(1, vec![3, 4], 4)).unwrap();
        e.submit(Request::new(2, vec![5], 2)).unwrap();
        let events = drain(&mut e);
        let poisoned: Vec<_> = events.iter().filter(|ev| ev.request == 1).collect();
        assert_eq!(poisoned.len(), 1);
        assert_eq!(poisoned[0].finish, Some(FinishReason::Failed));
        // the survivor decodes its full budget.
        assert_eq!(
            events.iter().filter(|ev| ev.request == 2).filter_map(|ev| ev.token).count(),
            2
        );
        assert_eq!(e.stats.requests_quarantined, 1);
        assert!(e.stats.faulted_epochs >= 2, "retry budget 1 → at least two failures");
        assert_eq!(e.kv_free_blocks(), e.kv_total_blocks());
    }

    #[test]
    fn mock_gives_up_on_unattributable_persistent_failure() {
        let mut e = MockEngine::new(1)
            .with_faults(FaultPlan { kernel_rate: 1.0, ..Default::default() }, 2);
        e.submit(Request::new(1, vec![3], 2)).unwrap();
        let err = e.step().unwrap_err();
        assert!(matches!(err, EngineError::Kernel(_)), "got: {err}");
    }
}
