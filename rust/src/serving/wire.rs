//! The serving wire protocol: versioned, length-prefixed binary frames.
//!
//! This module is the *pure codec* half of the network transport (the
//! socket half lives in [`crate::serving::transport`]): it defines the
//! frame types both sides exchange, encodes/decodes them on byte
//! slices, and owns the typed [`TransportError`] every malformed byte
//! sequence maps to — no stringly-typed errors on the wire path.
//!
//! # Frame layout
//!
//! Every frame is a 4-byte little-endian length prefix followed by a
//! body; the prefix counts the body bytes only and is checked against
//! the receiver's max-frame cap *before* the body is read (oversized-
//! frame protection). The body always starts with the protocol version
//! byte ([`WIRE_VERSION`]) and a tag byte:
//!
//! | bytes | field | notes |
//! |-------|-------|-------|
//! | 4     | `len` | u32 LE, body length, `<= max_frame` |
//! | 1     | `version` | [`WIRE_VERSION`], mismatch → [`TransportError::BadVersion`] |
//! | 1     | `tag` | frame discriminant (client `0x01..`, server `0x81..`) |
//! | `len-2` | payload | tag-specific, all integers LE |
//!
//! Client → server frames ([`ClientFrame`]): `Submit` (0x01), `Cancel`
//! (0x02), `Status` (0x03). Server → client frames ([`ServerFrame`]):
//! `Accepted` (0x81), `Token` (0x82), `Finish` (0x83), `Error` (0x84),
//! `Shed` (0x85), `Status` (0x86), `Close` (0x87).
//!
//! Strings travel as `u32` length + UTF-8 bytes; prompts as `u32`
//! count + `i32` tokens; optional values as a presence byte. The
//! existing typed vocabulary crosses the wire intact:
//! [`FinishReason`] and every [`EngineError`] variant have stable
//! one-byte codes and round-trip losslessly, so a remote client
//! dispatches on *the same types* an in-process caller does.
//!
//! # Deterministic wire chaos
//!
//! [`WireFaultPlan`] extends the engine's
//! [`FaultPlan`](crate::serving::FaultPlan) idiom to the transport: a
//! seed-driven schedule that truncates, corrupts, or delays frames and
//! drops whole connections — armable on the server's outbound path and
//! inside the loopback
//! [`TransportClient`](crate::serving::transport::TransportClient), so
//! the chaos tests exercise both directions deterministically.

use crate::serving::error::EngineError;
use crate::serving::server::Priority;
use crate::serving::step::FinishReason;
use crate::util::{boundary_error, XorShift64};
use std::time::Duration;

/// Protocol version spoken by this build; the first byte of every
/// frame body. A receiver rejects any other value with
/// [`TransportError::BadVersion`] before touching the payload.
///
/// v2 widened [`ServerFrame::Status`] with the paged-KV pool gauges
/// (block occupancy, prefix sharing, COW copies, prefill chunks); a v1
/// peer cannot parse the longer payload, so the version byte moved.
pub const WIRE_VERSION: u8 = 2;

/// Default max-frame cap (bytes of body), sized for 16k-token prompts
/// with ample header room. See
/// [`TransportConfig::max_frame`](crate::serving::transport::TransportConfig::max_frame).
pub const DEFAULT_MAX_FRAME: u32 = 64 * 1024;

boundary_error!(
    /// What can go wrong on the wire path. Field-carrying variants let
    /// both sides dispatch (and tests assert) on the *kind* of
    /// protocol violation; the `From` shim into
    /// [`EngineError::Transport`] keeps `?` fluent where transport code
    /// meets the serving layer.
    enum TransportError {
        /// A length prefix announced a body beyond the receiver's cap
        /// — rejected before any body byte is read.
        FrameTooLarge { len: u32, cap: u32 } => "frame body of {len} bytes exceeds the {cap}-byte cap",
        /// The peer closed (or the read stalled out) mid-frame:
        /// `got` of `want` bytes arrived.
        Truncated { want: usize, got: usize } => "frame truncated: got {got} of {want} bytes",
        /// Version byte mismatch (this build speaks [`WIRE_VERSION`]).
        BadVersion { got: u8, want: u8 } => "unsupported wire version {got} (this build speaks {want})",
        /// Tag byte names no frame in this direction.
        UnknownFrame { tag: u8 } => "unknown frame tag {tag:#04x}",
        /// Structurally invalid payload (short fields, bad UTF-8, an
        /// out-of-range enum code) in the named frame.
        BadPayload { frame: String, detail: String } => "malformed {frame} payload: {detail}",
        /// Socket I/O failure, stringified (`std::io::Error` carries no
        /// `Eq`); `what` names the operation that failed.
        Io { what: String } => "socket i/o: {what}",
        /// A started frame failed to complete within the read deadline
        /// — the slowloris guard tearing the connection down.
        Stalled { ms: u64 } => "peer stalled mid-frame beyond the {ms}ms read deadline",
        /// The peer's outbound queue overflowed under the `Shed`
        /// slow-reader policy; the connection was closed with a
        /// [`CloseReason::SlowConsumer`] frame.
        SlowConsumer { depth: usize } => "slow consumer: outbound queue ({depth} frames) overflowed",
        /// The server closed this connection deliberately; `reason` is
        /// the [`CloseReason`] it sent.
        Closed { reason: CloseReason } => "connection closed by peer: {reason:?}",
        /// Transport configuration rejected before any socket was
        /// opened (bad fault rates, zero queue depths).
        Config { what: String } => "invalid transport config: {what}",
    }
);

impl From<std::io::Error> for TransportError {
    fn from(e: std::io::Error) -> Self {
        TransportError::Io { what: e.to_string() }
    }
}

/// Why the server closed a connection (the payload of a
/// [`ServerFrame::Close`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CloseReason {
    /// Graceful shutdown: the transport is draining; live streams were
    /// flushed (or force-cancelled at the drain deadline).
    Drain,
    /// The client read too slowly under the `Shed` policy and its
    /// bounded outbound queue overflowed.
    SlowConsumer,
    /// The client sent bytes that do not parse as a frame (bad
    /// version, unknown tag, malformed payload, oversized length).
    Protocol,
    /// The listener is at its connection cap; retry after backoff.
    Overloaded,
}

impl CloseReason {
    fn code(self) -> u8 {
        match self {
            CloseReason::Drain => 0,
            CloseReason::SlowConsumer => 1,
            CloseReason::Protocol => 2,
            CloseReason::Overloaded => 3,
        }
    }

    fn from_code(c: u8) -> Result<CloseReason, TransportError> {
        Ok(match c {
            0 => CloseReason::Drain,
            1 => CloseReason::SlowConsumer,
            2 => CloseReason::Protocol,
            3 => CloseReason::Overloaded,
            _ => return bad("Close", format!("close reason code {c}")),
        })
    }
}

/// A client → server frame.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ClientFrame {
    /// Submit a request: answered by exactly one of
    /// [`ServerFrame::Accepted`], [`ServerFrame::Shed`], or
    /// [`ServerFrame::Error`]; once accepted, [`ServerFrame::Token`]s
    /// stream until the single [`ServerFrame::Finish`].
    Submit {
        id: u64,
        priority: Priority,
        /// Relative deadline in milliseconds; `None` means none.
        deadline_ms: Option<u64>,
        max_new_tokens: u32,
        prompt: Vec<i32>,
    },
    /// Cancel a live request; its stream ends with a terminal
    /// [`FinishReason::Cancelled`] finish frame.
    Cancel { id: u64 },
    /// Ask for a [`ServerFrame::Status`] occupancy snapshot.
    Status,
}

/// A server → client frame.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ServerFrame {
    /// The submission was accepted; token frames will follow.
    Accepted { id: u64 },
    /// One decoded token for a streaming request.
    Token { id: u64, token: i32 },
    /// The request's single terminal event: why it stopped, plus the
    /// final token when the terminal step produced one.
    Finish { id: u64, token: Option<i32>, reason: FinishReason },
    /// A typed failure for one request (or `id: 0` for a
    /// connection-scoped failure) — the full [`EngineError`] crosses
    /// the wire, not its message.
    Error { id: u64, err: EngineError },
    /// Typed backpressure: the submission was shed — by the server's
    /// bounded wait queue ([`EngineError::Overloaded`]) or by the
    /// connection's own in-flight cap. Retry after backoff.
    Shed { id: u64, queue_depth: u32 },
    /// Occupancy snapshot answering [`ClientFrame::Status`]. The six
    /// `kv_*` gauges mirror [`KvPoolStats`](crate::metrics::KvPoolStats)
    /// — all zero when the engine runs the legacy contiguous KV arena.
    Status {
        queued: u32,
        in_flight: u32,
        capacity: u32,
        finished: u64,
        shed: u64,
        rejected: u64,
        kv_blocks_total: u64,
        kv_blocks_free: u64,
        kv_blocks_shared: u64,
        kv_blocks_cowed: u64,
        kv_prefix_hits: u64,
        kv_prefill_chunks: u64,
    },
    /// The server is closing this connection; no frame follows.
    Close { reason: CloseReason },
}

// frame tags — client direction low, server direction high bit set.
const TAG_SUBMIT: u8 = 0x01;
const TAG_CANCEL: u8 = 0x02;
const TAG_STATUS_REQ: u8 = 0x03;
const TAG_ACCEPTED: u8 = 0x81;
const TAG_TOKEN: u8 = 0x82;
const TAG_FINISH: u8 = 0x83;
const TAG_ERROR: u8 = 0x84;
const TAG_SHED: u8 = 0x85;
const TAG_STATUS: u8 = 0x86;
const TAG_CLOSE: u8 = 0x87;

/// Sentinel for "no deadline" in the Submit frame.
const NO_DEADLINE: u64 = u64::MAX;

fn bad<T>(frame: &str, detail: impl Into<String>) -> Result<T, TransportError> {
    Err(TransportError::BadPayload { frame: frame.into(), detail: detail.into() })
}

// ---------------------------------------------------------------------------
// primitive writers/readers

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_i32(out: &mut Vec<u8>, v: i32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_str(out: &mut Vec<u8>, s: &str) {
    put_u32(out, s.len() as u32);
    out.extend_from_slice(s.as_bytes());
}

/// Byte-slice reader with typed underrun errors; `frame` names the
/// frame being decoded for [`TransportError::BadPayload`] context.
struct Cursor<'a> {
    b: &'a [u8],
    i: usize,
    frame: &'static str,
}

impl<'a> Cursor<'a> {
    fn new(b: &'a [u8], frame: &'static str) -> Cursor<'a> {
        Cursor { b, i: 0, frame }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], TransportError> {
        if self.i + n > self.b.len() {
            return bad(self.frame, format!("need {n} bytes at offset {}, have {}", self.i, self.b.len() - self.i));
        }
        let s = &self.b[self.i..self.i + n];
        self.i += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, TransportError> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32, TransportError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().expect("4 bytes")))
    }

    fn u64(&mut self) -> Result<u64, TransportError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().expect("8 bytes")))
    }

    fn i32(&mut self) -> Result<i32, TransportError> {
        Ok(i32::from_le_bytes(self.take(4)?.try_into().expect("4 bytes")))
    }

    fn str(&mut self) -> Result<String, TransportError> {
        let n = self.u32()? as usize;
        let raw = self.take(n)?;
        match std::str::from_utf8(raw) {
            Ok(s) => Ok(s.to_string()),
            Err(_) => bad(self.frame, "string is not UTF-8"),
        }
    }

    /// Every payload must be fully consumed — trailing bytes mean the
    /// peer speaks a different dialect.
    fn finish<T>(self, v: T) -> Result<T, TransportError> {
        if self.i != self.b.len() {
            return bad(self.frame, format!("{} trailing bytes", self.b.len() - self.i));
        }
        Ok(v)
    }
}

// ---------------------------------------------------------------------------
// enum codes

fn priority_code(p: Priority) -> u8 {
    match p {
        Priority::Interactive => 0,
        Priority::Batch => 1,
    }
}

fn priority_from(c: u8) -> Result<Priority, TransportError> {
    match c {
        0 => Ok(Priority::Interactive),
        1 => Ok(Priority::Batch),
        _ => bad("Submit", format!("priority code {c}")),
    }
}

fn finish_code(r: FinishReason) -> u8 {
    match r {
        FinishReason::MaxTokens => 0,
        FinishReason::Eos => 1,
        FinishReason::Cancelled => 2,
        FinishReason::DeadlineExceeded => 3,
        FinishReason::Shed => 4,
        FinishReason::Failed => 5,
    }
}

fn finish_from(c: u8) -> Result<FinishReason, TransportError> {
    Ok(match c {
        0 => FinishReason::MaxTokens,
        1 => FinishReason::Eos,
        2 => FinishReason::Cancelled,
        3 => FinishReason::DeadlineExceeded,
        4 => FinishReason::Shed,
        5 => FinishReason::Failed,
        _ => return bad("Finish", format!("finish reason code {c}")),
    })
}

/// [`EngineError`] wire encoding: a one-byte code plus the variant's
/// fields. `usize` fields travel as u64 (lossless both ways on the
/// 64-bit targets this crate supports).
fn put_engine_error(out: &mut Vec<u8>, e: &EngineError) {
    match e {
        EngineError::InvalidConfig(m) => {
            out.push(0);
            put_str(out, m);
        }
        EngineError::Manifest(m) => {
            out.push(1);
            put_str(out, m);
        }
        EngineError::Pool(m) => {
            out.push(2);
            put_str(out, m);
        }
        EngineError::Kernel(m) => {
            out.push(3);
            put_str(out, m);
        }
        EngineError::Task(m) => {
            out.push(4);
            put_str(out, m);
        }
        EngineError::ZeroBudget { id } => {
            out.push(5);
            put_u64(out, *id);
        }
        EngineError::RequestTooLong { id, worst, max_seq } => {
            out.push(6);
            put_u64(out, *id);
            put_u64(out, *worst as u64);
            put_u64(out, *max_seq as u64);
        }
        EngineError::KvPoolExceeded { id, worst, need_blocks, pool_blocks } => {
            out.push(7);
            put_u64(out, *id);
            put_u64(out, *worst as u64);
            put_u64(out, *need_blocks as u64);
            put_u64(out, *pool_blocks as u64);
        }
        EngineError::DuplicateId { id } => {
            out.push(8);
            put_u64(out, *id);
        }
        EngineError::UnknownRequest { id } => {
            out.push(9);
            put_u64(out, *id);
        }
        EngineError::AlreadyFinished { id } => {
            out.push(10);
            put_u64(out, *id);
        }
        EngineError::Overloaded { id, queue_depth } => {
            out.push(11);
            put_u64(out, *id);
            put_u64(out, *queue_depth as u64);
        }
        EngineError::ServerClosed => out.push(12),
        EngineError::SlotRemap { id, from, to } => {
            out.push(13);
            put_u64(out, *id);
            put_u64(out, *from as u64);
            put_u64(out, *to as u64);
        }
        EngineError::NoSession { batch } => {
            out.push(14);
            put_u64(out, *batch as u64);
        }
        EngineError::Transport(m) => {
            out.push(15);
            put_str(out, m);
        }
    }
}

fn take_engine_error(c: &mut Cursor<'_>) -> Result<EngineError, TransportError> {
    let code = c.u8()?;
    Ok(match code {
        0 => EngineError::InvalidConfig(c.str()?),
        1 => EngineError::Manifest(c.str()?),
        2 => EngineError::Pool(c.str()?),
        3 => EngineError::Kernel(c.str()?),
        4 => EngineError::Task(c.str()?),
        5 => EngineError::ZeroBudget { id: c.u64()? },
        6 => EngineError::RequestTooLong {
            id: c.u64()?,
            worst: c.u64()? as usize,
            max_seq: c.u64()? as usize,
        },
        7 => EngineError::KvPoolExceeded {
            id: c.u64()?,
            worst: c.u64()? as usize,
            need_blocks: c.u64()? as usize,
            pool_blocks: c.u64()? as usize,
        },
        8 => EngineError::DuplicateId { id: c.u64()? },
        9 => EngineError::UnknownRequest { id: c.u64()? },
        10 => EngineError::AlreadyFinished { id: c.u64()? },
        11 => EngineError::Overloaded { id: c.u64()?, queue_depth: c.u64()? as usize },
        12 => EngineError::ServerClosed,
        13 => EngineError::SlotRemap { id: c.u64()?, from: c.u64()? as usize, to: c.u64()? as usize },
        14 => EngineError::NoSession { batch: c.u64()? as usize },
        15 => EngineError::Transport(c.str()?),
        _ => return bad("Error", format!("engine error code {code}")),
    })
}

// ---------------------------------------------------------------------------
// frame encode/decode

fn frame_with(tag: u8, payload: impl FnOnce(&mut Vec<u8>)) -> Vec<u8> {
    let mut body = vec![WIRE_VERSION, tag];
    payload(&mut body);
    let mut out = Vec::with_capacity(4 + body.len());
    put_u32(&mut out, body.len() as u32);
    out.extend_from_slice(&body);
    out
}

/// Encode a client frame to full wire bytes (length prefix included).
pub fn encode_client(f: &ClientFrame) -> Vec<u8> {
    match f {
        ClientFrame::Submit { id, priority, deadline_ms, max_new_tokens, prompt } => {
            frame_with(TAG_SUBMIT, |b| {
                put_u64(b, *id);
                b.push(priority_code(*priority));
                put_u64(b, deadline_ms.unwrap_or(NO_DEADLINE));
                put_u32(b, *max_new_tokens);
                put_u32(b, prompt.len() as u32);
                for t in prompt {
                    put_i32(b, *t);
                }
            })
        }
        ClientFrame::Cancel { id } => frame_with(TAG_CANCEL, |b| put_u64(b, *id)),
        ClientFrame::Status => frame_with(TAG_STATUS_REQ, |_| {}),
    }
}

/// Encode a server frame to full wire bytes (length prefix included).
pub fn encode_server(f: &ServerFrame) -> Vec<u8> {
    match f {
        ServerFrame::Accepted { id } => frame_with(TAG_ACCEPTED, |b| put_u64(b, *id)),
        ServerFrame::Token { id, token } => frame_with(TAG_TOKEN, |b| {
            put_u64(b, *id);
            put_i32(b, *token);
        }),
        ServerFrame::Finish { id, token, reason } => frame_with(TAG_FINISH, |b| {
            put_u64(b, *id);
            b.push(finish_code(*reason));
            match token {
                Some(t) => {
                    b.push(1);
                    put_i32(b, *t);
                }
                None => b.push(0),
            }
        }),
        ServerFrame::Error { id, err } => frame_with(TAG_ERROR, |b| {
            put_u64(b, *id);
            put_engine_error(b, err);
        }),
        ServerFrame::Shed { id, queue_depth } => frame_with(TAG_SHED, |b| {
            put_u64(b, *id);
            put_u32(b, *queue_depth);
        }),
        ServerFrame::Status {
            queued,
            in_flight,
            capacity,
            finished,
            shed,
            rejected,
            kv_blocks_total,
            kv_blocks_free,
            kv_blocks_shared,
            kv_blocks_cowed,
            kv_prefix_hits,
            kv_prefill_chunks,
        } => frame_with(TAG_STATUS, |b| {
            put_u32(b, *queued);
            put_u32(b, *in_flight);
            put_u32(b, *capacity);
            put_u64(b, *finished);
            put_u64(b, *shed);
            put_u64(b, *rejected);
            put_u64(b, *kv_blocks_total);
            put_u64(b, *kv_blocks_free);
            put_u64(b, *kv_blocks_shared);
            put_u64(b, *kv_blocks_cowed);
            put_u64(b, *kv_prefix_hits);
            put_u64(b, *kv_prefill_chunks);
        }),
        ServerFrame::Close { reason } => frame_with(TAG_CLOSE, |b| b.push(reason.code())),
    }
}

/// Check a frame body's version byte and split off the tag; shared by
/// both decode directions.
fn open_body(body: &[u8]) -> Result<(u8, &[u8]), TransportError> {
    if body.len() < 2 {
        return Err(TransportError::Truncated { want: 2, got: body.len() });
    }
    if body[0] != WIRE_VERSION {
        return Err(TransportError::BadVersion { got: body[0], want: WIRE_VERSION });
    }
    Ok((body[1], &body[2..]))
}

/// Decode a client-direction frame body (bytes after the length
/// prefix).
pub fn decode_client(body: &[u8]) -> Result<ClientFrame, TransportError> {
    let (tag, payload) = open_body(body)?;
    match tag {
        TAG_SUBMIT => {
            let mut c = Cursor::new(payload, "Submit");
            let id = c.u64()?;
            let priority = priority_from(c.u8()?)?;
            let dl = c.u64()?;
            let deadline_ms = if dl == NO_DEADLINE { None } else { Some(dl) };
            let max_new_tokens = c.u32()?;
            let n = c.u32()? as usize;
            let mut prompt = Vec::with_capacity(n.min(DEFAULT_MAX_FRAME as usize / 4));
            for _ in 0..n {
                prompt.push(c.i32()?);
            }
            c.finish(ClientFrame::Submit { id, priority, deadline_ms, max_new_tokens, prompt })
        }
        TAG_CANCEL => {
            let mut c = Cursor::new(payload, "Cancel");
            let id = c.u64()?;
            c.finish(ClientFrame::Cancel { id })
        }
        TAG_STATUS_REQ => Cursor::new(payload, "Status").finish(ClientFrame::Status),
        _ => Err(TransportError::UnknownFrame { tag }),
    }
}

/// Decode a server-direction frame body (bytes after the length
/// prefix).
pub fn decode_server(body: &[u8]) -> Result<ServerFrame, TransportError> {
    let (tag, payload) = open_body(body)?;
    match tag {
        TAG_ACCEPTED => {
            let mut c = Cursor::new(payload, "Accepted");
            let id = c.u64()?;
            c.finish(ServerFrame::Accepted { id })
        }
        TAG_TOKEN => {
            let mut c = Cursor::new(payload, "Token");
            let id = c.u64()?;
            let token = c.i32()?;
            c.finish(ServerFrame::Token { id, token })
        }
        TAG_FINISH => {
            let mut c = Cursor::new(payload, "Finish");
            let id = c.u64()?;
            let reason = finish_from(c.u8()?)?;
            let token = match c.u8()? {
                0 => None,
                1 => Some(c.i32()?),
                p => return bad("Finish", format!("presence byte {p}")),
            };
            c.finish(ServerFrame::Finish { id, token, reason })
        }
        TAG_ERROR => {
            let mut c = Cursor::new(payload, "Error");
            let id = c.u64()?;
            let err = take_engine_error(&mut c)?;
            c.finish(ServerFrame::Error { id, err })
        }
        TAG_SHED => {
            let mut c = Cursor::new(payload, "Shed");
            let id = c.u64()?;
            let queue_depth = c.u32()?;
            c.finish(ServerFrame::Shed { id, queue_depth })
        }
        TAG_STATUS => {
            let mut c = Cursor::new(payload, "Status");
            let queued = c.u32()?;
            let in_flight = c.u32()?;
            let capacity = c.u32()?;
            let finished = c.u64()?;
            let shed = c.u64()?;
            let rejected = c.u64()?;
            let kv_blocks_total = c.u64()?;
            let kv_blocks_free = c.u64()?;
            let kv_blocks_shared = c.u64()?;
            let kv_blocks_cowed = c.u64()?;
            let kv_prefix_hits = c.u64()?;
            let kv_prefill_chunks = c.u64()?;
            c.finish(ServerFrame::Status {
                queued,
                in_flight,
                capacity,
                finished,
                shed,
                rejected,
                kv_blocks_total,
                kv_blocks_free,
                kv_blocks_shared,
                kv_blocks_cowed,
                kv_prefix_hits,
                kv_prefill_chunks,
            })
        }
        TAG_CLOSE => {
            let mut c = Cursor::new(payload, "Close");
            let reason = CloseReason::from_code(c.u8()?)?;
            c.finish(ServerFrame::Close { reason })
        }
        _ => Err(TransportError::UnknownFrame { tag }),
    }
}

/// Parse a length prefix against the receiver's cap. Returns the body
/// length to read next.
pub fn check_len(prefix: [u8; 4], cap: u32) -> Result<usize, TransportError> {
    let len = u32::from_le_bytes(prefix);
    if len < 2 {
        return Err(TransportError::Truncated { want: 2, got: len as usize });
    }
    if len > cap {
        return Err(TransportError::FrameTooLarge { len, cap });
    }
    Ok(len as usize)
}

// ---------------------------------------------------------------------------
// wire fault injection

/// A deterministic, seed-driven schedule of wire-level chaos — the
/// transport's analogue of the engine's
/// [`FaultPlan`](crate::serving::FaultPlan). All-zero rates (the
/// default) inject nothing. Armed on the server's outbound path via
/// [`TransportConfig::faults`](crate::serving::transport::TransportConfig::faults)
/// and on the loopback client via
/// [`TransportClient::with_faults`](crate::serving::transport::TransportClient::with_faults).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct WireFaultPlan {
    /// RNG seed: same plan + same frame sequence → same fault sequence.
    pub seed: u64,
    /// Probability (0..=1) a frame is written truncated, after which
    /// the connection is dropped (the peer sees a mid-frame EOF).
    pub truncate_rate: f64,
    /// Probability (0..=1) one byte of a frame is flipped in flight.
    pub corrupt_rate: f64,
    /// Probability (0..=1) a frame write is delayed by [`WireFaultPlan::delay`]
    /// first (models a congested or slow peer).
    pub delay_rate: f64,
    /// The per-frame delay `delay_rate` applies.
    pub delay: Duration,
    /// Probability (0..=1) the connection is dropped abruptly instead
    /// of writing the frame at all.
    pub drop_rate: f64,
}

impl Default for WireFaultPlan {
    fn default() -> Self {
        WireFaultPlan {
            seed: 0x5eed,
            truncate_rate: 0.0,
            corrupt_rate: 0.0,
            delay_rate: 0.0,
            delay: Duration::from_millis(1),
            drop_rate: 0.0,
        }
    }
}

impl WireFaultPlan {
    /// Rates must be finite probabilities; rejected before any socket
    /// is opened.
    pub fn validate(&self) -> Result<(), String> {
        for (name, rate) in [
            ("truncate_rate", self.truncate_rate),
            ("corrupt_rate", self.corrupt_rate),
            ("delay_rate", self.delay_rate),
            ("drop_rate", self.drop_rate),
        ] {
            if !rate.is_finite() || !(0.0..=1.0).contains(&rate) {
                return Err(format!("wire fault {name} must be in 0..=1, got {rate}"));
            }
        }
        Ok(())
    }

    /// True when this plan can ever inject anything.
    pub fn is_armed(&self) -> bool {
        self.truncate_rate > 0.0 || self.corrupt_rate > 0.0 || self.delay_rate > 0.0 || self.drop_rate > 0.0
    }
}

/// One injected wire fault for the frame about to be written.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WireFault {
    /// Write only the first `keep` bytes, then drop the connection.
    Truncate { keep: usize },
    /// Flip one bit of the byte at `at` (index into the full frame,
    /// length prefix included) before writing.
    Corrupt { at: usize },
    /// Sleep this long before the write.
    Delay(Duration),
    /// Drop the connection without writing.
    Drop,
}

/// Draws [`WireFault`]s from a [`WireFaultPlan`] — one draw per
/// outbound frame. Draw order is fixed (drop, truncate, corrupt,
/// delay) so a given seed replays identically.
#[derive(Debug)]
pub struct WireFaultInjector {
    plan: WireFaultPlan,
    rng: XorShift64,
}

impl WireFaultInjector {
    pub fn new(plan: WireFaultPlan) -> WireFaultInjector {
        WireFaultInjector { rng: XorShift64::new(plan.seed), plan }
    }

    /// Decide the fate of a `frame_len`-byte frame about to be written.
    pub fn draw(&mut self, frame_len: usize) -> Option<WireFault> {
        if self.plan.drop_rate > 0.0 && self.rng.f64() < self.plan.drop_rate {
            return Some(WireFault::Drop);
        }
        if self.plan.truncate_rate > 0.0 && self.rng.f64() < self.plan.truncate_rate {
            return Some(WireFault::Truncate { keep: self.rng.below(frame_len.max(1)) });
        }
        if self.plan.corrupt_rate > 0.0 && self.rng.f64() < self.plan.corrupt_rate {
            return Some(WireFault::Corrupt { at: self.rng.below(frame_len.max(1)) });
        }
        if self.plan.delay_rate > 0.0 && self.rng.f64() < self.plan.delay_rate {
            return Some(WireFault::Delay(self.plan.delay));
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip_client(f: ClientFrame) {
        let bytes = encode_client(&f);
        let len = check_len(bytes[..4].try_into().unwrap(), DEFAULT_MAX_FRAME).unwrap();
        assert_eq!(len, bytes.len() - 4);
        assert_eq!(decode_client(&bytes[4..]).unwrap(), f);
    }

    fn roundtrip_server(f: ServerFrame) {
        let bytes = encode_server(&f);
        let len = check_len(bytes[..4].try_into().unwrap(), DEFAULT_MAX_FRAME).unwrap();
        assert_eq!(len, bytes.len() - 4);
        assert_eq!(decode_server(&bytes[4..]).unwrap(), f);
    }

    #[test]
    fn client_frames_roundtrip() {
        roundtrip_client(ClientFrame::Submit {
            id: u64::MAX - 1,
            priority: Priority::Batch,
            deadline_ms: Some(1500),
            max_new_tokens: 32,
            prompt: vec![-1, 0, 7, i32::MAX],
        });
        roundtrip_client(ClientFrame::Submit {
            id: 0,
            priority: Priority::Interactive,
            deadline_ms: None,
            max_new_tokens: 1,
            prompt: vec![],
        });
        roundtrip_client(ClientFrame::Cancel { id: 9 });
        roundtrip_client(ClientFrame::Status);
    }

    #[test]
    fn server_frames_roundtrip() {
        roundtrip_server(ServerFrame::Accepted { id: 3 });
        roundtrip_server(ServerFrame::Token { id: 3, token: -42 });
        for reason in [
            FinishReason::MaxTokens,
            FinishReason::Eos,
            FinishReason::Cancelled,
            FinishReason::DeadlineExceeded,
            FinishReason::Shed,
            FinishReason::Failed,
        ] {
            roundtrip_server(ServerFrame::Finish { id: 7, token: Some(5), reason });
            roundtrip_server(ServerFrame::Finish { id: 7, token: None, reason });
        }
        roundtrip_server(ServerFrame::Shed { id: 11, queue_depth: 64 });
        roundtrip_server(ServerFrame::Status {
            queued: 1,
            in_flight: 2,
            capacity: 8,
            finished: 100,
            shed: 3,
            rejected: 4,
            kv_blocks_total: 64,
            kv_blocks_free: 12,
            kv_blocks_shared: 5,
            kv_blocks_cowed: 2,
            kv_prefix_hits: 31,
            kv_prefill_chunks: 7,
        });
        for reason in
            [CloseReason::Drain, CloseReason::SlowConsumer, CloseReason::Protocol, CloseReason::Overloaded]
        {
            roundtrip_server(ServerFrame::Close { reason });
        }
    }

    #[test]
    fn every_engine_error_variant_roundtrips() {
        let variants = vec![
            EngineError::InvalidConfig("bad".into()),
            EngineError::Manifest("missing".into()),
            EngineError::Pool("no backend".into()),
            EngineError::Kernel("wedged".into()),
            EngineError::Task("nan".into()),
            EngineError::ZeroBudget { id: 1 },
            EngineError::RequestTooLong { id: 2, worst: 80, max_seq: 64 },
            EngineError::KvPoolExceeded { id: 3, worst: 90, need_blocks: 12, pool_blocks: 8 },
            EngineError::DuplicateId { id: 4 },
            EngineError::UnknownRequest { id: 5 },
            EngineError::AlreadyFinished { id: 6 },
            EngineError::Overloaded { id: 7, queue_depth: 64 },
            EngineError::ServerClosed,
            EngineError::SlotRemap { id: 8, from: 1, to: 0 },
            EngineError::NoSession { batch: 5 },
            EngineError::Transport("truncated".into()),
        ];
        for err in variants {
            roundtrip_server(ServerFrame::Error { id: 42, err });
        }
    }

    #[test]
    fn oversized_and_tiny_prefixes_are_typed() {
        let bytes = encode_client(&ClientFrame::Cancel { id: 1 });
        let err = check_len(bytes[..4].try_into().unwrap(), 4).unwrap_err();
        assert_eq!(err, TransportError::FrameTooLarge { len: bytes.len() as u32 - 4, cap: 4 });
        let err = check_len(1u32.to_le_bytes(), DEFAULT_MAX_FRAME).unwrap_err();
        assert!(matches!(err, TransportError::Truncated { .. }), "got: {err}");
    }

    #[test]
    fn corruption_maps_to_typed_errors() {
        // bad version byte
        let mut bytes = encode_client(&ClientFrame::Status);
        bytes[4] = 9;
        assert_eq!(decode_client(&bytes[4..]).unwrap_err(), TransportError::BadVersion { got: 9, want: WIRE_VERSION });
        // unknown tag (server tag in the client direction)
        let bytes = encode_server(&ServerFrame::Accepted { id: 1 });
        assert_eq!(decode_client(&bytes[4..]).unwrap_err(), TransportError::UnknownFrame { tag: TAG_ACCEPTED });
        // truncated payload
        let bytes = encode_client(&ClientFrame::Cancel { id: 1 });
        let err = decode_client(&bytes[4..bytes.len() - 2]).unwrap_err();
        assert!(matches!(err, TransportError::BadPayload { .. }), "got: {err}");
        // trailing garbage
        let mut bytes = encode_client(&ClientFrame::Cancel { id: 1 });
        bytes.push(0xff);
        let err = decode_client(&bytes[4..]).unwrap_err();
        assert!(matches!(err, TransportError::BadPayload { .. }), "got: {err}");
        // out-of-range finish reason code
        let mut bytes = encode_server(&ServerFrame::Finish { id: 1, token: None, reason: FinishReason::Eos });
        bytes[4 + 2 + 8] = 99;
        let err = decode_server(&bytes[4..]).unwrap_err();
        assert!(matches!(err, TransportError::BadPayload { .. }), "got: {err}");
    }

    #[test]
    fn wire_fault_plan_validates_and_replays() {
        assert!(WireFaultPlan::default().validate().is_ok());
        assert!(!WireFaultPlan::default().is_armed());
        let bad = WireFaultPlan { corrupt_rate: 2.0, ..Default::default() };
        assert!(bad.validate().unwrap_err().contains("corrupt_rate"));
        let plan = WireFaultPlan {
            seed: 11,
            truncate_rate: 0.2,
            corrupt_rate: 0.2,
            delay_rate: 0.2,
            drop_rate: 0.1,
            ..Default::default()
        };
        assert!(plan.is_armed());
        let seq = |p: WireFaultPlan| {
            let mut inj = WireFaultInjector::new(p);
            (0..128).map(|_| inj.draw(32)).collect::<Vec<_>>()
        };
        let a = seq(plan);
        assert_eq!(a, seq(plan), "same seed must replay the same faults");
        assert!(a.iter().any(|f| f.is_some()) && a.iter().any(|f| f.is_none()));
        for f in a.iter().flatten() {
            match f {
                WireFault::Truncate { keep } => assert!(*keep < 32),
                WireFault::Corrupt { at } => assert!(*at < 32),
                _ => {}
            }
        }
        assert_ne!(a, seq(WireFaultPlan { seed: 12, ..plan }));
    }

    #[test]
    fn transport_error_display_names_the_failure() {
        let e = TransportError::FrameTooLarge { len: 70000, cap: 65536 };
        assert!(e.to_string().contains("70000") && e.to_string().contains("65536"), "got: {e}");
        let e = TransportError::BadVersion { got: 1, want: WIRE_VERSION };
        assert!(e.to_string().contains("version 1"), "got: {e}");
        let e = TransportError::SlowConsumer { depth: 8 };
        assert!(e.to_string().contains("slow consumer"), "got: {e}");
        let e = TransportError::Closed { reason: CloseReason::Drain };
        assert!(e.to_string().contains("Drain"), "got: {e}");
    }
}
