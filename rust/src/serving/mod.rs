//! LLM serving substrate (§6.1): requests, paged KV allocation,
//! continuous batching, and the decode loop over the real megakernel.
pub mod batcher;
pub mod engine;
pub mod kvcache;

pub use batcher::{Batcher, Request};
pub use engine::{ServeEngine, ServeStats};
pub use kvcache::{KvAllocator, KvArena, KvResidency};
