//! LLM serving substrate (§6.1): a **step-driven streaming API** over
//! the persistent megakernel — continuous batching, paged KV, stable
//! slots, typed errors.
//!
//! # Lifecycle
//!
//! 1. **Build** an engine through the validated [`EngineBuilder`]
//!    (`ServeEngine::builder()`): batch ceiling, pool threads, seed,
//!    kernel shape, optional EOS token, opt-in compaction. Config
//!    mistakes are [`EngineError::InvalidConfig`] before any resource
//!    is touched.
//! 2. **Submit** requests with [`ServeEngine::submit`] — at any time,
//!    including between steps on a live engine. Admission into stable
//!    batch slots happens at the next step (online admission).
//! 3. **Step**: every [`ServeEngine::step`] call runs one decode
//!    iteration on the resident kernel and returns a [`StepOutcome`] of
//!    per-request [`TokenEvent`]s — stream them to clients as they
//!    arrive. Terminal events carry a [`FinishReason`]
//!    (`MaxTokens` | `Eos` | `Cancelled`).
//! 4. **Cancel** with [`ServeEngine::cancel`]: the request retires
//!    immediately (slot + KV blocks free for the next admission) and
//!    its `Cancelled` notice rides the next outcome.
//! 5. **Observe and drain**: [`ServeStats`] tracks iterations,
//!    busy-vs-wall time (throughput is computed over busy time),
//!    per-iteration latency quantiles, and per-request TTFT/completion
//!    latency keyed by id. [`ServeEngine::take_stats`] closes a stats
//!    window, and long-lived streaming loops reclaim retired requests
//!    periodically with [`ServeEngine::take_finished`].
//!
//! Batch-mode callers keep the old one-call surface:
//! [`ServeEngine::serve`] is a thin loop over `step()` with identical
//! outputs.
//!
//! ```no_run
//! use mpk::serving::{FinishReason, Request, ServeEngine};
//!
//! let mut engine = ServeEngine::builder()
//!     .max_batch(4)
//!     .seed(42)
//!     .build()
//!     .expect("needs `make artifacts` and a PJRT backend");
//! engine.submit(Request::new(0, vec![3, 7], 16))?;
//! while engine.has_work() {
//!     for ev in engine.step()?.events {
//!         print!("req {} -> {:?}", ev.request, ev.token);
//!         if ev.finish == Some(FinishReason::Eos) {
//!             println!(" (eos)");
//!         }
//!     }
//!     // mid-flight: submit() / cancel() freely between steps.
//! }
//! # Ok::<(), mpk::serving::EngineError>(())
//! ```
pub mod batcher;
pub mod engine;
pub mod error;
pub mod kvcache;
pub mod step;

pub use batcher::{Batcher, Request};
pub use engine::{EngineBuilder, RequestLatency, ServeEngine, ServeStats};
pub use error::EngineError;
pub use kvcache::{KvAllocator, KvArena, KvResidency};
pub use step::{FinishReason, StepOutcome, TokenEvent};
