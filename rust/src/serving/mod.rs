//! LLM serving substrate (§6.1): a threaded, overload-hardened
//! **server** over a step-driven streaming engine — continuous
//! batching, paged KV, stable slots, deadlines, load shedding,
//! fault-tolerant steps, typed errors.
//!
//! # The server lifecycle
//!
//! Most callers should hold a [`ServeServer`] and talk to it through
//! [`ServerClient`] handles; the engine's single-threaded step loop
//! becomes an implementation detail owned by one serving thread:
//!
//! 1. **Spawn** with [`ServeServer::spawn`], passing a configured
//!    [`EngineBuilder`] and a [`ServerConfig`] (wait-queue bound, idle
//!    poll). The engine is built on the caller's thread, so
//!    configuration mistakes surface synchronously as
//!    [`EngineError::InvalidConfig`] — then it moves onto a dedicated
//!    thread that loops [`ServeEngine::step`].
//! 2. **Submit** from any thread via [`ServerClient::submit_with`]:
//!    pick a [`Priority`] class and an optional deadline
//!    ([`SubmitOptions`]). Acceptance returns a [`TokenStream`] that
//!    yields the request's [`TokenEvent`]s as the engine decodes them,
//!    ending with exactly one terminal event ([`FinishReason`]).
//! 3. **Overload degrades loudly, not silently**: the wait queue is
//!    bounded; a submission past the bound displaces a strictly
//!    lower-priority waiter (terminal [`FinishReason::Shed`] on its
//!    stream) or is refused with the typed, retryable
//!    [`EngineError::Overloaded`]. Deadlines are enforced by the server
//!    as scheduled terminations — a terminal
//!    [`FinishReason::DeadlineExceeded`] event, never an engine error.
//! 4. **Failures are contained**: the engine retries failed epochs
//!    against its resident kernel and quarantines a request only when
//!    repeated failures are attributed to it (terminal
//!    [`FinishReason::Failed`], everyone else keeps slots and KV) —
//!    see [`fault`]. Only a persistent unattributable failure kills
//!    the serving thread, and then every live stream is failed
//!    terminally and the error lands in `ServerReport::fatal`.
//! 5. **Shut down** with [`ServeServer::shutdown`]: in-flight work
//!    drains to terminal events, and the [`ServerReport`] returns the
//!    counters (finished / shed / rejected / expired / quarantined)
//!    plus the engine's final [`ServeStats`] window.
//!
//! ```no_run
//! use mpk::serving::{Request, ServeEngine, ServeServer, ServerConfig};
//!
//! let server = ServeServer::spawn(
//!     ServeEngine::builder().max_batch(4).seed(42),
//!     ServerConfig::default(),
//! ).expect("needs `make artifacts` and a PJRT backend");
//! let client = server.client();
//! let (tokens, finish) = client.submit(Request::new(0, vec![3, 7], 16))?.collect_output();
//! println!("req 0 -> {tokens:?} ({finish:?})");
//! let report = server.shutdown();
//! assert_eq!(report.finished, 1);
//! # Ok::<(), mpk::serving::EngineError>(())
//! ```
//!
//! # The engine underneath
//!
//! [`ServeEngine`] is the embeddable single-threaded core for callers
//! that want to own the loop: build through the validated
//! [`EngineBuilder`], [`ServeEngine::submit`] at any time (online
//! admission into stable slots), drive [`ServeEngine::step`] and fan
//! out each [`StepOutcome`], terminate early with
//! [`ServeEngine::cancel`] / [`ServeEngine::terminate`], observe
//! [`ServeStats`], reclaim retired requests with
//! [`ServeEngine::take_finished`]. Batch-mode callers keep the one-call
//! [`ServeEngine::serve`]. The server front-end is a thin, testable
//! layer over exactly this surface (the [`StepEngine`] trait —
//! [`mock::MockEngine`] runs the front-end without artifacts).
//!
//! # The paged KV lifecycle
//!
//! With [`EngineBuilder::paged_kv`], a request's KV cache is a set of
//! fixed-size blocks from a [`PagedKvPool`] carved over the shared
//! arena ([`paged`]), indirected through a per-request block table —
//! not a contiguous span. The request's life then reads:
//!
//! 1. **Admit**: [`Batcher`] admission reserves only the blocks the
//!    *prompt* needs (growth is on demand, one block at a time), after
//!    consulting the prefix index — a rolling hash over full prompt
//!    blocks. Every indexed block that matches token-for-token is
//!    mapped into the new table refcounted ([`Admission`] reports how
//!    many), so a wave sharing a system prompt physically shares its
//!    prefix blocks and decode resumes past them.
//! 2. **Prefill, optionally chunked**: with
//!    [`EngineBuilder::prefill_chunk`], a long prompt is staged across
//!    up to that many *extra* kernel epochs per step, so one giant
//!    prefill cannot stall the decode cadence of the rest of the
//!    batch (decode rows are re-staged idempotently; their logits are
//!    discarded).
//! 3. **Decode, zero-copy**: every step appends one KV row through the
//!    block table ([`Append`] names the physical block). Steady-state
//!    decode allocates nothing and copies nothing; writing into a
//!    block shared with another request first copies it
//!    (copy-on-write — one counted block copy, see
//!    [`ServeStats::kv_blocks_cowed`]). A request that needs one more
//!    block from an exhausted pool is displaced with a terminal
//!    [`FinishReason::Shed`] — never a panic, never a stall.
//! 4. **Release**: retirement returns the request's blocks to the free
//!    list; blocks still referenced by the prefix index or another
//!    table survive until their last reference drops. Pool occupancy
//!    is observable at every step via [`ServeEngine::kv_status`] and
//!    crosses the wire in the `Status` frame.
//!
//! The legacy contiguous allocator ([`KvAllocator`]) remains the
//! default; its slot-moving compaction machinery is quarantined to
//! that path and asserted unreachable when paging is on.
//!
//! # The network transport
//!
//! [`ServeTransport`] puts the server behind a TCP socket: a
//! stdlib-only listener that speaks a versioned, length-prefixed
//! binary frame protocol ([`wire`]) and translates each connection
//! into [`ServerClient`] calls. One frame is
//!
//! | bytes | field | meaning |
//! |------:|-------|---------|
//! | 4 | `len` (u32 LE) | body length, checked against the max-frame cap **before** the body is read |
//! | 1 | `version` | [`wire::WIRE_VERSION`] — mismatch is a typed [`TransportError::BadVersion`] |
//! | 1 | `tag` | frame kind: client `0x01..=0x03`, server `0x81..=0x87` |
//! | `len - 2` | payload | tag-specific fields, little-endian |
//!
//! Clients send [`ClientFrame`] (`Submit` / `Cancel` / `Status`); the
//! server streams back [`ServerFrame`] (`Accepted`, `Token`, `Finish`,
//! typed `Error` / `Shed`, `Status`, `Close`). Serving-layer errors
//! cross the wire *typed*: every [`EngineError`] variant round-trips
//! through the `Error` frame, and transport-layer failures map into
//! [`EngineError::Transport`] via the `From<TransportError>` shim.
//!
//! The transport is hardened the same way the server is: read/write
//! deadlines and a frame-size cap bound slow or hostile peers, a
//! per-connection in-flight cap sheds excess load with the existing
//! typed backpressure, a client disconnect mid-stream cancels its
//! requests immediately (slots and KV free at once), per-stream
//! outbound buffering is bounded with a pick-one
//! [`SlowReaderPolicy`], and [`ServeTransport::drain`] stops
//! accepting, flushes live streams until a deadline, force-terminates
//! the rest, and returns a [`DrainReport`]. A seeded
//! [`WireFaultPlan`] injects truncated/corrupted/delayed frames and
//! dropped connections for chaos tests.
//!
//! ```no_run
//! use std::time::Duration;
//! use mpk::serving::mock::MockEngine;
//! use mpk::serving::{
//!     ServeServer, ServeTransport, ServerConfig, SubmitOptions, TransportClient, TransportConfig,
//! };
//!
//! // Listener: any StepEngine behind a socket.
//! let server = ServeServer::spawn_with(MockEngine::new(4), ServerConfig::default());
//! let transport =
//!     ServeTransport::bind("127.0.0.1:0", server, TransportConfig::default()).unwrap();
//! let addr = transport.local_addr();
//!
//! // Client: connect, run one request to its terminal event.
//! let mut client = TransportClient::connect(addr).unwrap();
//! let (tokens, finish) = client.run(1, vec![3, 7], 8, SubmitOptions::default()).unwrap();
//! println!("req 1 -> {tokens:?} ({finish:?})");
//!
//! // Graceful drain: bounded, reconciled.
//! let report = transport.drain(Duration::from_secs(2));
//! assert!(report.server.fatal.is_none());
//! ```
pub mod batcher;
pub mod engine;
pub mod error;
pub mod fault;
pub mod kvcache;
pub mod mock;
pub mod paged;
pub mod server;
pub mod step;
pub mod transport;
pub mod wire;

pub use batcher::{Batcher, KvPool, Request};
pub use engine::{EngineBuilder, RequestLatency, ServeEngine, ServeStats};
pub use error::EngineError;
pub use fault::FaultPlan;
pub use kvcache::{KvAllocator, KvArena, KvResidency};
pub use paged::{Admission, Append, PagedKvPool};
pub use server::{
    Priority, ServeServer, ServerClient, ServerConfig, ServerReport, ServerStatus, StepEngine,
    SubmitOptions, TokenStream,
};
pub use step::{FinishReason, StepOutcome, TokenEvent};
pub use transport::{
    DrainReport, ServeTransport, SlowReaderPolicy, TransportClient, TransportConfig,
};
pub use wire::{ClientFrame, CloseReason, ServerFrame, TransportError, WireFaultPlan};
