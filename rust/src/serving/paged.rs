//! Paged KV cache: block-granular allocation over the shared
//! [`KvArena`] slab, copy-on-write prefix sharing, and the accounting
//! the chunked-prefill scheduler reads.
//!
//! # Why paging
//!
//! The slot-contiguous [`KvArena`] layout gives every admitted request
//! `s_max` rows from admission to retirement: memory scales with the
//! *worst-case* sequence length, and two requests with an identical
//! system prompt share nothing. [`PagedKvPool`] keeps the arena's
//! physical layout (same slab, same per-layer K/V segments) but
//! re-partitions each segment's `slots × s_max` token rows into
//! fixed-size **blocks** of `block_tokens` rows. A request owns a
//! *block table* — logical block `i` of the sequence maps to physical
//! block `table[i]` — in the pooling-allocator idiom: index-based
//! reuse off a free list, no per-step allocation, no compaction (a
//! "relocation" would be a table rewrite, which is why the legacy
//! `move_slot` compaction path is unreachable when paging is on).
//!
//! # Prefix sharing and copy-on-write
//!
//! Fully written **prompt** blocks are published to a prefix index
//! keyed by a *chained* rolling hash: the key for block `i` hashes the
//! whole prompt prefix `tokens[0..(i+1)·block_tokens]`, so a lookup
//! chain only continues while every earlier block matched, and each
//! hit is verified against the stored block tokens (hash collisions
//! degrade to a miss, never to aliasing a wrong block). Admission
//! walks the chain and maps matched physical blocks into the new
//! request's table with a reference-count bump; the request resumes
//! prefill at the first unshared token (clamped to `prompt_len - 1` so
//! at least one prompt token is always processed and the request's
//! first logits are computed from its own forward pass).
//!
//! Shared blocks are **read-only**. The index itself holds one pinning
//! reference per published block (so a popular prefix survives its
//! original request), and any append into a block with `refs > 1`
//! triggers exactly one block copy — counted honestly in
//! `kv_blocks_cowed`, the only arena copy the zero-copy decode
//! contract permits. When the free list runs dry, pinned prefixes are
//! evicted FIFO until a block frees; live request tables are never
//! evicted, so exhaustion surfaces to the engine as a typed
//! [`Append::Exhausted`] / failed admission, never a panic.

use crate::exec::store::SharedSlab;
use crate::metrics::KvPoolStats;
use crate::serving::kvcache::KvArena;
use std::collections::{HashMap, VecDeque};

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// Extend a running FNV-1a hash over a block of prompt tokens. The
/// chain property (block `i`'s key depends on every earlier token)
/// falls out of threading the running hash through consecutive calls.
fn fnv_extend(mut h: u64, tokens: &[i32]) -> u64 {
    for &t in tokens {
        for b in t.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(FNV_PRIME);
        }
    }
    h
}

/// A published full prompt block: which physical block holds it, plus
/// the block's own tokens for collision-proof equality (the chained
/// key already pins every earlier token).
struct PrefixEntry {
    phys: usize,
    tokens: Vec<i32>,
}

/// Result of a paged admission.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Admission {
    /// Token position prefill resumes at (`0` for a cold prompt): the
    /// first `resume` cache rows were mapped in from shared blocks.
    pub resume: usize,
    /// How many whole blocks were shared from the prefix index.
    pub shared_blocks: usize,
}

/// What `ensure_append` had to do to make position `pos` writable.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Append {
    /// The position lands in a block this request exclusively owns.
    Ready,
    /// A fresh block was appended to the table (on-demand growth).
    Grew,
    /// The target block was shared: one block copy was made and the
    /// table now points at the private copy (`kv_blocks_cowed` += 1).
    Cowed,
    /// No block could be allocated even after evicting every pinned
    /// prefix — the caller must shed, never panic.
    Exhausted,
}

/// Block-granular KV pool over the shared max-batch [`KvArena`] slab.
///
/// Physical block `p` of layer `l`'s K (resp. V) segment is the
/// contiguous element span `[k_offset(l) + p·block_tokens·kv_dim, …)`
/// of length `block_tokens·kv_dim` — block tables are pure pointer
/// arithmetic over the same memory the slot-contiguous layout used.
pub struct PagedKvPool {
    slab: SharedSlab,
    layers: usize,
    /// Elements per layer-direction segment (= slots · s_max · kv_dim).
    seg: usize,
    kv_dim: usize,
    block_tokens: usize,
    total_blocks: usize,
    /// Free physical blocks (LIFO for reuse locality).
    free: Vec<usize>,
    /// Per-block reference count: one per request table containing the
    /// block, plus one if the prefix index pins it.
    refs: Vec<u32>,
    /// request id → block table (logical block i → physical block).
    tables: HashMap<u64, Vec<usize>>,
    /// chained prefix hash → published block.
    prefix: HashMap<u64, PrefixEntry>,
    /// physical block → the chained hash it is published under.
    hash_of: HashMap<usize, u64>,
    /// Publication order of chained hashes — the FIFO eviction queue.
    registered: VecDeque<u64>,
    /// Cumulative copy-on-write block copies.
    cowed: u64,
    /// Cumulative fresh-block allocations (shared mappings excluded).
    alloc_total: u64,
    /// Cumulative blocks mapped in from the prefix index at admission.
    share_hits: u64,
}

impl PagedKvPool {
    /// Build a pool over `arena`'s slab with `block_tokens`-token
    /// blocks. `block_tokens` must divide the arena's `s_max` so block
    /// boundaries never straddle a legacy slot boundary mid-row.
    pub fn over(arena: &KvArena, block_tokens: usize) -> Self {
        assert!(block_tokens > 0, "block_tokens must be nonzero");
        assert_eq!(
            arena.s_max() % block_tokens,
            0,
            "block_tokens {} must divide s_max {}",
            block_tokens,
            arena.s_max()
        );
        let total_blocks = arena.slots() * arena.s_max() / block_tokens;
        PagedKvPool {
            slab: arena.slab(),
            layers: arena.layers(),
            seg: arena.slots() * arena.s_max() * arena.kv_dim(),
            kv_dim: arena.kv_dim(),
            block_tokens,
            total_blocks,
            free: (0..total_blocks).rev().collect(),
            refs: vec![0; total_blocks],
            tables: HashMap::new(),
            prefix: HashMap::new(),
            hash_of: HashMap::new(),
            registered: VecDeque::new(),
            cowed: 0,
            alloc_total: 0,
            share_hits: 0,
        }
    }

    pub fn block_tokens(&self) -> usize {
        self.block_tokens
    }

    pub fn total_blocks(&self) -> usize {
        self.total_blocks
    }

    pub fn free_blocks(&self) -> usize {
        self.free.len()
    }

    /// Blocks needed to hold `tokens` tokens.
    pub fn blocks_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.block_tokens)
    }

    /// Blocks currently mapped by a request's table.
    pub fn held_by(&self, req: u64) -> usize {
        self.tables.get(&req).map_or(0, |t| t.len())
    }

    /// The request's block table (logical → physical), if admitted.
    pub fn table(&self, req: u64) -> Option<&[usize]> {
        self.tables.get(&req).map(|t| t.as_slice())
    }

    /// Handle to the backing slab (the same memory every session's
    /// cache tensors alias).
    pub fn slab(&self) -> SharedSlab {
        self.slab.clone()
    }

    /// Element offset of layer `l`'s K segment (mirrors
    /// [`KvArena::k_offset`] — the pool never re-lays-out the arena).
    pub fn k_offset(&self, l: usize) -> usize {
        assert!(l < self.layers);
        2 * l * self.seg
    }

    /// Element offset of layer `l`'s V segment.
    pub fn v_offset(&self, l: usize) -> usize {
        assert!(l < self.layers);
        (2 * l + 1) * self.seg
    }

    /// Gauge: blocks currently referenced more than once (shared
    /// between requests, or between a request and the prefix index).
    pub fn shared_blocks(&self) -> usize {
        self.refs.iter().filter(|&&r| r >= 2).count()
    }

    /// Cumulative copy-on-write block copies.
    pub fn cowed_total(&self) -> u64 {
        self.cowed
    }

    /// Cumulative fresh-block allocations (admission + growth + COW).
    pub fn blocks_allocated(&self) -> u64 {
        self.alloc_total
    }

    /// Cumulative blocks mapped from the prefix index at admission.
    pub fn prefix_hits(&self) -> u64 {
        self.share_hits
    }

    /// Plain-data snapshot for observability (`prefill_chunks` is
    /// engine-side scheduling state and stays 0 here — the engine
    /// overlays it).
    pub fn stats(&self) -> KvPoolStats {
        KvPoolStats {
            blocks_total: self.total_blocks as u64,
            blocks_free: self.free.len() as u64,
            blocks_shared: self.shared_blocks() as u64,
            blocks_cowed: self.cowed,
            prefix_hits: self.share_hits,
            prefill_chunks: 0,
        }
    }

    /// Pop a free block, evicting pinned prefixes FIFO on demand.
    /// Returns `None` only when every block is held by a live table.
    /// The returned block's refcount is set to 1 for the caller.
    fn alloc_block(&mut self) -> Option<usize> {
        while self.free.is_empty() {
            if !self.evict_one() {
                return None;
            }
        }
        let p = self.free.pop().unwrap();
        debug_assert_eq!(self.refs[p], 0, "free block {p} had live references");
        self.refs[p] = 1;
        Some(p)
    }

    /// Unpublish the oldest prefix entry. Its block frees only if no
    /// live table still maps it. A mid-chain eviction leaves deeper
    /// entries of the same prefix unreachable (an admission walk stops
    /// at the first miss), but they sit ahead in the same FIFO and are
    /// evicted next — temporarily cold, never leaked.
    fn evict_one(&mut self) -> bool {
        let Some(h) = self.registered.pop_front() else { return false };
        let e = self.prefix.remove(&h).expect("registered hash lost its prefix entry");
        self.hash_of.remove(&e.phys);
        self.refs[e.phys] -= 1;
        if self.refs[e.phys] == 0 {
            self.free.push(e.phys);
        }
        true
    }

    /// Admit a request: walk the prefix index over `prompt`'s full
    /// blocks, map every matching block in (refcount bump, no copy),
    /// then allocate fresh blocks so the table covers the whole
    /// prompt — **only** the prompt; decode-time growth is on demand.
    /// All-or-nothing: on exhaustion the partial table is rolled back
    /// and `None` is returned (the caller keeps the request queued or
    /// sheds it — this is not a panic path).
    pub fn admit(&mut self, id: u64, prompt: &[i32]) -> Option<Admission> {
        debug_assert!(!self.tables.contains_key(&id), "request {id} admitted twice");
        let bt = self.block_tokens;
        let need = self.blocks_for(prompt.len());
        let mut table: Vec<usize> = Vec::with_capacity(need);

        let mut h = FNV_OFFSET;
        for i in 0..prompt.len() / bt {
            let tokens = &prompt[i * bt..(i + 1) * bt];
            h = fnv_extend(h, tokens);
            let Some(e) = self.prefix.get(&h) else { break };
            if e.tokens != tokens {
                break; // chained-hash collision: treat as a miss.
            }
            self.refs[e.phys] += 1;
            table.push(e.phys);
        }
        let shared = table.len();

        while table.len() < need {
            match self.alloc_block() {
                Some(p) => table.push(p),
                None => {
                    // roll back shared bumps and fresh blocks alike.
                    for &p in &table {
                        self.refs[p] -= 1;
                        if self.refs[p] == 0 {
                            self.free.push(p);
                        }
                    }
                    return None;
                }
            }
        }
        self.alloc_total += (need - shared) as u64;
        self.share_hits += shared as u64;
        self.tables.insert(id, table);
        // resume clamps to the last prompt token: the request always
        // runs its own forward pass for at least one position, and a
        // fully shared prompt re-appends its final row (one honest COW
        // into a private block) instead of skipping prefill entirely.
        Some(Admission { resume: (shared * bt).min(prompt.len().saturating_sub(1)), shared_blocks: shared })
    }

    /// Make token position `pos` writable for `id`: grow the table by
    /// one block if `pos` is past its end, or copy-on-write if the
    /// target block is shared. Exhaustion is a typed outcome.
    pub fn ensure_append(&mut self, id: u64, pos: usize) -> Append {
        let bt = self.block_tokens;
        let b = pos / bt;
        let Some(len) = self.tables.get(&id).map(|t| t.len()) else {
            debug_assert!(false, "ensure_append for unadmitted request {id}");
            return Append::Exhausted;
        };
        if b >= len {
            debug_assert_eq!(b, len, "append skipped block {len}..{b} for request {id}");
            let Some(p) = self.alloc_block() else { return Append::Exhausted };
            self.alloc_total += 1;
            self.tables.get_mut(&id).unwrap().push(p);
            return Append::Grew;
        }
        let phys = self.tables[&id][b];
        if self.refs[phys] <= 1 {
            return Append::Ready;
        }
        // copy-on-write: one block copy per layer-direction segment,
        // then repoint this request's table at the private copy.
        let Some(np) = self.alloc_block() else { return Append::Exhausted };
        self.alloc_total += 1;
        let bs = bt * self.kv_dim;
        for l in 0..self.layers {
            for base in [self.k_offset(l), self.v_offset(l)] {
                self.slab.copy_within(base + phys * bs, base + np * bs, bs);
            }
        }
        self.refs[phys] -= 1;
        debug_assert!(self.refs[phys] >= 1, "COW source lost its other reference");
        self.tables.get_mut(&id).unwrap()[b] = np;
        self.cowed += 1;
        Append::Cowed
    }

    /// Publish `id`'s fully written prompt blocks to the prefix index.
    /// Call after appends whenever `cache_len` crosses a block
    /// boundary inside the prompt; idempotent (an already-published
    /// chain hash is skipped, so a COW'd duplicate of a published
    /// block is never double-registered). Publication pins the block
    /// with one index-owned reference so the prefix outlives the
    /// request; pins are dropped FIFO under memory pressure.
    pub fn promote(&mut self, id: u64, prompt: &[i32], cache_len: usize) {
        let bt = self.block_tokens;
        let Some(table) = self.tables.get(&id) else { return };
        let full = cache_len.min(prompt.len()) / bt;
        let mut h = FNV_OFFSET;
        for i in 0..full {
            let tokens = &prompt[i * bt..(i + 1) * bt];
            h = fnv_extend(h, tokens);
            if self.prefix.contains_key(&h) {
                continue;
            }
            let phys = table[i];
            self.refs[phys] += 1;
            self.prefix.insert(h, PrefixEntry { phys, tokens: tokens.to_vec() });
            self.hash_of.insert(phys, h);
            self.registered.push_back(h);
        }
    }

    /// Release a retired request's table. Blocks free when their last
    /// reference drops; published blocks stay resident under their
    /// index pin (that is the point of prefix sharing). Returns the
    /// number of table entries released.
    pub fn release(&mut self, id: u64) -> usize {
        let Some(table) = self.tables.remove(&id) else { return 0 };
        let n = table.len();
        for p in table {
            self.refs[p] -= 1;
            if self.refs[p] == 0 {
                self.free.push(p);
            }
        }
        n
    }

    /// Structural invariants, for tests and the property harness:
    /// every block's refcount equals (tables mapping it) + (1 if the
    /// index pins it); the free list is exactly the zero-ref blocks,
    /// without duplicates; index bookkeeping is mutually consistent.
    pub fn check_invariants(&self) -> Result<(), String> {
        let mut want = vec![0u32; self.total_blocks];
        for (id, t) in &self.tables {
            for &p in t {
                if p >= self.total_blocks {
                    return Err(format!("request {id} maps out-of-range block {p}"));
                }
                want[p] += 1;
            }
        }
        for &p in self.hash_of.keys() {
            want[p] += 1;
        }
        for (p, (&got, &w)) in self.refs.iter().zip(&want).enumerate() {
            if got != w {
                return Err(format!("block {p}: refs {got}, expected {w}"));
            }
        }
        let mut seen = vec![false; self.total_blocks];
        for &p in &self.free {
            if seen[p] {
                return Err(format!("block {p} on the free list twice"));
            }
            seen[p] = true;
            if self.refs[p] != 0 {
                return Err(format!("block {p} free with {} refs", self.refs[p]));
            }
        }
        let zero_refs = self.refs.iter().filter(|&&r| r == 0).count();
        if zero_refs != self.free.len() {
            return Err(format!(
                "{zero_refs} zero-ref blocks but {} on the free list (leak)",
                self.free.len()
            ));
        }
        if self.prefix.len() != self.hash_of.len() || self.prefix.len() != self.registered.len() {
            return Err(format!(
                "index bookkeeping skew: {} entries, {} hash_of, {} registered",
                self.prefix.len(),
                self.hash_of.len(),
                self.registered.len()
            ));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pool(slots: usize) -> PagedKvPool {
        // 2 layers, 16-token slots, kv_dim 4, 4-token blocks.
        PagedKvPool::over(&KvArena::new(2, slots, 16, 4), 4)
    }

    /// Simulate a prefill: mark blocks written and publish full ones,
    /// painting recognizable data so sharing can be bit-checked.
    fn prefill(p: &mut PagedKvPool, id: u64, prompt: &[i32]) {
        for pos in 0..prompt.len() {
            assert_ne!(p.ensure_append(id, pos), Append::Exhausted);
            let t = p.table(id).unwrap();
            let (b, o) = (pos / 4, pos % 4);
            let bs = 4 * 4;
            for l in 0..2 {
                for (s, base) in [p.k_offset(l), p.v_offset(l)].into_iter().enumerate() {
                    let row: Vec<f32> =
                        (0..4).map(|e| (l * 1000 + s * 100 + pos * 10 + e) as f32).collect();
                    p.slab().write(base + t[b] * bs + o * 4, &row);
                }
            }
            p.promote(id, prompt, pos + 1);
        }
        p.check_invariants().unwrap();
    }

    #[test]
    fn admit_reserves_prompt_blocks_only() {
        let mut p = pool(2); // 8 blocks
        let a = p.admit(1, &[9; 6]).unwrap();
        assert_eq!(a, Admission { resume: 0, shared_blocks: 0 });
        assert_eq!(p.held_by(1), 2, "6 tokens -> 2 blocks, not worst-case");
        assert_eq!(p.free_blocks(), 6);
        p.check_invariants().unwrap();
    }

    #[test]
    fn growth_is_on_demand_and_release_frees() {
        let mut p = pool(2);
        p.admit(1, &[3; 4]).unwrap();
        assert_eq!(p.ensure_append(1, 0), Append::Ready);
        assert_eq!(p.ensure_append(1, 3), Append::Ready);
        assert_eq!(p.ensure_append(1, 4), Append::Grew);
        assert_eq!(p.held_by(1), 2);
        assert_eq!(p.release(1), 2);
        assert_eq!(p.free_blocks(), 8);
        p.check_invariants().unwrap();
    }

    #[test]
    fn shared_prefix_resumes_and_cows_bit_identically() {
        let mut p = pool(2);
        let prompt: Vec<i32> = (0..8).collect();
        p.admit(1, &prompt).unwrap();
        prefill(&mut p, 1, &prompt);
        assert_eq!(p.prefix_hits(), 0);
        p.release(1); // both blocks stay pinned by the index
        assert_eq!(p.free_blocks(), 6);

        let a = p.admit(2, &prompt).unwrap();
        assert_eq!(a.shared_blocks, 2);
        assert_eq!(a.resume, 7, "resume clamps to prompt_len - 1");
        assert_eq!(p.free_blocks(), 6, "sharing allocates nothing");
        let shared_phys = p.table(2).unwrap()[1];
        let before = p.slab().read(p.k_offset(0) + shared_phys * 16, 16);

        // appending at the resume position must COW the shared block...
        assert_eq!(p.ensure_append(2, 7), Append::Cowed);
        assert_eq!(p.cowed_total(), 1);
        let new_phys = p.table(2).unwrap()[1];
        assert_ne!(new_phys, shared_phys);
        // ...with a bit-identical copy, leaving the original untouched.
        assert_eq!(p.slab().read(p.k_offset(0) + new_phys * 16, 16), before);
        p.slab().write(p.k_offset(0) + new_phys * 16, &[-1.0; 4]);
        assert_eq!(p.slab().read(p.k_offset(0) + shared_phys * 16, 16), before);
        p.check_invariants().unwrap();
    }

    #[test]
    fn partial_prefix_shares_full_blocks_only() {
        let mut p = pool(2);
        let prompt: Vec<i32> = (0..8).collect();
        p.admit(1, &prompt).unwrap();
        prefill(&mut p, 1, &prompt);
        p.release(1);

        // same first block, diverging second block.
        let other: Vec<i32> = (0..4).chain(90..94).collect();
        let a = p.admit(2, &other).unwrap();
        assert_eq!(a.shared_blocks, 1);
        assert_eq!(a.resume, 4, "resume at the first unshared token");
        assert_eq!(p.held_by(2), 2);
        // the unshared tail never COWs: writes land in the fresh block.
        assert_eq!(p.ensure_append(2, 4), Append::Ready);
        assert_eq!(p.cowed_total(), 0);
        p.check_invariants().unwrap();
    }

    #[test]
    fn mid_block_prompts_do_not_publish_or_share_the_partial_block() {
        let mut p = pool(2);
        let prompt: Vec<i32> = (0..6).collect(); // 1 full + 1 partial block
        p.admit(1, &prompt).unwrap();
        prefill(&mut p, 1, &prompt);
        p.release(1);
        assert_eq!(p.free_blocks(), 7, "only the full block stays pinned");
        let a = p.admit(2, &prompt).unwrap();
        assert_eq!(a.shared_blocks, 1);
        assert_eq!(a.resume, 4);
        p.check_invariants().unwrap();
    }

    #[test]
    fn pinned_prefixes_evict_fifo_under_pressure() {
        let mut p = pool(2); // 8 blocks
        // publish two 2-block prefixes (4 pinned blocks), retire both.
        for (id, base) in [(1u64, 0i32), (2, 50)] {
            let prompt: Vec<i32> = (base..base + 8).collect();
            p.admit(id, &prompt).unwrap();
            prefill(&mut p, id, &prompt);
            p.release(id);
        }
        assert_eq!(p.free_blocks(), 4);
        // a cold 6-block prompt forces FIFO eviction of prefix 1 first.
        let cold: Vec<i32> = (900..924).collect();
        let a = p.admit(3, &cold).unwrap();
        assert_eq!(a.shared_blocks, 0);
        assert_eq!(p.held_by(3), 6);
        // prefix 2 survived (evictions stop as soon as a block frees).
        let again: Vec<i32> = (50..58).collect();
        let b = p.admit(4, &again);
        assert!(b.is_none(), "pool is full of live tables now");
        p.release(3);
        let b = p.admit(4, &again).unwrap();
        assert!(b.shared_blocks >= 1, "the younger prefix outlived the eviction");
        p.check_invariants().unwrap();
    }

    #[test]
    fn exhaustion_is_typed_and_rolls_back() {
        let mut p = pool(1); // 4 blocks
        p.admit(1, &[1; 16]).unwrap(); // all 4 blocks, live
        assert!(p.admit(2, &[2; 4]).is_none(), "no free blocks, nothing evictable");
        assert_eq!(p.held_by(2), 0, "failed admission must not leak");
        assert_eq!(p.ensure_append(1, 16), Append::Exhausted);
        p.check_invariants().unwrap();
        p.release(1);
        assert_eq!(p.free_blocks(), 4);
        assert!(p.admit(2, &[2; 4]).is_some());
    }

    #[test]
    fn blocks_for_boundary_rounding() {
        let p = pool(2);
        assert_eq!(p.blocks_for(0), 0);
        assert_eq!(p.blocks_for(1), 1);
        assert_eq!(p.blocks_for(4), 1);
        assert_eq!(p.blocks_for(5), 2);
        assert_eq!(p.blocks_for(32), 8, "exactly the whole pool");
        assert_eq!(p.blocks_for(33), 9, "one past the pool boundary");
    }

    #[test]
    fn stats_snapshot_tracks_gauges_and_counters() {
        let mut p = pool(2);
        let prompt: Vec<i32> = (0..8).collect();
        p.admit(1, &prompt).unwrap();
        prefill(&mut p, 1, &prompt);
        let s = p.stats();
        assert_eq!(s.blocks_total, 8);
        assert_eq!(s.blocks_free, 6);
        assert_eq!(s.blocks_shared, 2, "published blocks are request+index shared");
        p.admit(2, &prompt).unwrap();
        p.ensure_append(2, 7);
        let s = p.stats();
        assert_eq!(s.blocks_cowed, 1);
        assert_eq!(s.prefix_hits, 2);
        assert_eq!(s.blocks_free as usize + p.refs.iter().filter(|&&r| r > 0).count(), 8);
    }
}
