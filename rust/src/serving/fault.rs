//! Deterministic fault injection and the step-recovery state machine.
//!
//! Production hardening needs failures on demand: [`FaultPlan`] is a
//! seed-driven chaos schedule the [`EngineBuilder`] wires into the
//! engine (`.faults(plan)`), making kernel epochs and task bodies fail
//! at configured rates — deterministically per seed, with an optional
//! *poison* request whose presence fails every epoch it is staged in
//! (the reproducible worst case for quarantine testing).
//!
//! The [`Recovery`] state machine decides what a failed epoch attempt
//! becomes, for both the real engine and the mock engine (so the server
//! front-end's failure behavior is testable without artifacts):
//!
//! 1. **Retry** with bounded exponential backoff while the per-step
//!    retry budget lasts — a retried epoch is idempotent because the
//!    staging inputs (token ids, row lengths) are rewritten from
//!    request state that only advances at harvest, and the KV row for
//!    this step is written at a position derived from that same state,
//!    so a partial epoch's writes are simply overwritten.
//! 2. **Quarantine** the most-blamed request once the budget is spent
//!    and the failures were attributable (injected task faults carry a
//!    victim): the request retires with a terminal
//!    [`FinishReason::Failed`](crate::serving::FinishReason::Failed)
//!    event, every other request keeps its slot and KV residency, and
//!    the epoch restages without the offender — the engine is never
//!    rebuilt.
//! 3. **Give up** only when the budget is spent and no request can be
//!    blamed (a persistent, unattributable kernel failure): the step
//!    returns the underlying error and the caller decides.
//!
//! [`EngineBuilder`]: crate::serving::EngineBuilder

use crate::serving::batcher::Request;
use crate::util::XorShift64;
use std::time::Duration;

/// Retry backoff is bounded: exponential growth from the configured
/// base is capped here, so a misconfigured backoff cannot stall the
/// serving thread for seconds per failure.
pub(crate) const MAX_BACKOFF: Duration = Duration::from_millis(100);

/// A deterministic, seed-driven fault schedule (chaos testing knob; see
/// the module docs). All-zero rates with no poison — the default —
/// injects nothing.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FaultPlan {
    /// RNG seed: two engines with the same plan draw the same fault
    /// sequence for the same epoch sequence.
    pub seed: u64,
    /// Probability (0..=1) that an epoch fails wholesale — models a
    /// watchdog timeout / scheduler wedge. Unattributable: no victim.
    pub kernel_rate: f64,
    /// Probability (0..=1) that a task body fails mid-epoch, attributed
    /// to a uniformly drawn victim among the active requests — models a
    /// poisoned row (bad input, NaN blowup) surfacing through
    /// `ExecCore::fail`.
    pub task_rate: f64,
    /// A request id whose presence fails *every* epoch it is staged in,
    /// attributed to it — the deterministic repeat offender that drives
    /// the quarantine path end to end.
    pub poison: Option<u64>,
}

impl Default for FaultPlan {
    fn default() -> Self {
        FaultPlan { seed: 0x5eed, kernel_rate: 0.0, task_rate: 0.0, poison: None }
    }
}

impl FaultPlan {
    /// Rates must be finite probabilities; rejected at engine build
    /// time as `InvalidConfig` before any resource is touched.
    pub fn validate(&self) -> Result<(), String> {
        for (name, rate) in [("kernel_rate", self.kernel_rate), ("task_rate", self.task_rate)] {
            if !rate.is_finite() || !(0.0..=1.0).contains(&rate) {
                return Err(format!("fault {name} must be in 0..=1, got {rate}"));
            }
        }
        Ok(())
    }

    /// True when this plan can ever inject anything.
    pub fn is_armed(&self) -> bool {
        self.kernel_rate > 0.0 || self.task_rate > 0.0 || self.poison.is_some()
    }
}

/// One injected failure for the epoch about to run (or just run).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub(crate) enum Fault {
    /// The whole epoch fails; nobody to blame.
    Epoch,
    /// A task body fails, attributed to `victim`'s row.
    Task { victim: u64 },
}

/// Draws faults from a [`FaultPlan`] — owned by the engine, one draw
/// per epoch attempt over the currently staged requests.
#[derive(Debug)]
pub(crate) struct FaultInjector {
    plan: FaultPlan,
    rng: XorShift64,
}

impl FaultInjector {
    pub(crate) fn new(plan: FaultPlan) -> Self {
        FaultInjector { rng: XorShift64::new(plan.seed), plan }
    }

    /// Decide whether the epoch staging `active` fails, and how. Poison
    /// wins (deterministic repeat offender), then the kernel-level
    /// draw, then the task-level draw with a uniform victim.
    pub(crate) fn draw(&mut self, active: &[Request]) -> Option<Fault> {
        if active.is_empty() {
            return None;
        }
        if let Some(p) = self.plan.poison {
            if active.iter().any(|r| r.id == p) {
                return Some(Fault::Task { victim: p });
            }
        }
        if self.plan.kernel_rate > 0.0 && self.rng.f64() < self.plan.kernel_rate {
            return Some(Fault::Epoch);
        }
        if self.plan.task_rate > 0.0 && self.rng.f64() < self.plan.task_rate {
            let victim = active[self.rng.below(active.len())].id;
            return Some(Fault::Task { victim });
        }
        None
    }
}

/// What the recovery state machine tells the step loop to do next.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub(crate) enum RecoveryAction {
    /// Re-arm the resident kernel and re-run the epoch after sleeping
    /// the given (bounded, exponentially grown) backoff.
    Retry(Duration),
    /// Retire this request with a terminal `Failed` event, then restage
    /// and continue with the survivors under a fresh retry budget.
    Quarantine(u64),
    /// Unattributable persistent failure: surface the error.
    GiveUp,
}

/// Per-engine recovery bookkeeping: a retry budget per step and blame
/// counts accumulated across this step's failed attempts. Kept in a
/// `Vec` (not a map) so the most-blamed pick is deterministic.
#[derive(Debug)]
pub(crate) struct Recovery {
    retry_limit: usize,
    backoff: Duration,
    attempts: usize,
    blamed: Vec<(u64, u32)>,
}

impl Recovery {
    pub(crate) fn new(retry_limit: usize, backoff: Duration) -> Self {
        Recovery { retry_limit, backoff, attempts: 0, blamed: Vec::new() }
    }

    /// A whole epoch (with whatever was staged) completed: consecutive-
    /// failure tracking resets.
    pub(crate) fn on_success(&mut self) {
        self.attempts = 0;
        self.blamed.clear();
    }

    /// A failed epoch attempt, with an optional blamed request.
    /// `still_active` filters quarantine candidates to requests that
    /// can actually be retired (a blamed request may have finished or
    /// been cancelled between attempts).
    pub(crate) fn on_failure(
        &mut self,
        victim: Option<u64>,
        still_active: impl Fn(u64) -> bool,
    ) -> RecoveryAction {
        if let Some(v) = victim {
            match self.blamed.iter_mut().find(|(id, _)| *id == v) {
                Some(entry) => entry.1 += 1,
                None => self.blamed.push((v, 1)),
            }
        }
        self.attempts += 1;
        if self.attempts <= self.retry_limit {
            let shift = (self.attempts - 1).min(6) as u32;
            return RecoveryAction::Retry(self.backoff.saturating_mul(1 << shift).min(MAX_BACKOFF));
        }
        let worst = self
            .blamed
            .iter()
            .filter(|(id, _)| still_active(*id))
            .max_by_key(|(_, n)| *n)
            .map(|(id, _)| *id);
        match worst {
            Some(id) => {
                // fresh budget for the survivors; the offender's blame
                // record goes with it.
                self.blamed.retain(|(b, _)| *b != id);
                self.attempts = 0;
                RecoveryAction::Quarantine(id)
            }
            None => {
                self.attempts = 0;
                self.blamed.clear();
                RecoveryAction::GiveUp
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn reqs(ids: &[u64]) -> Vec<Request> {
        ids.iter().map(|&id| Request::new(id, vec![1], 4)).collect()
    }

    #[test]
    fn plan_validation_rejects_bad_rates() {
        assert!(FaultPlan::default().validate().is_ok());
        assert!(!FaultPlan::default().is_armed());
        let bad = FaultPlan { kernel_rate: 1.5, ..Default::default() };
        assert!(bad.validate().unwrap_err().contains("kernel_rate"));
        let bad = FaultPlan { task_rate: f64::NAN, ..Default::default() };
        assert!(bad.validate().unwrap_err().contains("task_rate"));
        assert!(FaultPlan { kernel_rate: 1.0, ..Default::default() }.is_armed());
        assert!(FaultPlan { poison: Some(3), ..Default::default() }.is_armed());
    }

    #[test]
    fn injector_is_deterministic_per_seed() {
        let active = reqs(&[1, 2, 3]);
        let plan = FaultPlan { seed: 7, kernel_rate: 0.3, task_rate: 0.3, ..Default::default() };
        let draw_seq = |plan: FaultPlan| {
            let mut inj = FaultInjector::new(plan);
            (0..64).map(|_| inj.draw(&active)).collect::<Vec<_>>()
        };
        let a = draw_seq(plan);
        assert_eq!(a, draw_seq(plan), "same seed must draw the same faults");
        assert!(a.iter().any(|f| f.is_some()), "30% rates over 64 epochs never fired");
        assert!(a.iter().any(|f| f.is_none()), "30% rates over 64 epochs always fired");
        assert_ne!(
            a,
            draw_seq(FaultPlan { seed: 8, ..plan }),
            "different seeds should diverge"
        );
        // task faults always blame a staged request.
        for f in a.iter().flatten() {
            if let Fault::Task { victim } = f {
                assert!([1, 2, 3].contains(victim));
            }
        }
    }

    #[test]
    fn poison_fails_every_epoch_it_is_staged_in() {
        let plan = FaultPlan { poison: Some(2), ..Default::default() };
        let mut inj = FaultInjector::new(plan);
        for _ in 0..8 {
            assert_eq!(inj.draw(&reqs(&[1, 2])), Some(Fault::Task { victim: 2 }));
        }
        assert_eq!(inj.draw(&reqs(&[1, 3])), None, "poison gone → epoch clean");
        assert_eq!(inj.draw(&[]), None, "idle epochs never fault");
    }

    #[test]
    fn recovery_retries_then_quarantines_the_repeat_offender() {
        let mut rec = Recovery::new(2, Duration::from_millis(1));
        let active = |_: u64| true;
        // two failed attempts blaming request 5 → retry with growing,
        // bounded backoff.
        assert_eq!(rec.on_failure(Some(5), active), RecoveryAction::Retry(Duration::from_millis(1)));
        assert_eq!(rec.on_failure(Some(5), active), RecoveryAction::Retry(Duration::from_millis(2)));
        // budget spent → the blamed request is quarantined and the
        // budget resets for the survivors.
        assert_eq!(rec.on_failure(Some(5), active), RecoveryAction::Quarantine(5));
        assert_eq!(rec.on_failure(None, active), RecoveryAction::Retry(Duration::from_millis(1)));
        rec.on_success();
        // most-blamed wins when several requests were blamed.
        let mut rec = Recovery::new(2, Duration::ZERO);
        assert_eq!(rec.on_failure(Some(1), active), RecoveryAction::Retry(Duration::ZERO));
        assert_eq!(rec.on_failure(Some(2), active), RecoveryAction::Retry(Duration::ZERO));
        assert_eq!(rec.on_failure(Some(2), active), RecoveryAction::Quarantine(2));
    }

    #[test]
    fn recovery_gives_up_only_when_unattributable() {
        let mut rec = Recovery::new(1, Duration::ZERO);
        assert_eq!(rec.on_failure(None, |_| true), RecoveryAction::Retry(Duration::ZERO));
        assert_eq!(rec.on_failure(None, |_| true), RecoveryAction::GiveUp);
        // after GiveUp the budget resets — the next step retries afresh.
        assert_eq!(rec.on_failure(None, |_| true), RecoveryAction::Retry(Duration::ZERO));
        // a blamed request that already retired cannot be quarantined.
        let mut rec = Recovery::new(0, Duration::ZERO);
        assert_eq!(rec.on_failure(Some(9), |_| false), RecoveryAction::GiveUp);
    }

    #[test]
    fn backoff_is_bounded() {
        let mut rec = Recovery::new(64, Duration::from_millis(50));
        let mut last = Duration::ZERO;
        for _ in 0..64 {
            match rec.on_failure(None, |_| true) {
                RecoveryAction::Retry(d) => {
                    assert!(d <= MAX_BACKOFF, "backoff {d:?} above cap");
                    last = d;
                }
                other => panic!("expected retry, got {other:?}"),
            }
        }
        assert_eq!(last, MAX_BACKOFF);
    }
}
