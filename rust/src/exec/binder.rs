//! Task → artifact binding: the executor that megakernel workers call
//! on the real-numerics path.
//!
//! Each compute task's tile is mapped to one AOT artifact (index
//! pre-resolved per op at executor construction — the hot path does no
//! name formatting or manifest scanning) plus input slices *borrowed
//! straight from the tensor arena* — whole-tensor inputs and contiguous
//! per-row attention slices cross into the PJRT pool as
//! [`Value::Borrowed`] with zero copies and zero allocations; only
//! strided matmul weight tiles are gathered, into a per-worker scratch
//! buffer that is reused across tasks (no allocation at steady state).
//!
//! Results take the mirror path out: every task body passes its output
//! tile to the pool as a mutable arena destination
//! (`TensorStore::tile_mut` → `OutView` → [`ExecPool::execute_into`]),
//! so matmul, attention, and the elementwise ops land their results
//! directly in the destination tensor — the pool allocates no output
//! buffer and the task copies nothing afterwards (`output_allocs`
//! stays 0; per-op full-output regions are pre-resolved at executor
//! construction like the artifact indices, so whole-tensor writes
//! build no `Region` per task either). `KvAppend` is executed natively
//! as a direct arena-to-arena row copy through
//! `TensorStore::view_region_mut` (pure cache bookkeeping, zero flops
//! — the §6.1 in-kernel KV metadata update).
//!
//! Two executor front-ends share the binding logic via [`ExecCore`]:
//!
//! * [`TileExecutor`] borrows graph/store/pool — the one-shot
//!   validation and example paths.
//! * [`OwningTileExecutor`] owns `Arc`s of all three — the serving
//!   engine hoists one into each long-lived `Session` so the decode hot
//!   path constructs nothing per iteration.

use crate::exec::store::TensorStore;
use crate::megakernel::runtime::TaskExecutor;
use crate::ops::{CompGraph, OpKind, Region};
use crate::runtime::pool::{ExecPool, Value};
use crate::tgraph::{CompiledGraph, TaskDesc, TaskKind};
use std::cell::RefCell;
use std::sync::{Arc, Mutex};

crate::util::boundary_error! {
    /// Typed failure harvested from task bodies after an epoch — the
    /// `exec` boundary error of [`ExecCore::take_error`]. The threaded
    /// runtime has no error channel, so the first failing task body
    /// records its diagnostic here and callers collect it once the
    /// epoch drains. Legacy `String` contexts convert through the
    /// `From<TaskError> for String` shim; the serving layer converts it
    /// into its own typed error.
    TaskError
}

/// Per-worker reusable staging buffers. Keyed by OS thread — megakernel
/// workers are long-lived, so after warm-up every gather reuses
/// capacity and the task hot path performs no heap allocation.
#[derive(Default)]
struct Scratch {
    /// Strided-tile gather target (matmul weight columns).
    tile: Vec<f32>,
    /// i32 staging (embedding ids, attention valid-length).
    ints: Vec<i32>,
}

thread_local! {
    static SCRATCH: RefCell<Scratch> = RefCell::new(Scratch::default());
}

/// Resolve each op's AOT artifact index once, at executor construction:
/// the per-task hot path then submits to the pool by index — no name
/// formatting, no manifest scan, no allocation. Ops executed natively
/// (`KvAppend`) or unsupported on the real path resolve to `None`.
fn resolve_artifacts(graph: &CompGraph, pool: &ExecPool, batch: usize) -> Vec<Option<usize>> {
    let manifest = pool.manifest();
    let tile_n = manifest.tile_n;
    graph
        .ops
        .iter()
        .map(|op| {
            let name = match &op.kind {
                OpKind::Embedding => format!("embed_b{batch}"),
                OpKind::RmsNorm => format!("rmsnorm_b{batch}"),
                OpKind::MatMul => {
                    let k = graph.tensor(op.inputs[0]).shape[1];
                    format!("matmul_b{batch}_k{k}_n{tile_n}")
                }
                OpKind::Attention { .. } => "attn_q1".to_string(),
                OpKind::Add => format!("add_b{batch}"),
                OpKind::SwiGLU => format!("swiglu_b{batch}"),
                _ => return None,
            };
            manifest.find(&name).map(|(i, _)| i)
        })
        .collect()
}

/// Executor state + binding logic shared by both front-ends.
pub struct ExecCore {
    batch: usize,
    /// Per-op artifact index, resolved once (see [`resolve_artifacts`]).
    artifacts: Vec<Option<usize>>,
    /// Per-op full region of the output tensor, resolved once at
    /// construction like the artifact indices: whole-tensor result
    /// writes (embedding, rmsnorm, add, swiglu) borrow their pool
    /// destination through this instead of building a fresh `Region`
    /// per task.
    out_full: Vec<Region>,
    /// Valid cache length *before* this iteration's token, per batch
    /// row (continuous batching admits requests at different times, so
    /// rows carry different cache lengths). The new K/V row is written
    /// at this position.
    row_lens: Mutex<Vec<usize>>,
    /// First execution error, if any (the runtime has no error channel;
    /// callers check this after the epoch).
    error: Mutex<Option<String>>,
}

impl ExecCore {
    fn new(graph: &CompGraph, pool: &ExecPool, batch: usize) -> Self {
        ExecCore {
            batch,
            artifacts: resolve_artifacts(graph, pool, batch),
            out_full: graph
                .ops
                .iter()
                .map(|op| graph.tensor(op.output).full_region())
                .collect(),
            row_lens: Mutex::new(vec![0; batch]),
            error: Mutex::new(None),
        }
    }

    /// The op's pre-resolved artifact index, or a diagnostic error.
    fn artifact(&self, graph: &CompGraph, op_id: usize) -> Result<usize, String> {
        self.artifacts[op_id].ok_or_else(|| {
            format!(
                "no AOT artifact for op {} (missing batch/tile specialization?)",
                graph.ops[op_id].name
            )
        })
    }

    pub fn batch(&self) -> usize {
        self.batch
    }

    /// Uniform cache length for all rows (the validation path).
    pub fn set_cur_len(&self, l: usize) {
        let mut g = self.row_lens.lock().unwrap();
        g.iter_mut().for_each(|x| *x = l);
    }

    /// Per-row cache lengths (continuous batching).
    pub fn set_row_lens(&self, lens: &[usize]) {
        let mut g = self.row_lens.lock().unwrap();
        assert_eq!(lens.len(), self.batch);
        g.copy_from_slice(lens);
    }

    fn row_len(&self, r: usize) -> usize {
        self.row_lens.lock().unwrap()[r]
    }

    /// First task error of the epoch, if any (cleared on read).
    pub fn take_error(&self) -> Option<TaskError> {
        self.error.lock().unwrap().take().map(TaskError)
    }

    fn fail(&self, e: String) {
        let mut g = self.error.lock().unwrap();
        if g.is_none() {
            *g = Some(e);
        }
    }

    fn execute_task(&self, graph: &CompGraph, store: &TensorStore, pool: &ExecPool, task: &TaskDesc) {
        if let TaskKind::Compute { op, kind } = &task.kind {
            if let Err(e) = self.run_compute(graph, store, pool, *op, kind, &task.out_region) {
                self.fail(format!("task {} ({}): {e}", task.id, graph.ops[*op].name));
            }
        }
    }

    fn run_compute(
        &self,
        graph: &CompGraph,
        store: &TensorStore,
        pool: &ExecPool,
        op_id: usize,
        kind: &OpKind,
        out_region: &Region,
    ) -> Result<(), String> {
        let op = &graph.ops[op_id];
        let m = pool.manifest().model;
        match kind {
            OpKind::Embedding => {
                // ids arrive as exact small floats; stage as i32 in the
                // per-worker scratch, table is a borrowed arena view.
                let art = self.artifact(graph, op_id)?;
                let mut out = store.tile_mut(op.output, &self.out_full[op_id]);
                let dst = out.out_view().expect("whole-tensor output is contiguous");
                SCRATCH.with(|s| {
                    let mut s = s.borrow_mut();
                    s.ints.clear();
                    s.ints.extend(store.view(op.inputs[0]).iter().map(|&v| v as i32));
                    pool.execute_into(
                        art,
                        vec![Value::BorrowedI32(&s.ints), Value::Borrowed(store.view(op.inputs[1]))],
                        &mut [dst],
                    )
                })?;
            }
            OpKind::RmsNorm => {
                let art = self.artifact(graph, op_id)?;
                let mut out = store.tile_mut(op.output, &self.out_full[op_id]);
                let dst = out.out_view().expect("whole-tensor output is contiguous");
                pool.execute_into(
                    art,
                    vec![
                        Value::Borrowed(store.view(op.inputs[0])),
                        Value::Borrowed(store.view(op.inputs[1])),
                    ],
                    &mut [dst],
                )?;
            }
            OpKind::MatMul => {
                let k = graph.tensor(op.inputs[0]).shape[1];
                let (c0, c1) = out_region.dims[1];
                let tile_n = pool.manifest().tile_n;
                if c1 - c0 != tile_n {
                    return Err(format!(
                        "matmul tile width {} != artifact tile {}",
                        c1 - c0,
                        tile_n
                    ));
                }
                let art = self.artifact(graph, op_id)?;
                let w_region = Region::new(vec![(0, k), (c0, c1)]);
                let x = store.view(op.inputs[0]);
                let wv = store.tile(op.inputs[1], &w_region);
                // rank-2 output tiles are always regularly strided (one
                // run per output row), so the artifact's result lands
                // straight in the arena at the output row stride.
                let mut out = store.tile_mut(op.output, out_region);
                let dst = out.out_view().expect("rank-2 matmul tile is regularly strided");
                match wv.as_slice() {
                    // full-width weight tile: zero-copy borrowed slice.
                    Some(w) => pool.execute_into(
                        art,
                        vec![Value::Borrowed(x), Value::Borrowed(w)],
                        &mut [dst],
                    )?,
                    // strided columns: gather into the reused scratch.
                    None => SCRATCH.with(|s| {
                        let mut s = s.borrow_mut();
                        wv.gather_into(&mut s.tile);
                        pool.execute_into(
                            art,
                            vec![Value::Borrowed(x), Value::Borrowed(&s.tile)],
                            &mut [dst],
                        )
                    })?,
                }
            }
            OpKind::Attention { .. } => {
                // one task per request row; q and the per-row cache
                // slabs are contiguous in the arena → all borrowed, and
                // the per-row output is a contiguous arena destination.
                let (r0, r1) = out_region.dims[0];
                debug_assert_eq!(r1 - r0, 1, "attention tasks are per-request");
                let r = r0;
                let q_dim = m.q_dim();
                let kv_dim = m.kv_dim();
                let s_max = pool.manifest().s_max;
                // inputs: [qkv, kcache, vcache, kv_new]
                let q_r = Region::new(vec![(r, r + 1), (0, q_dim)]);
                let c_r = Region::new(vec![(r, r + 1), (0, s_max), (0, kv_dim)]);
                let q = store.view_region(op.inputs[0], &q_r);
                let kc = store.view_region(op.inputs[1], &c_r);
                let vc = store.view_region(op.inputs[2], &c_r);
                let valid = self.row_len(r) + 1;
                let art = self.artifact(graph, op_id)?;
                let mut out = store.tile_mut(op.output, &q_r);
                let dst = out.out_view().expect("per-row attention output is contiguous");
                SCRATCH.with(|s| {
                    let mut s = s.borrow_mut();
                    s.ints.clear();
                    s.ints.push(valid as i32);
                    pool.execute_into(
                        art,
                        vec![
                            Value::Borrowed(q),
                            Value::Borrowed(kc),
                            Value::Borrowed(vc),
                            Value::BorrowedI32(&s.ints),
                        ],
                        &mut [dst],
                    )
                })?;
            }
            OpKind::KvAppend => {
                // native: copy this step's K/V rows from the fused qkv
                // output into the caches at position cur_len — a direct
                // arena-to-arena copy through mutable row views whose
                // debug write registration spans each copy, no staging
                // buffer.
                let q_dim = m.q_dim();
                let kv_dim = m.kv_dim();
                let qkv = op.inputs[0];
                for r in 0..self.batch {
                    let pos = self.row_len(r);
                    let row_r = Region::new(vec![(r, r + 1), (pos, pos + 1), (0, kv_dim)]);
                    let krow = store
                        .view_region(qkv, &Region::new(vec![(r, r + 1), (q_dim, q_dim + kv_dim)]));
                    let mut kdst = store.tile_mut(op.inputs[2], &row_r);
                    kdst.as_slice_mut().expect("cache row is contiguous").copy_from_slice(krow);
                    drop(kdst);
                    let vrow = store.view_region(
                        qkv,
                        &Region::new(vec![(r, r + 1), (q_dim + kv_dim, q_dim + 2 * kv_dim)]),
                    );
                    let mut vdst = store.tile_mut(op.inputs[3], &row_r);
                    vdst.as_slice_mut().expect("cache row is contiguous").copy_from_slice(vrow);
                }
            }
            OpKind::Add => {
                let art = self.artifact(graph, op_id)?;
                let mut out = store.tile_mut(op.output, &self.out_full[op_id]);
                let dst = out.out_view().expect("whole-tensor output is contiguous");
                pool.execute_into(
                    art,
                    vec![
                        Value::Borrowed(store.view(op.inputs[0])),
                        Value::Borrowed(store.view(op.inputs[1])),
                    ],
                    &mut [dst],
                )?;
            }
            OpKind::SwiGLU => {
                let art = self.artifact(graph, op_id)?;
                let mut out = store.tile_mut(op.output, &self.out_full[op_id]);
                let dst = out.out_view().expect("whole-tensor output is contiguous");
                pool.execute_into(
                    art,
                    vec![Value::Borrowed(store.view(op.inputs[0]))],
                    &mut [dst],
                )?;
            }
            other => {
                return Err(format!("real path does not support op kind {other:?}"));
            }
        }
        Ok(())
    }
}

/// Executes tile tasks against the PJRT pool over borrowed
/// graph/store/pool (one-shot validation and example paths).
pub struct TileExecutor<'a> {
    pub graph: &'a CompGraph,
    pub store: &'a TensorStore,
    pub pool: &'a ExecPool,
    core: ExecCore,
}

impl<'a> TileExecutor<'a> {
    pub fn new(graph: &'a CompGraph, store: &'a TensorStore, pool: &'a ExecPool, batch: usize) -> Self {
        TileExecutor { graph, store, pool, core: ExecCore::new(graph, pool, batch) }
    }
}

/// Both front-ends deref to [`ExecCore`] for the shared control surface
/// (`batch` / `set_cur_len` / `set_row_lens` / `take_error`) instead of
/// duplicating delegation methods.
impl std::ops::Deref for TileExecutor<'_> {
    type Target = ExecCore;

    fn deref(&self) -> &ExecCore {
        &self.core
    }
}

impl TaskExecutor for TileExecutor<'_> {
    fn execute(&self, task: &TaskDesc) {
        self.core.execute_task(self.graph, self.store, self.pool, task);
    }
}

/// Owning executor for long-lived sessions: holds `Arc`s of the
/// compiled graph, the tensor arena, and the pool, so the serving
/// engine constructs nothing on the per-iteration hot path — it just
/// updates row lengths and re-arms the resident kernel with `&self`.
pub struct OwningTileExecutor {
    graph: Arc<CompiledGraph>,
    store: Arc<TensorStore>,
    pool: Arc<ExecPool>,
    core: ExecCore,
}

impl OwningTileExecutor {
    pub fn new(
        graph: Arc<CompiledGraph>,
        store: Arc<TensorStore>,
        pool: Arc<ExecPool>,
        batch: usize,
    ) -> Self {
        let core = ExecCore::new(&graph.graph, &pool, batch);
        OwningTileExecutor { graph, store, pool, core }
    }

    pub fn store(&self) -> &TensorStore {
        &self.store
    }

    pub fn graph(&self) -> &CompiledGraph {
        &self.graph
    }
}

impl std::ops::Deref for OwningTileExecutor {
    type Target = ExecCore;

    fn deref(&self) -> &ExecCore {
        &self.core
    }
}

impl TaskExecutor for OwningTileExecutor {
    fn execute(&self, task: &TaskDesc) {
        self.core.execute_task(&self.graph.graph, &self.store, &self.pool, task);
    }
}
