//! Task → artifact binding: the executor that megakernel workers call
//! on the real-numerics path.
//!
//! Each compute task's tile is mapped to one AOT artifact (index
//! pre-resolved per op at executor construction — the hot path does no
//! name formatting or manifest scanning) plus input slices *borrowed
//! straight from the tensor arena* — whole-tensor inputs and contiguous
//! per-row attention slices cross into the PJRT pool as
//! [`Value::Borrowed`] with zero copies and zero allocations; only
//! strided matmul weight tiles are gathered, into a per-worker scratch
//! buffer that is reused across tasks (no allocation at steady state).
//!
//! Results take the mirror path out: every task body passes its output
//! tile to the pool as a mutable arena destination
//! (`TensorStore::tile_mut` → `OutView` → [`ExecPool::execute_into`]),
//! so matmul, attention, and the elementwise ops land their results
//! directly in the destination tensor — the pool allocates no output
//! buffer and the task copies nothing afterwards (`output_allocs`
//! stays 0; per-op full-output regions are pre-resolved at executor
//! construction like the artifact indices, so whole-tensor writes
//! build no `Region` per task either). `KvAppend` is executed natively
//! as a direct arena-to-arena row copy through
//! `TensorStore::view_region_mut` (pure cache bookkeeping, zero flops
//! — the §6.1 in-kernel KV metadata update).
//!
//! With **paged KV** on ([`ExecCore::set_paged_geometry`] +
//! per-epoch [`ExecCore::set_block_tables`]), attention and `KvAppend`
//! stop assuming slot-contiguous cache rows: each position resolves
//! through the staged block table to a borrowed span of the shared KV
//! slab (`SharedSlab::view_span` — pointer arithmetic, no gather, no
//! per-step allocation), attention runs the CPU backend's
//! position-closure online-softmax kernel natively (the fixed-arity
//! `attn_q1` artifact cannot take a scattered cache), and appends
//! write the slab offset the table names. The zero-copy counters never
//! see any of it.
//!
//! Two executor front-ends share the binding logic via [`ExecCore`]:
//!
//! * [`TileExecutor`] borrows graph/store/pool — the one-shot
//!   validation and example paths.
//! * [`OwningTileExecutor`] owns `Arc`s of all three — the serving
//!   engine hoists one into each long-lived `Session` so the decode hot
//!   path constructs nothing per iteration.

use crate::exec::store::{SharedSlab, TensorStore};
use crate::megakernel::runtime::TaskExecutor;
use crate::ops::{CompGraph, OpKind, Region, TensorId};
use crate::runtime::backend::cpu::{attention_row_paged, AttnShape};
use crate::runtime::pool::{ExecPool, Value};
use crate::tgraph::{CompiledGraph, TaskDesc, TaskKind};
use std::cell::RefCell;
use std::sync::{Arc, Mutex};

crate::util::boundary_error! {
    /// Typed failure harvested from task bodies after an epoch — the
    /// `exec` boundary error of [`ExecCore::take_error`]. The threaded
    /// runtime has no error channel, so the first failing task body
    /// records its diagnostic here and callers collect it once the
    /// epoch drains. Legacy `String` contexts convert through the
    /// `From<TaskError> for String` shim; the serving layer converts it
    /// into its own typed error.
    TaskError
}

/// Per-worker reusable staging buffers. Keyed by OS thread — megakernel
/// workers are long-lived, so after warm-up every gather reuses
/// capacity and the task hot path performs no heap allocation.
#[derive(Default)]
struct Scratch {
    /// Strided-tile gather target (matmul weight columns).
    tile: Vec<f32>,
    /// i32 staging (embedding ids, attention valid-length).
    ints: Vec<i32>,
    /// Paged-attention per-head accumulator (the online-softmax value
    /// accumulator the contiguous artifact keeps inside the backend
    /// session).
    acc: Vec<f32>,
}

thread_local! {
    static SCRATCH: RefCell<Scratch> = RefCell::new(Scratch::default());
}

/// Resolve each op's AOT artifact index once, at executor construction:
/// the per-task hot path then submits to the pool by index — no name
/// formatting, no manifest scan, no allocation. Ops executed natively
/// (`KvAppend`) or unsupported on the real path resolve to `None`.
fn resolve_artifacts(graph: &CompGraph, pool: &ExecPool, batch: usize) -> Vec<Option<usize>> {
    let manifest = pool.manifest();
    let tile_n = manifest.tile_n;
    graph
        .ops
        .iter()
        .map(|op| {
            let name = match &op.kind {
                OpKind::Embedding => format!("embed_b{batch}"),
                OpKind::RmsNorm => format!("rmsnorm_b{batch}"),
                OpKind::MatMul => {
                    let k = graph.tensor(op.inputs[0]).shape[1];
                    format!("matmul_b{batch}_k{k}_n{tile_n}")
                }
                OpKind::Attention { .. } => "attn_q1".to_string(),
                OpKind::Add => format!("add_b{batch}"),
                OpKind::SwiGLU => format!("swiglu_b{batch}"),
                _ => return None,
            };
            manifest.find(&name).map(|(i, _)| i)
        })
        .collect()
}

/// Session-constant paged-KV geometry: how a cache tensor id resolves
/// to a base element offset in the shared KV slab. Set once per session
/// by the serving engine when paging is on; the per-epoch variable part
/// (the block tables) is staged separately via
/// [`ExecCore::set_block_tables`].
///
/// Physical blocks are addressed through the **slab**, not the
/// session's cache tensor regions: a batch-`b` session's `l{l}.kcache`
/// tensor covers only the first `b` slots of the layer segment, but a
/// block table may legitimately map any block in the whole max-batch
/// segment — slab-offset arithmetic is the only addressing that is
/// valid for every specialization.
pub struct PagedKvMap {
    pub slab: SharedSlab,
    pub block_tokens: usize,
    pub kv_dim: usize,
    /// `(cache tensor id, slab element base offset)` for every layer's
    /// kcache and vcache tensor — 2·layers entries, scanned linearly
    /// (tensor ids are tiny integers; no hashing on the hot path).
    pub bases: Vec<(TensorId, usize)>,
}

impl PagedKvMap {
    fn base_for(&self, t: TensorId) -> Result<usize, String> {
        self.bases
            .iter()
            .find(|&&(id, _)| id == t)
            .map(|&(_, b)| b)
            .ok_or_else(|| format!("tensor {t} is not a mapped paged cache tensor"))
    }
}

/// Per-epoch block tables, staged while the kernel is quiesced and
/// read by attention/KvAppend task bodies. Buffers are reused across
/// epochs (clear + extend), so staging allocates nothing at steady
/// state.
#[derive(Default)]
struct PagedTables {
    /// Per batch row: `(start, len)` into `flat`. `len == 0` marks a
    /// vacant row (attention writes zeros, KvAppend skips it).
    spans: Vec<(usize, usize)>,
    flat: Vec<usize>,
}

/// Executor state + binding logic shared by both front-ends.
pub struct ExecCore {
    batch: usize,
    /// Per-op artifact index, resolved once (see [`resolve_artifacts`]).
    artifacts: Vec<Option<usize>>,
    /// Per-op full region of the output tensor, resolved once at
    /// construction like the artifact indices: whole-tensor result
    /// writes (embedding, rmsnorm, add, swiglu) borrow their pool
    /// destination through this instead of building a fresh `Region`
    /// per task.
    out_full: Vec<Region>,
    /// Valid cache length *before* this iteration's token, per batch
    /// row (continuous batching admits requests at different times, so
    /// rows carry different cache lengths). The new K/V row is written
    /// at this position.
    row_lens: Mutex<Vec<usize>>,
    /// First execution error, if any (the runtime has no error channel;
    /// callers check this after the epoch).
    error: Mutex<Option<String>>,
    /// Paged-KV geometry (None = legacy slot-contiguous path). Set
    /// once per session, before the first epoch.
    paged: Mutex<Option<PagedKvMap>>,
    /// Per-epoch staged block tables (meaningful only with `paged`).
    tables: Mutex<PagedTables>,
}

impl ExecCore {
    fn new(graph: &CompGraph, pool: &ExecPool, batch: usize) -> Self {
        ExecCore {
            batch,
            artifacts: resolve_artifacts(graph, pool, batch),
            out_full: graph
                .ops
                .iter()
                .map(|op| graph.tensor(op.output).full_region())
                .collect(),
            row_lens: Mutex::new(vec![0; batch]),
            error: Mutex::new(None),
            paged: Mutex::new(None),
            tables: Mutex::new(PagedTables::default()),
        }
    }

    /// The op's pre-resolved artifact index, or a diagnostic error.
    fn artifact(&self, graph: &CompGraph, op_id: usize) -> Result<usize, String> {
        self.artifacts[op_id].ok_or_else(|| {
            format!(
                "no AOT artifact for op {} (missing batch/tile specialization?)",
                graph.ops[op_id].name
            )
        })
    }

    pub fn batch(&self) -> usize {
        self.batch
    }

    /// Uniform cache length for all rows (the validation path).
    pub fn set_cur_len(&self, l: usize) {
        let mut g = self.row_lens.lock().unwrap();
        g.iter_mut().for_each(|x| *x = l);
    }

    /// Per-row cache lengths (continuous batching).
    pub fn set_row_lens(&self, lens: &[usize]) {
        let mut g = self.row_lens.lock().unwrap();
        assert_eq!(lens.len(), self.batch);
        g.copy_from_slice(lens);
    }

    fn row_len(&self, r: usize) -> usize {
        self.row_lens.lock().unwrap()[r]
    }

    /// Enable the paged-KV path for this session (set once, before the
    /// first epoch). Attention and KvAppend then resolve cache rows
    /// through the staged block tables instead of slot-contiguous
    /// regions.
    pub fn set_paged_geometry(&self, map: PagedKvMap) {
        *self.paged.lock().unwrap() = Some(map);
    }

    /// Whether this session runs the paged-KV path.
    pub fn paged_enabled(&self) -> bool {
        self.paged.lock().unwrap().is_some()
    }

    /// Stage this epoch's block tables: `spans[r]` is the `(start,
    /// len)` slice of `flat` holding batch row `r`'s table (`len == 0`
    /// marks a vacant row). Runs while the kernel is quiesced; buffers
    /// are reused, so a steady-state epoch stages with zero
    /// allocations.
    pub fn set_block_tables(&self, spans: &[(usize, usize)], flat: &[usize]) {
        debug_assert_eq!(spans.len(), self.batch, "one span per batch row");
        let mut g = self.tables.lock().unwrap();
        g.spans.clear();
        g.spans.extend_from_slice(spans);
        g.flat.clear();
        g.flat.extend_from_slice(flat);
    }

    /// First task error of the epoch, if any (cleared on read).
    pub fn take_error(&self) -> Option<TaskError> {
        self.error.lock().unwrap().take().map(TaskError)
    }

    fn fail(&self, e: String) {
        let mut g = self.error.lock().unwrap();
        if g.is_none() {
            *g = Some(e);
        }
    }

    fn execute_task(&self, graph: &CompGraph, store: &TensorStore, pool: &ExecPool, task: &TaskDesc) {
        if let TaskKind::Compute { op, kind } = &task.kind {
            if let Err(e) = self.run_compute(graph, store, pool, *op, kind, &task.out_region) {
                self.fail(format!("task {} ({}): {e}", task.id, graph.ops[*op].name));
            }
        }
    }

    fn run_compute(
        &self,
        graph: &CompGraph,
        store: &TensorStore,
        pool: &ExecPool,
        op_id: usize,
        kind: &OpKind,
        out_region: &Region,
    ) -> Result<(), String> {
        let op = &graph.ops[op_id];
        let m = pool.manifest().model;
        match kind {
            OpKind::Embedding => {
                // ids arrive as exact small floats; stage as i32 in the
                // per-worker scratch, table is a borrowed arena view.
                let art = self.artifact(graph, op_id)?;
                let mut out = store.tile_mut(op.output, &self.out_full[op_id]);
                let dst = out.out_view().expect("whole-tensor output is contiguous");
                SCRATCH.with(|s| {
                    let mut s = s.borrow_mut();
                    s.ints.clear();
                    s.ints.extend(store.view(op.inputs[0]).iter().map(|&v| v as i32));
                    pool.execute_into(
                        art,
                        vec![Value::BorrowedI32(&s.ints), Value::Borrowed(store.view(op.inputs[1]))],
                        &mut [dst],
                    )
                })?;
            }
            OpKind::RmsNorm => {
                let art = self.artifact(graph, op_id)?;
                let mut out = store.tile_mut(op.output, &self.out_full[op_id]);
                let dst = out.out_view().expect("whole-tensor output is contiguous");
                pool.execute_into(
                    art,
                    vec![
                        Value::Borrowed(store.view(op.inputs[0])),
                        Value::Borrowed(store.view(op.inputs[1])),
                    ],
                    &mut [dst],
                )?;
            }
            OpKind::MatMul => {
                let k = graph.tensor(op.inputs[0]).shape[1];
                let (c0, c1) = out_region.dims[1];
                let tile_n = pool.manifest().tile_n;
                if c1 - c0 != tile_n {
                    return Err(format!(
                        "matmul tile width {} != artifact tile {}",
                        c1 - c0,
                        tile_n
                    ));
                }
                let art = self.artifact(graph, op_id)?;
                let w_region = Region::new(vec![(0, k), (c0, c1)]);
                let x = store.view(op.inputs[0]);
                let wv = store.tile(op.inputs[1], &w_region);
                // rank-2 output tiles are always regularly strided (one
                // run per output row), so the artifact's result lands
                // straight in the arena at the output row stride.
                let mut out = store.tile_mut(op.output, out_region);
                let dst = out.out_view().expect("rank-2 matmul tile is regularly strided");
                match wv.as_slice() {
                    // full-width weight tile: zero-copy borrowed slice.
                    Some(w) => pool.execute_into(
                        art,
                        vec![Value::Borrowed(x), Value::Borrowed(w)],
                        &mut [dst],
                    )?,
                    // strided columns: gather into the reused scratch.
                    None => SCRATCH.with(|s| {
                        let mut s = s.borrow_mut();
                        wv.gather_into(&mut s.tile);
                        pool.execute_into(
                            art,
                            vec![Value::Borrowed(x), Value::Borrowed(&s.tile)],
                            &mut [dst],
                        )
                    })?,
                }
            }
            OpKind::Attention { .. } => {
                // one task per request row; q and the per-row cache
                // slabs are contiguous in the arena → all borrowed, and
                // the per-row output is a contiguous arena destination.
                let (r0, r1) = out_region.dims[0];
                debug_assert_eq!(r1 - r0, 1, "attention tasks are per-request");
                let r = r0;
                let q_dim = m.q_dim();
                let kv_dim = m.kv_dim();
                let q_r = Region::new(vec![(r, r + 1), (0, q_dim)]);
                let paged = self.paged.lock().unwrap();
                if let Some(map) = paged.as_ref() {
                    // paged path: the fixed-arity attention artifact
                    // wants one contiguous [s_max, kv_dim] cache slice,
                    // which a block table cannot provide without a
                    // gather (a per-step copy the zero-copy contract
                    // forbids) — so run the same online-softmax kernel
                    // natively, resolving each position to a borrowed
                    // slab span through the staged table. Shared blocks
                    // are read-only here (COW already re-pointed any
                    // row this epoch appends), so these reads race with
                    // nothing.
                    let q = store.view_region(op.inputs[0], &q_r);
                    let kbase = map.base_for(op.inputs[1])?;
                    let vbase = map.base_for(op.inputs[2])?;
                    let tables = self.tables.lock().unwrap();
                    let (start, len) = tables.spans.get(r).copied().unwrap_or((0, 0));
                    let table = &tables.flat[start..start + len];
                    let bt = map.block_tokens;
                    debug_assert_eq!(kv_dim, map.kv_dim);
                    // vacant rows (no table) compute nothing and write
                    // zeros; live rows never see more positions than
                    // their table covers.
                    let valid =
                        if len == 0 { 0 } else { (self.row_len(r) + 1).min(len * bt) };
                    let shape =
                        AttnShape { heads: m.heads, kv_heads: m.kv_heads, head_dim: m.head_dim };
                    let mut out = store.tile_mut(op.output, &q_r);
                    let dst = out.as_slice_mut().expect("per-row attention output is contiguous");
                    SCRATCH.with(|s| {
                        let mut s = s.borrow_mut();
                        let row = |base: usize, pos: usize| {
                            map.slab
                                .view_span(base + (table[pos / bt] * bt + pos % bt) * kv_dim, kv_dim)
                        };
                        attention_row_paged(
                            &shape,
                            q,
                            |p| row(kbase, p),
                            |p| row(vbase, p),
                            valid,
                            &mut s.acc,
                            dst,
                        );
                    });
                } else {
                    drop(paged);
                    let s_max = pool.manifest().s_max;
                    // inputs: [qkv, kcache, vcache, kv_new]
                    let c_r = Region::new(vec![(r, r + 1), (0, s_max), (0, kv_dim)]);
                    let q = store.view_region(op.inputs[0], &q_r);
                    let kc = store.view_region(op.inputs[1], &c_r);
                    let vc = store.view_region(op.inputs[2], &c_r);
                    let valid = self.row_len(r) + 1;
                    let art = self.artifact(graph, op_id)?;
                    let mut out = store.tile_mut(op.output, &q_r);
                    let dst = out.out_view().expect("per-row attention output is contiguous");
                    SCRATCH.with(|s| {
                        let mut s = s.borrow_mut();
                        s.ints.clear();
                        s.ints.push(valid as i32);
                        pool.execute_into(
                            art,
                            vec![
                                Value::Borrowed(q),
                                Value::Borrowed(kc),
                                Value::Borrowed(vc),
                                Value::BorrowedI32(&s.ints),
                            ],
                            &mut [dst],
                        )
                    })?;
                }
            }
            OpKind::KvAppend => {
                // native: copy this step's K/V rows from the fused qkv
                // output into the caches at position cur_len — a direct
                // arena-to-arena copy, no staging buffer.
                let q_dim = m.q_dim();
                let kv_dim = m.kv_dim();
                let qkv = op.inputs[0];
                let paged = self.paged.lock().unwrap();
                if let Some(map) = paged.as_ref() {
                    // paged path: the target row lives wherever the
                    // block table says — possibly beyond this
                    // specialization's cache-tensor bounds (the tensor
                    // covers only the first `batch` slots of the layer
                    // segment), so address the slab directly. The
                    // engine's pre-epoch `ensure_append` guarantees
                    // every written block has exactly one referencing
                    // table (COW happened already), and this single
                    // KvAppend task is the only writer the event graph
                    // admits before the per-row attention reads — the
                    // same happens-before edge the contiguous path
                    // relies on, resolved through the same table.
                    let kbase = map.base_for(op.inputs[2])?;
                    let vbase = map.base_for(op.inputs[3])?;
                    let tables = self.tables.lock().unwrap();
                    let bt = map.block_tokens;
                    for r in 0..self.batch {
                        let (start, len) = tables.spans.get(r).copied().unwrap_or((0, 0));
                        if len == 0 {
                            continue; // vacant row: nothing to append
                        }
                        let table = &tables.flat[start..start + len];
                        let pos = self.row_len(r);
                        let b = pos / bt;
                        if b >= len {
                            return Err(format!(
                                "kv append at position {pos} beyond row {r}'s block table \
                                 ({len} blocks of {bt} tokens) — ensure_append missed a row"
                            ));
                        }
                        let off = (table[b] * bt + pos % bt) * kv_dim;
                        let krow = store.view_region(
                            qkv,
                            &Region::new(vec![(r, r + 1), (q_dim, q_dim + kv_dim)]),
                        );
                        map.slab.write(kbase + off, krow);
                        let vrow = store.view_region(
                            qkv,
                            &Region::new(vec![(r, r + 1), (q_dim + kv_dim, q_dim + 2 * kv_dim)]),
                        );
                        map.slab.write(vbase + off, vrow);
                    }
                } else {
                    for r in 0..self.batch {
                        let pos = self.row_len(r);
                        let row_r = Region::new(vec![(r, r + 1), (pos, pos + 1), (0, kv_dim)]);
                        let krow = store.view_region(
                            qkv,
                            &Region::new(vec![(r, r + 1), (q_dim, q_dim + kv_dim)]),
                        );
                        let mut kdst = store.tile_mut(op.inputs[2], &row_r);
                        kdst.as_slice_mut().expect("cache row is contiguous").copy_from_slice(krow);
                        drop(kdst);
                        let vrow = store.view_region(
                            qkv,
                            &Region::new(vec![(r, r + 1), (q_dim + kv_dim, q_dim + 2 * kv_dim)]),
                        );
                        let mut vdst = store.tile_mut(op.inputs[3], &row_r);
                        vdst.as_slice_mut().expect("cache row is contiguous").copy_from_slice(vrow);
                    }
                }
            }
            OpKind::Add => {
                let art = self.artifact(graph, op_id)?;
                let mut out = store.tile_mut(op.output, &self.out_full[op_id]);
                let dst = out.out_view().expect("whole-tensor output is contiguous");
                pool.execute_into(
                    art,
                    vec![
                        Value::Borrowed(store.view(op.inputs[0])),
                        Value::Borrowed(store.view(op.inputs[1])),
                    ],
                    &mut [dst],
                )?;
            }
            OpKind::SwiGLU => {
                let art = self.artifact(graph, op_id)?;
                let mut out = store.tile_mut(op.output, &self.out_full[op_id]);
                let dst = out.out_view().expect("whole-tensor output is contiguous");
                pool.execute_into(
                    art,
                    vec![Value::Borrowed(store.view(op.inputs[0]))],
                    &mut [dst],
                )?;
            }
            other => {
                return Err(format!("real path does not support op kind {other:?}"));
            }
        }
        Ok(())
    }
}

/// Executes tile tasks against the PJRT pool over borrowed
/// graph/store/pool (one-shot validation and example paths).
pub struct TileExecutor<'a> {
    pub graph: &'a CompGraph,
    pub store: &'a TensorStore,
    pub pool: &'a ExecPool,
    core: ExecCore,
}

impl<'a> TileExecutor<'a> {
    pub fn new(graph: &'a CompGraph, store: &'a TensorStore, pool: &'a ExecPool, batch: usize) -> Self {
        TileExecutor { graph, store, pool, core: ExecCore::new(graph, pool, batch) }
    }
}

/// Both front-ends deref to [`ExecCore`] for the shared control surface
/// (`batch` / `set_cur_len` / `set_row_lens` / `take_error`) instead of
/// duplicating delegation methods.
impl std::ops::Deref for TileExecutor<'_> {
    type Target = ExecCore;

    fn deref(&self) -> &ExecCore {
        &self.core
    }
}

impl TaskExecutor for TileExecutor<'_> {
    fn execute(&self, task: &TaskDesc) {
        self.core.execute_task(self.graph, self.store, self.pool, task);
    }
}

/// Owning executor for long-lived sessions: holds `Arc`s of the
/// compiled graph, the tensor arena, and the pool, so the serving
/// engine constructs nothing on the per-iteration hot path — it just
/// updates row lengths and re-arms the resident kernel with `&self`.
pub struct OwningTileExecutor {
    graph: Arc<CompiledGraph>,
    store: Arc<TensorStore>,
    pool: Arc<ExecPool>,
    core: ExecCore,
}

impl OwningTileExecutor {
    pub fn new(
        graph: Arc<CompiledGraph>,
        store: Arc<TensorStore>,
        pool: Arc<ExecPool>,
        batch: usize,
    ) -> Self {
        let core = ExecCore::new(&graph.graph, &pool, batch);
        OwningTileExecutor { graph, store, pool, core }
    }

    pub fn store(&self) -> &TensorStore {
        &self.store
    }

    pub fn graph(&self) -> &CompiledGraph {
        &self.graph
    }
}

impl std::ops::Deref for OwningTileExecutor {
    type Target = ExecCore;

    fn deref(&self) -> &ExecCore {
        &self.core
    }
}

impl TaskExecutor for OwningTileExecutor {
    fn execute(&self, task: &TaskDesc) {
        self.core.execute_task(&self.graph.graph, &self.store, &self.pool, task);
    }
}
