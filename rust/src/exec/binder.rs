//! Task → artifact binding: the executor that megakernel workers call
//! on the real-numerics path.
//!
//! Each compute task's tile is mapped to one AOT artifact plus input
//! slices from the [`TensorStore`]; results are written back to the
//! task's output tile. `KvAppend` is executed natively (pure cache
//! bookkeeping, zero flops — the §6.1 in-kernel KV metadata update).

use crate::exec::store::TensorStore;
use crate::megakernel::runtime::TaskExecutor;
use crate::ops::{CompGraph, OpKind, Region};
use crate::runtime::pool::{ExecPool, Value};
use crate::runtime::Manifest;
use crate::tgraph::{TaskDesc, TaskKind};
use std::sync::Mutex;

/// Executes tile tasks against the PJRT pool.
pub struct TileExecutor<'a> {
    pub graph: &'a CompGraph,
    pub store: &'a TensorStore,
    pub pool: &'a ExecPool,
    pub batch: usize,
    /// Valid cache length *before* this iteration's token, per batch
    /// row (continuous batching admits requests at different times, so
    /// rows carry different cache lengths). The new K/V row is written
    /// at this position.
    pub row_lens: Mutex<Vec<usize>>,
    /// First execution error, if any (the runtime has no error channel;
    /// tests assert this is None afterwards).
    pub error: Mutex<Option<String>>,
}

impl<'a> TileExecutor<'a> {
    pub fn new(graph: &'a CompGraph, store: &'a TensorStore, pool: &'a ExecPool, batch: usize) -> Self {
        TileExecutor {
            graph,
            store,
            pool,
            batch,
            row_lens: Mutex::new(vec![0; batch]),
            error: Mutex::new(None),
        }
    }

    /// Uniform cache length for all rows (the validation path).
    pub fn set_cur_len(&self, l: usize) {
        let mut g = self.row_lens.lock().unwrap();
        g.iter_mut().for_each(|x| *x = l);
    }

    /// Per-row cache lengths (continuous batching).
    pub fn set_row_lens(&self, lens: &[usize]) {
        let mut g = self.row_lens.lock().unwrap();
        assert_eq!(lens.len(), self.batch);
        g.copy_from_slice(lens);
    }

    fn row_len(&self, r: usize) -> usize {
        self.row_lens.lock().unwrap()[r]
    }

    pub fn take_error(&self) -> Option<String> {
        self.error.lock().unwrap().take()
    }

    fn fail(&self, e: String) {
        let mut g = self.error.lock().unwrap();
        if g.is_none() {
            *g = Some(e);
        }
    }

    fn meta(&self) -> &Manifest {
        self.pool.manifest()
    }

    fn run_compute(&self, op_id: usize, kind: &OpKind, out_region: &Region) -> Result<(), String> {
        let op = &self.graph.ops[op_id];
        let b = self.batch;
        let m = self.meta().model;
        match kind {
            OpKind::Embedding => {
                let ids: Vec<i32> =
                    self.store.get(op.inputs[0]).iter().map(|&v| v as i32).collect();
                let table = self.store.get(op.inputs[1]);
                let out = self
                    .pool
                    .execute_by_name(&format!("embed_b{b}"), vec![Value::I32(ids), Value::F32(table)])?;
                self.store.set(op.output, out.into_iter().next().unwrap());
            }
            OpKind::RmsNorm => {
                let x = self.store.get(op.inputs[0]);
                let w = self.store.get(op.inputs[1]);
                let out =
                    self.pool.execute_by_name(&format!("rmsnorm_b{b}"), vec![Value::F32(x), Value::F32(w)])?;
                self.store.set(op.output, out.into_iter().next().unwrap());
            }
            OpKind::MatMul => {
                let k = self.graph.tensor(op.inputs[0]).shape[1];
                let (c0, c1) = out_region.dims[1];
                let tile_n = self.meta().tile_n;
                if c1 - c0 != tile_n {
                    return Err(format!(
                        "matmul tile width {} != artifact tile {}",
                        c1 - c0,
                        tile_n
                    ));
                }
                let x = self.store.get(op.inputs[0]);
                let w = self.store.read_tile(op.inputs[1], &Region::new(vec![(0, k), (c0, c1)]));
                let out = self.pool.execute_by_name(
                    &format!("matmul_b{b}_k{k}_n{tile_n}"),
                    vec![Value::F32(x), Value::F32(w)],
                )?;
                self.store.write_tile(op.output, out_region, &out.into_iter().next().unwrap());
            }
            OpKind::Attention { .. } => {
                // one task per request row.
                let (r0, r1) = out_region.dims[0];
                debug_assert_eq!(r1 - r0, 1, "attention tasks are per-request");
                let r = r0;
                let q_dim = m.q_dim();
                let kv_dim = m.kv_dim();
                let s_max = self.meta().s_max;
                // inputs: [qkv, kcache, vcache, kv_new]
                let q = self.store.read_tile(op.inputs[0], &Region::new(vec![(r, r + 1), (0, q_dim)]));
                let kc = self
                    .store
                    .read_tile(op.inputs[1], &Region::new(vec![(r, r + 1), (0, s_max), (0, kv_dim)]));
                let vc = self
                    .store
                    .read_tile(op.inputs[2], &Region::new(vec![(r, r + 1), (0, s_max), (0, kv_dim)]));
                let valid = self.row_len(r) + 1;
                let out = self.pool.execute_by_name(
                    "attn_q1",
                    vec![Value::F32(q), Value::F32(kc), Value::F32(vc), Value::I32(vec![valid as i32])],
                )?;
                self.store.write_tile(
                    op.output,
                    &Region::new(vec![(r, r + 1), (0, q_dim)]),
                    &out.into_iter().next().unwrap(),
                );
            }
            OpKind::KvAppend => {
                // native: copy this step's K/V rows from the fused qkv
                // output into the caches at position cur_len.
                let q_dim = m.q_dim();
                let kv_dim = m.kv_dim();
                let qkv = op.inputs[0];
                for r in 0..b {
                    let pos = self.row_len(r);
                    let krow = self
                        .store
                        .read_tile(qkv, &Region::new(vec![(r, r + 1), (q_dim, q_dim + kv_dim)]));
                    let vrow = self.store.read_tile(
                        qkv,
                        &Region::new(vec![(r, r + 1), (q_dim + kv_dim, q_dim + 2 * kv_dim)]),
                    );
                    self.store.write_tile(
                        op.inputs[2],
                        &Region::new(vec![(r, r + 1), (pos, pos + 1), (0, kv_dim)]),
                        &krow,
                    );
                    self.store.write_tile(
                        op.inputs[3],
                        &Region::new(vec![(r, r + 1), (pos, pos + 1), (0, kv_dim)]),
                        &vrow,
                    );
                }
            }
            OpKind::Add => {
                let a = self.store.get(op.inputs[0]);
                let c = self.store.get(op.inputs[1]);
                let out =
                    self.pool.execute_by_name(&format!("add_b{b}"), vec![Value::F32(a), Value::F32(c)])?;
                self.store.set(op.output, out.into_iter().next().unwrap());
            }
            OpKind::SwiGLU => {
                let gu = self.store.get(op.inputs[0]);
                let out = self.pool.execute_by_name(&format!("swiglu_b{b}"), vec![Value::F32(gu)])?;
                self.store.set(op.output, out.into_iter().next().unwrap());
            }
            other => {
                return Err(format!("real path does not support op kind {other:?}"));
            }
        }
        Ok(())
    }
}

impl TaskExecutor for TileExecutor<'_> {
    fn execute(&self, task: &TaskDesc) {
        if let TaskKind::Compute { op, kind } = &task.kind {
            if let Err(e) = self.run_compute(*op, kind, &task.out_region) {
                self.fail(format!("task {} ({}): {e}", task.id, self.graph.ops[*op].name));
            }
        }
    }
}
