//! Real-numerics execution of compiled tGraphs: flat tensor arena with
//! zero-copy views, task → artifact binding over borrowed slices, and
//! the end-to-end validated decode path.
pub mod binder;
pub mod real;
pub mod store;

pub use binder::{ExecCore, OwningTileExecutor, TaskError, TileExecutor};
pub use real::{
    build_real_graph, compile_real, init_weights, run_iteration, run_reference, RealSession,
    WeightArena,
};
pub use store::{SharedSlab, StoreCounters, TensorStore, TileView, TileViewMut};
