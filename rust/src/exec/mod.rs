//! Real-numerics execution of compiled tGraphs: tensor store, task →
//! artifact binding, and the end-to-end validated decode path.
pub mod binder;
pub mod real;
pub mod store;

pub use binder::TileExecutor;
pub use real::{build_real_graph, compile_real, init_weights, run_iteration, run_reference, RealSession};
pub use store::TensorStore;
