//! Tensor store for the real-numerics path.
//!
//! One host buffer per computation-graph tensor (weights, activations,
//! KV caches), each behind its own mutex. Tasks hold a lock only while
//! memcpy-ing a tile in or out — the actual math happens in the PJRT
//! pool — so contention stays negligible at tiny-model scale. Buffers
//! are f32 throughout; integer tensors (token ids) store exact small
//! ints and are converted at the artifact boundary.

use crate::ops::{CompGraph, Region, TensorId};
use std::sync::Mutex;

/// Named f32 buffers, indexed by graph tensor id.
pub struct TensorStore {
    bufs: Vec<Mutex<Vec<f32>>>,
    shapes: Vec<Vec<usize>>,
}

impl TensorStore {
    /// Zero-initialized buffers for every tensor of `g`.
    pub fn new(g: &CompGraph) -> Self {
        TensorStore {
            bufs: g.tensors.iter().map(|t| Mutex::new(vec![0.0; t.numel()])).collect(),
            shapes: g.tensors.iter().map(|t| t.shape.clone()).collect(),
        }
    }

    pub fn shape(&self, t: TensorId) -> &[usize] {
        &self.shapes[t]
    }

    /// Replace the whole buffer.
    pub fn set(&self, t: TensorId, data: Vec<f32>) {
        let mut b = self.bufs[t].lock().unwrap();
        assert_eq!(b.len(), data.len(), "tensor {t} size mismatch");
        *b = data;
    }

    /// Copy of the whole buffer.
    pub fn get(&self, t: TensorId) -> Vec<f32> {
        self.bufs[t].lock().unwrap().clone()
    }

    /// Copy out an axis-aligned tile.
    pub fn read_tile(&self, t: TensorId, r: &Region) -> Vec<f32> {
        let shape = &self.shapes[t];
        assert_eq!(r.rank(), shape.len(), "tile rank mismatch for tensor {t}");
        let buf = self.bufs[t].lock().unwrap();
        let mut out = Vec::with_capacity(r.numel());
        copy_region(&buf, shape, r, &mut |src| out.extend_from_slice(src));
        out
    }

    /// Copy a tile in (row-major within the tile).
    pub fn write_tile(&self, t: TensorId, r: &Region, data: &[f32]) {
        let shape = self.shapes[t].clone();
        assert_eq!(r.numel(), data.len(), "tile data size mismatch for tensor {t}");
        let mut buf = self.bufs[t].lock().unwrap();
        let mut offset = 0;
        write_region(&mut buf, &shape, r, &mut |dst| {
            dst.copy_from_slice(&data[offset..offset + dst.len()]);
            offset += dst.len();
        });
    }

    /// Copy a tile directly from another tensor into this one, run by
    /// run, without materializing the tile in between — the KV
    /// migration path for slot remaps, both across batch-size-
    /// specialized session stores and within one store's cache tensor.
    ///
    /// Panics if the regions' per-dimension extents differ, or if
    /// source and destination are the same tensor with *overlapping*
    /// regions (slot moves are always disjoint). For distinct tensors
    /// it locks source then destination: callers copying concurrently
    /// in opposite directions between the same pair of tensors could
    /// deadlock — the serving engine only migrates from the
    /// single-threaded staging phase.
    pub fn copy_tile_from(
        &self,
        t: TensorId,
        r: &Region,
        src: &TensorStore,
        src_t: TensorId,
        src_r: &Region,
    ) {
        assert_eq!(r.rank(), src_r.rank(), "tile rank mismatch");
        for (d, (a, b)) in r.dims.iter().zip(src_r.dims.iter()).enumerate() {
            assert_eq!(a.1 - a.0, b.1 - b.0, "extent mismatch in dim {d}");
        }
        let run = run_len(r);
        if std::ptr::eq(self, src) && t == src_t {
            // intra-tensor move (slot compaction): one lock, run-wise
            // copy_within. Axis-aligned regions are disjoint iff the
            // ranges of some dimension are.
            assert!(
                r.dims
                    .iter()
                    .zip(src_r.dims.iter())
                    .any(|(&(d0, d1), &(s0, s1))| d1 <= s0 || s1 <= d0),
                "same-tensor copy_tile_from requires disjoint regions"
            );
            let mut src_bases = Vec::new();
            for_each_run(&self.shapes[t], src_r, &mut |b| src_bases.push(b));
            let mut buf = self.bufs[t].lock().unwrap();
            let mut i = 0;
            for_each_run(&self.shapes[t], r, &mut |b| {
                buf.copy_within(src_bases[i]..src_bases[i] + run, b);
                i += 1;
            });
            return;
        }
        let mut dst_bases = Vec::new();
        for_each_run(&self.shapes[t], r, &mut |b| dst_bases.push(b));
        let src_buf = src.bufs[src_t].lock().unwrap();
        let mut dst_buf = self.bufs[t].lock().unwrap();
        let mut i = 0;
        for_each_run(&src.shapes[src_t], src_r, &mut |b| {
            dst_buf[dst_bases[i]..dst_bases[i] + run].copy_from_slice(&src_buf[b..b + run]);
            i += 1;
        });
    }
}

/// Length of the contiguous innermost run of `region`.
fn run_len(region: &Region) -> usize {
    let (s, e) = region.dims[region.rank() - 1];
    e - s
}

/// Call `f(base)` with the row-major start offset of each contiguous
/// innermost run of `region` within a buffer of `shape`, in region
/// row-major order.
fn for_each_run(shape: &[usize], region: &Region, f: &mut impl FnMut(usize)) {
    let rank = shape.len();
    let (last_s, _) = region.dims[rank - 1];
    let mut strides = vec![1usize; rank];
    for d in (0..rank - 1).rev() {
        strides[d] = strides[d + 1] * shape[d + 1];
    }
    let mut idx: Vec<usize> = region.dims[..rank - 1].iter().map(|&(s, _)| s).collect();
    loop {
        let base: usize =
            idx.iter().zip(&strides[..rank - 1]).map(|(&i, &st)| i * st).sum::<usize>() + last_s;
        f(base);
        // advance multi-index over the outer dims.
        let mut d = rank.wrapping_sub(2);
        loop {
            if d == usize::MAX {
                return;
            }
            idx[d] += 1;
            if idx[d] < region.dims[d].1 {
                break;
            }
            idx[d] = region.dims[d].0;
            d = d.wrapping_sub(1);
        }
    }
}

/// Walk the contiguous innermost runs of `region` within a row-major
/// buffer of `shape`, calling `f` with each source slice.
fn copy_region(buf: &[f32], shape: &[usize], region: &Region, f: &mut impl FnMut(&[f32])) {
    let run = run_len(region);
    for_each_run(shape, region, &mut |base| f(&buf[base..base + run]));
}

fn write_region(buf: &mut [f32], shape: &[usize], region: &Region, f: &mut impl FnMut(&mut [f32])) {
    let run = run_len(region);
    for_each_run(shape, region, &mut |base| f(&mut buf[base..base + run]));
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::{DType, OpKind};

    fn store_2d() -> (TensorStore, TensorId) {
        let mut g = CompGraph::new();
        let t = g.input("x", vec![4, 6], DType::F32);
        let w = g.param("w", vec![6, 2], DType::F32);
        g.op("y", OpKind::MatMul, &[t, w], vec![4, 2], DType::F32);
        (TensorStore::new(&g), t)
    }

    #[test]
    fn whole_tensor_roundtrip() {
        let (s, t) = store_2d();
        let data: Vec<f32> = (0..24).map(|i| i as f32).collect();
        s.set(t, data.clone());
        assert_eq!(s.get(t), data);
    }

    #[test]
    fn tile_read_matches_manual_slice() {
        let (s, t) = store_2d();
        s.set(t, (0..24).map(|i| i as f32).collect());
        // rows 1..3, cols 2..5 of a 4x6 row-major buffer
        let tile = s.read_tile(t, &Region::new(vec![(1, 3), (2, 5)]));
        assert_eq!(tile, vec![8.0, 9.0, 10.0, 14.0, 15.0, 16.0]);
    }

    #[test]
    fn tile_write_then_read() {
        let (s, t) = store_2d();
        let r = Region::new(vec![(2, 4), (0, 3)]);
        s.write_tile(t, &r, &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(s.read_tile(t, &r), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        // untouched region stays zero
        assert_eq!(s.read_tile(t, &Region::new(vec![(0, 2), (0, 6)])), vec![0.0; 12]);
    }

    #[test]
    fn rank3_tiles() {
        let mut g = CompGraph::new();
        let t = g.input("c", vec![2, 3, 4], DType::F32);
        let s = TensorStore::new(&g);
        s.set(t, (0..24).map(|i| i as f32).collect());
        // [1:2, 0:3, 1:3]
        let tile = s.read_tile(t, &Region::new(vec![(1, 2), (0, 3), (1, 3)]));
        assert_eq!(tile, vec![13.0, 14.0, 17.0, 18.0, 21.0, 22.0]);
        // write a row of the cache (KvAppend pattern)
        s.write_tile(t, &Region::new(vec![(0, 1), (2, 3), (0, 4)]), &[9.0; 4]);
        let back = s.read_tile(t, &Region::new(vec![(0, 1), (2, 3), (0, 4)]));
        assert_eq!(back, vec![9.0; 4]);
    }

    #[test]
    fn copy_tile_from_between_stores() {
        // two stores with different batch dims, as in KV migration
        // between batch-size-specialized sessions.
        let mut g_src = CompGraph::new();
        let ts = g_src.input("kc", vec![2, 4, 3], DType::F32);
        let src = TensorStore::new(&g_src);
        src.set(ts, (0..24).map(|i| i as f32).collect());

        let mut g_dst = CompGraph::new();
        let td = g_dst.input("kc", vec![4, 4, 3], DType::F32);
        let dst = TensorStore::new(&g_dst);

        // migrate src slot 1, rows 0..2 → dst slot 3, rows 0..2.
        dst.copy_tile_from(
            td,
            &Region::new(vec![(3, 4), (0, 2), (0, 3)]),
            &src,
            ts,
            &Region::new(vec![(1, 2), (0, 2), (0, 3)]),
        );
        let got = dst.read_tile(td, &Region::new(vec![(3, 4), (0, 2), (0, 3)]));
        let want = src.read_tile(ts, &Region::new(vec![(1, 2), (0, 2), (0, 3)]));
        assert_eq!(got, want);
        assert_eq!(got, vec![12.0, 13.0, 14.0, 15.0, 16.0, 17.0]);
        // rest of dst untouched.
        assert_eq!(dst.read_tile(td, &Region::new(vec![(0, 3), (0, 4), (0, 3)])), vec![0.0; 36]);
    }

    #[test]
    fn copy_tile_from_different_tensors_same_store() {
        let mut g = CompGraph::new();
        let a = g.input("a", vec![2, 6], DType::F32);
        let b = g.input("b", vec![2, 6], DType::F32);
        let s = TensorStore::new(&g);
        s.set(a, (0..12).map(|i| i as f32).collect());
        s.copy_tile_from(b, &Region::new(vec![(0, 2), (0, 6)]), &s, a, &Region::new(vec![(0, 2), (0, 6)]));
        assert_eq!(s.get(b), s.get(a));
    }

    #[test]
    fn copy_tile_from_same_tensor_disjoint_slots() {
        // intra-tensor slot compaction: move slot 2's rows into slot 0.
        let mut g = CompGraph::new();
        let t = g.input("kc", vec![3, 4, 2], DType::F32);
        let s = TensorStore::new(&g);
        s.set(t, (0..24).map(|i| i as f32).collect());
        let src = Region::new(vec![(2, 3), (0, 3), (0, 2)]);
        let want = s.read_tile(t, &src);
        s.copy_tile_from(t, &Region::new(vec![(0, 1), (0, 3), (0, 2)]), &s, t, &src);
        assert_eq!(s.read_tile(t, &Region::new(vec![(0, 1), (0, 3), (0, 2)])), want);
        // source slot is left as-is (dead data for the engine).
        assert_eq!(s.read_tile(t, &src), want);
    }

    #[test]
    #[should_panic(expected = "disjoint regions")]
    fn copy_tile_from_same_tensor_overlap_panics() {
        let (s, t) = store_2d();
        s.copy_tile_from(
            t,
            &Region::new(vec![(0, 2), (0, 6)]),
            &s,
            t,
            &Region::new(vec![(1, 3), (0, 6)]),
        );
    }

    #[test]
    fn concurrent_disjoint_tile_writes() {
        let (s, t) = store_2d();
        std::thread::scope(|sc| {
            for row in 0..4 {
                let s = &s;
                sc.spawn(move || {
                    s.write_tile(t, &Region::new(vec![(row, row + 1), (0, 6)]), &[row as f32; 6]);
                });
            }
        });
        for row in 0..4 {
            let tile = s.read_tile(t, &Region::new(vec![(row, row + 1), (0, 6)]));
            assert_eq!(tile, vec![row as f32; 6]);
        }
    }
}
